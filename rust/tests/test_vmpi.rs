//! Integration: the vmpi substrate under realistic concurrent load,
//! including the spawn + state-transfer choreography the resize protocol
//! relies on.

use dmr::dmr::{expand_dest, merge_rows, shrink_role, split_rows, ShrinkRole, StateMsg};
use dmr::vmpi::{f32s_to_bytes, RecvSelector, World, TAG_STATE};

#[test]
fn heavy_pingpong_many_ranks() {
    let w = World::new();
    let gid = w.spawn(16, |ep| {
        let n = ep.size();
        let r = ep.rank();
        // Ring: send to (r+1)%n, receive from (r-1+n)%n, 50 rounds.
        for round in 0..50u64 {
            ep.send((r + 1) % n, round, f32s_to_bytes(&[r as f32, round as f32]));
            let m = ep.recv(RecvSelector::from_rank(ep.group(), (r + n - 1) % n, round));
            let v = dmr::vmpi::bytes_to_f32s(&m.payload);
            assert_eq!(v[0] as usize, (r + n - 1) % n);
            assert_eq!(v[1] as u64, round);
        }
        ep.barrier();
    });
    w.join_group(gid);
}

#[test]
fn allreduce_stress_is_consistent() {
    let w = World::new();
    let gid = w.spawn(8, |ep| {
        let mut acc = 0.0;
        for i in 0..100 {
            let s = ep.allreduce_sum((ep.rank() * i) as f64);
            acc += s;
        }
        // sum over ranks of r*i = i * (0+..+7) = 28 i; total = 28 * 4950
        assert_eq!(acc, 28.0 * 4950.0);
    });
    w.join_group(gid);
}

/// The expand choreography: an old group of 2 spawns a new group of 4 and
/// hands over sharded state; the new shards tile the old data exactly.
#[test]
fn spawn_and_expand_state_transfer() {
    let w = World::new();
    let row = 2usize;
    let global: Vec<f32> = (0..32).map(|x| x as f32).collect(); // 16 rows

    let (done_tx, done_rx) = std::sync::mpsc::channel::<(usize, Vec<f32>)>();

    let w2 = w.clone();
    let g_old = {
        let global = global.clone();
        w.spawn(2, move |ep| {
            let size = ep.size();
            let rows = 16 / size;
            let shard =
                global[ep.rank() * rows * row..(ep.rank() + 1) * rows * row].to_vec();

            // rank0 spawns the new group; everyone learns its id via bcast.
            let new_gid = if ep.rank() == 0 {
                let done_tx = done_tx.clone();
                let gid = w2.spawn(4, move |nep| {
                    let m = nep.recv(RecvSelector::tag(TAG_STATE));
                    let sm = StateMsg::decode(&m.payload).expect("state frame decodes");
                    assert_eq!(sm.iter, 7);
                    done_tx.send((nep.rank(), sm.data)).unwrap();
                });
                ep.bcast(Some(gid.to_le_bytes().to_vec()));
                gid
            } else {
                u64::from_le_bytes(ep.bcast(None).try_into().unwrap())
            };

            let factor = 2;
            let parts = split_rows(&shard, row, factor);
            for (i, p) in parts.into_iter().enumerate() {
                let sm = StateMsg { iter: 7, inhibit_last: 0.0, scalars: vec![], data: p };
                ep.send_to_group(new_gid, expand_dest(ep.rank(), factor, i), TAG_STATE, sm.encode());
            }
        })
    };
    w.join_group(g_old);

    let mut shards: Vec<(usize, Vec<f32>)> = (0..4).map(|_| done_rx.recv().unwrap()).collect();
    shards.sort_by_key(|(r, _)| *r);
    let reassembled: Vec<f32> = shards.into_iter().flat_map(|(_, d)| d).collect();
    assert_eq!(reassembled, global);
}

/// The shrink merge: 4 ranks merge pairwise at the receivers; the merged
/// blocks tile the original data.
#[test]
fn shrink_merge_state_transfer() {
    let w = World::new();
    let row = 3usize;
    let global: Vec<f32> = (0..48).map(|x| x as f32).collect(); // 16 rows
    let (done_tx, done_rx) = std::sync::mpsc::channel::<(usize, Vec<f32>)>();

    let g = {
        let global = global.clone();
        w.spawn(4, move |ep| {
            let rows = 16 / ep.size();
            let shard =
                global[ep.rank() * rows * row..(ep.rank() + 1) * rows * row].to_vec();
            let factor = 2;
            match shrink_role(ep.rank(), factor) {
                ShrinkRole::Sender { dst } => {
                    ep.send(dst, TAG_STATE, f32s_to_bytes(&shard));
                }
                ShrinkRole::Receiver { srcs, new_dst } => {
                    let mut parts = Vec::new();
                    for s in srcs {
                        let m = ep.recv(RecvSelector::from_rank(ep.group(), s, TAG_STATE));
                        parts.push(dmr::vmpi::bytes_to_f32s(&m.payload));
                    }
                    parts.push(shard);
                    done_tx.send((new_dst, merge_rows(parts))).unwrap();
                }
            }
        })
    };
    w.join_group(g);

    let mut merged: Vec<(usize, Vec<f32>)> = (0..2).map(|_| done_rx.recv().unwrap()).collect();
    merged.sort_by_key(|(r, _)| *r);
    let reassembled: Vec<f32> = merged.into_iter().flat_map(|(_, d)| d).collect();
    assert_eq!(reassembled, global);
}

#[test]
fn large_payload_throughput() {
    // 64 MB moved through a mailbox — sanity for the Fig. 3(b) study.
    let w = World::new();
    let (_g, eps) = w.create_group(2);
    let data = vec![0u8; 64 << 20];
    let t0 = std::time::Instant::now();
    eps[0].send(1, 1, data);
    let m = eps[1].recv(RecvSelector::tag(1));
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(m.payload.len(), 64 << 20);
    // Ownership transfer: must be far faster than a memcpy-bound network.
    assert!(dt < 1.0, "64MB took {dt}s");
}
