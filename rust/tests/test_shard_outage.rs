//! Shard-level failure-domain tests: correlated outages, partitions, and
//! cross-shard failover.
//!
//! Three load-bearing properties:
//!
//! 1. **Fold to baseline** — with the outage layer absent *or* present
//!    but inactive, every per-shard event-log digest and the makespan
//!    bits are identical to a pre-outage run.  This is the determinism
//!    contract that lets `[federation.outages] enabled = [false, true]`
//!    campaign points share control rows with outage-free builds.
//! 2. **Outage timeline independence** — a scripted whole-shard outage
//!    fires at the same simulated times under every routing policy, and
//!    repeating a run reproduces every digest bit for bit.
//! 3. **Exactly-once failover** — under a whole-shard outage (alone or
//!    stacked on machine faults and drains) no job is ever lost: every
//!    interrupted job is rescued, requeued, or evacuated exactly once,
//!    and every evacuation lands on exactly one surviving shard.

use dmr::des::DesConfig;
use dmr::dmr::SchedMode;
use dmr::federation::{
    FedEngine, FederationConfig, FedRunResult, RoutingPolicy, ShardSpec, StealPolicy,
};
use dmr::resilience::{
    DrainSet, DrainWindow, FailureDomain, FaultKind, FaultSpec, FaultTraceEvent, OutageEvent,
    OutageSpec, PartitionWindow, RecoveryConfig, ResilienceConfig,
};
use dmr::rms::RmsConfig;
use dmr::workload::{self, WorkloadSpec};

const JOBS: usize = 40;

fn base_cfg(sched: SchedMode, faulty: bool) -> DesConfig {
    let resilience = if faulty {
        ResilienceConfig {
            faults: FaultSpec {
                mtbf: 60_000.0,
                mttr: 1_000.0,
                scripted: vec![FaultTraceEvent { at: 300.0, node: 1, kind: FaultKind::Fail }],
                drains: vec![DrainWindow {
                    start: 1_500.0,
                    end: 3_000.0,
                    nodes: DrainSet::Count(6),
                }],
            },
            recovery: RecoveryConfig { checkpoint_interval: 500.0, ..Default::default() },
            ..Default::default()
        }
    } else {
        ResilienceConfig::default()
    };
    DesConfig {
        rms: RmsConfig { nodes: 64, ..Default::default() },
        mode: sched,
        resilience,
        ..Default::default()
    }
}

fn stream(flexible: bool) -> WorkloadSpec {
    let w = workload::generate(JOBS, 17);
    if flexible {
        w
    } else {
        w.as_fixed()
    }
}

/// A whole-shard outage on shard 0: dark at t=500 for 1500 s.  By t=500
/// the whole stream has arrived, so round-robin guarantees shard 0 holds
/// live work when the lights go out.
fn shard0_blackout() -> Vec<OutageSpec> {
    vec![
        OutageSpec {
            scripted: vec![OutageEvent { domain: String::new(), at: 500.0, duration: 1_500.0 }],
            ..Default::default()
        },
        OutageSpec::default(),
    ]
}

fn fed_run(
    cfg: DesConfig,
    routing: RoutingPolicy,
    steal: StealPolicy,
    outages: Option<Vec<OutageSpec>>,
    w: &WorkloadSpec,
    label: &str,
) -> FedRunResult {
    FedEngine::new(
        cfg,
        FederationConfig {
            shards: ShardSpec::uniform(64, 2),
            routing,
            steal,
            outages,
            ..Default::default()
        },
    )
    .run(w, label)
}

fn digests(r: &FedRunResult) -> Vec<u64> {
    r.shards.iter().map(|s| s.rms.log.digest()).collect()
}

fn completed(r: &FedRunResult) -> usize {
    r.shards.iter().map(|s| s.rms.completed_jobs()).sum()
}

/// Per-shard failure ledger: every interrupted job is accounted for by
/// exactly one of rescue, local requeue, or cross-shard evacuation.
fn assert_ledger(r: &FedRunResult, tag: &str) {
    for sh in &r.shards {
        assert_eq!(
            sh.stats.interrupted,
            sh.stats.rescued + sh.stats.requeued + sh.stats.evacuated,
            "{tag}: shard {} ledger",
            sh.shard
        );
        assert_eq!(
            sh.rms.log.evacuations() as u64,
            sh.evac_out,
            "{tag}: shard {} evac events match the counter",
            sh.shard
        );
    }
    assert_eq!(
        r.evacuations(),
        r.cross_shard_requeues(),
        "{tag}: every evacuated job lands on exactly one shard"
    );
    assert_eq!(
        r.resilience.evacuated,
        r.evacuations(),
        "{tag}: merged resilience stats agree with the shard counters"
    );
}

// ------------------------------------------------------------ fold-off

#[test]
fn inactive_outage_layer_folds_to_baseline() {
    for faulty in [false, true] {
        for (mode, sched, flexible) in
            [("fixed", SchedMode::Sync, false), ("sync", SchedMode::Sync, true)]
        {
            let w = stream(flexible);
            let run = |outages: Option<Vec<OutageSpec>>| {
                fed_run(
                    base_cfg(sched, faulty),
                    RoutingPolicy::RoundRobin,
                    StealPolicy::Head,
                    outages,
                    &w,
                    mode,
                )
            };
            let absent = run(None);
            // Present but inactive: empty vector, and default (inert) specs.
            for (form, outages) in [
                ("empty vec", Some(vec![])),
                ("inert specs", Some(vec![OutageSpec::default(), OutageSpec::default()])),
                (
                    "domains only",
                    // Declared domains with no outage source are inert too.
                    Some(vec![
                        OutageSpec {
                            domains: vec![FailureDomain {
                                name: "rackA".into(),
                                nodes: DrainSet::Count(8),
                            }],
                            ..Default::default()
                        },
                        OutageSpec::default(),
                    ]),
                ),
            ] {
                let r = run(outages);
                let tag = format!("{mode} faulty={faulty} ({form})");
                assert_eq!(digests(&r), digests(&absent), "{tag}: per-shard digests");
                assert_eq!(
                    r.makespan.to_bits(),
                    absent.makespan.to_bits(),
                    "{tag}: makespan bits"
                );
                assert_eq!(r.events, absent.events, "{tag}: event count");
                assert_eq!(r.evacuations(), 0, "{tag}: nothing to evacuate");
            }
        }
    }
}

// ----------------------------------------------- timeline independence

#[test]
fn scripted_outage_timeline_is_routing_independent_and_deterministic() {
    let w = stream(true);
    let routings =
        [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded, RoutingPolicy::Locality];
    for routing in routings {
        let run = || {
            fed_run(
                base_cfg(SchedMode::Sync, false),
                routing,
                StealPolicy::Head,
                Some(shard0_blackout()),
                &w,
                routing.label(),
            )
        };
        let a = run();
        let b = run();
        let tag = routing.label();
        // The outage timeline is scripted, so it is identical under every
        // routing policy: exactly one blackout, on shard 0 only.  (The
        // recovery marker only lands if the run outlives t=2000 — the
        // engine stops at the last completion — so it is at most one.)
        assert_eq!(a.shards[0].rms.log.shard_downs(), 1, "{tag}: shard 0 went down once");
        assert!(a.shards[0].rms.log.shard_ups() <= 1, "{tag}: at most one recovery");
        assert_eq!(a.shards[1].rms.log.shard_downs(), 0, "{tag}: shard 1 untouched");
        assert_eq!(completed(&a), JOBS, "{tag}: every job completes");
        assert_ledger(&a, tag);
        // Bit-for-bit reproducibility under outages.
        assert_eq!(digests(&a), digests(&b), "{tag}: repeat digests");
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{tag}: repeat makespan");
        assert_eq!(a.evacuations(), b.evacuations(), "{tag}: repeat evacuations");
    }
}

// -------------------------------------------------- exactly-once failover

#[test]
fn whole_shard_outage_evacuates_malleable_work_exactly_once() {
    let w = stream(true);
    let r = fed_run(
        base_cfg(SchedMode::Sync, false),
        RoutingPolicy::RoundRobin,
        StealPolicy::Head,
        Some(shard0_blackout()),
        &w,
        "evac",
    );
    assert_eq!(completed(&r), JOBS, "outages displace work, they never lose it");
    assert!(
        r.evacuations() > 0,
        "shard 0 held live malleable jobs at t=500; they must fail over"
    );
    assert!(
        r.shards[1].evac_in > 0 && r.shards[0].evac_out == r.shards[1].evac_in,
        "evacuees from shard 0 land on the surviving shard 1"
    );
    assert_ledger(&r, "evac");
    assert!(
        r.shards[0].stats.availability < 1.0,
        "the blackout must show up in shard 0 availability"
    );
}

#[test]
fn rigid_jobs_requeue_locally_instead_of_evacuating() {
    let w = stream(false);
    let r = fed_run(
        base_cfg(SchedMode::Sync, false),
        RoutingPolicy::RoundRobin,
        StealPolicy::Off,
        Some(shard0_blackout()),
        &w,
        "rigid",
    );
    assert_eq!(completed(&r), JOBS, "rigid work survives by waiting out the outage");
    assert_eq!(r.evacuations(), 0, "rigid jobs cannot carry state across shards");
    assert!(
        r.shards[0].stats.interrupted > 0 && r.shards[0].stats.requeued > 0,
        "interrupted rigid jobs were killed and requeued locally"
    );
    // The requeued jobs can only restart once shard 0 repairs, so the run
    // outlives the outage and both timeline markers land.
    assert_eq!(r.shards[0].rms.log.shard_downs(), 1, "blackout logged");
    assert_eq!(r.shards[0].rms.log.shard_ups(), 1, "recovery logged");
    assert!(r.makespan > 2_000.0, "shard 0's queue waited out the 1500 s outage");
    assert_ledger(&r, "rigid");
}

#[test]
fn evacuation_is_exactly_once_under_combined_faults() {
    // Machine faults + drains + a whole-shard outage, stacked: the ledger
    // and the completion count must still close exactly.
    for (mode, sched, flexible) in
        [("fixed", SchedMode::Sync, false), ("sync", SchedMode::Sync, true)]
    {
        let w = stream(flexible);
        let run = || {
            fed_run(
                base_cfg(sched, true),
                RoutingPolicy::LeastLoaded,
                StealPolicy::Half,
                Some(shard0_blackout()),
                &w,
                mode,
            )
        };
        let r = run();
        let tag = format!("{mode} combined");
        assert_eq!(completed(&r), JOBS, "{tag}: every job completes");
        assert_ledger(&r, &tag);
        // Stacked fault sources stay deterministic.
        let b = run();
        assert_eq!(digests(&r), digests(&b), "{tag}: repeat digests");
    }
}

// ------------------------------------------------------------ partitions

#[test]
fn partitions_suppress_cross_shard_traffic_without_losing_work() {
    let w = stream(true);
    let outages = vec![
        OutageSpec::default(),
        OutageSpec {
            partitions: vec![PartitionWindow { start: 200.0, end: 1_200.0 }],
            ..Default::default()
        },
    ];
    let run = |outages: Option<Vec<OutageSpec>>| {
        fed_run(
            base_cfg(SchedMode::Sync, false),
            RoutingPolicy::LeastLoaded,
            StealPolicy::Head,
            outages,
            &w,
            "part",
        )
    };
    let r = run(Some(outages));
    assert_eq!(completed(&r), JOBS, "partitioned shards keep running local work");
    assert_eq!(r.shards[1].rms.log.partitions(), 1, "one partition window on shard 1");
    assert_eq!(r.shards[0].rms.log.partitions(), 0, "shard 0 never partitioned");
    assert_eq!(r.evacuations(), 0, "partitions do not interrupt running jobs");
    assert_ledger(&r, "part");
    // Determinism holds with partitions in play.
    let b = run(Some(vec![
        OutageSpec::default(),
        OutageSpec {
            partitions: vec![PartitionWindow { start: 200.0, end: 1_200.0 }],
            ..Default::default()
        },
    ]));
    assert_eq!(digests(&r), digests(&b), "partition runs reproduce bit for bit");
}

// ----------------------------------------------------- named domains

#[test]
fn named_domain_outage_downs_only_its_members() {
    let w = stream(true);
    let outages = vec![
        OutageSpec {
            domains: vec![FailureDomain { name: "rackA".into(), nodes: DrainSet::Count(8) }],
            scripted: vec![OutageEvent { domain: "rackA".into(), at: 500.0, duration: 1_000.0 }],
            ..Default::default()
        },
        OutageSpec::default(),
    ];
    let r = fed_run(
        base_cfg(SchedMode::Sync, false),
        RoutingPolicy::RoundRobin,
        StealPolicy::Head,
        Some(outages),
        &w,
        "domain",
    );
    assert_eq!(completed(&r), JOBS, "a rack-sized blast radius loses nothing");
    assert_eq!(r.shards[0].rms.log.shard_downs(), 1, "the domain outage is logged");
    assert_eq!(r.shards[1].rms.log.shard_downs(), 0, "the blast radius stays on shard 0");
    // A rack-sized domain leaves 24 of 32 nodes up: victims prefer a
    // rescue shrink onto survivors, and only jobs with no feasible
    // shrink cross shards — either way the ledger closes exactly.
    assert!(
        r.shards[0].stats.availability < 1.0,
        "eight nodes were dark for 1000 s"
    );
    assert_ledger(&r, "domain");
}
