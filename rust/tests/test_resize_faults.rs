//! Integration: transactional reconfiguration under injected resize
//! faults — the fold-to-no-op bit-identity contract, exactly-once
//! completion with paired abort/rollback accounting, worker-count
//! independence of the campaign outputs, the checked-in acceptance
//! study, and the randomized rollback differential (every abort must
//! restore the exact pre-transaction job state, and the incremental
//! availability profile must match a from-scratch rebuild after every
//! transition).

use dmr::campaign::{self, CampaignSpec};
use dmr::des::{DesConfig, Engine};
use dmr::dmr::SchedMode;
use dmr::metrics::report::{campaign_agg_rows, campaign_run_rows};
use dmr::resilience::{FaultSpec, ResilienceConfig, ResizeFaultSpec};
use dmr::rms::{Action, DmrOutcome, Job, JobState, Rms, RmsConfig};
use dmr::util::rng::Rng;
use dmr::workload;

/// Run the 30-job reference stream (the same workload the engine unit
/// tests pin down) under a given mode / machine-fault / resize-fault
/// combination and return the full determinism triple.
fn run_triple(
    mode: SchedMode,
    fixed: bool,
    machine_faults: bool,
    rf: ResizeFaultSpec,
) -> (u64, u64, u64) {
    let w = workload::generate(30, 7);
    let w = if fixed { w.as_fixed() } else { w };
    let faults = if machine_faults {
        FaultSpec { mtbf: 60_000.0, mttr: 1_000.0, ..Default::default() }
    } else {
        FaultSpec::default()
    };
    let cfg = DesConfig {
        rms: RmsConfig { nodes: 64, ..Default::default() },
        mode,
        resilience: ResilienceConfig { faults, resize_faults: rf, ..Default::default() },
        ..Default::default()
    };
    let r = Engine::new(cfg).run(&w, "rf-itest");
    assert_eq!(r.rms.completed_jobs(), 30, "workload must drain");
    assert!(r.rms.check_invariants());
    (r.rms.log.digest(), r.makespan.to_bits(), r.events)
}

/// The fold-to-no-op contract: an inactive spec (all fail probabilities
/// zero) must leave every run bit-identical to the default engine, no
/// matter how its retry/backoff knobs are tuned — across fixed/sync/async
/// and fault-free/faulty machines.
#[test]
fn inactive_resize_fault_specs_fold_to_the_legacy_engine() {
    // Deliberately exotic knobs: with fail_prob = 0 they must be inert.
    let inactive = ResizeFaultSpec {
        spawn_fail: 0.0,
        redist_fail: 0.0,
        revoke: 0.0,
        max_retries: 9,
        backoff_base: 1.0,
        backoff_cap: 1.0,
    };
    for (mode, fixed) in [
        (SchedMode::Sync, true),
        (SchedMode::Sync, false),
        (SchedMode::Async, false),
    ] {
        for machine_faults in [false, true] {
            let legacy = run_triple(mode, fixed, machine_faults, ResizeFaultSpec::default());
            let folded = run_triple(mode, fixed, machine_faults, inactive.clone());
            assert_eq!(
                legacy, folded,
                "inactive spec diverged (mode {mode:?}, fixed {fixed}, \
                 machine_faults {machine_faults})"
            );
        }
    }
}

/// Injected faults on top of machine faults: the stream still drains with
/// every job completing exactly once, every transaction that began either
/// committed or aborted (an abort always pairs with a rollback — the
/// post-run invariant check would catch a half-rolled-back allocation),
/// and the whole thing replays bit-identically.
#[test]
fn injected_faults_complete_exactly_once_with_paired_aborts() {
    let run = || {
        let w = workload::generate(30, 7);
        let cfg = DesConfig {
            rms: RmsConfig { nodes: 64, ..Default::default() },
            mode: SchedMode::Sync,
            resilience: ResilienceConfig {
                faults: FaultSpec { mtbf: 60_000.0, mttr: 1_000.0, ..Default::default() },
                resize_faults: ResizeFaultSpec {
                    spawn_fail: 0.3,
                    redist_fail: 0.15,
                    revoke: 0.1,
                    max_retries: 2,
                    backoff_base: 10.0,
                    backoff_cap: 40.0,
                },
                ..Default::default()
            },
            ..Default::default()
        };
        let r = Engine::new(cfg).run(&w, "rf-faulty");

        // Exactly-once completion: all 30 user jobs end Completed.
        let user: Vec<&Job> = r.rms.jobs().filter(|j| !j.is_resizer).collect();
        assert_eq!(user.len(), 30);
        assert!(
            user.iter().all(|j| j.state == JobState::Completed && j.end_time.is_some()),
            "every user job completes despite aborted resizes"
        );
        assert_eq!(r.rms.completed_jobs(), 30);

        // Ledger closure: begins = commits + aborts, and the resilience
        // stats mirror the digest-covered event log.
        let log = &r.rms.log;
        assert!(log.resize_begins() > 0, "transactions were attempted");
        assert!(log.resize_aborts() > 0, "the fault mix must actually fire");
        assert_eq!(
            log.resize_begins(),
            log.resize_commits() + log.resize_aborts(),
            "every transaction that began either committed or aborted"
        );
        assert_eq!(r.resilience.resize_attempts, log.resize_begins() as u64);
        assert_eq!(r.resilience.resize_aborts, log.resize_aborts() as u64);
        assert_eq!(r.resilience.degraded_jobs, log.degradations() as u64);
        assert!(r.resilience.retry_time > 0.0, "aborts pay backoff time");

        // Degradations flow into the job records and stick.
        let degraded = user.iter().filter(|j| j.degraded).count() as u64;
        assert_eq!(degraded, r.resilience.degraded_jobs);

        assert!(r.rms.check_invariants());
        (r.rms.log.digest(), r.makespan.to_bits(), r.events)
    };
    assert_eq!(run(), run(), "faulty resize replay must be bit-identical");
}

/// Campaign outputs with an active resize-fault axis are a pure function
/// of the spec: the runs/agg CSV rows must not depend on how many worker
/// threads executed the matrix.
#[test]
fn campaign_rows_are_identical_across_worker_counts() {
    let spec = CampaignSpec::from_toml_str(
        r#"
        name = "rf_workers"
        nodes = [64]
        modes = ["sync"]
        seeds = [7, 8]

        [resize_faults]
        spawn_fail = [0.0, 0.5]
        redist_fail = 0.1
        revoke = 0.05
        max_retries = 2
        backoff_base = 10.0
        backoff_cap = 40.0

        [[workload]]
        kind = "feitelson"
        jobs = 30
        "#,
    )
    .unwrap();
    assert_eq!(spec.matrix_size(), 4, "2 spawn_fail x 2 seeds");

    let serial = campaign::run_campaign(&spec, 1).unwrap();
    let threaded = campaign::run_campaign(&spec, 4).unwrap();
    assert_eq!(
        campaign_run_rows(&serial.records),
        campaign_run_rows(&threaded.records),
        "per-run CSV rows depend on the worker count"
    );
    assert_eq!(
        campaign_agg_rows(&campaign::aggregate(&serial.records)),
        campaign_agg_rows(&campaign::aggregate(&threaded.records)),
        "aggregate CSV rows depend on the worker count"
    );

    // The swept axis is visible in the scenario ids, and the control
    // column stays on the legacy path.
    let aggs = campaign::aggregate(&serial.records);
    let quiet = aggs.iter().find(|a| a.scenario.ends_with("-rf0")).unwrap();
    let noisy = aggs.iter().find(|a| a.scenario.ends_with("-rf0.5")).unwrap();
    assert_eq!(quiet.resize_attempts.sum(), 0.0, "rf0 keeps the single-event resize");
    assert_eq!(quiet.resize_aborts.sum(), 0.0);
    assert!(noisy.resize_attempts.sum() > 0.0, "rf0.5 opens transactions");
    assert!(noisy.resize_aborts.sum() > 0.0, "rf0.5 aborts some of them");
}

/// The checked-in acceptance study: rigid runs never open transactions
/// (their rows are flat across the sweep), the malleable control column
/// is abort-free, and aborts/retry time grow in while nothing is lost —
/// completed stays at the full stream size everywhere.
#[test]
fn resize_faults_scenario_shows_degradation_without_loss() {
    let spec = CampaignSpec::from_file("scenarios/resize_faults.toml").unwrap();
    assert_eq!(spec.matrix_size(), 36, "1 workload x 1 nodes x 3 modes x 4 rf x 3 seeds");
    assert_eq!(spec.resize_faults.spawn_fail, vec![0.0, 0.1, 0.25, 0.5]);

    let res = campaign::run_campaign(&spec, 3).unwrap();
    let aggs = campaign::aggregate(&res.records);
    assert_eq!(aggs.len(), 12, "3 modes x 4 spawn_fail scenarios");

    let find = |mode: &str, rf: &str| {
        aggs.iter()
            .find(|a| a.scenario.contains(mode) && a.scenario.ends_with(rf))
            .unwrap_or_else(|| panic!("no {mode} {rf} scenario"))
    };

    // Nothing is ever lost: every run drains all 30 jobs.
    for r in &res.records {
        assert_eq!(r.summary.jobs.len(), 30, "{}: jobs lost", r.plan.label);
    }

    // Rigid jobs never resize, so the fault axis is a no-op for them:
    // identical makespans all the way across the sweep.
    let fixed0 = find("-fixed", "-rf0");
    for rf in ["-rf0.1", "-rf0.25", "-rf0.5"] {
        let f = find("-fixed", rf);
        assert_eq!(
            fixed0.makespan_s.sum().to_bits(),
            f.makespan_s.sum().to_bits(),
            "resize faults perturbed rigid runs ({rf})"
        );
        assert_eq!(f.resize_attempts.sum(), 0.0);
    }

    // The malleable control column is transaction-free; the noisy end
    // aborts and pays measurable retry time.
    for mode in ["-sync", "-async"] {
        let quiet = find(mode, "-rf0");
        assert_eq!(quiet.resize_aborts.sum(), 0.0, "{mode} control column aborted");
        assert_eq!(quiet.retry_time_s.sum(), 0.0);
        let noisy = find(mode, "-rf0.5");
        assert!(noisy.resize_attempts.sum() > 0.0, "{mode} rf0.5 never resized");
        assert!(noisy.resize_aborts.sum() > 0.0, "{mode} rf0.5 never aborted");
        assert!(noisy.retry_time_s.sum() > 0.0, "{mode} rf0.5 paid no retry time");
    }
}

/// Satellite: the randomized rollback differential.  Drive the real
/// [`Rms`] through thousands of random lifecycle transitions; every
/// transaction that gets aborted must leave the job *exactly* as the
/// pre-transaction snapshot recorded it (state, allocation, resize log,
/// boost, expected end, requeue count, degradation flag), and
/// `check_invariants()` — which rebuilds the availability profile from
/// scratch and compares it entry-for-entry with the incrementally
/// maintained one — must hold after every single op.
#[test]
fn rollback_restores_the_exact_pre_transaction_job_state() {
    const NODES: usize = 64;
    let snap = |j: &Job| {
        (
            j.state,
            j.nodes.clone(),
            j.resize_log
                .iter()
                .map(|e| (e.time, e.from_procs, e.to_procs))
                .collect::<Vec<_>>(),
            j.qos_boost,
            j.expected_end,
            j.requeues,
            j.degraded,
        )
    };
    let running_ids = |rms: &Rms, all: &[u64]| -> Vec<u64> {
        all.iter()
            .copied()
            .filter(|&id| {
                rms.job(id)
                    .map(|j| j.state == JobState::Running && !j.is_resizer && !j.degraded)
                    .unwrap_or(false)
            })
            .collect()
    };

    let mut rng = Rng::new(0xAB0_07);
    let mut rms = Rms::new(RmsConfig { nodes: NODES, ..Default::default() });
    let mut all: Vec<u64> = Vec::new();
    let mut t = 0.0f64;
    let mut next_name = 0u64;

    for step in 0..2000 {
        t += rng.exp(7.0);
        match rng.below(10) {
            0..=2 => {
                let app = *rng.choice(&[
                    dmr::apps::config::AppKind::Cg,
                    dmr::apps::config::AppKind::Jacobi,
                    dmr::apps::config::AppKind::NBody,
                ]);
                next_name += 1;
                let spec =
                    dmr::workload::JobSpec::from_app(app, format!("{app}-{next_name}"), t, 1.0);
                all.push(rms.submit(spec, t));
            }
            3 | 4 => {
                rms.schedule(t);
            }
            5 => {
                let running = running_ids(&rms, &all);
                if !running.is_empty() {
                    let id = running[rng.below(running.len() as u64) as usize];
                    rms.finish(id, t);
                }
            }
            6 | 7 => {
                // The differential itself: open a transaction, abort it
                // at a random phase, compare against the snapshot.
                let running = running_ids(&rms, &all);
                if !running.is_empty() {
                    let id = running[rng.below(running.len() as u64) as usize];
                    let procs = rms.job(id).unwrap().procs();
                    let before = snap(rms.job(id).unwrap());
                    let phase = rng.below(3) as u8;
                    if rng.below(2) == 0 && procs >= 2 {
                        let to = procs / 2;
                        if let Ok(DmrOutcome::Shrink { .. }) =
                            rms.dmr_apply(id, Action::Shrink { to }, t)
                        {
                            rms.abort_shrink(id, t, phase);
                            assert_eq!(
                                snap(rms.job(id).unwrap()),
                                before,
                                "step {step}: aborted shrink leaked state"
                            );
                        }
                    } else if let Ok(DmrOutcome::Expand { .. }) =
                        rms.dmr_apply(id, Action::Expand { to: procs * 2 }, t)
                    {
                        rms.abort_expand_to(id, procs, t, phase);
                        assert_eq!(
                            snap(rms.job(id).unwrap()),
                            before,
                            "step {step}: aborted expand leaked state"
                        );
                    }
                }
            }
            8 => {
                // A committed resize, to interleave real reconfigurations
                // with the aborted ones.
                let running = running_ids(&rms, &all);
                if !running.is_empty() {
                    let id = running[rng.below(running.len() as u64) as usize];
                    let procs = rms.job(id).unwrap().procs();
                    if rng.below(2) == 0 && procs >= 2 {
                        let to = procs / 2;
                        if let Ok(DmrOutcome::Shrink { to, .. }) =
                            rms.dmr_apply(id, Action::Shrink { to }, t)
                        {
                            rms.commit_shrink_to(id, to, t);
                        }
                    } else if let Ok(DmrOutcome::Expand { .. }) =
                        rms.dmr_apply(id, Action::Expand { to: procs * 2 }, t)
                    {
                        rms.commit_resize(id, t);
                    }
                }
            }
            _ => {
                // Degrade a job and verify the policy gate: further
                // decisions pin to NoAction and leave it untouched.
                let running = running_ids(&rms, &all);
                if !running.is_empty() {
                    let id = running[rng.below(running.len() as u64) as usize];
                    let before_procs = rms.job(id).unwrap().procs();
                    rms.degrade(id, t);
                    assert!(
                        matches!(
                            rms.dmr_apply(id, Action::Expand { to: before_procs * 2 }, t),
                            Ok(DmrOutcome::NoAction)
                        ),
                        "step {step}: degraded job still resizes"
                    );
                    let j = rms.job(id).unwrap();
                    assert!(j.degraded && j.state == JobState::Running);
                    assert_eq!(j.procs(), before_procs);
                }
            }
        }
        assert!(
            rms.check_invariants(),
            "step {step}: incremental profile diverged from the from-scratch rebuild"
        );
    }

    // The mix must have exercised the transitions under test.
    assert!(rms.completed_jobs() > 0);
    assert!(rms.log.resize_aborts() > 0, "no transaction was ever aborted");
    assert!(rms.log.resize_commits() + rms.log.shrinks() + rms.log.expansions() > 0);
    assert!(rms.log.degradations() > 0, "no job was ever degraded");
}
