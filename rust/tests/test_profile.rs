//! Differential tests for the incremental cluster-availability profile
//! (`rms::profile`) and its no-op elision.
//!
//! Three layers:
//!
//! 1. **Structure-level randomized differential**: thousands of random
//!    insert/remove/set_procs/set_end ops against [`AvailProfile`],
//!    re-deriving the shadow projection from a flat model after *every*
//!    op and requiring bit-identical `(time, free)` answers.
//! 2. **RMS-level randomized lifecycle**: thousands of random
//!    submit/schedule/finish/resize/fail/rescue/requeue/repair/cancel
//!    transitions through the real [`Rms`] entry points, asserting
//!    `check_invariants()` (which rebuilds the profile's contents from
//!    scratch and compares) after every op.
//! 3. **Driver-level sanity**: a sync DES run must actually elide
//!    repeated `NoAction` checks, and elision counters must stay zero on
//!    the reference path.  (Whole-run profile-on/off digest equality
//!    across fixed/sync/async and faulty scenarios lives in
//!    `test_golden_determinism.rs`.)

use dmr::apps::config::AppKind;
use dmr::des::{DesConfig, Engine};
use dmr::dmr::SchedMode;
use dmr::rms::profile::AvailProfile;
use dmr::rms::{Action, DmrOutcome, JobState, Rms, RmsConfig};
use dmr::util::rng::Rng;
use dmr::workload::{self, JobSpec};

// ------------------------------------------------------------------
// 1. Structure-level randomized differential

/// Flat reference model: `(id, procs, end, est)` kept in ascending-id
/// order — exactly the iteration order the pre-profile scheduling pass
/// used when snapshotting running jobs.
type Model = Vec<(u64, usize, Option<f64>, f64)>;

/// The reference snapshot: `(end, procs)` in id order, stable-sorted by
/// end (`total_cmp`).  This mirrors `rms::backfill`'s `SortedEnds` path
/// verbatim; sorted once per mutation, then queried many times.
fn reference_ends(model: &Model, now: f64) -> Vec<(f64, usize)> {
    let mut ends: Vec<(f64, usize)> = model
        .iter()
        .map(|&(_, procs, end, est)| (end.unwrap_or(now + est), procs))
        .collect();
    ends.sort_by(|a, b| a.0.total_cmp(&b.0));
    ends
}

fn reference_shadow(
    ends: &[(f64, usize)],
    free_now: usize,
    need: usize,
    now: f64,
) -> (f64, usize) {
    if free_now >= need {
        return (now, free_now);
    }
    let mut free = free_now;
    for &(t, p) in ends {
        free += p;
        if free >= need {
            return (t.max(now), free);
        }
    }
    (f64::INFINITY, free)
}

#[test]
fn randomized_ops_match_rebuilt_reference_after_every_op() {
    let mut rng = Rng::new(0xBEEF);
    let mut profile = AvailProfile::default();
    let mut model: Model = Vec::new();
    let mut next_id: u64 = 1;
    let mut now = 0.0f64;

    for step in 0..4000 {
        now += rng.exp(5.0);
        let op = rng.below(10);
        match op {
            // 0..=3: insert a new job (40 % — the set keeps growing and
            // shrinking around a few hundred entries).
            0..=3 => {
                let id = next_id;
                next_id += 1;
                let procs = 1 + rng.below(32) as usize;
                let est = 10.0 + rng.exp(300.0);
                // 30 % of inserts have no known end (the estimated
                // fallback path).
                let end = if rng.below(10) < 3 { None } else { Some(now + rng.exp(500.0)) };
                profile.insert(id, procs, end, est);
                model.push((id, procs, end, est));
            }
            // 4..=5: remove a random tracked job.
            4 | 5 if !model.is_empty() => {
                let idx = rng.below(model.len() as u64) as usize;
                let id = model[idx].0;
                profile.remove(id);
                model.retain(|e| e.0 != id);
            }
            // 6..=7: resize a random tracked job.
            6 | 7 if !model.is_empty() => {
                let idx = rng.below(model.len() as u64) as usize;
                let procs = 1 + rng.below(64) as usize;
                model[idx].1 = procs;
                profile.set_procs(model[idx].0, procs);
            }
            // 8..=9: refresh a random job's end estimate (ties included:
            // reuse an existing end 20 % of the time to stress the
            // equal-key id ordering).
            _ if !model.is_empty() => {
                let idx = rng.below(model.len() as u64) as usize;
                let end = if rng.below(5) == 0 {
                    let other = rng.below(model.len() as u64) as usize;
                    model[other].2.unwrap_or(now + 111.0)
                } else {
                    now + rng.exp(500.0)
                };
                model[idx].2 = Some(end);
                profile.set_end(model[idx].0, end);
            }
            _ => continue,
        }

        assert!(profile.check_invariants(), "step {step}: profile indices diverged");
        assert_eq!(profile.len(), model.len(), "step {step}: cardinality diverged");
        // The shadow projection must be bit-identical to the rebuilt
        // reference for a spread of (free, need) queries.
        let total: usize = model.iter().map(|e| e.1).sum();
        let ends = reference_ends(&model, now);
        let mut scratch = Vec::new();
        for need in [1usize, 8, 64, total / 2 + 1, total + 7] {
            for free in [0usize, 3, 17] {
                let fast = profile.shadow(free, need, now, &mut scratch);
                let slow = reference_shadow(&ends, free, need, now);
                assert_eq!(
                    fast.0.to_bits(),
                    slow.0.to_bits(),
                    "step {step}: shadow time diverged (need {need}, free {free})"
                );
                assert_eq!(
                    fast.1, slow.1,
                    "step {step}: projected free diverged (need {need}, free {free})"
                );
            }
        }
    }
    assert!(next_id > 1000, "the op mix must exercise a substantial population");
}

// ------------------------------------------------------------------
// 2. RMS-level randomized lifecycle

fn rand_spec(rng: &mut Rng, t: f64, i: u64) -> JobSpec {
    let app = *rng.choice(&[AppKind::Cg, AppKind::Jacobi, AppKind::NBody]);
    JobSpec::from_app(app, format!("{app}-{i}"), t, 1.0)
}

/// Ids of live jobs matching a predicate, in ascending-id order (so the
/// random choices are deterministic).
fn ids_where(rms: &Rms, all: &[u64], pred: impl Fn(&dmr::rms::Job) -> bool) -> Vec<u64> {
    all.iter()
        .copied()
        .filter(|&id| rms.job(id).map(|j| pred(j) && !j.is_resizer).unwrap_or(false))
        .collect()
}

#[test]
fn rms_random_lifecycle_keeps_profile_consistent() {
    const NODES: usize = 64;
    let mut rng = Rng::new(0xD1FF);
    let mut rms = Rms::new(RmsConfig { nodes: NODES, ..Default::default() });
    let mut all: Vec<u64> = Vec::new();
    let mut t = 0.0f64;

    for step in 0..2500 {
        t += rng.exp(7.0);
        match rng.below(12) {
            // Submissions keep the machine saturated.
            0..=3 => {
                let id = rms.submit(rand_spec(&mut rng, t, step), t);
                all.push(id);
            }
            4 | 5 => {
                rms.schedule(t);
            }
            6 => {
                let running =
                    ids_where(&rms, &all, |j| j.state == JobState::Running);
                if !running.is_empty() {
                    let id = running[rng.below(running.len() as u64) as usize];
                    rms.finish(id, t);
                }
            }
            7 => {
                // A node failure; the victim is rescued onto its
                // survivors or killed + requeued, like the DES does.
                let node = rng.below(NODES as u64) as usize;
                if let Some(f) = rms.fail_node(node, t) {
                    if f.survivors > 0 && rng.below(2) == 0 {
                        rms.rescue_shrink_to(f.job, f.survivors.div_ceil(2), t);
                    } else {
                        rms.requeue_after_failure(f.job, t);
                    }
                }
            }
            8 => {
                let node = rng.below(NODES as u64) as usize;
                rms.repair_node(node, t);
            }
            9 => {
                let active = ids_where(&rms, &all, |j| j.is_active());
                if !active.is_empty() {
                    let id = active[rng.below(active.len() as u64) as usize];
                    rms.set_expected_end(id, t + rng.exp(400.0));
                }
            }
            10 => {
                // A voluntary resize through the async-apply protocol,
                // committed immediately (shrink half / double).
                let running =
                    ids_where(&rms, &all, |j| j.state == JobState::Running);
                if !running.is_empty() {
                    let id = running[rng.below(running.len() as u64) as usize];
                    let procs = rms.job(id).unwrap().procs();
                    if rng.below(2) == 0 && procs >= 2 {
                        let to = procs / 2;
                        if let Ok(DmrOutcome::Shrink { to, .. }) =
                            rms.dmr_apply(id, Action::Shrink { to }, t)
                        {
                            rms.commit_shrink_to(id, to, t);
                        }
                    } else if let Ok(DmrOutcome::Expand { .. }) =
                        rms.dmr_apply(id, Action::Expand { to: procs * 2 }, t)
                    {
                        rms.commit_resize(id, t);
                    }
                }
            }
            _ => {
                let pending =
                    ids_where(&rms, &all, |j| j.state == JobState::Pending);
                if !pending.is_empty() {
                    let id = pending[rng.below(pending.len() as u64) as usize];
                    rms.cancel(id, t);
                }
            }
        }
        assert!(
            rms.check_invariants(),
            "step {step}: incremental profile diverged from the rebuilt reference"
        );
    }
    // The mix must have exercised the interesting transitions.
    assert!(rms.completed_jobs() > 0);
    assert!(rms.log.node_failures() > 0);
    assert!(rms.log.rescues() + rms.log.requeues() > 0);
    assert!(rms.log.shrinks() + rms.log.expansions() > 0);
}

// ------------------------------------------------------------------
// 3. Driver-level elision sanity

#[test]
fn sync_des_run_elides_noop_checks_and_reference_path_does_not() {
    let run = |incremental: bool| {
        let w = workload::generate(40, 23);
        let cfg = DesConfig {
            rms: RmsConfig { nodes: 64, incremental_profile: incremental, ..Default::default() },
            mode: SchedMode::Sync,
            ..Default::default()
        };
        let r = Engine::new(cfg).run(&w, "elision");
        assert_eq!(r.rms.completed_jobs(), 40);
        assert!(r.rms.check_invariants());
        (r.rms.pass_stats(), r.rms.log.digest(), r.makespan.to_bits())
    };
    let (fast, fast_log, fast_mk) = run(true);
    let (slow, slow_log, slow_mk) = run(false);
    assert_eq!(fast_log, slow_log, "elision changed the event stream");
    assert_eq!(fast_mk, slow_mk, "elision changed the makespan");
    assert_eq!(slow.sched_elided + slow.dmr_elided, 0, "reference path must not elide");
    assert!(
        fast.dmr_elided > 0,
        "a sync run with repeated NoAction checks must hit the memo \
         (checks={}, elided={})",
        fast.dmr_checks,
        fast.dmr_elided
    );
    assert_eq!(fast.dmr_checks, slow.dmr_checks, "check count must not change");
    assert_eq!(fast.sched_passes, slow.sched_passes, "pass count must not change");
}
