//! Integration tests for the pluggable reconfiguration-policy engine:
//!
//! 1. **Golden lock** — the default `RmsConfig` (strategy unset) and an
//!    explicit `ThroughputAware` selection produce bit-identical event
//!    streams across fixed/sync/async and the faulty-cluster
//!    configuration.  (The cross-PR digests themselves are pinned by the
//!    self-recording fixture in `test_golden_determinism.rs`; this file
//!    locks that the strategy plumbing — trait object, context assembly,
//!    scan-based `dmr_peek` — cannot perturb the baseline.)
//! 2. **Drain + determinism per strategy** — every strategy processes a
//!    contended workload to completion, deterministically, in both
//!    scheduling modes, with RMS invariants intact.
//! 3. **Strategy semantics end-to-end** — deadline jobs are never
//!    voluntarily shrunk; the strategy sweep produces per-strategy
//!    scenarios and the comparative metric columns.

use dmr::des::{DesConfig, Engine, RunResult};
use dmr::dmr::SchedMode;
use dmr::metrics::RunSummary;
use dmr::resilience::{
    DrainSet, DrainWindow, FaultKind, FaultSpec, FaultTraceEvent, RecoveryConfig,
    ResilienceConfig,
};
use dmr::rms::{PolicyStrategy, RmsConfig, RmsEvent};
use dmr::workload;

fn run_with(
    strategy: Option<PolicyStrategy>,
    mode: &str,
    faults: bool,
    deadlines: Option<f64>,
) -> RunResult {
    let w = workload::generate(40, 17);
    let (sched, flexible) = match mode {
        "fixed" => (SchedMode::Sync, false),
        "sync" => (SchedMode::Sync, true),
        "async" => (SchedMode::Async, true),
        other => panic!("unknown mode {other}"),
    };
    let mut w = if flexible { w } else { w.as_fixed() };
    if let Some(slack) = deadlines {
        w = w.with_deadlines(slack);
    }
    let mut rms = RmsConfig { nodes: 64, ..Default::default() };
    if let Some(s) = strategy {
        rms.strategy = s;
    }
    let resilience = if faults {
        ResilienceConfig {
            faults: FaultSpec {
                mtbf: 60_000.0,
                mttr: 1_000.0,
                scripted: vec![FaultTraceEvent { at: 300.0, node: 1, kind: FaultKind::Fail }],
                drains: vec![DrainWindow {
                    start: 1_500.0,
                    end: 3_000.0,
                    nodes: DrainSet::Count(6),
                }],
            },
            recovery: RecoveryConfig { checkpoint_interval: 500.0, ..Default::default() },
            ..Default::default()
        }
    } else {
        ResilienceConfig::default()
    };
    let cfg = DesConfig { rms, mode: sched, resilience, ..Default::default() };
    let r = Engine::new(cfg).run(&w, mode);
    assert_eq!(r.rms.completed_jobs(), 40, "{mode}: workload must drain");
    assert!(r.rms.check_invariants());
    r
}

fn digest(r: &RunResult) -> String {
    format!(
        "events={} log={:016x} makespan={:016x}",
        r.events,
        r.rms.log.digest(),
        r.makespan.to_bits()
    )
}

/// The explicit `ThroughputAware` selection is bit-identical to the
/// default config — across all modes, with and without fault injection.
/// Combined with the self-recording golden fixture (which pins the
/// default config's digests across PRs), this locks the baseline to its
/// pre-refactor event streams.
#[test]
fn throughput_strategy_is_bit_identical_to_default() {
    for mode in ["fixed", "sync", "async"] {
        for faults in [false, true] {
            let default_cfg = digest(&run_with(None, mode, faults, None));
            let explicit =
                digest(&run_with(Some(PolicyStrategy::ThroughputAware), mode, faults, None));
            assert_eq!(default_cfg, explicit, "{mode} faults={faults}");
        }
    }
}

/// Every strategy drains the contended stream in both scheduling modes
/// and is bit-for-bit deterministic across reruns.
#[test]
fn all_strategies_drain_deterministically() {
    for strategy in PolicyStrategy::ALL {
        for mode in ["sync", "async"] {
            let a = digest(&run_with(Some(strategy), mode, false, Some(4.0)));
            let b = digest(&run_with(Some(strategy), mode, false, Some(4.0)));
            assert_eq!(a, b, "{mode}/{}: nondeterministic", strategy.label());
        }
        // and under fault injection (rescue paths included)
        let a = digest(&run_with(Some(strategy), "sync", true, None));
        let b = digest(&run_with(Some(strategy), "sync", true, None));
        assert_eq!(a, b, "fault-sync/{}: nondeterministic", strategy.label());
    }
}

/// The strategies genuinely disagree: on a contended stream, at least
/// one alternative strategy diverges from the baseline's event stream.
#[test]
fn strategies_diverge_from_baseline() {
    let base = digest(&run_with(Some(PolicyStrategy::ThroughputAware), "sync", false, None));
    let diverged = [PolicyStrategy::QueueAware, PolicyStrategy::FairShare]
        .iter()
        .map(|&s| digest(&run_with(Some(s), "sync", false, None)))
        .filter(|d| *d != base)
        .count();
    assert!(diverged > 0, "no alternative strategy changed the event stream");
}

/// DeadlineAware end-to-end: deadline-carrying jobs are never
/// voluntarily shrunk (no Shrunk event for any job — the DES issues no
/// §4.1 forced requests, and every job carries a deadline).
#[test]
fn deadline_strategy_never_shrinks_deadline_jobs() {
    let r = run_with(Some(PolicyStrategy::DeadlineAware), "sync", false, Some(2.0));
    let shrinks = r
        .rms
        .log
        .all()
        .iter()
        .filter(|e| matches!(e, RmsEvent::Shrunk { .. }))
        .count();
    assert_eq!(shrinks, 0, "deadline jobs must not be shrunk");
    let s = RunSummary::from_run(r);
    assert_eq!(s.deadline_jobs, 40);
    assert!(s.deadline_misses <= s.deadline_jobs);
}

/// On a stream with no deadlines at all, the deadline strategy's
/// fallback path makes it bit-identical to the baseline — the protection
/// logic must be a strict extension, not a reinterpretation.
#[test]
fn deadline_strategy_without_deadlines_equals_baseline() {
    for mode in ["sync", "async"] {
        let base = digest(&run_with(Some(PolicyStrategy::ThroughputAware), mode, false, None));
        let dl = digest(&run_with(Some(PolicyStrategy::DeadlineAware), mode, false, None));
        assert_eq!(base, dl, "{mode}: fallback diverged from baseline");
    }
    // ...and with deadlines it genuinely diverges (it stops the shrinks
    // the baseline performs on this contended stream).
    let base = run_with(Some(PolicyStrategy::ThroughputAware), "sync", false, Some(4.0));
    assert!(base.rms.log.shrinks() > 0, "baseline must shrink under contention");
    let dl = run_with(Some(PolicyStrategy::DeadlineAware), "sync", false, Some(4.0));
    assert_eq!(dl.rms.log.shrinks(), 0);
}

/// The checked-in comparative study parses, expands to all four
/// strategies with per-strategy scenario suffixes, and multiplies the
/// matrix as documented (2 workloads x 4 strategies x 2 mtbf x 3 seeds).
#[test]
fn policy_matrix_spec_expands_all_strategies() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/policy_matrix.toml");
    let spec = dmr::campaign::CampaignSpec::from_file(path).expect("spec parses");
    assert_eq!(spec.policy.strategy.len(), 4);
    assert_eq!(spec.matrix_size(), 2 * 4 * 2 * 3);
    let plans = spec.expand();
    assert_eq!(plans.len(), 48);
    for label in ["throughput", "queue", "fair", "deadline"] {
        assert!(
            plans.iter().any(|p| p.scenario.contains(&format!("-{label}"))),
            "no scenario for strategy {label}"
        );
    }
    // both workloads carry deadline slack -> the miss columns are live
    assert!(spec.workloads.iter().all(|w| w.deadline_slack.is_some()));
}
