//! Observability subsystem tests ([`dmr::obs`]): the inertness contract,
//! the Chrome-trace exporter, and the self-profile plumbing.
//!
//! The load-bearing property is **observational inertness**: deriving and
//! exporting a span trace must not change a single bit of a run.  The
//! matrix below locks event-log digests and makespan bits across
//! fixed/sync/async × fault-free/faulty × flat/federated, with a full
//! trace built and streamed in between.  On top of that: the exported
//! Chrome-trace JSON round-trips through `util::json` with every span
//! begin/end paired, the `running`-span count equals jobs completed +
//! failure requeues, stride/cap bound the job tracks, and the
//! deterministic pass counters reach the campaign CSV/JSON surfaces.

use std::collections::HashMap;

use dmr::campaign::{self, CampaignSpec};
use dmr::des::{DesConfig, Engine, RunResult};
use dmr::dmr::SchedMode;
use dmr::federation::{FedEngine, FederationConfig, FedRunResult, RoutingPolicy, ShardSpec};
use dmr::metrics::report;
use dmr::obs::{Phase, Trace, TraceConfig};
use dmr::resilience::{
    DrainSet, DrainWindow, FaultKind, FaultSpec, FaultTraceEvent, RecoveryConfig,
    ResilienceConfig,
};
use dmr::rms::RmsConfig;
use dmr::util::json::Json;
use dmr::workload::{self, WorkloadSpec};

fn modes() -> [(&'static str, SchedMode, bool); 3] {
    [
        ("fixed", SchedMode::Sync, false),
        ("sync", SchedMode::Sync, true),
        ("async", SchedMode::Async, true),
    ]
}

fn base_cfg(sched: SchedMode, faulty: bool) -> DesConfig {
    let resilience = if faulty {
        ResilienceConfig {
            faults: FaultSpec {
                mtbf: 60_000.0,
                mttr: 1_000.0,
                scripted: vec![FaultTraceEvent { at: 300.0, node: 1, kind: FaultKind::Fail }],
                drains: vec![DrainWindow {
                    start: 1_500.0,
                    end: 3_000.0,
                    nodes: DrainSet::Count(6),
                }],
            },
            recovery: RecoveryConfig { checkpoint_interval: 500.0, ..Default::default() },
            ..Default::default()
        }
    } else {
        ResilienceConfig::default()
    };
    DesConfig {
        rms: RmsConfig { nodes: 64, ..Default::default() },
        mode: sched,
        resilience,
        ..Default::default()
    }
}

fn stream(flexible: bool) -> WorkloadSpec {
    let w = workload::generate(40, 17);
    if flexible {
        w
    } else {
        w.as_fixed()
    }
}

fn flat_run(mode: &str, sched: SchedMode, flexible: bool, faulty: bool) -> RunResult {
    Engine::new(base_cfg(sched, faulty)).run(&stream(flexible), mode)
}

fn flat_digest(r: &RunResult) -> String {
    format!(
        "events={} log={:016x} makespan={:016x}",
        r.events,
        r.rms.log.digest(),
        r.makespan.to_bits()
    )
}

fn fed_run(faulty: bool) -> FedRunResult {
    let fed = FederationConfig {
        shards: ShardSpec::uniform(64, 2),
        routing: RoutingPolicy::RoundRobin,
        ..Default::default()
    };
    FedEngine::new(base_cfg(SchedMode::Sync, faulty), fed).run(&stream(true), "fed")
}

fn fed_digest(r: &FedRunResult) -> String {
    let shards: Vec<String> =
        r.shards.iter().map(|s| format!("{:016x}", s.rms.log.digest())).collect();
    format!("events={} logs={} makespan={:016x}", r.events, shards.join(","), r.makespan.to_bits())
}

/// Derive a full trace from a finished run and stream both exporters into
/// memory — the heaviest thing tracing ever does.  Returns bytes written
/// so the caller can assert the writers actually ran.
fn exercise_trace_flat(r: &RunResult) -> usize {
    let t = Trace::from_run(r, &TraceConfig::on());
    let mut chrome = Vec::new();
    t.write_chrome(&mut chrome).unwrap();
    let mut jsonl = Vec::new();
    t.write_jsonl(&mut jsonl).unwrap();
    chrome.len() + jsonl.len()
}

/// Trace-on vs trace-off bit-identity across the full flat matrix:
/// fixed/sync/async × fault-free/faulty.  Tracing happens strictly
/// post-run, so the digests cannot differ — this test is the contract
/// that keeps it that way.
#[test]
fn tracing_is_observationally_inert_flat_matrix() {
    for faulty in [false, true] {
        for (mode, sched, flexible) in modes() {
            let plain = flat_digest(&flat_run(mode, sched, flexible, faulty));
            let traced_run = flat_run(mode, sched, flexible, faulty);
            let bytes = exercise_trace_flat(&traced_run);
            assert!(bytes > 0, "{mode} faulty={faulty}: exporters wrote nothing");
            assert_eq!(
                plain,
                flat_digest(&traced_run),
                "{mode} faulty={faulty}: tracing changed the run"
            );
        }
    }
}

/// Same inertness lock for the federated engine (one track pair per
/// shard): per-shard digests and the global makespan are bit-identical
/// with a trace derived and streamed in between.
#[test]
fn tracing_is_observationally_inert_federated() {
    for faulty in [false, true] {
        let plain = fed_digest(&fed_run(faulty));
        let traced_run = fed_run(faulty);
        let t = Trace::from_fed(&traced_run, &TraceConfig::on());
        let mut chrome = Vec::new();
        t.write_chrome(&mut chrome).unwrap();
        assert!(!chrome.is_empty());
        assert_eq!(
            plain,
            fed_digest(&traced_run),
            "faulty={faulty}: tracing changed the federated run"
        );
        assert!(t.stats().job_tracks_kept > 0, "both shards contribute job tracks");
    }
}

/// The exported Chrome-trace JSON must round-trip through the crate's own
/// strict parser with every span begin paired to an end on its (pid, tid)
/// track, and the `running`-span count must equal jobs completed +
/// failure requeues — the acceptance criterion of the exporter.
#[test]
fn chrome_export_round_trips_with_paired_spans() {
    let r = flat_run("sync", SchedMode::Sync, true, true);
    let completed = r.rms.completed_jobs();
    let requeues = r.rms.log.requeues();
    let t = Trace::from_run(&r, &TraceConfig::on());
    let stats = t.stats();
    let mut chrome = Vec::new();
    t.write_chrome(&mut chrome).unwrap();
    let text = String::from_utf8(chrome).unwrap();
    let doc = Json::parse(&text).expect("exported Chrome trace must be valid JSON");

    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    let mut begins = 0usize;
    let mut ends = 0usize;
    let mut running_spans = 0usize;
    let mut names_seen: Vec<String> = Vec::new();
    let mut stacks: HashMap<(i64, i64), Vec<String>> = HashMap::new();
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).expect("every event has ph");
        let key = (
            ev.get("pid").and_then(|p| p.as_f64()).unwrap_or(-1.0) as i64,
            ev.get("tid").and_then(|p| p.as_f64()).unwrap_or(-1.0) as i64,
        );
        let name = ev.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string();
        match ph {
            "B" => {
                begins += 1;
                if name == "running" {
                    running_spans += 1;
                }
                names_seen.push(name.clone());
                stacks.entry(key).or_default().push(name);
            }
            "E" => {
                ends += 1;
                let open = stacks
                    .get_mut(&key)
                    .and_then(|s| s.pop())
                    .unwrap_or_else(|| panic!("E without open B on track {key:?}"));
                assert_eq!(open, name, "mismatched begin/end pair on track {key:?}");
            }
            "i" | "M" => {}
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    assert!(
        stacks.values().all(|s| s.is_empty()),
        "unclosed spans left on some track: {stacks:?}"
    );
    assert_eq!(begins, ends, "every begin is paired");
    assert_eq!(begins, stats.spans, "span count matches TraceStats");
    assert_eq!(
        running_spans,
        completed + requeues,
        "running spans == jobs completed + failure requeues"
    );
    for required in ["pending", "running", "down", "drain"] {
        assert!(
            names_seen.iter().any(|n| n == required),
            "span {required:?} missing from the faulty-run trace"
        );
    }
}

/// Every line of the JSONL exporter is a standalone JSON object.
#[test]
fn jsonl_export_parses_line_by_line() {
    let r = flat_run("sync", SchedMode::Sync, true, true);
    let t = Trace::from_run(&r, &TraceConfig::on());
    let mut out = Vec::new();
    t.write_jsonl(&mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    let mut lines = 0usize;
    for line in text.lines() {
        let v = Json::parse(line).expect("every JSONL line parses");
        assert!(v.get("type").and_then(|t| t.as_str()).is_some());
        lines += 1;
    }
    let stats = t.stats();
    assert_eq!(lines, stats.spans + stats.instants, "one line per span/instant");
}

/// Stride and cap bound the kept job tracks, and the machine tracks are
/// never filtered — trace size stays controlled on huge workloads.
#[test]
fn stride_and_cap_bound_exported_job_tracks() {
    let r = flat_run("sync", SchedMode::Sync, true, true);
    let full = Trace::from_run(&r, &TraceConfig::on()).stats();
    assert_eq!(full.job_tracks_kept, full.job_tracks_total, "stride 1 keeps everything");
    assert_eq!(full.job_tracks_total, 40);

    let strided = Trace::from_run(
        &r,
        &TraceConfig { enabled: true, stride: 4, cap: 0 },
    )
    .stats();
    assert_eq!(strided.job_tracks_total, 40, "total is filter-independent");
    assert_eq!(strided.job_tracks_kept, 10, "every 4th of 40 job tracks");

    let capped = Trace::from_run(
        &r,
        &TraceConfig { enabled: true, stride: 1, cap: 5 },
    )
    .stats();
    assert_eq!(capped.job_tracks_kept, 5, "cap bounds the kept set");
    assert!(capped.spans < full.spans, "fewer tracks, fewer spans");
    assert!(capped.spans > 0, "machine tracks survive the cap");
}

/// The self-profile counts every dispatched event exactly once, phases
/// are recorded, and merged profiles accumulate — monotone by
/// construction (fixed arrays of saturating counters).
#[test]
fn self_profile_counts_phases() {
    let r = flat_run("sync", SchedMode::Sync, true, false);
    assert_eq!(
        r.profile.calls(Phase::Dispatch),
        r.events,
        "one dispatch sample per DES event"
    );
    assert!(r.profile.calls(Phase::Schedule) > 0, "schedule passes sampled");
    assert!(r.profile.calls(Phase::Dmr) > 0, "DMR checks sampled");
    assert!(r.profile.total_ns() > 0, "wall clock advanced");
    let share_sched = r.profile.share(Phase::Schedule);
    assert!(share_sched >= 0.0 && share_sched.is_finite(), "share is a fraction");
    assert!(r.profile.events_per_sec(r.events) > 0.0);
    // histogram mass equals dispatch samples
    let hist_mass: u64 = r.profile.histogram().iter().sum();
    assert_eq!(hist_mass, r.profile.calls(Phase::Dispatch));

    // the federated engine threads one global profile through too
    let f = fed_run(false);
    assert_eq!(f.profile.calls(Phase::Dispatch), f.events);
    assert!(f.profile.calls(Phase::Schedule) > 0);
}

/// The deterministic pass/check counters (never the wall-clock numbers)
/// reach the campaign CSV headers, the per-run rows, and the aggregate
/// JSON — and stay worker-count-invariant like every other column.
#[test]
fn pass_counters_reach_campaign_surfaces() {
    let spec = CampaignSpec::from_toml_str(
        r#"
name = "obs-surfaces"
nodes = [32]
modes = ["fixed", "sync"]
seeds = [1, 2]
[[workload]]
kind = "feitelson"
jobs = 8
"#,
    )
    .unwrap();
    let res = campaign::run_campaign(&spec, 2).unwrap();

    let run_cols = report::run_columns();
    for col in ["sched_passes", "sched_elided", "dmr_checks", "dmr_elided"] {
        assert!(run_cols.contains(&col), "runs CSV header missing {col}");
    }
    let run_rows = report::campaign_run_rows(&res.records);
    assert!(run_rows.iter().all(|r| r.len() == run_cols.len()), "ragged runs CSV");

    let aggs = campaign::aggregate(&res.records);
    let agg_cols = report::agg_columns();
    for col in ["sched_passes_mean", "sched_elided_mean", "dmr_checks_mean", "dmr_elided_mean"] {
        assert!(agg_cols.contains(&col), "agg CSV header missing {col}");
    }
    let agg_rows = report::campaign_agg_rows(&aggs);
    assert!(agg_rows.iter().all(|r| r.len() == agg_cols.len()), "ragged agg CSV");

    let json = report::campaign_agg_json(&spec, &aggs).render();
    for key in ["sched_passes", "sched_elided", "dmr_checks", "dmr_elided"] {
        assert!(json.contains(key), "agg JSON missing {key}");
    }
    // wall-clock values must NOT leak into the deterministic outputs
    assert!(!json.contains("wall_ns"), "wall clock leaked into agg JSON");
    assert!(!run_cols.iter().any(|c| c.contains("wall")), "wall clock leaked into runs CSV");

    // sync runs actually schedule and check
    for r in &res.records {
        assert!(r.summary.passes.sched_passes > 0, "{}: no passes", r.plan.label);
        if !r.plan.label.contains("fixed") {
            assert!(r.summary.passes.dmr_checks > 0, "{}: no DMR checks", r.plan.label);
        }
    }
}
