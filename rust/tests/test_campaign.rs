//! Integration: the campaign engine end-to-end — spec parsing, parallel
//! execution, SWF ingestion from disk, and the determinism contract: the
//! same spec + seeds must produce bit-identical aggregate output
//! regardless of worker-thread count.

use dmr::campaign::{self, CampaignSpec};
use dmr::metrics::report;
use dmr::workload::swf;

/// A small matrix covering all three workload sources (the SWF fixture is
/// the checked-in sample trace; tests run from the workspace root).
const SPEC: &str = r#"
name = "itest"
nodes = [32, 64]
modes = ["fixed", "sync", "async"]
seeds = [1, 2, 3]

[[workload]]
kind = "feitelson"
jobs = 10

[[workload]]
kind = "burst_lull"
jobs = 10
burst = 4
burst_gap = 1.0
lull = 120.0

[[workload]]
kind = "swf"
path = "scenarios/traces/small.swf"
max_jobs = 10
rescale_nodes = 64
malleable_fraction = 0.5
time_scale = 0.2
"#;

fn run_with_workers(workers: usize) -> (Vec<Vec<String>>, Vec<Vec<String>>, String) {
    let spec = CampaignSpec::from_toml_str(SPEC).unwrap();
    let res = campaign::run_campaign(&spec, workers).unwrap();
    assert_eq!(res.records.len(), spec.matrix_size());
    let aggs = campaign::aggregate(&res.records);
    (
        report::campaign_run_rows(&res.records),
        report::campaign_agg_rows(&aggs),
        report::campaign_agg_json(&spec, &aggs).render(),
    )
}

#[test]
fn aggregate_output_identical_across_worker_counts() {
    let (runs1, agg1, json1) = run_with_workers(1);
    let (runs8, agg8, json8) = run_with_workers(8);
    assert_eq!(runs1, runs8, "per-run rows must not depend on worker count");
    assert_eq!(agg1, agg8, "aggregate rows must not depend on worker count");
    assert_eq!(json1, json8, "aggregate JSON must not depend on worker count");

    // 3 workloads x 2 nodes x 3 modes x 3 seeds
    assert_eq!(runs1.len(), 54);
    assert_eq!(agg1.len(), 18, "one aggregate row per scenario");
}

#[test]
fn campaign_writes_csv_and_json_artifacts() {
    let mut spec = CampaignSpec::from_toml_str(SPEC).unwrap();
    spec.name = "itest-files".into();
    let dir = std::env::temp_dir().join(format!("dmr_campaign_itest_{}", std::process::id()));
    spec.output_dir = dir.clone();
    // shrink the matrix: this test is about the files
    spec.nodes = vec![64];
    spec.modes = vec![campaign::RunMode::Fixed, campaign::RunMode::Sync];
    spec.seeds = vec![1, 2];

    let res = campaign::run_campaign(&spec, 4).unwrap();
    let out = campaign::write_outputs(&spec, &res).unwrap();
    let runs = std::fs::read_to_string(&out.runs_csv).unwrap();
    // header + one line per run
    assert_eq!(runs.lines().count(), 1 + spec.matrix_size());
    assert!(runs.starts_with("run,scenario,label,nodes,mode,policy,seed,jobs,makespan_s"));
    let agg = std::fs::read_to_string(&out.agg_csv).unwrap();
    assert_eq!(agg.lines().count(), 1 + 6, "6 scenarios (3 workloads x 2 modes)");
    let json = std::fs::read_to_string(&out.agg_json).unwrap();
    let parsed = dmr::util::json::Json::parse(&json).unwrap();
    assert_eq!(parsed.get("campaign").unwrap().as_str(), Some("itest-files"));
    assert_eq!(parsed.get("scenarios").unwrap().as_arr().unwrap().len(), 6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn flexible_scenarios_beat_fixed_on_wait() {
    let spec = CampaignSpec::from_toml_str(
        r#"
name = "signal"
nodes = [64]
modes = ["fixed", "sync"]
seeds = [1, 2, 3]
[[workload]]
kind = "burst_lull"
jobs = 16
burst = 8
burst_gap = 1.0
lull = 200.0
"#,
    )
    .unwrap();
    let res = campaign::run_campaign(&spec, 4).unwrap();
    let aggs = campaign::aggregate(&res.records);
    assert_eq!(aggs.len(), 2);
    let fixed = aggs.iter().find(|a| a.scenario.ends_with("-fixed")).unwrap();
    let sync = aggs.iter().find(|a| a.scenario.ends_with("-sync")).unwrap();
    // the paper's headline, now as a campaign aggregate: flexible cuts
    // waiting and completes the stream no later (within noise)
    assert!(
        sync.wait_s.mean() < fixed.wait_s.mean(),
        "flexible wait {} !< fixed wait {}",
        sync.wait_s.mean(),
        fixed.wait_s.mean()
    );
    assert!(sync.shrinks.sum() + sync.expands.sum() > 0.0, "reconfigurations happened");
    assert_eq!(fixed.shrinks.sum() + fixed.expands.sum(), 0.0, "rigid baseline never resizes");
}

#[test]
fn swf_fixture_parses_from_disk() {
    let trace = swf::load("scenarios/traces/small.swf").unwrap();
    assert_eq!(trace.records.len(), 24, "all 24 sample jobs parseable");
    assert!(trace.stats.comments >= 10, "header comment block");
    assert_eq!(trace.stats.malformed, 0);
    assert_eq!(trace.stats.nonsuccess, 1, "job 10 is marked failed (status 0)");
    assert_eq!(trace.max_procs, 128);
    // job 10 has run time -1: requested time is the fallback
    let j10 = trace.records.iter().find(|r| r.job_id == 10).unwrap();
    assert_eq!(j10.runtime, 1200.0);
    assert!(!j10.completed());
    // job 7 has requested procs -1: allocation is the fallback
    let j7 = trace.records.iter().find(|r| r.job_id == 7).unwrap();
    assert_eq!(j7.procs, 8);

    // the replay spec's view of it: rescaled 128 -> 64, runtime
    // preserved, and the failed job skipped by default
    let w = swf::to_workload(
        &trace,
        &swf::SwfOptions { rescale_nodes: Some(64), ..Default::default() },
        1,
    );
    assert_eq!(w.len(), 23, "failed job 10 dropped");
    assert!(!w.jobs.iter().any(|j| j.name == "swf-00010"));
    let biggest = w.jobs.iter().map(|j| j.procs).max().unwrap();
    assert_eq!(biggest, 64);
    for j in &w.jobs {
        assert!(j.procs >= 1);
        assert!(j.exec_time_at(j.procs) > 0.0);
    }
    // the include_failed knob restores the old replay-everything behavior
    let all = swf::to_workload(
        &trace,
        &swf::SwfOptions { rescale_nodes: Some(64), include_failed: true, ..Default::default() },
        1,
    );
    assert_eq!(all.len(), 24);
    assert!(all.jobs.iter().any(|j| j.name == "swf-00010"));
}

#[test]
fn checked_in_specs_load_and_size_correctly() {
    let sweep = CampaignSpec::from_file("scenarios/sweep_small.toml").unwrap();
    assert_eq!(
        sweep.matrix_size(),
        24,
        "acceptance matrix: 2 workloads x 2 nodes x 2 modes x 3 seeds"
    );
    assert_eq!(sweep.name, "sweep_small");

    let replay = CampaignSpec::from_file("scenarios/swf_replay.toml").unwrap();
    assert_eq!(replay.matrix_size(), 9);
    // its trace reference resolves from the workspace root
    let campaign::WorkloadSource::Swf { ref path, .. } = replay.workloads[0].source else {
        panic!("swf_replay should use an swf source");
    };
    assert!(std::path::Path::new(path).exists());

    let matrix = CampaignSpec::from_file("scenarios/policy_matrix.toml").unwrap();
    assert_eq!(
        matrix.matrix_size(),
        48,
        "policy study: 2 workloads x 4 strategies x 2 mtbf x 3 seeds"
    );
}
