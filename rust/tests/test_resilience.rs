//! Integration: the resilience engine end-to-end — scripted failures and
//! drains through the DES, malleability-aware recovery (shrink rescue vs
//! kill + requeue), the availability/rework metrics, and the acceptance
//! scenario: malleable beats rigid under an identical fault trace.

use dmr::apps::config::AppKind;
use dmr::campaign::{self, CampaignSpec};
use dmr::des::{DesConfig, Engine, RunResult};
use dmr::resilience::{
    DrainSet, DrainWindow, FaultKind, FaultSpec, FaultTraceEvent, RecoveryConfig,
    ResilienceConfig,
};
use dmr::rms::RmsConfig;
use dmr::workload::{JobSpec, WorkloadSpec};

/// One CG job (32 procs, min 2, factor 2) submitted at t=0 on a 64-node
/// machine; it runs ~600 s, so a scripted failure at t=50 is guaranteed
/// to hit it (the deterministic allocator hands it nodes 0..31).
fn one_cg_workload() -> WorkloadSpec {
    let spec = JobSpec::from_app(AppKind::Cg, "CG-0".into(), 0.0, 1.0);
    WorkloadSpec { jobs: vec![spec], seed: 1 }
}

fn run_with(faults: FaultSpec, recovery: RecoveryConfig, w: &WorkloadSpec) -> RunResult {
    let cfg = DesConfig {
        rms: RmsConfig { nodes: 64, ..Default::default() },
        resilience: ResilienceConfig { faults, recovery, ..Default::default() },
        ..Default::default()
    };
    Engine::new(cfg).run(w, "resilience-itest")
}

fn fail_at(node: usize, at: f64) -> FaultSpec {
    FaultSpec {
        scripted: vec![FaultTraceEvent { at, node, kind: FaultKind::Fail }],
        ..Default::default()
    }
}

#[test]
fn malleable_job_is_rescued_by_shrink() {
    let w = one_cg_workload();
    let r = run_with(fail_at(5, 50.0), RecoveryConfig::default(), &w);
    assert_eq!(r.rms.completed_jobs(), 1);
    assert_eq!(r.resilience.node_failures, 1);
    assert_eq!(r.resilience.interrupted, 1);
    assert_eq!(r.resilience.rescued, 1, "32-proc CG shrinks onto 16 survivors");
    assert_eq!(r.resilience.requeued, 0);
    assert_eq!(r.rms.log.rescues(), 1);
    // the job record shows the rescue as a shrink to a factor-chain size
    let job = r.rms.jobs().next().unwrap();
    let rescue = job.resize_log.first().unwrap();
    assert_eq!((rescue.from_procs, rescue.to_procs), (32, 16));
    // rework: 50 s of execution post-dated the (600 s) checkpoint grid
    assert!((r.resilience.rework_time - 50.0).abs() < 1e-6, "{}", r.resilience.rework_time);
    // the dead node stays down: availability dips below 1
    assert!(r.resilience.availability < 1.0);
    assert!(r.resilience.lost_node_seconds > 0.0);
    assert!(r.rms.check_invariants());
}

#[test]
fn rigid_job_is_requeued_with_rework() {
    let w = one_cg_workload().as_fixed();
    let r = run_with(fail_at(5, 50.0), RecoveryConfig::default(), &w);
    assert_eq!(r.rms.completed_jobs(), 1, "requeued job still completes");
    assert_eq!(r.resilience.interrupted, 1);
    assert_eq!(r.resilience.rescued, 0);
    assert_eq!(r.resilience.requeued, 1);
    assert_eq!(r.rms.log.requeues(), 1);
    let job = r.rms.jobs().next().unwrap();
    assert_eq!(job.requeues, 1);
    // it restarted on the 63 surviving nodes at the failure instant and
    // redid the lost 50 s: exec ends later than the fault-free ~607 s
    assert!(r.makespan > 650.0, "makespan {}", r.makespan);
    assert!(r.rms.check_invariants());
}

#[test]
fn no_checkpointing_loses_all_progress() {
    let w = one_cg_workload().as_fixed();
    let keep = run_with(
        fail_at(5, 250.0),
        RecoveryConfig { checkpoint_interval: 100.0, ..Default::default() },
        &w,
    );
    let lose = run_with(
        fail_at(5, 250.0),
        RecoveryConfig { checkpoint_interval: 0.0, ..Default::default() },
        &w,
    );
    assert!((keep.resilience.rework_time - 50.0).abs() < 1e-6, "50 s past the last checkpoint");
    assert!((lose.resilience.rework_time - 250.0).abs() < 1e-6, "everything lost");
    assert!(
        lose.makespan > keep.makespan,
        "restart-from-scratch {} must outlast checkpointed {}",
        lose.makespan,
        keep.makespan
    );
}

#[test]
fn shrink_below_min_falls_back_to_requeue() {
    // An N-body job at its minimum (1 proc) has no reachable shrink: the
    // failure must requeue it even though it is malleable.
    let mut spec = JobSpec::from_app(AppKind::NBody, "NB-0".into(), 0.0, 1.0);
    spec.procs = 1;
    spec.min_procs = 1;
    spec.max_procs = 1;
    spec.pref_procs = None;
    let w = WorkloadSpec { jobs: vec![spec], seed: 1 };
    let r = run_with(fail_at(0, 50.0), RecoveryConfig::default(), &w);
    assert_eq!(r.resilience.interrupted, 1);
    assert_eq!(r.resilience.rescued, 0);
    assert_eq!(r.resilience.requeued, 1);
    assert_eq!(r.rms.completed_jobs(), 1);
}

#[test]
fn drained_nodes_finish_their_job_then_go_offline() {
    // Two rigid CG jobs (32 nodes each); a drain window [10, 100) over
    // nodes 0..40 blocks the second job until the window ends.
    let a = JobSpec::from_app(AppKind::Cg, "CG-A".into(), 0.0, 1.0);
    let b = JobSpec::from_app(AppKind::Cg, "CG-B".into(), 20.0, 1.0);
    let w = WorkloadSpec { jobs: vec![a, b], seed: 1 }.as_fixed();
    let faults = FaultSpec {
        drains: vec![DrainWindow { start: 10.0, end: 100.0, nodes: DrainSet::Count(40) }],
        ..Default::default()
    };
    let r = run_with(faults, RecoveryConfig::default(), &w);
    assert_eq!(r.rms.completed_jobs(), 2);
    // A kept its 32 nodes through the window (drain never kills).
    let ja = r.rms.jobs().find(|j| j.spec.name == "CG-A").unwrap();
    assert_eq!(ja.start_time, Some(0.0));
    assert!(ja.requeues == 0 && ja.resize_log.is_empty());
    // B needed 32 nodes but only 24 were up inside the window: it starts
    // exactly when the window ends.
    let jb = r.rms.jobs().find(|j| j.spec.name == "CG-B").unwrap();
    let start_b = jb.start_time.unwrap();
    assert!((start_b - 100.0).abs() < 1e-9, "B started at {start_b}, want 100");
    // 8 idle drained nodes were offline for the 90 s window
    assert!((r.resilience.lost_node_seconds - 8.0 * 90.0).abs() < 1e-6);
    assert!(r.rms.check_invariants());
}

#[test]
fn node_repair_restores_capacity() {
    // Fail an idle region before arrival, repair mid-queue: the second
    // job starts at the repair.
    let a = JobSpec::from_app(AppKind::Cg, "CG-A".into(), 0.0, 1.0);
    let b = JobSpec::from_app(AppKind::Cg, "CG-B".into(), 5.0, 1.0);
    let w = WorkloadSpec { jobs: vec![a, b], seed: 1 }.as_fixed();
    let faults = FaultSpec {
        scripted: (40..48)
            .flat_map(|n| {
                vec![
                    FaultTraceEvent { at: 1.0, node: n, kind: FaultKind::Fail },
                    FaultTraceEvent { at: 200.0, node: n, kind: FaultKind::Repair },
                ]
            })
            .collect(),
        ..Default::default()
    };
    let r = run_with(faults, RecoveryConfig::default(), &w);
    assert_eq!(r.rms.completed_jobs(), 2);
    let jb = r.rms.jobs().find(|j| j.spec.name == "CG-B").unwrap();
    let start_b = jb.start_time.unwrap();
    assert!((start_b - 200.0).abs() < 1e-9, "B started at {start_b}, want 200");
}

#[test]
fn mtbf_runs_drain_and_are_deterministic() {
    let w = dmr::workload::generate(25, 9);
    let run = || {
        let cfg = DesConfig {
            rms: RmsConfig { nodes: 64, ..Default::default() },
            resilience: ResilienceConfig {
                faults: FaultSpec { mtbf: 40_000.0, mttr: 800.0, ..Default::default() },
                recovery: RecoveryConfig::default(),
                ..Default::default()
            },
            ..Default::default()
        };
        let r = Engine::new(cfg).run(&w, "mtbf");
        assert_eq!(r.rms.completed_jobs(), 25, "faulty workload must still drain");
        assert!(r.rms.check_invariants());
        (r.rms.log.digest(), r.makespan.to_bits(), r.events)
    };
    assert_eq!(run(), run(), "fault replay must be bit-identical");
}

/// The acceptance scenario: the checked-in faulty_cluster campaign shows
/// malleable jobs rescued by shrink and a lower completion time than the
/// rigid configuration under the same fault trace.
#[test]
fn faulty_cluster_campaign_shows_the_malleability_dividend() {
    let spec = CampaignSpec::from_file("scenarios/faulty_cluster.toml").unwrap();
    assert_eq!(spec.matrix_size(), 6, "1 workload x 1 nodes x 2 modes x 3 seeds");
    let res = campaign::run_campaign(&spec, 2).unwrap();
    let aggs = campaign::aggregate(&res.records);
    assert_eq!(aggs.len(), 2);
    let fixed = aggs.iter().find(|a| a.scenario.ends_with("-fixed")).unwrap();
    let sync = aggs.iter().find(|a| a.scenario.ends_with("-sync")).unwrap();

    // Failures hit both configurations (same machine timeline) ...
    assert!(fixed.interrupted.sum() > 0.0, "rigid runs saw no failures");
    assert!(sync.interrupted.sum() > 0.0, "malleable runs saw no failures");
    // ... but only malleable jobs get rescued,
    assert!(sync.rescued.sum() > 0.0, "no malleable job was rescued by shrink");
    assert_eq!(fixed.rescued.sum(), 0.0, "rigid jobs cannot be rescued");
    assert!(fixed.requeued.sum() > 0.0, "rigid victims must requeue");
    // ... and the malleable configuration completes the stream sooner.
    assert!(
        sync.completion_s.mean() < fixed.completion_s.mean(),
        "malleable completion {} !< rigid completion {} under the same faults",
        sync.completion_s.mean(),
        fixed.completion_s.mean()
    );
    assert!(
        sync.makespan_s.mean() < fixed.makespan_s.mean(),
        "malleable makespan {} !< rigid makespan {}",
        sync.makespan_s.mean(),
        fixed.makespan_s.mean()
    );
}
