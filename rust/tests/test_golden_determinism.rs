//! Golden behavior-preservation tests for the O(active) hot-path
//! refactor: the optimized scheduling/DES paths must produce
//! **bit-identical** event logs, makespans and campaign aggregates.
//!
//! Four layers of protection:
//!
//! 1. The cached pending-queue order (a nontrivial reuse rule) is
//!    compared against the always-re-sort reference path
//!    (`RmsConfig::cache_pending_order = false`) across fixed/sync/async
//!    modes.
//! 2. The incremental availability profile + no-op elision
//!    (`RmsConfig::incremental_profile = true`, the default) is compared
//!    against the rebuild-and-sort reference path — fault-free and under
//!    fault injection.
//! 3. Campaign aggregate CSV rows are compared across worker counts.
//! 4. A recorded fixture (`rust/tests/fixtures/golden_hotpath.txt`) locks
//!    the exact event stream across PRs.  On the first run the fixture is
//!    recorded; afterwards any drift fails the test.  Rerun with
//!    `GOLDEN_UPDATE=1` to re-record after an *intentional* behavior
//!    change (and say why in the PR).  CI refuses a tree where the
//!    fixture had to be recorded (see the "Golden fixture is committed"
//!    step in `.github/workflows/ci.yml`) — commit the recorded file,
//!    otherwise the drift lock is inert.

use std::fs;
use std::path::PathBuf;

use dmr::campaign::{self, CampaignSpec};
use dmr::des::{DesConfig, Engine};
use dmr::dmr::SchedMode;
use dmr::metrics::report;
use dmr::resilience::{
    DrainSet, DrainWindow, FaultKind, FaultSpec, FaultTraceEvent, RecoveryConfig,
    ResilienceConfig,
};
use dmr::rms::RmsConfig;
use dmr::workload::{self, Adapted, FeitelsonParams, FeitelsonStream};

/// One run reduced to a digest line: event count, event-log FNV digest,
/// makespan bits.  Equal lines <=> bit-identical observable behavior.
fn run_digest(mode: &str, cache_pending_order: bool, incremental_profile: bool) -> String {
    let w = workload::generate(40, 17);
    let (sched, flexible) = match mode {
        "fixed" => (SchedMode::Sync, false),
        "sync" => (SchedMode::Sync, true),
        "async" => (SchedMode::Async, true),
        other => panic!("unknown mode {other}"),
    };
    let w = if flexible { w } else { w.as_fixed() };
    let cfg = DesConfig {
        rms: RmsConfig { nodes: 64, cache_pending_order, incremental_profile, ..Default::default() },
        mode: sched,
        ..Default::default()
    };
    let r = Engine::new(cfg).run(&w, mode);
    assert_eq!(r.rms.completed_jobs(), 40, "{mode}: workload must drain");
    assert!(r.rms.check_invariants());
    format!(
        "{mode} events={} log={:016x} makespan={:016x}",
        r.events,
        r.rms.log.digest(),
        r.makespan.to_bits()
    )
}

/// A fault-heavy run reduced to a digest line: MTBF sampling + a scripted
/// failure + a drain window over the same 40-job stream.  The digest
/// covers the failure events (NodeFailed/Interrupted/Rescued/Requeued/
/// Drain*) through `EventLog::digest`, so any drift in the fault replay
/// fails the fixture comparison.
fn fault_run_digest(mode: &str, incremental_profile: bool) -> String {
    let w = workload::generate(40, 17);
    let (sched, flexible) = match mode {
        "fixed" => (SchedMode::Sync, false),
        "sync" => (SchedMode::Sync, true),
        "async" => (SchedMode::Async, true),
        other => panic!("unknown mode {other}"),
    };
    let w = if flexible { w } else { w.as_fixed() };
    let cfg = DesConfig {
        rms: RmsConfig { nodes: 64, incremental_profile, ..Default::default() },
        mode: sched,
        resilience: ResilienceConfig {
            faults: FaultSpec {
                mtbf: 60_000.0,
                mttr: 1_000.0,
                scripted: vec![FaultTraceEvent { at: 300.0, node: 1, kind: FaultKind::Fail }],
                drains: vec![DrainWindow {
                    start: 1_500.0,
                    end: 3_000.0,
                    nodes: DrainSet::Count(6),
                }],
            },
            recovery: RecoveryConfig { checkpoint_interval: 500.0, ..Default::default() },
            ..Default::default()
        },
        ..Default::default()
    };
    let r = Engine::new(cfg).run(&w, mode);
    assert_eq!(r.rms.completed_jobs(), 40, "fault-{mode}: workload must drain");
    assert!(r.rms.check_invariants());
    assert!(r.resilience.node_failures > 0, "fault-{mode}: the scripted failure must land");
    format!(
        "fault-{mode} events={} log={:016x} makespan={:016x} failures={} rescued={} requeued={}",
        r.events,
        r.rms.log.digest(),
        r.makespan.to_bits(),
        r.resilience.node_failures,
        r.resilience.rescued,
        r.resilience.requeued,
    )
}

/// The same run as [`run_digest`]'s optimized path, but pulled lazily
/// from the generator stream with the given look-ahead window instead of
/// a materialized workload vector.  `keep_records` toggles slab/telemetry
/// reclamation — the rolling log digest must survive either way.
fn streamed_run_digest(mode: &str, window: usize, keep_records: bool) -> String {
    let (sched, flexible) = match mode {
        "fixed" => (SchedMode::Sync, false),
        "sync" => (SchedMode::Sync, true),
        "async" => (SchedMode::Async, true),
        other => panic!("unknown mode {other}"),
    };
    // Mirror run_digest exactly: generate(40, 17) applies no cluster fit,
    // so the adapter only carries the rigid-baseline transform.
    let params = FeitelsonParams { jobs: 40, ..Default::default() };
    let mut stream = Adapted::new(FeitelsonStream::new(params, 17)).fixed(!flexible);
    let cfg = DesConfig {
        rms: RmsConfig { nodes: 64, keep_records, ..Default::default() },
        mode: sched,
        ..Default::default()
    };
    let r = Engine::new(cfg)
        .run_stream(&mut stream, window, mode)
        .expect("generator streams cannot fail");
    assert_eq!(r.user_jobs, 40, "streamed-{mode}: workload must drain");
    assert!(r.rms.check_invariants());
    assert!(r.peak_slab > 0 && r.peak_slab <= 64, "peak {} out of bounds", r.peak_slab);
    format!(
        "{mode} events={} log={:016x} makespan={:016x}",
        r.events,
        r.rms.log.digest(),
        r.makespan.to_bits()
    )
}

fn campaign_digest() -> String {
    let spec = CampaignSpec::from_toml_str(
        r#"
name = "golden"
nodes = [32, 64]
modes = ["fixed", "sync", "async"]
seeds = [1, 2]
[[workload]]
kind = "feitelson"
jobs = 15
"#,
    )
    .unwrap();
    let res = campaign::run_campaign(&spec, 2).unwrap();
    let aggs = campaign::aggregate(&res.records);
    let rows = report::campaign_agg_rows(&aggs);
    // Flatten the CSV rows into one stable line.
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for cell in rows.iter().flatten() {
        for b in cell.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    format!("campaign rows={} agg={h:016x}", rows.len())
}

/// The cached pending order must be indistinguishable from re-sorting on
/// every pass — across all three scheduling modes.
#[test]
fn optimized_path_matches_uncached_reference() {
    for mode in ["fixed", "sync", "async"] {
        let fast = run_digest(mode, true, true);
        let slow = run_digest(mode, false, true);
        assert_eq!(fast, slow, "{mode}: cached pending order changed behavior");
    }
}

/// Repeated runs are bit-identical (no hidden iteration-order or
/// allocation-order dependence anywhere in the hot path).
#[test]
fn repeated_runs_bit_identical() {
    for mode in ["fixed", "sync", "async"] {
        assert_eq!(run_digest(mode, true, true), run_digest(mode, true, true), "{mode}");
    }
}

/// The incremental availability profile (and its no-op pass/check
/// elision) must be indistinguishable from the rebuild-and-sort
/// reference path — across all three scheduling modes, fault-free.
#[test]
fn incremental_profile_matches_reference_path() {
    for mode in ["fixed", "sync", "async"] {
        let fast = run_digest(mode, true, true);
        let slow = run_digest(mode, true, false);
        assert_eq!(fast, slow, "{mode}: incremental profile changed behavior");
    }
}

/// Same lock under fault injection: failure evictions, rescue shrinks
/// and requeues all publish profile deltas, and the elided passes around
/// them must not change a single event.
#[test]
fn incremental_profile_matches_reference_path_under_faults() {
    for mode in ["fixed", "sync", "async"] {
        let fast = fault_run_digest(mode, true);
        let slow = fault_run_digest(mode, false);
        assert_eq!(fast, slow, "fault-{mode}: incremental profile changed behavior");
    }
}

/// Fault replay is deterministic: same spec + seed produces bit-identical
/// event logs (failure events included) across runs, in every mode.
#[test]
fn fault_injection_replays_bit_identical() {
    for mode in ["fixed", "sync", "async"] {
        assert_eq!(fault_run_digest(mode, true), fault_run_digest(mode, true), "fault-{mode}");
    }
}

/// The rigid and malleable runs of one scenario face the *same* machine
/// timeline: node-failure times come from a dedicated RNG stream whose
/// draws never depend on job events, so one run's (node, time) failure
/// sequence is a prefix of the other's (the longer makespan simply sees
/// more of the shared timeline).
#[test]
fn fault_timeline_identical_across_modes() {
    use dmr::rms::RmsEvent;
    let failure_seq = |mode: &str, flexible: bool| -> Vec<(usize, u64)> {
        let w = workload::generate(40, 17);
        let w = if flexible { w } else { w.as_fixed() };
        let cfg = DesConfig {
            rms: RmsConfig { nodes: 64, ..Default::default() },
            mode: SchedMode::Sync,
            resilience: ResilienceConfig {
                faults: FaultSpec { mtbf: 60_000.0, mttr: 1_000.0, ..Default::default() },
                recovery: RecoveryConfig::default(),
                ..Default::default()
            },
            ..Default::default()
        };
        let r = Engine::new(cfg).run(&w, mode);
        r.rms
            .log
            .all()
            .iter()
            .filter_map(|e| match e {
                RmsEvent::NodeFailed { node, time } => Some((*node, time.to_bits())),
                _ => None,
            })
            .collect()
    };
    let fixed = failure_seq("fixed", false);
    let sync = failure_seq("sync", true);
    let n = fixed.len().min(sync.len());
    assert!(n > 0, "both runs must observe failures");
    assert_eq!(
        &fixed[..n],
        &sync[..n],
        "rigid and malleable runs diverged on the shared machine timeline"
    );
}

/// The streamed replay path must be bit-identical with the batch path —
/// for every mode, any look-ahead window, and with record retention on
/// or off (reclamation must never touch the observable event stream).
#[test]
fn streamed_replay_matches_batch_path() {
    for mode in ["fixed", "sync", "async"] {
        let batch = run_digest(mode, true, true);
        for window in [1, 7, 64, usize::MAX] {
            for keep in [true, false] {
                assert_eq!(
                    streamed_run_digest(mode, window, keep),
                    batch,
                    "{mode}: streamed (window {window}, keep_records {keep}) \
                     diverged from the batch path"
                );
            }
        }
    }
}

/// Campaign aggregates must not depend on the worker count.
#[test]
fn campaign_aggregates_identical_across_worker_counts() {
    let spec = CampaignSpec::from_toml_str(
        r#"
name = "golden-workers"
nodes = [32]
modes = ["fixed", "sync"]
seeds = [1, 2, 3]
[[workload]]
kind = "feitelson"
jobs = 10
"#,
    )
    .unwrap();
    let rows = |workers: usize| {
        let res = campaign::run_campaign(&spec, workers).unwrap();
        report::campaign_agg_rows(&campaign::aggregate(&res.records))
    };
    let base = rows(1);
    assert_eq!(base, rows(3), "aggregates must not depend on worker count");
}

/// Cross-PR drift lock: compare against (or record) the golden fixture.
/// Covers the fault-free event streams, the campaign aggregate, and the
/// fault-injection streams (failure events included).
#[test]
fn golden_fixture_locks_event_stream() {
    let mut lines: Vec<String> = ["fixed", "sync", "async"]
        .iter()
        .map(|m| run_digest(m, true, true))
        .collect();
    lines.push(campaign_digest());
    for m in ["fixed", "sync", "async"] {
        lines.push(fault_run_digest(m, true));
    }
    // Streamed replay digests (window 7, records reclaimed): locked
    // directly so fixture drift points at the streaming layer even if
    // the batch path moves in the same PR.
    for m in ["fixed", "sync", "async"] {
        lines.push(format!("streamed-{}", streamed_run_digest(m, 7, false)));
    }
    let body = format!("{}\n", lines.join("\n"));

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/golden_hotpath.txt");
    let update = std::env::var("GOLDEN_UPDATE").map(|v| v == "1").unwrap_or(false);
    if update || !path.exists() {
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(&path, &body).unwrap();
        eprintln!("golden fixture recorded at {}", path.display());
        return;
    }
    let want = fs::read_to_string(&path).unwrap();
    assert_eq!(
        body, want,
        "scheduling behavior drifted from the recorded golden fixture \
         ({}); if the change is intentional, re-record with GOLDEN_UPDATE=1 \
         and justify it in the PR",
        path.display()
    );
}
