//! DES workload integration: drain, determinism, conservation and the
//! paper's headline orderings across sizes, seeds and modes.

use dmr::des::{DesConfig, Engine, ExecModel};
use dmr::dmr::SchedMode;
use dmr::metrics::RunSummary;
use dmr::rms::RmsConfig;
use dmr::workload;

fn run(jobs: usize, seed: u64, mode: SchedMode, flexible: bool) -> RunSummary {
    let w = workload::generate(jobs, seed);
    let w = if flexible { w } else { w.as_fixed() };
    let cfg = DesConfig { mode, ..Default::default() };
    RunSummary::from_run(Engine::new(cfg).run(&w, if flexible { "flex" } else { "fixed" }))
}

#[test]
fn drains_all_sizes_and_modes() {
    for &n in &[10usize, 50, 120] {
        for mode in [SchedMode::Sync, SchedMode::Async] {
            for flexible in [false, true] {
                let s = run(n, 5, mode, flexible);
                assert_eq!(s.jobs.len(), n, "{n} jobs, {mode:?}, flexible={flexible}");
                // every job has consistent timestamps
                for j in &s.jobs {
                    assert!(j.start >= j.submit);
                    assert!(j.end > j.start);
                }
            }
        }
    }
}

#[test]
fn deterministic_across_runs() {
    let a = run(60, 9, SchedMode::Sync, true);
    let b = run(60, 9, SchedMode::Sync, true);
    assert_eq!(a.makespan, b.makespan);
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.start, y.start);
        assert_eq!(x.end, y.end);
    }
}

#[test]
fn different_seeds_differ() {
    let a = run(60, 1, SchedMode::Sync, true);
    let b = run(60, 2, SchedMode::Sync, true);
    assert_ne!(a.makespan, b.makespan);
}

/// Conservation: a fixed job's execution time equals exactly its modeled
/// work at its allocation (no time lost or created by the engine).
#[test]
fn fixed_exec_times_match_model_exactly() {
    let s = run(40, 13, SchedMode::Sync, false);
    let w = workload::generate(40, 13);
    let em = ExecModel::default();
    for (rec, spec) in s.jobs.iter().zip(&w.jobs) {
        assert_eq!(rec.name, spec.name);
        let want = em.exec_time(spec, spec.procs);
        assert!(
            (rec.exec() - want).abs() < 1e-6,
            "{}: exec {} vs model {}",
            rec.name,
            rec.exec(),
            want
        );
    }
}

/// Flexible jobs can only run slower than fixed ones individually —
/// malleability trades per-job speed for global throughput.
#[test]
fn flexible_headlines_hold_across_seeds() {
    for seed in [3u64, 21, 99] {
        let fixed = run(50, seed, SchedMode::Sync, false);
        let flex = run(50, seed, SchedMode::Sync, true);
        assert!(flex.makespan < fixed.makespan, "seed {seed}: makespan");
        assert!(flex.wait.mean() < fixed.wait.mean(), "seed {seed}: wait");
        assert!(flex.exec.mean() > fixed.exec.mean(), "seed {seed}: exec");
        assert!(
            flex.node_seconds() < fixed.node_seconds(),
            "seed {seed}: node-seconds (smarter usage)"
        );
    }
}

#[test]
fn no_expand_timeouts_in_sync_mode() {
    let s = run(100, 4, SchedMode::Sync, true);
    assert_eq!(s.actions.expand_aborts, 0, "sync expansions never wait");
}

#[test]
fn async_mode_suffers_timeouts_under_pressure() {
    let s = run(200, 4, SchedMode::Async, true);
    assert!(
        s.actions.expand_aborts > 0,
        "stale async decisions must hit the resizer timeout"
    );
    // Aborted expansions show up as the long tail of expand durations
    // (Table 2's 40 s max).
    assert!(s.actions.expand.max() >= 39.0);
}

#[test]
fn smaller_cluster_serializes_more() {
    let w = workload::generate(40, 8);
    let small = DesConfig {
        rms: RmsConfig { nodes: 32, ..Default::default() },
        ..Default::default()
    };
    let big = DesConfig {
        rms: RmsConfig { nodes: 128, ..Default::default() },
        ..Default::default()
    };
    let s = RunSummary::from_run(Engine::new(small).run(&w, "small"));
    let b = RunSummary::from_run(Engine::new(big).run(&w, "big"));
    assert!(s.makespan > b.makespan);
}

/// Failure injection: a cluster with down nodes still drains (capacity
/// shrinks, waits grow).
#[test]
fn down_nodes_reduce_capacity_but_workload_drains() {
    let w = workload::generate(20, 15);
    let mut cfg = DesConfig::default();
    cfg.rms.nodes = 64;
    let mut engine = Engine::new(cfg);
    // mark 16 nodes down before any arrival
    for n in 48..64 {
        engine_cluster(&mut engine).set_down(n).unwrap();
    }
    let r = engine.run(&w, "degraded");
    assert_eq!(r.rms.completed_jobs(), 20);
    let healthy = run(20, 15, SchedMode::Sync, true);
    let degraded = RunSummary::from_run(r);
    assert!(degraded.makespan >= healthy.makespan);
}

// Small helper: reach the engine's cluster for failure injection.
fn engine_cluster(engine: &mut Engine) -> &mut dmr::cluster::Cluster {
    engine.cluster_mut()
}
