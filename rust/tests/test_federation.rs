//! Federation subsystem tests: the meta-scheduler over sharded clusters.
//!
//! The load-bearing property is the determinism contract from
//! `rust/src/federation/mod.rs`:
//!
//! 1. A **1-shard federation is bit-identical to the flat engine** —
//!    event-log digests and makespan bits — across fixed/sync/async,
//!    fault-free and under fault injection.  This proves the shard
//!    generalization of `des::Engine` did not perturb the existing
//!    single-cluster behavior that the golden fixtures lock.
//! 2. A **multi-shard run is a pure function of (spec, seed, layout)**:
//!    repeating a run reproduces every per-shard digest.
//!
//! On top of that: routing-policy behavior (least-loaded beats
//! round-robin on a speed-skewed topology; locality homes users), work
//! stealing (backlogged shards drain into idle ones and the makespan
//! improves), and the campaign-level `[federation]` axis end to end.

use dmr::campaign::{self, CampaignSpec};
use dmr::des::{DesConfig, Engine};
use dmr::dmr::SchedMode;
use dmr::federation::{
    FedEngine, FederationConfig, FedRunResult, RoutingPolicy, ShardSpec, StealPolicy,
};
use dmr::metrics::RunSummary;
use dmr::resilience::{
    DrainSet, DrainWindow, FaultKind, FaultSpec, FaultTraceEvent, RecoveryConfig,
    ResilienceConfig,
};
use dmr::rms::RmsConfig;
use dmr::workload::{self, WorkloadSpec};

fn modes() -> [(&'static str, SchedMode, bool); 3] {
    [
        ("fixed", SchedMode::Sync, false),
        ("sync", SchedMode::Sync, true),
        ("async", SchedMode::Async, true),
    ]
}

fn base_cfg(sched: SchedMode, faulty: bool) -> DesConfig {
    let resilience = if faulty {
        ResilienceConfig {
            faults: FaultSpec {
                mtbf: 60_000.0,
                mttr: 1_000.0,
                scripted: vec![FaultTraceEvent { at: 300.0, node: 1, kind: FaultKind::Fail }],
                drains: vec![DrainWindow {
                    start: 1_500.0,
                    end: 3_000.0,
                    nodes: DrainSet::Count(6),
                }],
            },
            recovery: RecoveryConfig { checkpoint_interval: 500.0, ..Default::default() },
            ..Default::default()
        }
    } else {
        ResilienceConfig::default()
    };
    DesConfig {
        rms: RmsConfig { nodes: 64, ..Default::default() },
        mode: sched,
        resilience,
        ..Default::default()
    }
}

fn stream(flexible: bool) -> WorkloadSpec {
    let w = workload::generate(40, 17);
    if flexible {
        w
    } else {
        w.as_fixed()
    }
}

fn fed_run(cfg: DesConfig, fed: FederationConfig, w: &WorkloadSpec, label: &str) -> FedRunResult {
    FedEngine::new(cfg, fed).run(w, label)
}

#[test]
fn one_shard_federation_is_bit_identical_to_flat_engine() {
    for faulty in [false, true] {
        for (mode, sched, flexible) in modes() {
            let w = stream(flexible);
            let flat = Engine::new(base_cfg(sched, faulty)).run(&w, mode);
            let fed = fed_run(
                base_cfg(sched, faulty),
                FederationConfig {
                    shards: ShardSpec::uniform(64, 1),
                    routing: RoutingPolicy::RoundRobin,
                    steal: StealPolicy::Head, // must be inert at one shard
                    ..Default::default()
                },
                &w,
                mode,
            );
            let tag = format!("{mode} faulty={faulty}");
            assert_eq!(fed.shards.len(), 1);
            assert_eq!(fed.events, flat.events, "{tag}: event count");
            assert_eq!(
                fed.shards[0].rms.log.digest(),
                flat.rms.log.digest(),
                "{tag}: event-log digest"
            );
            assert_eq!(
                fed.makespan.to_bits(),
                flat.makespan.to_bits(),
                "{tag}: makespan bits"
            );
            assert_eq!(fed.shards[0].rms.completed_jobs(), 40, "{tag}: drained");
            assert_eq!(fed.steals(), 0, "{tag}: no peers to steal from");
            assert_eq!(
                fed.resilience.node_failures, flat.resilience.node_failures,
                "{tag}: fault replay"
            );
        }
    }
}

#[test]
fn multi_shard_runs_are_deterministic() {
    let run = || {
        let w = workload::generate(50, 23);
        let r = fed_run(
            base_cfg(SchedMode::Sync, true),
            FederationConfig {
                shards: vec![
                    ShardSpec { nodes: 32, speed: 1.0, mtbf_scale: 1.0, ..Default::default() },
                    ShardSpec { nodes: 24, speed: 0.5, mtbf_scale: 2.0, ..Default::default() },
                    ShardSpec { nodes: 8, speed: 2.0, mtbf_scale: 0.5, ..Default::default() },
                ],
                routing: RoutingPolicy::LeastLoaded,
                steal: StealPolicy::Head,
                ..Default::default()
            },
            &w,
            "det",
        );
        let digests: Vec<u64> = r.shards.iter().map(|s| s.rms.log.digest()).collect();
        (r.events, digests, r.makespan.to_bits(), r.steals())
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same (spec, seed, layout) must replay bit-identically");
    // and the heterogeneous layout actually engaged all shards
    let (_, digests, _, _) = a;
    assert_eq!(digests.len(), 3);
}

#[test]
fn every_job_completes_exactly_once_across_shards() {
    let w = workload::generate(60, 5);
    let r = fed_run(
        base_cfg(SchedMode::Sync, false),
        FederationConfig {
            shards: ShardSpec::uniform(64, 4),
            routing: RoutingPolicy::RoundRobin,
            steal: StealPolicy::Head,
            ..Default::default()
        },
        &w,
        "complete",
    );
    let total: usize = r.shards.iter().map(|s| s.rms.completed_jobs()).sum();
    assert_eq!(total, 60, "no job lost or duplicated by routing/stealing");
    assert_eq!(r.user_jobs, 60);
    let routed: u64 = r.shards.iter().map(|s| s.routed).sum();
    assert_eq!(routed, 60, "every arrival routed exactly once");
    for s in &r.shards {
        assert!(s.rms.check_invariants(), "shard {} invariants", s.shard);
    }
}

#[test]
fn least_loaded_beats_round_robin_on_speed_skewed_topology() {
    // Two equal-size shards, one 5x slower.  Round-robin alternates
    // blindly, so half the stream lands on the slow shard; least-loaded
    // sees the slow shard's backlog and steers work to the fast one.
    // Rigid jobs + no stealing isolate the routing signal.
    let shards = vec![
        ShardSpec { nodes: 32, speed: 1.0, mtbf_scale: 1.0, ..Default::default() },
        ShardSpec { nodes: 32, speed: 0.2, mtbf_scale: 1.0, ..Default::default() },
    ];
    let run = |routing: RoutingPolicy| {
        let w = workload::generate(60, 11).as_fixed();
        fed_run(
            base_cfg(SchedMode::Sync, false),
            FederationConfig { shards: shards.clone(), routing, ..Default::default() },
            &w,
            routing.label(),
        )
    };
    let rr = run(RoutingPolicy::RoundRobin);
    let ll = run(RoutingPolicy::LeastLoaded);
    assert!(
        ll.makespan < rr.makespan,
        "least-loaded ({:.0}s) must beat round-robin ({:.0}s) on a skewed topology",
        ll.makespan,
        rr.makespan
    );
    // and it does so by routing more work to the fast shard
    assert!(
        ll.shards[0].routed > rr.shards[0].routed,
        "ll routed {} to the fast shard, rr routed {}",
        ll.shards[0].routed,
        rr.shards[0].routed
    );
}

#[test]
fn work_stealing_drains_a_backlogged_shard() {
    // Home every job on shard 0 via locality routing (single user), so
    // shard 1 idles unless the meta-scheduler steals.
    let mut w = workload::generate(30, 9);
    for j in &mut w.jobs {
        j.user = 0;
    }
    let run = |steal: StealPolicy| {
        fed_run(
            base_cfg(SchedMode::Sync, false),
            FederationConfig {
                shards: ShardSpec::uniform(64, 2),
                routing: RoutingPolicy::Locality,
                steal,
                ..Default::default()
            },
            &w,
            steal.label(),
        )
    };
    let idle = run(StealPolicy::Off);
    assert_eq!(idle.steals(), 0);
    assert_eq!(idle.shards[1].routed, 0, "all arrivals home on shard 0");
    assert_eq!(idle.shards[1].rms.completed_jobs(), 0);

    let stealing = run(StealPolicy::Head);
    assert!(stealing.steals() > 0, "the idle shard must pull queued work");
    assert_eq!(stealing.shards[0].steals_out, stealing.shards[1].steals_in);
    assert!(
        stealing.shards[1].rms.completed_jobs() > 0,
        "stolen jobs complete on the thief shard"
    );
    let total: usize = stealing.shards.iter().map(|s| s.rms.completed_jobs()).sum();
    assert_eq!(total, 30);
    assert!(
        stealing.makespan < idle.makespan,
        "stealing ({:.0}s) must beat the idle-shard run ({:.0}s)",
        stealing.makespan,
        idle.makespan
    );
}

#[test]
fn locality_routing_homes_users_on_their_shard() {
    // 64 nodes in 2 shards of 32: every generated job (max 32 procs)
    // fits its home shard, so the fall-forward never fires and user u
    // lands exactly on shard u mod 2.
    let w = workload::generate(40, 3);
    let r = fed_run(
        base_cfg(SchedMode::Sync, false),
        FederationConfig {
            shards: ShardSpec::uniform(64, 2),
            routing: RoutingPolicy::Locality,
            ..Default::default()
        },
        &w,
        "locality",
    );
    for s in &r.shards {
        assert!(s.routed > 0, "both shards receive their users' jobs");
        for j in dmr::metrics::extract(&s.rms) {
            assert_eq!(
                j.user as usize % 2,
                s.shard,
                "job {} (user {}) homed on the wrong shard",
                j.name,
                j.user
            );
        }
    }
}

#[test]
fn fed_summary_merges_shards_and_reports_per_shard_measures() {
    let w = workload::generate(30, 7);
    let r = fed_run(
        base_cfg(SchedMode::Sync, false),
        FederationConfig {
            shards: ShardSpec::uniform(64, 2),
            routing: RoutingPolicy::LeastLoaded,
            steal: StealPolicy::Head,
            ..Default::default()
        },
        &w,
        "summary",
    );
    let s = RunSummary::from_fed(&r, RoutingPolicy::LeastLoaded, StealPolicy::Head);
    assert_eq!(s.jobs.len(), 30, "merged job records cover every shard");
    let fed = s.federation.as_ref().expect("federated summary present");
    assert_eq!(fed.shards, 2);
    assert_eq!(fed.routing, "ll");
    assert_eq!(fed.steal, "head");
    assert_eq!(fed.evacuations, 0, "no outages configured");
    assert_eq!(fed.per_shard.len(), 2);
    assert_eq!(fed.per_shard.iter().map(|p| p.nodes).sum::<usize>(), 64);
    assert_eq!(
        fed.per_shard.iter().map(|p| p.jobs).sum::<usize>(),
        30,
        "per-shard job counts partition the workload"
    );
    for p in &fed.per_shard {
        assert!((0.0..=100.0 + 1e-9).contains(&p.util_pct), "util {}", p.util_pct);
        assert!(p.queue_depth >= 0.0);
        assert!(p.availability > 0.0);
    }
    // flat summaries stay federation-free
    let flat = Engine::new(base_cfg(SchedMode::Sync, false)).run(&w, "flat");
    assert!(RunSummary::from_run(flat).federation.is_none());
}

#[test]
fn campaign_federation_axis_runs_end_to_end() {
    let mut spec = CampaignSpec::from_toml_str(
        r#"
name = "fed-e2e"
nodes = [64]
modes = ["fixed", "sync"]
seeds = [1, 2]
[federation]
shards = [2]
routing = ["rr", "ll"]
steal = true
[[workload]]
kind = "feitelson"
jobs = 10
"#,
    )
    .unwrap();
    let dir = std::env::temp_dir().join(format!("dmr_fed_itest_{}", std::process::id()));
    spec.output_dir = dir.clone();
    assert_eq!(spec.matrix_size(), 2 * 2 * 2);
    let res = campaign::run_campaign(&spec, 4).unwrap();
    assert_eq!(res.records.len(), 8);
    for r in &res.records {
        let fed = r.summary.federation.as_ref().expect("every run is federated");
        assert_eq!(fed.shards, 2);
        assert!(r.plan.scenario.contains("-s2xrr") || r.plan.scenario.contains("-s2xll"));
    }
    let out = campaign::write_outputs(&spec, &res).unwrap();
    let runs = std::fs::read_to_string(&out.runs_csv).unwrap();
    let header = runs.lines().next().unwrap();
    assert!(header.contains(
        "fed_shards,fed_routing,fed_steals,shard_util_pct,shard_queue_depth,shard_steals"
    ));
    assert!(header.ends_with("shard_jain,evacuations,cross_shard_requeues,shard_avail_pct"));
    let row = runs.lines().nth(1).unwrap();
    assert!(row.contains(",2,rr,") || row.contains(",2,ll,"), "fed cells present: {row}");
    assert!(row.contains(';'), "per-shard cells are ;-joined: {row}");
    let agg = std::fs::read_to_string(&out.agg_csv).unwrap();
    let agg_header = agg.lines().next().unwrap();
    assert!(agg_header.contains("fed_shards,fed_steals_mean,shard_util_mean_pct"));
    assert!(agg_header.ends_with(
        "shard_jain_mean,evacuations_mean,cross_shard_requeues_mean,shard_avail_mean_pct"
    ));
    let json = std::fs::read_to_string(&out.agg_json).unwrap();
    assert!(json.contains("\"federation\""), "aggregate JSON carries the federation object");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn one_shard_campaign_matches_flat_campaign_bit_for_bit() {
    let flat_toml = r#"
name = "flatbase"
nodes = [64]
modes = ["fixed", "sync", "async"]
seeds = [1, 2]
[[workload]]
kind = "feitelson"
jobs = 12
"#;
    let fed_toml = r#"
name = "fedbase"
nodes = [64]
modes = ["fixed", "sync", "async"]
seeds = [1, 2]
[federation]
shards = [1]
[[workload]]
kind = "feitelson"
jobs = 12
"#;
    let flat_spec = CampaignSpec::from_toml_str(flat_toml).unwrap();
    let fed_spec = CampaignSpec::from_toml_str(fed_toml).unwrap();
    let flat = campaign::run_campaign(&flat_spec, 4).unwrap();
    let fed = campaign::run_campaign(&fed_spec, 4).unwrap();
    assert_eq!(flat.records.len(), fed.records.len());
    for (a, b) in flat.records.iter().zip(&fed.records) {
        assert_eq!(
            a.summary.makespan.to_bits(),
            b.summary.makespan.to_bits(),
            "{}: 1-shard federated campaign must equal the flat campaign",
            a.plan.label
        );
        assert_eq!(a.summary.util_mean.to_bits(), b.summary.util_mean.to_bits());
        let fb = b.summary.federation.as_ref().unwrap();
        assert_eq!(fb.shards, 1);
    }
}
