//! Integration: the PJRT bridge executes the real AOT artifacts and the
//! numerics match the pure-Rust references.  Requires `make artifacts`.

use dmr::runtime::{ArtifactStore, ComputeServer, TensorF32};

fn store() -> Option<ArtifactStore> {
    // Tests run from the workspace root.
    ArtifactStore::open("artifacts").ok()
}

/// CPU-side reference for tridiag(-1,2,-1) @ x on a padded shard.
fn matvec_ref(xp: &[f32]) -> Vec<f32> {
    let n = xp.len() - 2;
    (0..n)
        .map(|i| 2.0 * xp[i + 1] - xp[i] - xp[i + 2])
        .collect()
}

#[test]
fn manifest_lists_all_variants() {
    let Some(store) = store() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // 5 functions x 6 process counts
    assert_eq!(store.len(), 30);
    for p in [1usize, 2, 4, 8, 16, 32] {
        for f in ["cg_phase1", "cg_phase2", "cg_phase3", "jacobi_step", "nbody_step"] {
            assert!(store.get(&format!("{f}_p{p}")).is_ok());
        }
    }
}

#[test]
fn cg_phase1_matches_reference() {
    let Some(store) = store() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let Ok(server) = ComputeServer::start(store) else {
        eprintln!("skipping: PJRT backend unavailable (build with --features pjrt)");
        return;
    };
    let h = server.handle();

    let p = 32usize; // shard n = 16384/32 = 512
    let n = 16384 / p;
    let p_loc: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.01).sin()).collect();
    let hl = 0.5f32;
    let hr = -0.25f32;

    let out = h
        .execute(
            &format!("cg_phase1_p{p}"),
            vec![
                TensorF32::vec(p_loc.clone()),
                TensorF32::scalar(hl),
                TensorF32::scalar(hr),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 2);
    let q = &out[0];
    assert_eq!(q.shape, vec![n]);

    let mut xp = vec![hl];
    xp.extend_from_slice(&p_loc);
    xp.push(hr);
    let want = matvec_ref(&xp);
    for (a, b) in q.data.iter().zip(&want) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
    // partial p.q
    let want_pq: f32 = p_loc.iter().zip(&want).map(|(a, b)| a * b).sum();
    assert!((out[1].item() - want_pq).abs() / want_pq.abs().max(1.0) < 1e-3);
}

#[test]
fn cg_phase2_updates_and_reduces() {
    let Some(store) = store() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let Ok(server) = ComputeServer::start(store) else {
        eprintln!("skipping: PJRT backend unavailable (build with --features pjrt)");
        return;
    };
    let h = server.handle();

    let p = 32usize;
    let n = 16384 / p;
    let x: Vec<f32> = vec![1.0; n];
    let r: Vec<f32> = (0..n).map(|i| (i % 7) as f32 * 0.1).collect();
    let pp: Vec<f32> = (0..n).map(|i| (i % 5) as f32 * 0.2).collect();
    let q: Vec<f32> = (0..n).map(|i| (i % 3) as f32 * 0.3).collect();
    let alpha = 0.125f32;

    let out = h
        .execute(
            &format!("cg_phase2_p{p}"),
            vec![
                TensorF32::vec(x.clone()),
                TensorF32::vec(r.clone()),
                TensorF32::vec(pp.clone()),
                TensorF32::vec(q.clone()),
                TensorF32::scalar(alpha),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 3);
    let mut want_rr = 0.0f32;
    for i in 0..n {
        let x2 = x[i] + alpha * pp[i];
        let r2 = r[i] - alpha * q[i];
        assert!((out[0].data[i] - x2).abs() < 1e-5);
        assert!((out[1].data[i] - r2).abs() < 1e-5);
        want_rr += r2 * r2;
    }
    assert!((out[2].item() - want_rr).abs() / want_rr < 1e-3);
}

#[test]
fn nbody_step_conserves_momentum_roughly() {
    let Some(store) = store() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let Ok(server) = ComputeServer::start(store) else {
        eprintln!("skipping: PJRT backend unavailable (build with --features pjrt)");
        return;
    };
    let h = server.handle();

    // p=1: local = all 1024 bodies.
    let nb = 1024usize;
    let pos: Vec<f32> = (0..nb * 3)
        .map(|i| ((i as f32 * 0.37).sin() * 2.0) + ((i % 3) as f32))
        .collect();
    let vel = vec![0.0f32; nb * 3];
    let mass = vec![1.0f32 / nb as f32; nb];
    let dt = 1e-3f32;

    let out = h
        .execute(
            "nbody_step_p1",
            vec![
                TensorF32::new(vec![nb, 3], pos.clone()),
                TensorF32::new(vec![nb, 3], pos.clone()),
                TensorF32::new(vec![nb, 3], vel),
                TensorF32::vec(mass),
                TensorF32::scalar(dt),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 3);
    // equal masses, zero initial velocity: net momentum after one step ~ 0
    let v2 = &out[1].data;
    for d in 0..3 {
        let total: f32 = (0..nb).map(|i| v2[i * 3 + d]).sum();
        assert!(total.abs() < 1e-1, "momentum[{d}] = {total}");
    }
    // kinetic energy partial is positive
    assert!(out[2].item() > 0.0);
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(store) = store() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let Ok(server) = ComputeServer::start(store) else {
        eprintln!("skipping: PJRT backend unavailable (build with --features pjrt)");
        return;
    };
    let h = server.handle();
    // wrong arity
    assert!(h.execute("cg_phase3_p32", vec![]).is_err());
    // wrong shape
    let bad = h.execute(
        "cg_phase3_p32",
        vec![
            TensorF32::vec(vec![0.0; 7]),
            TensorF32::vec(vec![0.0; 512]),
            TensorF32::scalar(0.0),
        ],
    );
    assert!(bad.is_err());
    // unknown artifact
    assert!(h.execute("nope_p1", vec![]).is_err());
}

#[test]
fn warm_compiles_and_stats_accumulate() {
    let Some(store) = store() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let Ok(server) = ComputeServer::start(store) else {
        eprintln!("skipping: PJRT backend unavailable (build with --features pjrt)");
        return;
    };
    let h = server.handle();
    h.warm("cg_phase3_p32").unwrap();
    let stats = h.stats();
    let s = stats.iter().find(|s| s.artifact == "cg_phase3_p32").unwrap();
    assert_eq!(s.calls, 0);
    assert!(s.compile_secs > 0.0);

    let n = 512;
    let out = h
        .execute(
            "cg_phase3_p32",
            vec![
                TensorF32::vec(vec![1.0; n]),
                TensorF32::vec(vec![2.0; n]),
                TensorF32::scalar(0.5),
            ],
        )
        .unwrap();
    assert_eq!(out[0].data[0], 2.0); // r + beta*p = 1 + 0.5*2
    let stats = h.stats();
    let s = stats.iter().find(|s| s.artifact == "cg_phase3_p32").unwrap();
    assert_eq!(s.calls, 1);
}
