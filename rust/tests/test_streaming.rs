//! Streaming-equivalence differential suite: the lazy-pull replay path
//! must be observationally indistinguishable from the materialized batch
//! path, bit for bit.
//!
//! The contract under test (see `rust/src/des/engine.rs` and
//! `rust/src/workload/stream.rs`):
//!
//! 1. **Every source** — Feitelson generator, burst–lull generator, SWF
//!    line-streaming reader, and the `Materialized` compatibility adapter
//!    — replayed through `Engine::run_stream` produces the exact event
//!    log (rolling FNV digest), makespan bits, and event count of
//!    `Engine::run` over the equivalent materialized workload.
//! 2. The equivalence holds **across scheduling modes** (fixed / sync /
//!    async), **under fault injection** (MTBF + scripted failures +
//!    drain windows + transactional resize faults), and **federated**
//!    (multi-shard with stealing).
//! 3. The look-ahead **window is unobservable**: any window in
//!    {1, 7, 64, ∞} yields the same run.
//! 4. **Reclamation is unobservable**: `keep_records = false` drops the
//!    retained event vector, per-job records and slab slots, yet digests,
//!    counters and streamed metric folds match the retaining run — and
//!    peak-resident slab occupancy stays bounded by cluster capacity on a
//!    50k-job replay (memory scales with concurrency, not replay length).

use dmr::des::{DesConfig, Engine, RunResult};
use dmr::dmr::SchedMode;
use dmr::federation::{FedEngine, FederationConfig, RoutingPolicy, ShardSpec, StealPolicy};
use dmr::metrics::RunSummary;
use dmr::resilience::{
    DrainSet, DrainWindow, FaultKind, FaultSpec, FaultTraceEvent, RecoveryConfig,
    ResilienceConfig, ResizeFaultSpec,
};
use dmr::rms::RmsConfig;
use dmr::workload::{
    self, swf, Adapted, BurstLullParams, BurstLullStream, FeitelsonParams, FeitelsonStream,
    JobStream, Materialized, SwfStream, WorkloadSpec,
};

const NODES: usize = 64;

fn modes() -> [(&'static str, SchedMode, bool); 3] {
    [
        ("fixed", SchedMode::Sync, false),
        ("sync", SchedMode::Sync, true),
        ("async", SchedMode::Async, true),
    ]
}

fn swf_path() -> String {
    format!("{}/scenarios/traces/small.swf", env!("CARGO_MANIFEST_DIR"))
}

fn swf_opts() -> swf::SwfOptions {
    swf::SwfOptions {
        rescale_nodes: Some(NODES),
        malleable_fraction: 0.5,
        ..Default::default()
    }
}

/// The three real sources, as (name, materialized workload, fresh
/// stream) — streams are consumed by a run, so every comparison asks for
/// a fresh pair.
fn source(name: &str, seed: u64) -> (WorkloadSpec, Box<dyn JobStream>) {
    match name {
        "feitelson" => {
            let p = FeitelsonParams { jobs: 40, ..Default::default() };
            (workload::generate_with(&p, seed), Box::new(FeitelsonStream::new(p, seed)))
        }
        "burst-lull" => {
            let p = BurstLullParams { jobs: 30, burst: 6, ..Default::default() };
            (
                workload::generate_burst_lull(&p, seed),
                Box::new(BurstLullStream::new(p, seed)),
            )
        }
        "swf" => {
            let trace = swf::load(&swf_path()).expect("sample trace readable");
            let w = swf::to_workload(&trace, &swf_opts(), seed);
            let s = SwfStream::open(&swf_path(), swf_opts(), seed).expect("stream opens");
            (w, Box::new(s))
        }
        other => panic!("unknown source {other}"),
    }
}

fn faulty_resilience() -> ResilienceConfig {
    ResilienceConfig {
        faults: FaultSpec {
            mtbf: 60_000.0,
            mttr: 1_000.0,
            scripted: vec![FaultTraceEvent { at: 300.0, node: 1, kind: FaultKind::Fail }],
            drains: vec![DrainWindow { start: 1_500.0, end: 3_000.0, nodes: DrainSet::Count(6) }],
        },
        recovery: RecoveryConfig { checkpoint_interval: 500.0, ..Default::default() },
        resize_faults: ResizeFaultSpec {
            spawn_fail: 0.2,
            redist_fail: 0.1,
            revoke: 0.05,
            max_retries: 2,
            backoff_base: 30.0,
            backoff_cap: 240.0,
        },
    }
}

fn cfg(sched: SchedMode, faulty: bool, keep_records: bool) -> DesConfig {
    DesConfig {
        rms: RmsConfig { nodes: NODES, keep_records, ..Default::default() },
        mode: sched,
        resilience: if faulty { faulty_resilience() } else { ResilienceConfig::default() },
        ..Default::default()
    }
}

/// A run reduced to its observable identity.
fn identity(r: &RunResult) -> (u64, u64, u64, usize) {
    (r.events, r.rms.log.digest(), r.makespan.to_bits(), r.user_jobs)
}

fn batch_run(w: &WorkloadSpec, sched: SchedMode, flexible: bool, faulty: bool) -> RunResult {
    let w = if flexible { w.clone() } else { w.as_fixed() };
    Engine::new(cfg(sched, faulty, true)).run(&w, "batch")
}

fn streamed_run(
    inner: Box<dyn JobStream>,
    sched: SchedMode,
    flexible: bool,
    faulty: bool,
    window: usize,
    keep_records: bool,
) -> RunResult {
    let mut stream = Adapted::new(inner).fixed(!flexible);
    Engine::new(cfg(sched, faulty, keep_records))
        .run_stream(&mut stream, window, "streamed")
        .expect("stream sources are well-formed")
}

/// Tentpole lock: every source × every mode, streamed ≡ materialized.
#[test]
fn every_source_and_mode_is_bit_identical() {
    for src in ["feitelson", "burst-lull", "swf"] {
        for (label, sched, flexible) in modes() {
            let (w, _) = source(src, 11);
            let batch = batch_run(&w, sched, flexible, false);
            let (_, stream) = source(src, 11);
            let streamed = streamed_run(stream, sched, flexible, false, 64, true);
            assert_eq!(
                identity(&batch),
                identity(&streamed),
                "{src}/{label}: streamed replay diverged from the batch path"
            );
            assert!(streamed.peak_slab > 0 && streamed.peak_slab <= NODES);
        }
    }
}

/// The same lock under the full fault stack: machine failures, drain
/// windows, checkpoint recovery, and transactional resize faults all
/// draw from seeded RNG streams that must not observe arrival laziness.
#[test]
fn fault_injection_is_stream_invariant() {
    for src in ["feitelson", "swf"] {
        for (label, sched, flexible) in modes() {
            let (w, _) = source(src, 11);
            let batch = batch_run(&w, sched, flexible, true);
            let (_, stream) = source(src, 11);
            let streamed = streamed_run(stream, sched, flexible, true, 64, true);
            assert_eq!(
                identity(&batch),
                identity(&streamed),
                "{src}/{label}: fault replay diverged under streaming"
            );
            assert_eq!(
                batch.resilience.node_failures, streamed.resilience.node_failures,
                "{src}/{label}: failure counts diverged"
            );
        }
    }
}

/// The look-ahead window must be unobservable: 1 (minimum legal), small,
/// default, and unbounded all produce the same run.
#[test]
fn lookahead_window_is_unobservable() {
    for src in ["feitelson", "burst-lull", "swf"] {
        let (w, _) = source(src, 23);
        let batch = batch_run(&w, SchedMode::Sync, true, false);
        for window in [1, 7, 64, usize::MAX] {
            let (_, stream) = source(src, 23);
            let streamed = streamed_run(stream, SchedMode::Sync, true, false, window, true);
            assert_eq!(
                identity(&batch),
                identity(&streamed),
                "{src}: window {window} changed the run"
            );
        }
        // window 0 is clamped to 1, not an error
        let (_, stream) = source(src, 23);
        let streamed = streamed_run(stream, SchedMode::Sync, true, false, 0, true);
        assert_eq!(identity(&batch), identity(&streamed), "{src}: window 0 must clamp to 1");
    }
}

/// The `Materialized` adapter is the compatibility path `Engine::run`
/// itself rides through — pin the explicit form too.
#[test]
fn materialized_adapter_matches_batch_entry_point() {
    for (label, sched, flexible) in modes() {
        let (w, _) = source("feitelson", 29);
        let w = if flexible { w } else { w.as_fixed() };
        let batch = Engine::new(cfg(sched, false, true)).run(&w, "batch");
        let mut stream = Materialized::from(&w);
        let streamed = Engine::new(cfg(sched, false, true))
            .run_stream(&mut stream, usize::MAX, "materialized")
            .unwrap();
        assert_eq!(identity(&batch), identity(&streamed), "{label}");
    }
}

/// Federated runs: lazy pull + meta-scheduler routing + stealing must be
/// bit-identical with the materialized federated path, per shard.
#[test]
fn federated_streaming_is_bit_identical() {
    let layouts = [
        (RoutingPolicy::LeastLoaded, StealPolicy::Head),
        (RoutingPolicy::RoundRobin, StealPolicy::Off),
        (RoutingPolicy::Locality, StealPolicy::Off),
    ];
    for (routing, steal) in layouts {
        for faulty in [false, true] {
            let fed = || FederationConfig {
                shards: vec![
                    ShardSpec { nodes: 40, ..Default::default() },
                    ShardSpec { nodes: 24, ..Default::default() },
                ],
                routing,
                steal,
                ..Default::default()
            };
            let (w, _) = source("feitelson", 31);
            let batch = FedEngine::new(cfg(SchedMode::Sync, faulty, true), fed())
                .run(&w, "fed-batch");
            let (_, inner) = source("feitelson", 31);
            let mut stream = Adapted::new(inner);
            let streamed = FedEngine::new(cfg(SchedMode::Sync, faulty, true), fed())
                .run_stream(&mut stream, 7, "fed-streamed")
                .unwrap();
            assert_eq!(batch.events, streamed.events, "{routing:?} faulty={faulty}");
            assert_eq!(
                batch.makespan.to_bits(),
                streamed.makespan.to_bits(),
                "{routing:?} faulty={faulty}"
            );
            assert_eq!(batch.shards.len(), streamed.shards.len());
            for (a, b) in batch.shards.iter().zip(&streamed.shards) {
                assert_eq!(
                    a.rms.log.digest(),
                    b.rms.log.digest(),
                    "{routing:?} faulty={faulty}: shard {} digest diverged",
                    a.shard
                );
            }
            assert!(streamed.peak_slab > 0 && streamed.peak_slab <= NODES);
        }
    }
}

/// Reclamation must be unobservable: with `keep_records = false` the
/// retained event vector and per-job records are gone, but the rolling
/// digest, counters and streamed metric folds are identical.
#[test]
fn record_reclamation_is_unobservable() {
    for (label, sched, flexible) in modes() {
        let (_, s1) = source("feitelson", 37);
        let keep = streamed_run(s1, sched, flexible, false, 64, true);
        let (_, s2) = source("feitelson", 37);
        let drop = streamed_run(s2, sched, flexible, false, 64, false);
        assert_eq!(identity(&keep), identity(&drop), "{label}");
        assert!(!keep.rms.log.all().is_empty(), "{label}: retaining run keeps events");
        assert!(drop.rms.log.all().is_empty(), "{label}: reclaiming run retains nothing");
        assert_eq!(
            keep.rms.log.total_pushed(),
            drop.rms.log.total_pushed(),
            "{label}: pushed-event counters"
        );

        // Summaries agree on everything the fold computes; only the
        // per-job record vector differs.
        let sk = RunSummary::from_run(keep);
        let sd = RunSummary::from_run(drop);
        assert_eq!(sk.makespan.to_bits(), sd.makespan.to_bits(), "{label}");
        assert_eq!(sk.util_mean.to_bits(), sd.util_mean.to_bits(), "{label}");
        assert_eq!(sk.wait.mean().to_bits(), sd.wait.mean().to_bits(), "{label}");
        assert_eq!(sk.exec.mean().to_bits(), sd.exec.mean().to_bits(), "{label}");
        assert_eq!(sk.completion.mean().to_bits(), sd.completion.mean().to_bits(), "{label}");
        assert_eq!(sk.node_seconds().to_bits(), sd.node_seconds().to_bits(), "{label}");
        assert_eq!(sk.peak_live, sd.peak_live, "{label}");
        assert_eq!(sk.jobs.len(), 40, "{label}");
        assert!(sd.jobs.is_empty(), "{label}");
    }
}

/// Memory-bound property at scale: a 50k-job replay with reclamation on
/// keeps the live slab bounded by cluster capacity — three orders of
/// magnitude below the job count — and still drains deterministically.
#[test]
fn fifty_thousand_job_replay_stays_bounded() {
    // 4096 nodes keeps the default Feitelson arrival process
    // under-saturated (steady-state demand ~2.6k node-seconds/second), so
    // the queue stays shallow and the replay is fast even unoptimized —
    // the same sizing the stream_scale bench uses at 1M jobs.
    let nodes = 4096;
    let p = FeitelsonParams { jobs: 50_000, ..Default::default() };
    let mut stream = Adapted::new(FeitelsonStream::new(p, 42)).fit(nodes).fixed(true);
    let cfg = DesConfig {
        rms: RmsConfig { nodes, keep_records: false, ..Default::default() },
        mode: SchedMode::Sync,
        ..Default::default()
    };
    let r = Engine::new(cfg).run_stream(&mut stream, 64, "50k").unwrap();
    assert_eq!(r.user_jobs, 50_000, "stream must drain fully");
    assert!(r.peak_slab > 0, "peak never recorded");
    assert!(
        r.peak_slab <= nodes,
        "peak-resident jobs {} exceeds the {nodes}-node capacity bound",
        r.peak_slab
    );
    assert!(r.rms.log.all().is_empty(), "no events retained at scale");
    assert!(!r.rms.log.retains(), "retention off for the bounded-memory profile");
    assert!(r.rms.log.total_pushed() > 100_000, "events were still pushed and digested");
    // Repeat run: bit-identical (reclamation cannot introduce
    // nondeterminism at scale).
    let p2 = FeitelsonParams { jobs: 50_000, ..Default::default() };
    let mut stream2 = Adapted::new(FeitelsonStream::new(p2, 42)).fit(nodes).fixed(true);
    let cfg2 = DesConfig {
        rms: RmsConfig { nodes, keep_records: false, ..Default::default() },
        mode: SchedMode::Sync,
        ..Default::default()
    };
    let r2 = Engine::new(cfg2).run_stream(&mut stream2, 64, "50k").unwrap();
    assert_eq!(identity(&r), identity(&r2), "50k replay must be deterministic");
    assert_eq!(r.peak_slab, r2.peak_slab);
}

/// Submit-order is a hard precondition of the streaming contract: a
/// disordered source must fail loudly (deterministic panic), never
/// silently reorder.
#[test]
#[should_panic(expected = "submit-ordered")]
fn disordered_stream_panics_deterministically() {
    struct Disordered(usize);
    impl JobStream for Disordered {
        fn next_job(&mut self) -> anyhow::Result<Option<dmr::workload::JobSpec>> {
            let w = workload::generate(3, 1);
            // emit jobs in reverse submit order
            let j = w.jobs.get(2usize.wrapping_sub(self.0)).cloned();
            self.0 += 1;
            Ok(j)
        }
    }
    let cfg = DesConfig {
        rms: RmsConfig { nodes: NODES, ..Default::default() },
        ..Default::default()
    };
    let _ = Engine::new(cfg).run_stream(&mut Disordered(0), 64, "disordered");
}

/// SWF stream errors surface as `Err`, not panics, and carry the line
/// context (satellite of the reader-robustness suite; the shared
/// batch-vs-stream assertion set lives in `workload::stream` unit tests).
#[test]
fn swf_stream_errors_propagate_through_the_engine() {
    let dir = std::env::temp_dir().join(format!("dmr_stream_bad_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.swf");
    std::fs::write(
        &path,
        "1 50 5 100 16 -1 -1 16 120 -1 1 1 1 1 1 -1 -1 -1\n\
         2 20 2 200 8 -1 -1 8 240 -1 1 2 1 1 1 -1 -1 -1\n",
    )
    .unwrap();
    let mut stream = Adapted::new(
        SwfStream::open(path.to_str().unwrap(), swf::SwfOptions::default(), 1).unwrap(),
    );
    let err = Engine::new(DesConfig {
        rms: RmsConfig { nodes: NODES, ..Default::default() },
        ..Default::default()
    })
    .run_stream(&mut stream, 64, "bad-swf")
    .expect_err("out-of-order trace must error");
    let msg = format!("{err}");
    assert!(msg.contains("out-of-order submit"), "unexpected error: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}
