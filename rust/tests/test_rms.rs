//! RMS integration scenarios: the scheduling/reconfiguration protocols
//! across whole lifecycles.

use dmr::apps::config::AppKind;
use dmr::rms::{DmrOutcome, DmrRequest, JobState, Rms, RmsConfig, RmsEvent};
use dmr::workload::JobSpec;

fn spec(app: AppKind, name: &str, t: f64) -> JobSpec {
    JobSpec::from_app(app, name.into(), t, 1.0)
}

fn custom(name: &str, t: f64, procs: usize, min: usize, max: usize, pref: Option<usize>) -> JobSpec {
    let mut s = spec(AppKind::Cg, name, t);
    s.procs = procs;
    s.min_procs = min;
    s.max_procs = max;
    s.pref_procs = pref;
    s
}

#[test]
fn fifo_when_no_backfill_possible() {
    let mut rms = Rms::new(RmsConfig { nodes: 64, ..Default::default() });
    let a = rms.submit(custom("a", 0.0, 64, 2, 64, None), 0.0);
    let b = rms.submit(custom("b", 1.0, 64, 2, 64, None), 1.0);
    let c = rms.submit(custom("c", 2.0, 64, 2, 64, None), 2.0);
    rms.schedule(2.0);
    assert_eq!(rms.job(a).unwrap().state, JobState::Running);
    assert_eq!(rms.job(b).unwrap().state, JobState::Pending);
    rms.finish(a, 10.0);
    rms.schedule(10.0);
    assert_eq!(rms.job(b).unwrap().state, JobState::Running);
    assert_eq!(rms.job(c).unwrap().state, JobState::Pending);
}

#[test]
fn backfill_lets_short_small_job_jump() {
    let mut rms = Rms::new(RmsConfig { nodes: 64, ..Default::default() });
    // Long 48-node job running until ~t=1000 (est from spec).
    let mut big = custom("big", 0.0, 48, 48, 48, None);
    big.iterations = 10_000;
    let a = rms.submit(big, 0.0);
    rms.schedule(0.0);
    rms.set_expected_end(a, 1000.0);
    // Head blocker wants 64; a small short job can use the 16 idle nodes.
    let blocker = custom("blocker", 1.0, 64, 64, 64, None);
    let mut small = custom("small", 2.0, 16, 16, 16, None);
    small.iterations = 10; // short
    let b = rms.submit(blocker, 1.0);
    let s = rms.submit(small, 2.0);
    rms.schedule(2.0);
    assert_eq!(rms.job(s).unwrap().state, JobState::Running, "small job backfills");
    assert_eq!(rms.job(b).unwrap().state, JobState::Pending);
    assert!(rms.check_invariants());
}

#[test]
fn expand_protocol_leaves_no_resizer_residue() {
    let mut rms = Rms::new(RmsConfig { nodes: 32, ..Default::default() });
    let a = rms.submit(custom("a", 0.0, 4, 2, 32, Some(4)), 0.0);
    rms.schedule(0.0);
    // queue empty -> expansion toward max
    let req = DmrRequest { min: 2, max: 32, pref: Some(4), factor: 2 };
    let out = rms.dmr_check(a, &req, 5.0);
    match out {
        DmrOutcome::Expand { to, new_nodes } => {
            assert_eq!(to, 32);
            assert_eq!(new_nodes.len(), 28);
        }
        o => panic!("expected expand, got {o:?}"),
    }
    rms.commit_resize(a, 6.0);
    // the resizer job must be cancelled and hold nothing
    let resizers: Vec<_> = rms.jobs().filter(|j| j.is_resizer).collect();
    assert_eq!(resizers.len(), 1);
    assert_eq!(resizers[0].state, JobState::Cancelled);
    assert!(resizers[0].nodes.is_empty());
    assert_eq!(rms.cluster.available(), 0);
    assert!(rms.check_invariants());
    // events recorded
    assert_eq!(rms.log.expansions(), 1);
    assert!(rms
        .log
        .all()
        .iter()
        .any(|e| matches!(e, RmsEvent::Expanded { from: 4, to: 32, .. })));
}

#[test]
fn shrink_starts_boosted_waiter() {
    let mut rms = Rms::new(RmsConfig { nodes: 32, ..Default::default() });
    let a = rms.submit(custom("a", 0.0, 32, 2, 32, Some(8)), 0.0);
    rms.schedule(0.0);
    let w = rms.submit(custom("w", 1.0, 16, 16, 16, None), 1.0);
    rms.schedule(1.0);
    assert_eq!(rms.job(w).unwrap().state, JobState::Pending);

    let req = DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 };
    let out = rms.dmr_check(a, &req, 20.0);
    let to = match out {
        DmrOutcome::Shrink { to, release_nodes } => {
            assert_eq!(release_nodes.len(), 24);
            to
        }
        o => panic!("expected shrink, got {o:?}"),
    };
    // waiter got the boost before the release
    assert!(rms.job(w).unwrap().qos_boost);
    rms.commit_shrink_to(a, to, 21.0);
    let started = rms.schedule(21.0);
    assert!(started.iter().any(|s| s.job == w), "boosted waiter starts");
    assert!(rms.check_invariants());
}

#[test]
fn resizer_dependency_blocks_start_without_original() {
    let mut rms = Rms::new(RmsConfig { nodes: 32, ..Default::default() });
    let a = rms.submit(custom("a", 0.0, 8, 2, 32, None), 0.0);
    rms.schedule(0.0);
    // Fabricate a pending resizer-like situation by finishing the original
    // before its (hypothetical) resizer could run: dmr_apply on a finished
    // job is simply never called; instead verify schedule() skips resizers
    // whose dependency is inactive by inspecting a forced expand abort.
    rms.finish(a, 1.0);
    // expansion of a completed job is a programming error; the protocol
    // only ever runs against active jobs.  Here we just assert the system
    // stays consistent after the finish.
    assert!(rms.check_invariants());
    assert!(rms.all_done());
}

#[test]
fn sync_expand_aborts_cleanly_when_raced() {
    // Cluster with zero spare nodes: the policy may still decide to
    // expand (forced via dmr_apply), but the resizer job cannot start.
    let mut rms = Rms::new(RmsConfig { nodes: 16, ..Default::default() });
    let a = rms.submit(custom("a", 0.0, 16, 2, 32, None), 0.0);
    rms.schedule(0.0);
    let r = rms.dmr_apply(a, dmr::rms::Action::Expand { to: 32 }, 1.0);
    assert!(r.is_err(), "no resources -> protocol reports the wait");
    assert_eq!(rms.job(a).unwrap().state, JobState::Running);
    assert!(rms.check_invariants());
}

#[test]
fn cancel_pending_job_releases_nothing_and_removes_from_queue() {
    let mut rms = Rms::new(RmsConfig { nodes: 8, ..Default::default() });
    let a = rms.submit(custom("a", 0.0, 8, 8, 8, None), 0.0);
    rms.schedule(0.0);
    let b = rms.submit(custom("b", 1.0, 8, 8, 8, None), 1.0);
    rms.cancel(b, 2.0);
    assert_eq!(rms.job(b).unwrap().state, JobState::Cancelled);
    assert_eq!(rms.pending_user_jobs(), 0);
    rms.finish(a, 3.0);
    assert!(rms.all_done());
    assert!(rms.check_invariants());
}

#[test]
fn telemetry_series_monotone_time() {
    let mut rms = Rms::new(RmsConfig { nodes: 64, ..Default::default() });
    for i in 0..6 {
        rms.submit(spec(AppKind::Cg, &format!("j{i}"), i as f64), i as f64);
        rms.schedule(i as f64);
    }
    let times: Vec<f64> = rms.telemetry.alloc_series.iter().map(|(t, _)| *t).collect();
    assert!(times.windows(2).all(|w| w[1] >= w[0]));
    assert!(!times.is_empty());
}
