//! Property-based tests (homegrown driver over the crate's deterministic
//! PRNG — no proptest offline) on the coordinator's invariants: policy
//! decisions, cluster bookkeeping, and random RMS operation sequences.

use dmr::apps::config::AppKind;
use dmr::cluster::Cluster;
use dmr::rms::policy::{decide, Action, DmrRequest, PolicyConfig, SystemView};
use dmr::rms::{DmrOutcome, JobState, Rms, RmsConfig};
use dmr::util::rng::Rng;
use dmr::workload::JobSpec;

const CASES: usize = 500;

/// Property: every decision respects the request bounds, factor
/// reachability, and resource availability.
#[test]
fn prop_policy_decisions_respect_bounds() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..CASES {
        // random but consistent request/state
        let factor = *rng.choice(&[2usize, 2, 2, 4]);
        let min = rng.range(1, 4) as usize;
        let max = min * factor.pow(rng.range(0, 4) as u32);
        // current somewhere factor-reachable within [min, max]
        let mut current = min;
        while current * factor <= max && rng.f64() < 0.5 {
            current *= factor;
        }
        let pref = if rng.f64() < 0.7 {
            let mut p = min;
            while p * factor <= max && rng.f64() < 0.5 {
                p *= factor;
            }
            Some(p)
        } else {
            None
        };
        let req = DmrRequest { min, max, pref, factor };
        let view = SystemView {
            available: rng.range(0, 64) as usize,
            pending_jobs: rng.range(0, 5) as usize,
            head_need: if rng.f64() < 0.7 { Some(rng.range(1, 64) as usize) } else { None },
        };
        let view = SystemView {
            pending_jobs: if view.head_need.is_none() { 0 } else { view.pending_jobs.max(1) },
            ..view
        };
        let cfg = PolicyConfig::default();
        match decide(&cfg, current, &req, &view) {
            Action::NoAction => {}
            Action::Expand { to } => {
                assert!(to > current, "case {case}: expand must grow");
                assert!(to <= req.max.max(current), "case {case}: expand caps at max");
                assert!(
                    to - current <= view.available,
                    "case {case}: expand within available ({to} from {current}, avail {})",
                    view.available
                );
            }
            Action::Shrink { to } => {
                assert!(to < current, "case {case}: shrink must reduce");
                assert!(to >= req.min.min(current), "case {case}: shrink floors at min");
            }
        }
    }
}

/// Property: random alloc/release/transfer sequences never break the
/// cluster's free-list bookkeeping.
#[test]
fn prop_cluster_bookkeeping() {
    let mut rng = Rng::new(77);
    for _ in 0..200 {
        let n = rng.range(4, 64) as usize;
        let mut c = Cluster::new(n);
        let mut held: Vec<(u64, Vec<usize>)> = Vec::new();
        let mut next_job = 1u64;
        for _ in 0..100 {
            match rng.range(0, 2) {
                0 => {
                    let want = rng.range(1, 8) as usize;
                    if let Ok(nodes) = c.alloc(next_job, want) {
                        held.push((next_job, nodes));
                        next_job += 1;
                    }
                }
                1 if !held.is_empty() => {
                    let i = rng.below(held.len() as u64) as usize;
                    let (job, nodes) = held.swap_remove(i);
                    c.release(job, &nodes).unwrap();
                }
                _ if !held.is_empty() => {
                    let i = rng.below(held.len() as u64) as usize;
                    let (job, nodes) = held[i].clone();
                    let to = next_job;
                    next_job += 1;
                    c.transfer(job, to, &nodes).unwrap();
                    held[i] = (to, nodes);
                }
                _ => {}
            }
            assert!(c.check_invariants());
            let held_count: usize = held.iter().map(|(_, v)| v.len()).sum();
            assert_eq!(c.available() + held_count, n);
        }
    }
}

/// Property: random RMS operation sequences (submit / schedule / dmr /
/// commit / finish) preserve the allocation invariants and never lose a
/// node.
#[test]
fn prop_rms_random_walk_keeps_invariants() {
    let mut rng = Rng::new(0xDA7A);
    for walk in 0..30 {
        let nodes = *rng.choice(&[16usize, 32, 64]);
        let mut rms = Rms::new(RmsConfig { nodes, ..Default::default() });
        let mut now = 0.0f64;
        let mut live: Vec<u64> = Vec::new();
        let mut resizing: Vec<(u64, usize)> = Vec::new();
        let mut submitted = 0usize;

        for step in 0..300 {
            now += rng.f64() * 5.0;
            match rng.range(0, 4) {
                0 if submitted < 40 => {
                    let app = *rng.choice(&AppKind::WORKLOAD_APPS.as_slice());
                    let mut spec =
                        JobSpec::from_app(app, format!("w{walk}-j{submitted}"), now, 1.0);
                    // keep sizes modest so things actually run
                    spec.procs = spec.procs.min(nodes);
                    spec.max_procs = spec.max_procs.min(nodes);
                    rms.submit(spec, now);
                    submitted += 1;
                }
                1 => {
                    rms.schedule(now);
                    for s in rms.take_recent_starts() {
                        if !rms.job(s.job).map(|j| j.is_resizer).unwrap_or(true) {
                            live.push(s.job);
                        }
                    }
                }
                2 if !live.is_empty() => {
                    let i = rng.below(live.len() as u64) as usize;
                    let id = live[i];
                    let j = rms.job(id).unwrap();
                    if j.state != JobState::Running {
                        continue;
                    }
                    let req = DmrRequest {
                        min: j.spec.min_procs,
                        max: j.spec.max_procs,
                        pref: j.spec.pref_procs,
                        factor: 2,
                    };
                    match rms.dmr_check(id, &req, now) {
                        DmrOutcome::Shrink { to, .. } => resizing.push((id, to)),
                        DmrOutcome::Expand { .. } => resizing.push((id, 0)),
                        DmrOutcome::NoAction => {}
                    }
                    for s in rms.take_recent_starts() {
                        if !rms.job(s.job).map(|j| j.is_resizer).unwrap_or(true) {
                            live.push(s.job);
                        }
                    }
                }
                3 if !resizing.is_empty() => {
                    let (id, to) = resizing.swap_remove(0);
                    if to == 0 {
                        rms.commit_resize(id, now);
                    } else {
                        rms.commit_shrink_to(id, to, now);
                    }
                    rms.schedule(now);
                    for s in rms.take_recent_starts() {
                        if !rms.job(s.job).map(|j| j.is_resizer).unwrap_or(true) {
                            live.push(s.job);
                        }
                    }
                }
                _ if !live.is_empty() => {
                    // finish a random running (not resizing) job
                    let i = rng.below(live.len() as u64) as usize;
                    let id = live[i];
                    if rms.job(id).unwrap().state == JobState::Running
                        && !resizing.iter().any(|(r, _)| *r == id)
                    {
                        rms.finish(id, now);
                        live.swap_remove(i);
                        rms.schedule(now);
                        for s in rms.take_recent_starts() {
                            if !rms.job(s.job).map(|j| j.is_resizer).unwrap_or(true) {
                                live.push(s.job);
                            }
                        }
                    }
                }
                _ => {}
            }
            assert!(rms.check_invariants(), "walk {walk} step {step}: invariants broken");
            assert!(
                rms.cluster.available() <= nodes,
                "walk {walk} step {step}: free nodes exceed cluster"
            );
        }
    }
}

/// Property: backfill never oversubscribes — at any instant, allocated
/// nodes <= cluster size (checked across random schedules).
#[test]
fn prop_schedule_never_oversubscribes() {
    let mut rng = Rng::new(0x5EED);
    for _ in 0..50 {
        let nodes = rng.range(8, 96) as usize;
        let mut rms = Rms::new(RmsConfig { nodes, ..Default::default() });
        let mut now = 0.0;
        for i in 0..30 {
            now += rng.f64();
            let app = *rng.choice(&AppKind::WORKLOAD_APPS.as_slice());
            let mut spec = JobSpec::from_app(app, format!("j{i}"), now, 1.0);
            spec.procs = (rng.range(1, 64) as usize).min(nodes);
            spec.min_procs = spec.procs.min(spec.min_procs);
            spec.max_procs = spec.max_procs.max(spec.procs).min(nodes);
            rms.submit(spec, now);
            rms.schedule(now);
            rms.take_recent_starts();
            assert!(rms.cluster.allocated() <= nodes);
            assert!(rms.check_invariants());
        }
    }
}
