//! SWF `user` field (field 12) coverage: malformed and missing user ids,
//! traces with more users than the synthetic generator's four, and the
//! FairShare strategy's determinism on a user-bearing SWF workload with
//! soft deadlines (`deadline_slack`).
//!
//! The traces are inline strings fed through [`dmr::workload::swf::parse`]
//! — no fixture files on disk.

use dmr::des::{DesConfig, Engine};
use dmr::metrics::RunSummary;
use dmr::rms::{PolicyStrategy, RmsConfig};
use dmr::workload::swf::{self, SwfOptions};
use dmr::workload::WorkloadSpec;

/// Eight completed jobs from six distinct users (field 12 = 10, 20, 30,
/// 40, 50, 60), plus the user-field edge cases:
/// * job 7: user id `-1` (explicitly unknown),
/// * job 8: non-numeric user id (`xx`),
/// * job 9: only 11 fields — the user column is absent entirely.
const TRACE: &str = "\
; inline user-bearing trace
1 0 1 100 16 -1 -1 16 120 -1 1 10 1 1 1 -1 -1 -1
2 10 1 200 8 -1 -1 8 240 -1 1 20 1 1 1 -1 -1 -1
3 20 1 150 8 -1 -1 8 160 -1 1 30 1 1 1 -1 -1 -1
4 30 1 120 16 -1 -1 16 130 -1 1 40 1 2 1 -1 -1 -1
5 40 1 180 4 -1 -1 4 190 -1 1 50 1 2 1 -1 -1 -1
6 50 1 160 8 -1 -1 8 170 -1 1 60 1 3 1 -1 -1 -1
7 60 1 140 8 -1 -1 8 150 -1 1 -1 1 3 1 -1 -1 -1
8 70 1 130 4 -1 -1 4 140 -1 1 xx 1 3 1 -1 -1 -1
9 80 1 110 4 -1 -1 4 120 -1 1
";

fn workload(slack: Option<f64>) -> WorkloadSpec {
    let trace = swf::parse(TRACE);
    let opts = SwfOptions {
        rescale_nodes: Some(32),
        malleable_fraction: 0.5,
        time_scale: 0.05,
        ..Default::default()
    };
    let w = swf::to_workload(&trace, &opts, 3);
    match slack {
        Some(s) => w.with_deadlines(s),
        None => w,
    }
}

#[test]
fn user_ids_parse_with_unknowns_mapped_to_zero() {
    let trace = swf::parse(TRACE);
    assert_eq!(trace.stats.malformed, 0, "all lines have >= 9 fields");
    assert_eq!(trace.records.len(), 9);
    let user_of = |id: u64| trace.records.iter().find(|r| r.job_id == id).unwrap().user;
    assert_eq!(user_of(1), 10);
    assert_eq!(user_of(6), 60);
    assert_eq!(user_of(7), -1, "explicit -1 stays unknown");
    assert_eq!(user_of(8), -1, "garbage user id maps to unknown");
    assert_eq!(user_of(9), -1, "absent user column maps to unknown");

    // materialization folds every unknown onto user 0
    let w = workload(None);
    assert_eq!(w.jobs.len(), 9);
    let unknown = w
        .jobs
        .iter()
        .filter(|j| j.user == 0)
        .map(|j| j.name.clone())
        .collect::<Vec<_>>();
    assert_eq!(unknown, vec!["swf-00007", "swf-00008", "swf-00009"]);
}

#[test]
fn more_than_four_distinct_users_survive_materialization() {
    // The synthetic generator deals users 0..4; real traces carry many
    // more, and the per-user fairness path must not clamp them.
    let w = workload(None);
    let mut users: Vec<u32> = w.jobs.iter().map(|j| j.user).collect();
    users.sort_unstable();
    users.dedup();
    assert_eq!(users, vec![0, 10, 20, 30, 40, 50, 60], "7 distinct users");

    let cfg = DesConfig {
        rms: RmsConfig { nodes: 32, strategy: PolicyStrategy::FairShare, ..Default::default() },
        ..Default::default()
    };
    let r = Engine::new(cfg).run(&w, "users");
    assert_eq!(r.rms.completed_jobs(), 9);
    let s = RunSummary::from_run(r);
    let mut seen: Vec<u32> = s.jobs.iter().map(|j| j.user).collect();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), 7, "all users reach the job records");
    assert!(
        s.fairness_jain > 0.0 && s.fairness_jain <= 1.0 + 1e-12,
        "jain over 7 users: {}",
        s.fairness_jain
    );
}

#[test]
fn fair_share_is_deterministic_on_user_bearing_swf_with_deadlines() {
    let run = |strategy: PolicyStrategy| {
        let w = workload(Some(2.0));
        assert_eq!(w.jobs.len(), 9);
        assert!(w.jobs.iter().all(|j| j.deadline.is_some()), "slack decorates every job");
        let cfg = DesConfig {
            rms: RmsConfig { nodes: 32, strategy, ..Default::default() },
            ..Default::default()
        };
        let r = Engine::new(cfg).run(&w, strategy.label());
        assert_eq!(r.rms.completed_jobs(), 9, "{}: workload drains", strategy.label());
        (r.events, r.rms.log.digest(), r.makespan.to_bits())
    };
    for strategy in [PolicyStrategy::FairShare, PolicyStrategy::DeadlineAware] {
        let a = run(strategy);
        let b = run(strategy);
        assert_eq!(a, b, "{}: same trace + seed must replay bit-identically", strategy.label());
    }
    // the deadline decoration is visible in the summary
    let w = workload(Some(2.0));
    let cfg = DesConfig {
        rms: RmsConfig { nodes: 32, strategy: PolicyStrategy::FairShare, ..Default::default() },
        ..Default::default()
    };
    let s = RunSummary::from_run(Engine::new(cfg).run(&w, "deadlines"));
    assert_eq!(s.deadline_jobs, 9);
    assert!(s.deadline_misses <= s.deadline_jobs);
}
