//! End-to-end live-mode integration: real rank threads, real PJRT compute,
//! real redistribution, driven by the real RMS policy.  Requires
//! `make artifacts`.

use std::sync::mpsc;

use dmr::apps::config::AppKind;
use dmr::live::{LiveDriver, LiveOpts, SchedMode};
use dmr::rms::{PolicyConfig, PriorityWeights, RmsConfig};
use dmr::runtime::{ArtifactStore, ComputeServer};
use dmr::workload::JobSpec;

fn compute() -> Option<ComputeServer> {
    let store = ArtifactStore::open("artifacts").ok()?;
    ComputeServer::start(store).ok()
}

/// f64 reference CG on tridiag(-1,2,-1) x = b with b[i] = sin(0.01 i).
fn cg_ref(n: usize, iters: u32) -> Vec<f64> {
    let b: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.01).sin()).collect();
    let matvec = |v: &[f64]| -> Vec<f64> {
        (0..n)
            .map(|i| {
                let l = if i > 0 { v[i - 1] } else { 0.0 };
                let r = if i + 1 < n { v[i + 1] } else { 0.0 };
                2.0 * v[i] - l - r
            })
            .collect()
    };
    let mut x = vec![0.0; n];
    let mut r = b.clone();
    let mut p = b;
    let mut rr: f64 = r.iter().map(|v| v * v).sum();
    for _ in 0..iters {
        let q = matvec(&p);
        let pq: f64 = p.iter().zip(&q).map(|(a, b)| a * b).sum();
        let alpha = rr / pq;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * q[i];
        }
        let rr2: f64 = r.iter().map(|v| v * v).sum();
        let beta = rr2 / rr;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rr = rr2;
    }
    x
}

fn cg_spec(iters: u32, procs: usize, min: usize, max: usize, pref: Option<usize>) -> JobSpec {
    let mut s = JobSpec::from_app(AppKind::Cg, format!("CG-live-{procs}"), 0.0, 1.0);
    s.iterations = iters;
    s.procs = procs;
    s.min_procs = min;
    s.max_procs = max;
    s.pref_procs = pref;
    s.sched_period = 0.0; // check every iteration in the tests
    s
}

fn rel_err(got: &[f32], want: &[f64]) -> f64 {
    let num: f64 = got
        .iter()
        .zip(want)
        .map(|(g, w)| (*g as f64 - w) * (*g as f64 - w))
        .sum::<f64>()
        .sqrt();
    let den: f64 = want.iter().map(|w| w * w).sum::<f64>().sqrt();
    num / den.max(1e-12)
}

#[test]
fn live_cg_fixed_matches_reference() {
    let Some(server) = compute() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (tx, rx) = mpsc::channel();
    let opts = LiveOpts {
        rms: RmsConfig { nodes: 4, ..Default::default() },
        probe: Some(tx),
        ..Default::default()
    };
    let mut driver = LiveDriver::new(opts, server.handle());
    let iters = 12;
    let mut spec = cg_spec(iters, 4, 4, 4, None);
    spec.malleable = false;
    let report = driver.run(vec![spec]);
    assert_eq!(report.jobs, 1);
    let (_id, sol) = rx.recv().unwrap();
    assert_eq!(sol.len(), 16384);
    let want = cg_ref(16384, iters);
    let e = rel_err(&sol, &want);
    assert!(e < 1e-3, "rel err {e}");
}

#[test]
fn live_cg_shrinks_when_queue_pressure_and_stays_correct() {
    let Some(server) = compute() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (tx, rx) = mpsc::channel();
    let opts = LiveOpts {
        rms: RmsConfig {
            nodes: 4,
            weights: PriorityWeights::default(),
            policy: PolicyConfig::default(),
            ..Default::default()
        },
        probe: Some(tx),
        ..Default::default()
    };
    let mut driver = LiveDriver::new(opts, server.handle());

    let iters = 16;
    // Job A: CG at 4 procs, prefers 2 => will shrink once B queues.
    let a = cg_spec(iters, 4, 2, 4, Some(2));
    // Job B: a tiny FS job needing 2 nodes, arrives shortly after.
    let mut b = JobSpec::from_app(AppKind::FlexibleSleep, "FS-live".into(), 0.05, 0.001);
    b.iterations = 2;
    b.procs = 2;
    b.min_procs = 2;
    b.max_procs = 2;
    b.malleable = false;

    let report = driver.run(vec![a, b]);
    assert_eq!(report.jobs, 2);

    // Collect both probes; find the CG one (16384 elements).
    let mut sols = vec![rx.recv().unwrap(), rx.recv().unwrap()];
    sols.sort_by_key(|(_, s)| s.len());
    let (_, sol) = sols.pop().unwrap();
    assert_eq!(sol.len(), 16384);
    let want = cg_ref(16384, iters);
    let e = rel_err(&sol, &want);
    assert!(e < 1e-3, "rel err after shrink {e}");

    // The shrink actually happened.
    let rms = report.rms.lock().unwrap();
    assert!(rms.log.shrinks() >= 1, "expected at least one shrink");
    let cg_job = rms
        .jobs()
        .find(|j| j.spec.app == AppKind::Cg && !j.is_resizer)
        .unwrap();
    // nodes are released on completion; the resize log records the shrink
    assert!(cg_job.resize_log.iter().any(|r| r.to_procs == 2));
    assert!(rms.check_invariants());
}

#[test]
fn live_cg_expands_on_empty_queue_and_stays_correct() {
    let Some(server) = compute() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (tx, rx) = mpsc::channel();
    let opts = LiveOpts {
        rms: RmsConfig { nodes: 8, ..Default::default() },
        probe: Some(tx),
        ..Default::default()
    };
    let mut driver = LiveDriver::new(opts, server.handle());

    let iters = 16;
    // Starts at 2; empty queue + pref given => §4.2 expands toward max 8.
    let a = cg_spec(iters, 2, 2, 8, Some(2));
    let report = driver.run(vec![a]);
    let (_, sol) = rx.recv().unwrap();
    let want = cg_ref(16384, iters);
    let e = rel_err(&sol, &want);
    assert!(e < 1e-3, "rel err after expand {e}");

    let rms = report.rms.lock().unwrap();
    assert!(rms.log.expansions() >= 1, "expected an expansion");
    let cg_job = rms
        .jobs()
        .find(|j| j.spec.app == AppKind::Cg && !j.is_resizer)
        .unwrap();
    assert_eq!(cg_job.resize_log.last().unwrap().to_procs, 8);
    assert!(rms.check_invariants());
}

#[test]
fn live_nbody_and_jacobi_complete() {
    let Some(server) = compute() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let opts = LiveOpts {
        rms: RmsConfig { nodes: 8, ..Default::default() },
        ..Default::default()
    };
    let mut driver = LiveDriver::new(opts, server.handle());

    let mut j = JobSpec::from_app(AppKind::Jacobi, "J-live".into(), 0.0, 1.0);
    j.iterations = 6;
    j.procs = 4;
    j.min_procs = 4;
    j.max_procs = 4;
    j.pref_procs = None;
    j.malleable = false;

    let mut n = JobSpec::from_app(AppKind::NBody, "NB-live".into(), 0.0, 1.0);
    n.iterations = 4;
    n.procs = 4;
    n.min_procs = 4;
    n.max_procs = 4;
    n.pref_procs = None;
    n.malleable = false;

    let report = driver.run(vec![j, n]);
    assert_eq!(report.jobs, 2);
    let rms = report.rms.lock().unwrap();
    assert_eq!(rms.completed_jobs(), 2);
    assert!(rms.check_invariants());
}

#[test]
fn live_async_mode_runs() {
    let Some(server) = compute() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let opts = LiveOpts {
        rms: RmsConfig { nodes: 8, ..Default::default() },
        mode: SchedMode::Async,
        ..Default::default()
    };
    let mut driver = LiveDriver::new(opts, server.handle());
    // Async: expansion decided one point ahead, applied on the next.
    let a = cg_spec(12, 2, 2, 8, Some(2));
    let report = driver.run(vec![a]);
    let rms = report.rms.lock().unwrap();
    assert_eq!(rms.completed_jobs(), 1);
    assert!(rms.log.expansions() >= 1);
    assert!(rms.check_invariants());
}

/// f64 reference Jacobi sweep over the global grid (b(i,j) matching
/// apps::jacobi::b_at).
fn jacobi_ref(rows: usize, cols: usize, iters: u32) -> Vec<f64> {
    let b = |r: usize, c: usize| -> f64 {
        (((r as f32) * 0.05).sin() * ((c as f32) * 0.05).cos()) as f64
    };
    let mut u = vec![0.0f64; rows * cols];
    for _ in 0..iters {
        let mut v = vec![0.0f64; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                let n = if r > 0 { u[(r - 1) * cols + c] } else { 0.0 };
                let s = if r + 1 < rows { u[(r + 1) * cols + c] } else { 0.0 };
                let w = if c > 0 { u[r * cols + c - 1] } else { 0.0 };
                let e = if c + 1 < cols { u[r * cols + c + 1] } else { 0.0 };
                v[r * cols + c] = 0.25 * (n + s + w + e - b(r, c));
            }
        }
        u = v;
    }
    u
}

#[test]
fn live_jacobi_shrinks_and_matches_reference() {
    let Some(server) = compute() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (tx, rx) = mpsc::channel();
    let opts = LiveOpts {
        rms: RmsConfig { nodes: 4, ..Default::default() },
        probe: Some(tx),
        ..Default::default()
    };
    let mut driver = LiveDriver::new(opts, server.handle());

    let iters = 10;
    let mut j = JobSpec::from_app(AppKind::Jacobi, "J-live".into(), 0.0, 1.0);
    j.iterations = iters;
    j.procs = 4;
    j.min_procs = 2;
    j.max_procs = 4;
    j.pref_procs = Some(2);
    j.sched_period = 0.0;

    let mut fs = JobSpec::from_app(AppKind::FlexibleSleep, "FS-q".into(), 0.05, 0.001);
    fs.iterations = 2;
    fs.procs = 2;
    fs.min_procs = 2;
    fs.max_procs = 2;
    fs.malleable = false;

    let report = driver.run(vec![j, fs]);
    let rms = report.rms.lock().unwrap();
    assert!(rms.log.shrinks() >= 1);
    drop(rms);

    let want = jacobi_ref(512, 256, iters);
    let mut checked = false;
    while let Ok((_, sol)) = rx.try_recv() {
        if sol.len() == 512 * 256 {
            let num: f64 = sol
                .iter()
                .zip(&want)
                .map(|(g, w)| (*g as f64 - w) * (*g as f64 - w))
                .sum::<f64>()
                .sqrt();
            let den: f64 = want.iter().map(|w| w * w).sum::<f64>().sqrt().max(1e-12);
            let rel = num / den;
            assert!(rel < 1e-3, "jacobi rel err {rel}");
            checked = true;
        }
    }
    assert!(checked, "no Jacobi solution probe received");
}

/// Stress: several malleable jobs resizing concurrently on a small
/// cluster — exercises simultaneous spawn/redistribute/commit without
/// deadlocking and with the RMS staying consistent.
#[test]
fn live_concurrent_malleable_jobs_stress() {
    let Some(server) = compute() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let opts = LiveOpts {
        rms: RmsConfig { nodes: 12, ..Default::default() },
        ..Default::default()
    };
    let mut driver = LiveDriver::new(opts, server.handle());
    let mut specs = Vec::new();
    for i in 0..5 {
        let app = [AppKind::Cg, AppKind::Jacobi, AppKind::NBody][i % 3];
        let mut s = JobSpec::from_app(app, format!("stress-{i}"), i as f64 * 0.03, 1.0);
        s.iterations = if app == AppKind::NBody { 5 } else { 8 };
        s.procs = if i % 2 == 0 { 8 } else { 4 };
        s.min_procs = 2;
        s.max_procs = 8;
        s.pref_procs = Some(2);
        s.sched_period = 0.0;
        specs.push(s);
    }
    let report = driver.run(specs);
    let rms = report.rms.lock().unwrap();
    assert_eq!(rms.completed_jobs(), 5);
    assert!(rms.check_invariants());
    assert!(
        rms.log.shrinks() + rms.log.expansions() >= 2,
        "stress run should reconfigure (got {} + {})",
        rms.log.shrinks(),
        rms.log.expansions()
    );
    assert_eq!(rms.cluster.available(), 12, "all nodes returned");
}
