//! Offline stand-in for the `anyhow` crate, API-compatible with the subset
//! this workspace uses: [`Result`], [`Error`], the [`anyhow!`] / [`bail!`]
//! macros and the [`Context`] extension trait.
//!
//! The build environment is fully offline (no crates.io), so the real
//! crate cannot be fetched; errors here are a formatted message chain
//! rather than a captured backtrace + source chain, which is all the
//! reports and tests rely on.

use std::fmt;

/// A string-backed error value.  Like `anyhow::Error` it deliberately does
/// **not** implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the [`anyhow!`] entry point).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line, `context: cause` style.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a result defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error branch of a `Result` or to a `None`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/anyhow/shim")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {}", 1);
            }
            ensure!(!flag, "unreachable");
            Ok(9)
        }
        assert_eq!(f(false).unwrap(), 9);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged 1");
    }
}
