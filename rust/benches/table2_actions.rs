//! E3 — Table 2: analysis of the actions performed by the framework in a
//! 400-job workload, synchronous vs asynchronous scheduling (§7.3).

mod common;

use dmr::dmr::SchedMode;
use dmr::metrics::report;

fn main() {
    common::banner("table2_actions", "Table 2 (action analysis, 400-job workload)");
    let jobs = 400;
    let sync = common::run(jobs, common::SEED, SchedMode::Sync, true, "Synchronous");
    let asy = common::run(jobs, common::SEED, SchedMode::Async, true, "Asynchronous");
    println!("{}", report::table2(&sync.actions, &asy.actions, jobs).render());

    // Shape assertions vs the paper's Table 2:
    // "the synchronous version schedules fewer reconfigurations"
    let s_total = sync.actions.expand.count() + sync.actions.shrink.count();
    let a_total = asy.actions.expand.count() + asy.actions.shrink.count();
    assert!(
        s_total < a_total + asy.actions.expand_aborts,
        "sync schedules fewer actions ({s_total} vs {a_total})"
    );
    // "the negative effect of a timeout during an expansion": async expand
    // max far above sync's, with a large standard deviation.
    assert!(asy.actions.expand.max() > sync.actions.expand.max() * 5.0);
    assert!(asy.actions.expand.std() > sync.actions.expand.std() * 3.0);
    // no-action decisions are milliseconds in both modes
    assert!(sync.actions.no_action.mean() < 0.05);
    assert!(asy.actions.no_action.mean() < 0.05);
    println!(
        "async expand aborts (timeouts): {} of {} attempts",
        asy.actions.expand_aborts,
        asy.actions.expand.count()
    );
    println!("table2_actions OK (shapes match the paper)");
}
