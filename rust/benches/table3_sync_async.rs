//! E4 — Table 3: cluster and job measures of the 400-job workloads —
//! fixed vs synchronous vs asynchronous (§7.4, "dismissing the
//! asynchronous scheduling").

mod common;

use dmr::dmr::SchedMode;
use dmr::metrics::report;

fn main() {
    common::banner("table3_sync_async", "Table 3 (fixed vs sync vs async, 400 jobs)");
    let jobs = 400;
    let fixed = common::run(jobs, common::SEED, SchedMode::Sync, false, "Fixed");
    let sync = common::run(jobs, common::SEED, SchedMode::Sync, true, "Synchronous");
    let asy = common::run(jobs, common::SEED, SchedMode::Async, true, "Asynchronous");
    println!("{}", report::table3(&fixed, &sync, &asy).render());

    let (ws, es, cs) = sync.gains_vs(&fixed);
    let (wa, ea, ca) = asy.gains_vs(&fixed);
    // Paper shapes: malleability cuts waiting dramatically in both modes;
    // execution degrades (negative gain); completion still improves; and
    // the synchronous mode beats the asynchronous one overall.
    assert!(ws.mean() > 0.0 && wa.mean() > 0.0, "wait gains positive");
    assert!(es.mean() < 0.0 && ea.mean() < 0.0, "exec gains negative");
    assert!(cs.mean() > 0.0, "sync completion gain positive");
    assert!(
        cs.mean() > ca.mean(),
        "sync completion gain {} !> async {}",
        cs.mean(),
        ca.mean()
    );
    assert!(
        ea.mean() < es.mean(),
        "async exec degradation worse (paper: -97% vs -58%)"
    );
    assert!(sync.makespan <= asy.makespan, "sync makespan at least as good");
    println!("table3_sync_async OK (shapes match the paper)");
}
