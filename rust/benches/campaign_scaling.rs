//! Bench: campaign runner throughput vs worker count — the speed win of
//! sharding the single-threaded DES across a thread pool.  Also verifies
//! the aggregate output is identical at every worker count (the runner's
//! determinism contract) while timing it.

mod common;

use dmr::campaign::{self, CampaignSpec};
use dmr::metrics::report;
use dmr::util::table::Table;

fn spec(jobs: usize, seeds: usize) -> CampaignSpec {
    let seed_list: Vec<String> = (1..=seeds as u64).map(|s| s.to_string()).collect();
    CampaignSpec::from_toml_str(&format!(
        r#"
name = "scaling"
nodes = [32, 64]
modes = ["fixed", "sync"]
seeds = [{seeds}]
[[workload]]
kind = "feitelson"
jobs = {jobs}
[[workload]]
kind = "burst_lull"
jobs = {jobs}
"#,
        seeds = seed_list.join(", "),
        jobs = jobs,
    ))
    .expect("valid bench spec")
}

fn main() {
    common::banner("campaign_scaling", "campaign runner throughput vs worker count");
    let (jobs, seeds) = if common::full() { (100, 8) } else { (25, 4) };
    let s = spec(jobs, seeds);
    println!(
        "matrix: {} runs ({} jobs per workload), machine has {} cores\n",
        s.matrix_size(),
        jobs,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );

    let mut t = Table::new(vec!["Workers", "Wall (s)", "Runs/s", "Speedup"]);
    let mut base = None;
    let mut reference: Option<Vec<Vec<String>>> = None;
    for workers in [1usize, 2, 4, 8] {
        let res = campaign::run_campaign(&s, workers).expect("campaign runs");
        let agg_rows = report::campaign_agg_rows(&campaign::aggregate(&res.records));
        match &reference {
            None => reference = Some(agg_rows),
            Some(r) => assert_eq!(r, &agg_rows, "aggregates must not depend on workers"),
        }
        let wall = res.wall_secs;
        let b = *base.get_or_insert(wall);
        t.row(vec![
            workers.to_string(),
            format!("{wall:.3}"),
            format!("{:.1}", res.runs_per_sec()),
            format!("{:.2}x", b / wall.max(1e-9)),
        ]);
    }
    println!("{}", t.render());
    println!("(aggregate CSV rows verified identical across all worker counts)");
}
