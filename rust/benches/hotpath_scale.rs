//! Bench: hot-path scaling — DES events/s on multi-thousand-job
//! workloads (Feitelson + SWF-style trace replay) across 256–4096-node
//! clusters.  This is the repo's perf trajectory point: it emits the
//! machine-readable `BENCH_hotpath.json` (per-scenario events/s, overall
//! runs/s, makespan checksums) so future PRs can be compared against it.
//!
//! Every scenario runs **twice**; the second (warm) run is the one
//! measured, and the two runs' checksums (event-log digest + makespan
//! bits) must match exactly — CI fails on a determinism mismatch or a
//! panic, never on timing.
//!
//! Quick mode (default, CI): 1k/5k-job workloads on 256 nodes, sync and
//! async.  `BENCH_FULL=1` adds the 5k-job runs on 1024- and 4096-node
//! clusters and a 20k-job / 4096-node async case (the scale the
//! incremental availability profile targets).
//!
//! `HOTPATH_REFERENCE=1` forces `RmsConfig::incremental_profile = false`
//! (the rebuild-and-sort reference path, elision off).  CI runs the
//! bench both ways and asserts the per-scenario checksum sets are
//! identical — the profile must be a pure optimization.

mod common;

use std::time::Instant;

use dmr::des::{DesConfig, Engine};
use dmr::dmr::SchedMode;
use dmr::metrics::report::{bench_checksum, bench_json, BenchRecord};
use dmr::obs::{Phase, PhaseProfile};
use dmr::rms::RmsConfig;
use dmr::util::rng::Rng;
use dmr::util::table::Table;
use dmr::workload::{self, swf, WorkloadSpec};

struct Case {
    workload: &'static str, // feitelson | swf
    jobs: usize,
    nodes: usize,
    mode: &'static str, // fixed | sync | async
}

/// Deterministic synthetic SWF-shaped trace: power-of-two job sizes,
/// exponential runtimes and inter-arrivals (stands in for an archive
/// trace so the bench has no file dependency at 1k/5k-job scale).
fn synth_trace(jobs: usize, seed: u64) -> swf::SwfTrace {
    let mut rng = Rng::new(seed);
    let mut records = Vec::with_capacity(jobs);
    let mut t = 0.0;
    let mut max_procs = 0;
    for i in 0..jobs {
        t += rng.exp(8.0);
        let procs = 1usize << rng.below(8); // 1..=128
        let runtime = 60.0 + rng.exp(600.0);
        max_procs = max_procs.max(procs);
        // Deal a small user population round-robin (deterministic — the
        // checksummed event stream is user-agnostic under the default
        // strategy, but the fairness metrics become meaningful).
        let user = (i % 8) as i64 + 1;
        records.push(swf::SwfRecord {
            job_id: i as u64 + 1,
            submit: t,
            runtime,
            procs,
            status: 1,
            user,
        });
    }
    swf::SwfTrace { records, stats: swf::SwfStats::default(), max_procs }
}

fn materialize(case: &Case) -> WorkloadSpec {
    let w = match case.workload {
        "feitelson" => workload::generate(case.jobs, common::SEED),
        "swf" => {
            let trace = synth_trace(case.jobs, common::SEED);
            let opts = swf::SwfOptions {
                rescale_nodes: Some(case.nodes),
                malleable_fraction: 0.3,
                ..Default::default()
            };
            swf::to_workload(&trace, &opts, common::SEED)
        }
        other => panic!("unknown workload kind {other}"),
    };
    if case.mode == "fixed" {
        w.as_fixed()
    } else {
        w
    }
}

fn reference_path() -> bool {
    std::env::var("HOTPATH_REFERENCE").map(|v| v == "1").unwrap_or(false)
}

fn run_once(case: &Case, w: &WorkloadSpec) -> (u64, f64, f64, String, u64, usize, PhaseProfile) {
    let mode = if case.mode == "async" { SchedMode::Async } else { SchedMode::Sync };
    let cfg = DesConfig {
        rms: RmsConfig {
            nodes: case.nodes,
            incremental_profile: !reference_path(),
            ..Default::default()
        },
        mode,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = Engine::new(cfg).run(w, "hotpath");
    let wall = t0.elapsed().as_secs_f64();
    let checksum = bench_checksum(&r.rms.log, r.makespan);
    let stats = r.rms.pass_stats();
    let elided = stats.sched_elided + stats.dmr_elided;
    (r.events, wall, r.makespan, checksum, elided, r.peak_slab, r.profile)
}

fn main() {
    let path = if reference_path() { "reference path (profile+elision off)" } else { "incremental profile" };
    common::banner("hotpath_scale", &format!("DES events/s at 1k-20k jobs, 256-4096 nodes — {path}"));
    let mut cases = vec![
        Case { workload: "feitelson", jobs: 1000, nodes: 256, mode: "fixed" },
        Case { workload: "feitelson", jobs: 1000, nodes: 256, mode: "sync" },
        Case { workload: "feitelson", jobs: 5000, nodes: 256, mode: "sync" },
        Case { workload: "feitelson", jobs: 5000, nodes: 256, mode: "async" },
        Case { workload: "swf", jobs: 1000, nodes: 256, mode: "sync" },
        Case { workload: "swf", jobs: 5000, nodes: 256, mode: "async" },
    ];
    if common::full() {
        cases.extend([
            Case { workload: "feitelson", jobs: 5000, nodes: 1024, mode: "sync" },
            Case { workload: "feitelson", jobs: 5000, nodes: 4096, mode: "sync" },
            Case { workload: "swf", jobs: 5000, nodes: 1024, mode: "sync" },
            Case { workload: "swf", jobs: 5000, nodes: 4096, mode: "async" },
            // The profile's target scale: a deep saturated backlog where
            // the pre-profile pass cost O(R log R) every event.
            Case { workload: "feitelson", jobs: 20000, nodes: 4096, mode: "async" },
        ]);
    }

    let mut t = Table::new(vec![
        "Scenario", "Events", "Elided", "Wall (s)", "Events/s", "Makespan (s)", "Checksum",
    ]);
    let mut records = Vec::with_capacity(cases.len());
    for case in &cases {
        let scenario = format!("{}{}-n{}-{}", case.workload, case.jobs, case.nodes, case.mode);
        let w = materialize(case);
        // Cold run: determinism reference.  Warm run: the measurement.
        let (ev_a, _, mk_a, sum_a, _, _, _) = run_once(case, &w);
        let (ev_b, wall, mk_b, sum_b, elided, peak, profile) = run_once(case, &w);
        assert_eq!(
            sum_a, sum_b,
            "{scenario}: determinism checksum mismatch ({mk_a} vs {mk_b})"
        );
        assert_eq!(ev_a, ev_b, "{scenario}: event count mismatch");
        t.row(vec![
            scenario.clone(),
            ev_b.to_string(),
            elided.to_string(),
            format!("{wall:.3}"),
            format!("{:.0}", ev_b as f64 / wall.max(1e-9)),
            format!("{mk_b:.1}"),
            sum_b.clone(),
        ]);
        records.push(BenchRecord {
            scenario,
            workload: case.workload.to_string(),
            jobs: case.jobs,
            nodes: case.nodes,
            mode: case.mode.to_string(),
            events: ev_b,
            wall_secs: wall,
            makespan_s: mk_b,
            checksum: sum_b,
            peak_live: peak,
            dispatch_ns: profile.total_ns(),
            sched_ns: profile.wall_ns(Phase::Schedule),
            dmr_ns: profile.wall_ns(Phase::Dmr),
        });
    }
    println!("{}", t.render());

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".into());
    let doc = bench_json("hotpath_scale", &records).render();
    std::fs::write(&out, format!("{doc}\n")).expect("write BENCH_hotpath.json");
    println!("wrote {out} ({} scenarios, determinism checksums verified)", records.len());
}
