//! Ablation (DESIGN.md §5): the checking-inhibitor period (§5.1).
//! Sweeps the period on the CG/Jacobi jobs of a 100-job workload and
//! reports makespan + action counts: too-frequent checks buy nothing but
//! overhead, too-rare checks miss reconfiguration opportunities.

mod common;

use dmr::des::{DesConfig, Engine};
use dmr::metrics::RunSummary;
use dmr::util::table::Table;
use dmr::workload;

fn main() {
    common::banner("ablate_inhibitor", "checking-inhibitor period sweep (100 jobs)");
    let mut t = Table::new(vec![
        "Period (s)",
        "Makespan (s)",
        "Actions",
        "No-action calls",
        "Avg exec (s)",
    ]);
    let mut results = Vec::new();
    for period in [1.0, 5.0, 15.0, 60.0, 240.0] {
        let mut w = workload::generate(100, common::SEED);
        for j in &mut w.jobs {
            if j.sched_period > 0.0 {
                j.sched_period = period;
            }
        }
        let r = Engine::new(DesConfig::default()).run(&w, &format!("p{period}"));
        let s = RunSummary::from_run(r);
        let acts = s.actions.expand.count() + s.actions.shrink.count();
        t.row(vec![
            format!("{period}"),
            format!("{:.0}", s.makespan),
            format!("{acts}"),
            format!("{}", s.actions.no_action.count()),
            format!("{:.0}", s.exec.mean()),
        ]);
        results.push((period, s));
    }
    println!("{}", t.render());

    // The knob's purpose: fewer RMS calls with longer periods.
    assert!(
        results.first().unwrap().1.actions.no_action.count()
            > results.last().unwrap().1.actions.no_action.count(),
        "longer inhibition must reduce RMS traffic"
    );
    println!("ablate_inhibitor OK");
}
