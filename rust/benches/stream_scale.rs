//! Bench: million-job streaming replay — DES events/s and peak-resident
//! job count when arrivals are pulled lazily from a [`JobStream`] instead
//! of a materialized workload vector.  Emits `BENCH_stream.json`
//! (per-scenario events/s, makespan checksums, `peak_live_jobs`) so future
//! PRs can track both throughput and the memory bound.
//!
//! The point of the streaming pipeline is that memory scales with peak
//! *concurrency*, not total job count: a fault-free run can never hold
//! more live slab slots than the cluster has nodes, so every scenario
//! asserts `peak_live <= nodes` — at a million jobs that is a ~250×
//! reduction over keeping every job resident.
//!
//! Every scenario runs **twice**; the second (warm) run is the one
//! measured, and the two runs' checksums (rolling event-log digest +
//! makespan bits) must match exactly — CI fails on a determinism mismatch
//! or a panic, never on timing.  Records are dropped (`keep_records =
//! false`): the rolling digest and streaming metric folds survive, which
//! is exactly the bounded-memory configuration a million-job replay uses.
//!
//! Quick mode (default, CI): 100k jobs sync + 1M jobs fixed on 4096
//! nodes.  `BENCH_FULL=1` adds the 1M-job sync (malleable) case.

mod common;

use std::time::Instant;

use dmr::des::{DesConfig, Engine, RunResult};
use dmr::dmr::SchedMode;
use dmr::metrics::report::{bench_checksum, bench_json, BenchRecord};
use dmr::obs::Phase;
use dmr::rms::RmsConfig;
use dmr::util::table::Table;
use dmr::workload::{Adapted, FeitelsonParams, FeitelsonStream};

struct Case {
    jobs: usize,
    nodes: usize,
    mode: &'static str, // fixed | sync | async
    /// Engine look-ahead window (pulled-but-not-yet-arrived jobs).
    window: usize,
}

/// Build the case's job stream.  Nothing is materialized: the Feitelson
/// generator emits one job per pull and [`Adapted`] applies the
/// fit/fixed transforms per job, so the only job storage anywhere is the
/// engine's look-ahead buffer plus the live slab.
fn stream_for(case: &Case) -> Adapted<FeitelsonStream> {
    let params = FeitelsonParams { jobs: case.jobs, ..Default::default() };
    let s = Adapted::new(FeitelsonStream::new(params, common::SEED)).fit(case.nodes);
    if case.mode == "fixed" {
        s.fixed(true)
    } else {
        s
    }
}

fn run_once(case: &Case) -> (RunResult, f64) {
    let mode = if case.mode == "async" { SchedMode::Async } else { SchedMode::Sync };
    let cfg = DesConfig {
        rms: RmsConfig {
            nodes: case.nodes,
            // The bounded-memory configuration: no per-job records, no
            // retained event vector — digests and folds only.
            keep_records: false,
            ..Default::default()
        },
        mode,
        ..Default::default()
    };
    let mut stream = stream_for(case);
    let t0 = Instant::now();
    let r = Engine::new(cfg)
        .run_stream(&mut stream, case.window, "stream")
        .expect("generator streams cannot fail");
    let wall = t0.elapsed().as_secs_f64();
    (r, wall)
}

fn main() {
    common::banner(
        "stream_scale",
        "streamed DES replay at 100k-1M jobs: events/s + peak-resident jobs",
    );
    let mut cases = vec![
        Case { jobs: 100_000, nodes: 4096, mode: "sync", window: 64 },
        Case { jobs: 1_000_000, nodes: 4096, mode: "fixed", window: 64 },
    ];
    if common::full() {
        cases.push(Case { jobs: 1_000_000, nodes: 4096, mode: "sync", window: 64 });
    }

    let mut t = Table::new(vec![
        "Scenario", "Events", "Wall (s)", "Events/s", "Peak live", "Makespan (s)", "Checksum",
    ]);
    let mut records = Vec::with_capacity(cases.len());
    for case in &cases {
        let scenario = format!("stream-feitelson{}-n{}-{}", case.jobs, case.nodes, case.mode);
        // Cold run: determinism reference.  Warm run: the measurement.
        let (ra, _) = run_once(case);
        let (rb, wall) = run_once(case);
        let (sum_a, sum_b) =
            (bench_checksum(&ra.rms.log, ra.makespan), bench_checksum(&rb.rms.log, rb.makespan));
        assert_eq!(sum_a, sum_b, "{scenario}: determinism checksum mismatch");
        assert_eq!(ra.events, rb.events, "{scenario}: event count mismatch");
        assert_eq!(rb.user_jobs, case.jobs, "{scenario}: stream must drain fully");
        // The memory bound the whole subsystem exists for: live slab
        // slots are capped by cluster capacity, never by replay length.
        assert!(rb.peak_slab > 0, "{scenario}: peak never recorded");
        assert!(
            rb.peak_slab <= case.nodes,
            "{scenario}: peak-resident jobs {} exceeds the {}-node capacity bound",
            rb.peak_slab,
            case.nodes
        );
        assert_eq!(ra.peak_slab, rb.peak_slab, "{scenario}: peak mismatch");

        t.row(vec![
            scenario.clone(),
            rb.events.to_string(),
            format!("{wall:.3}"),
            format!("{:.0}", rb.events as f64 / wall.max(1e-9)),
            rb.peak_slab.to_string(),
            format!("{:.1}", rb.makespan),
            sum_b.clone(),
        ]);
        records.push(BenchRecord {
            scenario,
            workload: "feitelson".to_string(),
            jobs: case.jobs,
            nodes: case.nodes,
            mode: case.mode.to_string(),
            events: rb.events,
            wall_secs: wall,
            makespan_s: rb.makespan,
            checksum: sum_b,
            peak_live: rb.peak_slab,
            dispatch_ns: rb.profile.total_ns(),
            sched_ns: rb.profile.wall_ns(Phase::Schedule),
            dmr_ns: rb.profile.wall_ns(Phase::Dmr),
        });
    }
    println!("{}", t.render());

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_stream.json".into());
    let doc = bench_json("stream_scale", &records).render();
    std::fs::write(&out, format!("{doc}\n")).expect("write BENCH_stream.json");
    println!("wrote {out} ({} scenarios, determinism checksums verified)", records.len());
}
