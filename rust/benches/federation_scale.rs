//! Bench: federated meta-scheduler scaling — trace replay across sharded
//! clusters (default 8 shards x 512 nodes; `BENCH_FULL=1` grows to the
//! 32k-node, 8 x 4096 layout the subsystem targets).  Emits the
//! machine-readable `BENCH_federation.json` (per-scenario events/s,
//! steal counts, determinism checksums) so future PRs can compare.
//!
//! Every scenario runs **twice** and the combined per-shard checksums
//! (event-log digests folded with the makespan bits) must match exactly
//! — CI fails on a determinism mismatch or a panic, never on timing.
//! The 1-shard scenario is additionally compared against the flat
//! `des::Engine` on the same stream: digests and makespan bits must be
//! identical (the federation's bit-exactness contract).

mod common;

use std::time::Instant;

use dmr::des::{DesConfig, Engine};
use dmr::dmr::SchedMode;
use dmr::federation::{
    FedEngine, FederationConfig, FedRunResult, RoutingPolicy, ShardSpec, StealPolicy,
};
use dmr::metrics::report::{bench_json, BenchRecord};
use dmr::obs::Phase;
use dmr::rms::RmsConfig;
use dmr::util::rng::Rng;
use dmr::util::table::Table;
use dmr::workload::{swf, WorkloadSpec};

struct Case {
    shards: usize,
    routing: RoutingPolicy,
    steal: StealPolicy,
}

/// Deterministic SWF-shaped trace sized to the federated pool:
/// power-of-two job widths up to half a shard, exponential runtimes and
/// inter-arrivals, an 8-user population for the locality policy.
fn synth_trace(jobs: usize, max_width_pow: u32, seed: u64) -> swf::SwfTrace {
    let mut rng = Rng::new(seed);
    let mut records = Vec::with_capacity(jobs);
    let mut t = 0.0;
    let mut max_procs = 0;
    for i in 0..jobs {
        t += rng.exp(4.0);
        let procs = 1usize << rng.below(max_width_pow as u64);
        let runtime = 60.0 + rng.exp(600.0);
        max_procs = max_procs.max(procs);
        records.push(swf::SwfRecord {
            job_id: i as u64 + 1,
            submit: t,
            runtime,
            procs,
            status: 1,
            user: (i % 8) as i64 + 1,
        });
    }
    swf::SwfTrace { records, stats: swf::SwfStats::default(), max_procs }
}

fn materialize(jobs: usize, total_nodes: usize) -> WorkloadSpec {
    let trace = synth_trace(jobs, 9, common::SEED); // widths 1..=256
    let opts = swf::SwfOptions {
        rescale_nodes: Some(total_nodes / 8),
        malleable_fraction: 0.3,
        ..Default::default()
    };
    swf::to_workload(&trace, &opts, common::SEED)
}

fn cfg(total_nodes: usize) -> DesConfig {
    DesConfig {
        rms: RmsConfig { nodes: total_nodes, ..Default::default() },
        mode: SchedMode::Sync,
        ..Default::default()
    }
}

/// Fold the per-shard event-log digests and the makespan bits into one
/// hex checksum (shard order is part of the digest).
fn fed_checksum(r: &FedRunResult) -> String {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for s in &r.shards {
        h ^= s.rms.log.digest();
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    format!("{:016x}", h ^ r.makespan.to_bits())
}

fn run_once(case: &Case, total_nodes: usize, w: &WorkloadSpec) -> (FedRunResult, f64) {
    let fed = FederationConfig {
        shards: ShardSpec::uniform(total_nodes, case.shards),
        routing: case.routing,
        steal: case.steal,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = FedEngine::new(cfg(total_nodes), fed).run(w, "federation");
    (r, t0.elapsed().as_secs_f64())
}

fn main() {
    let (jobs, total_nodes) = if common::full() {
        (10_000, 8 * 4096) // the subsystem's target: 8 shards x 4096 nodes
    } else {
        (2_000, 8 * 512)
    };
    common::banner(
        "federation_scale",
        &format!("meta-scheduler replay: {jobs} jobs across {total_nodes} nodes"),
    );
    let cases = [
        Case { shards: 1, routing: RoutingPolicy::RoundRobin, steal: StealPolicy::Off },
        Case { shards: 8, routing: RoutingPolicy::RoundRobin, steal: StealPolicy::Off },
        Case { shards: 8, routing: RoutingPolicy::LeastLoaded, steal: StealPolicy::Off },
        Case { shards: 8, routing: RoutingPolicy::LeastLoaded, steal: StealPolicy::Head },
        Case { shards: 8, routing: RoutingPolicy::Locality, steal: StealPolicy::Half },
    ];
    let w = materialize(jobs, total_nodes);

    let mut t = Table::new(vec![
        "Scenario", "Events", "Steals", "Wall (s)", "Events/s", "Makespan (s)", "Checksum",
    ]);
    let mut records = Vec::with_capacity(cases.len());
    for case in &cases {
        let scenario = format!(
            "swf{jobs}-n{total_nodes}-s{}x{}{}",
            case.shards,
            case.routing.label(),
            if case.steal.enabled() { "-steal" } else { "" }
        );
        // Cold run: determinism reference.  Warm run: the measurement.
        let (ra, _) = run_once(case, total_nodes, &w);
        let (rb, wall) = run_once(case, total_nodes, &w);
        let (sum_a, sum_b) = (fed_checksum(&ra), fed_checksum(&rb));
        assert_eq!(sum_a, sum_b, "{scenario}: determinism checksum mismatch");
        assert_eq!(ra.events, rb.events, "{scenario}: event count mismatch");
        let done: usize = rb.shards.iter().map(|s| s.rms.completed_jobs()).sum();
        assert_eq!(done, w.len(), "{scenario}: workload must drain");

        if case.shards == 1 {
            // Bit-exactness against the flat engine on the same stream.
            let flat = Engine::new(cfg(total_nodes)).run(&w, "flat");
            assert_eq!(
                rb.shards[0].rms.log.digest(),
                flat.rms.log.digest(),
                "{scenario}: 1-shard digest must equal the flat engine"
            );
            assert_eq!(
                rb.makespan.to_bits(),
                flat.makespan.to_bits(),
                "{scenario}: 1-shard makespan must equal the flat engine"
            );
        }

        t.row(vec![
            scenario.clone(),
            rb.events.to_string(),
            rb.steals().to_string(),
            format!("{wall:.3}"),
            format!("{:.0}", rb.events as f64 / wall.max(1e-9)),
            format!("{:.1}", rb.makespan),
            sum_b.clone(),
        ]);
        records.push(BenchRecord {
            scenario,
            workload: "swf".to_string(),
            jobs,
            nodes: total_nodes,
            mode: format!(
                "s{}x{}{}",
                case.shards,
                case.routing.label(),
                if case.steal.enabled() { "-steal" } else { "" }
            ),
            events: rb.events,
            wall_secs: wall,
            makespan_s: rb.makespan,
            checksum: sum_b,
            peak_live: rb.peak_slab,
            dispatch_ns: rb.profile.total_ns(),
            sched_ns: rb.profile.wall_ns(Phase::Schedule),
            dmr_ns: rb.profile.wall_ns(Phase::Dmr),
        });
    }
    println!("{}", t.render());

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_federation.json".into());
    let doc = bench_json("federation_scale", &records).render();
    std::fs::write(&out, format!("{doc}\n")).expect("write BENCH_federation.json");
    println!("wrote {out} ({} scenarios, determinism checksums verified)", records.len());
}
