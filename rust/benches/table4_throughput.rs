//! E5/E6/E7 — Table 4 + Fig 4 + Fig 5: the throughput evaluation (§7.5).
//! Fixed vs flexible across workload sizes, same seeded stream.

mod common;

use dmr::dmr::SchedMode;
use dmr::metrics::report;
use dmr::util::csv::write_csv;

fn main() {
    common::banner("table4_throughput", "Table 4 / Fig 4 / Fig 5 (workload sweep)");
    let sizes: Vec<usize> = if common::full() {
        vec![50, 100, 200, 400]
    } else {
        vec![50, 100, 200, 400] // DES is fast enough for full scale always
    };
    let mut rows = Vec::new();
    for n in sizes {
        let t0 = std::time::Instant::now();
        let fixed = common::run(n, common::SEED, SchedMode::Sync, false, "Fixed");
        let flex = common::run(n, common::SEED, SchedMode::Sync, true, "Flexible");
        eprintln!("  {n} jobs simulated in {:.2?}", t0.elapsed());
        rows.push((n, fixed, flex));
    }
    println!("{}", report::table4(&rows).render());
    println!("{}", report::fig4(&rows));
    println!("{}", report::fig5(&rows));
    write_csv(
        "results/table4_fig4_fig5.csv",
        &["jobs", "version", "makespan_s", "util_pct", "wait_s", "exec_s", "completion_s", "node_seconds"],
        &report::throughput_rows(&rows),
    )
    .unwrap();

    // Shape assertions vs the paper.
    for (n, fixed, flex) in &rows {
        assert!(flex.makespan < fixed.makespan, "{n}: flexible must win");
        assert!(flex.wait.mean() < fixed.wait.mean(), "{n}: waiting must improve");
        assert!(flex.exec.mean() > fixed.exec.mean(), "{n}: exec degrades (jobs run shrunk)");
        assert!(flex.util_mean < fixed.util_mean, "{n}: allocation rate drops (Table 4)");
    }
    println!("table4_throughput OK (shapes match the paper)");
}
