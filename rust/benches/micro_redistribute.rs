//! Micro-benchmark: redistribution-path throughput (the L3 data hot path
//! behind Fig 3(b)) across payload sizes and patterns.

mod common;

use dmr::live::overhead::measure_resize;
use dmr::util::table::Table;

fn main() {
    common::banner("micro_redistribute", "redistribution throughput");
    let mut t = Table::new(vec!["Pattern", "Payload (MB)", "Time (ms)", "GB/s"]);
    let mbs = if common::full() { vec![16usize, 64, 256, 1024] } else { vec![16, 64, 128] };
    for mb in mbs {
        for (from, to, name) in [(4usize, 8usize, "expand 4->8"), (8, 4, "shrink 8->4"), (1, 32, "expand 1->32"), (32, 1, "shrink 32->1")] {
            let f32s = mb * 1024 * 1024 / 4;
            // best of 3
            let secs = (0..3)
                .map(|_| measure_resize(from, to, f32s))
                .fold(f64::INFINITY, f64::min);
            t.row(vec![
                name.to_string(),
                format!("{mb}"),
                format!("{:.1}", secs * 1e3),
                format!("{:.2}", mb as f64 / 1024.0 / secs),
            ]);
        }
    }
    println!("{}", t.render());
    println!("micro_redistribute OK");
}
