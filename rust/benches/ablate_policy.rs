//! Ablation (DESIGN.md §5): the §4 reconfiguration-policy components —
//! preference handling (§4.2), wide optimization (§4.3) and the
//! shrink-trigger priority boost — each disabled in turn on the same
//! 100-job workload.

mod common;

use dmr::des::{DesConfig, Engine};
use dmr::metrics::RunSummary;
use dmr::rms::{PolicyConfig, RmsConfig};
use dmr::util::table::Table;
use dmr::workload;

fn run_with(policy: PolicyConfig, boost: bool, label: &str) -> RunSummary {
    run_cfg(policy, boost, true, label)
}

fn run_cfg(policy: PolicyConfig, boost: bool, backfill: bool, label: &str) -> RunSummary {
    let cfg = DesConfig {
        rms: RmsConfig { policy, shrink_priority_boost: boost, backfill, ..Default::default() },
        ..Default::default()
    };
    let w = workload::generate(100, common::SEED);
    RunSummary::from_run(Engine::new(cfg).run(&w, label))
}

fn main() {
    common::banner("ablate_policy", "reconfiguration-policy component ablation (100 jobs)");
    let full = run_with(PolicyConfig::default(), true, "full");
    let no_wide = run_with(
        PolicyConfig { wide_optimization: false, ..Default::default() },
        true,
        "no-wide-opt",
    );
    let no_pref = run_with(
        PolicyConfig { honor_preference: false, ..Default::default() },
        true,
        "no-preference",
    );
    let no_boost = run_with(PolicyConfig::default(), false, "no-shrink-boost");
    let no_backfill = run_cfg(PolicyConfig::default(), true, false, "no-backfill");
    let fixed = {
        let w = workload::generate(100, common::SEED).as_fixed();
        RunSummary::from_run(Engine::new(DesConfig::default()).run(&w, "rigid"))
    };

    let mut t = Table::new(vec!["Variant", "Makespan (s)", "Wait (s)", "Exec (s)", "Util (%)", "Actions"]);
    for s in [&full, &no_wide, &no_pref, &no_boost, &no_backfill, &fixed] {
        t.row(vec![
            s.label.clone(),
            format!("{:.0}", s.makespan),
            format!("{:.0}", s.wait.mean()),
            format!("{:.0}", s.exec.mean()),
            format!("{:.1}", s.util_mean * 100.0),
            format!("{}", s.actions.expand.count() + s.actions.shrink.count()),
        ]);
    }
    println!("{}", t.render());

    // Every policy variant still beats rigid; the full policy is best or
    // tied among variants.
    for s in [&full, &no_wide, &no_pref, &no_boost, &no_backfill] {
        assert!(s.makespan < fixed.makespan, "{} must beat rigid", s.label);
    }
    let best = [&no_wide, &no_pref, &no_boost]
        .iter()
        .map(|s| s.makespan)
        .fold(f64::INFINITY, f64::min);
    assert!(
        full.makespan <= best * 1.10,
        "full policy within 10% of the best ablation (usually strictly best)"
    );
    println!("ablate_policy OK");
}
