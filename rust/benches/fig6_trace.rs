//! E8 — Fig. 6: time evolution of the 50-job workload — allocated nodes
//! and running jobs (top), completed jobs (bottom), fixed vs flexible.

mod common;

use dmr::dmr::SchedMode;
use dmr::metrics::report;
use dmr::util::csv::write_csv;

fn main() {
    common::banner("fig6_trace", "Fig 6 (50-job workload time evolution)");
    let fixed = common::run(50, common::SEED, SchedMode::Sync, false, "Fixed");
    let flex = common::run(50, common::SEED, SchedMode::Sync, true, "Flexible");
    println!("{}", report::fig6(&fixed, &flex));

    let mut rows = Vec::new();
    for (name, s) in [("fixed", &fixed), ("flex", &flex)] {
        for (t, v) in &s.alloc_series {
            rows.push(vec![format!("alloc-{name}"), format!("{t:.1}"), format!("{v}")]);
        }
        for (t, v) in &s.running_series {
            rows.push(vec![format!("running-{name}"), format!("{t:.1}"), format!("{v}")]);
        }
        for (t, v) in &s.completed_series {
            rows.push(vec![format!("completed-{name}"), format!("{t:.1}"), format!("{v}")]);
        }
    }
    write_csv("results/fig6_trace.csv", &["series", "t_s", "value"], &rows).unwrap();

    // Shape assertions: the flexible workload runs more jobs concurrently
    // on fewer allocated nodes and finishes earlier.
    let peak_running = |s: &dmr::metrics::RunSummary| {
        s.running_series.iter().map(|(_, v)| *v).fold(0.0, f64::max)
    };
    assert!(peak_running(&flex) > peak_running(&fixed), "more concurrent jobs");
    assert!(flex.makespan < fixed.makespan);
    println!(
        "peak running jobs: fixed {} vs flexible {}",
        peak_running(&fixed),
        peak_running(&flex)
    );
    println!("fig6_trace OK (shapes match the paper)");
}
