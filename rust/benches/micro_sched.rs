//! Micro-benchmark: L3 scheduler hot paths — the scheduling pass and the
//! DMR decision under growing queue depth (the §Perf targets: decisions
//! well under the paper's 9.4 ms "no action" average).

mod common;

use std::time::Instant;

use dmr::rms::{DmrRequest, Rms, RmsConfig};
use dmr::util::table::Table;
use dmr::workload;

fn bench<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    common::banner("micro_sched", "RMS scheduling-pass + DMR-decision latency");
    let mut t = Table::new(vec![
        "Pending jobs",
        "schedule() (µs)",
        "dmr_check no-action (µs)",
        "dmr_check shrink-path (µs)",
    ]);
    for depth in [10usize, 50, 100, 400, 1000] {
        // Saturated cluster: one big running job + `depth` queued jobs.
        let mut rms = Rms::new(RmsConfig { nodes: 64, ..Default::default() });
        let w = workload::generate(depth + 1, 1);
        let mut ids = Vec::new();
        for (i, mut spec) in w.jobs.clone().into_iter().enumerate() {
            spec.procs = if i == 0 { 64 } else { 32 };
            spec.max_procs = 64;
            ids.push(rms.submit(spec, i as f64 * 0.1));
        }
        rms.schedule(0.0);
        rms.take_recent_starts();
        let running = ids[0];

        let sched_us = bench(200, || {
            rms.schedule(1000.0);
            rms.take_recent_starts();
        }) * 1e6;

        // A no-action decision (job already huge, nothing to do).
        let req_noact = DmrRequest { min: 2, max: 64, pref: Some(64), factor: 2 };
        let noact_us = bench(200, || {
            let _ = rms.dmr_peek(running, &req_noact, 1000.0);
        }) * 1e6;

        // The shrink decision path (policy evaluation only — peek).
        let req_shrink = DmrRequest { min: 2, max: 64, pref: Some(8), factor: 2 };
        let shrink_us = bench(200, || {
            let _ = rms.dmr_peek(running, &req_shrink, 1000.0);
        }) * 1e6;

        t.row(vec![
            format!("{depth}"),
            format!("{sched_us:.1}"),
            format!("{noact_us:.1}"),
            format!("{shrink_us:.1}"),
        ]);
    }
    println!("{}", t.render());
    println!("micro_sched OK");
}
