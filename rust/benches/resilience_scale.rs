//! Bench: resilience at scale — DES events/s on fault-heavy 1k-job
//! workloads, the robustness axis of the repo's perf trajectory.  Emits
//! the machine-readable `BENCH_resilience.json` (same schema as
//! `BENCH_hotpath.json`: per-scenario events/s, overall runs/s, makespan
//! checksums) so future PRs can be compared against it.
//!
//! Every scenario runs **twice**; the second (warm) run is measured and
//! the two runs' checksums (event-log digest + makespan bits — failure
//! events included) must match exactly — CI fails on a determinism
//! mismatch or a panic, never on timing.
//!
//! Quick mode (default, CI): 1k-job workloads on 256 nodes, rigid +
//! malleable + malleable-with-resize-faults (the `sync-rf` scenario puts
//! the transactional resize path — aborts, rollbacks, retries — on the
//! trajectory) + a federated failure-domain run (`fed-out`: two shards,
//! machine faults stacked with a whole-shard blackout and a partition
//! window, cross-shard evacuations verified).  `BENCH_FULL=1` adds
//! 5k-job runs.

mod common;

use std::time::Instant;

use dmr::des::{DesConfig, Engine};
use dmr::dmr::SchedMode;
use dmr::federation::{
    FedEngine, FederationConfig, FedRunResult, RoutingPolicy, ShardSpec, StealPolicy,
};
use dmr::metrics::report::{bench_checksum, bench_json, BenchRecord};
use dmr::obs::{Phase, PhaseProfile};
use dmr::resilience::{
    DrainSet, DrainWindow, FaultKind, FaultSpec, FaultTraceEvent, OutageEvent, OutageSpec,
    PartitionWindow, RecoveryConfig, ResilienceConfig, ResizeFaultSpec,
};
use dmr::rms::RmsConfig;
use dmr::util::table::Table;
use dmr::workload::{self, WorkloadSpec};

struct Case {
    jobs: usize,
    nodes: usize,
    // fixed | sync | sync-rf (resize faults on) | fed-out (2 shards,
    // machine faults + whole-shard outage + partition).
    mode: &'static str,
}

impl Case {
    fn resize_faults(&self) -> bool {
        self.mode == "sync-rf"
    }

    fn federated(&self) -> bool {
        self.mode == "fed-out"
    }
}

/// A fault-heavy machine model: per-node MTBF tuned to land a few dozen
/// failures across the run, one scripted early failure (so the fault path
/// is exercised even if the sampled times drift past the makespan) and a
/// mid-run 16-node drain window.
fn fault_model() -> ResilienceConfig {
    ResilienceConfig {
        faults: FaultSpec {
            mtbf: 500_000.0,
            mttr: 2_000.0,
            scripted: vec![FaultTraceEvent {
                at: 1_000.0,
                node: 0,
                kind: FaultKind::Fail,
            }],
            drains: vec![DrainWindow {
                start: 5_000.0,
                end: 12_000.0,
                nodes: DrainSet::Count(16),
            }],
        },
        recovery: RecoveryConfig { checkpoint_interval: 600.0, ..Default::default() },
        ..Default::default()
    }
}

fn materialize(case: &Case) -> WorkloadSpec {
    let w = workload::generate(case.jobs, common::SEED);
    if case.mode == "fixed" {
        w.as_fixed()
    } else {
        w
    }
}

/// The `fed-out` correlated-fault layer: shard 0 goes entirely dark for
/// 3000 s mid-stream, shard 1 rides out a 1000 s network partition.
fn outage_model() -> Vec<OutageSpec> {
    vec![
        OutageSpec {
            scripted: vec![OutageEvent {
                domain: String::new(),
                at: 5_000.0,
                duration: 3_000.0,
            }],
            ..Default::default()
        },
        OutageSpec {
            partitions: vec![PartitionWindow { start: 9_000.0, end: 10_000.0 }],
            ..Default::default()
        },
    ]
}

/// Fold the per-shard event-log digests and the makespan bits into one
/// hex checksum (shard order is part of the digest), as in
/// `federation_scale`.
fn fed_checksum(r: &FedRunResult) -> String {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for s in &r.shards {
        h ^= s.rms.log.digest();
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    format!("{:016x}", h ^ r.makespan.to_bits())
}

/// One federated fault-heavy run: machine faults on both shards plus the
/// correlated-outage layer.  Verifies evacuation invariants inline (every
/// interrupted job rescued, requeued or evacuated exactly once; work
/// fails over rather than getting lost) and returns the same measurement
/// tuple as `run_once`.
fn run_once_fed(
    case: &Case,
    w: &WorkloadSpec,
) -> (u64, f64, f64, String, u64, u64, u64, usize, PhaseProfile) {
    let cfg = DesConfig {
        rms: RmsConfig { nodes: case.nodes, ..Default::default() },
        mode: SchedMode::Sync,
        resilience: fault_model(),
        ..Default::default()
    };
    let fed = FederationConfig {
        shards: ShardSpec::uniform(case.nodes, 2),
        routing: RoutingPolicy::LeastLoaded,
        steal: StealPolicy::Half,
        outages: Some(outage_model()),
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = FedEngine::new(cfg, fed).run(w, "resilience-fed");
    let wall = t0.elapsed().as_secs_f64();
    let done: usize = r.shards.iter().map(|s| s.rms.completed_jobs()).sum();
    assert_eq!(done, w.len(), "fed-out: outages displace work, they never lose it");
    assert!(r.evacuations() > 0, "fed-out: the blackout must force cross-shard failover");
    assert_eq!(
        r.evacuations(),
        r.cross_shard_requeues(),
        "fed-out: every evacuee lands exactly once"
    );
    for s in &r.shards {
        assert_eq!(
            s.stats.interrupted,
            s.stats.rescued + s.stats.requeued + s.stats.evacuated,
            "fed-out: shard {} failure ledger must close",
            s.shard
        );
    }
    let checksum = fed_checksum(&r);
    (
        r.events,
        wall,
        r.makespan,
        checksum,
        r.resilience.node_failures,
        r.resilience.rescued + r.resilience.requeued + r.resilience.evacuated,
        r.resilience.resize_aborts,
        r.peak_slab,
        r.profile,
    )
}

fn run_once(
    case: &Case,
    w: &WorkloadSpec,
) -> (u64, f64, f64, String, u64, u64, u64, usize, PhaseProfile) {
    let mut resilience = fault_model();
    if case.resize_faults() {
        // The transactional-resize trajectory point: a third of the
        // spawns fail, with a trickle of redistribution aborts and grant
        // revocations on top of the machine faults above.
        resilience.resize_faults = ResizeFaultSpec {
            spawn_fail: 0.3,
            redist_fail: 0.1,
            revoke: 0.05,
            max_retries: 2,
            backoff_base: 30.0,
            backoff_cap: 240.0,
        };
    }
    let cfg = DesConfig {
        rms: RmsConfig { nodes: case.nodes, ..Default::default() },
        mode: SchedMode::Sync,
        resilience,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = Engine::new(cfg).run(w, "resilience");
    let wall = t0.elapsed().as_secs_f64();
    let checksum = bench_checksum(&r.rms.log, r.makespan);
    (
        r.events,
        wall,
        r.makespan,
        checksum,
        r.resilience.node_failures,
        r.resilience.rescued + r.resilience.requeued,
        r.resilience.resize_aborts,
        r.peak_slab,
        r.profile,
    )
}

fn main() {
    common::banner("resilience_scale", "DES events/s under fault-heavy 1k-job workloads");
    let mut cases = vec![
        Case { jobs: 1000, nodes: 256, mode: "fixed" },
        Case { jobs: 1000, nodes: 256, mode: "sync" },
        Case { jobs: 1000, nodes: 256, mode: "sync-rf" },
        Case { jobs: 1000, nodes: 256, mode: "fed-out" },
    ];
    if common::full() {
        cases.extend([
            Case { jobs: 5000, nodes: 256, mode: "fixed" },
            Case { jobs: 5000, nodes: 256, mode: "sync" },
            Case { jobs: 5000, nodes: 256, mode: "sync-rf" },
            Case { jobs: 5000, nodes: 256, mode: "fed-out" },
        ]);
    }

    let mut t = Table::new(vec![
        "Scenario", "Events", "Wall (s)", "Events/s", "Makespan (s)", "Failures",
        "Recoveries", "Checksum",
    ]);
    let mut records = Vec::with_capacity(cases.len());
    for case in &cases {
        let scenario = format!("faulty-feitelson{}-n{}-{}", case.jobs, case.nodes, case.mode);
        let w = materialize(case);
        let runner = if case.federated() { run_once_fed } else { run_once };
        // Cold run: determinism reference.  Warm run: the measurement.
        let (ev_a, _, mk_a, sum_a, _, _, aborts_a, _, _) = runner(case, &w);
        let (ev_b, wall, mk_b, sum_b, failures, recoveries, aborts_b, peak, profile) =
            runner(case, &w);
        assert_eq!(
            sum_a, sum_b,
            "{scenario}: determinism checksum mismatch (makespans {mk_a} / {mk_b})"
        );
        assert_eq!(ev_a, ev_b, "{scenario}: event count mismatch");
        assert!(failures > 0, "{scenario}: fault injection never fired");
        assert_eq!(aborts_a, aborts_b, "{scenario}: resize-abort count mismatch");
        if case.resize_faults() {
            assert!(aborts_b > 0, "{scenario}: resize faults never fired");
        } else {
            assert_eq!(aborts_b, 0, "{scenario}: unexpected resize aborts");
        }
        t.row(vec![
            scenario.clone(),
            ev_b.to_string(),
            format!("{wall:.3}"),
            format!("{:.0}", ev_b as f64 / wall.max(1e-9)),
            format!("{mk_b:.1}"),
            failures.to_string(),
            recoveries.to_string(),
            sum_b.clone(),
        ]);
        records.push(BenchRecord {
            scenario,
            workload: "feitelson".to_string(),
            jobs: case.jobs,
            nodes: case.nodes,
            mode: case.mode.to_string(),
            events: ev_b,
            wall_secs: wall,
            makespan_s: mk_b,
            checksum: sum_b,
            peak_live: peak,
            dispatch_ns: profile.total_ns(),
            sched_ns: profile.wall_ns(Phase::Schedule),
            dmr_ns: profile.wall_ns(Phase::Dmr),
        });
    }
    println!("{}", t.render());

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_resilience.json".into());
    let doc = bench_json("resilience_scale", &records).render();
    std::fs::write(&out, format!("{doc}\n")).expect("write BENCH_resilience.json");
    println!("wrote {out} ({} scenarios, determinism checksums verified)", records.len());
}
