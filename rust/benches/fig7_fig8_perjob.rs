//! E9/E10 — Fig. 7 + Fig. 8: per-job execution and waiting times grouped
//! by application, and the fixed-vs-flexible per-job time differences.

mod common;

use dmr::dmr::SchedMode;
use dmr::metrics::report;
use dmr::util::csv::write_csv;

fn main() {
    common::banner("fig7_fig8_perjob", "Fig 7 / Fig 8 (per-job times, 50-job workload)");
    let fixed = common::run(50, common::SEED, SchedMode::Sync, false, "Fixed");
    let flex = common::run(50, common::SEED, SchedMode::Sync, true, "Flexible");
    println!("{}", report::fig7_fig8_preview(&fixed, &flex));
    let rows = report::perjob_rows(&fixed, &flex);
    write_csv(
        "results/fig7_fig8_perjob.csv",
        &["app", "job", "wait_fixed", "wait_flex", "exec_fixed", "exec_flex",
          "d_wait", "d_exec", "d_completion"],
        &rows,
    )
    .unwrap();

    // Fig. 8 shape: execution difference below zero (flexible slower),
    // completion difference dominated by the waiting difference.
    let mut d_exec_sum = 0.0;
    let mut d_wait_sum = 0.0;
    let mut d_comp_sum = 0.0;
    let mut pos_comp = 0usize;
    for r in &rows {
        let d_wait: f64 = r[6].parse().unwrap();
        let d_exec: f64 = r[7].parse().unwrap();
        let d_comp: f64 = r[8].parse().unwrap();
        d_exec_sum += d_exec;
        d_wait_sum += d_wait;
        d_comp_sum += d_comp;
        if d_comp > 0.0 {
            pos_comp += 1;
        }
    }
    assert!(d_exec_sum < 0.0, "flexible execution slower overall (Fig 8)");
    assert!(d_wait_sum > 0.0, "flexible waiting much lower (Fig 8)");
    assert!(d_comp_sum > 0.0, "completion dominated by waiting (Fig 8)");
    println!(
        "per-job deltas: sum(d_exec)={:.0}s sum(d_wait)={:.0}s sum(d_completion)={:.0}s; {}/{} jobs complete earlier",
        d_exec_sum, d_wait_sum, d_comp_sum, pos_comp, rows.len()
    );
    println!("fig7_fig8_perjob OK (shapes match the paper)");
}
