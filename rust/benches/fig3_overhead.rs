//! E1/E2 — Fig. 3: reconfiguration scheduling and resize times, measured
//! live on this stack (§7.3).  Default payload 32 MB for bench speed;
//! `BENCH_FULL=1` uses 1 GB like the paper.

mod common;

use dmr::live::overhead::fig3_sweep;
use dmr::util::csv::write_csv;
use dmr::util::table::Table;

fn main() {
    let (mb, reps) = if common::full() { (1024usize, 10usize) } else { (32, 3) };
    common::banner("fig3_overhead", "Fig 3 (scheduling + resize overheads, live)");
    println!("payload {mb} MB, {reps} reps per point\n");
    let samples = fig3_sweep(reps, mb * 1024 * 1024 / 4);

    let mut t = Table::new(vec!["Reconfiguration", "Scheduling (ms)", "Resize (ms)"])
        .with_title("Fig 3 (a) scheduling and (b) resize times");
    let mut rows = Vec::new();
    for s in &samples {
        t.row(vec![
            format!("{:>2} -> {:<2}", s.from, s.to),
            format!("{:.3}", s.sched_secs * 1e3),
            format!("{:.1}", s.resize_secs * 1e3),
        ]);
        rows.push(vec![
            s.from.to_string(),
            s.to.to_string(),
            format!("{:.6}", s.sched_secs),
            format!("{:.6}", s.resize_secs),
        ]);
    }
    println!("{}", t.render());
    write_csv("results/fig3_overhead_live.csv", &["from", "to", "sched_s", "resize_s"], &rows)
        .unwrap();

    // Fig 3(b) headline shape: "the more processes involved in the
    // reconfiguration, the shorter resize time".
    let get = |f: usize, t_: usize| {
        samples.iter().find(|s| s.from == f && s.to == t_).unwrap().resize_secs
    };
    assert!(get(1, 2) > get(32, 64), "1->2 slower than 32->64");
    assert!(get(2, 1) > get(64, 32), "2->1 slower than 64->32");
    println!("fig3_overhead OK (shapes match the paper)");
}
