//! Shared helpers for the bench harnesses (each bench regenerates one
//! table/figure of the paper; `BENCH_FULL=1` switches to paper-scale
//! workload sizes).

use dmr::des::{DesConfig, Engine};
use dmr::dmr::SchedMode;
use dmr::metrics::RunSummary;
use dmr::workload;

pub fn full() -> bool {
    std::env::var("BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Default seed used across all benches (the paper fixes its seed too).
pub const SEED: u64 = 42;

pub fn run(jobs: usize, seed: u64, mode: SchedMode, flexible: bool, label: &str) -> RunSummary {
    let w = workload::generate(jobs, seed);
    let w = if flexible { w } else { w.as_fixed() };
    let cfg = DesConfig { mode, ..Default::default() };
    RunSummary::from_run(Engine::new(cfg).run(&w, label))
}

pub fn banner(name: &str, what: &str) {
    println!("==============================================================");
    println!("bench {name}: {what}");
    println!("==============================================================");
}
