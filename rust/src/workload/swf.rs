//! Standard Workload Format (SWF) ingestion: replay real cluster traces
//! (Feitelson's Parallel Workloads Archive format) through the DES.
//!
//! Each SWF line carries 18 whitespace-separated fields; `;` lines are
//! header comments and `-1` marks an unknown field.  The fields used here:
//!
//! | #  | field                  | use                                    |
//! |----|------------------------|----------------------------------------|
//! | 2  | submit time (s)        | arrival, shifted so the trace starts 0 |
//! | 4  | run time (s)           | modeled execution time                 |
//! | 5  | allocated processors   | fallback size when request is unknown  |
//! | 8  | requested processors   | submitted job size                     |
//! | 9  | requested time (s)     | fallback runtime when run time unknown |
//! | 11 | status                 | failed/cancelled jobs skipped by default |
//! | 12 | user id                | per-user fairness / fair-share policy  |
//!
//! Status semantics (SWF v2.2): `1` = completed, `0` = failed, `5` =
//! cancelled, `2`–`4` = partial executions, `-1` = unknown.  By default
//! only completed and unknown-status jobs are replayed — a trace job that
//! never ran to completion carries a runtime that says nothing about its
//! real demand; `SwfOptions::include_failed` restores the old
//! replay-everything behavior.
//!
//! Real traces contain only rigid jobs; following *Evaluating Malleable
//! Job Scheduling in HPC Clusters using Real-World Workloads* (Zojer et
//! al.), a configurable fraction of jobs is *injected* as malleable
//! (shrink-only: submitted at their maximum, factor-chain minimum below),
//! which is what lets trace replay exercise the DMR policies.

use crate::apps::config::AppKind;
use crate::util::rng::Rng;
use crate::workload::{JobSpec, WorkloadSpec};

/// One usable record of a trace (already reduced to the fields the DES
/// needs; see module docs for the SWF column mapping).
#[derive(Debug, Clone)]
pub struct SwfRecord {
    pub job_id: u64,
    /// Submit time in seconds from the trace epoch (not yet shifted).
    pub submit: f64,
    /// Runtime in seconds at `procs` processors.
    pub runtime: f64,
    /// Processors the job asked for (requested, falling back to
    /// allocated).
    pub procs: usize,
    /// SWF status field (`1` completed, `0` failed, `5` cancelled,
    /// `2`–`4` partial, negative = unknown).
    pub status: i64,
    /// SWF user id (field 12; `-1`/absent = unknown).  Feeds the
    /// fair-share policy strategy and the per-user fairness metrics.
    pub user: i64,
}

impl SwfRecord {
    /// Whether the trace marks this job as having run to completion
    /// (unknown statuses count as completed — old traces omit the field).
    pub fn completed(&self) -> bool {
        self.status == 1 || self.status < 0
    }
}

/// Parse statistics — surfaced so spec files referencing a trace can be
/// sanity-checked and tests can assert on malformed-line handling.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SwfStats {
    /// Total lines in the file.
    pub lines: usize,
    /// `;` header/comment lines.
    pub comments: usize,
    /// Lines that were not parseable as an SWF record.
    pub malformed: usize,
    /// Parseable records dropped for missing essentials (no positive
    /// runtime or processor count).
    pub skipped: usize,
    /// Usable records whose status marks a job that never completed
    /// (failed/cancelled/partial).  Kept in the trace; skipped at
    /// materialization unless `SwfOptions::include_failed`.
    pub nonsuccess: usize,
}

/// A parsed trace.
#[derive(Debug, Clone)]
pub struct SwfTrace {
    pub records: Vec<SwfRecord>,
    pub stats: SwfStats,
    /// Largest processor request in the trace (node-rescaling baseline).
    pub max_procs: usize,
}

/// How a trace is materialized into a [`WorkloadSpec`].
#[derive(Debug, Clone)]
pub struct SwfOptions {
    /// Keep only the first N usable records (in submit order).
    pub max_jobs: Option<usize>,
    /// Rescale processor counts so the trace's largest request maps onto
    /// this cluster size (Zojer et al. §4: traces are recorded on machines
    /// of a different size than the simulated one).
    pub rescale_nodes: Option<usize>,
    /// Fraction of jobs injected as malleable, in `[0, 1]`.
    pub malleable_fraction: f64,
    /// Depth of the shrink chain for injected jobs: minimum size is
    /// `procs / factor^levels`, stopping early where the factor chain
    /// ends (odd sizes shrink only while divisible).
    pub shrink_levels: u32,
    /// Expand/shrink factor for injected jobs (2 in the paper).
    pub factor: usize,
    /// Multiply all inter-arrival gaps (e.g. 0.1 compresses a day-long
    /// trace tenfold).
    pub time_scale: f64,
    /// Outer-loop iterations (reconfiguring points) per replayed job.
    pub iterations: u32,
    /// Replay failed/cancelled/partial jobs too (by default only jobs the
    /// trace marks completed — or with unknown status — are replayed).
    pub include_failed: bool,
}

impl Default for SwfOptions {
    fn default() -> Self {
        SwfOptions {
            max_jobs: None,
            rescale_nodes: None,
            malleable_fraction: 0.0,
            shrink_levels: 2,
            factor: 2,
            time_scale: 1.0,
            iterations: 20,
            include_failed: false,
        }
    }
}

/// Classification of one SWF line by the shared line parser
/// ([`parse_line`]).  Both readers — the batch [`parse`] and the
/// line-streaming [`crate::workload::stream::SwfStream`] — classify
/// through this one function, so the two paths cannot drift.
#[derive(Debug, Clone)]
pub enum SwfLine {
    /// Empty (whitespace-only) line.
    Blank,
    /// `;` header/comment line.
    Comment,
    /// Not parseable as an SWF record (also covers lines truncated
    /// mid-stream).
    Malformed,
    /// Parseable but missing essentials (no positive runtime or
    /// processor count, or a negative submit time).
    Skipped,
    /// A usable record.
    Record(SwfRecord),
}

/// Parse one SWF line.  Shared by the batch and streaming readers.
pub fn parse_line(line: &str) -> SwfLine {
    let t = line.trim();
    if t.is_empty() {
        return SwfLine::Blank;
    }
    if t.starts_with(';') {
        return SwfLine::Comment;
    }
    let fields: Vec<&str> = t.split_whitespace().collect();
    // The format specifies 18 fields; everything we need is in the
    // first 9.
    if fields.len() < 9 {
        return SwfLine::Malformed;
    }
    let num = |i: usize| -> Option<f64> { fields.get(i).and_then(|s| s.parse::<f64>().ok()) };
    let (Some(job_id), Some(submit), Some(run), Some(alloc), Some(req), Some(req_time)) = (
        num(0),
        num(1),
        num(3),
        num(4),
        num(7),
        num(8),
    ) else {
        return SwfLine::Malformed;
    };
    // -1 = unknown: prefer the request, fall back to the measurement
    // (and vice versa for the runtime).
    let procs = if req > 0.0 { req } else { alloc };
    let runtime = if run > 0.0 { run } else { req_time };
    if procs <= 0.0 || runtime <= 0.0 || submit < 0.0 {
        return SwfLine::Skipped;
    }
    // Field 11 (index 10) is the status; field 12 (index 11) the
    // user id; absent/garbage = unknown.
    let status = num(10).map(|s| s as i64).unwrap_or(-1);
    let user = num(11).map(|s| s as i64).unwrap_or(-1);
    SwfLine::Record(SwfRecord {
        job_id: job_id.max(0.0) as u64,
        submit,
        runtime,
        procs: procs as usize,
        status,
        user,
    })
}

/// Parse SWF text.  Records are sorted by submit time; malformed lines are
/// counted, not fatal (real archive traces contain glitches).
pub fn parse(text: &str) -> SwfTrace {
    let mut stats = SwfStats::default();
    let mut records = Vec::new();
    for line in text.lines() {
        stats.lines += 1;
        match parse_line(line) {
            SwfLine::Blank => {}
            SwfLine::Comment => stats.comments += 1,
            SwfLine::Malformed => stats.malformed += 1,
            SwfLine::Skipped => stats.skipped += 1,
            SwfLine::Record(rec) => {
                if !rec.completed() {
                    stats.nonsuccess += 1;
                }
                records.push(rec);
            }
        }
    }
    records.sort_by(|a, b| a.submit.total_cmp(&b.submit).then(a.job_id.cmp(&b.job_id)));
    let max_procs = records.iter().map(|r| r.procs).max().unwrap_or(0);
    SwfTrace { records, stats, max_procs }
}

/// Parse a trace file from disk.
pub fn load(path: &str) -> std::io::Result<SwfTrace> {
    let trace = parse(&std::fs::read_to_string(path)?);
    if trace.stats.malformed > 0 || trace.stats.skipped > 0 {
        crate::obs::log::info(&format!(
            "SWF trace {path}: {} usable records ({} malformed, {} skipped)",
            trace.records.len(),
            trace.stats.malformed,
            trace.stats.skipped
        ));
    }
    Ok(trace)
}

/// Materialize one usable record into a [`JobSpec`] under `opts` — the
/// single place the record→job arithmetic lives, shared by
/// [`to_workload`] and the streaming reader
/// ([`crate::workload::stream::SwfStream`]) so the two paths are
/// bit-identical.  `scale` is the node-rescaling factor (1.0 = none),
/// `t0` the trace start shift; the malleability draw consumes exactly
/// one `rng.f64()` per call, in record order.
pub(crate) fn materialize_record(
    rec: &SwfRecord,
    opts: &SwfOptions,
    scale: f64,
    t0: f64,
    rng: &mut Rng,
) -> JobSpec {
    let fs = crate::apps::config::config_for(AppKind::FlexibleSleep);
    let procs = ((rec.procs as f64 * scale).round() as usize).max(1);
    let malleable = rng.f64() < opts.malleable_fraction;
    // Shrink-only malleability: submitted at the maximum (the paper's
    // "user-preferred scenario of a fast execution"), minimum a few
    // factor steps below.
    let mut min_procs = procs;
    if malleable {
        let f = opts.factor.max(2);
        for _ in 0..opts.shrink_levels {
            // Stay on the factor chain: a 6-proc job stops at 3, not
            // 1 (1 is unreachable by factor-2 resizes from 6).
            if min_procs % f == 0 && min_procs / f >= 1 {
                min_procs /= f;
            } else {
                break;
            }
        }
    }
    let iterations = opts.iterations.max(1);
    // exec_time_at(p) = iterations * work_per_iter * work_scale / p
    // (alpha = 1) == runtime at p = procs.
    let work_scale = rec.runtime * procs as f64 / (iterations as f64 * fs.work_per_iter);
    JobSpec {
        name: format!("swf-{:05}", rec.job_id),
        app: AppKind::FlexibleSleep,
        iterations,
        work_scale,
        procs,
        min_procs,
        max_procs: procs,
        pref_procs: if malleable { Some(min_procs) } else { None },
        factor: opts.factor,
        sched_period: 15.0,
        alpha: 1.0,
        malleable,
        submit_time: (rec.submit - t0) * opts.time_scale,
        // Real traces carry real user ids; unknown maps to user 0.
        user: rec.user.max(0) as u32,
        deadline: None,
    }
}

/// Materialize a trace into a [`WorkloadSpec`] under `opts`.
///
/// Every job is modeled as a perfectly divisible workload
/// ([`AppKind::FlexibleSleep`], alpha = 1): `work_scale` is chosen so the
/// modeled execution time at the submitted size equals the trace runtime.
/// `seed` drives only the malleability injection, so the same trace +
/// seed always yields the same workload (bit-identical campaign reruns).
pub fn to_workload(trace: &SwfTrace, opts: &SwfOptions, seed: u64) -> WorkloadSpec {
    let mut rng = Rng::new(seed);
    let scale = match opts.rescale_nodes {
        Some(n) if trace.max_procs > 0 => n as f64 / trace.max_procs as f64,
        _ => 1.0,
    };
    // Jobs the trace marks as never having completed are skipped unless
    // asked for (their recorded runtime says nothing about real demand).
    let usable: Vec<&SwfRecord> = trace
        .records
        .iter()
        .filter(|r| opts.include_failed || r.completed())
        .collect();
    let t0 = usable.first().map(|r| r.submit).unwrap_or(0.0);
    let n = opts.max_jobs.unwrap_or(usable.len()).min(usable.len());
    let mut jobs = Vec::with_capacity(n);
    for rec in &usable[..n] {
        jobs.push(materialize_record(rec, opts, scale, t0, &mut rng));
    }
    WorkloadSpec { jobs, seed }
}

// 18-field records; job 3 has -1 run time (falls back to requested
// time), job 4 has -1 requested procs (falls back to allocated).
// Shared with the streaming-reader tests so both readers run against
// one assertion set.
#[cfg(test)]
pub(crate) const FIXTURE: &str = "\
; UnixStartTime: 0
; MaxNodes: 64
;  a second comment line
1 0 5 100 16 -1 -1 16 120 -1 1 1 1 1 1 -1 -1 -1
2 30 2 200 8 -1 -1 8 240 -1 1 2 1 1 1 -1 -1 -1
3 60 9 -1 32 -1 -1 32 300 -1 0 3 1 2 1 -1 -1 -1
4 90 1 150 4 -1 -1 -1 160 -1 1 4 1 2 1 -1 -1 -1
garbage line that is not swf
5 120 3 -1 -1 -1 -1 -1 -1 -1 5 5 1 3 1 -1 -1 -1
6 150 4 80 64 -1 -1 64 90 -1 1 6 1 3 1 -1 -1 -1
";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_comments_malformed_and_unknown_fields() {
        let t = parse(FIXTURE);
        assert_eq!(t.stats.lines, 10);
        assert_eq!(t.stats.comments, 3);
        assert_eq!(t.stats.malformed, 1, "the garbage line");
        assert_eq!(t.stats.skipped, 1, "job 5: no runtime, no procs");
        assert_eq!(t.stats.nonsuccess, 1, "job 3 is marked failed");
        assert_eq!(t.records.len(), 5);
        assert_eq!(t.max_procs, 64);
        // -1 run time -> requested time; failed records stay in the trace
        let j3 = t.records.iter().find(|r| r.job_id == 3).unwrap();
        assert_eq!(j3.runtime, 300.0);
        assert_eq!(j3.status, 0);
        assert!(!j3.completed());
        // -1 requested procs -> allocated
        let j4 = t.records.iter().find(|r| r.job_id == 4).unwrap();
        assert_eq!(j4.procs, 4);
        assert!(j4.completed());
        // field 12 is the user id (job 4's line carries user 4)
        assert_eq!(j4.user, 4);
        assert_eq!(t.records.iter().find(|r| r.job_id == 2).unwrap().user, 2);
        let w = to_workload(&t, &SwfOptions::default(), 1);
        assert_eq!(w.jobs.iter().find(|j| j.name == "swf-00002").unwrap().user, 2);
    }

    #[test]
    fn workload_matches_trace_runtimes() {
        let t = parse(FIXTURE);
        let w = to_workload(&t, &SwfOptions::default(), 1);
        // job 3 is marked failed (status 0) and skipped by default
        assert_eq!(w.len(), 4);
        assert!(!w.jobs.iter().any(|j| j.name == "swf-00003"));
        // arrivals shifted to start at 0 and stay sorted
        assert_eq!(w.jobs[0].submit_time, 0.0);
        for p in w.jobs.windows(2) {
            assert!(p[1].submit_time >= p[0].submit_time);
        }
        // modeled exec time at the submitted size == trace runtime
        let j1 = w.jobs.iter().find(|j| j.name == "swf-00001").unwrap();
        assert!((j1.exec_time_at(j1.procs) - 100.0).abs() < 1e-9, "{}", j1.exec_time_at(j1.procs));
        assert_eq!(j1.procs, 16);
        // rigid by default
        assert!(w.jobs.iter().all(|j| !j.malleable));
        assert!(w.jobs.iter().all(|j| j.min_procs == j.procs));
    }

    #[test]
    fn include_failed_restores_noncompleted_jobs() {
        let t = parse(FIXTURE);
        let with = to_workload(
            &t,
            &SwfOptions { include_failed: true, ..Default::default() },
            1,
        );
        assert_eq!(with.len(), 5);
        assert!(with.jobs.iter().any(|j| j.name == "swf-00003"));
        // max_jobs caps *usable* records: with job 3 filtered the cap
        // reaches one record further into the trace
        let capped = to_workload(
            &t,
            &SwfOptions { max_jobs: Some(3), ..Default::default() },
            1,
        );
        let names: Vec<&str> = capped.jobs.iter().map(|j| j.name.as_str()).collect();
        assert_eq!(names, vec!["swf-00001", "swf-00002", "swf-00004"]);
    }

    #[test]
    fn rescale_max_jobs_and_time_scale() {
        let t = parse(FIXTURE);
        let opts = SwfOptions {
            rescale_nodes: Some(32),
            max_jobs: Some(3),
            time_scale: 0.5,
            ..Default::default()
        };
        let w = to_workload(&t, &opts, 1);
        assert_eq!(w.len(), 3);
        // 64-proc trace onto 32 nodes: every size halves
        let j1 = &w.jobs[0];
        assert_eq!(j1.procs, 8);
        // runtime preserved at the rescaled size
        assert!((j1.exec_time_at(8) - 100.0).abs() < 1e-9);
        // arrivals compressed: job 2 arrived 30 s in -> 15 s
        assert!((w.jobs[1].submit_time - 15.0).abs() < 1e-9);
    }

    #[test]
    fn malleable_injection_is_deterministic_and_fractional() {
        let t = parse(FIXTURE);
        let opts = SwfOptions { malleable_fraction: 1.0, ..Default::default() };
        let w = to_workload(&t, &opts, 7);
        assert!(w.jobs.iter().all(|j| j.malleable));
        // factor-chain minimum two levels below the submitted size
        let j1 = w.jobs.iter().find(|j| j.name == "swf-00001").unwrap();
        assert_eq!((j1.min_procs, j1.max_procs), (4, 16));
        assert_eq!(j1.pref_procs, Some(4));

        // same seed -> identical injection; different seed may differ,
        // fraction 0 -> none
        let opts_half = SwfOptions { malleable_fraction: 0.5, ..Default::default() };
        let a = to_workload(&t, &opts_half, 3);
        let b = to_workload(&t, &opts_half, 3);
        let flags = |w: &WorkloadSpec| w.jobs.iter().map(|j| j.malleable).collect::<Vec<_>>();
        assert_eq!(flags(&a), flags(&b));
        let none = to_workload(&t, &SwfOptions { malleable_fraction: 0.0, ..Default::default() }, 3);
        assert!(none.jobs.iter().all(|j| !j.malleable));
    }

    #[test]
    fn tiny_procs_never_shrink_below_one() {
        let trace = SwfTrace {
            records: vec![SwfRecord {
                job_id: 1,
                submit: 0.0,
                runtime: 50.0,
                procs: 1,
                status: 1,
                user: -1,
            }],
            stats: SwfStats::default(),
            max_procs: 1,
        };
        let opts = SwfOptions { malleable_fraction: 1.0, shrink_levels: 3, ..Default::default() };
        let w = to_workload(&trace, &opts, 1);
        assert_eq!(w.jobs[0].min_procs, 1);
        assert_eq!(w.jobs[0].max_procs, 1);
    }

    #[test]
    fn injected_minimum_stays_on_factor_chain() {
        // 6 procs, factor 2: the chain from 6 is {6, 3}; the minimum must
        // stop at 3 even with shrink_levels = 2.
        let trace = SwfTrace {
            records: vec![SwfRecord {
                job_id: 1,
                submit: 0.0,
                runtime: 50.0,
                procs: 6,
                status: 1,
                user: -1,
            }],
            stats: SwfStats::default(),
            max_procs: 6,
        };
        let opts = SwfOptions { malleable_fraction: 1.0, shrink_levels: 2, ..Default::default() };
        let w = to_workload(&trace, &opts, 1);
        let j = &w.jobs[0];
        assert_eq!(j.min_procs, 3);
        assert_eq!(j.clamp_procs(j.min_procs), 3, "minimum is factor-reachable");
    }
}
