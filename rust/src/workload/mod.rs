//! Workload generation: the Feitelson statistical model (§7.1) materialized
//! into the job streams the evaluation processes (50–400 jobs, fixed and
//! flexible versions of the same stream), plus the campaign engine's two
//! extra sources — real traces in Standard Workload Format ([`swf`]) and
//! the synthetic burst–lull arrival pattern
//! ([`generate_burst_lull`]).

pub mod feitelson;
mod spec;
pub mod stream;
pub mod swf;

pub use feitelson::{sample, FeitelsonParams, SampledJob};
pub use spec::{fit_spec, JobSpec, WorkloadSpec};
pub use stream::{
    Adapted, BurstLullStream, FeitelsonStream, JobStream, Materialized, SwfStream,
};

use crate::apps::config::AppKind;

/// Generate the paper's throughput-evaluation workload: `jobs` jobs,
/// Poisson arrivals with 10 s mean gap, uniform CG/Jacobi/N-body mix,
/// submitted at each app's maximum size, malleable.
///
/// `WorkloadSpec::as_fixed()` derives the rigid baseline from the same
/// stream.
pub fn generate(jobs: usize, seed: u64) -> WorkloadSpec {
    let params = FeitelsonParams { jobs, ..Default::default() };
    generate_with(&params, seed)
}

/// Generate with explicit model parameters.  Implemented as the collect
/// of [`FeitelsonStream`], so a streamed generator run and a
/// materialized one process bit-identical jobs by construction.
pub fn generate_with(params: &FeitelsonParams, seed: u64) -> WorkloadSpec {
    let jobs = FeitelsonStream::new(params.clone(), seed)
        .collect_all()
        .expect("generator streams cannot fail");
    WorkloadSpec { jobs, seed }
}

/// Parameters of the burst–lull arrival pattern: bursts of `burst` jobs
/// with short exponential gaps (`burst_gap` mean), separated by `lull`
/// seconds of silence.  Bursty arrivals are where malleability pays —
/// shrink under the burst's queue pressure, expand during the lull — so
/// campaigns sweep this against the smoother Poisson stream.
#[derive(Debug, Clone)]
pub struct BurstLullParams {
    pub jobs: usize,
    /// Jobs per burst.
    pub burst: usize,
    /// Mean gap between jobs inside a burst (seconds).
    pub burst_gap: f64,
    /// Silence between bursts (seconds).
    pub lull: f64,
    /// Log-uniform work-scale half-width (as in [`FeitelsonParams`]).
    pub work_spread: f64,
    /// Applications to draw from.
    pub apps: Vec<AppKind>,
    /// Simulated user population (round-robin by submission index, as in
    /// [`FeitelsonParams::users`]).
    pub users: usize,
}

impl Default for BurstLullParams {
    fn default() -> Self {
        Self {
            jobs: 50,
            burst: 8,
            burst_gap: 2.0,
            lull: 300.0,
            work_spread: 0.25,
            apps: AppKind::WORKLOAD_APPS.to_vec(),
            users: 4,
        }
    }
}

/// Generate a burst–lull workload.  Deterministic for a given seed; the
/// job mix and naming follow [`generate_with`].  Implemented as the
/// collect of [`BurstLullStream`] (streamed ≡ materialized by
/// construction).
pub fn generate_burst_lull(params: &BurstLullParams, seed: u64) -> WorkloadSpec {
    let jobs = BurstLullStream::new(params.clone(), seed)
        .collect_all()
        .expect("generator streams cannot fail");
    WorkloadSpec { jobs, seed }
}

/// A Flexible-Sleep-only workload (overhead study, §7.3).
pub fn generate_fs(jobs: usize, seed: u64) -> WorkloadSpec {
    let params = FeitelsonParams {
        jobs,
        apps: vec![AppKind::FlexibleSleep],
        ..Default::default()
    };
    generate_with(&params, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_sizes_and_names() {
        let w = generate(50, 42);
        assert_eq!(w.len(), 50);
        // users dealt round-robin over the default population
        assert_eq!(w.jobs[0].user, 0);
        assert_eq!(w.jobs[1].user, 1);
        assert_eq!(w.jobs[4].user, 0);
        let distinct: std::collections::BTreeSet<u32> =
            w.jobs.iter().map(|j| j.user).collect();
        assert_eq!(distinct.len(), 4);
        // names are unique
        let mut names: Vec<&str> = w.jobs.iter().map(|j| j.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 50);
        // arrivals sorted
        for p in w.jobs.windows(2) {
            assert!(p[1].submit_time >= p[0].submit_time);
        }
    }

    #[test]
    fn same_seed_same_workload() {
        let a = generate(100, 7);
        let b = generate(100, 7);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.submit_time, y.submit_time);
        }
        let c = generate(100, 8);
        assert!(a.jobs.iter().zip(&c.jobs).any(|(x, y)| x.name != y.name
            || x.submit_time != y.submit_time));
    }

    #[test]
    fn burst_lull_shape() {
        let p = BurstLullParams { jobs: 24, burst: 8, burst_gap: 1.0, lull: 500.0, ..Default::default() };
        let w = generate_burst_lull(&p, 5);
        assert_eq!(w.len(), 24);
        for pair in w.jobs.windows(2) {
            assert!(pair[1].submit_time >= pair[0].submit_time);
        }
        // gaps at burst boundaries are the lull, gaps inside are small
        let gap = |i: usize| w.jobs[i].submit_time - w.jobs[i - 1].submit_time;
        assert!(gap(8) >= 500.0 && gap(16) >= 500.0);
        let inside: f64 = (1..8).map(gap).sum::<f64>() / 7.0;
        assert!(inside < 50.0, "inside-burst mean gap {inside}");
        // deterministic
        let w2 = generate_burst_lull(&p, 5);
        assert_eq!(w.jobs.len(), w2.jobs.len());
        for (a, b) in w.jobs.iter().zip(&w2.jobs) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.submit_time, b.submit_time);
        }
    }

    #[test]
    fn fs_workload_all_fs() {
        let w = generate_fs(10, 1);
        assert!(w.jobs.iter().all(|j| j.app == AppKind::FlexibleSleep));
        assert!(w.jobs.iter().all(|j| j.procs == 20));
    }
}
