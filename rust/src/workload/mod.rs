//! Workload generation: the Feitelson statistical model (§7.1) materialized
//! into the job streams the evaluation processes (50–400 jobs, fixed and
//! flexible versions of the same stream).

pub mod feitelson;
mod spec;

pub use feitelson::{sample, FeitelsonParams, SampledJob};
pub use spec::{JobSpec, WorkloadSpec};

use crate::apps::config::AppKind;
use crate::util::rng::Rng;

/// Generate the paper's throughput-evaluation workload: `jobs` jobs,
/// Poisson arrivals with 10 s mean gap, uniform CG/Jacobi/N-body mix,
/// submitted at each app's maximum size, malleable.
///
/// `WorkloadSpec::as_fixed()` derives the rigid baseline from the same
/// stream.
pub fn generate(jobs: usize, seed: u64) -> WorkloadSpec {
    let params = FeitelsonParams { jobs, ..Default::default() };
    generate_with(&params, seed)
}

/// Generate with explicit model parameters.
pub fn generate_with(params: &FeitelsonParams, seed: u64) -> WorkloadSpec {
    let mut rng = Rng::new(seed);
    let sampled = sample(params, &mut rng);
    let mut counts = std::collections::HashMap::new();
    let jobs = sampled
        .into_iter()
        .map(|s| {
            let k = counts.entry(s.app).or_insert(0usize);
            let name = format!("{}-{:03}", s.app, *k);
            *k += 1;
            JobSpec::from_app(s.app, name, s.arrival, s.work_scale)
        })
        .collect();
    WorkloadSpec { jobs, seed }
}

/// A Flexible-Sleep-only workload (overhead study, §7.3).
pub fn generate_fs(jobs: usize, seed: u64) -> WorkloadSpec {
    let params = FeitelsonParams {
        jobs,
        apps: vec![AppKind::FlexibleSleep],
        ..Default::default()
    };
    generate_with(&params, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_sizes_and_names() {
        let w = generate(50, 42);
        assert_eq!(w.len(), 50);
        // names are unique
        let mut names: Vec<&str> = w.jobs.iter().map(|j| j.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 50);
        // arrivals sorted
        for p in w.jobs.windows(2) {
            assert!(p[1].submit_time >= p[0].submit_time);
        }
    }

    #[test]
    fn same_seed_same_workload() {
        let a = generate(100, 7);
        let b = generate(100, 7);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.submit_time, y.submit_time);
        }
        let c = generate(100, 8);
        assert!(a.jobs.iter().zip(&c.jobs).any(|(x, y)| x.name != y.name
            || x.submit_time != y.submit_time));
    }

    #[test]
    fn fs_workload_all_fs() {
        let w = generate_fs(10, 1);
        assert!(w.jobs.iter().all(|j| j.app == AppKind::FlexibleSleep));
        assert!(w.jobs.iter().all(|j| j.procs == 20));
    }
}
