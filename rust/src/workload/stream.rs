//! Pull-based job streams: the workload side of the streaming memory
//! model.
//!
//! A [`JobStream`] yields [`JobSpec`]s one at a time, ordered by submit
//! time, so the engine can pull arrivals lazily into its event heap
//! (a small look-ahead window) instead of materializing the whole
//! workload up front.  Three sources implement it:
//!
//! * [`Materialized`] — wraps an already-built `Vec<JobSpec>`
//!   (the compatibility path; `Engine::run` delegates through it, kept
//!   bit-identical with the historical batch behavior).
//! * [`FeitelsonStream`] / [`BurstLullStream`] — on-demand generator
//!   adapters.  The batch generators ([`crate::workload::generate_with`],
//!   [`crate::workload::generate_burst_lull`]) are implemented as
//!   `collect_all()` of these streams, so streamed and materialized
//!   generator workloads are equal by construction.
//! * [`SwfStream`] — a line-streaming SWF reader that never holds the
//!   file (or the record vector) in memory; it shares its line parser
//!   and record materializer with the batch reader
//!   ([`crate::workload::swf`]), so the two paths emit bit-identical
//!   jobs for submit-sorted traces.
//!
//! [`Adapted`] layers the campaign runner's per-job transforms (cluster
//! fitting → deadline decoration → rigid baseline, in exactly that
//! order) over any source.

use std::collections::HashMap;
use std::io::BufRead;

use anyhow::{bail, Context, Result};

use crate::apps::config::AppKind;
use crate::util::rng::Rng;
use crate::workload::swf::{parse_line, SwfLine, SwfOptions, SwfStats};
use crate::workload::{fit_spec, BurstLullParams, FeitelsonParams, JobSpec, WorkloadSpec};

/// A pull-based, submit-ordered source of job specifications.
///
/// Contract: successive `Ok(Some(job))` results have non-decreasing
/// `submit_time` (the engine's look-ahead window depends on it; sources
/// either generate in order or — like [`SwfStream`] — error on
/// violations), and after the first `Ok(None)` every further call also
/// returns `Ok(None)`.
pub trait JobStream {
    /// The next job in submit order; `Ok(None)` when exhausted.
    fn next_job(&mut self) -> Result<Option<JobSpec>>;

    /// Drain the rest of the stream into a vector (the batch
    /// compatibility path and tests; defeats the purpose of streaming
    /// for million-job sources).
    fn collect_all(&mut self) -> Result<Vec<JobSpec>> {
        let mut out = Vec::new();
        while let Some(j) = self.next_job()? {
            out.push(j);
        }
        Ok(out)
    }
}

/// Compatibility adapter: a [`JobStream`] over an in-memory job vector.
/// `Engine::run` wraps every [`WorkloadSpec`] in one of these, so the
/// historical batch API is the streamed engine with an infinite
/// look-ahead window.
pub struct Materialized {
    iter: std::vec::IntoIter<JobSpec>,
}

impl Materialized {
    /// Stream an owned workload.
    pub fn new(w: WorkloadSpec) -> Self {
        Self::from_jobs(w.jobs)
    }

    /// Stream an owned job vector (must be submit-sorted, as every
    /// workload source guarantees).
    pub fn from_jobs(jobs: Vec<JobSpec>) -> Self {
        Materialized { iter: jobs.into_iter() }
    }
}

impl From<&WorkloadSpec> for Materialized {
    fn from(w: &WorkloadSpec) -> Self {
        Self::from_jobs(w.jobs.clone())
    }
}

impl JobStream for Materialized {
    fn next_job(&mut self) -> Result<Option<JobSpec>> {
        Ok(self.iter.next())
    }
}

/// Deals per-app sequence names (`CG-017`) exactly like the batch
/// generators' `HashMap` counters.
#[derive(Default)]
struct Namer {
    counts: HashMap<AppKind, usize>,
}

impl Namer {
    fn name(&mut self, app: AppKind) -> String {
        let k = self.counts.entry(app).or_insert(0);
        let name = format!("{}-{:03}", app, *k);
        *k += 1;
        name
    }
}

/// On-demand Feitelson-model generator (§7.1): each pull draws one
/// job's arrival gap, application, and work scale — the same RNG
/// sequence as the batch [`crate::workload::feitelson::sample`], which
/// draws per job in the same order, so collecting this stream equals
/// the batch generator bit for bit.
pub struct FeitelsonStream {
    params: FeitelsonParams,
    rng: Rng,
    t: f64,
    i: usize,
    namer: Namer,
}

impl FeitelsonStream {
    /// A stream of `params.jobs` jobs, deterministic for a given seed.
    pub fn new(params: FeitelsonParams, seed: u64) -> Self {
        FeitelsonStream { params, rng: Rng::new(seed), t: 0.0, i: 0, namer: Namer::default() }
    }
}

impl JobStream for FeitelsonStream {
    fn next_job(&mut self) -> Result<Option<JobSpec>> {
        if self.i >= self.params.jobs {
            return Ok(None);
        }
        self.t += self.rng.exp(self.params.mean_interarrival);
        let app = *self.rng.choice(&self.params.apps);
        // log-uniform in [e^-spread, e^+spread]
        let u = self.rng.f64() * 2.0 - 1.0;
        let work_scale = (u * self.params.work_spread).exp();
        let name = self.namer.name(app);
        let mut spec = JobSpec::from_app(app, name, self.t, work_scale);
        // Round-robin by submission index: deterministic and free of
        // RNG draws, so the sampled stream is unchanged.
        spec.user = (self.i % self.params.users.max(1)) as u32;
        self.i += 1;
        Ok(Some(spec))
    }
}

/// On-demand burst–lull generator: the streaming form of
/// [`crate::workload::generate_burst_lull`] (which collects this
/// stream).
pub struct BurstLullStream {
    params: BurstLullParams,
    rng: Rng,
    t: f64,
    i: usize,
    namer: Namer,
}

impl BurstLullStream {
    /// A stream of `params.jobs` jobs, deterministic for a given seed.
    pub fn new(params: BurstLullParams, seed: u64) -> Self {
        BurstLullStream { params, rng: Rng::new(seed), t: 0.0, i: 0, namer: Namer::default() }
    }
}

impl JobStream for BurstLullStream {
    fn next_job(&mut self) -> Result<Option<JobSpec>> {
        if self.i >= self.params.jobs {
            return Ok(None);
        }
        let burst = self.params.burst.max(1);
        if self.i > 0 {
            self.t += if self.i % burst == 0 {
                self.params.lull
            } else {
                self.rng.exp(self.params.burst_gap)
            };
        }
        let app = *self.rng.choice(&self.params.apps);
        let u = self.rng.f64() * 2.0 - 1.0;
        let work_scale = (u * self.params.work_spread).exp();
        let name = self.namer.name(app);
        let mut spec = JobSpec::from_app(app, name, self.t, work_scale);
        spec.user = (self.i % self.params.users.max(1)) as u32;
        self.i += 1;
        Ok(Some(spec))
    }
}

/// Line-streaming SWF reader: parses one line at a time from any
/// [`BufRead`] and materializes usable records on demand — the file is
/// never resident, and neither is a record vector.
///
/// Differences from the batch path ([`crate::workload::swf::parse`] +
/// [`crate::workload::swf::to_workload`]), both deliberate:
///
/// * The batch reader *sorts* records by submit time; a stream cannot.
///   Records must arrive submit-sorted (real archive traces are) — an
///   out-of-order submit is a deterministic error, not a panic.
/// * Parse statistics ([`SwfStream::stats`]) only cover lines read so
///   far: with `max_jobs` set the tail of the file is never read.
///
/// For submit-sorted input the emitted jobs are bit-identical with the
/// batch path: both share [`parse_line`] and the record materializer,
/// and both draw exactly one `rng.f64()` per emitted-eligible record in
/// file order.
pub struct SwfStream {
    lines: std::io::Lines<Box<dyn BufRead>>,
    opts: SwfOptions,
    rng: Rng,
    stats: SwfStats,
    /// Node-rescaling factor (1.0 = none) — scanned in a first pass by
    /// [`SwfStream::open`] when `rescale_nodes` is set.
    scale: f64,
    /// First usable record's submit time (the trace start shift).
    t0: Option<f64>,
    last_submit: f64,
    line_no: usize,
    emitted: usize,
}

impl SwfStream {
    /// Stream a trace file from disk.  When `opts.rescale_nodes` is set
    /// this makes a first line-streaming pass over the file to find the
    /// largest processor request (the rescaling baseline, exactly the
    /// batch reader's `max_procs`) — still constant-memory — then
    /// reopens for the emit pass.
    pub fn open(path: &str, opts: SwfOptions, seed: u64) -> Result<SwfStream> {
        let max_procs = if opts.rescale_nodes.is_some() {
            let f = std::fs::File::open(path)
                .with_context(|| format!("SWF trace {path}: open for rescale scan"))?;
            Some(scan_max_procs(Box::new(std::io::BufReader::new(f)))?)
        } else {
            None
        };
        let f = std::fs::File::open(path).with_context(|| format!("SWF trace {path}: open"))?;
        Self::from_reader(Box::new(std::io::BufReader::new(f)), opts, seed, max_procs)
    }

    /// Stream from any reader.  `max_procs` is the trace-wide largest
    /// processor request and is required when `opts.rescale_nodes` is
    /// set (a plain reader cannot be rewound for the scan pass; use
    /// [`SwfStream::open`] for files, or [`scan_max_procs`] on a copy).
    pub fn from_reader(
        reader: Box<dyn BufRead>,
        opts: SwfOptions,
        seed: u64,
        max_procs: Option<usize>,
    ) -> Result<SwfStream> {
        let scale = match (opts.rescale_nodes, max_procs) {
            (Some(n), Some(max)) if max > 0 => n as f64 / max as f64,
            (Some(_), None) => {
                bail!("SWF stream: rescale_nodes needs the trace's max_procs (use SwfStream::open)")
            }
            _ => 1.0,
        };
        Ok(SwfStream {
            lines: reader.lines(),
            opts,
            rng: Rng::new(seed),
            stats: SwfStats::default(),
            scale,
            t0: None,
            last_submit: f64::NEG_INFINITY,
            line_no: 0,
            emitted: 0,
        })
    }

    /// Parse statistics over the lines read so far (final once the
    /// stream returns `Ok(None)` — except that `max_jobs` stops reading
    /// early, leaving the tail uncounted).
    pub fn stats(&self) -> &SwfStats {
        &self.stats
    }
}

impl JobStream for SwfStream {
    fn next_job(&mut self) -> Result<Option<JobSpec>> {
        if self.opts.max_jobs.is_some_and(|n| self.emitted >= n) {
            return Ok(None);
        }
        for line in self.lines.by_ref() {
            self.line_no += 1;
            let line = line.with_context(|| format!("SWF stream: read line {}", self.line_no))?;
            self.stats.lines += 1;
            match parse_line(&line) {
                SwfLine::Blank => {}
                SwfLine::Comment => self.stats.comments += 1,
                SwfLine::Malformed => self.stats.malformed += 1,
                SwfLine::Skipped => self.stats.skipped += 1,
                SwfLine::Record(rec) => {
                    if !rec.completed() {
                        self.stats.nonsuccess += 1;
                    }
                    // The batch reader sorts; a stream must insist.
                    if rec.submit < self.last_submit {
                        bail!(
                            "SWF stream: out-of-order submit at line {} (job {}): {} < {}",
                            self.line_no,
                            rec.job_id,
                            rec.submit,
                            self.last_submit
                        );
                    }
                    self.last_submit = rec.submit;
                    if !(self.opts.include_failed || rec.completed()) {
                        continue;
                    }
                    let t0 = *self.t0.get_or_insert(rec.submit);
                    let job = crate::workload::swf::materialize_record(
                        &rec,
                        &self.opts,
                        self.scale,
                        t0,
                        &mut self.rng,
                    );
                    self.emitted += 1;
                    return Ok(Some(job));
                }
            }
        }
        Ok(None)
    }
}

/// One line-streaming pass over a trace, returning the largest
/// processor request (the batch reader's `max_procs`; the node-rescaling
/// baseline).  Constant memory.
pub fn scan_max_procs(reader: Box<dyn BufRead>) -> Result<usize> {
    let mut max = 0usize;
    for (i, line) in reader.lines().enumerate() {
        let line = line.with_context(|| format!("SWF rescale scan: read line {}", i + 1))?;
        if let SwfLine::Record(rec) = parse_line(&line) {
            max = max.max(rec.procs);
        }
    }
    Ok(max)
}

/// Per-job transform pipeline over any source, mirroring the campaign
/// runner's materialized path in order: cluster fitting
/// ([`fit_spec`]) → deadline decoration
/// ([`WorkloadSpec::with_deadlines`] semantics) → rigid baseline
/// ([`WorkloadSpec::as_fixed`] semantics).  Each job is transformed
/// exactly as the batch path would, so streamed campaign runs stay
/// bit-identical.
pub struct Adapted<S> {
    inner: S,
    fit_nodes: Option<usize>,
    deadline_slack: Option<f64>,
    fixed: bool,
}

impl<S: JobStream> Adapted<S> {
    /// Identity adapter over `inner`; add transforms with the builder
    /// methods.
    pub fn new(inner: S) -> Self {
        Adapted { inner, fit_nodes: None, deadline_slack: None, fixed: false }
    }

    /// Clamp every job's size bounds onto a `nodes`-node pool.
    pub fn fit(mut self, nodes: usize) -> Self {
        self.fit_nodes = Some(nodes);
        self
    }

    /// Give every job a soft deadline of `submit + slack × est_duration`
    /// (computed after fitting, like the batch path).
    pub fn deadlines(mut self, slack: f64) -> Self {
        self.deadline_slack = Some(slack);
        self
    }

    /// Force every job rigid (the paper's fixed baseline).
    pub fn fixed(mut self, fixed: bool) -> Self {
        self.fixed = fixed;
        self
    }
}

impl<S: JobStream> JobStream for Adapted<S> {
    fn next_job(&mut self) -> Result<Option<JobSpec>> {
        let Some(mut j) = self.inner.next_job()? else {
            return Ok(None);
        };
        if let Some(n) = self.fit_nodes {
            fit_spec(&mut j, n);
        }
        if let Some(slack) = self.deadline_slack {
            j.deadline = Some(j.submit_time + slack * j.est_duration());
        }
        if self.fixed {
            j.malleable = false;
        }
        Ok(Some(j))
    }
}

/// Boxed streams forward (`Box<dyn JobStream>` composes with
/// [`Adapted`] and the engines' `&mut dyn JobStream` entry points).
impl<'a> JobStream for Box<dyn JobStream + 'a> {
    fn next_job(&mut self) -> Result<Option<JobSpec>> {
        (**self).next_job()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::swf::{self, FIXTURE};
    use crate::workload::{feitelson, generate_burst_lull, generate_with};

    #[test]
    fn feitelson_stream_matches_batch_sample() {
        // The stream must draw the exact RNG sequence of the batch
        // sampler (drift tripwire: both draw gap → app → scale per job).
        let p = FeitelsonParams { jobs: 40, ..Default::default() };
        let sampled = feitelson::sample(&p, &mut Rng::new(11));
        let jobs = FeitelsonStream::new(p.clone(), 11).collect_all().unwrap();
        assert_eq!(jobs.len(), sampled.len());
        for (j, s) in jobs.iter().zip(&sampled) {
            assert_eq!(j.app, s.app);
            assert_eq!(j.submit_time.to_bits(), s.arrival.to_bits());
            assert_eq!(j.work_scale.to_bits(), s.work_scale.to_bits());
        }
        // And the batch generator (collect of this stream) agrees with
        // naming/users too.
        let w = generate_with(&p, 11);
        for (a, b) in jobs.iter().zip(&w.jobs) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.user, b.user);
        }
    }

    #[test]
    fn burst_lull_stream_matches_batch() {
        let p = BurstLullParams { jobs: 24, burst: 8, ..Default::default() };
        let w = generate_burst_lull(&p, 5);
        // Replicate the historical batch draw order inline as a drift
        // tripwire (gap → app → scale per job).
        let mut rng = Rng::new(5);
        let mut t = 0.0;
        for (i, j) in w.jobs.iter().enumerate() {
            if i > 0 {
                t += if i % 8 == 0 { p.lull } else { rng.exp(p.burst_gap) };
            }
            let app = *rng.choice(&p.apps);
            let u = rng.f64() * 2.0 - 1.0;
            let work_scale = (u * p.work_spread).exp();
            assert_eq!(j.app, app, "job {i}");
            assert_eq!(j.submit_time.to_bits(), t.to_bits(), "job {i}");
            assert_eq!(j.work_scale.to_bits(), work_scale.to_bits(), "job {i}");
            assert_eq!(j.user, (i % p.users) as u32, "job {i}");
        }
    }

    fn cursor(text: &str) -> Box<dyn BufRead> {
        Box::new(std::io::Cursor::new(text.to_string()))
    }

    /// Both SWF readers over the same text + options must emit
    /// bit-identical jobs — the shared assertion set of the reader
    /// tests.
    fn assert_swf_stream_matches_batch(text: &str, opts: &SwfOptions, seed: u64) {
        let trace = swf::parse(text);
        let batch = swf::to_workload(&trace, opts, seed);
        let max_procs = scan_max_procs(cursor(text)).unwrap();
        let mut stream =
            SwfStream::from_reader(cursor(text), opts.clone(), seed, Some(max_procs)).unwrap();
        let streamed = stream.collect_all().unwrap();
        assert_eq!(streamed.len(), batch.jobs.len());
        for (s, b) in streamed.iter().zip(&batch.jobs) {
            assert_eq!(s.name, b.name);
            assert_eq!(s.app, b.app);
            assert_eq!(s.iterations, b.iterations);
            assert_eq!(s.work_scale.to_bits(), b.work_scale.to_bits(), "{}", s.name);
            assert_eq!(
                (s.procs, s.min_procs, s.max_procs, s.pref_procs, s.factor),
                (b.procs, b.min_procs, b.max_procs, b.pref_procs, b.factor),
                "{}",
                s.name
            );
            assert_eq!(s.submit_time.to_bits(), b.submit_time.to_bits(), "{}", s.name);
            assert_eq!(s.malleable, b.malleable);
            assert_eq!(s.user, b.user);
        }
    }

    #[test]
    fn swf_stream_matches_batch_across_options() {
        assert_swf_stream_matches_batch(FIXTURE, &SwfOptions::default(), 1);
        assert_swf_stream_matches_batch(
            FIXTURE,
            &SwfOptions { include_failed: true, ..Default::default() },
            1,
        );
        assert_swf_stream_matches_batch(
            FIXTURE,
            &SwfOptions { max_jobs: Some(3), ..Default::default() },
            1,
        );
        assert_swf_stream_matches_batch(
            FIXTURE,
            &SwfOptions {
                rescale_nodes: Some(32),
                max_jobs: Some(3),
                time_scale: 0.5,
                ..Default::default()
            },
            1,
        );
        assert_swf_stream_matches_batch(
            FIXTURE,
            &SwfOptions { malleable_fraction: 1.0, ..Default::default() },
            7,
        );
        assert_swf_stream_matches_batch(
            FIXTURE,
            &SwfOptions { malleable_fraction: 0.5, ..Default::default() },
            3,
        );
    }

    #[test]
    fn swf_stream_handles_crlf_comments_and_truncation() {
        // CRLF line endings, interleaved comments, and a final line
        // truncated mid-record: counted, never fatal.
        let text = "; header\r\n\
                    1 0 5 100 16 -1 -1 16 120 -1 1 1 1 1 1 -1 -1 -1\r\n\
                    ; interleaved comment\r\n\
                    2 30 2 200 8 -1 -1 8 240 -1 1 2 1 1 1 -1 -1 -1\r\n\
                    3 60 9 150";
        let mut s = SwfStream::from_reader(cursor(text), SwfOptions::default(), 1, None).unwrap();
        let jobs = s.collect_all().unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].name, "swf-00001");
        assert_eq!(jobs[1].name, "swf-00002");
        assert_eq!(s.stats().comments, 2);
        assert_eq!(s.stats().malformed, 1, "the truncated tail line");
        // and the batch reader agrees on the emitted jobs
        assert_swf_stream_matches_batch(text, &SwfOptions::default(), 1);
    }

    #[test]
    fn swf_stream_errors_deterministically_on_out_of_order_submits() {
        let text = "1 50 5 100 16 -1 -1 16 120 -1 1 1 1 1 1 -1 -1 -1\n\
                    2 20 2 200 8 -1 -1 8 240 -1 1 2 1 1 1 -1 -1 -1\n";
        for _ in 0..2 {
            let mut s =
                SwfStream::from_reader(cursor(text), SwfOptions::default(), 1, None).unwrap();
            assert!(s.next_job().unwrap().is_some(), "first record is fine");
            let err = s.next_job().expect_err("out-of-order must error, not panic");
            let msg = format!("{err}");
            assert_eq!(
                msg, "SWF stream: out-of-order submit at line 2 (job 2): 20 < 50",
                "error must be deterministic"
            );
        }
    }

    #[test]
    fn swf_stream_order_check_covers_filtered_records_too() {
        // The out-of-order record is a failed job (status 0) that the
        // usable filter would drop — ordering is still enforced on it.
        let text = "1 50 5 100 16 -1 -1 16 120 -1 1 1 1 1 1 -1 -1 -1\n\
                    2 20 2 200 8 -1 -1 8 240 -1 0 2 1 1 1 -1 -1 -1\n";
        let mut s = SwfStream::from_reader(cursor(text), SwfOptions::default(), 1, None).unwrap();
        assert!(s.next_job().unwrap().is_some());
        assert!(s.next_job().is_err());
    }

    #[test]
    fn swf_stream_rescale_requires_scan() {
        let opts = SwfOptions { rescale_nodes: Some(32), ..Default::default() };
        let err = SwfStream::from_reader(cursor(FIXTURE), opts, 1, None)
            .err()
            .expect("rescale without max_procs must error");
        assert!(format!("{err}").contains("max_procs"));
    }

    #[test]
    fn swf_open_two_pass_matches_batch_rescale() {
        // Write the fixture to a temp file and use the two-pass open().
        let dir = std::env::temp_dir();
        let path = dir.join("dmr_swf_stream_test.swf");
        std::fs::write(&path, FIXTURE).unwrap();
        let opts = SwfOptions { rescale_nodes: Some(32), ..Default::default() };
        let mut s = SwfStream::open(path.to_str().unwrap(), opts.clone(), 1).unwrap();
        let streamed = s.collect_all().unwrap();
        let batch = swf::to_workload(&swf::parse(FIXTURE), &opts, 1);
        assert_eq!(streamed.len(), batch.jobs.len());
        for (a, b) in streamed.iter().zip(&batch.jobs) {
            assert_eq!(a.procs, b.procs, "{}", a.name);
            assert_eq!(a.submit_time.to_bits(), b.submit_time.to_bits());
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn adapted_matches_batch_transforms() {
        // fit → deadline → fixed, in the campaign runner's order.
        let p = FeitelsonParams { jobs: 12, ..Default::default() };
        let mut batch = generate_with(&p, 3);
        for j in &mut batch.jobs {
            fit_spec(j, 24);
        }
        let batch = batch.with_deadlines(1.5).as_fixed();
        let streamed = Adapted::new(FeitelsonStream::new(p, 3))
            .fit(24)
            .deadlines(1.5)
            .fixed(true)
            .collect_all()
            .unwrap();
        assert_eq!(streamed.len(), batch.jobs.len());
        for (s, b) in streamed.iter().zip(&batch.jobs) {
            assert_eq!(s.name, b.name);
            assert_eq!(
                (s.procs, s.min_procs, s.max_procs, s.pref_procs),
                (b.procs, b.min_procs, b.max_procs, b.pref_procs)
            );
            assert_eq!(s.malleable, b.malleable);
            assert!(!s.malleable);
            assert_eq!(
                s.deadline.unwrap().to_bits(),
                b.deadline.unwrap().to_bits(),
                "{}",
                s.name
            );
        }
    }

    #[test]
    fn materialized_round_trips() {
        let w = generate_with(&FeitelsonParams { jobs: 9, ..Default::default() }, 2);
        let jobs = Materialized::from(&w).collect_all().unwrap();
        assert_eq!(jobs.len(), 9);
        for (a, b) in jobs.iter().zip(&w.jobs) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.submit_time.to_bits(), b.submit_time.to_bits());
        }
        // exhausted stream keeps returning None
        let mut m = Materialized::new(w);
        while m.next_job().unwrap().is_some() {}
        assert!(m.next_job().unwrap().is_none());
    }
}
