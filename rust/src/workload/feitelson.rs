//! Feitelson's statistical workload model (§7.1 of the paper).
//!
//! The paper generates workloads "using the statistical model proposed by
//! Feitelson \[4\], which characterizes rigid jobs based on observations from
//! logs of actual cluster workloads", customizing two parameters: the job
//! count and the inter-arrival times ("Poisson distribution of factor 10").
//!
//! We implement the relevant components of the Feitelson '96 model:
//!
//! * **Arrivals** — a Poisson process: exponential inter-arrival gaps with
//!   the configured mean (10 s in all the paper's workloads).
//! * **Job mix** — jobs instantiate one of the three applications
//!   (CG / Jacobi / N-body), uniformly with a fixed seed, matching §7.5
//!   ("randomly-sorted jobs (with a fixed seed) which instantiate one of
//!   the three non-synthetic applications").
//! * **Runtime variability** — the model's log-uniform runtime component,
//!   applied as a work-scale multiplier around 1.0 so per-app Table 1
//!   calibration is preserved while jobs are not clones of each other.

use crate::apps::config::AppKind;
use crate::util::rng::Rng;

/// Parameters of the workload model.
#[derive(Debug, Clone)]
pub struct FeitelsonParams {
    /// Number of jobs.
    pub jobs: usize,
    /// Mean inter-arrival gap in seconds ("Poisson distribution of factor
    /// 10" — §7.1).
    pub mean_interarrival: f64,
    /// Half-width of the log-uniform work-scale component, in natural-log
    /// units (0 = all jobs exactly Table 1 scale).
    pub work_spread: f64,
    /// Applications to draw from.
    pub apps: Vec<AppKind>,
    /// Simulated user population: jobs are dealt to users round-robin by
    /// submission index (deterministic, consumes no RNG draws, so adding
    /// users never perturbs the sampled stream).  Drives the fair-share
    /// strategy and the per-user fairness metrics; `1` = everything
    /// belongs to one user.
    pub users: usize,
}

impl Default for FeitelsonParams {
    fn default() -> Self {
        Self {
            jobs: 50,
            mean_interarrival: 10.0,
            work_spread: 0.25,
            apps: AppKind::WORKLOAD_APPS.to_vec(),
            users: 4,
        }
    }
}

/// One sampled job (before being materialized into a [`crate::workload::JobSpec`]).
#[derive(Debug, Clone)]
pub struct SampledJob {
    pub app: AppKind,
    pub arrival: f64,
    pub work_scale: f64,
}

/// Sample `params.jobs` jobs.  Deterministic for a given seed.
pub fn sample(params: &FeitelsonParams, rng: &mut Rng) -> Vec<SampledJob> {
    let mut t = 0.0;
    let mut out = Vec::with_capacity(params.jobs);
    for _ in 0..params.jobs {
        t += rng.exp(params.mean_interarrival);
        let app = *rng.choice(&params.apps);
        // log-uniform in [e^-spread, e^+spread]
        let u = rng.f64() * 2.0 - 1.0;
        let work_scale = (u * params.work_spread).exp();
        out.push(SampledJob { app, arrival: t, work_scale });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let p = FeitelsonParams::default();
        let a = sample(&p, &mut Rng::new(99));
        let b = sample(&p, &mut Rng::new(99));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.app, y.app);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn arrivals_monotone_and_poisson_mean() {
        let p = FeitelsonParams { jobs: 5000, ..Default::default() };
        let s = sample(&p, &mut Rng::new(1));
        for w in s.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let mean_gap = s.last().unwrap().arrival / s.len() as f64;
        assert!((mean_gap - 10.0).abs() < 0.6, "mean gap {mean_gap}");
    }

    #[test]
    fn app_mix_roughly_uniform() {
        let p = FeitelsonParams { jobs: 3000, ..Default::default() };
        let s = sample(&p, &mut Rng::new(2));
        for app in AppKind::WORKLOAD_APPS {
            let n = s.iter().filter(|j| j.app == app).count();
            assert!(
                (n as f64 / s.len() as f64 - 1.0 / 3.0).abs() < 0.05,
                "{app}: {n}"
            );
        }
    }

    #[test]
    fn work_scale_bounded() {
        let p = FeitelsonParams { jobs: 1000, work_spread: 0.25, ..Default::default() };
        let s = sample(&p, &mut Rng::new(3));
        for j in &s {
            assert!(j.work_scale >= (-0.25f64).exp() && j.work_scale <= (0.25f64).exp());
        }
    }
}
