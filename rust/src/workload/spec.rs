//! Job and workload specifications.

use crate::apps::config::{config_for, AppKind};
use crate::Time;

/// Everything the RMS needs to know about a job at submission time.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Human-readable name (e.g. `"CG-017"`).
    pub name: String,
    pub app: AppKind,
    /// Outer-loop iterations (reconfiguring points).
    pub iterations: u32,
    /// Work multiplier sampled by the workload model (scales the
    /// per-iteration cost; 1.0 = Table 1 baseline).
    pub work_scale: f64,
    /// Requested (initial) number of processes.  The paper submits every
    /// job with its *maximum* ("the user-preferred scenario of a fast
    /// execution", §7.5).
    pub procs: usize,
    pub min_procs: usize,
    pub max_procs: usize,
    pub pref_procs: Option<usize>,
    /// Expand/shrink factor (2 in all the paper's experiments).
    pub factor: usize,
    /// Checking-inhibitor period (seconds).
    pub sched_period: f64,
    /// Parallel-scaling exponent (see [`crate::apps::config::AppConfig::alpha`]).
    pub alpha: f64,
    /// Whether the job participates in reconfiguration (flexible) or not
    /// (fixed).  The framework is "compatible with unmodified
    /// non-malleable applications" (§2).
    pub malleable: bool,
    /// Arrival (submission) time.
    pub submit_time: Time,
    /// Owning user (0 = the default single user).  Drives the fair-share
    /// policy strategy and the per-user fairness metrics; workload
    /// sources assign it (SWF traces carry real user ids, the synthetic
    /// generators deal users round-robin).
    pub user: u32,
    /// Optional soft deadline (absolute time).  The deadline-aware policy
    /// strategy expands jobs projected to miss it and never shrinks them;
    /// metrics count the misses.  `None` = no deadline.
    pub deadline: Option<Time>,
}

impl JobSpec {
    /// A job instantiating `app` with Table 1 parameters, submitted at its
    /// maximum size.
    pub fn from_app(app: AppKind, name: String, submit_time: Time, work_scale: f64) -> Self {
        let c = config_for(app);
        JobSpec {
            name,
            app,
            iterations: c.iterations,
            work_scale,
            procs: c.max_procs,
            min_procs: c.min_procs,
            max_procs: c.max_procs,
            pref_procs: c.pref_procs,
            factor: c.factor,
            sched_period: c.sched_period,
            alpha: c.alpha,
            malleable: true,
            submit_time,
            user: 0,
            deadline: None,
        }
    }

    /// Node-seconds of work in one iteration.
    pub fn work_per_iter(&self) -> f64 {
        config_for(self.app).work_per_iter * self.work_scale
    }

    /// Modeled execution time at `p` processes (per-app scaling: CG and
    /// Jacobi linear per §7.4; N-body communication-bound).
    pub fn exec_time_at(&self, p: usize) -> f64 {
        self.iterations as f64 * self.work_per_iter() / (p as f64).powf(self.alpha)
    }

    /// Runtime estimate the scheduler uses for backfill reservations.
    pub fn est_duration(&self) -> f64 {
        self.exec_time_at(self.procs)
    }

    /// Valid process counts honour min/max and the resize factor chain
    /// from the initial size: the job can only ever run at
    /// `procs * factor^k` / `procs / factor^k` (§5.1 — resizes move by
    /// powers of the factor), so `p` is first clamped to `[min, max]` and
    /// then rounded to the nearest in-range chain size (ties toward the
    /// smaller size; a resize that cannot reach a chain size keeps the
    /// clamped value, e.g. factor 1 or an empty in-range chain).
    pub fn clamp_procs(&self, p: usize) -> usize {
        let clamped = p.clamp(self.min_procs, self.max_procs);
        if self.factor < 2 {
            return clamped;
        }
        // Walk the chain out from the initial size in both directions,
        // keeping the values inside [min, max].
        let mut chain = Vec::new();
        let mut down = self.procs;
        loop {
            if (self.min_procs..=self.max_procs).contains(&down) {
                chain.push(down);
            }
            if down % self.factor != 0 || down / self.factor < 1 || down < self.min_procs {
                break;
            }
            down /= self.factor;
        }
        let mut up = self.procs;
        while up <= self.max_procs / self.factor {
            up *= self.factor;
            if (self.min_procs..=self.max_procs).contains(&up) {
                chain.push(up);
            }
        }
        chain
            .into_iter()
            .min_by_key(|&c| (c.abs_diff(clamped), c))
            .unwrap_or(clamped)
    }
}

/// Clamp one job's size bounds onto a `nodes`-node pool: a job asking
/// for more nodes than exist would never start.  The submitted size is
/// re-rounded onto the job's factor chain while the chain is still
/// rooted at the original size (e.g. 32 on a 24-node pool lands on 16,
/// keeping resizes power-of-factor).  Idempotent — the campaign runner
/// applies it per scenario cluster, and the federated meta-scheduler
/// re-applies it per shard on routing and on every cross-shard steal.
pub fn fit_spec(j: &mut JobSpec, nodes: usize) {
    if j.max_procs > nodes {
        j.max_procs = nodes;
    }
    if j.min_procs > j.max_procs {
        j.min_procs = j.max_procs;
    }
    if j.procs > j.max_procs {
        j.procs = j.clamp_procs(j.max_procs);
    }
    if j.pref_procs.is_some_and(|p| p > j.max_procs) {
        j.pref_procs = Some(j.max_procs);
    }
}

/// A workload: jobs sorted by arrival time (§7.1).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub jobs: Vec<JobSpec>,
    pub seed: u64,
}

impl WorkloadSpec {
    pub fn len(&self) -> usize {
        self.jobs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// The same workload with every job forced rigid (the paper's "fixed"
    /// baseline: identical job stream, no malleability).
    pub fn as_fixed(&self) -> Self {
        let mut w = self.clone();
        for j in &mut w.jobs {
            j.malleable = false;
        }
        w
    }

    /// This workload with every job given a soft deadline of
    /// `submit + slack × est_duration` (the runtime estimate at the
    /// submitted size).  `slack` just above 1 is aggressive — any queue
    /// wait causes a miss; larger values leave headroom for waiting and
    /// for running shrunk.  Consumes `self` (decoration in place — a
    /// 5k-job trace replay should not clone every job spec).
    pub fn with_deadlines(mut self, slack: f64) -> Self {
        for j in &mut self.jobs {
            j.deadline = Some(j.submit_time + slack * j.est_duration());
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_app_uses_table1() {
        let j = JobSpec::from_app(AppKind::Cg, "CG-0".into(), 5.0, 1.0);
        assert_eq!(j.procs, 32);
        assert_eq!(j.min_procs, 2);
        assert_eq!(j.pref_procs, Some(8));
        assert!(j.malleable);
        assert_eq!(j.submit_time, 5.0);
    }

    #[test]
    fn scaling_follows_alpha() {
        let j = JobSpec::from_app(AppKind::Cg, "CG-0".into(), 0.0, 1.0);
        let e32 = j.exec_time_at(32);
        let e8 = j.exec_time_at(8);
        // alpha = 0.33: quartering the procs costs ~1.58x (paper's
        // Table 3 exec-gain signature)
        assert!((e8 / e32 - 4f64.powf(0.33)).abs() < 1e-9);
        // N-body is nearly size-invariant
        let n = JobSpec::from_app(AppKind::NBody, "NB".into(), 0.0, 1.0);
        assert!(n.exec_time_at(1) / n.exec_time_at(16) < 1.3);
    }

    #[test]
    fn clamp_procs_follows_factor_chain() {
        // CG: procs 32, factor 2, min 2, max 32 -> chain {2,4,8,16,32}
        let j = JobSpec::from_app(AppKind::Cg, "CG-0".into(), 0.0, 1.0);
        assert_eq!(j.clamp_procs(32), 32);
        assert_eq!(j.clamp_procs(8), 8);
        // off-chain values round to the nearest chain size
        assert_eq!(j.clamp_procs(20), 16);
        assert_eq!(j.clamp_procs(7), 8);
        assert_eq!(j.clamp_procs(5), 4);
        // ties go to the smaller size
        assert_eq!(j.clamp_procs(12), 8);
        assert_eq!(j.clamp_procs(3), 2);
        // out-of-range clamps to the chain ends
        assert_eq!(j.clamp_procs(1), 2);
        assert_eq!(j.clamp_procs(100), 32);

        // an off-chain initial size keeps its own chain: 5 -> {5, 10}
        let mut odd = j.clone();
        odd.procs = 5;
        odd.min_procs = 2;
        odd.max_procs = 16;
        assert_eq!(odd.clamp_procs(7), 5);
        assert_eq!(odd.clamp_procs(9), 10);
        assert_eq!(odd.clamp_procs(16), 10);

        // factor < 2 degenerates to a plain min/max clamp
        let mut f1 = j.clone();
        f1.factor = 1;
        assert_eq!(f1.clamp_procs(20), 20);
        assert_eq!(f1.clamp_procs(1), 2);
    }

    #[test]
    fn as_fixed_clears_malleable_only() {
        let j = JobSpec::from_app(AppKind::Jacobi, "J-0".into(), 0.0, 1.3);
        let w = WorkloadSpec { jobs: vec![j], seed: 1 };
        let f = w.as_fixed();
        assert!(!f.jobs[0].malleable);
        assert_eq!(f.jobs[0].work_scale, 1.3);
        assert!(w.jobs[0].malleable, "original untouched");
    }

    #[test]
    fn with_deadlines_sets_submit_plus_slack() {
        let j = JobSpec::from_app(AppKind::Cg, "CG-0".into(), 100.0, 1.0);
        let est = j.est_duration();
        let w = WorkloadSpec { jobs: vec![j], seed: 1 };
        assert_eq!(w.jobs[0].deadline, None, "no deadlines by default");
        let d = w.with_deadlines(2.0);
        let dl = d.jobs[0].deadline.expect("deadline set");
        assert!((dl - (100.0 + 2.0 * est)).abs() < 1e-9);
        // deadlines survive the rigid baseline derivation
        assert_eq!(d.as_fixed().jobs[0].deadline, Some(dl));
    }
}
