//! Rank endpoints: tagged blocking send/recv with MPI-style matching.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use super::world::{GroupId, World};

/// A message in flight.
#[derive(Debug, Clone)]
pub struct Msg {
    pub src_group: GroupId,
    pub src_rank: usize,
    pub tag: u64,
    pub payload: Vec<u8>,
}

/// Message selector for `recv` (MPI_ANY_SOURCE / MPI_ANY_TAG analogues).
#[derive(Debug, Clone, Copy, Default)]
pub struct RecvSelector {
    pub src_group: Option<GroupId>,
    pub src_rank: Option<usize>,
    pub tag: Option<u64>,
}

impl RecvSelector {
    pub fn tag(tag: u64) -> Self {
        RecvSelector { tag: Some(tag), ..Default::default() }
    }
    pub fn from_rank(group: GroupId, rank: usize, tag: u64) -> Self {
        RecvSelector { src_group: Some(group), src_rank: Some(rank), tag: Some(tag) }
    }
    fn matches(&self, m: &Msg) -> bool {
        self.src_group.map(|g| g == m.src_group).unwrap_or(true)
            && self.src_rank.map(|r| r == m.src_rank).unwrap_or(true)
            && self.tag.map(|t| t == m.tag).unwrap_or(true)
    }
}

/// Per-rank inbox: unordered-match queue + condvar.
#[derive(Default)]
pub(super) struct Mailbox {
    queue: Mutex<VecDeque<Msg>>,
    cv: Condvar,
}

impl Mailbox {
    fn push(&self, m: Msg) {
        self.queue.lock().unwrap().push_back(m);
        self.cv.notify_all();
    }

    fn pop(&self, sel: &RecvSelector) -> Msg {
        let mut q = self.queue.lock().unwrap();
        loop {
            if let Some(pos) = q.iter().position(|m| sel.matches(m)) {
                return q.remove(pos).unwrap();
            }
            q = self.cv.wait(q).unwrap();
        }
    }

    fn try_pop(&self, sel: &RecvSelector) -> Option<Msg> {
        let mut q = self.queue.lock().unwrap();
        q.iter()
            .position(|m| sel.matches(m))
            .map(|pos| q.remove(pos).unwrap())
    }
}

/// One rank's communication handle (intra-group rank + world access for
/// inter-group sends).  Clonable; cheap.
#[derive(Clone)]
pub struct Endpoint {
    world: World,
    group: GroupId,
    rank: usize,
    size: usize,
}

impl Endpoint {
    pub(super) fn new(world: World, group: GroupId, rank: usize, size: usize) -> Self {
        Endpoint { world, group, rank, size }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }
    /// Intra-group size (MPI_Comm_size of the "world" communicator).
    pub fn size(&self) -> usize {
        self.size
    }
    pub fn group(&self) -> GroupId {
        self.group
    }
    pub fn world(&self) -> &World {
        &self.world
    }

    /// Send within the group.
    pub fn send(&self, dst: usize, tag: u64, payload: Vec<u8>) {
        self.send_to_group(self.group, dst, tag, payload);
    }

    /// Send to a rank of another group (inter-communicator path).
    pub fn send_to_group(&self, group: GroupId, dst: usize, tag: u64, payload: Vec<u8>) {
        let mb = self.world.mailbox(group, dst);
        mb.push(Msg { src_group: self.group, src_rank: self.rank, tag, payload });
    }

    /// Blocking receive with matching.
    pub fn recv(&self, sel: RecvSelector) -> Msg {
        self.world.mailbox(self.group, self.rank).pop(&sel)
    }

    /// Non-blocking probe-receive.
    pub fn try_recv(&self, sel: RecvSelector) -> Option<Msg> {
        self.world.mailbox(self.group, self.rank).try_pop(&sel)
    }

    /// Convenience: intra-group receive from a specific rank/tag.
    pub fn recv_from(&self, src: usize, tag: u64) -> Msg {
        self.recv(RecvSelector::from_rank(self.group, src, tag))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_recv_same_group() {
        let w = World::new();
        let (_gid, eps) = w.create_group(2);
        let (a, b) = (eps[0].clone(), eps[1].clone());
        let t = std::thread::spawn(move || {
            let m = b.recv(RecvSelector::tag(7));
            assert_eq!(m.payload, vec![1, 2, 3]);
            assert_eq!(m.src_rank, 0);
        });
        a.send(1, 7, vec![1, 2, 3]);
        t.join().unwrap();
    }

    #[test]
    fn tag_matching_out_of_order() {
        let w = World::new();
        let (_gid, eps) = w.create_group(2);
        eps[0].send(1, 1, vec![1]);
        eps[0].send(1, 2, vec![2]);
        // Receive tag 2 first even though tag 1 arrived first.
        let m2 = eps[1].recv(RecvSelector::tag(2));
        assert_eq!(m2.payload, vec![2]);
        let m1 = eps[1].recv(RecvSelector::tag(1));
        assert_eq!(m1.payload, vec![1]);
    }

    #[test]
    fn inter_group_send() {
        let w = World::new();
        let (ga, a) = w.create_group(1);
        let (gb, b) = w.create_group(1);
        a[0].send_to_group(gb, 0, 5, vec![9]);
        let m = b[0].recv(RecvSelector::tag(5));
        assert_eq!(m.src_group, ga);
        assert_eq!(m.payload, vec![9]);
    }

    #[test]
    fn try_recv_nonblocking() {
        let w = World::new();
        let (_g, eps) = w.create_group(1);
        assert!(eps[0].try_recv(RecvSelector::tag(1)).is_none());
        eps[0].send(0, 1, vec![1]);
        assert!(eps[0].try_recv(RecvSelector::tag(1)).is_some());
    }
}
