//! Collectives over the endpoint primitives: barrier, broadcast,
//! allreduce(sum), allgather.  Rank-0-rooted linear algorithms — the
//! groups are small (≤ 64 ranks) and in-process, so tree algorithms buy
//! nothing here (see EXPERIMENTS.md §Perf for the measurement).

use super::endpoint::{Endpoint, RecvSelector};
use super::{bytes_to_f32s, f32s_to_bytes, TAG_BARRIER, TAG_BCAST, TAG_GATHER, TAG_REDUCE};

impl Endpoint {
    /// Synchronize all ranks of the group.
    pub fn barrier(&self) {
        if self.size() == 1 {
            return;
        }
        if self.rank() == 0 {
            for _ in 1..self.size() {
                self.recv(RecvSelector::tag(TAG_BARRIER));
            }
            for r in 1..self.size() {
                self.send(r, TAG_BARRIER, Vec::new());
            }
        } else {
            self.send(0, TAG_BARRIER, Vec::new());
            self.recv(RecvSelector::from_rank(self.group(), 0, TAG_BARRIER));
        }
    }

    /// Broadcast `data` from rank 0 to everyone; returns the payload.
    pub fn bcast(&self, data: Option<Vec<u8>>) -> Vec<u8> {
        if self.size() == 1 {
            return data.expect("bcast root payload");
        }
        if self.rank() == 0 {
            let data = data.expect("bcast root payload");
            for r in 1..self.size() {
                self.send(r, TAG_BCAST, data.clone());
            }
            data
        } else {
            self.recv(RecvSelector::from_rank(self.group(), 0, TAG_BCAST)).payload
        }
    }

    /// Sum-allreduce of a single f64 (CG dot products, residual norms).
    pub fn allreduce_sum(&self, x: f64) -> f64 {
        if self.size() == 1 {
            return x;
        }
        if self.rank() == 0 {
            let mut acc = x;
            for _ in 1..self.size() {
                let m = self.recv(RecvSelector::tag(TAG_REDUCE));
                acc += f64::from_le_bytes(m.payload.try_into().expect("8-byte f64"));
            }
            let b = acc.to_le_bytes().to_vec();
            for r in 1..self.size() {
                self.send(r, TAG_REDUCE, b.clone());
            }
            acc
        } else {
            self.send(0, TAG_REDUCE, x.to_le_bytes().to_vec());
            let m = self.recv(RecvSelector::from_rank(self.group(), 0, TAG_REDUCE));
            f64::from_le_bytes(m.payload.try_into().expect("8-byte f64"))
        }
    }

    /// Allgather of equal-length f32 slices (N-body position exchange).
    /// Returns the concatenation ordered by rank.
    pub fn allgather_f32(&self, local: &[f32]) -> Vec<f32> {
        if self.size() == 1 {
            return local.to_vec();
        }
        if self.rank() == 0 {
            let mut parts: Vec<Vec<f32>> = vec![Vec::new(); self.size()];
            parts[0] = local.to_vec();
            for _ in 1..self.size() {
                let m = self.recv(RecvSelector::tag(TAG_GATHER));
                parts[m.src_rank] = bytes_to_f32s(&m.payload);
            }
            let all: Vec<f32> = parts.concat();
            let bytes = f32s_to_bytes(&all);
            for r in 1..self.size() {
                self.send(r, TAG_GATHER, bytes.clone());
            }
            all
        } else {
            self.send(0, TAG_GATHER, f32s_to_bytes(local));
            let m = self.recv(RecvSelector::from_rank(self.group(), 0, TAG_GATHER));
            bytes_to_f32s(&m.payload)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::World;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn run_group<F>(n: usize, f: F)
    where
        F: Fn(super::Endpoint) + Send + Sync + 'static,
    {
        let w = World::new();
        let gid = w.spawn(n, f);
        w.join_group(gid);
    }

    #[test]
    fn barrier_orders_phases() {
        let flag = Arc::new(AtomicUsize::new(0));
        let f2 = Arc::clone(&flag);
        run_group(4, move |ep| {
            f2.fetch_add(1, Ordering::SeqCst);
            ep.barrier();
            // After the barrier every rank must observe all 4 increments.
            assert_eq!(f2.load(Ordering::SeqCst), 4);
        });
    }

    #[test]
    fn bcast_delivers_payload() {
        run_group(4, |ep| {
            let data = if ep.rank() == 0 { Some(vec![42u8; 16]) } else { None };
            let got = ep.bcast(data);
            assert_eq!(got, vec![42u8; 16]);
        });
    }

    #[test]
    fn allreduce_sums() {
        run_group(8, |ep| {
            let s = ep.allreduce_sum((ep.rank() + 1) as f64);
            assert_eq!(s, 36.0);
        });
    }

    #[test]
    fn allgather_orders_by_rank() {
        run_group(4, |ep| {
            let local = vec![ep.rank() as f32; 2];
            let all = ep.allgather_f32(&local);
            assert_eq!(all, vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        });
    }

    #[test]
    fn single_rank_collectives_trivial() {
        run_group(1, |ep| {
            ep.barrier();
            assert_eq!(ep.allreduce_sum(5.0), 5.0);
            assert_eq!(ep.allgather_f32(&[1.0]), vec![1.0]);
            assert_eq!(ep.bcast(Some(vec![1])), vec![1]);
        });
    }
}
