//! The process universe: groups of rank mailboxes and dynamic spawn.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::endpoint::{Endpoint, Mailbox};

/// Identifier of a process group (an intra-communicator's group).
pub type GroupId = u64;

/// The registry of all process groups.  Cheap to clone (Arc inside).
#[derive(Clone)]
pub struct World {
    inner: Arc<WorldInner>,
}

pub(super) struct WorldInner {
    pub(super) groups: Mutex<HashMap<GroupId, Vec<Arc<Mailbox>>>>,
    next_group: AtomicU64,
    /// Join registry for spawned rank threads (drained by `join_group`).
    handles: Mutex<HashMap<GroupId, Vec<std::thread::JoinHandle<()>>>>,
}

impl Default for World {
    fn default() -> Self {
        Self::new()
    }
}

impl World {
    pub fn new() -> Self {
        World {
            inner: Arc::new(WorldInner {
                groups: Mutex::new(HashMap::new()),
                next_group: AtomicU64::new(1),
                handles: Mutex::new(HashMap::new()),
            }),
        }
    }

    /// Create a group of `n` mailboxes and return its id plus endpoints
    /// (one per rank).  The caller decides how to run the ranks (threads
    /// via [`World::spawn`], or inline for tests).
    pub fn create_group(&self, n: usize) -> (GroupId, Vec<Endpoint>) {
        let gid = self.inner.next_group.fetch_add(1, Ordering::Relaxed);
        let boxes: Vec<Arc<Mailbox>> = (0..n).map(|_| Arc::new(Mailbox::default())).collect();
        self.inner.groups.lock().unwrap().insert(gid, boxes);
        let eps = (0..n)
            .map(|r| Endpoint::new(self.clone(), gid, r, n))
            .collect();
        (gid, eps)
    }

    /// `MPI_Comm_spawn`: create a group of `n` ranks, each running `f` on
    /// its own OS thread.  Returns the new group id (the parent uses it as
    /// the remote side of the inter-communicator).
    pub fn spawn<F>(&self, n: usize, f: F) -> GroupId
    where
        F: Fn(Endpoint) + Send + Sync + 'static,
    {
        let (gid, eps) = self.create_group(n);
        let f = Arc::new(f);
        let mut hs = Vec::with_capacity(n);
        for ep in eps {
            let f = Arc::clone(&f);
            hs.push(
                std::thread::Builder::new()
                    .name(format!("vmpi-g{gid}-r{}", ep.rank()))
                    .spawn(move || f(ep))
                    .expect("spawn rank thread"),
            );
        }
        self.inner.handles.lock().unwrap().insert(gid, hs);
        gid
    }

    /// Wait for every rank thread of `gid` to return.
    pub fn join_group(&self, gid: GroupId) {
        let hs = self.inner.handles.lock().unwrap().remove(&gid);
        if let Some(hs) = hs {
            for h in hs {
                h.join().expect("rank thread panicked");
            }
        }
    }

    /// Drop a group's mailboxes (after its ranks exited).
    pub fn destroy_group(&self, gid: GroupId) {
        self.inner.groups.lock().unwrap().remove(&gid);
    }

    pub(super) fn mailbox(&self, gid: GroupId, rank: usize) -> Arc<Mailbox> {
        let groups = self.inner.groups.lock().unwrap();
        let g = groups.get(&gid).unwrap_or_else(|| panic!("no group {gid}"));
        Arc::clone(&g[rank])
    }

    pub fn group_size(&self, gid: GroupId) -> usize {
        self.inner.groups.lock().unwrap().get(&gid).map(|g| g.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_and_destroy() {
        let w = World::new();
        let (gid, eps) = w.create_group(4);
        assert_eq!(eps.len(), 4);
        assert_eq!(w.group_size(gid), 4);
        w.destroy_group(gid);
        assert_eq!(w.group_size(gid), 0);
    }

    #[test]
    fn spawn_runs_all_ranks() {
        let w = World::new();
        let counter = Arc::new(AtomicU64::new(0));
        let c2 = Arc::clone(&counter);
        let gid = w.spawn(8, move |ep| {
            c2.fetch_add(ep.rank() as u64 + 1, Ordering::Relaxed);
        });
        w.join_group(gid);
        assert_eq!(counter.load(Ordering::Relaxed), 36); // 1+..+8
    }
}
