//! vmpi — a virtual MPI substrate over in-process channels.
//!
//! Substitutes MPICH on the paper's testbed (see DESIGN.md §2).  Provides
//! exactly the facilities the malleability framework needs:
//!
//! * process *groups* of ranks with point-to-point tagged send/recv
//!   (blocking, message-matching semantics like MPI),
//! * collectives: barrier, broadcast, allreduce, allgather,
//! * **dynamic process creation** — the [`World::spawn`] analogue of
//!   `MPI_Comm_spawn` (§3): a running group creates a new group of rank
//!   threads and gets an inter-communicator to it, over which the data
//!   redistribution of Listing 3 runs with real byte movement.
//!
//! Payloads are owned byte buffers; the redistribution paths copy real
//! data (the Fig. 3(b) resize-time measurements exercise these copies).

mod collectives;
mod endpoint;
mod world;

pub use endpoint::{Endpoint, Msg, RecvSelector};
pub use world::{GroupId, World};

/// Tags reserved by the runtime (apps use tags < `TAG_RESERVED_BASE`).
pub const TAG_RESERVED_BASE: u64 = 1 << 48;
pub const TAG_BARRIER: u64 = TAG_RESERVED_BASE;
pub const TAG_BCAST: u64 = TAG_RESERVED_BASE + 1;
pub const TAG_REDUCE: u64 = TAG_RESERVED_BASE + 2;
pub const TAG_GATHER: u64 = TAG_RESERVED_BASE + 3;
pub const TAG_STATE: u64 = TAG_RESERVED_BASE + 4;
pub const TAG_ACK: u64 = TAG_RESERVED_BASE + 5;
pub const TAG_DECISION: u64 = TAG_RESERVED_BASE + 6;

/// Encode a `&[f32]` as little-endian bytes (payload helper).
///
/// Perf note (EXPERIMENTS.md §Perf): on little-endian targets this is a
/// single memcpy of the POD buffer; the per-element `to_le_bytes` loop it
/// replaces was a measurable slice of redistribution time.
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    #[cfg(target_endian = "little")]
    {
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(xs.as_ptr().cast::<u8>(), xs.len() * 4) };
        bytes.to_vec()
    }
    #[cfg(not(target_endian = "little"))]
    {
        let mut out = Vec::with_capacity(xs.len() * 4);
        for x in xs {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }
}

/// Decode little-endian bytes into `f32`s.
pub fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    assert_eq!(b.len() % 4, 0, "payload not f32-aligned");
    #[cfg(target_endian = "little")]
    {
        // One memcpy into an f32 buffer (the source Vec<u8> is not
        // guaranteed 4-aligned, so reinterpreting in place is unsound).
        let n = b.len() / 4;
        let mut out = vec![0f32; n];
        unsafe {
            std::ptr::copy_nonoverlapping(b.as_ptr(), out.as_mut_ptr().cast::<u8>(), b.len());
        }
        out
    }
    #[cfg(not(target_endian = "little"))]
    {
        b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let xs = vec![1.0f32, -2.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&xs)), xs);
    }

    #[test]
    #[should_panic(expected = "aligned")]
    fn misaligned_payload_panics() {
        bytes_to_f32s(&[1, 2, 3]);
    }
}
