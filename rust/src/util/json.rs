//! Minimal JSON parser/writer (offline build: no serde).  Covers the
//! subset the project emits/consumes: the artifact manifest, calibration
//! files and results metadata.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.obj(),
            Some(b'[') => self.arr(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.num(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn num(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn arr(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn obj(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let s = r#"{"cg_phase1_p4": {"inputs": [{"shape": [4096], "dtype": "float32"},
                     {"shape": [1], "dtype": "float32"}], "outputs": []}}"#;
        let j = Json::parse(s).unwrap();
        let entry = j.get("cg_phase1_p4").unwrap();
        let inputs = entry.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs.len(), 2);
        assert_eq!(
            inputs[0].get("shape").unwrap().idx(0).unwrap().as_usize(),
            Some(4096)
        );
        assert_eq!(inputs[0].get("dtype").unwrap().as_str(), Some("float32"));
    }

    #[test]
    fn roundtrip() {
        let s = r#"{"a":[1,2.5,-3],"b":"x\"y","c":true,"d":null,"e":{}}"#;
        let j = Json::parse(s).unwrap();
        let j2 = Json::parse(&j.render()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café \n\t""#).unwrap();
        assert_eq!(j.as_str(), Some("café \n\t"));
    }

    #[test]
    fn renders_ints_cleanly() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(1.5).render(), "1.5");
    }
}
