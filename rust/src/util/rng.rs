//! Deterministic PRNG + distributions (xoshiro256++ seeded via SplitMix64).
//!
//! The workload generator (Feitelson model, §7.1 of the paper) needs
//! exponential / Poisson / log-normal / normal sampling; experiments need
//! reproducible fixed-seed streams ("randomly-sorted jobs (with a fixed
//! seed)" — §7.5).

/// xoshiro256++ PRNG.  Deterministic, fast, good statistical quality.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's rejection-free-ish method with one retry loop.
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Exponential with the given mean (inter-arrival sampling).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Poisson-distributed count with the given mean (Knuth for small
    /// means, normal approximation for large).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal(mean, mean.sqrt());
            if x < 0.0 {
                0
            } else {
                x.round() as u64
            }
        }
    }

    /// Normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Log-normal: exp(Normal(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Pick one element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
        for _ in 0..100 {
            let x = r.range(5, 9);
            assert!((5..=9).contains(&x));
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.exp(10.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 10.0).abs() < 0.3, "mean={mean}");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let sum: u64 = (0..n).map(|_| r.poisson(10.0)).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 10.0).abs() < 0.2, "mean={mean}");
        // large-mean path
        let sum: u64 = (0..n).map(|_| r.poisson(100.0)).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 100.0).abs() < 1.0, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(5.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.05);
        assert!((var - 4.0).abs() < 0.2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
