//! Summary statistics used throughout the evaluation reports
//! (min / max / average / standard deviation — the exact columns of
//! Tables 2–4 in the paper).

/// Online summary of a sample (Welford's algorithm for numerical
/// stability; the paper's Table 2 spans 4 orders of magnitude).
#[derive(Debug, Clone)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Summary {
    fn default() -> Self {
        // NOT derived: min must start at +inf (a derived 0.0 would absorb
        // every later sample into a bogus minimum).
        Self::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn from_iter<I: IntoIterator<Item = f64>>(xs: I) -> Self {
        let mut s = Self::new();
        for x in xs {
            s.push(x);
        }
        s
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
    pub fn sum(&self) -> f64 {
        self.mean() * self.n as f64
    }

    /// Sample standard deviation (Bessel's correction); 0 for n < 2.
    pub fn sample_std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Half-width of the normal-approximation 95 % confidence interval of
    /// the mean (the campaign aggregates quote `mean ± ci95`); 0 for
    /// n < 2.
    pub fn ci95_half(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            1.96 * self.sample_std() / (self.n as f64).sqrt()
        }
    }

    /// Merge another summary into this one (Chan et al.'s parallel
    /// Welford update).  Used by the streaming metrics path to combine
    /// per-shard archive-time folds into run-level statistics without
    /// retaining per-job records; merge order is fixed (shard-id order)
    /// so the result is deterministic.
    pub fn merge(&mut self, o: &Summary) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let n = self.n + o.n;
        let d = o.mean - self.mean;
        let mean = self.mean + d * (o.n as f64 / n as f64);
        let m2 = self.m2 + o.m2 + d * d * (self.n as f64 * o.n as f64 / n as f64);
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }
}

/// Percentage gain of `new` over `base` (positive = improvement when lower
/// is better), as used for the bar labels of Figs. 4–5:
/// `gain = (base - new) / base * 100`.
pub fn gain_pct(base: f64, new: f64) -> f64 {
    if base == 0.0 {
        0.0
    } else {
        (base - new) / base * 100.0
    }
}

/// Trapezoidal mean of a step time-series `(t, value)` over `[t0, t1]` —
/// used for the average resource-utilization columns.
pub fn step_series_mean(points: &[(f64, f64)], t0: f64, t1: f64) -> f64 {
    if points.is_empty() || t1 <= t0 {
        return 0.0;
    }
    let mut area = 0.0;
    let mut prev_t = t0;
    let mut prev_v = 0.0;
    for &(t, v) in points {
        let t = t.clamp(t0, t1);
        if t > prev_t {
            area += prev_v * (t - prev_t);
        }
        prev_t = t;
        prev_v = v;
    }
    if t1 > prev_t {
        area += prev_v * (t1 - prev_t);
    }
    area / (t1 - t0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_iter([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.sum() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn default_matches_new_not_derived() {
        let mut s = Summary::default();
        s.push(5.0);
        assert_eq!(s.min(), 5.0, "derived Default would report 0.0");
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn ci95_and_sample_std() {
        let s = Summary::from_iter([1.0, 2.0, 3.0, 4.0]);
        // sample variance = 5/3
        assert!((s.sample_std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        let want = 1.96 * (5.0f64 / 3.0).sqrt() / 2.0;
        assert!((s.ci95_half() - want).abs() < 1e-12);
        // degenerate cases
        assert_eq!(Summary::new().ci95_half(), 0.0);
        assert_eq!(Summary::from_iter([5.0]).ci95_half(), 0.0);
        assert_eq!(Summary::from_iter([5.0]).sample_std(), 0.0);
    }

    #[test]
    fn merge_matches_batch_formulas() {
        // Welford merge (Chan) vs the batch moments, across uneven splits
        // and 4 orders of magnitude (the Table 2 spread).
        let xs: Vec<f64> =
            (0..97).map(|i| ((i * 37 % 89) as f64).mul_add(123.456, 0.001 * i as f64)).collect();
        let batch_mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let batch_var = xs.iter().map(|x| (x - batch_mean) * (x - batch_mean)).sum::<f64>()
            / xs.len() as f64;
        for split in [0, 1, 13, 48, 96, 97] {
            let mut a = Summary::from_iter(xs[..split].iter().copied());
            let b = Summary::from_iter(xs[split..].iter().copied());
            a.merge(&b);
            assert_eq!(a.count(), xs.len() as u64, "split {split}");
            assert!((a.mean() - batch_mean).abs() < 1e-9, "split {split}: mean");
            assert!((a.std() - batch_var.sqrt()).abs() < 1e-9, "split {split}: std");
            assert_eq!(a.min(), Summary::from_iter(xs.iter().copied()).min());
            assert_eq!(a.max(), Summary::from_iter(xs.iter().copied()).max());
            // ci95 goes through sample_std, so it must agree too.
            let whole = Summary::from_iter(xs.iter().copied());
            assert!((a.ci95_half() - whole.ci95_half()).abs() < 1e-9, "split {split}: ci95");
        }
    }

    #[test]
    fn merge_empty_identities() {
        let mut a = Summary::from_iter([1.0, 2.0]);
        a.merge(&Summary::new());
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 1.5).abs() < 1e-12);
        let mut e = Summary::new();
        e.merge(&Summary::from_iter([1.0, 2.0]));
        assert_eq!(e.count(), 2);
        assert!((e.mean() - 1.5).abs() < 1e-12);
        let mut both = Summary::new();
        both.merge(&Summary::new());
        assert_eq!(both.count(), 0);
        assert_eq!(both.mean(), 0.0);
    }

    #[test]
    fn gain() {
        assert!((gain_pct(100.0, 50.0) - 50.0).abs() < 1e-12);
        assert!((gain_pct(100.0, 150.0) + 50.0).abs() < 1e-12);
        assert_eq!(gain_pct(0.0, 5.0), 0.0);
    }

    #[test]
    fn step_mean() {
        // value 2 over [0,5), value 4 over [5,10) => mean 3
        let pts = vec![(0.0, 2.0), (5.0, 4.0)];
        assert!((step_series_mean(&pts, 0.0, 10.0) - 3.0).abs() < 1e-12);
        // window clipped to [5, 10) => 4
        assert!((step_series_mean(&pts, 5.0, 10.0) - 4.0).abs() < 1e-12);
    }
}
