//! Summary statistics used throughout the evaluation reports
//! (min / max / average / standard deviation — the exact columns of
//! Tables 2–4 in the paper).

/// Online summary of a sample (Welford's algorithm for numerical
/// stability; the paper's Table 2 spans 4 orders of magnitude).
#[derive(Debug, Clone)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Summary {
    fn default() -> Self {
        // NOT derived: min must start at +inf (a derived 0.0 would absorb
        // every later sample into a bogus minimum).
        Self::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn from_iter<I: IntoIterator<Item = f64>>(xs: I) -> Self {
        let mut s = Self::new();
        for x in xs {
            s.push(x);
        }
        s
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
    pub fn sum(&self) -> f64 {
        self.mean() * self.n as f64
    }

    /// Sample standard deviation (Bessel's correction); 0 for n < 2.
    pub fn sample_std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Half-width of the normal-approximation 95 % confidence interval of
    /// the mean (the campaign aggregates quote `mean ± ci95`); 0 for
    /// n < 2.
    pub fn ci95_half(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            1.96 * self.sample_std() / (self.n as f64).sqrt()
        }
    }
}

/// Percentage gain of `new` over `base` (positive = improvement when lower
/// is better), as used for the bar labels of Figs. 4–5:
/// `gain = (base - new) / base * 100`.
pub fn gain_pct(base: f64, new: f64) -> f64 {
    if base == 0.0 {
        0.0
    } else {
        (base - new) / base * 100.0
    }
}

/// Trapezoidal mean of a step time-series `(t, value)` over `[t0, t1]` —
/// used for the average resource-utilization columns.
pub fn step_series_mean(points: &[(f64, f64)], t0: f64, t1: f64) -> f64 {
    if points.is_empty() || t1 <= t0 {
        return 0.0;
    }
    let mut area = 0.0;
    let mut prev_t = t0;
    let mut prev_v = 0.0;
    for &(t, v) in points {
        let t = t.clamp(t0, t1);
        if t > prev_t {
            area += prev_v * (t - prev_t);
        }
        prev_t = t;
        prev_v = v;
    }
    if t1 > prev_t {
        area += prev_v * (t1 - prev_t);
    }
    area / (t1 - t0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_iter([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.sum() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn default_matches_new_not_derived() {
        let mut s = Summary::default();
        s.push(5.0);
        assert_eq!(s.min(), 5.0, "derived Default would report 0.0");
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn ci95_and_sample_std() {
        let s = Summary::from_iter([1.0, 2.0, 3.0, 4.0]);
        // sample variance = 5/3
        assert!((s.sample_std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        let want = 1.96 * (5.0f64 / 3.0).sqrt() / 2.0;
        assert!((s.ci95_half() - want).abs() < 1e-12);
        // degenerate cases
        assert_eq!(Summary::new().ci95_half(), 0.0);
        assert_eq!(Summary::from_iter([5.0]).ci95_half(), 0.0);
        assert_eq!(Summary::from_iter([5.0]).sample_std(), 0.0);
    }

    #[test]
    fn gain() {
        assert!((gain_pct(100.0, 50.0) - 50.0).abs() < 1e-12);
        assert!((gain_pct(100.0, 150.0) + 50.0).abs() < 1e-12);
        assert_eq!(gain_pct(0.0, 5.0), 0.0);
    }

    #[test]
    fn step_mean() {
        // value 2 over [0,5), value 4 over [5,10) => mean 3
        let pts = vec![(0.0, 2.0), (5.0, 4.0)];
        assert!((step_series_mean(&pts, 0.0, 10.0) - 3.0).abs() < 1e-12);
        // window clipped to [5, 10) => 4
        assert!((step_series_mean(&pts, 5.0, 10.0) - 4.0).abs() < 1e-12);
    }
}
