//! Tiny command-line argument parser (no external crates available
//! offline): `prog SUBCOMMAND --key value --flag positional`.

use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding `argv[0]`).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    a.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    a.flags.push(name.to_string());
                }
            } else if a.subcommand.is_none() && a.positional.is_empty() {
                a.subcommand = Some(tok);
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            Some(s) => s.parse().unwrap_or(default),
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE: a bare `--flag value` is parsed as an option (the value
        // binds to the flag); use `--flag=` -less style only at the end or
        // with `=` syntax when a positional follows.
        let a = parse("run out.csv --jobs 50 --mode flexible --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("jobs"), Some("50"));
        assert_eq!(a.get_or("mode", "fixed"), "flexible");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn eq_syntax_and_defaults() {
        let a = parse("bench --jobs=400");
        assert_eq!(a.get_parse("jobs", 0u32), 400);
        assert_eq!(a.get_parse("nodes", 64u32), 64);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("x --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }
}
