//! Minimal TOML parser (offline build: no `toml` crate), covering the
//! subset the campaign specs use and parsing into [`Json`] so both spec
//! formats share one accessor API:
//!
//! * `key = value` pairs with bare (`[A-Za-z0-9_-]`, optionally dotted) or
//!   quoted keys;
//! * `[table]` and `[[array-of-tables]]` headers (dotted paths allowed);
//! * basic `"..."` strings (with `\n \t \r \" \\ \u{XXXX}`-less JSON-style
//!   escapes), literal `'...'` strings;
//! * integers, floats, booleans;
//! * homogeneous arrays, which may span lines and carry trailing commas;
//! * `#` comments.
//!
//! Datetimes, inline tables and multi-line strings are rejected with an
//! error rather than mis-parsed.

use std::collections::BTreeMap;

use super::json::Json;

/// Parse a TOML document into a [`Json::Obj`] tree.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    // Path of the table currently receiving keys; each segment may index
    // into an array-of-tables.
    let mut current: Vec<(String, Option<usize>)> = Vec::new();

    let mut p = Cursor { b: text.as_bytes(), i: 0, line: 1 };
    loop {
        p.skip_trivia();
        if p.at_end() {
            break;
        }
        if p.peek() == Some(b'[') {
            let many = p.starts_with("[[");
            p.advance(if many { 2 } else { 1 });
            let path = p.key_path()?;
            p.skip_inline_ws();
            let closer = if many { "]]" } else { "]" };
            if !p.starts_with(closer) {
                return Err(p.err(&format!("expected '{closer}' closing table header")));
            }
            p.advance(closer.len());
            p.expect_line_end()?;
            current = enter_table(&mut root, &path, many).map_err(|e| p.err(&e))?;
        } else {
            let path = p.key_path()?;
            p.skip_inline_ws();
            if p.peek() != Some(b'=') {
                return Err(p.err("expected '=' after key"));
            }
            p.advance(1);
            p.skip_inline_ws();
            let value = p.value()?;
            p.expect_line_end()?;
            let table = descend_mut(&mut root, &current)
                .ok_or_else(|| p.err("internal: lost current table"))?;
            insert_value(table, &path, value).map_err(|e| p.err(&e))?;
        }
    }
    Ok(Json::Obj(root))
}

/// Create (or re-enter) the table at `path`; for `[[path]]` append a fresh
/// element to the array of tables.  Returns the indexed path to it.
fn enter_table(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    array_of_tables: bool,
) -> Result<Vec<(String, Option<usize>)>, String> {
    let mut indexed: Vec<(String, Option<usize>)> = Vec::new();
    let (last, prefix) = path.split_last().ok_or("empty table name")?;
    for seg in prefix {
        indexed.push((seg.clone(), None));
    }
    {
        // Materialize intermediate tables.
        let mut map = root;
        for (seg, _) in &indexed {
            let entry = map
                .entry(seg.clone())
                .or_insert_with(|| Json::Obj(BTreeMap::new()));
            map = match entry {
                Json::Obj(m) => m,
                Json::Arr(v) => match v.last_mut() {
                    Some(Json::Obj(m)) => m,
                    _ => return Err(format!("'{seg}' is not a table")),
                },
                _ => return Err(format!("'{seg}' is not a table")),
            };
        }
        if array_of_tables {
            let entry = map
                .entry(last.clone())
                .or_insert_with(|| Json::Arr(Vec::new()));
            match entry {
                Json::Arr(v) => {
                    v.push(Json::Obj(BTreeMap::new()));
                    indexed.push((last.clone(), Some(v.len() - 1)));
                }
                _ => return Err(format!("'{last}' already defined as a non-array")),
            }
        } else {
            let entry = map
                .entry(last.clone())
                .or_insert_with(|| Json::Obj(BTreeMap::new()));
            match entry {
                Json::Obj(_) => indexed.push((last.clone(), None)),
                _ => return Err(format!("'{last}' already defined as a non-table")),
            }
        }
    }
    Ok(indexed)
}

/// Follow an indexed path to the map it denotes.
fn descend_mut<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[(String, Option<usize>)],
) -> Option<&'a mut BTreeMap<String, Json>> {
    let mut map = root;
    for (seg, idx) in path {
        let entry = map.get_mut(seg)?;
        map = match (entry, idx) {
            (Json::Obj(m), None) => m,
            (Json::Arr(v), Some(i)) => match v.get_mut(*i)? {
                Json::Obj(m) => m,
                _ => return None,
            },
            // Re-entering `[a.b]` after `[[a]]`: keys belong to the last
            // element of the array.
            (Json::Arr(v), None) => match v.last_mut()? {
                Json::Obj(m) => m,
                _ => return None,
            },
            _ => return None,
        };
    }
    Some(map)
}

/// Insert `value` at a (possibly dotted) key path below `table`.
fn insert_value(
    table: &mut BTreeMap<String, Json>,
    path: &[String],
    value: Json,
) -> Result<(), String> {
    let (last, prefix) = path.split_last().ok_or("empty key")?;
    let mut map = table;
    for seg in prefix {
        let entry = map
            .entry(seg.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        map = match entry {
            Json::Obj(m) => m,
            _ => return Err(format!("'{seg}' is not a table")),
        };
    }
    if map.insert(last.clone(), value).is_some() {
        return Err(format!("duplicate key '{last}'"));
    }
    Ok(())
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
    line: usize,
}

impl<'a> Cursor<'a> {
    fn at_end(&self) -> bool {
        self.i >= self.b.len()
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn advance(&mut self, n: usize) {
        for _ in 0..n {
            if self.peek() == Some(b'\n') {
                self.line += 1;
            }
            self.i += 1;
        }
    }

    fn starts_with(&self, s: &str) -> bool {
        self.b[self.i..].starts_with(s.as_bytes())
    }

    fn err(&self, msg: &str) -> String {
        format!("toml line {}: {msg}", self.line)
    }

    /// Skip spaces and tabs (not newlines).
    fn skip_inline_ws(&mut self) {
        while matches!(self.peek(), Some(b' ') | Some(b'\t')) {
            self.i += 1;
        }
    }

    /// Skip whitespace, newlines and comments.
    fn skip_trivia(&mut self) {
        loop {
            match self.peek() {
                Some(b' ') | Some(b'\t') | Some(b'\r') => self.i += 1,
                Some(b'\n') => {
                    self.line += 1;
                    self.i += 1;
                }
                Some(b'#') => {
                    while !matches!(self.peek(), None | Some(b'\n')) {
                        self.i += 1;
                    }
                }
                _ => return,
            }
        }
    }

    /// After a value or header: only trivia may remain on the line.
    fn expect_line_end(&mut self) -> Result<(), String> {
        self.skip_inline_ws();
        match self.peek() {
            None | Some(b'\n') | Some(b'#') | Some(b'\r') => {
                self.skip_trivia();
                Ok(())
            }
            Some(c) => Err(self.err(&format!("unexpected '{}' after value", c as char))),
        }
    }

    /// A dotted key path: `a`, `a.b`, `"quoted key"`.
    fn key_path(&mut self) -> Result<Vec<String>, String> {
        let mut parts = Vec::new();
        loop {
            self.skip_inline_ws();
            let part = match self.peek() {
                Some(b'"') => self.basic_string()?,
                Some(b'\'') => self.literal_string()?,
                _ => {
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c.is_ascii_alphanumeric() || c == b'_' || c == b'-' {
                            self.i += 1;
                        } else {
                            break;
                        }
                    }
                    if self.i == start {
                        return Err(self.err("expected a key"));
                    }
                    String::from_utf8_lossy(&self.b[start..self.i]).into_owned()
                }
            };
            parts.push(part);
            self.skip_inline_ws();
            if self.peek() == Some(b'.') {
                self.advance(1);
            } else {
                return Ok(parts);
            }
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'"') => Ok(Json::Str(self.basic_string()?)),
            Some(b'\'') => Ok(Json::Str(self.literal_string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => Err(self.err("inline tables are not supported")),
            Some(b't') | Some(b'f') => {
                if self.starts_with("true") {
                    self.advance(4);
                    Ok(Json::Bool(true))
                } else if self.starts_with("false") {
                    self.advance(5);
                    Ok(Json::Bool(false))
                } else {
                    Err(self.err("bad literal"))
                }
            }
            Some(c) if c == b'-' || c == b'+' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'_') {
                self.i += 1;
            } else {
                break;
            }
        }
        let raw = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad number"))?;
        // `1979-05-27`-style dates scan like numbers; reject them clearly.
        if raw.matches('-').count() > 1 && !raw.starts_with('-') {
            return Err(self.err("datetimes are not supported"));
        }
        let cleaned: String = raw.chars().filter(|&c| c != '_').collect();
        cleaned
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{raw}'")))
    }

    fn basic_string(&mut self) -> Result<String, String> {
        if self.starts_with("\"\"\"") {
            return Err(self.err("multi-line strings are not supported"));
        }
        self.advance(1); // opening quote
        let mut s = String::new();
        loop {
            match self.peek() {
                None | Some(b'\n') => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.advance(1);
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.advance(1);
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        _ => return Err(self.err("unsupported escape")),
                    }
                    self.advance(1);
                }
                Some(_) => {
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn literal_string(&mut self) -> Result<String, String> {
        self.advance(1); // opening quote
        let start = self.i;
        while !matches!(self.peek(), None | Some(b'\'') | Some(b'\n')) {
            self.i += 1;
        }
        if self.peek() != Some(b'\'') {
            return Err(self.err("unterminated literal string"));
        }
        let s = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.advance(1);
        Ok(s)
    }

    fn array(&mut self) -> Result<Json, String> {
        self.advance(1); // '['
        let mut v = Vec::new();
        loop {
            self.skip_trivia();
            if self.peek() == Some(b']') {
                self.advance(1);
                return Ok(Json::Arr(v));
            }
            v.push(self.value()?);
            self.skip_trivia();
            match self.peek() {
                Some(b',') => self.advance(1),
                Some(b']') => {
                    self.advance(1);
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_tables_and_arrays() {
        let doc = r#"
# campaign
name = "sweep" # trailing comment
workers = 4
scale = 2.5
fast = true
nodes = [32, 64]
modes = [
    "fixed",
    "sync",   # mixed lines + trailing comma
]

[policy]
backfill = [true, false]
"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("sweep"));
        assert_eq!(j.get("workers").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("scale").unwrap().as_f64(), Some(2.5));
        assert_eq!(j.get("fast"), Some(&Json::Bool(true)));
        let nodes = j.get("nodes").unwrap().as_arr().unwrap();
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[1].as_usize(), Some(64));
        let modes = j.get("modes").unwrap().as_arr().unwrap();
        assert_eq!(modes[0].as_str(), Some("fixed"));
        let bf = j.get("policy").unwrap().get("backfill").unwrap().as_arr().unwrap();
        assert_eq!(bf, &[Json::Bool(true), Json::Bool(false)]);
    }

    #[test]
    fn array_of_tables() {
        let doc = r#"
[[workload]]
kind = "feitelson"
jobs = 40

[[workload]]
kind = "swf"
path = 'traces/small.swf'
"#;
        let j = parse(doc).unwrap();
        let w = j.get("workload").unwrap().as_arr().unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].get("kind").unwrap().as_str(), Some("feitelson"));
        assert_eq!(w[0].get("jobs").unwrap().as_usize(), Some(40));
        assert_eq!(w[1].get("path").unwrap().as_str(), Some("traces/small.swf"));
    }

    #[test]
    fn dotted_and_quoted_keys() {
        let doc = "a.b = 1\n\"odd key\" = 2\n[t.u]\nc = 3\n";
        let j = parse(doc).unwrap();
        assert_eq!(j.get("a").unwrap().get("b").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("odd key").unwrap().as_usize(), Some(2));
        assert_eq!(
            j.get("t").unwrap().get("u").unwrap().get("c").unwrap().as_usize(),
            Some(3)
        );
    }

    #[test]
    fn underscored_and_negative_numbers() {
        let j = parse("big = 1_000_000\nneg = -3\nexp = 1e3\n").unwrap();
        assert_eq!(j.get("big").unwrap().as_f64(), Some(1_000_000.0));
        assert_eq!(j.get("neg").unwrap().as_f64(), Some(-3.0));
        assert_eq!(j.get("exp").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("key").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"unterminated").is_err());
        assert!(parse("k = 1 trailing").is_err());
        assert!(parse("k = {a = 1}").is_err());
        assert!(parse("k = 1\nk = 2\n").is_err());
        assert!(parse("[t\nk = 1\n").is_err());
    }

    #[test]
    fn reenter_array_of_tables_keys_go_to_last() {
        let doc = "[[w]]\nx = 1\n[[w]]\nx = 2\ny = 3\n";
        let j = parse(doc).unwrap();
        let w = j.get("w").unwrap().as_arr().unwrap();
        assert_eq!(w[0].get("x").unwrap().as_usize(), Some(1));
        assert_eq!(w[1].get("y").unwrap().as_usize(), Some(3));
    }
}
