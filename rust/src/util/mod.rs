//! Small self-contained utilities (the crate builds offline against the
//! vendored dependency set, so PRNG, stats, tables, plots, CSV and CLI
//! parsing are implemented here rather than pulled from crates.io).

pub mod cli;
pub mod csv;
pub mod json;
pub mod plot;
pub mod rng;
pub mod stats;
pub mod table;
pub mod toml;

/// Format seconds compactly: `"431.2s"` / `"1h12m"` style used in reports.
pub fn fmt_secs(s: f64) -> String {
    if s < 0.0 {
        return format!("-{}", fmt_secs(-s));
    }
    if s < 120.0 {
        format!("{s:.2}s")
    } else if s < 7200.0 {
        format!("{:.1}m", s / 60.0)
    } else {
        format!("{:.2}h", s / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(1.5), "1.50s");
        assert_eq!(fmt_secs(600.0), "10.0m");
        assert_eq!(fmt_secs(7200.0), "2.00h");
        assert_eq!(fmt_secs(-1.5), "-1.50s");
    }
}
