//! ASCII charts: horizontal bar charts (Figs. 4–5 style, with gain labels)
//! and step line charts (Fig. 6 style time evolution).

/// Horizontal bar chart. Each entry is (label, value, annotation).
pub fn bar_chart(title: &str, entries: &[(String, f64, String)], width: usize) -> String {
    let max = entries.iter().map(|e| e.1).fold(0.0_f64, f64::max).max(1e-12);
    let lw = entries.iter().map(|e| e.0.len()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (label, v, ann) in entries {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "  {:<lw$} |{:<width$}| {:>10.1} {}\n",
            label,
            "#".repeat(n),
            v,
            ann,
            lw = lw,
            width = width
        ));
    }
    out
}

/// Step-function time series rendered as an ASCII grid.
/// `series`: (name, points (t, v)); all series share the x/y axes.
pub fn step_chart(title: &str, series: &[(String, Vec<(f64, f64)>)], cols: usize, rows: usize) -> String {
    let mut tmax = 0.0_f64;
    let mut vmax = 0.0_f64;
    for (_, pts) in series {
        for &(t, v) in pts {
            tmax = tmax.max(t);
            vmax = vmax.max(v);
        }
    }
    if tmax <= 0.0 || vmax <= 0.0 {
        return format!("{title}\n  (empty)\n");
    }
    let marks = ['#', '*', '+', 'o', 'x', '@'];
    let mut grid = vec![vec![' '; cols]; rows];
    for (si, (_, pts)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        // sample the step function at each column
        for c in 0..cols {
            let t = tmax * (c as f64 + 0.5) / cols as f64;
            let mut v = 0.0;
            for &(pt, pv) in pts {
                if pt <= t {
                    v = pv;
                } else {
                    break;
                }
            }
            let r = ((v / vmax) * (rows as f64 - 1.0)).round() as usize;
            let r = rows - 1 - r.min(rows - 1);
            grid[r][c] = mark;
        }
    }
    let mut out = format!("{title}   (ymax={vmax:.0}, tmax={tmax:.0}s)\n");
    for (i, row) in grid.iter().enumerate() {
        let y = vmax * (rows - 1 - i) as f64 / (rows as f64 - 1.0);
        out.push_str(&format!("{:>8.0} |{}\n", y, row.iter().collect::<String>()));
    }
    out.push_str(&format!("{:>8} +{}\n", "", "-".repeat(cols)));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (n, _))| format!("{}={}", marks[i % marks.len()], n))
        .collect();
    out.push_str(&format!("{:>10}{}\n", "", legend.join("  ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bars_scale() {
        let s = bar_chart(
            "t",
            &[("a".into(), 10.0, "".into()), ("b".into(), 5.0, "(x)".into())],
            20,
        );
        assert!(s.contains("a"));
        let a_hashes = s.lines().nth(1).unwrap().matches('#').count();
        let b_hashes = s.lines().nth(2).unwrap().matches('#').count();
        assert_eq!(a_hashes, 20);
        assert_eq!(b_hashes, 10);
    }

    #[test]
    fn step_chart_nonempty() {
        let s = step_chart(
            "T",
            &[("x".into(), vec![(0.0, 1.0), (50.0, 3.0)])],
            40,
            8,
        );
        assert!(s.contains('#'));
        assert!(s.contains("#=x"));
    }

    #[test]
    fn step_chart_empty() {
        let s = step_chart("T", &[("x".into(), vec![])], 40, 8);
        assert!(s.contains("empty"));
    }
}
