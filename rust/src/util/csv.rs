//! Minimal CSV emission for the results/ directory (figures are re-plotted
//! from these files; the ASCII charts are previews).

use std::io::Write;
use std::path::Path;

/// Write rows to a CSV file, escaping only what the report data needs
/// (commas and quotes).
pub fn write_csv<P: AsRef<Path>>(
    path: P,
    header: &[&str],
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for r in rows {
        let line: Vec<String> = r.iter().map(|c| escape(c)).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

fn escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes() {
        assert_eq!(escape("abc"), "abc");
        assert_eq!(escape("a,b"), "\"a,b\"");
        assert_eq!(escape("a\"b"), "\"a\"\"b\"");
    }

    #[test]
    fn writes_file() {
        let p = std::env::temp_dir().join("dmr_csv_test.csv");
        write_csv(&p, &["x", "y"], &[vec!["1".into(), "2,3".into()]]).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "x,y\n1,\"2,3\"\n");
        std::fs::remove_file(p).ok();
    }
}
