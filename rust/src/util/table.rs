//! ASCII table rendering for the evaluation reports (Tables 2–4).

/// Simple column-aligned ASCII table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self {
            header: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title<S: Into<String>>(mut self, t: S) -> Self {
        self.title = Some(t.into());
        self
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for wi in &w {
                s.push_str(&"-".repeat(wi + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["a", "bbbb"]).with_title("T");
        t.row(vec!["1", "2"]);
        t.row(vec!["333", "4"]);
        let s = t.render();
        assert!(s.starts_with("T\n"));
        assert!(s.contains("| a   | bbbb |"));
        assert!(s.contains("| 333 | 4    |"));
        // all lines same width
        let widths: Vec<usize> = s.lines().skip(1).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_mismatch_panics() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["1", "2"]);
    }
}
