//! The machine substrate: a cluster of identical compute nodes with an
//! allocation map.
//!
//! Substitutes the paper's testbed (Marenostrum: 2× 8-core Xeon E5-2670
//! per node, InfiniBand FDR10).  The paper's phenomena are scheduling-level
//! — what matters is the node count, who holds which nodes, and when they
//! are released; see DESIGN.md §2.
//!
//! The resilience engine ([`crate::resilience`]) adds two unavailability
//! flavors: `Down` (failed or offline for maintenance — never allocatable)
//! and `Draining` (still running its job, but released nodes go offline
//! instead of back to the free pool).

mod allocation;

pub use allocation::{AllocError, Cluster};

use crate::JobId;

/// State of one compute node.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NodeState {
    /// Free for allocation.
    Idle,
    /// Held by a job.
    Allocated(JobId),
    /// Held by a job, but scheduled for maintenance: the job finishes (or
    /// shrinks away from the node) and the node then goes `Down` instead
    /// of `Idle`.
    Draining(JobId),
    /// Offline: failed, or drained for maintenance.
    Down,
}

/// Number of nodes of the paper's evaluation partition (Fig. 6 peaks at
/// 64 allocated nodes).
pub const DEFAULT_NODES: usize = 64;

/// O(1) head-counts of one shard's node pool, snapshotted from its
/// [`Cluster`] counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolCounts {
    /// Nodes the shard owns.
    pub total: usize,
    /// Nodes currently free for allocation.
    pub available: usize,
    /// Nodes currently offline (failed or drained).
    pub down: usize,
}

/// Read-only aggregate over the shard-scoped node pools of a federation
/// ([`crate::federation`]): each shard keeps its own [`Cluster`], and
/// this view presents them as one machine for metrics and routing
/// decisions without merging the allocation maps.
#[derive(Debug, Clone, Default)]
pub struct FederatedView {
    shards: Vec<PoolCounts>,
}

impl FederatedView {
    /// Append one shard's pool (shard ids follow push order).
    pub fn push(&mut self, c: &Cluster) {
        self.shards.push(PoolCounts {
            total: c.total(),
            available: c.available(),
            down: c.down(),
        });
    }

    /// Number of shards in the view.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// Whether the view holds no shards.
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// One shard's counts, by shard id.
    pub fn shard(&self, i: usize) -> Option<&PoolCounts> {
        self.shards.get(i)
    }

    /// Total nodes across the federation.
    pub fn total(&self) -> usize {
        self.shards.iter().map(|s| s.total).sum()
    }

    /// Free nodes across the federation.
    pub fn available(&self) -> usize {
        self.shards.iter().map(|s| s.available).sum()
    }

    /// Offline nodes across the federation.
    pub fn down(&self) -> usize {
        self.shards.iter().map(|s| s.down).sum()
    }
}
