//! The machine substrate: a cluster of identical compute nodes with an
//! allocation map.
//!
//! Substitutes the paper's testbed (Marenostrum: 2× 8-core Xeon E5-2670
//! per node, InfiniBand FDR10).  The paper's phenomena are scheduling-level
//! — what matters is the node count, who holds which nodes, and when they
//! are released; see DESIGN.md §2.

mod allocation;

pub use allocation::{AllocError, Cluster};

use crate::JobId;

/// State of one compute node.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NodeState {
    /// Free for allocation.
    Idle,
    /// Held by a job.
    Allocated(JobId),
    /// Administratively removed (failure injection in tests).
    Down,
}

/// Number of nodes of the paper's evaluation partition (Fig. 6 peaks at
/// 64 allocated nodes).
pub const DEFAULT_NODES: usize = 64;
