//! The machine substrate: a cluster of identical compute nodes with an
//! allocation map.
//!
//! Substitutes the paper's testbed (Marenostrum: 2× 8-core Xeon E5-2670
//! per node, InfiniBand FDR10).  The paper's phenomena are scheduling-level
//! — what matters is the node count, who holds which nodes, and when they
//! are released; see DESIGN.md §2.
//!
//! The resilience engine ([`crate::resilience`]) adds two unavailability
//! flavors: `Down` (failed or offline for maintenance — never allocatable)
//! and `Draining` (still running its job, but released nodes go offline
//! instead of back to the free pool).

mod allocation;

pub use allocation::{AllocError, Cluster};

use crate::JobId;

/// State of one compute node.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NodeState {
    /// Free for allocation.
    Idle,
    /// Held by a job.
    Allocated(JobId),
    /// Held by a job, but scheduled for maintenance: the job finishes (or
    /// shrinks away from the node) and the node then goes `Down` instead
    /// of `Idle`.
    Draining(JobId),
    /// Offline: failed, or drained for maintenance.
    Down,
}

/// Number of nodes of the paper's evaluation partition (Fig. 6 peaks at
/// 64 allocated nodes).
pub const DEFAULT_NODES: usize = 64;
