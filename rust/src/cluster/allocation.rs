//! Node allocation map: the RMS-facing interface of the machine.

use std::collections::BTreeSet;

use super::NodeState;
use crate::{JobId, NodeId};

/// Allocation failure causes.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum AllocError {
    #[error("requested {requested} nodes but only {available} available")]
    Insufficient { requested: usize, available: usize },
    #[error("node {0} is not allocated to job {1}")]
    NotOwner(NodeId, JobId),
    #[error("node {0} is not idle")]
    NotIdle(NodeId),
}

/// A cluster of identical nodes.  Allocation is by count (the paper's
/// policies reason about node *numbers*, not topology); the free set is a
/// BTreeSet so allocations are deterministic (lowest ids first).
///
/// `allocated()` is answered from an incrementally maintained counter —
/// the scheduler snapshots it after every start/finish, so a scan over
/// `nodes` would make each simulated event O(cluster size).
#[derive(Debug, Clone)]
pub struct Cluster {
    nodes: Vec<NodeState>,
    free: BTreeSet<NodeId>,
    allocated: usize,
}

impl Cluster {
    pub fn new(n: usize) -> Self {
        Self { nodes: vec![NodeState::Idle; n], free: (0..n).collect(), allocated: 0 }
    }

    /// Total node count (including down nodes).
    pub fn total(&self) -> usize {
        self.nodes.len()
    }

    /// Currently allocatable node count.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Nodes currently held by jobs (O(1): maintained counter).
    pub fn allocated(&self) -> usize {
        self.allocated
    }

    pub fn state(&self, n: NodeId) -> &NodeState {
        &self.nodes[n]
    }

    /// Allocate `count` nodes to `job`. Deterministic: lowest free ids.
    pub fn alloc(&mut self, job: JobId, count: usize) -> Result<Vec<NodeId>, AllocError> {
        if self.free.len() < count {
            return Err(AllocError::Insufficient { requested: count, available: self.free.len() });
        }
        let mut picked = Vec::with_capacity(count);
        for _ in 0..count {
            let n = self.free.pop_first().expect("free count checked above");
            self.nodes[n] = NodeState::Allocated(job);
            picked.push(n);
        }
        self.allocated += count;
        Ok(picked)
    }

    /// Release specific nodes held by `job` (the shrink path releases a
    /// chosen suffix of the job's node list).
    pub fn release(&mut self, job: JobId, nodes: &[NodeId]) -> Result<(), AllocError> {
        for &n in nodes {
            match self.nodes[n] {
                NodeState::Allocated(j) if j == job => {}
                _ => return Err(AllocError::NotOwner(n, job)),
            }
        }
        for &n in nodes {
            self.nodes[n] = NodeState::Idle;
            self.free.insert(n);
        }
        self.allocated -= nodes.len();
        Ok(())
    }

    /// Re-assign nodes from one job to another *without* freeing them —
    /// the Slurm resizer-job trick (§3): job B's allocation is handed to
    /// job A with no gap during which another job could steal the nodes.
    pub fn transfer(&mut self, from: JobId, to: JobId, nodes: &[NodeId]) -> Result<(), AllocError> {
        for &n in nodes {
            match self.nodes[n] {
                NodeState::Allocated(j) if j == from => {}
                _ => return Err(AllocError::NotOwner(n, from)),
            }
        }
        for &n in nodes {
            self.nodes[n] = NodeState::Allocated(to);
        }
        Ok(())
    }

    /// Mark a node down (test/failure injection). Must be idle.
    pub fn set_down(&mut self, n: NodeId) -> Result<(), AllocError> {
        if self.nodes[n] != NodeState::Idle {
            return Err(AllocError::NotIdle(n));
        }
        self.free.remove(&n);
        self.nodes[n] = NodeState::Down;
        Ok(())
    }

    /// Bring a down node back.
    pub fn set_up(&mut self, n: NodeId) {
        if self.nodes[n] == NodeState::Down {
            self.nodes[n] = NodeState::Idle;
            self.free.insert(n);
        }
    }

    /// Internal consistency check (used by property tests).
    pub fn check_invariants(&self) -> bool {
        let idle = self.nodes.iter().filter(|s| **s == NodeState::Idle).count();
        let alloc = self.nodes.iter().filter(|s| matches!(s, NodeState::Allocated(_))).count();
        idle == self.free.len()
            && alloc == self.allocated
            && self.free.iter().all(|&n| self.nodes[n] == NodeState::Idle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut c = Cluster::new(8);
        assert_eq!(c.available(), 8);
        let got = c.alloc(1, 3).unwrap();
        assert_eq!(got, vec![0, 1, 2]);
        assert_eq!(c.available(), 5);
        assert_eq!(c.allocated(), 3);
        c.release(1, &got).unwrap();
        assert_eq!(c.available(), 8);
        assert!(c.check_invariants());
    }

    #[test]
    fn insufficient() {
        let mut c = Cluster::new(4);
        c.alloc(1, 3).unwrap();
        let err = c.alloc(2, 2).unwrap_err();
        assert_eq!(err, AllocError::Insufficient { requested: 2, available: 1 });
    }

    #[test]
    fn release_wrong_owner_rejected() {
        let mut c = Cluster::new(4);
        let n = c.alloc(1, 2).unwrap();
        assert!(c.release(2, &n).is_err());
        // failed release must not mutate
        assert_eq!(c.allocated(), 2);
        assert!(c.check_invariants());
    }

    #[test]
    fn transfer_keeps_nodes_allocated() {
        let mut c = Cluster::new(4);
        let n = c.alloc(7, 2).unwrap();
        c.transfer(7, 9, &n).unwrap();
        assert_eq!(*c.state(n[0]), NodeState::Allocated(9));
        assert_eq!(c.available(), 2);
        c.release(9, &n).unwrap();
        assert!(c.check_invariants());
    }

    #[test]
    fn down_nodes_unavailable() {
        let mut c = Cluster::new(4);
        c.set_down(0).unwrap();
        assert_eq!(c.available(), 3);
        let got = c.alloc(1, 3).unwrap();
        assert_eq!(got, vec![1, 2, 3]);
        c.set_up(0);
        assert_eq!(c.available(), 1);
        assert!(c.check_invariants());
    }

    #[test]
    fn allocated_counter_tracks_transfer_and_release() {
        let mut c = Cluster::new(8);
        let a = c.alloc(1, 3).unwrap();
        let b = c.alloc(2, 2).unwrap();
        assert_eq!(c.allocated(), 5);
        // transfer moves ownership without changing the allocated count
        c.transfer(2, 1, &b).unwrap();
        assert_eq!(c.allocated(), 5);
        c.release(1, &b).unwrap();
        assert_eq!(c.allocated(), 3);
        // failed release must not touch the counter
        assert!(c.release(9, &a).is_err());
        assert_eq!(c.allocated(), 3);
        assert!(c.check_invariants());
    }

    #[test]
    fn down_requires_idle() {
        let mut c = Cluster::new(2);
        c.alloc(1, 1).unwrap();
        assert!(c.set_down(0).is_err());
    }
}
