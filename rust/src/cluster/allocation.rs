//! Node allocation map: the RMS-facing interface of the machine.

use std::collections::BTreeSet;

use super::NodeState;
use crate::{JobId, NodeId};

/// Allocation failure causes.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum AllocError {
    #[error("requested {requested} nodes but only {available} available")]
    Insufficient { requested: usize, available: usize },
    #[error("node {0} is not allocated to job {1}")]
    NotOwner(NodeId, JobId),
    #[error("node {0} is not idle")]
    NotIdle(NodeId),
}

/// A cluster of identical nodes.  Allocation is by count (the paper's
/// policies reason about node *numbers*, not topology); the free set is a
/// BTreeSet so allocations are deterministic (lowest ids first).
///
/// `allocated()` and `down()` are answered from incrementally maintained
/// counters — the scheduler snapshots the former after every start/finish
/// and the resilience engine integrates the latter after every event, so
/// a scan over `nodes` would make each simulated event O(cluster size).
/// `allocated()` counts `Allocated` *and* `Draining` nodes (both are held
/// by jobs); `down()` counts only `Down` nodes.
///
/// `version()` is a monotonic mutation counter bumped by every
/// state-changing method; the RMS folds it into the stamp that lets
/// no-op scheduling passes be elided (equal stamps ⇒ the free pool
/// cannot have changed).
#[derive(Debug, Clone)]
pub struct Cluster {
    nodes: Vec<NodeState>,
    free: BTreeSet<NodeId>,
    allocated: usize,
    down_count: usize,
    version: u64,
}

impl Cluster {
    pub fn new(n: usize) -> Self {
        Self {
            nodes: vec![NodeState::Idle; n],
            free: (0..n).collect(),
            allocated: 0,
            down_count: 0,
            version: 0,
        }
    }

    /// Monotonic mutation counter (bumped by every `&mut self` method,
    /// including failed attempts — conservative is cheap and always
    /// sound for cache invalidation).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Total node count (including down nodes).
    pub fn total(&self) -> usize {
        self.nodes.len()
    }

    /// Currently allocatable node count.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Nodes currently held by jobs, draining included (O(1) counter).
    pub fn allocated(&self) -> usize {
        self.allocated
    }

    /// Nodes currently offline (O(1) counter).
    pub fn down(&self) -> usize {
        self.down_count
    }

    pub fn state(&self, n: NodeId) -> &NodeState {
        &self.nodes[n]
    }

    /// Allocate `count` nodes to `job`. Deterministic: lowest free ids.
    pub fn alloc(&mut self, job: JobId, count: usize) -> Result<Vec<NodeId>, AllocError> {
        self.version += 1;
        if self.free.len() < count {
            return Err(AllocError::Insufficient { requested: count, available: self.free.len() });
        }
        let mut picked = Vec::with_capacity(count);
        for _ in 0..count {
            let n = self.free.pop_first().expect("free count checked above");
            self.nodes[n] = NodeState::Allocated(job);
            picked.push(n);
        }
        self.allocated += count;
        Ok(picked)
    }

    /// Release specific nodes held by `job` (the shrink path releases a
    /// chosen suffix of the job's node list).  Draining nodes go offline
    /// instead of back to the free pool — the drain's whole point.
    pub fn release(&mut self, job: JobId, nodes: &[NodeId]) -> Result<(), AllocError> {
        self.version += 1;
        for &n in nodes {
            match self.nodes[n] {
                NodeState::Allocated(j) | NodeState::Draining(j) if j == job => {}
                _ => return Err(AllocError::NotOwner(n, job)),
            }
        }
        for &n in nodes {
            if matches!(self.nodes[n], NodeState::Draining(_)) {
                self.nodes[n] = NodeState::Down;
                self.down_count += 1;
            } else {
                self.nodes[n] = NodeState::Idle;
                self.free.insert(n);
            }
        }
        self.allocated -= nodes.len();
        Ok(())
    }

    /// Re-assign nodes from one job to another *without* freeing them —
    /// the Slurm resizer-job trick (§3): job B's allocation is handed to
    /// job A with no gap during which another job could steal the nodes.
    pub fn transfer(&mut self, from: JobId, to: JobId, nodes: &[NodeId]) -> Result<(), AllocError> {
        self.version += 1;
        for &n in nodes {
            match self.nodes[n] {
                NodeState::Allocated(j) if j == from => {}
                _ => return Err(AllocError::NotOwner(n, from)),
            }
        }
        for &n in nodes {
            self.nodes[n] = NodeState::Allocated(to);
        }
        Ok(())
    }

    /// Mark a node down (test/failure injection). Must be idle.
    pub fn set_down(&mut self, n: NodeId) -> Result<(), AllocError> {
        self.version += 1;
        if self.nodes[n] != NodeState::Idle {
            return Err(AllocError::NotIdle(n));
        }
        self.free.remove(&n);
        self.nodes[n] = NodeState::Down;
        self.down_count += 1;
        Ok(())
    }

    /// Fail a node regardless of state.  Returns the job that held it (the
    /// failure's victim), if any; the caller must repair the victim's
    /// bookkeeping (the node is gone from the machine's point of view).
    pub fn force_down(&mut self, n: NodeId) -> Option<JobId> {
        self.version += 1;
        match self.nodes[n] {
            NodeState::Idle => {
                self.free.remove(&n);
                self.nodes[n] = NodeState::Down;
                self.down_count += 1;
                None
            }
            NodeState::Down => None,
            NodeState::Allocated(j) | NodeState::Draining(j) => {
                self.nodes[n] = NodeState::Down;
                self.allocated -= 1;
                self.down_count += 1;
                Some(j)
            }
        }
    }

    /// Start draining a node: idle nodes go offline immediately (returns
    /// `true`); allocated nodes keep running their job and go offline on
    /// release.  Down nodes are untouched.
    pub fn begin_drain(&mut self, n: NodeId) -> bool {
        self.version += 1;
        match self.nodes[n] {
            NodeState::Idle => {
                self.free.remove(&n);
                self.nodes[n] = NodeState::Down;
                self.down_count += 1;
                true
            }
            NodeState::Allocated(j) => {
                self.nodes[n] = NodeState::Draining(j);
                false
            }
            NodeState::Draining(_) | NodeState::Down => false,
        }
    }

    /// End a drain: offline nodes come back to the free pool (returns
    /// `true`), still-draining nodes return to plain `Allocated`.
    pub fn end_drain(&mut self, n: NodeId) -> bool {
        self.version += 1;
        match self.nodes[n] {
            NodeState::Down => {
                self.nodes[n] = NodeState::Idle;
                self.free.insert(n);
                self.down_count -= 1;
                true
            }
            NodeState::Draining(j) => {
                self.nodes[n] = NodeState::Allocated(j);
                false
            }
            _ => false,
        }
    }

    /// Bring a down node back.
    pub fn set_up(&mut self, n: NodeId) {
        self.version += 1;
        if self.nodes[n] == NodeState::Down {
            self.nodes[n] = NodeState::Idle;
            self.free.insert(n);
            self.down_count -= 1;
        }
    }

    /// Internal consistency check (used by property tests).
    pub fn check_invariants(&self) -> bool {
        let idle = self.nodes.iter().filter(|s| **s == NodeState::Idle).count();
        let alloc = self
            .nodes
            .iter()
            .filter(|s| matches!(s, NodeState::Allocated(_) | NodeState::Draining(_)))
            .count();
        let down = self.nodes.iter().filter(|s| **s == NodeState::Down).count();
        idle == self.free.len()
            && alloc == self.allocated
            && down == self.down_count
            && self.free.iter().all(|&n| self.nodes[n] == NodeState::Idle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut c = Cluster::new(8);
        assert_eq!(c.available(), 8);
        let got = c.alloc(1, 3).unwrap();
        assert_eq!(got, vec![0, 1, 2]);
        assert_eq!(c.available(), 5);
        assert_eq!(c.allocated(), 3);
        c.release(1, &got).unwrap();
        assert_eq!(c.available(), 8);
        assert!(c.check_invariants());
    }

    #[test]
    fn insufficient() {
        let mut c = Cluster::new(4);
        c.alloc(1, 3).unwrap();
        let err = c.alloc(2, 2).unwrap_err();
        assert_eq!(err, AllocError::Insufficient { requested: 2, available: 1 });
    }

    #[test]
    fn release_wrong_owner_rejected() {
        let mut c = Cluster::new(4);
        let n = c.alloc(1, 2).unwrap();
        assert!(c.release(2, &n).is_err());
        // failed release must not mutate
        assert_eq!(c.allocated(), 2);
        assert!(c.check_invariants());
    }

    #[test]
    fn transfer_keeps_nodes_allocated() {
        let mut c = Cluster::new(4);
        let n = c.alloc(7, 2).unwrap();
        c.transfer(7, 9, &n).unwrap();
        assert_eq!(*c.state(n[0]), NodeState::Allocated(9));
        assert_eq!(c.available(), 2);
        c.release(9, &n).unwrap();
        assert!(c.check_invariants());
    }

    #[test]
    fn down_nodes_unavailable() {
        let mut c = Cluster::new(4);
        c.set_down(0).unwrap();
        assert_eq!(c.available(), 3);
        assert_eq!(c.down(), 1);
        let got = c.alloc(1, 3).unwrap();
        assert_eq!(got, vec![1, 2, 3]);
        c.set_up(0);
        assert_eq!(c.available(), 1);
        assert_eq!(c.down(), 0);
        assert!(c.check_invariants());
    }

    #[test]
    fn allocated_counter_tracks_transfer_and_release() {
        let mut c = Cluster::new(8);
        let a = c.alloc(1, 3).unwrap();
        let b = c.alloc(2, 2).unwrap();
        assert_eq!(c.allocated(), 5);
        // transfer moves ownership without changing the allocated count
        c.transfer(2, 1, &b).unwrap();
        assert_eq!(c.allocated(), 5);
        c.release(1, &b).unwrap();
        assert_eq!(c.allocated(), 3);
        // failed release must not touch the counter
        assert!(c.release(9, &a).is_err());
        assert_eq!(c.allocated(), 3);
        assert!(c.check_invariants());
    }

    #[test]
    fn version_bumps_on_mutation() {
        let mut c = Cluster::new(4);
        let v0 = c.version();
        let n = c.alloc(1, 2).unwrap();
        assert!(c.version() > v0, "alloc must bump the version");
        let v1 = c.version();
        c.release(1, &n).unwrap();
        assert!(c.version() > v1, "release must bump the version");
    }

    #[test]
    fn down_requires_idle() {
        let mut c = Cluster::new(2);
        c.alloc(1, 1).unwrap();
        assert!(c.set_down(0).is_err());
    }

    #[test]
    fn force_down_evicts_the_holder() {
        let mut c = Cluster::new(4);
        let n = c.alloc(3, 2).unwrap();
        assert_eq!(c.force_down(n[0]), Some(3));
        assert_eq!(*c.state(n[0]), NodeState::Down);
        assert_eq!(c.allocated(), 1);
        assert_eq!(c.down(), 1);
        // the machine no longer tracks the node for job 3: releasing the
        // survivor only
        c.release(3, &n[1..]).unwrap();
        assert_eq!(c.allocated(), 0);
        // idle and already-down nodes have no victim
        assert_eq!(c.force_down(3), None);
        assert_eq!(c.force_down(n[0]), None, "double fail is a no-op");
        assert_eq!(c.down(), 2);
        assert!(c.check_invariants());
    }

    #[test]
    fn drain_lifecycle() {
        let mut c = Cluster::new(4);
        let n = c.alloc(1, 2).unwrap(); // nodes 0, 1
        // idle node drains offline immediately
        assert!(c.begin_drain(2));
        assert_eq!(*c.state(2), NodeState::Down);
        assert_eq!(c.available(), 1);
        // allocated node keeps its job
        assert!(!c.begin_drain(n[0]));
        assert_eq!(*c.state(n[0]), NodeState::Draining(1));
        assert_eq!(c.allocated(), 2, "draining still counts as held");
        assert!(c.check_invariants());

        // the job finishes: the draining node goes down, the other frees
        c.release(1, &n).unwrap();
        assert_eq!(*c.state(n[0]), NodeState::Down);
        assert_eq!(*c.state(n[1]), NodeState::Idle);
        assert_eq!(c.down(), 2);
        assert_eq!(c.available(), 2);

        // window ends: both drained nodes return
        assert!(c.end_drain(2));
        assert!(c.end_drain(n[0]));
        assert_eq!(c.available(), 4);
        assert_eq!(c.down(), 0);
        assert!(c.check_invariants());
    }

    #[test]
    fn end_drain_mid_job_restores_allocated() {
        let mut c = Cluster::new(2);
        let n = c.alloc(9, 1).unwrap();
        c.begin_drain(n[0]);
        assert_eq!(*c.state(n[0]), NodeState::Draining(9));
        assert!(!c.end_drain(n[0]), "no capacity freed");
        assert_eq!(*c.state(n[0]), NodeState::Allocated(9));
        // a later release now frees normally
        c.release(9, &n).unwrap();
        assert_eq!(c.available(), 2);
        assert!(c.check_invariants());
    }

    #[test]
    fn draining_node_can_fail() {
        let mut c = Cluster::new(2);
        let n = c.alloc(4, 2).unwrap();
        c.begin_drain(n[0]);
        assert_eq!(c.force_down(n[0]), Some(4));
        assert_eq!(c.allocated(), 1);
        assert_eq!(c.down(), 1);
        assert!(c.check_invariants());
    }
}
