//! Engine self-profiling: fixed-size wall-clock counters for the DES hot
//! path.
//!
//! The profile answers "where does the simulator burn host time" — event
//! dispatch overall, scheduling passes, DMR policy calls — with nothing
//! but fixed arrays of monotonic counters: no RNG, no heap allocation,
//! no branching on simulation state.  Recording therefore cannot perturb
//! the simulation (the inertness contract in `docs/ARCHITECTURE.md`);
//! the *values* are host-timing noise, so they are reported only through
//! non-deterministic channels (the campaign stdout table, `BENCH_*.json`,
//! trace/profile files) — never the worker-count-invariant CSVs, which
//! carry the deterministic [`crate::rms::PassStats`] counters instead.

/// Latency-histogram bucket count (power-of-two nanosecond buckets:
/// bucket `i` holds durations in `[2^i, 2^(i+1))` ns, the last bucket is
/// open-ended).
pub const HIST_BUCKETS: usize = 32;

/// The instrumented engine phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// One whole event dispatch (the engine's match arm), the superset of
    /// the other phases — its wall total is the run's measured wall.
    Dispatch = 0,
    /// An RMS scheduling pass (`Rms::schedule`), elided passes included.
    Schedule = 1,
    /// A DMR policy evaluation (`dmr_check` / `dmr_peek` + `dmr_apply`).
    Dmr = 2,
}

impl Phase {
    /// Every phase, in index order.
    pub const ALL: [Phase; 3] = [Phase::Dispatch, Phase::Schedule, Phase::Dmr];

    /// Number of phases (array dimension).
    pub const COUNT: usize = 3;

    /// Short label used in reports (`dispatch`, `sched`, `dmr`).
    pub fn label(self) -> &'static str {
        match self {
            Phase::Dispatch => "dispatch",
            Phase::Schedule => "sched",
            Phase::Dmr => "dmr",
        }
    }
}

/// Per-phase wall-clock totals + call counts + a dispatch-latency
/// histogram.  All counters are monotone under [`PhaseProfile::record`];
/// merging two profiles adds them field-wise.
#[derive(Debug, Clone)]
pub struct PhaseProfile {
    wall_ns: [u64; Phase::COUNT],
    calls: [u64; Phase::COUNT],
    hist: [u64; HIST_BUCKETS],
}

impl Default for PhaseProfile {
    fn default() -> Self {
        PhaseProfile {
            wall_ns: [0; Phase::COUNT],
            calls: [0; Phase::COUNT],
            hist: [0; HIST_BUCKETS],
        }
    }
}

impl PhaseProfile {
    /// A zeroed profile.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one timed call of `phase` lasting `ns` nanoseconds.
    /// Dispatch calls also land in the latency histogram.
    #[inline]
    pub fn record(&mut self, phase: Phase, ns: u64) {
        let i = phase as usize;
        self.wall_ns[i] += ns;
        self.calls[i] += 1;
        if matches!(phase, Phase::Dispatch) {
            self.hist[Self::bucket_of(ns)] += 1;
        }
    }

    /// Histogram bucket index of a duration (`floor(log2 ns)`, clamped).
    #[inline]
    fn bucket_of(ns: u64) -> usize {
        if ns == 0 {
            0
        } else {
            ((63 - ns.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
        }
    }

    /// Total wall time recorded for `phase`, nanoseconds.
    pub fn wall_ns(&self, phase: Phase) -> u64 {
        self.wall_ns[phase as usize]
    }

    /// Calls recorded for `phase`.
    pub fn calls(&self, phase: Phase) -> u64 {
        self.calls[phase as usize]
    }

    /// Total measured wall (the dispatch phase), nanoseconds.
    pub fn total_ns(&self) -> u64 {
        self.wall_ns[Phase::Dispatch as usize]
    }

    /// Share of the measured wall spent in `phase` (`0.0` when nothing
    /// was recorded; `Dispatch` reports `1.0`).
    pub fn share(&self, phase: Phase) -> f64 {
        let total = self.total_ns();
        if total == 0 {
            0.0
        } else {
            self.wall_ns(phase) as f64 / total as f64
        }
    }

    /// Wall-clock event throughput given the run's processed-event count
    /// (`0.0` before anything was recorded).
    pub fn events_per_sec(&self, events: u64) -> f64 {
        let total = self.total_ns();
        if total == 0 {
            0.0
        } else {
            events as f64 * 1e9 / total as f64
        }
    }

    /// The dispatch-latency histogram (power-of-two ns buckets).
    pub fn histogram(&self) -> &[u64; HIST_BUCKETS] {
        &self.hist
    }

    /// Add another profile's counters into this one (federated runs and
    /// campaign aggregation).
    pub fn merge(&mut self, other: &PhaseProfile) {
        for i in 0..Phase::COUNT {
            self.wall_ns[i] += other.wall_ns[i];
            self.calls[i] += other.calls[i];
        }
        for i in 0..HIST_BUCKETS {
            self.hist[i] += other.hist[i];
        }
    }

    /// One human-readable summary line (stderr diagnostics and the
    /// `repro trace` report): events/s plus per-phase shares.
    pub fn summary_line(&self, events: u64) -> String {
        format!(
            "{:.0} events/s wall={:.3}s sched={:.1}% dmr={:.1}%",
            self.events_per_sec(events),
            self.total_ns() as f64 / 1e9,
            100.0 * self.share(Phase::Schedule),
            100.0 * self.share(Phase::Dmr),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates_monotonically() {
        let mut p = PhaseProfile::new();
        p.record(Phase::Dispatch, 100);
        p.record(Phase::Dispatch, 50);
        p.record(Phase::Schedule, 30);
        assert_eq!(p.calls(Phase::Dispatch), 2);
        assert_eq!(p.wall_ns(Phase::Dispatch), 150);
        assert_eq!(p.calls(Phase::Schedule), 1);
        assert_eq!(p.total_ns(), 150);
        assert!((p.share(Phase::Schedule) - 0.2).abs() < 1e-12);
        assert!((p.share(Phase::Dispatch) - 1.0).abs() < 1e-12);
        // Histogram counts only dispatch calls.
        let hist_total: u64 = p.histogram().iter().sum();
        assert_eq!(hist_total, 2);
    }

    #[test]
    fn bucket_of_is_log2() {
        assert_eq!(PhaseProfile::bucket_of(0), 0);
        assert_eq!(PhaseProfile::bucket_of(1), 0);
        assert_eq!(PhaseProfile::bucket_of(2), 1);
        assert_eq!(PhaseProfile::bucket_of(1023), 9);
        assert_eq!(PhaseProfile::bucket_of(1024), 10);
        assert_eq!(PhaseProfile::bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn merge_adds_fieldwise() {
        let mut a = PhaseProfile::new();
        a.record(Phase::Dispatch, 1000);
        let mut b = PhaseProfile::new();
        b.record(Phase::Dispatch, 500);
        b.record(Phase::Dmr, 200);
        a.merge(&b);
        assert_eq!(a.wall_ns(Phase::Dispatch), 1500);
        assert_eq!(a.calls(Phase::Dispatch), 2);
        assert_eq!(a.wall_ns(Phase::Dmr), 200);
    }

    #[test]
    fn events_per_sec_uses_dispatch_wall() {
        let mut p = PhaseProfile::new();
        p.record(Phase::Dispatch, 1_000_000_000);
        assert!((p.events_per_sec(2_000) - 2_000.0).abs() < 1e-9);
        assert_eq!(PhaseProfile::new().events_per_sec(10), 0.0);
    }
}
