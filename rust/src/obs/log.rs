//! Env-filtered diagnostic logging (`DMR_LOG=off|warn|info|debug`).
//!
//! One tiny helper replaces the ad-hoc `eprintln!` diagnostics scattered
//! through the crate: every message carries a [`Level`], the threshold is
//! read **once** from the `DMR_LOG` environment variable (default
//! [`Level::Warn`], so existing one-shot warnings keep printing), and
//! everything below the threshold is dropped before any formatting cost.
//! Messages go to stderr — stdout stays reserved for machine-readable
//! report output (tables, CSV paths).
//!
//! This is diagnostics-only plumbing: nothing here is read back by the
//! engine, so it can never perturb the simulation (see the inertness
//! contract in `docs/ARCHITECTURE.md`).

use std::sync::OnceLock;

/// Message severity, ordered from most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Logging disabled (`DMR_LOG=off`); nothing prints, not even warnings.
    Off = 0,
    /// Actionable problems (ignored env vars, clamped knobs).  The default
    /// threshold — matches the crate's historical unconditional warnings.
    Warn = 1,
    /// Progress and configuration notes (`DMR_LOG=info`).
    Info = 2,
    /// Verbose diagnostics (`DMR_LOG=debug`).
    Debug = 3,
}

impl Level {
    /// Parse a `DMR_LOG` value; unknown strings fall back to `Warn` so a
    /// typo can never silence real warnings.
    fn parse(s: &str) -> Level {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" | "silent" => Level::Off,
            "info" => Level::Info,
            "debug" => Level::Debug,
            _ => Level::Warn,
        }
    }

    /// Label used in the stderr prefix.
    fn tag(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Warn => "warning",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

static THRESHOLD: OnceLock<Level> = OnceLock::new();

/// The active threshold: parsed from `DMR_LOG` on first use, then cached
/// for the life of the process.
pub fn threshold() -> Level {
    *THRESHOLD.get_or_init(|| match std::env::var("DMR_LOG") {
        Ok(v) => Level::parse(&v),
        Err(_) => Level::Warn,
    })
}

/// Whether messages at `level` would currently print — check this before
/// building an expensive message.
pub fn enabled(level: Level) -> bool {
    level <= threshold() && threshold() != Level::Off && level != Level::Off
}

/// Emit one message at `level` (dropped when below the threshold).
pub fn log(level: Level, msg: &str) {
    if enabled(level) {
        eprintln!("dmr: {}: {msg}", level.tag());
    }
}

/// Emit a warning (prints under the default threshold).
pub fn warn(msg: &str) {
    log(Level::Warn, msg);
}

/// Emit an informational note (`DMR_LOG=info` or `debug`).
pub fn info(msg: &str) {
    log(Level::Info, msg);
}

/// Emit a verbose diagnostic (`DMR_LOG=debug` only).
pub fn debug(msg: &str) {
    log(Level::Debug, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_maps_known_names() {
        assert_eq!(Level::parse("off"), Level::Off);
        assert_eq!(Level::parse("NONE"), Level::Off);
        assert_eq!(Level::parse("warn"), Level::Warn);
        assert_eq!(Level::parse("info"), Level::Info);
        assert_eq!(Level::parse(" debug "), Level::Debug);
        // A typo must not silence warnings.
        assert_eq!(Level::parse("verbose"), Level::Warn);
        assert_eq!(Level::parse(""), Level::Warn);
    }

    #[test]
    fn severity_ordering() {
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Off < Level::Warn);
    }

    #[test]
    fn threshold_defaults_to_warn_without_env() {
        // The suite does not set DMR_LOG, so the cached threshold is the
        // default and warnings are enabled while info/debug are not.
        // (If a developer runs tests with DMR_LOG set, only the
        // always-true implications are asserted.)
        let t = threshold();
        if t == Level::Warn {
            assert!(enabled(Level::Warn));
            assert!(!enabled(Level::Info));
            assert!(!enabled(Level::Debug));
        }
        assert!(!enabled(Level::Off), "Off is never an emit level");
    }
}
