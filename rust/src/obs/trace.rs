//! Span tracing: per-job lifecycle and per-shard machine-fault timelines
//! derived **post-run** from the [`EventLog`], exported as Chrome-trace /
//! Perfetto JSON plus a compact JSONL.
//!
//! ## Inertness by construction
//!
//! Nothing here runs during the simulation.  The event log always exists
//! and is digest-locked (`EventLog::digest`), and the builder only *reads*
//! it after the run completes — so enabling tracing cannot draw from any
//! RNG stream, reorder any event, or change a single bit of the run
//! (locked anyway by the trace-on/off matrix in `rust/tests/test_obs.rs`).
//!
//! ## Span model
//!
//! One Chrome-trace *process* per shard track pair: pid `2s+1` holds the
//! shard's job tracks (one *thread* per job id), pid `2s+2` its machine
//! tracks (one thread per node).  Spans:
//!
//! * `pending` — `Submitted`/`Requeued`/start-of-wait → `Started`
//!   (or `Stolen`, which moves the wait to another shard).
//! * `running` — `Started` → `Finished` or `Requeued`.  The number of
//!   exported `running` spans equals jobs completed + failure requeues.
//! * `resize` — `ResizeBegin` → `ResizeCommit`/`ResizeAbort`, nested
//!   inside the owning `running` span (multi-phase transaction path).
//! * `down` / `drain` — `NodeFailed` → `NodeRepaired`,
//!   `DrainStarted` → `DrainEnded` per node (outages nest; the span
//!   covers the whole nested outage).
//!
//! Commits, aborts, faults and recovery land as instant events on the
//! owning track: `expanded`, `shrunk`, `expand-aborted`, `interrupted`,
//! `rescued`, `requeued`, `resize-aborted`, `degraded`, `stolen`,
//! `cancelled`.
//!
//! ## Bounded memory
//!
//! [`TraceConfig::stride`] keeps every k-th job track and
//! [`TraceConfig::cap`] bounds the total number of job tracks, so trace
//! size is controlled independently of workload size; the writers stream
//! span-by-span through `io::Write` (no JSON tree is ever built).

use std::collections::{HashMap, HashSet};
use std::io::{self, Write};
use std::path::{Path, PathBuf};

use crate::rms::{EventLog, RmsEvent};
use crate::Time;

/// Tracing knobs (off by default; zero work is done when disabled).
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Master switch — `false` means no trace is built at all.
    pub enabled: bool,
    /// Keep every `stride`-th job track (1 = every job; 0 is treated
    /// as 1).  Applied to jobs in first-submission order across shards.
    pub stride: usize,
    /// Upper bound on kept job tracks across all shards (0 = unlimited).
    pub cap: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: false, stride: 1, cap: 0 }
    }
}

impl TraceConfig {
    /// An enabled config with default stride/cap.
    pub fn on() -> Self {
        TraceConfig { enabled: true, ..Default::default() }
    }
}

/// Optional numeric argument attached to a span or instant.
type Arg = Option<(&'static str, f64)>;

/// One closed span on a (pid, tid) track.
#[derive(Debug, Clone)]
struct Span {
    pid: u32,
    tid: u64,
    name: &'static str,
    begin: Time,
    end: Time,
    args: [Arg; 2],
}

/// One instant event on a (pid, tid) track.
#[derive(Debug, Clone)]
struct Mark {
    pid: u32,
    tid: u64,
    name: &'static str,
    t: Time,
    args: [Arg; 2],
}

/// Summary counts of a built trace (test hooks + the `repro trace`
/// report line).
#[derive(Debug, Clone, Copy, Default)]
pub struct TraceStats {
    /// Total exported spans (job + machine).
    pub spans: usize,
    /// Exported `running` spans — equals jobs completed + failure
    /// requeues on the kept tracks.
    pub job_spans: usize,
    /// Exported instant events.
    pub instants: usize,
    /// Distinct jobs observed across all shards.
    pub job_tracks_total: usize,
    /// Job tracks kept after stride/cap filtering.
    pub job_tracks_kept: usize,
}

/// A fully-built trace, ready to stream to disk.
#[derive(Debug, Clone)]
pub struct Trace {
    spans: Vec<Span>,
    marks: Vec<Mark>,
    shards: usize,
    stats: TraceStats,
}

/// Per-job builder state during the event walk.
#[derive(Debug, Clone, Copy, Default)]
struct JobState {
    pending_since: Option<Time>,
    running_since: Option<Time>,
    resize_since: Option<Time>,
    resize_from: usize,
    resize_to: usize,
}

impl Trace {
    /// Build from one event log per shard (`logs[s]` is shard `s`).
    /// `end` closes any span still open when the run drained (a node
    /// still down, a drain window outliving the last completion).
    pub fn from_logs(logs: &[&EventLog], end: Time, cfg: &TraceConfig) -> Trace {
        // Pass 1: enumerate jobs in first-appearance order (across shards
        // in shard order) and pick the kept set via stride/cap.
        let stride = cfg.stride.max(1);
        let mut seen: HashSet<(usize, u64)> = HashSet::new();
        let mut keep: HashSet<(usize, u64)> = HashSet::new();
        let mut total = 0usize;
        for (s, log) in logs.iter().enumerate() {
            for ev in log.all() {
                if let Some(job) = job_of(ev) {
                    if seen.insert((s, job)) {
                        let kept = total % stride == 0
                            && (cfg.cap == 0 || keep.len() < cfg.cap);
                        total += 1;
                        if kept {
                            keep.insert((s, job));
                        }
                    }
                }
            }
        }

        let mut spans = Vec::new();
        let mut marks = Vec::new();
        let mut job_spans = 0usize;

        // Pass 2: per-shard state machines over the kept jobs + machine.
        for (s, log) in logs.iter().enumerate() {
            let job_pid = (2 * s + 1) as u32;
            let machine_pid = (2 * s + 2) as u32;
            let mut jobs: HashMap<u64, JobState> = HashMap::new();
            // Per-node outage nesting depth and open-span starts.
            let mut fail_depth: HashMap<usize, (u32, Time)> = HashMap::new();
            let mut drain_depth: HashMap<usize, (u32, Time)> = HashMap::new();
            for ev in log.all() {
                match *ev {
                    RmsEvent::Submitted { job, time } => {
                        if keep.contains(&(s, job)) {
                            jobs.entry(job).or_default().pending_since = Some(time);
                        }
                    }
                    RmsEvent::Started { job, time, procs } => {
                        let Some(j) = kept_job(&mut jobs, &keep, s, job) else { continue };
                        if let Some(b) = j.pending_since.take() {
                            spans.push(Span {
                                pid: job_pid,
                                tid: job,
                                name: "pending",
                                begin: b,
                                end: time,
                                args: [None, None],
                            });
                        }
                        j.running_since = Some(time);
                        marks.push(Mark {
                            pid: job_pid,
                            tid: job,
                            name: "started",
                            t: time,
                            args: [Some(("procs", procs as f64)), None],
                        });
                    }
                    RmsEvent::Finished { job, time } => {
                        let Some(j) = kept_job(&mut jobs, &keep, s, job) else { continue };
                        close_resize(&mut spans, job_pid, job, j, time);
                        if let Some(b) = j.running_since.take() {
                            spans.push(Span {
                                pid: job_pid,
                                tid: job,
                                name: "running",
                                begin: b,
                                end: time,
                                args: [None, None],
                            });
                            job_spans += 1;
                        }
                    }
                    RmsEvent::Requeued { job, time } => {
                        let Some(j) = kept_job(&mut jobs, &keep, s, job) else { continue };
                        close_resize(&mut spans, job_pid, job, j, time);
                        if let Some(b) = j.running_since.take() {
                            spans.push(Span {
                                pid: job_pid,
                                tid: job,
                                name: "running",
                                begin: b,
                                end: time,
                                args: [None, None],
                            });
                            job_spans += 1;
                        }
                        j.pending_since = Some(time);
                        marks.push(Mark {
                            pid: job_pid,
                            tid: job,
                            name: "requeued",
                            t: time,
                            args: [None, None],
                        });
                    }
                    RmsEvent::Cancelled { job, time } => {
                        let Some(j) = kept_job(&mut jobs, &keep, s, job) else { continue };
                        if let Some(b) = j.pending_since.take() {
                            spans.push(Span {
                                pid: job_pid,
                                tid: job,
                                name: "pending",
                                begin: b,
                                end: time,
                                args: [None, None],
                            });
                        }
                        marks.push(Mark {
                            pid: job_pid,
                            tid: job,
                            name: "cancelled",
                            t: time,
                            args: [None, None],
                        });
                    }
                    RmsEvent::Stolen { job, time } => {
                        let Some(j) = kept_job(&mut jobs, &keep, s, job) else { continue };
                        if let Some(b) = j.pending_since.take() {
                            spans.push(Span {
                                pid: job_pid,
                                tid: job,
                                name: "pending",
                                begin: b,
                                end: time,
                                args: [None, None],
                            });
                        }
                        marks.push(Mark {
                            pid: job_pid,
                            tid: job,
                            name: "stolen",
                            t: time,
                            args: [None, None],
                        });
                    }
                    RmsEvent::ResizeBegin { job, time, from, to } => {
                        let Some(j) = kept_job(&mut jobs, &keep, s, job) else { continue };
                        j.resize_since = Some(time);
                        j.resize_from = from;
                        j.resize_to = to;
                    }
                    RmsEvent::ResizeCommit { job, time, .. } => {
                        let Some(j) = kept_job(&mut jobs, &keep, s, job) else { continue };
                        close_resize(&mut spans, job_pid, job, j, time);
                    }
                    RmsEvent::ResizeAbort { job, time, phase } => {
                        let Some(j) = kept_job(&mut jobs, &keep, s, job) else { continue };
                        close_resize(&mut spans, job_pid, job, j, time);
                        marks.push(Mark {
                            pid: job_pid,
                            tid: job,
                            name: "resize-aborted",
                            t: time,
                            args: [Some(("phase", phase as f64)), None],
                        });
                    }
                    RmsEvent::Expanded { job, time, from, to } => {
                        if keep.contains(&(s, job)) {
                            marks.push(Mark {
                                pid: job_pid,
                                tid: job,
                                name: "expanded",
                                t: time,
                                args: [
                                    Some(("from", from as f64)),
                                    Some(("to", to as f64)),
                                ],
                            });
                        }
                    }
                    RmsEvent::Shrunk { job, time, from, to } => {
                        if keep.contains(&(s, job)) {
                            marks.push(Mark {
                                pid: job_pid,
                                tid: job,
                                name: "shrunk",
                                t: time,
                                args: [
                                    Some(("from", from as f64)),
                                    Some(("to", to as f64)),
                                ],
                            });
                        }
                    }
                    RmsEvent::ExpandAborted { job, time } => {
                        if keep.contains(&(s, job)) {
                            marks.push(Mark {
                                pid: job_pid,
                                tid: job,
                                name: "expand-aborted",
                                t: time,
                                args: [None, None],
                            });
                        }
                    }
                    RmsEvent::Interrupted { job, time, node } => {
                        if keep.contains(&(s, job)) {
                            marks.push(Mark {
                                pid: job_pid,
                                tid: job,
                                name: "interrupted",
                                t: time,
                                args: [Some(("node", node as f64)), None],
                            });
                        }
                    }
                    RmsEvent::Rescued { job, time, from, to } => {
                        if keep.contains(&(s, job)) {
                            marks.push(Mark {
                                pid: job_pid,
                                tid: job,
                                name: "rescued",
                                t: time,
                                args: [
                                    Some(("from", from as f64)),
                                    Some(("to", to as f64)),
                                ],
                            });
                        }
                    }
                    RmsEvent::Degraded { job, time } => {
                        if keep.contains(&(s, job)) {
                            marks.push(Mark {
                                pid: job_pid,
                                tid: job,
                                name: "degraded",
                                t: time,
                                args: [None, None],
                            });
                        }
                    }
                    RmsEvent::DmrDecision { .. } => {
                        // High-volume and already summarized by the
                        // commit/abort events; skipped to keep traces
                        // proportional to actions, not checks.
                    }
                    RmsEvent::NodeFailed { node, time } => {
                        let e = fail_depth.entry(node).or_insert((0, time));
                        if e.0 == 0 {
                            e.1 = time;
                        }
                        e.0 += 1;
                    }
                    RmsEvent::NodeRepaired { node, time } => {
                        if let Some(e) = fail_depth.get_mut(&node) {
                            if e.0 > 0 {
                                e.0 -= 1;
                                if e.0 == 0 {
                                    spans.push(Span {
                                        pid: machine_pid,
                                        tid: node as u64,
                                        name: "down",
                                        begin: e.1,
                                        end: time,
                                        args: [None, None],
                                    });
                                }
                            }
                        }
                    }
                    RmsEvent::DrainStarted { node, time } => {
                        let e = drain_depth.entry(node).or_insert((0, time));
                        if e.0 == 0 {
                            e.1 = time;
                        }
                        e.0 += 1;
                    }
                    RmsEvent::DrainEnded { node, time } => {
                        if let Some(e) = drain_depth.get_mut(&node) {
                            if e.0 > 0 {
                                e.0 -= 1;
                                if e.0 == 0 {
                                    spans.push(Span {
                                        pid: machine_pid,
                                        tid: node as u64,
                                        name: "drain",
                                        begin: e.1,
                                        end: time,
                                        args: [None, None],
                                    });
                                }
                            }
                        }
                    }
                }
            }
            // Close whatever the drained run left open at its makespan
            // (nodes still down, drain windows outliving the last job).
            for (&node, &(depth, b)) in &fail_depth {
                if depth > 0 {
                    spans.push(Span {
                        pid: machine_pid,
                        tid: node as u64,
                        name: "down",
                        begin: b,
                        end: end.max(b),
                        args: [None, None],
                    });
                }
            }
            for (&node, &(depth, b)) in &drain_depth {
                if depth > 0 {
                    spans.push(Span {
                        pid: machine_pid,
                        tid: node as u64,
                        name: "drain",
                        begin: b,
                        end: end.max(b),
                        args: [None, None],
                    });
                }
            }
            for (&job, st) in &jobs {
                if let Some(b) = st.pending_since {
                    spans.push(Span {
                        pid: job_pid,
                        tid: job,
                        name: "pending",
                        begin: b,
                        end: end.max(b),
                        args: [None, None],
                    });
                }
                if let Some(b) = st.running_since {
                    spans.push(Span {
                        pid: job_pid,
                        tid: job,
                        name: "running",
                        begin: b,
                        end: end.max(b),
                        args: [None, None],
                    });
                }
            }
        }

        let stats = TraceStats {
            spans: spans.len(),
            job_spans,
            instants: marks.len(),
            job_tracks_total: total,
            job_tracks_kept: keep.len(),
        };
        Trace { spans, marks, shards: logs.len(), stats }
    }

    /// Build from a flat run (one shard).
    pub fn from_run(r: &crate::des::RunResult, cfg: &TraceConfig) -> Trace {
        Trace::from_logs(&[&r.rms.log], r.makespan, cfg)
    }

    /// Build from a federated run (one track pair per shard).
    pub fn from_fed(r: &crate::federation::FedRunResult, cfg: &TraceConfig) -> Trace {
        let logs: Vec<&EventLog> = r.shards.iter().map(|sh| &sh.rms.log).collect();
        Trace::from_logs(&logs, r.makespan, cfg)
    }

    /// Summary counts of this trace.
    pub fn stats(&self) -> TraceStats {
        self.stats
    }

    /// Stream the trace as Chrome-trace JSON (open with Perfetto:
    /// <https://ui.perfetto.dev>, or `chrome://tracing`).  Span `B`/`E`
    /// events are emitted per track in stack order, so every begin has a
    /// matching, correctly-nested end.  Timestamps are simulated seconds
    /// rendered as microseconds (the format's native unit).
    pub fn write_chrome<W: Write>(&self, w: &mut W) -> io::Result<()> {
        write!(w, "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[")?;
        let mut first = true;
        // Process-name metadata: one entry per shard track pair.
        for s in 0..self.shards {
            for (pid, kind) in [(2 * s + 1, "jobs"), (2 * s + 2, "machine")] {
                sep(w, &mut first)?;
                write!(
                    w,
                    "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\"name\":\"process_name\",\
                     \"args\":{{\"name\":\"shard{s} {kind}\"}}}}"
                )?;
            }
        }
        // Group spans per (pid, tid) and emit each track in nesting order.
        let mut order: Vec<usize> = (0..self.spans.len()).collect();
        order.sort_by(|&a, &b| {
            let (x, y) = (&self.spans[a], &self.spans[b]);
            (x.pid, x.tid)
                .cmp(&(y.pid, y.tid))
                .then(x.begin.total_cmp(&y.begin))
                .then(y.end.total_cmp(&x.end))
        });
        let mut stack: Vec<usize> = Vec::new();
        let mut track: Option<(u32, u64)> = None;
        for &i in &order {
            let sp = &self.spans[i];
            if track != Some((sp.pid, sp.tid)) {
                while let Some(j) = stack.pop() {
                    self.emit_end(w, &mut first, &self.spans[j])?;
                }
                track = Some((sp.pid, sp.tid));
            }
            while let Some(&j) = stack.last() {
                if self.spans[j].end <= sp.begin {
                    stack.pop();
                    self.emit_end(w, &mut first, &self.spans[j])?;
                } else {
                    break;
                }
            }
            sep(w, &mut first)?;
            write!(
                w,
                "{{\"ph\":\"B\",\"pid\":{},\"tid\":{},\"ts\":{},\"name\":\"{}\"",
                sp.pid,
                sp.tid,
                us(sp.begin),
                sp.name
            )?;
            write_args(w, &sp.args)?;
            write!(w, "}}")?;
            stack.push(i);
        }
        while let Some(j) = stack.pop() {
            self.emit_end(w, &mut first, &self.spans[j])?;
        }
        for m in &self.marks {
            sep(w, &mut first)?;
            write!(
                w,
                "{{\"ph\":\"i\",\"s\":\"t\",\"pid\":{},\"tid\":{},\"ts\":{},\"name\":\"{}\"",
                m.pid,
                m.tid,
                us(m.t),
                m.name
            )?;
            write_args(w, &m.args)?;
            write!(w, "}}")?;
        }
        writeln!(w, "]}}")
    }

    fn emit_end<W: Write>(&self, w: &mut W, first: &mut bool, sp: &Span) -> io::Result<()> {
        sep(w, first)?;
        write!(
            w,
            "{{\"ph\":\"E\",\"pid\":{},\"tid\":{},\"ts\":{},\"name\":\"{}\"}}",
            sp.pid,
            sp.tid,
            us(sp.end),
            sp.name
        )
    }

    /// Stream the compact JSONL form: one object per span / instant,
    /// times in simulated seconds.
    pub fn write_jsonl<W: Write>(&self, w: &mut W) -> io::Result<()> {
        for sp in &self.spans {
            write!(
                w,
                "{{\"type\":\"span\",\"pid\":{},\"tid\":{},\"name\":\"{}\",\"t0\":{},\"t1\":{}",
                sp.pid,
                sp.tid,
                sp.name,
                num(sp.begin),
                num(sp.end)
            )?;
            for a in sp.args.iter().flatten() {
                write!(w, ",\"{}\":{}", a.0, num(a.1))?;
            }
            writeln!(w, "}}")?;
        }
        for m in &self.marks {
            write!(
                w,
                "{{\"type\":\"instant\",\"pid\":{},\"tid\":{},\"name\":\"{}\",\"t\":{}",
                m.pid,
                m.tid,
                m.name,
                num(m.t)
            )?;
            for a in m.args.iter().flatten() {
                write!(w, ",\"{}\":{}", a.0, num(a.1))?;
            }
            writeln!(w, "}}")?;
        }
        Ok(())
    }

    /// Write both exports under `dir` (created if missing) as
    /// `<label>.trace.json` and `<label>.spans.jsonl`; returns the two
    /// paths.
    pub fn write_files(&self, dir: &Path, label: &str) -> io::Result<(PathBuf, PathBuf)> {
        std::fs::create_dir_all(dir)?;
        let chrome = dir.join(format!("{label}.trace.json"));
        let jsonl = dir.join(format!("{label}.spans.jsonl"));
        let mut w = io::BufWriter::new(std::fs::File::create(&chrome)?);
        self.write_chrome(&mut w)?;
        w.flush()?;
        let mut w = io::BufWriter::new(std::fs::File::create(&jsonl)?);
        self.write_jsonl(&mut w)?;
        w.flush()?;
        Ok((chrome, jsonl))
    }
}

/// The job id an event belongs to (`None` for machine events).
fn job_of(ev: &RmsEvent) -> Option<u64> {
    match *ev {
        RmsEvent::Submitted { job, .. }
        | RmsEvent::Started { job, .. }
        | RmsEvent::Finished { job, .. }
        | RmsEvent::Cancelled { job, .. }
        | RmsEvent::DmrDecision { job, .. }
        | RmsEvent::Expanded { job, .. }
        | RmsEvent::Shrunk { job, .. }
        | RmsEvent::ExpandAborted { job, .. }
        | RmsEvent::Interrupted { job, .. }
        | RmsEvent::Requeued { job, .. }
        | RmsEvent::Rescued { job, .. }
        | RmsEvent::Stolen { job, .. }
        | RmsEvent::ResizeBegin { job, .. }
        | RmsEvent::ResizeAbort { job, .. }
        | RmsEvent::ResizeCommit { job, .. }
        | RmsEvent::Degraded { job, .. } => Some(job),
        RmsEvent::NodeFailed { .. }
        | RmsEvent::NodeRepaired { .. }
        | RmsEvent::DrainStarted { .. }
        | RmsEvent::DrainEnded { .. } => None,
    }
}

/// Mutable state of a kept job (`None` when the track was filtered out).
fn kept_job<'a>(
    jobs: &'a mut HashMap<u64, JobState>,
    keep: &HashSet<(usize, u64)>,
    shard: usize,
    job: u64,
) -> Option<&'a mut JobState> {
    if keep.contains(&(shard, job)) {
        Some(jobs.entry(job).or_default())
    } else {
        None
    }
}

/// Close an open resize sub-span at `time`, if any.
fn close_resize(spans: &mut Vec<Span>, pid: u32, job: u64, j: &mut JobState, time: Time) {
    if let Some(b) = j.resize_since.take() {
        spans.push(Span {
            pid,
            tid: job,
            name: "resize",
            begin: b,
            end: time,
            args: [
                Some(("from", j.resize_from as f64)),
                Some(("to", j.resize_to as f64)),
            ],
        });
    }
}

/// Comma separator management for the streamed JSON array.
fn sep<W: Write>(w: &mut W, first: &mut bool) -> io::Result<()> {
    if *first {
        *first = false;
        Ok(())
    } else {
        write!(w, ",")
    }
}

/// Simulated seconds → Chrome-trace microseconds.
fn us(t: Time) -> String {
    num(t * 1e6)
}

/// Strict-JSON number rendering (no `inf`/`nan`; integral values print
/// without a fraction).
fn num(x: f64) -> String {
    if !x.is_finite() {
        "0".to_string()
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_log() -> EventLog {
        let mut log = EventLog::default();
        log.push(RmsEvent::Submitted { job: 1, time: 0.0 });
        log.push(RmsEvent::Submitted { job: 2, time: 1.0 });
        log.push(RmsEvent::Started { job: 1, time: 2.0, procs: 4 });
        log.push(RmsEvent::ResizeBegin { job: 1, time: 3.0, from: 4, to: 8 });
        log.push(RmsEvent::ResizeCommit { job: 1, time: 4.0, procs: 8 });
        log.push(RmsEvent::Expanded { job: 1, time: 4.0, from: 4, to: 8 });
        log.push(RmsEvent::Started { job: 2, time: 5.0, procs: 2 });
        log.push(RmsEvent::NodeFailed { node: 3, time: 6.0 });
        log.push(RmsEvent::Interrupted { job: 2, time: 6.0, node: 3 });
        log.push(RmsEvent::Requeued { job: 2, time: 6.0 });
        log.push(RmsEvent::NodeRepaired { node: 3, time: 7.0 });
        log.push(RmsEvent::Started { job: 2, time: 8.0, procs: 2 });
        log.push(RmsEvent::Finished { job: 1, time: 9.0 });
        log.push(RmsEvent::Finished { job: 2, time: 10.0 });
        log
    }

    #[test]
    fn spans_derive_from_event_log() {
        let log = demo_log();
        let tr = Trace::from_logs(&[&log], 10.0, &TraceConfig::on());
        let st = tr.stats();
        // running spans: job1 (started→finished), job2 (started→requeued,
        // started→finished) = completed(2) + requeued(1).
        assert_eq!(st.job_spans, 3);
        assert_eq!(st.job_tracks_total, 2);
        assert_eq!(st.job_tracks_kept, 2);
        let down = tr.spans.iter().filter(|s| s.name == "down").count();
        assert_eq!(down, 1);
        let resize = tr.spans.iter().filter(|s| s.name == "resize").count();
        assert_eq!(resize, 1);
        let pending = tr.spans.iter().filter(|s| s.name == "pending").count();
        assert_eq!(pending, 3, "one initial wait per job + one requeue wait");
    }

    #[test]
    fn stride_and_cap_bound_job_tracks() {
        let log = demo_log();
        let strided =
            Trace::from_logs(&[&log], 10.0, &TraceConfig { enabled: true, stride: 2, cap: 0 });
        assert_eq!(strided.stats().job_tracks_kept, 1, "every 2nd of 2 jobs");
        let capped =
            Trace::from_logs(&[&log], 10.0, &TraceConfig { enabled: true, stride: 1, cap: 1 });
        assert_eq!(capped.stats().job_tracks_kept, 1);
        assert_eq!(capped.stats().job_tracks_total, 2);
        // Machine spans are never filtered.
        assert!(capped.spans.iter().any(|s| s.name == "down"));
    }

    #[test]
    fn chrome_export_is_valid_json_with_paired_spans() {
        let log = demo_log();
        let tr = Trace::from_logs(&[&log], 10.0, &TraceConfig::on());
        let mut buf = Vec::new();
        tr.write_chrome(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let doc = crate::util::json::Json::parse(&text).expect("chrome trace parses");
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents");
        // Per-track B/E stack discipline.
        let mut stacks: HashMap<(i64, i64), Vec<String>> = HashMap::new();
        let mut begins = 0;
        let mut ends = 0;
        for ev in events {
            let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap();
            let key = (
                ev.get("pid").and_then(|p| p.as_f64()).unwrap() as i64,
                ev.get("tid").and_then(|p| p.as_f64()).unwrap() as i64,
            );
            let name = ev.get("name").and_then(|n| n.as_str()).unwrap().to_string();
            match ph {
                "B" => {
                    begins += 1;
                    stacks.entry(key).or_default().push(name);
                }
                "E" => {
                    ends += 1;
                    let top = stacks.get_mut(&key).and_then(|s| s.pop());
                    assert_eq!(top.as_deref(), Some(name.as_str()), "mismatched E");
                }
                _ => {}
            }
        }
        assert_eq!(begins, ends, "every B has an E");
        assert!(stacks.values().all(|s| s.is_empty()), "no dangling spans");
        assert_eq!(begins, tr.stats().spans);
    }

    #[test]
    fn jsonl_lines_each_parse() {
        let log = demo_log();
        let tr = Trace::from_logs(&[&log], 10.0, &TraceConfig::on());
        let mut buf = Vec::new();
        tr.write_jsonl(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let mut lines = 0;
        for line in text.lines() {
            crate::util::json::Json::parse(line).expect("jsonl line parses");
            lines += 1;
        }
        assert_eq!(lines, tr.stats().spans + tr.stats().instants);
    }

    #[test]
    fn unrepaired_outage_closes_at_end() {
        let mut log = EventLog::default();
        log.push(RmsEvent::NodeFailed { node: 0, time: 5.0 });
        let tr = Trace::from_logs(&[&log], 42.0, &TraceConfig::on());
        let down: Vec<_> = tr.spans.iter().filter(|s| s.name == "down").collect();
        assert_eq!(down.len(), 1);
        assert_eq!(down[0].end, 42.0);
    }
}
