//! Observability: span tracing, engine self-profiling, env-filtered
//! diagnostics.
//!
//! Three small, independent pieces with one shared contract — **nothing
//! here may perturb the simulation**:
//!
//! * [`trace`] — per-job lifecycle and per-shard machine-fault spans
//!   derived *post-run* from the digest-locked event log, exported as
//!   Chrome-trace/Perfetto JSON + compact JSONL (`repro trace`,
//!   `repro campaign --trace`).  Off by default; stride/cap knobs bound
//!   memory; the writers stream.
//! * [`profile`] — fixed-array wall-clock counters around the DES hot
//!   path (event dispatch, schedule pass, DMR pass).  No RNG, no heap,
//!   no branching on simulation state; values flow only through
//!   non-deterministic channels (stdout table, `BENCH_*.json`).
//! * [`log`] — `DMR_LOG=off|warn|info|debug` stderr diagnostics
//!   replacing ad-hoc `eprintln!` warnings.
//!
//! The inertness contract is locked by the trace-on/off digest +
//! makespan-bits matrix in `rust/tests/test_obs.rs` and documented in
//! `docs/ARCHITECTURE.md` ("Observability").

pub mod log;
pub mod profile;
pub mod trace;

pub use profile::{Phase, PhaseProfile};
pub use trace::{Trace, TraceConfig, TraceStats};
