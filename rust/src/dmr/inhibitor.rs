//! The "checking inhibitor" (§5.1): a timeout during which DMR API calls
//! are ignored, so iterative applications with short iterations do not
//! hammer the RMS.  Tunable via the `DMR_INHIBIT_PERIOD` environment
//! variable, like the paper's knob.

use crate::Time;

#[derive(Debug, Clone)]
pub struct Inhibitor {
    period: f64,
    last: Option<Time>,
}

impl Inhibitor {
    pub fn new(period: f64) -> Self {
        Inhibitor { period, last: None }
    }

    /// Period from the environment override, falling back to `default`.
    pub fn from_env(default: f64) -> Self {
        let period = std::env::var("DMR_INHIBIT_PERIOD")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(default);
        Self::new(period)
    }

    pub fn period(&self) -> f64 {
        self.period
    }

    /// Whether a DMR call at `now` may go through; if so, the inhibition
    /// window restarts.
    pub fn allow(&mut self, now: Time) -> bool {
        match self.last {
            Some(t) if now - t < self.period => false,
            _ => {
                self.last = Some(now);
                true
            }
        }
    }

    /// Next time a call will be allowed.
    pub fn next_allowed(&self, now: Time) -> Time {
        match self.last {
            Some(t) if now - t < self.period => t + self.period,
            _ => now,
        }
    }

    /// Carry the window across a reconfiguration (the new process set
    /// resumes with the parent's inhibition state).
    pub fn restore(period: f64, last: Option<Time>) -> Self {
        Inhibitor { period, last }
    }

    pub fn last(&self) -> Option<Time> {
        self.last
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_call_allowed() {
        let mut i = Inhibitor::new(15.0);
        assert!(i.allow(0.0));
        assert!(!i.allow(5.0));
        assert!(!i.allow(14.9));
        assert!(i.allow(15.0));
    }

    #[test]
    fn zero_period_always_allows() {
        let mut i = Inhibitor::new(0.0);
        assert!(i.allow(0.0));
        assert!(i.allow(0.0));
    }

    #[test]
    fn next_allowed() {
        let mut i = Inhibitor::new(10.0);
        assert_eq!(i.next_allowed(3.0), 3.0);
        i.allow(3.0);
        assert_eq!(i.next_allowed(5.0), 13.0);
        assert_eq!(i.next_allowed(20.0), 20.0);
    }

    #[test]
    fn restore_carries_window() {
        let mut a = Inhibitor::new(10.0);
        a.allow(7.0);
        let mut b = Inhibitor::restore(10.0, a.last());
        assert!(!b.allow(12.0));
        assert!(b.allow(17.0));
    }
}
