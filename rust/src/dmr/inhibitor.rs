//! The "checking inhibitor" (§5.1): a timeout during which DMR API calls
//! are ignored, so iterative applications with short iterations do not
//! hammer the RMS.  Tunable via the `DMR_INHIBIT_PERIOD` environment
//! variable, like the paper's knob.

use crate::Time;

#[derive(Debug, Clone)]
pub struct Inhibitor {
    period: f64,
    last: Option<Time>,
}

impl Inhibitor {
    pub fn new(period: f64) -> Self {
        Inhibitor { period, last: None }
    }

    /// Period from the environment override, falling back to `default`.
    /// An unusable `DMR_INHIBIT_PERIOD` (non-numeric, empty, negative or
    /// non-finite) falls back too, but says so once per process through
    /// [`crate::obs::log`] (so `DMR_LOG=off` silences it) instead of
    /// silently ignoring the knob the user tried to turn.
    pub fn from_env(default: f64) -> Self {
        let period = match std::env::var("DMR_INHIBIT_PERIOD") {
            Err(_) => default,
            Ok(raw) => match parse_period(&raw) {
                Ok(p) => p,
                Err(why) => {
                    static WARN_ONCE: std::sync::Once = std::sync::Once::new();
                    WARN_ONCE.call_once(|| {
                        crate::obs::log::warn(&format!(
                            "ignoring DMR_INHIBIT_PERIOD={raw:?} ({why}); \
                             using default {default}s"
                        ));
                    });
                    default
                }
            },
        };
        Self::new(period)
    }

    pub fn period(&self) -> f64 {
        self.period
    }

    /// Whether a DMR call at `now` may go through; if so, the inhibition
    /// window restarts.
    pub fn allow(&mut self, now: Time) -> bool {
        match self.last {
            Some(t) if now - t < self.period => false,
            _ => {
                self.last = Some(now);
                true
            }
        }
    }

    /// Next time a call will be allowed.
    pub fn next_allowed(&self, now: Time) -> Time {
        match self.last {
            Some(t) if now - t < self.period => t + self.period,
            _ => now,
        }
    }

    /// Carry the window across a reconfiguration (the new process set
    /// resumes with the parent's inhibition state).
    pub fn restore(period: f64, last: Option<Time>) -> Self {
        Inhibitor { period, last }
    }

    pub fn last(&self) -> Option<Time> {
        self.last
    }
}

/// Validate a `DMR_INHIBIT_PERIOD` value: a finite, non-negative number
/// of seconds.  Split from [`Inhibitor::from_env`] so the rejection rules
/// are unit-testable without touching process environment.
pub fn parse_period(raw: &str) -> Result<f64, &'static str> {
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return Err("empty value");
    }
    let p: f64 = trimmed.parse().map_err(|_| "not a number")?;
    if !p.is_finite() {
        return Err("not finite");
    }
    if p < 0.0 {
        return Err("negative period");
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_call_allowed() {
        let mut i = Inhibitor::new(15.0);
        assert!(i.allow(0.0));
        assert!(!i.allow(5.0));
        assert!(!i.allow(14.9));
        assert!(i.allow(15.0));
    }

    #[test]
    fn zero_period_always_allows() {
        let mut i = Inhibitor::new(0.0);
        assert!(i.allow(0.0));
        assert!(i.allow(0.0));
    }

    #[test]
    fn next_allowed() {
        let mut i = Inhibitor::new(10.0);
        assert_eq!(i.next_allowed(3.0), 3.0);
        i.allow(3.0);
        assert_eq!(i.next_allowed(5.0), 13.0);
        assert_eq!(i.next_allowed(20.0), 20.0);
    }

    #[test]
    fn period_env_values_validated() {
        assert_eq!(parse_period("15"), Ok(15.0));
        assert_eq!(parse_period("0.5"), Ok(0.5));
        assert_eq!(parse_period("  30.0 "), Ok(30.0), "surrounding whitespace tolerated");
        assert_eq!(parse_period("0"), Ok(0.0), "zero disables inhibition");
        assert_eq!(parse_period(""), Err("empty value"));
        assert_eq!(parse_period("   "), Err("empty value"));
        assert_eq!(parse_period("fast"), Err("not a number"));
        assert_eq!(parse_period("15s"), Err("not a number"));
        assert_eq!(parse_period("-3"), Err("negative period"));
        assert_eq!(parse_period("NaN"), Err("not finite"));
        assert_eq!(parse_period("inf"), Err("not finite"));
    }

    #[test]
    fn restore_carries_window() {
        let mut a = Inhibitor::new(10.0);
        a.allow(7.0);
        let mut b = Inhibitor::restore(10.0, a.last());
        assert!(!b.allow(12.0));
        assert!(b.allow(17.0));
    }
}
