//! Wire formats for the runtime's internal messages: the resize decision
//! broadcast and the state-transfer message that carries a shard (plus the
//! execution cursor) from the old process set to the new one.

use crate::vmpi::bytes_to_f32s;
#[cfg(not(target_endian = "little"))]
use crate::vmpi::f32s_to_bytes;

/// Why a received frame could not be decoded.  Malformed frames can reach
/// a decoder through any transport bug or version skew, so decoding is
/// fallible instead of indexing straight into the buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeError {
    /// The buffer ended before a fixed-size field: `need` bytes were
    /// required, only `got` were present.
    Truncated {
        /// Bytes the frame needed up to and including the missing field.
        need: usize,
        /// Bytes actually received.
        got: usize,
    },
    /// The leading tag byte named no known message kind.
    UnknownTag(u8),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated { need, got } => {
                write!(f, "truncated frame: need {need} bytes, got {got}")
            }
            DecodeError::UnknownTag(t) => write!(f, "unknown frame tag {t}"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Read a fixed-size little-endian field at `b[at..at + N]`.
fn field<const N: usize>(b: &[u8], at: usize) -> Result<[u8; N], DecodeError> {
    match b.get(at..at + N) {
        Some(s) => Ok(s.try_into().expect("slice length matches N")),
        None => Err(DecodeError::Truncated { need: at + N, got: b.len() }),
    }
}

/// The decision rank 0 broadcasts at each reconfiguring point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    Continue,
    /// Resize to `to` processes in group `new_group`; expand if
    /// `to > current`.
    Resize { to: u32, new_group: u64 },
    /// The whole job is done (drain and exit) — used on the last
    /// iteration.
    Stop,
}

impl Decision {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Decision::Continue => vec![0],
            Decision::Resize { to, new_group } => {
                let mut b = vec![1];
                b.extend_from_slice(&to.to_le_bytes());
                b.extend_from_slice(&new_group.to_le_bytes());
                b
            }
            Decision::Stop => vec![2],
        }
    }

    /// Decode a received frame.  Empty buffers, truncated `Resize`
    /// payloads and unknown tag bytes are reported, not panicked on.
    pub fn decode(b: &[u8]) -> Result<Decision, DecodeError> {
        match *b.first().ok_or(DecodeError::Truncated { need: 1, got: 0 })? {
            0 => Ok(Decision::Continue),
            1 => {
                let to = u32::from_le_bytes(field::<4>(b, 1)?);
                let new_group = u64::from_le_bytes(field::<8>(b, 5)?);
                Ok(Decision::Resize { to, new_group })
            }
            2 => Ok(Decision::Stop),
            x => Err(DecodeError::UnknownTag(x)),
        }
    }
}

/// State handed from an old rank to a new rank (or between old ranks in
/// the shrink merge): execution cursor + replicated scalars + shard rows.
#[derive(Debug, Clone, PartialEq)]
pub struct StateMsg {
    /// Next iteration to execute.
    pub iter: u32,
    /// Checking-inhibitor window start (carried across the resize).
    pub inhibit_last: f64,
    /// App-specific replicated scalars (e.g. CG's r·r).
    pub scalars: Vec<f64>,
    /// Shard rows.
    pub data: Vec<f32>,
}

impl StateMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(16 + self.scalars.len() * 8 + self.data.len() * 4);
        b.extend_from_slice(&self.iter.to_le_bytes());
        b.extend_from_slice(&self.inhibit_last.to_le_bytes());
        b.extend_from_slice(&(self.scalars.len() as u32).to_le_bytes());
        for s in &self.scalars {
            b.extend_from_slice(&s.to_le_bytes());
        }
        // Append the payload in one memcpy (a temp f32s_to_bytes Vec here
        // doubled the copies on the resize hot path — EXPERIMENTS.md §Perf).
        #[cfg(target_endian = "little")]
        unsafe {
            b.extend_from_slice(std::slice::from_raw_parts(
                self.data.as_ptr().cast::<u8>(),
                self.data.len() * 4,
            ));
        }
        #[cfg(not(target_endian = "little"))]
        b.extend_from_slice(&f32s_to_bytes(&self.data));
        b
    }

    /// Decode a received frame; truncated headers or scalar sections are
    /// reported, not panicked on.
    pub fn decode(b: &[u8]) -> Result<StateMsg, DecodeError> {
        let iter = u32::from_le_bytes(field::<4>(b, 0)?);
        let inhibit_last = f64::from_le_bytes(field::<8>(b, 4)?);
        let ns = u32::from_le_bytes(field::<4>(b, 12)?) as usize;
        // Cap the pre-allocation by what the buffer could actually hold —
        // a hostile/corrupt count must not drive a huge reservation.
        let mut scalars = Vec::with_capacity(ns.min(b.len() / 8));
        let mut off = 16;
        for _ in 0..ns {
            scalars.push(f64::from_le_bytes(field::<8>(b, off)?));
            off += 8;
        }
        let data = bytes_to_f32s(&b[off..]);
        Ok(StateMsg { iter, inhibit_last, scalars, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_roundtrip() {
        for d in [
            Decision::Continue,
            Decision::Resize { to: 8, new_group: 12345678901234 },
            Decision::Stop,
        ] {
            assert_eq!(Decision::decode(&d.encode()), Ok(d));
        }
    }

    #[test]
    fn malformed_decision_frames_are_errors() {
        assert_eq!(Decision::decode(&[]), Err(DecodeError::Truncated { need: 1, got: 0 }));
        // Resize tag with a truncated `to` field ...
        assert_eq!(
            Decision::decode(&[1, 8, 0]),
            Err(DecodeError::Truncated { need: 5, got: 3 })
        );
        // ... and with `to` intact but `new_group` cut short.
        let mut b = Decision::Resize { to: 8, new_group: 42 }.encode();
        b.truncate(9);
        assert_eq!(Decision::decode(&b), Err(DecodeError::Truncated { need: 13, got: 9 }));
        assert_eq!(Decision::decode(&[7]), Err(DecodeError::UnknownTag(7)));
        // error text is usable in logs
        let e = Decision::decode(&[]).unwrap_err();
        assert!(e.to_string().contains("truncated"), "{e}");
    }

    #[test]
    fn state_roundtrip() {
        let m = StateMsg {
            iter: 17,
            inhibit_last: 3.25,
            scalars: vec![1.5, -2.5e10],
            data: vec![1.0, 2.0, 3.0],
        };
        assert_eq!(StateMsg::decode(&m.encode()), Ok(m));
    }

    #[test]
    fn malformed_state_frames_are_errors() {
        assert_eq!(StateMsg::decode(&[]), Err(DecodeError::Truncated { need: 4, got: 0 }));
        let m = StateMsg {
            iter: 3,
            inhibit_last: 1.0,
            scalars: vec![2.0, 4.0],
            data: vec![1.0],
        };
        let full = m.encode();
        // header cut mid-field
        assert_eq!(
            StateMsg::decode(&full[..10]),
            Err(DecodeError::Truncated { need: 12, got: 10 })
        );
        // scalar section shorter than its declared count
        assert_eq!(
            StateMsg::decode(&full[..20]),
            Err(DecodeError::Truncated { need: 24, got: 20 })
        );
        // a corrupt scalar count must error out, not panic or reserve
        let mut bad = full.clone();
        bad[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(StateMsg::decode(&bad), Err(DecodeError::Truncated { .. })));
    }

    #[test]
    fn state_empty_sections() {
        let m = StateMsg { iter: 0, inhibit_last: 0.0, scalars: vec![], data: vec![] };
        assert_eq!(StateMsg::decode(&m.encode()), Ok(m));
    }
}
