//! Wire formats for the runtime's internal messages: the resize decision
//! broadcast and the state-transfer message that carries a shard (plus the
//! execution cursor) from the old process set to the new one.

use crate::vmpi::bytes_to_f32s;
#[cfg(not(target_endian = "little"))]
use crate::vmpi::f32s_to_bytes;

/// The decision rank 0 broadcasts at each reconfiguring point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    Continue,
    /// Resize to `to` processes in group `new_group`; expand if
    /// `to > current`.
    Resize { to: u32, new_group: u64 },
    /// The whole job is done (drain and exit) — used on the last
    /// iteration.
    Stop,
}

impl Decision {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Decision::Continue => vec![0],
            Decision::Resize { to, new_group } => {
                let mut b = vec![1];
                b.extend_from_slice(&to.to_le_bytes());
                b.extend_from_slice(&new_group.to_le_bytes());
                b
            }
            Decision::Stop => vec![2],
        }
    }

    pub fn decode(b: &[u8]) -> Decision {
        match b[0] {
            0 => Decision::Continue,
            1 => {
                let to = u32::from_le_bytes(b[1..5].try_into().unwrap());
                let new_group = u64::from_le_bytes(b[5..13].try_into().unwrap());
                Decision::Resize { to, new_group }
            }
            2 => Decision::Stop,
            x => panic!("bad decision byte {x}"),
        }
    }
}

/// State handed from an old rank to a new rank (or between old ranks in
/// the shrink merge): execution cursor + replicated scalars + shard rows.
#[derive(Debug, Clone, PartialEq)]
pub struct StateMsg {
    /// Next iteration to execute.
    pub iter: u32,
    /// Checking-inhibitor window start (carried across the resize).
    pub inhibit_last: f64,
    /// App-specific replicated scalars (e.g. CG's r·r).
    pub scalars: Vec<f64>,
    /// Shard rows.
    pub data: Vec<f32>,
}

impl StateMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(16 + self.scalars.len() * 8 + self.data.len() * 4);
        b.extend_from_slice(&self.iter.to_le_bytes());
        b.extend_from_slice(&self.inhibit_last.to_le_bytes());
        b.extend_from_slice(&(self.scalars.len() as u32).to_le_bytes());
        for s in &self.scalars {
            b.extend_from_slice(&s.to_le_bytes());
        }
        // Append the payload in one memcpy (a temp f32s_to_bytes Vec here
        // doubled the copies on the resize hot path — EXPERIMENTS.md §Perf).
        #[cfg(target_endian = "little")]
        unsafe {
            b.extend_from_slice(std::slice::from_raw_parts(
                self.data.as_ptr().cast::<u8>(),
                self.data.len() * 4,
            ));
        }
        #[cfg(not(target_endian = "little"))]
        b.extend_from_slice(&f32s_to_bytes(&self.data));
        b
    }

    pub fn decode(b: &[u8]) -> StateMsg {
        let iter = u32::from_le_bytes(b[0..4].try_into().unwrap());
        let inhibit_last = f64::from_le_bytes(b[4..12].try_into().unwrap());
        let ns = u32::from_le_bytes(b[12..16].try_into().unwrap()) as usize;
        let mut scalars = Vec::with_capacity(ns);
        let mut off = 16;
        for _ in 0..ns {
            scalars.push(f64::from_le_bytes(b[off..off + 8].try_into().unwrap()));
            off += 8;
        }
        let data = bytes_to_f32s(&b[off..]);
        StateMsg { iter, inhibit_last, scalars, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_roundtrip() {
        for d in [
            Decision::Continue,
            Decision::Resize { to: 8, new_group: 12345678901234 },
            Decision::Stop,
        ] {
            assert_eq!(Decision::decode(&d.encode()), d);
        }
    }

    #[test]
    fn state_roundtrip() {
        let m = StateMsg {
            iter: 17,
            inhibit_last: 3.25,
            scalars: vec![1.5, -2.5e10],
            data: vec![1.0, 2.0, 3.0],
        };
        assert_eq!(StateMsg::decode(&m.encode()), m);
    }

    #[test]
    fn state_empty_sections() {
        let m = StateMsg { iter: 0, inhibit_last: 0.0, scalars: vec![], data: vec![] };
        assert_eq!(StateMsg::decode(&m.encode()), m);
    }
}
