//! The DMR API runtime (§5): `dmr_check_status` semantics, the checking
//! inhibitor, and the data-redistribution patterns of §6.
//!
//! The live (threaded) execution of these mechanisms lives in
//! [`crate::live`]; the modeled (discrete-event) execution in
//! [`crate::des`].  Both share the policy/protocol implementations here
//! and in [`crate::rms`].

pub mod inhibitor;
pub mod protocol;
pub mod redistribute;

pub use inhibitor::Inhibitor;

/// Scheduling mode (§5.1): synchronous `dmr_check_status` or asynchronous
/// `dmr_icheck_status` (the decision is computed one reconfiguring point
/// ahead and applied at the next one).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    Sync,
    Async,
}

impl SchedMode {
    pub fn label(&self) -> &'static str {
        match self {
            SchedMode::Sync => "synchronous",
            SchedMode::Async => "asynchronous",
        }
    }
}
pub use protocol::{DecodeError, Decision, StateMsg};
pub use redistribute::{
    expand_dest, expand_src, merge_rows, shrink_role, split_rows, ShrinkRole,
};
