//! Data redistribution patterns of §6 (Listing 3 / Fig. 2), over
//! row-structured shards.
//!
//! Application state is serialized as *rows* of `row_f32s` consecutive
//! f32 values (CG interleaves x/r/p per element → 3; Jacobi packs u+b per
//! grid row → 2·cols; N-body packs pos+vel per body → 6).  Rows are
//! what moves between ranks, so every pattern here is
//! application-agnostic.
//!
//! * **Expand** (Fig. 2a): each of the old ranks partitions its rows into
//!   `factor` contiguous parts; part `i` goes to new rank
//!   `old_rank * factor + i`.
//! * **Shrink** (Fig. 2b, Listing 3): old ranks are grouped by `factor`;
//!   within each group all ranks but the last are *senders* that ship
//!   their rows to the group's last rank (the *receiver*), which merges
//!   rank-ordered and forwards the merged block to new rank
//!   `old_rank / factor`.

/// Role of an old rank in the shrink pattern.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShrinkRole {
    /// Send local rows to `dst` (the group's receiver).
    Sender { dst: usize },
    /// Collect from `srcs` (ascending), merge with own rows last, forward
    /// to new rank `new_dst`.
    Receiver { srcs: Vec<usize>, new_dst: usize },
}

/// Listing 3's sender/receiver assignment:
/// `sender = (rank % factor) < factor - 1`, `dst = factor*(rank/factor+1)-1`.
pub fn shrink_role(rank: usize, factor: usize) -> ShrinkRole {
    assert!(factor >= 2);
    if rank % factor < factor - 1 {
        ShrinkRole::Sender { dst: factor * (rank / factor + 1) - 1 }
    } else {
        let base = rank + 1 - factor;
        ShrinkRole::Receiver { srcs: (base..rank).collect(), new_dst: rank / factor }
    }
}

/// Partition `data` (rows of `row_f32s`) into `parts` contiguous blocks
/// (Listing 3's `part_data`).  Rows must divide evenly — the shipped
/// problem sizes guarantee it.
pub fn split_rows(data: &[f32], row_f32s: usize, parts: usize) -> Vec<Vec<f32>> {
    assert_eq!(data.len() % row_f32s, 0, "data not row-aligned");
    let rows = data.len() / row_f32s;
    assert_eq!(rows % parts, 0, "{rows} rows not divisible into {parts} parts");
    let rows_per = rows / parts;
    (0..parts)
        .map(|i| data[i * rows_per * row_f32s..(i + 1) * rows_per * row_f32s].to_vec())
        .collect()
}

/// Destination new rank for part `i` of old rank `r` during expansion.
pub fn expand_dest(old_rank: usize, factor: usize, part: usize) -> usize {
    old_rank * factor + part
}

/// Source old rank a new rank receives from during expansion.
pub fn expand_src(new_rank: usize, factor: usize) -> usize {
    new_rank / factor
}

/// Merge rank-ordered row blocks (shrink receiver side).
pub fn merge_rows(parts: Vec<Vec<f32>>) -> Vec<f32> {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total);
    for p in parts {
        out.extend_from_slice(&p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_rows_contiguous() {
        let data: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let parts = split_rows(&data, 2, 3); // 6 rows of 2, 3 parts
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0], vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(parts[2], vec![8.0, 9.0, 10.0, 11.0]);
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn split_rows_uneven_panics() {
        split_rows(&[0.0; 6], 2, 2); // 3 rows into 2 parts
    }

    #[test]
    fn shrink_roles_listing3() {
        // factor 2, 4 old ranks: 0->1 (sender), 1 recv {0}, 2->3, 3 recv {2}
        assert_eq!(shrink_role(0, 2), ShrinkRole::Sender { dst: 1 });
        assert_eq!(shrink_role(1, 2), ShrinkRole::Receiver { srcs: vec![0], new_dst: 0 });
        assert_eq!(shrink_role(2, 2), ShrinkRole::Sender { dst: 3 });
        assert_eq!(shrink_role(3, 2), ShrinkRole::Receiver { srcs: vec![2], new_dst: 1 });
        // factor 4, rank 5: group {4..7}, sender to 7
        assert_eq!(shrink_role(5, 4), ShrinkRole::Sender { dst: 7 });
        assert_eq!(
            shrink_role(7, 4),
            ShrinkRole::Receiver { srcs: vec![4, 5, 6], new_dst: 1 }
        );
    }

    #[test]
    fn expand_mapping_roundtrip() {
        for factor in [2usize, 4, 8] {
            for old in 0..4 {
                for part in 0..factor {
                    let dst = expand_dest(old, factor, part);
                    assert_eq!(expand_src(dst, factor), old);
                }
            }
        }
    }

    #[test]
    fn whole_redistribution_preserves_order_expand_then_shrink() {
        // 2 ranks -> 4 ranks -> 2 ranks roundtrip on a global array.
        let row = 3usize;
        let global: Vec<f32> = (0..24).map(|x| x as f32).collect(); // 8 rows
        let shard = |r: usize, size: usize| -> Vec<f32> {
            let rows = 8 / size;
            global[r * rows * row..(r + 1) * rows * row].to_vec()
        };
        // expand 2->4
        let mut new_shards = vec![Vec::new(); 4];
        for r in 0..2 {
            let parts = split_rows(&shard(r, 2), row, 2);
            for (i, p) in parts.into_iter().enumerate() {
                new_shards[expand_dest(r, 2, i)] = p;
            }
        }
        for (r, s) in new_shards.iter().enumerate() {
            assert_eq!(*s, shard(r, 4), "expand rank {r}");
        }
        // shrink 4->2
        let mut merged = vec![Vec::new(); 2];
        for r in 0..4 {
            if let ShrinkRole::Receiver { srcs, new_dst } = shrink_role(r, 2) {
                let mut parts: Vec<Vec<f32>> =
                    srcs.iter().map(|&s| new_shards[s].clone()).collect();
                parts.push(new_shards[r].clone());
                merged[new_dst] = merge_rows(parts);
            }
        }
        for (r, s) in merged.iter().enumerate() {
            assert_eq!(*s, shard(r, 2), "shrink rank {r}");
        }
    }
}
