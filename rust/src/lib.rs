//! # dmr — reproduction of the DMR API malleability framework
//!
//! Reproduces *"DMR API: Improving the Cluster Productivity by Turning
//! Applications into Malleable"* (Iserte, Mayo, Quintana-Ortí, Beltran,
//! Peña — Parallel Computing, 2018).
//!
//! The paper connects a resource manager (Slurm) with a parallel runtime
//! (Nanos++/OmpSs) so running MPI jobs can be *expanded* or *shrunk*
//! on-the-fly, raising global cluster throughput.  This crate rebuilds the
//! whole stack in Rust over a simulated cluster substrate:
//!
//! * [`cluster`] — the machine: nodes and the allocation map.
//! * [`workload`] — workload sources: Feitelson-model generation (§7.1),
//!   synthetic burst–lull arrivals, and real traces in Standard Workload
//!   Format ([`workload::swf`]); each available materialized or as a
//!   pull-based [`workload::JobStream`] (streaming replay, below).
//! * [`rms`] — the Slurm-like workload manager: multifactor priorities,
//!   EASY backfill, the pluggable reconfiguration-policy engine
//!   ([`rms::policy`], below) and the expand-via-resizer-job /
//!   shrink-with-ACK protocols (§5.2).
//! * [`vmpi`] — a virtual-MPI substrate: communicators, ranks, spawn,
//!   point-to-point and collectives over in-process channels.
//! * [`dmr`] — the DMR API itself: `dmr_check_status` /
//!   `dmr_icheck_status`, the checking inhibitor, and the data
//!   redistribution helpers of §6 (Listing 3 / Fig. 2).
//! * [`runtime`] — the PJRT bridge: loads the AOT-compiled HLO artifacts
//!   produced by `python/compile/aot.py` and executes them on the request
//!   path (Python never runs at job time).
//! * [`apps`] — the malleable applications of §7: CG, Jacobi, N-body and
//!   the synthetic Flexible Sleep.
//! * [`des`] — the discrete-event workload engine used to process the
//!   paper's 50–400-job workloads (fixed vs flexible) in virtual time.
//! * [`live`] — the threaded *live* driver: real rank threads, real data
//!   redistribution, real PJRT compute.
//! * [`metrics`] — recorders and report emitters for every table and
//!   figure of §7.
//! * [`campaign`] — the campaign engine (below).
//!
//! The full module map, the event/data flow of one reconfiguration and
//! the determinism contract live in `docs/ARCHITECTURE.md` at the repo
//! root — read that first when orienting.
//!
//! ## Reconfiguration-policy engine
//!
//! The paper's central mechanism — the RMS decision on a DMR trigger —
//! is a pluggable subsystem ([`rms::policy`]): a
//! [`rms::ReconfigPolicy`] trait consuming the request and a
//! [`rms::PolicyContext`] (system view + per-job/per-user facts) and
//! returning an [`rms::Action`].  Built-ins, selected via
//! [`rms::RmsConfig::strategy`] and swept by campaigns as
//! `[policy] strategy = [...]`:
//!
//! * **`ThroughputAware`** — the paper's §4 rule, preserved
//!   bit-identically (the golden determinism fixture covers it).
//! * **`QueueAware`** — the SLURM-extension flavor (Chadha et al.,
//!   arXiv:2009.08289): shrink aggressively when pending pressure
//!   crosses a threshold, expand only when the queue is drained.
//! * **`FairShare`** — per-user weighted balancing over the RMS's
//!   pending/running indices, one factor step at a time.
//! * **`DeadlineAware`** — jobs may carry soft deadlines
//!   ([`workload::JobSpec::deadline`]); jobs projected to miss are
//!   expanded and never shrunk, deadline-less jobs fall back to the
//!   baseline.
//!
//! Comparative metrics ride along ([`metrics`]): per-job bounded
//! slowdown, Jain's fairness index over per-user slowdowns, and
//! deadline-miss counts — per run, aggregated per scenario, and emitted
//! in every campaign CSV/JSON/table.  `scenarios/policy_matrix.toml` is
//! the checked-in study: all four strategies over Feitelson + SWF
//! workloads on a healthy and a faulty cluster.
//!
//! ## Campaign engine
//!
//! The paper evaluates a handful of hand-picked workloads one at a time;
//! the [`campaign`] subsystem scales that to parallel scenario sweeps: a
//! declarative TOML/JSON [`campaign::CampaignSpec`] describes a cartesian
//! matrix over workload sources (Feitelson, burst–lull, SWF real traces),
//! cluster sizes, scheduling modes (fixed/sync/async), policy knobs and
//! seeds; [`campaign::run_campaign`] shards the expanded DES runs across
//! a worker-thread pool; [`campaign::aggregate`] folds the results into
//! per-scenario statistics with 95 % confidence intervals, written as
//! CSV/JSON under `results/`.  Outputs are bit-identical for any worker
//! count.  See `scenarios/README.md` for the spec schema, and run e.g.
//! `repro campaign scenarios/sweep_small.toml --workers 8`.
//!
//! ## Sharded federation & meta-scheduling
//!
//! Real deployments front many partitions behind one scheduling brain;
//! the [`federation`] subsystem scales the paper's single flat pool to
//! that shape.  The node pool is partitioned into **shards**, each owning
//! its own [`rms::Rms`] (priorities, backfill, incremental availability
//! profile) and its own fault timeline; a meta-scheduler routes arrivals
//! via a pluggable [`federation::RoutingPolicy`] (round-robin,
//! least-loaded, user-locality), steals queued work from backlogged
//! shards into drained ones (the stolen job re-enters through the
//! thief's normal clamp/priority path with its original submission time,
//! so aging is preserved), and supports heterogeneous shards — per-shard
//! node counts, node speeds and MTBF scales.  Determinism is
//! shard-layout-reproducible: a (spec, seed, shard layout) triple yields
//! one event log, and the 1-shard layout is bit-identical to the flat
//! [`des::Engine`] — locked by `rust/tests/test_federation.rs`.
//! Campaigns sweep a `[federation]` axis (shard counts / topology ×
//! routing policies, `-sNxpolicy` scenario suffixes) and the outputs
//! carry per-shard utilization, queue depth and steal counts; see
//! `scenarios/federated_sweep.toml`.
//!
//! ## Performance model & complexity budget
//!
//! The paper's headline claim — malleability decisions cost ~10 ms
//! (Table 2) and can run continuously — only scales to real traces
//! (thousands of jobs, Chadha et al.; Zojer & Posner) if the simulated
//! RMS stays cheap too.  The hot paths therefore hold to a budget of
//! **O(pending + log active) per scheduling pass and O(log active) per
//! state transition**, never O(all jobs ever submitted) and never a
//! per-pass sort of the running set:
//!
//! * [`rms`] splits job storage into a live map and an archive, keeps
//!   O(1) counters for running/pending/completed queries, and caches the
//!   priority-ordered pending queue behind a dirty flag (membership and
//!   boost changes invalidate it; pure aging reuses it while provably
//!   order-preserving — both below the saturation horizon and once the
//!   whole queue is age-saturated, the deep-backlog regime).  Scheduling
//!   passes reuse Rms-owned scratch buffers — steady state allocates
//!   nothing.
//! * [`rms::profile`] is the **incremental availability profile**: a
//!   sorted end-time structure updated in O(log active) on every
//!   start/finish/resize/failure/requeue, so the EASY shadow-time
//!   projection is an in-order walk — `schedule()` never snapshots the
//!   running set and never sorts.  Version counters on (cluster, pending
//!   queue, profile) form a state stamp that lets provably no-op
//!   scheduling passes and repeated `NoAction` DMR checks return
//!   memoized answers in O(1) (`rms::PassStats` counts hits).  The
//!   rebuild-and-sort reference stays selectable via
//!   [`rms::RmsConfig::incremental_profile`] `= false` — force it when
//!   auditing a suspected divergence or benchmarking the win.
//! * [`des`] keeps per-job simulation state in a dense slab (no hash map
//!   on the event path), clones each `JobSpec` exactly once (for the RMS)
//!   and memoizes per-(job, procs) iteration times; every transition it
//!   drives publishes its profile delta through the `Rms` entry points.
//! * [`cluster`] answers `allocated()` from a maintained counter, so the
//!   telemetry snapshot after every start/finish is O(1).
//!
//! The budget is *measured*, not assumed: `cargo bench --bench
//! hotpath_scale` runs 1k–5k-job Feitelson and SWF workloads (sync and
//! async) on 256–4096-node clusters (quick mode by default;
//! `BENCH_FULL=1` adds the big clusters and a 20k-job / 4096-node case)
//! and writes the machine-readable `BENCH_hotpath.json` (per-scenario
//! events/s, elision counts, makespan checksums) — the repo's perf
//! trajectory point, uploaded as a CI artifact; `HOTPATH_REFERENCE=1`
//! reruns the same scenarios on the reference path and CI asserts the
//! checksum sets match.  Behavior preservation is enforced by
//! `rust/tests/test_golden_determinism.rs` (bit-identical event logs,
//! makespans and campaign aggregates between the optimized paths and
//! the rebuild-everything reference, fault-free and faulty, plus a
//! recorded fixture that locks the event stream across PRs) and by the
//! randomized differential tests in `rust/tests/test_profile.rs`.
//!
//! ## Streaming replay & bounded memory
//!
//! The complexity budget above bounds *time*; the streaming pipeline
//! bounds *space*.  [`workload::JobStream`] is a pull-based job source
//! (submit-ordered, one job per `next_job()` call) with three
//! implementations — the Feitelson/burst–lull generator streams, the
//! line-at-a-time SWF trace reader [`workload::SwfStream`], and the
//! [`workload::Materialized`] compatibility adapter — composed through
//! [`workload::Adapted`] for per-job fit/rigid/deadline transforms.
//! `des::Engine::run_stream` (and the federated
//! `federation::FedEngine::run_stream`) pull arrivals lazily behind a
//! bounded look-ahead window, reclaim per-job slab state at terminal
//! completion, and fold per-job metrics at archive time (Welford
//! streaming statistics, rolling event-log digest), so a million-job
//! replay holds memory proportional to peak *concurrency* instead of
//! total job count (`RunResult::peak_slab` measures the slab's
//! high-water mark, capped by cluster capacity; the `peak_live_jobs`
//! campaign column measures the manager's queued+running peak).
//! Streamed and materialized replays are
//! **bit-identical** — same digests, makespans and CSV bytes for any
//! window size — locked by `rust/tests/test_streaming.rs` across every
//! source × mode × fault config × federation layout; campaigns opt in
//! via the `[stream]` block (`scenarios/README.md`), and
//! `cargo bench --bench stream_scale` is the 100k–1M-job scale proof
//! (`BENCH_stream.json`: events/s + peak-resident jobs).
//!
//! ## Resilience & fault injection
//!
//! Node failures and maintenance drains are where RMS–runtime
//! collaboration pays twice: a malleable job can *shrink to survive* a
//! lost node while a rigid job must die and requeue.  The [`resilience`]
//! subsystem threads that scenario class through the stack:
//!
//! * **Fault sources** ([`resilience::model`]): seeded per-node MTBF/MTTR
//!   exponential sampling, scripted fault traces (`fail node=N at t=…,
//!   repair at t=…`) and scheduled drain windows.  All failure times come
//!   from a dedicated RNG stream, so the machine timeline is a pure
//!   function of (spec, seed) — bit-identical across reruns and identical
//!   between the rigid and malleable runs of a scenario.
//! * **Machine states** ([`cluster`]): `Down` nodes are skipped by
//!   allocation; `Draining` nodes finish their current job and then go
//!   offline; `available()`/`allocated()`/`down()` stay O(1).
//! * **Recovery** ([`resilience::recovery`] + [`rms`]): every interrupted
//!   job rolls back to its last checkpoint (configurable interval, rework
//!   accounted); malleable jobs attempt a factor-chain shrink onto their
//!   surviving nodes (paying the redistribution cost), rigid jobs — and
//!   malleable ones with no reachable fit — are killed and requeued.
//! * **Measurement**: `NodeFail`/`NodeRepair`/`DrainStart`/`DrainEnd`
//!   events are folded into [`rms::EventLog::digest`] (the golden
//!   determinism lock covers failures), and campaigns gain a `[faults]`
//!   sweep axis plus per-run lost node-seconds, interrupted/rescued/
//!   requeued counts, rework time and machine availability — see
//!   `scenarios/faulty_cluster.toml` for the malleable-vs-rigid
//!   comparison under an identical fault trace.
//!
//! ## Observability
//!
//! The [`obs`] subsystem makes runs *inspectable* without making them
//! different: [`obs::trace`] derives per-job lifecycle spans (pending /
//! running / resize-transaction) and per-shard machine-fault spans
//! **post-run** from the digest-locked event log and streams them as
//! Chrome-trace/Perfetto JSON + JSONL (`repro trace <scenario>`,
//! `repro campaign … --trace <dir>`, stride/cap knobs for bounded size);
//! [`obs::profile`] instruments the engine's hot phases (event dispatch,
//! schedule pass, DMR pass) with fixed-array wall-clock counters — no
//! RNG, no heap — reported via the campaign table and `BENCH_*.json`
//! while the worker-count-invariant CSVs carry the deterministic
//! [`rms::PassStats`] counters; [`obs::log`] gives the crate's stderr
//! diagnostics a `DMR_LOG=off|warn|info|debug` filter.  Tracing on vs
//! off is bit-identical (event-log digest + makespan) by construction
//! and by test (`rust/tests/test_obs.rs`).
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod apps;
pub mod campaign;
pub mod cluster;
pub mod des;
pub mod dmr;
pub mod federation;
pub mod live;
pub mod metrics;
pub mod obs;
pub mod resilience;
pub mod rms;
pub mod runtime;
pub mod util;
pub mod vmpi;
pub mod workload;

/// Simulation / wall-clock time in seconds (from an arbitrary epoch 0).
pub type Time = f64;

/// Job identifier assigned by the RMS at submission.
pub type JobId = u64;

/// Node identifier within the [`cluster::Cluster`].
pub type NodeId = usize;
