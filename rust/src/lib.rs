//! # dmr — reproduction of the DMR API malleability framework
//!
//! Reproduces *"DMR API: Improving the Cluster Productivity by Turning
//! Applications into Malleable"* (Iserte, Mayo, Quintana-Ortí, Beltran,
//! Peña — Parallel Computing, 2018).
//!
//! The paper connects a resource manager (Slurm) with a parallel runtime
//! (Nanos++/OmpSs) so running MPI jobs can be *expanded* or *shrunk*
//! on-the-fly, raising global cluster throughput.  This crate rebuilds the
//! whole stack in Rust over a simulated cluster substrate:
//!
//! * [`cluster`] — the machine: nodes and the allocation map.
//! * [`workload`] — workload sources: Feitelson-model generation (§7.1),
//!   synthetic burst–lull arrivals, and real traces in Standard Workload
//!   Format ([`workload::swf`]).
//! * [`rms`] — the Slurm-like workload manager: multifactor priorities,
//!   EASY backfill, and the paper's three-mode reconfiguration policy (§4)
//!   with the expand-via-resizer-job / shrink-with-ACK protocols (§5.2).
//! * [`vmpi`] — a virtual-MPI substrate: communicators, ranks, spawn,
//!   point-to-point and collectives over in-process channels.
//! * [`dmr`] — the DMR API itself: `dmr_check_status` /
//!   `dmr_icheck_status`, the checking inhibitor, and the data
//!   redistribution helpers of §6 (Listing 3 / Fig. 2).
//! * [`runtime`] — the PJRT bridge: loads the AOT-compiled HLO artifacts
//!   produced by `python/compile/aot.py` and executes them on the request
//!   path (Python never runs at job time).
//! * [`apps`] — the malleable applications of §7: CG, Jacobi, N-body and
//!   the synthetic Flexible Sleep.
//! * [`des`] — the discrete-event workload engine used to process the
//!   paper's 50–400-job workloads (fixed vs flexible) in virtual time.
//! * [`live`] — the threaded *live* driver: real rank threads, real data
//!   redistribution, real PJRT compute.
//! * [`metrics`] — recorders and report emitters for every table and
//!   figure of §7.
//! * [`campaign`] — the campaign engine (below).
//!
//! ## Campaign engine
//!
//! The paper evaluates a handful of hand-picked workloads one at a time;
//! the [`campaign`] subsystem scales that to parallel scenario sweeps: a
//! declarative TOML/JSON [`campaign::CampaignSpec`] describes a cartesian
//! matrix over workload sources (Feitelson, burst–lull, SWF real traces),
//! cluster sizes, scheduling modes (fixed/sync/async), policy knobs and
//! seeds; [`campaign::run_campaign`] shards the expanded DES runs across
//! a worker-thread pool; [`campaign::aggregate`] folds the results into
//! per-scenario statistics with 95 % confidence intervals, written as
//! CSV/JSON under `results/`.  Outputs are bit-identical for any worker
//! count.  See `scenarios/README.md` for the spec schema, and run e.g.
//! `repro campaign scenarios/sweep_small.toml --workers 8`.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod apps;
pub mod campaign;
pub mod cluster;
pub mod des;
pub mod dmr;
pub mod live;
pub mod metrics;
pub mod rms;
pub mod runtime;
pub mod util;
pub mod vmpi;
pub mod workload;

/// Simulation / wall-clock time in seconds (from an arbitrary epoch 0).
pub type Time = f64;

/// Job identifier assigned by the RMS at submission.
pub type JobId = u64;

/// Node identifier within the [`cluster::Cluster`].
pub type NodeId = usize;
