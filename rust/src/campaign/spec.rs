//! Declarative campaign specifications: a TOML (or JSON) file describing a
//! cartesian matrix of scenarios — workload sources × cluster sizes ×
//! scheduling modes × policy knobs × seeds — expanded into the flat run
//! list the [`super::runner`] shards across worker threads.
//!
//! See `scenarios/README.md` for the schema with a worked example; checked
//! in examples live under `scenarios/`.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::dmr::SchedMode;
use crate::federation::{RoutingPolicy, ShardSpec, StealPolicy};
use crate::resilience::{
    DrainSet, DrainWindow, FailureDomain, FaultKind, FaultTraceEvent, OutageEvent, OutageSpec,
    PartitionWindow, ResizeFaultSpec,
};
use crate::rms::PolicyStrategy;
use crate::util::json::Json;
use crate::util::toml;
use crate::workload::swf::SwfOptions;

/// One workload axis entry (`[[workload]]` in the spec).
#[derive(Debug, Clone)]
pub enum WorkloadSource {
    /// Feitelson statistical model (§7.1), the paper's generator.
    Feitelson { jobs: usize, mean_interarrival: f64, work_spread: f64 },
    /// Synthetic bursts of arrivals separated by lulls.
    BurstLull { jobs: usize, burst: usize, burst_gap: f64, lull: f64 },
    /// A real trace in Standard Workload Format.
    Swf { path: String, opts: SwfOptions },
}

impl WorkloadSource {
    /// Short scenario-id component (`feitelson40`, `burst40`,
    /// `swf-small`).
    pub fn label(&self) -> String {
        match self {
            WorkloadSource::Feitelson { jobs, .. } => format!("feitelson{jobs}"),
            WorkloadSource::BurstLull { jobs, .. } => format!("burst{jobs}"),
            WorkloadSource::Swf { path, .. } => {
                let stem = Path::new(path)
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_else(|| "trace".into());
                format!("swf-{stem}")
            }
        }
    }
}

/// One `[[workload]]` entry: the source plus source-independent job
/// decoration applied at materialization time.
#[derive(Debug, Clone)]
pub struct WorkloadAxis {
    /// Where the job stream comes from.
    pub source: WorkloadSource,
    /// Soft-deadline slack: every job gets
    /// `deadline = submit + slack × est_duration` (see
    /// [`crate::workload::WorkloadSpec::with_deadlines`]).  `None` = no
    /// deadlines — the deadline-aware strategy then degenerates to the
    /// baseline and the miss columns stay 0.
    pub deadline_slack: Option<f64>,
}

/// The run mode axis: the paper's rigid baseline plus the two DMR
/// scheduling modes (§5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunMode {
    /// Same stream, every job rigid (`WorkloadSpec::as_fixed`).
    Fixed,
    /// Malleable, synchronous `dmr_check_status`.
    Sync,
    /// Malleable, asynchronous `dmr_icheck_status`.
    Async,
}

impl RunMode {
    /// Parse a spec-file mode name.
    pub fn parse(s: &str) -> Result<RunMode> {
        match s {
            "fixed" => Ok(RunMode::Fixed),
            "sync" => Ok(RunMode::Sync),
            "async" => Ok(RunMode::Async),
            other => bail!("unknown mode {other:?} (expected fixed | sync | async)"),
        }
    }

    /// Short label used in scenario ids and CSV cells.
    pub fn label(&self) -> &'static str {
        match self {
            RunMode::Fixed => "fixed",
            RunMode::Sync => "sync",
            RunMode::Async => "async",
        }
    }

    /// DES scheduling mode + whether jobs stay malleable.
    pub fn des_mode(&self) -> (SchedMode, bool) {
        match self {
            RunMode::Fixed => (SchedMode::Sync, false),
            RunMode::Sync => (SchedMode::Sync, true),
            RunMode::Async => (SchedMode::Async, true),
        }
    }
}

/// Policy-knob axes; each knob is a list so it can be swept (defaults are
/// the `RmsConfig` defaults, a single-point axis).  `strategy` sweeps the
/// reconfiguration-policy engine itself ([`PolicyStrategy`]); the boolean
/// knobs ablate within a strategy.
#[derive(Debug, Clone)]
pub struct PolicyAxis {
    /// Which reconfiguration strategies to run (`[policy] strategy`).
    pub strategy: Vec<PolicyStrategy>,
    /// EASY-backfill on/off.
    pub backfill: Vec<bool>,
    /// §4.3 max-priority boost for the shrink trigger, on/off.
    pub shrink_boost: Vec<bool>,
    /// §4.2 preferred-size handling, on/off.
    pub honor_preference: Vec<bool>,
    /// §4.3 wide optimization, on/off.
    pub wide_optimization: Vec<bool>,
    /// QueueAware pending-pressure threshold (scalar tuning knob, shared
    /// by every run — see `PolicyConfig::queue_pressure`).
    pub queue_pressure: usize,
    /// FairShare over/under-share tolerance, ≥ 1 (scalar tuning knob —
    /// see `PolicyConfig::fair_share_slack`).
    pub fair_share_slack: f64,
}

impl Default for PolicyAxis {
    fn default() -> Self {
        let knobs = crate::rms::PolicyConfig::default();
        PolicyAxis {
            strategy: vec![PolicyStrategy::ThroughputAware],
            backfill: vec![true],
            shrink_boost: vec![true],
            honor_preference: vec![true],
            wide_optimization: vec![true],
            queue_pressure: knobs.queue_pressure,
            fair_share_slack: knobs.fair_share_slack,
        }
    }
}

impl PolicyAxis {
    /// Whether any boolean knob is actually swept (affects scenario ids).
    fn swept(&self) -> bool {
        self.backfill.len() > 1
            || self.shrink_boost.len() > 1
            || self.honor_preference.len() > 1
            || self.wide_optimization.len() > 1
    }

    /// Whether the strategy axis is swept (per-strategy scenario
    /// suffixes).
    fn strategy_swept(&self) -> bool {
        self.strategy.len() > 1
    }
}

/// The `[faults]` sweep axis ([`crate::resilience`]): per-node MTBF and
/// checkpoint interval are sweepable lists; the repair time, scripted
/// fault trace and drain schedule are shared by every scenario so rigid
/// and malleable runs face the *same* machine timeline.
#[derive(Debug, Clone)]
pub struct FaultAxis {
    /// Mean time between failures per node, seconds (`0` = no random
    /// failures).  Sweepable.
    pub mtbf: Vec<f64>,
    /// Mean time to repair, seconds.
    pub mttr: f64,
    /// Checkpoint interval for the rework model, seconds (`0` = no
    /// checkpointing).  Sweepable.
    pub checkpoint_interval: Vec<f64>,
    /// Scripted `fail node=N at t` / `repair at t` events.
    pub scripted: Vec<FaultTraceEvent>,
    /// Scheduled maintenance drain windows.
    pub drains: Vec<DrainWindow>,
}

impl Default for FaultAxis {
    fn default() -> Self {
        FaultAxis {
            mtbf: vec![0.0],
            mttr: 900.0,
            checkpoint_interval: vec![600.0],
            scripted: Vec::new(),
            drains: Vec::new(),
        }
    }
}

impl FaultAxis {
    fn swept(&self) -> bool {
        self.mtbf.len() > 1 || self.checkpoint_interval.len() > 1
    }
}

/// The `[resize_faults]` sweep axis ([`crate::resilience::resize`]): the
/// spawn-failure probability is a sweepable list; the other injection
/// probabilities and the retry/backoff policy are shared by every
/// scenario, so sweeping `spawn_fail` isolates one variable.
#[derive(Debug, Clone)]
pub struct ResizeFaultAxis {
    /// Spawn-failure probabilities to sweep.  A scenario whose resolved
    /// spec is inactive (all probabilities 0) keeps the legacy
    /// single-event resize path.
    pub spawn_fail: Vec<f64>,
    /// Redistribution-abort probability.
    pub redist_fail: f64,
    /// Allocation-revocation probability.
    pub revoke: f64,
    /// Retry budget before a job degrades to non-malleable.
    pub max_retries: u32,
    /// First-retry backoff, seconds.
    pub backoff_base: f64,
    /// Backoff ceiling, seconds.
    pub backoff_cap: f64,
}

impl Default for ResizeFaultAxis {
    fn default() -> Self {
        let d = ResizeFaultSpec::default();
        ResizeFaultAxis {
            spawn_fail: vec![0.0],
            redist_fail: d.redist_fail,
            revoke: d.revoke,
            max_retries: d.max_retries,
            backoff_base: d.backoff_base,
            backoff_cap: d.backoff_cap,
        }
    }
}

impl ResizeFaultAxis {
    fn swept(&self) -> bool {
        self.spawn_fail.len() > 1
    }

    /// The concrete [`ResizeFaultSpec`] of one matrix point.
    pub fn spec(&self, spawn_fail: f64) -> ResizeFaultSpec {
        ResizeFaultSpec {
            spawn_fail,
            redist_fail: self.redist_fail,
            revoke: self.revoke,
            max_retries: self.max_retries,
            backoff_base: self.backoff_base,
            backoff_cap: self.backoff_cap,
        }
    }
}

/// The `[federation]` sweep axis ([`crate::federation`]): shard count and
/// routing policy are sweepable lists; work stealing and an explicit
/// heterogeneous topology are shared by every scenario.  Present only
/// when the spec has a `[federation]` block — flat campaigns keep the
/// single-cluster engine and their historical scenario ids.
#[derive(Debug, Clone)]
pub struct FedAxis {
    /// Shard counts to sweep; each splits the `nodes` axis value evenly
    /// ([`ShardSpec::uniform`]).  Mutually exclusive with `topology`.
    pub shards: Vec<usize>,
    /// Routing policies to sweep ([`RoutingPolicy::parse`] names).
    pub routing: Vec<RoutingPolicy>,
    /// Work-stealing policies to sweep ([`StealPolicy::parse`] names; a
    /// bare boolean still parses as the historical on/off pair).
    pub steal: Vec<StealPolicy>,
    /// Shard-level failure-domain axis (`[federation.outages]`); `None`
    /// keeps every run outage-free.
    pub outages: Option<OutageAxis>,
    /// Explicit heterogeneous layout: `"nodes[:speed[:mtbf_scale]]"`
    /// entries ([`ShardSpec::parse`]).  When set, the shard-count axis
    /// collapses to this single layout, and every `nodes` axis entry must
    /// equal the topology's node total so scenario ids stay truthful.
    pub topology: Option<Vec<ShardSpec>>,
    /// Per-shard fault overrides (`[[federation.shard_fault]]`) wired into
    /// [`crate::federation::FederationConfig::shard_faults`].  Shards
    /// without an entry keep the base `[faults]` spec with their
    /// topology's `mtbf_scale` applied.
    pub shard_faults: Vec<ShardFault>,
}

/// One `[[federation.shard_fault]]` entry: a fault-spec override targeting
/// a single shard of every federated run in the campaign.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardFault {
    /// Shard index the override applies to.
    pub shard: usize,
    /// Per-node MTBF on that shard, seconds (`0` = no random failures
    /// there).
    pub mtbf: f64,
    /// Mean time to repair on that shard, seconds (`None` = inherit the
    /// campaign's `faults.mttr`).
    pub mttr: Option<f64>,
}

/// The `[federation.outages]` block: shard-level failure domains with
/// correlated outages, network partitions, and an optional seeded
/// domain-MTBF stream.  The `enabled` list is the sweepable on/off axis
/// (`[false, true]` runs every scenario both ways); the event tables are
/// shared by all enabled points.
#[derive(Debug, Clone, PartialEq)]
pub struct OutageAxis {
    /// Sweepable on/off axis (default `[true]`: the block's presence
    /// enables outages everywhere).
    pub enabled: Vec<bool>,
    /// Named failure domains, as `(shard, domain)` pairs
    /// (`[[federation.outages.domain]]`).
    pub domains: Vec<(usize, FailureDomain)>,
    /// Scripted outage events, as `(shard, event)` pairs
    /// (`[[federation.outages.outage]]`).
    pub outages: Vec<(usize, OutageEvent)>,
    /// Scripted partition windows, as `(shard, window)` pairs
    /// (`[[federation.outages.partition]]`).
    pub partitions: Vec<(usize, PartitionWindow)>,
    /// Mean time between correlated domain outages per shard (`0` = no
    /// random outages, scripted events only).
    pub mtbf: f64,
    /// Mean outage duration for the random stream.
    pub mttr: f64,
}

impl OutageAxis {
    /// Number of matrix points this axis contributes.
    fn points(&self) -> usize {
        self.enabled.len()
    }

    /// Materialize the per-shard [`OutageSpec`] list of one `shards`-wide
    /// layout.  Entries targeting shards beyond `shards` are dropped (the
    /// index is valid for *some* swept layout, just not this one).
    pub fn specs(&self, shards: usize) -> Vec<OutageSpec> {
        let mut specs = vec![OutageSpec::default(); shards];
        for (s, d) in &self.domains {
            if *s < shards {
                specs[*s].domains.push(d.clone());
            }
        }
        for (s, ev) in &self.outages {
            if *s < shards {
                specs[*s].scripted.push(ev.clone());
            }
        }
        for (s, w) in &self.partitions {
            if *s < shards {
                specs[*s].partitions.push(*w);
            }
        }
        if self.mtbf > 0.0 {
            for sp in &mut specs {
                sp.mtbf = self.mtbf;
                sp.mttr = self.mttr;
            }
        }
        specs
    }
}

impl Default for FedAxis {
    fn default() -> Self {
        FedAxis {
            shards: vec![1],
            routing: vec![RoutingPolicy::RoundRobin],
            steal: vec![StealPolicy::Off],
            outages: None,
            topology: None,
            shard_faults: Vec::new(),
        }
    }
}

impl FedAxis {
    /// Resolve the concrete [`FedPlan`] of one matrix point: the spec
    /// topology verbatim, or a uniform split of the point's cluster size.
    fn plan(
        &self,
        nodes: usize,
        shards: usize,
        routing: RoutingPolicy,
        steal: StealPolicy,
        outages_on: bool,
    ) -> FedPlan {
        let shards = match &self.topology {
            Some(t) => t.clone(),
            None => ShardSpec::uniform(nodes, shards),
        };
        let outages = if outages_on {
            self.outages.as_ref().map(|o| o.specs(shards.len()))
        } else {
            None
        };
        FedPlan { shards, routing, steal, outages }
    }
}

/// Resolved federation point of one [`RunPlan`] (`None` = flat engine).
#[derive(Debug, Clone)]
pub struct FedPlan {
    /// Concrete shard layout of this run (uniform split of the plan's
    /// cluster size, or the spec topology verbatim).
    pub shards: Vec<ShardSpec>,
    /// Routing policy of this run.
    pub routing: RoutingPolicy,
    /// Cross-shard work-stealing policy of this run.
    pub steal: StealPolicy,
    /// Per-shard outage specs (`None` = this point runs outage-free).
    pub outages: Option<Vec<OutageSpec>>,
}

/// One fully-resolved point of the matrix.
#[derive(Debug, Clone)]
pub struct RunPlan {
    /// Position in the expanded matrix (stable output ordering).
    pub index: usize,
    /// Scenario id: every axis except the seed.
    pub scenario: String,
    /// Run label: scenario + seed.
    pub label: String,
    /// Index into `CampaignSpec::workloads`.
    pub workload: usize,
    /// Cluster size of this matrix point.
    pub nodes: usize,
    /// Run mode (rigid baseline / sync / async).
    pub mode: RunMode,
    /// Seed of this run (workload sampling + DES cost jitter).
    pub seed: u64,
    /// Reconfiguration strategy of this matrix point.
    pub strategy: PolicyStrategy,
    /// EASY-backfill knob.
    pub backfill: bool,
    /// Shrink-trigger priority-boost knob.
    pub shrink_boost: bool,
    /// §4.2 preferred-size knob.
    pub honor_preference: bool,
    /// §4.3 wide-optimization knob.
    pub wide_optimization: bool,
    /// Per-node MTBF of this matrix point (0 = no random failures).
    pub mtbf: f64,
    /// Checkpoint interval of this matrix point.
    pub checkpoint_interval: f64,
    /// Resize spawn-failure probability of this matrix point (the swept
    /// component of the `[resize_faults]` axis).
    pub spawn_fail: f64,
    /// Federation point (`None` = the flat single-cluster engine).
    pub federation: Option<FedPlan>,
    /// Run through the streaming pipeline (lazy arrivals, reclaimed
    /// archives) instead of materializing the workload.
    pub stream: bool,
    /// Retain per-job records/events/telemetry (always `true` for
    /// non-streamed runs; the `[stream]` knob for streamed ones).
    pub keep_records: bool,
    /// Streaming look-ahead window (unused when `stream` is false).
    pub lookahead: usize,
}

/// The optional `[trace]` block: default stride/cap knobs applied when the
/// campaign runs with `--trace <dir>` (the CLI flags override them).  Not
/// a sweep axis — tracing is post-run and never changes scenario ids or
/// outputs, so there is nothing to sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceAxis {
    /// Keep every `stride`-th job track (1 = every job).
    pub stride: usize,
    /// Upper bound on kept job tracks (0 = unlimited).
    pub cap: usize,
}

impl Default for TraceAxis {
    fn default() -> Self {
        TraceAxis { stride: 1, cap: 0 }
    }
}

/// The optional `[stream]` block: the streaming-replay memory model
/// (see `docs/ARCHITECTURE.md`, "Streaming replay & memory model").  Not
/// a sweep axis — streamed and materialized runs are bit-identical by
/// construction, so there is nothing to sweep; the block only changes how
/// much memory a run holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamAxis {
    /// Pull jobs lazily through a [`crate::workload::JobStream`] instead
    /// of materializing the whole workload (`enabled = true`, or just the
    /// presence of a `[stream]` block).
    pub enabled: bool,
    /// Retain per-job records, raw events and telemetry even when
    /// streaming (needed for per-job CSVs and `--trace` export; costs
    /// O(total jobs) memory).  Default `false` under `[stream]`.
    pub keep_records: bool,
    /// Look-ahead window: unarrived jobs held resident (any value ≥ 1
    /// gives bit-identical results; bigger is marginally faster I/O).
    pub lookahead: usize,
}

impl Default for StreamAxis {
    fn default() -> Self {
        StreamAxis { enabled: false, keep_records: true, lookahead: 64 }
    }
}

/// A parsed campaign specification.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Campaign name (also names the output files).
    pub name: String,
    /// Where per-run and aggregate outputs land.
    pub output_dir: PathBuf,
    /// Worker threads (0 = one per available core); `--workers` overrides.
    pub workers: usize,
    /// The `[[workload]]` axis entries.
    pub workloads: Vec<WorkloadAxis>,
    /// Cluster-size axis.
    pub nodes: Vec<usize>,
    /// Run-mode axis.
    pub modes: Vec<RunMode>,
    /// Seed axis (one run per seed per scenario).
    pub seeds: Vec<u64>,
    /// Policy strategies + knobs.
    pub policy: PolicyAxis,
    /// Fault-injection axis.
    pub faults: FaultAxis,
    /// Resize-transaction fault-injection axis.
    pub resize_faults: ResizeFaultAxis,
    /// Federation axis (`None` = no `[federation]` block, flat runs).
    pub federation: Option<FedAxis>,
    /// Default trace-export knobs for `--trace` runs (`[trace]` block).
    pub trace: TraceAxis,
    /// Streaming-replay knobs (`[stream]` block; disabled by default).
    pub stream: StreamAxis,
}

impl CampaignSpec {
    /// Load from a `.toml` or `.json` file.
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<CampaignSpec> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading campaign spec {path:?}"))?;
        let is_json = path.extension().map(|e| e == "json").unwrap_or(false);
        let spec = if is_json {
            Self::from_json_str(&text)
        } else {
            Self::from_toml_str(&text)
        };
        spec.with_context(|| format!("in campaign spec {path:?}"))
    }

    /// Parse from TOML text (the subset in [`crate::util::toml`]).
    pub fn from_toml_str(text: &str) -> Result<CampaignSpec> {
        let v = toml::parse(text).map_err(|e| anyhow!("{e}"))?;
        Self::from_value(&v)
    }

    /// Parse from JSON text (same document shape as the TOML form).
    pub fn from_json_str(text: &str) -> Result<CampaignSpec> {
        let v = Json::parse(text).map_err(|e| anyhow!("json: {e}"))?;
        Self::from_value(&v)
    }

    /// Build from the parsed document (shared by both formats).
    pub fn from_value(v: &Json) -> Result<CampaignSpec> {
        let name = v
            .get("name")
            .and_then(|n| n.as_str())
            .context("spec needs a string `name`")?
            .to_string();
        let output_dir = v
            .get("output_dir")
            .and_then(|n| n.as_str())
            .map(PathBuf::from)
            .unwrap_or_else(|| Path::new("results/campaigns").join(&name));
        let workers = v.get("workers").and_then(|n| n.as_usize()).unwrap_or(0);

        let nodes = usize_list(v.get("nodes"), "nodes")?
            .unwrap_or_else(|| vec![crate::cluster::DEFAULT_NODES]);
        if nodes.iter().any(|&n| n == 0) {
            bail!("`nodes` entries must be positive");
        }

        let modes = match v.get("modes") {
            None => vec![RunMode::Fixed, RunMode::Sync],
            Some(m) => m
                .as_arr()
                .context("`modes` must be an array of strings")?
                .iter()
                .map(|s| {
                    RunMode::parse(s.as_str().context("`modes` entries must be strings")?)
                })
                .collect::<Result<Vec<_>>>()?,
        };

        let seeds = match usize_list(v.get("seeds"), "seeds")? {
            None => vec![1, 2, 3],
            Some(s) => s.into_iter().map(|x| x as u64).collect(),
        };

        let workloads = v
            .get("workload")
            .context("spec needs at least one [[workload]]")?
            .as_arr()
            .context("`workload` must be an array of tables")?
            .iter()
            .map(parse_workload)
            .collect::<Result<Vec<_>>>()?;
        if workloads.is_empty() || nodes.is_empty() || modes.is_empty() || seeds.is_empty() {
            bail!("workload/nodes/modes/seeds axes must be non-empty");
        }

        let policy = match v.get("policy") {
            None => PolicyAxis::default(),
            Some(p) => {
                let d = PolicyAxis::default();
                let fair_share_slack = match p.get("fair_share_slack") {
                    None => d.fair_share_slack,
                    Some(x) => {
                        let s = x
                            .as_f64()
                            .context("`policy.fair_share_slack` must be a number")?;
                        if !(s.is_finite() && s >= 1.0) {
                            bail!("`policy.fair_share_slack` must be >= 1 (got {s})");
                        }
                        s
                    }
                };
                let queue_pressure = match p.get("queue_pressure") {
                    None => d.queue_pressure,
                    Some(x) => usize_scalar(Some(x), "policy.queue_pressure")?,
                };
                PolicyAxis {
                    strategy: strategy_list(p.get("strategy"))?
                        .unwrap_or_else(|| vec![PolicyStrategy::ThroughputAware]),
                    backfill: bool_list(p.get("backfill"), "policy.backfill")?
                        .unwrap_or_else(|| vec![true]),
                    shrink_boost: bool_list(p.get("shrink_boost"), "policy.shrink_boost")?
                        .unwrap_or_else(|| vec![true]),
                    honor_preference: bool_list(
                        p.get("honor_preference"),
                        "policy.honor_preference",
                    )?
                    .unwrap_or_else(|| vec![true]),
                    wide_optimization: bool_list(
                        p.get("wide_optimization"),
                        "policy.wide_optimization",
                    )?
                    .unwrap_or_else(|| vec![true]),
                    queue_pressure,
                    fair_share_slack,
                }
            }
        };
        if policy.strategy.is_empty() {
            bail!("`policy.strategy` must not be empty");
        }

        let max_nodes = nodes.iter().copied().max().unwrap_or(0);
        let faults = match v.get("faults") {
            None => FaultAxis::default(),
            Some(f) => parse_faults(f, max_nodes)?,
        };

        let resize_faults = match v.get("resize_faults") {
            None => ResizeFaultAxis::default(),
            Some(f) => parse_resize_faults(f)?,
        };

        let federation = match v.get("federation") {
            None => None,
            Some(f) => Some(parse_federation(f, &nodes)?),
        };

        let trace = match v.get("trace") {
            None => TraceAxis::default(),
            Some(t) => parse_trace(t)?,
        };

        let stream = match v.get("stream") {
            None => StreamAxis::default(),
            Some(s) => parse_stream(s)?,
        };

        // A duplicate entry on any swept axis would emit two *non-adjacent*
        // scenario blocks with identical ids; aggregate() merges only
        // adjacent records, so the aggregate CSV would carry duplicate
        // scenario rows each holding a fraction of the seeds.  (Duplicate
        // [[workload]] sources are fine — expand() disambiguates their
        // labels with a -w<index> suffix.)
        no_duplicates(&nodes, "nodes")?;
        no_duplicates(&modes, "modes")?;
        no_duplicates(&seeds, "seeds")?;
        no_duplicates(&policy.strategy, "policy.strategy")?;
        no_duplicates(&policy.backfill, "policy.backfill")?;
        no_duplicates(&policy.shrink_boost, "policy.shrink_boost")?;
        no_duplicates(&policy.honor_preference, "policy.honor_preference")?;
        no_duplicates(&policy.wide_optimization, "policy.wide_optimization")?;
        no_duplicates(&faults.mtbf, "faults.mtbf")?;
        no_duplicates(&faults.checkpoint_interval, "faults.checkpoint_interval")?;
        no_duplicates(&resize_faults.spawn_fail, "resize_faults.spawn_fail")?;
        if let Some(fed) = &federation {
            no_duplicates(&fed.shards, "federation.shards")?;
            no_duplicates(&fed.routing, "federation.routing")?;
            no_duplicates(&fed.steal, "federation.steal")?;
            if let Some(out) = &fed.outages {
                no_duplicates(&out.enabled, "federation.outages.enabled")?;
            }
        }

        Ok(CampaignSpec {
            name,
            output_dir,
            workers,
            workloads,
            nodes,
            modes,
            seeds,
            policy,
            faults,
            resize_faults,
            federation,
            trace,
            stream,
        })
    }

    /// Number of runs the matrix expands to.
    pub fn matrix_size(&self) -> usize {
        self.workloads.len()
            * self.nodes.len()
            * self.modes.len()
            * self.seeds.len()
            * self.policy.strategy.len()
            * self.policy.backfill.len()
            * self.policy.shrink_boost.len()
            * self.policy.honor_preference.len()
            * self.policy.wide_optimization.len()
            * self.faults.mtbf.len()
            * self.faults.checkpoint_interval.len()
            * self.resize_faults.spawn_fail.len()
            * self
                .federation
                .as_ref()
                .map(|f| {
                    f.shards.len()
                        * f.routing.len()
                        * f.steal.len()
                        * f.outages.as_ref().map(|o| o.points()).unwrap_or(1)
                })
                .unwrap_or(1)
    }

    /// Expand the cartesian matrix into the flat, deterministic run list.
    /// Order: federation (outer) → workload → nodes → mode → strategy →
    /// policy knobs → faults → resize faults → seed (inner), so all seeds
    /// of one scenario are adjacent.
    pub fn expand(&self) -> Vec<RunPlan> {
        let mut plans = Vec::with_capacity(self.matrix_size());
        let pol = &self.policy;
        let swept = pol.swept();
        let strat_swept = pol.strategy_swept();
        // Labels only encode kind + size; two same-kind sources differing
        // in other params (e.g. two feitelson-30 with different
        // inter-arrivals) would collide and aggregate() would silently
        // merge them — disambiguate with the workload's position.
        let labels: Vec<String> = {
            let raw: Vec<String> =
                self.workloads.iter().map(|w| w.source.label()).collect();
            raw.iter()
                .enumerate()
                .map(|(i, l)| {
                    if raw.iter().filter(|x| *x == l).count() > 1 {
                        format!("{l}-w{i}")
                    } else {
                        l.clone()
                    }
                })
                .collect()
        };
        let faults_swept = self.faults.swept();
        let rf_swept = self.resize_faults.swept();
        // Fault-axis points as a flat (mtbf, checkpoint, spawn_fail) list
        // in axis order — machine faults outer, resize faults
        // innermost-but-seed — so adding the resize axis keeps the loop
        // nest below at its historical depth.
        let fault_points: Vec<(f64, f64, f64)> = {
            let mut pts = Vec::new();
            for &mtbf in &self.faults.mtbf {
                for &ckpt in &self.faults.checkpoint_interval {
                    for &rf in &self.resize_faults.spawn_fail {
                        pts.push((mtbf, ckpt, rf));
                    }
                }
            }
            pts
        };
        // Federation points as a flat (shard count, routing, steal,
        // outages-on, scenario suffix) list — one degenerate point with an
        // empty suffix when the spec has no [federation] block, so flat
        // campaigns keep their historical scenario ids.  The steal and
        // outage components suffix the id only when actually swept, so
        // single-policy campaigns keep their historical ids too.
        let fed_points: Vec<(usize, RoutingPolicy, StealPolicy, bool, String)> =
            match &self.federation {
                None => {
                    vec![(1, RoutingPolicy::RoundRobin, StealPolicy::Off, false, String::new())]
                }
                Some(f) => {
                    let steal_swept = f.steal.len() > 1;
                    let outage_axis: Vec<bool> = match &f.outages {
                        Some(o) => o.enabled.clone(),
                        None => vec![false],
                    };
                    let outage_swept = outage_axis.len() > 1;
                    let mut pts = Vec::new();
                    for &k in &f.shards {
                        for &r in &f.routing {
                            for &st in &f.steal {
                                for &out in &outage_axis {
                                    let mut sfx = format!("-s{k}x{}", r.label());
                                    if steal_swept {
                                        sfx.push('x');
                                        sfx.push_str(st.label());
                                    }
                                    if outage_swept && out {
                                        sfx.push_str("-out");
                                    }
                                    pts.push((k, r, st, out, sfx));
                                }
                            }
                        }
                    }
                    pts
                }
            };
        for (fed_k, fed_route, fed_steal, fed_out, fed_suffix) in &fed_points {
            for wi in 0..self.workloads.len() {
                for &nodes in &self.nodes {
                    let federation = match &self.federation {
                        None => None,
                        Some(f) => {
                            Some(f.plan(nodes, *fed_k, *fed_route, *fed_steal, *fed_out))
                        }
                    };
                    for &mode in &self.modes {
                        for &strategy in &pol.strategy {
                            for &backfill in &pol.backfill {
                                for &shrink_boost in &pol.shrink_boost {
                                    for &honor_preference in &pol.honor_preference {
                                        for &wide_optimization in &pol.wide_optimization {
                                            for &(mtbf, ckpt, spawn_fail) in &fault_points {
                                                let mut scenario = format!(
                                                    "{}-n{}-{}",
                                                    labels[wi],
                                                    nodes,
                                                    mode.label()
                                                );
                                                if strat_swept {
                                                    scenario.push('-');
                                                    scenario.push_str(strategy.label());
                                                }
                                                if swept {
                                                    scenario.push_str(&format!(
                                                        "-bf{}-sb{}-hp{}-wo{}",
                                                        u8::from(backfill),
                                                        u8::from(shrink_boost),
                                                        u8::from(honor_preference),
                                                        u8::from(wide_optimization),
                                                    ));
                                                }
                                                if faults_swept {
                                                    scenario.push_str(&format!(
                                                        "-mtbf{}-ck{}",
                                                        fmt_axis(mtbf),
                                                        fmt_axis(ckpt),
                                                    ));
                                                }
                                                if rf_swept {
                                                    scenario.push_str(&format!(
                                                        "-rf{}",
                                                        fmt_axis(spawn_fail),
                                                    ));
                                                }
                                                scenario.push_str(fed_suffix);
                                                for &seed in &self.seeds {
                                                    plans.push(RunPlan {
                                                        index: plans.len(),
                                                        scenario: scenario.clone(),
                                                        label: format!("{scenario}-s{seed}"),
                                                        workload: wi,
                                                        nodes,
                                                        mode,
                                                        seed,
                                                        strategy,
                                                        backfill,
                                                        shrink_boost,
                                                        honor_preference,
                                                        wide_optimization,
                                                        mtbf,
                                                        checkpoint_interval: ckpt,
                                                        spawn_fail,
                                                        federation: federation.clone(),
                                                        stream: self.stream.enabled,
                                                        keep_records: !self.stream.enabled
                                                            || self.stream.keep_records,
                                                        lookahead: self.stream.lookahead,
                                                    });
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        plans
    }
}

/// Compact axis-value rendering for scenario ids (`20000`, not `20000.0`).
fn fmt_axis(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn parse_workload(w: &Json) -> Result<WorkloadAxis> {
    let deadline_slack = match w.get("deadline_slack") {
        None => None,
        Some(x) => {
            let s = x
                .as_f64()
                .context("[[workload]] `deadline_slack` must be a number")?;
            if !(s.is_finite() && s > 0.0) {
                bail!("[[workload]] `deadline_slack` must be positive (got {s})");
            }
            Some(s)
        }
    };
    let source = parse_workload_source(w)?;
    Ok(WorkloadAxis { source, deadline_slack })
}

fn parse_workload_source(w: &Json) -> Result<WorkloadSource> {
    let kind = w
        .get("kind")
        .and_then(|k| k.as_str())
        .context("[[workload]] needs a string `kind`")?;
    let jobs = w.get("jobs").and_then(|j| j.as_usize());
    let f64_or = |key: &str, d: f64| w.get(key).and_then(|x| x.as_f64()).unwrap_or(d);
    match kind {
        "feitelson" => Ok(WorkloadSource::Feitelson {
            jobs: jobs.context("feitelson workload needs `jobs`")?,
            mean_interarrival: f64_or("mean_interarrival", 10.0),
            work_spread: f64_or("work_spread", 0.25),
        }),
        "burst_lull" => Ok(WorkloadSource::BurstLull {
            jobs: jobs.context("burst_lull workload needs `jobs`")?,
            burst: w.get("burst").and_then(|x| x.as_usize()).unwrap_or(8),
            burst_gap: f64_or("burst_gap", 2.0),
            lull: f64_or("lull", 300.0),
        }),
        "swf" => {
            let path = w
                .get("path")
                .and_then(|p| p.as_str())
                .context("swf workload needs a `path`")?
                .to_string();
            let d = SwfOptions::default();
            let opts = SwfOptions {
                max_jobs: w.get("max_jobs").and_then(|x| x.as_usize()),
                rescale_nodes: w.get("rescale_nodes").and_then(|x| x.as_usize()),
                malleable_fraction: f64_or("malleable_fraction", d.malleable_fraction),
                shrink_levels: w
                    .get("shrink_levels")
                    .and_then(|x| x.as_usize())
                    .map(|x| x as u32)
                    .unwrap_or(d.shrink_levels),
                factor: w.get("factor").and_then(|x| x.as_usize()).unwrap_or(d.factor),
                time_scale: f64_or("time_scale", d.time_scale),
                iterations: w
                    .get("iterations")
                    .and_then(|x| x.as_usize())
                    .map(|x| x as u32)
                    .unwrap_or(d.iterations),
                include_failed: match w.get("include_failed") {
                    None => d.include_failed,
                    Some(Json::Bool(b)) => *b,
                    Some(_) => bail!("`include_failed` must be a boolean"),
                },
            };
            if !(0.0..=1.0).contains(&opts.malleable_fraction) {
                bail!("malleable_fraction must be in [0, 1]");
            }
            Ok(WorkloadSource::Swf { path, opts })
        }
        other => bail!("unknown workload kind {other:?} (feitelson | burst_lull | swf)"),
    }
}

/// Non-negative integer scalar (rejects negatives and fractions, which
/// `Json::as_usize` would silently saturate/truncate).
fn usize_scalar(v: Option<&Json>, what: &str) -> Result<usize> {
    let f = v
        .and_then(|x| x.as_f64())
        .with_context(|| format!("`{what}` must be an integer"))?;
    if f.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&f) {
        bail!("`{what}` value {f} is not a non-negative integer");
    }
    Ok(f as usize)
}

/// Parse the `[faults]` section (see `scenarios/README.md` for the
/// schema and `scenarios/faulty_cluster.toml` for a worked example).
/// `max_nodes` is the largest entry of the `nodes` axis: a scripted or
/// drained node id at or beyond it could never fire in any scenario, so
/// it is rejected as a spec typo (ids valid only for *some* axis points
/// are allowed — the engine skips them on smaller machines).
fn parse_faults(f: &Json, max_nodes: usize) -> Result<FaultAxis> {
    let d = FaultAxis::default();
    let mtbf = f64_list(f.get("mtbf"), "faults.mtbf")?.unwrap_or(d.mtbf);
    if mtbf.is_empty() {
        bail!("`faults.mtbf` must not be empty");
    }
    let mttr = match f.get("mttr") {
        None => d.mttr,
        Some(x) => x.as_f64().context("`faults.mttr` must be a number")?,
    };
    if mttr < 0.0 {
        bail!("`faults.mttr` must be non-negative");
    }
    let checkpoint_interval =
        f64_list(f.get("checkpoint_interval"), "faults.checkpoint_interval")?
            .unwrap_or(d.checkpoint_interval);
    if checkpoint_interval.is_empty() {
        bail!("`faults.checkpoint_interval` must not be empty");
    }

    let mut scripted = Vec::new();
    if let Some(fails) = f.get("fail") {
        for (i, ev) in fails
            .as_arr()
            .context("`[[faults.fail]]` must be an array of tables")?
            .iter()
            .enumerate()
        {
            let node = usize_scalar(ev.get("node"), &format!("faults.fail[{i}].node"))?;
            if node >= max_nodes {
                bail!(
                    "faults.fail[{i}]: node {node} does not exist on any swept cluster \
                     (largest `nodes` entry is {max_nodes})"
                );
            }
            let at = ev
                .get("at")
                .and_then(|x| x.as_f64())
                .with_context(|| format!("faults.fail[{i}] needs a number `at`"))?;
            if at < 0.0 {
                bail!("faults.fail[{i}]: `at` must be non-negative");
            }
            scripted.push(FaultTraceEvent { at, node, kind: FaultKind::Fail });
            if let Some(r) = ev.get("repair_at") {
                let repair_at = r
                    .as_f64()
                    .with_context(|| format!("faults.fail[{i}]: `repair_at` must be a number"))?;
                if repair_at <= at {
                    bail!("faults.fail[{i}]: `repair_at` must be after `at`");
                }
                scripted.push(FaultTraceEvent { at: repair_at, node, kind: FaultKind::Repair });
            }
        }
    }

    let mut drains = Vec::new();
    if let Some(ds) = f.get("drain") {
        for (i, w) in ds
            .as_arr()
            .context("`[[faults.drain]]` must be an array of tables")?
            .iter()
            .enumerate()
        {
            let start = w
                .get("start")
                .and_then(|x| x.as_f64())
                .with_context(|| format!("faults.drain[{i}] needs a number `start`"))?;
            let end = w
                .get("end")
                .and_then(|x| x.as_f64())
                .with_context(|| format!("faults.drain[{i}] needs a number `end`"))?;
            if !(start >= 0.0 && end > start) {
                bail!("faults.drain[{i}]: need 0 <= start < end");
            }
            let nodes = match w.get("nodes") {
                Some(n @ Json::Num(_)) => {
                    let count = usize_scalar(Some(n), &format!("faults.drain[{i}].nodes"))?;
                    if count > max_nodes {
                        bail!(
                            "faults.drain[{i}]: count {count} exceeds the largest \
                             `nodes` entry ({max_nodes})"
                        );
                    }
                    DrainSet::Count(count)
                }
                Some(arr @ Json::Arr(_)) => {
                    let ids =
                        usize_list(Some(arr), "faults.drain.nodes")?.unwrap_or_default();
                    if ids.is_empty() {
                        bail!("faults.drain[{i}]: `nodes` list must not be empty");
                    }
                    if let Some(&bad) = ids.iter().find(|&&n| n >= max_nodes) {
                        bail!(
                            "faults.drain[{i}]: node {bad} does not exist on any swept \
                             cluster (largest `nodes` entry is {max_nodes})"
                        );
                    }
                    DrainSet::Nodes(ids)
                }
                _ => bail!("faults.drain[{i}] needs `nodes` (a count or a node list)"),
            };
            drains.push(DrainWindow { start, end, nodes });
        }
    }

    Ok(FaultAxis { mtbf, mttr, checkpoint_interval, scripted, drains })
}

/// Parse the `[resize_faults]` section (see `scenarios/README.md` for the
/// schema and `scenarios/resize_faults.toml` for a worked example).
fn parse_resize_faults(f: &Json) -> Result<ResizeFaultAxis> {
    let d = ResizeFaultAxis::default();
    let spawn_fail =
        f64_list(f.get("spawn_fail"), "resize_faults.spawn_fail")?.unwrap_or(d.spawn_fail);
    if spawn_fail.is_empty() {
        bail!("`resize_faults.spawn_fail` must not be empty");
    }
    // f64_list already rejects negatives/non-finites; cap the high side.
    if let Some(&bad) = spawn_fail.iter().find(|&&p| p > 1.0) {
        bail!("`resize_faults.spawn_fail` entry {bad} is not a probability in [0, 1]");
    }
    let prob = |key: &str, dv: f64| -> Result<f64> {
        match f.get(key) {
            None => Ok(dv),
            Some(x) => {
                let p = x
                    .as_f64()
                    .with_context(|| format!("`resize_faults.{key}` must be a number"))?;
                if !(0.0..=1.0).contains(&p) {
                    bail!("`resize_faults.{key}` must be a probability in [0, 1] (got {p})");
                }
                Ok(p)
            }
        }
    };
    let redist_fail = prob("redist_fail", d.redist_fail)?;
    let revoke = prob("revoke", d.revoke)?;
    let max_retries = match f.get("max_retries") {
        None => d.max_retries,
        Some(x) => usize_scalar(Some(x), "resize_faults.max_retries")? as u32,
    };
    let pos = |key: &str, dv: f64| -> Result<f64> {
        match f.get(key) {
            None => Ok(dv),
            Some(x) => {
                let v = x
                    .as_f64()
                    .with_context(|| format!("`resize_faults.{key}` must be a number"))?;
                if !(v.is_finite() && v > 0.0) {
                    bail!("`resize_faults.{key}` must be positive (got {v})");
                }
                Ok(v)
            }
        }
    };
    let backoff_base = pos("backoff_base", d.backoff_base)?;
    let backoff_cap = pos("backoff_cap", d.backoff_cap)?;
    if backoff_cap < backoff_base {
        bail!(
            "`resize_faults.backoff_cap` ({backoff_cap}) must be >= \
             `backoff_base` ({backoff_base})"
        );
    }
    Ok(ResizeFaultAxis { spawn_fail, redist_fail, revoke, max_retries, backoff_base, backoff_cap })
}

/// Parse the `[federation]` section (see `scenarios/README.md` for the
/// schema and `scenarios/federated_sweep.toml` for a worked example).
/// `nodes` is the cluster-size axis: every shard count must divide into
/// at least one node per shard on the *smallest* swept cluster, and an
/// explicit topology must sum to every swept cluster size so the
/// `-n<nodes>` scenario component stays truthful.
fn parse_federation(f: &Json, nodes: &[usize]) -> Result<FedAxis> {
    let d = FedAxis::default();
    let topology = match f.get("topology") {
        None => None,
        Some(t) => {
            let entries = t
                .as_arr()
                .context("`federation.topology` must be an array of strings")?
                .iter()
                .map(|x| {
                    let s = x
                        .as_str()
                        .context("`federation.topology` entries must be strings")?;
                    ShardSpec::parse(s).map_err(|e| anyhow!("federation.topology: {e}"))
                })
                .collect::<Result<Vec<_>>>()?;
            if entries.is_empty() {
                bail!("`federation.topology` must not be empty");
            }
            let total: usize = entries.iter().map(|s| s.nodes).sum();
            if let Some(&bad) = nodes.iter().find(|&&n| n != total) {
                bail!(
                    "`federation.topology` nodes sum to {total}, but the `nodes` axis \
                     lists {bad} — they must match so scenario ids stay truthful"
                );
            }
            Some(entries)
        }
    };
    let shards = match usize_list(f.get("shards"), "federation.shards")? {
        None => match &topology {
            Some(t) => vec![t.len()],
            None => d.shards,
        },
        Some(s) => {
            if topology.is_some() {
                bail!("`federation.shards` and `federation.topology` are mutually exclusive");
            }
            if s.is_empty() {
                bail!("`federation.shards` must not be empty");
            }
            if s.contains(&0) {
                bail!("`federation.shards` entries must be positive");
            }
            let min_nodes = nodes.iter().copied().min().unwrap_or(0);
            if let Some(&big) = s.iter().find(|&&k| k > min_nodes) {
                bail!(
                    "`federation.shards` entry {big} exceeds the smallest `nodes` \
                     entry ({min_nodes}); every shard needs at least one node"
                );
            }
            s
        }
    };
    let routing = match f.get("routing") {
        None => d.routing,
        Some(r) => {
            let pols = r
                .as_arr()
                .context("`federation.routing` must be an array of strings")?
                .iter()
                .map(|x| {
                    let s = x
                        .as_str()
                        .context("`federation.routing` entries must be strings")?;
                    RoutingPolicy::parse(s).ok_or_else(|| {
                        anyhow!("unknown routing policy {s:?} (expected rr | ll | loc)")
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            if pols.is_empty() {
                bail!("`federation.routing` must not be empty");
            }
            pols
        }
    };
    let parse_steal = |s: &str| {
        StealPolicy::parse(s)
            .ok_or_else(|| anyhow!("unknown steal policy {s:?} (expected off | head | half)"))
    };
    let steal = match f.get("steal") {
        None => d.steal,
        // Historical boolean form: `true` is the original steal-the-head
        // behaviour, `false` is off.
        Some(Json::Bool(b)) => vec![if *b { StealPolicy::Head } else { StealPolicy::Off }],
        Some(v) => {
            if let Some(s) = v.as_str() {
                vec![parse_steal(s)?]
            } else if let Some(arr) = v.as_arr() {
                let pols = arr
                    .iter()
                    .map(|x| {
                        let s = x
                            .as_str()
                            .context("`federation.steal` entries must be strings")?;
                        parse_steal(s)
                    })
                    .collect::<Result<Vec<_>>>()?;
                if pols.is_empty() {
                    bail!("`federation.steal` must not be empty");
                }
                pols
            } else {
                bail!(
                    "`federation.steal` must be a boolean, a policy name, or a list \
                     of policy names (off | head | half)"
                );
            }
        }
    };
    let outages = match f.get("outages") {
        None => None,
        Some(o) => Some(parse_outages(o, shards.iter().copied().max().unwrap_or(1))?),
    };
    let mut shard_faults: Vec<ShardFault> = Vec::new();
    if let Some(sf) = f.get("shard_fault") {
        // A shard index must exist in at least one swept layout; indices
        // valid only for *some* shard counts are allowed — the runner
        // defaults the missing shards on smaller layouts.
        let max_shards = shards.iter().copied().max().unwrap_or(1);
        for (i, ev) in sf
            .as_arr()
            .context("`[[federation.shard_fault]]` must be an array of tables")?
            .iter()
            .enumerate()
        {
            let shard = usize_scalar(ev.get("shard"), &format!("federation.shard_fault[{i}].shard"))?;
            if shard >= max_shards {
                bail!(
                    "federation.shard_fault[{i}]: shard {shard} does not exist in any \
                     swept layout (largest shard count is {max_shards})"
                );
            }
            if shard_faults.iter().any(|s| s.shard == shard) {
                bail!("federation.shard_fault[{i}]: shard {shard} listed more than once");
            }
            let mtbf = ev
                .get("mtbf")
                .and_then(|x| x.as_f64())
                .with_context(|| format!("federation.shard_fault[{i}] needs a number `mtbf`"))?;
            if !(mtbf.is_finite() && mtbf >= 0.0) {
                bail!("federation.shard_fault[{i}]: `mtbf` must be non-negative");
            }
            let mttr = match ev.get("mttr") {
                None => None,
                Some(x) => {
                    let m = x.as_f64().with_context(|| {
                        format!("federation.shard_fault[{i}]: `mttr` must be a number")
                    })?;
                    if !(m.is_finite() && m >= 0.0) {
                        bail!("federation.shard_fault[{i}]: `mttr` must be non-negative");
                    }
                    Some(m)
                }
            };
            shard_faults.push(ShardFault { shard, mtbf, mttr });
        }
    }
    Ok(FedAxis { shards, routing, steal, outages, topology, shard_faults })
}

/// Parse the `[federation.outages]` block (see `scenarios/README.md` for
/// the schema and `scenarios/shard_outage.toml` for a worked example).
/// `max_shards` is the largest swept shard count: an entry targeting a
/// shard at or beyond it could never fire in any scenario, so it is
/// rejected as a spec typo (indices valid only for *some* layouts are
/// allowed — [`OutageAxis::specs`] drops them on smaller layouts).
fn parse_outages(o: &Json, max_shards: usize) -> Result<OutageAxis> {
    let enabled = match o.get("enabled") {
        None => vec![true],
        Some(Json::Bool(b)) => vec![*b],
        Some(v) => {
            let arr = v.as_arr().context(
                "`federation.outages.enabled` must be a boolean or a boolean list",
            )?;
            let mut e = Vec::new();
            for x in arr {
                match x {
                    Json::Bool(b) => e.push(*b),
                    _ => bail!("`federation.outages.enabled` entries must be booleans"),
                }
            }
            if e.is_empty() {
                bail!("`federation.outages.enabled` must not be empty");
            }
            e
        }
    };
    let mtbf = match o.get("mtbf") {
        None => 0.0,
        Some(x) => x.as_f64().context("`federation.outages.mtbf` must be a number")?,
    };
    if !(mtbf.is_finite() && mtbf >= 0.0) {
        bail!("`federation.outages.mtbf` must be non-negative");
    }
    let mttr = match o.get("mttr") {
        None => 0.0,
        Some(x) => x.as_f64().context("`federation.outages.mttr` must be a number")?,
    };
    if !(mttr.is_finite() && mttr >= 0.0) {
        bail!("`federation.outages.mttr` must be non-negative");
    }
    if mtbf > 0.0 && mttr <= 0.0 {
        bail!("`federation.outages.mttr` must be positive when `mtbf` is set");
    }

    let shard_of = |t: &Json, what: &str| -> Result<usize> {
        let s = usize_scalar(t.get("shard"), &format!("{what}.shard"))?;
        if s >= max_shards {
            bail!(
                "{what}: shard {s} does not exist in any swept layout \
                 (largest shard count is {max_shards})"
            );
        }
        Ok(s)
    };

    let mut domains: Vec<(usize, FailureDomain)> = Vec::new();
    if let Some(ds) = o.get("domain") {
        for (i, dv) in ds
            .as_arr()
            .context("`[[federation.outages.domain]]` must be an array of tables")?
            .iter()
            .enumerate()
        {
            let what = format!("federation.outages.domain[{i}]");
            let shard = shard_of(dv, &what)?;
            let name = dv
                .get("name")
                .and_then(|x| x.as_str())
                .with_context(|| format!("{what} needs a string `name`"))?
                .to_string();
            if name.is_empty() || name == "shard" || name == "all" {
                bail!("{what}: name {name:?} is reserved for the whole-shard domain");
            }
            if domains.iter().any(|(s, d)| *s == shard && d.name == name) {
                bail!("{what}: domain {name:?} listed more than once for shard {shard}");
            }
            let nodes = match dv.get("nodes") {
                Some(n @ Json::Num(_)) => {
                    DrainSet::Count(usize_scalar(Some(n), &format!("{what}.nodes"))?)
                }
                Some(arr @ Json::Arr(_)) => {
                    let ids = usize_list(Some(arr), &format!("{what}.nodes"))?
                        .unwrap_or_default();
                    if ids.is_empty() {
                        bail!("{what}: `nodes` list must not be empty");
                    }
                    DrainSet::Nodes(ids)
                }
                _ => bail!("{what} needs `nodes` (a count or a node-id list)"),
            };
            domains.push((shard, FailureDomain { name, nodes }));
        }
    }

    let mut outages: Vec<(usize, OutageEvent)> = Vec::new();
    if let Some(evs) = o.get("outage") {
        for (i, ev) in evs
            .as_arr()
            .context("`[[federation.outages.outage]]` must be an array of tables")?
            .iter()
            .enumerate()
        {
            let what = format!("federation.outages.outage[{i}]");
            let shard = shard_of(ev, &what)?;
            let at = ev
                .get("at")
                .and_then(|x| x.as_f64())
                .with_context(|| format!("{what} needs a number `at`"))?;
            if !(at.is_finite() && at >= 0.0) {
                bail!("{what}: `at` must be non-negative");
            }
            let duration = ev
                .get("for")
                .and_then(|x| x.as_f64())
                .with_context(|| format!("{what} needs a number `for` (outage duration)"))?;
            if !(duration.is_finite() && duration > 0.0) {
                bail!("{what}: `for` must be positive");
            }
            let domain = match ev.get("domain") {
                None => String::new(),
                Some(x) => x
                    .as_str()
                    .with_context(|| format!("{what}: `domain` must be a string"))?
                    .to_string(),
            };
            let whole_shard = domain.is_empty() || domain == "shard" || domain == "all";
            if !whole_shard
                && !domains.iter().any(|(s, d)| *s == shard && d.name == domain)
            {
                bail!(
                    "{what}: domain {domain:?} is not declared for shard {shard} \
                     (add a [[federation.outages.domain]] entry)"
                );
            }
            outages.push((shard, OutageEvent { domain, at, duration }));
        }
    }

    let mut partitions: Vec<(usize, PartitionWindow)> = Vec::new();
    if let Some(ws) = o.get("partition") {
        for (i, w) in ws
            .as_arr()
            .context("`[[federation.outages.partition]]` must be an array of tables")?
            .iter()
            .enumerate()
        {
            let what = format!("federation.outages.partition[{i}]");
            let shard = shard_of(w, &what)?;
            let start = w
                .get("at")
                .or_else(|| w.get("start"))
                .and_then(|x| x.as_f64())
                .with_context(|| format!("{what} needs a number `at` (or `start`)"))?;
            let end = match w.get("for") {
                Some(x) => {
                    let dur = x
                        .as_f64()
                        .with_context(|| format!("{what}: `for` must be a number"))?;
                    if !(dur.is_finite() && dur > 0.0) {
                        bail!("{what}: `for` must be positive");
                    }
                    start + dur
                }
                None => w
                    .get("end")
                    .and_then(|x| x.as_f64())
                    .with_context(|| format!("{what} needs `for` (duration) or `end`"))?,
            };
            if !(start.is_finite() && start >= 0.0 && end > start) {
                bail!("{what}: need 0 <= start < end");
            }
            partitions.push((shard, PartitionWindow { start, end }));
        }
    }

    if mtbf == 0.0 && outages.is_empty() && partitions.is_empty() {
        bail!(
            "`[federation.outages]` needs at least one outage source: scripted \
             [[federation.outages.outage]] / [[federation.outages.partition]] \
             tables or `mtbf > 0`"
        );
    }
    Ok(OutageAxis { enabled, domains, outages, partitions, mtbf, mttr })
}

/// Parse the `[stream]` block (see `scenarios/README.md` for the schema).
/// The block's presence enables streaming unless `enabled = false`.
fn parse_stream(s: &Json) -> Result<StreamAxis> {
    let enabled = match s.get("enabled") {
        None => true,
        Some(Json::Bool(b)) => *b,
        Some(_) => bail!("`stream.enabled` must be a boolean"),
    };
    let keep_records = match s.get("keep_records") {
        None => false,
        Some(Json::Bool(b)) => *b,
        Some(_) => bail!("`stream.keep_records` must be a boolean"),
    };
    let lookahead = match s.get("lookahead") {
        None => StreamAxis::default().lookahead,
        Some(x) => {
            let n = usize_scalar(Some(x), "stream.lookahead")?;
            if n == 0 {
                bail!("`stream.lookahead` must be at least 1");
            }
            n
        }
    };
    Ok(StreamAxis { enabled, keep_records, lookahead })
}

/// Parse the `[trace]` block (see `scenarios/README.md` for the schema).
fn parse_trace(t: &Json) -> Result<TraceAxis> {
    let d = TraceAxis::default();
    let stride = match t.get("stride") {
        None => d.stride,
        Some(x) => {
            let s = usize_scalar(Some(x), "trace.stride")?;
            if s == 0 {
                bail!("`trace.stride` must be positive (1 keeps every job)");
            }
            s
        }
    };
    let cap = match t.get("cap") {
        None => d.cap,
        Some(x) => usize_scalar(Some(x), "trace.cap")?,
    };
    Ok(TraceAxis { stride, cap })
}

fn usize_list(v: Option<&Json>, what: &str) -> Result<Option<Vec<usize>>> {
    match v {
        None => Ok(None),
        Some(j) => Ok(Some(
            j.as_arr()
                .with_context(|| format!("`{what}` must be an array of integers"))?
                .iter()
                .map(|x| {
                    // `as_usize` is a saturating cast: 3.2 would silently
                    // become 3 and -1 would become 0, so validate first.
                    let f = x
                        .as_f64()
                        .with_context(|| format!("`{what}` entries must be integers"))?;
                    if f.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&f) {
                        bail!("`{what}` entry {f} is not a non-negative integer");
                    }
                    Ok(f as usize)
                })
                .collect::<Result<Vec<_>>>()?,
        )),
    }
}

fn f64_list(v: Option<&Json>, what: &str) -> Result<Option<Vec<f64>>> {
    match v {
        None => Ok(None),
        Some(j) => Ok(Some(
            j.as_arr()
                .with_context(|| format!("`{what}` must be an array of numbers"))?
                .iter()
                .map(|x| {
                    let f = x
                        .as_f64()
                        .with_context(|| format!("`{what}` entries must be numbers"))?;
                    if !(f.is_finite() && f >= 0.0) {
                        bail!("`{what}` entry {f} must be a non-negative number");
                    }
                    Ok(f)
                })
                .collect::<Result<Vec<_>>>()?,
        )),
    }
}

/// Reject a repeated entry on a swept axis (see the call site in
/// [`CampaignSpec::from_value`] for why duplicates corrupt aggregation).
fn no_duplicates<T: PartialEq + std::fmt::Debug>(axis: &[T], what: &str) -> Result<()> {
    if let Some((_, dup)) = axis
        .iter()
        .enumerate()
        .find(|(i, x)| axis[..*i].contains(*x))
    {
        bail!("`{what}` lists {dup:?} more than once");
    }
    Ok(())
}

/// Parse `[policy] strategy = ["throughput", ...]` via
/// [`PolicyStrategy::parse`].
fn strategy_list(v: Option<&Json>) -> Result<Option<Vec<PolicyStrategy>>> {
    match v {
        None => Ok(None),
        Some(j) => Ok(Some(
            j.as_arr()
                .context("`policy.strategy` must be an array of strings")?
                .iter()
                .map(|x| {
                    let s = x
                        .as_str()
                        .context("`policy.strategy` entries must be strings")?;
                    PolicyStrategy::parse(s).map_err(|e| anyhow!("{e}"))
                })
                .collect::<Result<Vec<_>>>()?,
        )),
    }
}

fn bool_list(v: Option<&Json>, what: &str) -> Result<Option<Vec<bool>>> {
    match v {
        None => Ok(None),
        Some(j) => Ok(Some(
            j.as_arr()
                .with_context(|| format!("`{what}` must be an array of booleans"))?
                .iter()
                .map(|x| match x {
                    Json::Bool(b) => Ok(*b),
                    _ => Err(anyhow!("`{what}` entries must be booleans")),
                })
                .collect::<Result<Vec<_>>>()?,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TOML_SPEC: &str = r#"
name = "unit"
workers = 2
nodes = [32, 64]
modes = ["fixed", "sync", "async"]
seeds = [1, 2]

[[workload]]
kind = "feitelson"
jobs = 10

[[workload]]
kind = "burst_lull"
jobs = 12
burst = 4
lull = 100.0

[[workload]]
kind = "swf"
path = "scenarios/traces/small.swf"
max_jobs = 8
rescale_nodes = 64
malleable_fraction = 0.5
"#;

    #[test]
    fn parses_toml_and_expands() {
        let s = CampaignSpec::from_toml_str(TOML_SPEC).unwrap();
        assert_eq!(s.name, "unit");
        assert_eq!(s.workers, 2);
        assert_eq!(s.nodes, vec![32, 64]);
        assert_eq!(s.modes, vec![RunMode::Fixed, RunMode::Sync, RunMode::Async]);
        assert_eq!(s.seeds, vec![1, 2]);
        assert_eq!(s.workloads.len(), 3);
        assert!(matches!(
            s.workloads[0].source,
            WorkloadSource::Feitelson { jobs: 10, .. }
        ));
        assert!(matches!(
            s.workloads[1].source,
            WorkloadSource::BurstLull { jobs: 12, burst: 4, .. }
        ));
        assert!(s.workloads.iter().all(|w| w.deadline_slack.is_none()));
        let WorkloadSource::Swf { ref path, ref opts } = s.workloads[2].source else {
            panic!("expected swf source");
        };
        assert_eq!(path, "scenarios/traces/small.swf");
        assert_eq!(opts.max_jobs, Some(8));
        assert_eq!(opts.rescale_nodes, Some(64));
        assert_eq!(opts.malleable_fraction, 0.5);

        assert_eq!(s.matrix_size(), 3 * 2 * 3 * 2);
        let plans = s.expand();
        assert_eq!(plans.len(), 36);
        // indices are positional, seeds adjacent within a scenario
        for (i, p) in plans.iter().enumerate() {
            assert_eq!(p.index, i);
        }
        assert_eq!(plans[0].scenario, plans[1].scenario);
        assert_eq!(plans[0].seed, 1);
        assert_eq!(plans[1].seed, 2);
        assert_ne!(plans[1].scenario, plans[2].scenario);
        // scenario count = matrix / seeds
        let mut ids: Vec<&str> = plans.iter().map(|p| p.scenario.as_str()).collect();
        ids.dedup();
        assert_eq!(ids.len(), 18);
        assert_eq!(plans[0].scenario, "feitelson10-n32-fixed");
        assert_eq!(plans[0].label, "feitelson10-n32-fixed-s1");
    }

    #[test]
    fn trace_block_parses_with_defaults() {
        let none = CampaignSpec::from_toml_str(
            "name = \"t\"\n[[workload]]\nkind = \"feitelson\"\njobs = 4\n",
        )
        .unwrap();
        assert_eq!(none.trace, TraceAxis::default());
        assert_eq!(none.trace.stride, 1, "default keeps every job track");
        assert_eq!(none.trace.cap, 0, "default is uncapped");
        let some = CampaignSpec::from_toml_str(
            "name = \"t\"\n[trace]\nstride = 4\ncap = 100\n\
             [[workload]]\nkind = \"feitelson\"\njobs = 4\n",
        )
        .unwrap();
        assert_eq!(some.trace, TraceAxis { stride: 4, cap: 100 });
        assert!(
            CampaignSpec::from_toml_str(
                "name = \"t\"\n[trace]\nstride = 0\n\
                 [[workload]]\nkind = \"feitelson\"\njobs = 4\n",
            )
            .is_err(),
            "zero stride rejected"
        );
    }

    #[test]
    fn json_spec_equivalent() {
        let json = r#"{
            "name": "unit-json",
            "nodes": [16],
            "modes": ["sync"],
            "seeds": [7],
            "workload": [{"kind": "feitelson", "jobs": 5}]
        }"#;
        let s = CampaignSpec::from_json_str(json).unwrap();
        assert_eq!(s.name, "unit-json");
        assert_eq!(s.matrix_size(), 1);
        let p = &s.expand()[0];
        assert_eq!(p.nodes, 16);
        assert_eq!(p.seed, 7);
        assert_eq!(p.mode, RunMode::Sync);
    }

    #[test]
    fn defaults_fill_in() {
        let s = CampaignSpec::from_toml_str(
            "name = \"d\"\n[[workload]]\nkind = \"feitelson\"\njobs = 4\n",
        )
        .unwrap();
        assert_eq!(s.nodes, vec![crate::cluster::DEFAULT_NODES]);
        assert_eq!(s.modes, vec![RunMode::Fixed, RunMode::Sync]);
        assert_eq!(s.seeds, vec![1, 2, 3]);
        assert_eq!(s.workers, 0);
        assert_eq!(s.output_dir, Path::new("results/campaigns/d"));
        assert_eq!(s.policy.backfill, vec![true]);
        assert_eq!(s.policy.strategy, vec![PolicyStrategy::ThroughputAware]);
        assert_eq!(s.expand()[0].strategy, PolicyStrategy::ThroughputAware);
    }

    #[test]
    fn policy_sweep_expands_and_labels() {
        let toml = r#"
name = "pol"
nodes = [32]
modes = ["sync"]
seeds = [1]
[policy]
backfill = [true, false]
[[workload]]
kind = "feitelson"
jobs = 4
"#;
        let s = CampaignSpec::from_toml_str(toml).unwrap();
        assert_eq!(s.matrix_size(), 2);
        let plans = s.expand();
        assert!(plans[0].scenario.contains("-bf1-"));
        assert!(plans[1].scenario.contains("-bf0-"));
    }

    #[test]
    fn strategy_sweep_expands_and_labels() {
        let toml = r#"
name = "strat"
nodes = [32]
modes = ["sync"]
seeds = [1, 2]
[policy]
strategy = ["throughput", "queue", "fair", "deadline"]
[[workload]]
kind = "feitelson"
jobs = 4
deadline_slack = 3.0
"#;
        let s = CampaignSpec::from_toml_str(toml).unwrap();
        assert_eq!(s.policy.strategy.len(), 4);
        assert_eq!(s.workloads[0].deadline_slack, Some(3.0));
        // scalar strategy knobs default from PolicyConfig
        assert_eq!(s.policy.queue_pressure, 2);
        assert_eq!(s.policy.fair_share_slack, 1.25);
        assert_eq!(s.matrix_size(), 4 * 2);
        let plans = s.expand();
        assert_eq!(plans.len(), 8);
        // per-strategy scenario suffixes, seeds adjacent within each
        assert_eq!(plans[0].scenario, "feitelson4-n32-sync-throughput");
        assert_eq!(plans[2].scenario, "feitelson4-n32-sync-queue");
        assert_eq!(plans[4].scenario, "feitelson4-n32-sync-fair");
        assert_eq!(plans[6].scenario, "feitelson4-n32-sync-deadline");
        assert_eq!(plans[2].strategy, PolicyStrategy::QueueAware);
        assert_eq!(plans[4].strategy, PolicyStrategy::FairShare);
        assert_eq!(plans[6].strategy, PolicyStrategy::DeadlineAware);
        assert_eq!(plans[0].seed, 1);
        assert_eq!(plans[1].seed, 2);

        // single-strategy specs keep their unsuffixed scenario ids
        let single = CampaignSpec::from_toml_str(
            "name = \"one\"\nmodes = [\"sync\"]\n[policy]\nstrategy = [\"queue\"]\n[[workload]]\nkind = \"feitelson\"\njobs = 2\n",
        )
        .unwrap();
        let p = single.expand();
        assert!(!p[0].scenario.contains("queue"), "{}", p[0].scenario);
        assert_eq!(p[0].strategy, PolicyStrategy::QueueAware);

        // bad strategy names, duplicates, and bad slack are rejected
        assert!(CampaignSpec::from_toml_str(
            "name = \"x\"\n[policy]\nstrategy = [\"warp\"]\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n"
        )
        .is_err());
        assert!(
            CampaignSpec::from_toml_str(
                "name = \"x\"\n[policy]\nstrategy = [\"queue\", \"fair\", \"queue\"]\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n"
            )
            .is_err(),
            "duplicate strategy entries must be rejected"
        );
        // scalar knobs parse, and out-of-range values are rejected
        let knobs = CampaignSpec::from_toml_str(
            "name = \"k\"\n[policy]\nqueue_pressure = 4\nfair_share_slack = 1.5\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n",
        )
        .unwrap();
        assert_eq!(knobs.policy.queue_pressure, 4);
        assert_eq!(knobs.policy.fair_share_slack, 1.5);
        assert!(CampaignSpec::from_toml_str(
            "name = \"x\"\n[policy]\nfair_share_slack = 0.5\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n"
        )
        .is_err());
        assert!(CampaignSpec::from_toml_str(
            "name = \"x\"\n[policy]\nqueue_pressure = -1\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n"
        )
        .is_err());
        // the duplicate guard covers every swept axis, not just strategy
        for bad in [
            "name = \"x\"\nmodes = [\"sync\", \"fixed\", \"sync\"]\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n",
            "name = \"x\"\nnodes = [32, 32]\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n",
            "name = \"x\"\nseeds = [1, 2, 1]\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n",
            "name = \"x\"\n[faults]\nmtbf = [0.0, 60000.0, 0.0]\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n",
            "name = \"x\"\n[policy]\nbackfill = [true, true]\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n",
        ] {
            assert!(CampaignSpec::from_toml_str(bad).is_err(), "accepted: {bad}");
        }
        assert!(CampaignSpec::from_toml_str(
            "name = \"x\"\n[[workload]]\nkind = \"feitelson\"\njobs = 1\ndeadline_slack = -2.0\n"
        )
        .is_err());
    }

    #[test]
    fn duplicate_workload_labels_disambiguated() {
        let toml = r#"
name = "dup"
nodes = [32]
modes = ["sync"]
seeds = [1]
[[workload]]
kind = "feitelson"
jobs = 10
mean_interarrival = 10.0
[[workload]]
kind = "feitelson"
jobs = 10
mean_interarrival = 60.0
"#;
        let s = CampaignSpec::from_toml_str(toml).unwrap();
        let plans = s.expand();
        assert_eq!(plans.len(), 2);
        assert_ne!(plans[0].scenario, plans[1].scenario, "same-label sources must not collide");
        assert_eq!(plans[0].scenario, "feitelson10-w0-n32-sync");
        assert_eq!(plans[1].scenario, "feitelson10-w1-n32-sync");
    }

    #[test]
    fn faults_axis_parses_and_expands() {
        let toml = r#"
name = "faulty"
nodes = [64]
modes = ["fixed", "sync"]
seeds = [1, 2]
[faults]
mtbf = [0.0, 20000.0]
mttr = 1200.0
checkpoint_interval = [600.0]
[[faults.fail]]
node = 3
at = 500.0
repair_at = 2500.0
[[faults.drain]]
start = 1000.0
end = 4000.0
nodes = 8
[[faults.drain]]
start = 6000.0
end = 7000.0
nodes = [60, 61]
[[workload]]
kind = "feitelson"
jobs = 10
"#;
        let s = CampaignSpec::from_toml_str(toml).unwrap();
        assert_eq!(s.faults.mtbf, vec![0.0, 20000.0]);
        assert_eq!(s.faults.mttr, 1200.0);
        assert_eq!(s.faults.checkpoint_interval, vec![600.0]);
        // one fail + its repair
        assert_eq!(s.faults.scripted.len(), 2);
        assert_eq!(s.faults.scripted[0].node, 3);
        assert!(matches!(s.faults.scripted[0].kind, crate::resilience::FaultKind::Fail));
        assert!(matches!(s.faults.scripted[1].kind, crate::resilience::FaultKind::Repair));
        assert_eq!(s.faults.scripted[1].at, 2500.0);
        assert_eq!(s.faults.drains.len(), 2);
        assert_eq!(s.faults.drains[0].nodes, crate::resilience::DrainSet::Count(8));
        assert_eq!(
            s.faults.drains[1].nodes,
            crate::resilience::DrainSet::Nodes(vec![60, 61])
        );

        // mtbf axis doubles the matrix and shows up in scenario ids
        assert_eq!(s.matrix_size(), 2 * 2 * 2);
        let plans = s.expand();
        assert_eq!(plans.len(), 8);
        assert!(plans[0].scenario.contains("-mtbf0-ck600"));
        assert!(plans[2].scenario.contains("-mtbf20000-ck600"));
        assert_eq!(plans[0].mtbf, 0.0);
        assert_eq!(plans[2].mtbf, 20000.0);
        assert_eq!(plans[0].checkpoint_interval, 600.0);

        // defaults: no [faults] section -> inactive single-point axis,
        // no scenario suffix
        let plain = CampaignSpec::from_toml_str(
            "name = \"p\"\n[[workload]]\nkind = \"feitelson\"\njobs = 2\n",
        )
        .unwrap();
        assert_eq!(plain.faults.mtbf, vec![0.0]);
        assert!(plain.faults.scripted.is_empty() && plain.faults.drains.is_empty());
        assert!(!plain.expand()[0].scenario.contains("mtbf"));
    }

    #[test]
    fn stream_block_parses_and_reaches_plans() {
        // No [stream] block: materialized plans with full retention.
        let plain = CampaignSpec::from_toml_str(
            "name = \"p\"\n[[workload]]\nkind = \"feitelson\"\njobs = 2\n",
        )
        .unwrap();
        assert!(!plain.stream.enabled);
        let p = &plain.expand()[0];
        assert!(!p.stream && p.keep_records);

        // Bare [stream] block: enabled, records dropped, default window.
        let bare = CampaignSpec::from_toml_str(
            "name = \"s\"\n[stream]\n[[workload]]\nkind = \"feitelson\"\njobs = 2\n",
        )
        .unwrap();
        assert!(bare.stream.enabled);
        assert!(!bare.stream.keep_records);
        assert_eq!(bare.stream.lookahead, 64);
        let p = &bare.expand()[0];
        assert!(p.stream && !p.keep_records && p.lookahead == 64);

        // Explicit knobs round-trip; lookahead = 0 is rejected.
        let knobs = CampaignSpec::from_toml_str(
            "name = \"k\"\n[stream]\nenabled = false\nkeep_records = true\n\
             lookahead = 7\n[[workload]]\nkind = \"feitelson\"\njobs = 2\n",
        )
        .unwrap();
        assert!(!knobs.stream.enabled);
        assert!(knobs.stream.keep_records);
        assert_eq!(knobs.stream.lookahead, 7);
        assert!(CampaignSpec::from_toml_str(
            "name = \"z\"\n[stream]\nlookahead = 0\n\
             [[workload]]\nkind = \"feitelson\"\njobs = 2\n",
        )
        .is_err());
    }

    #[test]
    fn federation_axis_parses_and_expands() {
        let toml = r#"
name = "fed"
nodes = [64]
modes = ["sync"]
seeds = [1, 2]
[federation]
shards = [1, 4]
routing = ["rr", "ll"]
steal = true
[[workload]]
kind = "feitelson"
jobs = 6
"#;
        let s = CampaignSpec::from_toml_str(toml).unwrap();
        let fed = s.federation.as_ref().unwrap();
        assert_eq!(fed.shards, vec![1, 4]);
        assert_eq!(
            fed.routing,
            vec![RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded]
        );
        assert_eq!(fed.steal, vec![StealPolicy::Head], "boolean form maps to head");
        assert!(fed.outages.is_none());
        assert!(fed.topology.is_none());
        assert_eq!(s.matrix_size(), 2 * 2 * 2);
        let plans = s.expand();
        assert_eq!(plans.len(), 8);
        // federation is the outermost axis; seeds stay adjacent
        assert_eq!(plans[0].scenario, "feitelson6-n64-sync-s1xrr");
        assert_eq!(plans[2].scenario, "feitelson6-n64-sync-s1xll");
        assert_eq!(plans[4].scenario, "feitelson6-n64-sync-s4xrr");
        assert_eq!(plans[6].scenario, "feitelson6-n64-sync-s4xll");
        assert_eq!(plans[0].label, "feitelson6-n64-sync-s1xrr-s1");
        assert_eq!(plans[1].seed, 2);
        let f = plans[4].federation.as_ref().unwrap();
        assert_eq!(f.shards.len(), 4);
        assert!(f.shards.iter().all(|sh| sh.nodes == 16));
        assert_eq!(f.routing, RoutingPolicy::RoundRobin);
        assert_eq!(f.steal, StealPolicy::Head);
        assert!(f.outages.is_none());

        // no [federation] block -> flat plans, historical scenario ids
        let plain = CampaignSpec::from_toml_str(
            "name = \"p\"\nmodes = [\"sync\"]\n[[workload]]\nkind = \"feitelson\"\njobs = 2\n",
        )
        .unwrap();
        let p = plain.expand();
        assert!(p[0].federation.is_none());
        assert!(!p[0].scenario.contains("-s1x"), "{}", p[0].scenario);
    }

    #[test]
    fn federation_topology_parses_and_bad_specs_rejected() {
        let toml = r#"
name = "topo"
nodes = [64]
modes = ["sync"]
seeds = [1]
[federation]
topology = ["32:1.0", "32:0.2:2.0"]
routing = ["ll"]
[[workload]]
kind = "feitelson"
jobs = 4
"#;
        let s = CampaignSpec::from_toml_str(toml).unwrap();
        let fed = s.federation.as_ref().unwrap();
        assert_eq!(fed.shards, vec![2], "topology fixes the shard count");
        let t = fed.topology.as_ref().unwrap();
        assert_eq!(t[1].nodes, 32);
        assert_eq!(t[1].speed, 0.2);
        assert_eq!(t[1].mtbf_scale, 2.0);
        let plans = s.expand();
        assert_eq!(plans[0].scenario, "feitelson4-n64-sync-s2xll");
        let f = plans[0].federation.as_ref().unwrap();
        assert_eq!(f.shards, *t, "topology is taken verbatim");

        let base = "name = \"x\"\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n";
        for fed in [
            "[federation]\nshards = [0]\n",
            "[federation]\nshards = []\n",
            "[federation]\nshards = [1, 1]\n",            // duplicate
            "[federation]\nshards = [128]\n",             // > smallest nodes (64)
            "[federation]\nrouting = [\"warp\"]\n",
            "[federation]\nrouting = [\"rr\", \"rr\"]\n", // duplicate
            "[federation]\nsteal = 1\n",
            "[federation]\nsteal = \"warp\"\n",           // unknown policy
            "[federation]\nsteal = []\n",
            "[federation]\nsteal = [\"head\", \"head\"]\n", // duplicate
            "[federation]\ntopology = [\"32\"]\n",        // sum != 64
            "[federation]\ntopology = [\"32:0\"]\n",      // bad speed
            "[federation]\nshards = [2]\ntopology = [\"32\", \"32\"]\n", // exclusive
        ] {
            let doc = format!("{base}{fed}");
            assert!(CampaignSpec::from_toml_str(&doc).is_err(), "accepted: {fed}");
        }
    }

    #[test]
    fn steal_axis_sweeps_and_suffixes() {
        let toml = r#"
name = "steal"
nodes = [64]
modes = ["sync"]
seeds = [1]
[federation]
shards = [2]
routing = ["rr"]
steal = ["off", "head", "half"]
[[workload]]
kind = "feitelson"
jobs = 4
"#;
        let s = CampaignSpec::from_toml_str(toml).unwrap();
        let fed = s.federation.as_ref().unwrap();
        assert_eq!(
            fed.steal,
            vec![StealPolicy::Off, StealPolicy::Head, StealPolicy::Half]
        );
        assert_eq!(s.matrix_size(), 3);
        let plans = s.expand();
        assert_eq!(plans[0].scenario, "feitelson4-n64-sync-s2xrrxoff");
        assert_eq!(plans[1].scenario, "feitelson4-n64-sync-s2xrrxhead");
        assert_eq!(plans[2].scenario, "feitelson4-n64-sync-s2xrrxhalf");
        assert_eq!(plans[2].federation.as_ref().unwrap().steal, StealPolicy::Half);

        // A single-policy axis keeps the historical un-suffixed ids.
        let one = toml.replace("steal = [\"off\", \"head\", \"half\"]", "steal = \"half\"");
        let s1 = CampaignSpec::from_toml_str(&one).unwrap();
        let p1 = s1.expand();
        assert_eq!(p1[0].scenario, "feitelson4-n64-sync-s2xrr");
        assert_eq!(p1[0].federation.as_ref().unwrap().steal, StealPolicy::Half);
    }

    #[test]
    fn outage_axis_parses_and_expands() {
        let toml = r#"
name = "out"
nodes = [64]
modes = ["sync"]
seeds = [1]
[federation]
shards = [2]
routing = ["rr"]
[federation.outages]
enabled = [false, true]
[[federation.outages.domain]]
shard = 0
name = "rackA"
nodes = [0, 1, 2, 3]
[[federation.outages.outage]]
shard = 0
domain = "rackA"
at = 100.0
for = 50.0
[[federation.outages.outage]]
shard = 1
at = 200.0
for = 25.0
[[federation.outages.partition]]
shard = 1
at = 400.0
for = 100.0
[[workload]]
kind = "feitelson"
jobs = 4
"#;
        let s = CampaignSpec::from_toml_str(toml).unwrap();
        let fed = s.federation.as_ref().unwrap();
        let out = fed.outages.as_ref().unwrap();
        assert_eq!(out.enabled, vec![false, true]);
        assert_eq!(out.domains.len(), 1);
        assert_eq!(out.outages.len(), 2);
        assert_eq!(out.partitions.len(), 1);
        assert_eq!(s.matrix_size(), 2);

        let plans = s.expand();
        assert_eq!(plans[0].scenario, "feitelson4-n64-sync-s2xrr");
        assert_eq!(plans[1].scenario, "feitelson4-n64-sync-s2xrr-out");
        assert!(plans[0].federation.as_ref().unwrap().outages.is_none());
        let specs = plans[1].federation.as_ref().unwrap().outages.as_ref().unwrap();
        assert_eq!(specs.len(), 2);
        assert!(specs[0].is_active() && specs[1].is_active());
        assert_eq!(specs[0].domains.len(), 1);
        assert_eq!(specs[0].domains[0].name, "rackA");
        assert_eq!(specs[0].scripted.len(), 1);
        assert_eq!(specs[1].scripted[0].domain, "");
        assert_eq!(specs[1].partitions[0].end, 500.0);

        // enabled defaults to [true]: no sweep, no -out suffix, specs set.
        let always = toml.replace("enabled = [false, true]\n", "");
        let sa = CampaignSpec::from_toml_str(&always).unwrap();
        assert_eq!(sa.matrix_size(), 1);
        let pa = sa.expand();
        assert_eq!(pa[0].scenario, "feitelson4-n64-sync-s2xrr");
        assert!(pa[0].federation.as_ref().unwrap().outages.is_some());
    }

    #[test]
    fn bad_outage_specs_rejected() {
        let base = "name = \"x\"\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n\
                    [federation]\nshards = [2]\n";
        for out in [
            // no outage source at all
            "[federation.outages]\nenabled = [true]\n",
            // duplicate enabled entries
            "[federation.outages]\nenabled = [true, true]\nmtbf = 1e4\nmttr = 600\n",
            "[federation.outages]\nenabled = []\nmtbf = 1e4\nmttr = 600\n",
            "[federation.outages]\nenabled = [1]\nmtbf = 1e4\nmttr = 600\n",
            // mtbf without mttr
            "[federation.outages]\nmtbf = 1e4\n",
            "[federation.outages]\nmtbf = -1.0\nmttr = 600\n",
            // shard beyond every swept layout
            "[[federation.outages.outage]]\nshard = 5\nat = 1.0\nfor = 1.0\n",
            // missing / bad fields
            "[[federation.outages.outage]]\nshard = 0\nfor = 1.0\n",
            "[[federation.outages.outage]]\nshard = 0\nat = 1.0\n",
            "[[federation.outages.outage]]\nshard = 0\nat = -1.0\nfor = 1.0\n",
            "[[federation.outages.outage]]\nshard = 0\nat = 1.0\nfor = 0.0\n",
            // outage naming an undeclared domain
            "[[federation.outages.outage]]\nshard = 0\nat = 1.0\nfor = 1.0\ndomain = \"rackZ\"\n",
            // reserved / duplicate / empty domain declarations
            "[[federation.outages.domain]]\nshard = 0\nname = \"all\"\nnodes = 2\n",
            "[[federation.outages.domain]]\nshard = 0\nname = \"a\"\nnodes = []\n",
            // partition with end before start
            "[[federation.outages.partition]]\nshard = 0\nat = 5.0\nend = 2.0\n",
            "[[federation.outages.partition]]\nshard = 0\nat = 5.0\nfor = -1.0\n",
        ] {
            let doc = format!("{base}{out}");
            assert!(CampaignSpec::from_toml_str(&doc).is_err(), "accepted: {out}");
        }
    }

    #[test]
    fn bad_fault_specs_rejected() {
        let base = "name = \"x\"\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n";
        for faults in [
            "[faults]\nmtbf = [-5.0]\n",
            "[faults]\nmttr = -1.0\n",
            "[faults]\nmtbf = []\n",
            "[[faults.fail]]\nat = 5.0\n",                        // missing node
            "[[faults.fail]]\nnode = 1\nat = 5.0\nrepair_at = 2.0\n", // repair before fail
            "[[faults.fail]]\nnode = -1\nat = 5.0\n",                 // negative node
            "[[faults.fail]]\nnode = 100\nat = 5.0\n",            // beyond every cluster
            "[[faults.drain]]\nstart = 5.0\nend = 2.0\nnodes = 4\n",  // end before start
            "[[faults.drain]]\nstart = 1.0\nend = 2.0\n",             // missing nodes
            "[[faults.drain]]\nstart = 1.0\nend = 2.0\nnodes = -8\n", // negative count
            "[[faults.drain]]\nstart = 1.0\nend = 2.0\nnodes = 8.5\n", // fractional count
            "[[faults.drain]]\nstart = 1.0\nend = 2.0\nnodes = [70]\n", // id beyond cluster
            "[[faults.drain]]\nstart = 1.0\nend = 2.0\nnodes = []\n",  // empty node list
            "[[faults.fail]]\nnode = 1\nat = 5.0\nrepair_at = \"x\"\n", // non-numeric repair
            "[faults]\nmttr = \"1500\"\n",                             // non-numeric mttr
        ] {
            let doc = format!("{base}{faults}");
            assert!(CampaignSpec::from_toml_str(&doc).is_err(), "accepted: {faults}");
        }
    }

    #[test]
    fn resize_fault_axis_parses_and_expands() {
        let toml = r#"
name = "rf"
nodes = [32]
modes = ["fixed", "sync"]
seeds = [1, 2]
[resize_faults]
spawn_fail = [0.0, 0.25]
redist_fail = 0.05
revoke = 0.02
max_retries = 2
backoff_base = 20.0
backoff_cap = 120.0
[[workload]]
kind = "feitelson"
jobs = 8
"#;
        let s = CampaignSpec::from_toml_str(toml).unwrap();
        assert_eq!(s.resize_faults.spawn_fail, vec![0.0, 0.25]);
        assert_eq!(s.resize_faults.max_retries, 2);
        let point = s.resize_faults.spec(0.25);
        assert_eq!(point.spawn_fail, 0.25);
        assert_eq!(point.redist_fail, 0.05);
        assert_eq!(point.backoff_base, 20.0);
        assert!(point.is_active());
        assert!(
            s.resize_faults.spec(0.0).is_active(),
            "nonzero redist/revoke probabilities keep the spawn_fail=0 point active"
        );

        // spawn_fail doubles the matrix and shows up in scenario ids
        assert_eq!(s.matrix_size(), 2 * 2 * 2 * 2);
        let plans = s.expand();
        assert_eq!(plans.len(), 16);
        assert!(plans[0].scenario.ends_with("-rf0"), "{}", plans[0].scenario);
        assert!(plans[2].scenario.ends_with("-rf0.25"), "{}", plans[2].scenario);
        assert_eq!(plans[0].spawn_fail, 0.0);
        assert_eq!(plans[2].spawn_fail, 0.25);
        // seeds stay adjacent within one resize-fault point
        assert_eq!(plans[0].scenario, plans[1].scenario);

        // defaults: no [resize_faults] section -> single inactive point,
        // no scenario suffix, legacy resize path
        let plain = CampaignSpec::from_toml_str(
            "name = \"p\"\n[[workload]]\nkind = \"feitelson\"\njobs = 2\n",
        )
        .unwrap();
        assert_eq!(plain.resize_faults.spawn_fail, vec![0.0]);
        assert!(!plain.resize_faults.spec(0.0).is_active());
        assert!(!plain.expand()[0].scenario.contains("-rf"));

        let base = "name = \"x\"\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n";
        for bad in [
            "[resize_faults]\nspawn_fail = [1.5]\n",
            "[resize_faults]\nspawn_fail = [-0.1]\n",
            "[resize_faults]\nspawn_fail = []\n",
            "[resize_faults]\nspawn_fail = [0.1, 0.1]\n", // duplicate
            "[resize_faults]\nredist_fail = 2.0\n",
            "[resize_faults]\nrevoke = -1.0\n",
            "[resize_faults]\nmax_retries = -1\n",
            "[resize_faults]\nmax_retries = 1.5\n",
            "[resize_faults]\nbackoff_base = 0.0\n",
            "[resize_faults]\nbackoff_base = 60.0\nbackoff_cap = 30.0\n",
        ] {
            let doc = format!("{base}{bad}");
            assert!(CampaignSpec::from_toml_str(&doc).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn shard_fault_overrides_parse_and_bad_specs_rejected() {
        let toml = r#"
name = "sf"
nodes = [64]
modes = ["sync"]
seeds = [1]
[federation]
shards = [4]
[[federation.shard_fault]]
shard = 1
mtbf = 8000.0
mttr = 600.0
[[federation.shard_fault]]
shard = 3
mtbf = 0.0
[[workload]]
kind = "feitelson"
jobs = 4
"#;
        let s = CampaignSpec::from_toml_str(toml).unwrap();
        let fed = s.federation.as_ref().unwrap();
        assert_eq!(fed.shard_faults.len(), 2);
        assert_eq!(
            fed.shard_faults[0],
            ShardFault { shard: 1, mtbf: 8000.0, mttr: Some(600.0) }
        );
        assert_eq!(fed.shard_faults[1], ShardFault { shard: 3, mtbf: 0.0, mttr: None });

        // no [[federation.shard_fault]] tables -> empty override list
        let plain = CampaignSpec::from_toml_str(
            "name = \"p\"\n[federation]\nshards = [2]\n[[workload]]\nkind = \"feitelson\"\njobs = 2\n",
        )
        .unwrap();
        assert!(plain.federation.as_ref().unwrap().shard_faults.is_empty());

        let base = "name = \"x\"\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n\
                    [federation]\nshards = [2]\n";
        for bad in [
            "[[federation.shard_fault]]\nmtbf = 100.0\n", // missing shard
            "[[federation.shard_fault]]\nshard = 2\nmtbf = 100.0\n", // beyond every layout
            "[[federation.shard_fault]]\nshard = 0\n",    // missing mtbf
            "[[federation.shard_fault]]\nshard = 0\nmtbf = -1.0\n",
            "[[federation.shard_fault]]\nshard = 0\nmtbf = 1.0\nmttr = -2.0\n",
            "[[federation.shard_fault]]\nshard = 0\nmtbf = 1.0\n\
             [[federation.shard_fault]]\nshard = 0\nmtbf = 2.0\n", // duplicate shard
        ] {
            let doc = format!("{base}{bad}");
            assert!(CampaignSpec::from_toml_str(&doc).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(CampaignSpec::from_toml_str("nodes = [1]\n").is_err(), "missing name");
        assert!(
            CampaignSpec::from_toml_str("name = \"x\"\n").is_err(),
            "missing workloads"
        );
        assert!(CampaignSpec::from_toml_str(
            "name = \"x\"\nmodes = [\"warp\"]\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n"
        )
        .is_err());
        assert!(CampaignSpec::from_toml_str(
            "name = \"x\"\n[[workload]]\nkind = \"swf\"\npath = \"t\"\nmalleable_fraction = 1.5\n"
        )
        .is_err());
        assert!(CampaignSpec::from_toml_str(
            "name = \"x\"\nnodes = [0]\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n"
        )
        .is_err());
        // non-integer / negative axis entries must error, not truncate
        assert!(CampaignSpec::from_toml_str(
            "name = \"x\"\nnodes = [3.2]\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n"
        )
        .is_err());
        assert!(CampaignSpec::from_toml_str(
            "name = \"x\"\nseeds = [-1]\n[[workload]]\nkind = \"feitelson\"\njobs = 1\n"
        )
        .is_err());
    }
}
