//! The campaign engine: parallel scenario sweeps over declarative specs.
//!
//! The paper's evaluation (§7) runs a handful of hand-picked workloads
//! one at a time; real scheduling studies (Zojer et al.'s real-trace
//! malleability evaluation, Chadha et al.'s scheduler-knob sweeps) need
//! hundreds of DES runs over many scenarios.  This subsystem provides:
//!
//! * [`spec`] — [`CampaignSpec`]: a TOML/JSON file describing a cartesian
//!   matrix of workload sources (Feitelson / burst–lull / SWF real
//!   traces), cluster sizes, scheduling modes, policy knobs and seeds;
//! * [`runner`] — [`run_campaign`]: matrix expansion + a `std::thread`
//!   worker pool sharding the (single-threaded) DES runs across cores;
//! * [`aggregate`] — per-scenario statistics across seeds with 95 %
//!   confidence intervals, emitted as CSV/JSON through
//!   [`crate::metrics::report`].
//!
//! Every run is a pure function of its [`RunPlan`], so campaign outputs
//! are bit-identical for any worker count.  Entry point:
//! `repro campaign scenarios/sweep_small.toml [--workers N]`.

pub mod aggregate;
pub mod runner;
pub mod spec;

pub use aggregate::{aggregate, write_outputs, CampaignOutputs, ScenarioAgg};
pub use runner::{
    run_campaign, run_campaign_opts, run_plan, CampaignOpts, CampaignResult, RunRecord,
};
pub use spec::{
    CampaignSpec, FedAxis, FedPlan, PolicyAxis, RunMode, RunPlan, StreamAxis, TraceAxis,
    WorkloadAxis, WorkloadSource,
};
