//! Cross-scenario aggregation: fold the per-run results of a campaign
//! into per-scenario statistics (across seeds) with normal-approximation
//! 95 % confidence intervals, and write the CSV/JSON outputs through
//! [`crate::metrics::report`] / [`crate::util::csv`].

use std::path::PathBuf;

use super::runner::{CampaignResult, RunRecord};
use super::spec::CampaignSpec;
use crate::metrics::report;
use crate::util::csv::write_csv;
use crate::util::stats::Summary;

/// Per-scenario aggregate over the scenario's seeds.
pub struct ScenarioAgg {
    /// Scenario id (all axes except the seed).
    pub scenario: String,
    /// Runs folded in (== number of seeds).
    pub runs: usize,
    /// Jobs per run.
    pub jobs: usize,
    /// Makespan, seconds.
    pub makespan_s: Summary,
    /// Mean cluster utilization per run, in percent.
    pub util_pct: Summary,
    /// Mean job waiting time per run, seconds.
    pub wait_s: Summary,
    /// Mean job execution time per run, seconds.
    pub exec_s: Summary,
    /// Mean job completion time per run, seconds.
    pub completion_s: Summary,
    /// Node-seconds allocated to user jobs per run.
    pub node_seconds: Summary,
    /// Committed expansions per run.
    pub expands: Summary,
    /// Committed shrinks per run.
    pub shrinks: Summary,
    /// Aborted (timed-out) expansions per run.
    pub expand_aborts: Summary,
    // --- policy-comparison measures (crate::rms::policy) --------------
    /// Mean bounded slowdown per run.
    pub slowdown: Summary,
    /// Jain's fairness index over per-user slowdowns, per run.
    pub fairness: Summary,
    /// Deadline misses per run.
    pub deadline_misses: Summary,
    // --- resilience measures (crate::resilience) ----------------------
    /// Jobs interrupted by node failures per run.
    pub interrupted: Summary,
    /// Shrink-rescued jobs per run.
    pub rescued: Summary,
    /// Killed-and-requeued jobs per run.
    pub requeued: Summary,
    /// Checkpoint rework per run, seconds.
    pub rework_s: Summary,
    /// Down-node integral per run, node-seconds.
    pub lost_node_s: Summary,
    /// Machine availability per run, percent.
    pub availability_pct: Summary,
    /// Resize transactions begun per run (multi-phase path only).
    pub resize_attempts: Summary,
    /// Resize transactions aborted per run.
    pub resize_aborts: Summary,
    /// Time lost to aborted transactions + backoff waits per run, seconds.
    pub retry_time_s: Summary,
    /// Jobs degraded to non-malleable per run.
    pub degraded_jobs: Summary,
    // --- self-profile counters (crate::obs) ----------------------------
    /// Scheduling passes executed per run (deterministic counter).
    pub sched_passes: Summary,
    /// Provably no-op scheduling passes elided per run.
    pub sched_elided: Summary,
    /// DMR policy checks evaluated per run.
    pub dmr_checks: Summary,
    /// Memoized (elided) DMR checks per run.
    pub dmr_elided: Summary,
    /// Peak-resident (live) job count per run — the streaming memory
    /// bound: under `[stream]` memory tracks peak queued+running
    /// concurrency, never total replay length.
    pub peak_live: Summary,
    /// Total DES events across the scenario's runs (the events/s
    /// numerator of the stdout table).
    pub events_total: u64,
    /// Total wall nanoseconds the engines spent dispatching across the
    /// scenario's runs.  Timing noise: feeds the stdout table only,
    /// never the CSVs/JSON.
    pub wall_ns_total: u64,
    // --- federation measures (crate::federation) -----------------------
    /// Shard count of the scenario (1 for flat scenarios).
    pub fed_shards: usize,
    /// Cross-shard steals per run (all zero for flat scenarios).
    pub fed_steals: Summary,
    /// Per-shard utilization percentage across seeds, one summary per
    /// shard id (empty for flat scenarios).
    pub shard_util: Vec<Summary>,
    /// Jain index over per-shard mean bounded slowdowns per run (empty —
    /// count 0 — for flat scenarios).
    pub shard_jain: Summary,
    /// Jobs evacuated across shards per run (zero without outages).
    pub evacuations: Summary,
    /// Cross-shard requeues received per run.
    pub cross_requeues: Summary,
    /// Per-shard availability percentage across seeds, one summary per
    /// shard id (empty for flat scenarios).
    pub shard_avail: Vec<Summary>,
}

impl ScenarioAgg {
    fn new(scenario: &str, jobs: usize) -> ScenarioAgg {
        ScenarioAgg {
            scenario: scenario.to_string(),
            runs: 0,
            jobs,
            makespan_s: Summary::new(),
            util_pct: Summary::new(),
            wait_s: Summary::new(),
            exec_s: Summary::new(),
            completion_s: Summary::new(),
            node_seconds: Summary::new(),
            expands: Summary::new(),
            shrinks: Summary::new(),
            expand_aborts: Summary::new(),
            slowdown: Summary::new(),
            fairness: Summary::new(),
            deadline_misses: Summary::new(),
            interrupted: Summary::new(),
            rescued: Summary::new(),
            requeued: Summary::new(),
            rework_s: Summary::new(),
            lost_node_s: Summary::new(),
            availability_pct: Summary::new(),
            resize_attempts: Summary::new(),
            resize_aborts: Summary::new(),
            retry_time_s: Summary::new(),
            degraded_jobs: Summary::new(),
            sched_passes: Summary::new(),
            sched_elided: Summary::new(),
            dmr_checks: Summary::new(),
            dmr_elided: Summary::new(),
            peak_live: Summary::new(),
            events_total: 0,
            wall_ns_total: 0,
            fed_shards: 1,
            fed_steals: Summary::new(),
            shard_util: Vec::new(),
            shard_jain: Summary::new(),
            evacuations: Summary::new(),
            cross_requeues: Summary::new(),
            shard_avail: Vec::new(),
        }
    }

    fn push(&mut self, r: &RunRecord) {
        let s = &r.summary;
        self.runs += 1;
        self.makespan_s.push(s.makespan);
        self.util_pct.push(s.util_mean * 100.0);
        self.wait_s.push(s.wait.mean());
        self.exec_s.push(s.exec.mean());
        self.completion_s.push(s.completion.mean());
        self.node_seconds.push(s.node_seconds());
        self.expands.push(s.actions.expand.count() as f64);
        self.shrinks.push(s.actions.shrink.count() as f64);
        self.expand_aborts.push(s.actions.expand_aborts as f64);
        self.slowdown.push(s.bounded_slowdown.mean());
        self.fairness.push(s.fairness_jain);
        self.deadline_misses.push(s.deadline_misses as f64);
        self.interrupted.push(s.resilience.interrupted as f64);
        self.rescued.push(s.resilience.rescued as f64);
        self.requeued.push(s.resilience.requeued as f64);
        self.rework_s.push(s.resilience.rework_time);
        self.lost_node_s.push(s.resilience.lost_node_seconds);
        self.availability_pct.push(s.resilience.availability * 100.0);
        self.resize_attempts.push(s.resilience.resize_attempts as f64);
        self.resize_aborts.push(s.resilience.resize_aborts as f64);
        self.retry_time_s.push(s.resilience.retry_time);
        self.degraded_jobs.push(s.resilience.degraded_jobs as f64);
        self.sched_passes.push(s.passes.sched_passes as f64);
        self.sched_elided.push(s.passes.sched_elided as f64);
        self.dmr_checks.push(s.passes.dmr_checks as f64);
        self.dmr_elided.push(s.passes.dmr_elided as f64);
        self.peak_live.push(s.peak_live as f64);
        self.events_total += s.events;
        self.wall_ns_total += s.profile.total_ns();
        match &s.federation {
            Some(f) => {
                self.fed_shards = f.shards;
                self.fed_steals.push(f.steals as f64);
                self.shard_jain.push(f.shard_jain);
                self.evacuations.push(f.evacuations as f64);
                self.cross_requeues.push(f.cross_requeues as f64);
                if self.shard_util.len() < f.per_shard.len() {
                    self.shard_util.resize_with(f.per_shard.len(), Summary::new);
                }
                if self.shard_avail.len() < f.per_shard.len() {
                    self.shard_avail.resize_with(f.per_shard.len(), Summary::new);
                }
                for (agg, sh) in self.shard_util.iter_mut().zip(&f.per_shard) {
                    agg.push(sh.util_pct);
                }
                for (agg, sh) in self.shard_avail.iter_mut().zip(&f.per_shard) {
                    agg.push(sh.availability * 100.0);
                }
            }
            None => {
                self.fed_steals.push(0.0);
                self.evacuations.push(0.0);
                self.cross_requeues.push(0.0);
            }
        }
    }
}

/// Fold run records into per-scenario aggregates, preserving matrix order
/// (records arrive index-ordered, with a scenario's seeds adjacent).
pub fn aggregate(records: &[RunRecord]) -> Vec<ScenarioAgg> {
    let mut out: Vec<ScenarioAgg> = Vec::new();
    for r in records {
        let scenario = &r.plan.scenario;
        if out.last().map(|a| a.scenario != *scenario).unwrap_or(true) {
            out.push(ScenarioAgg::new(scenario, r.jobs));
        }
        out.last_mut().unwrap().push(r);
    }
    out
}

/// The file set one campaign writes.
pub struct CampaignOutputs {
    /// One row per DES run, in matrix order.
    pub runs_csv: PathBuf,
    /// One row per scenario (across-seed mean + 95 % CI).
    pub agg_csv: PathBuf,
    /// The same aggregates as a JSON document.
    pub agg_json: PathBuf,
}

/// Write per-run CSV + aggregate CSV/JSON under the spec's output dir.
/// The contents are a pure function of the run results — worker count and
/// wall time never appear — so reruns diff clean (tested in
/// `tests/test_campaign.rs`).
pub fn write_outputs(spec: &CampaignSpec, result: &CampaignResult) -> std::io::Result<CampaignOutputs> {
    let aggs = aggregate(&result.records);
    let dir = &spec.output_dir;
    std::fs::create_dir_all(dir)?;

    let runs_csv = dir.join(format!("{}_runs.csv", spec.name));
    write_csv(&runs_csv, report::run_columns(), &report::campaign_run_rows(&result.records))?;

    let agg_csv = dir.join(format!("{}_agg.csv", spec.name));
    write_csv(&agg_csv, report::agg_columns(), &report::campaign_agg_rows(&aggs))?;

    let agg_json = dir.join(format!("{}_agg.json", spec.name));
    std::fs::write(&agg_json, report::campaign_agg_json(spec, &aggs).render())?;

    Ok(CampaignOutputs { runs_csv, agg_csv, agg_json })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::{run_campaign, CampaignSpec};

    #[test]
    fn aggregates_group_by_scenario_in_order() {
        let spec = CampaignSpec::from_toml_str(
            r#"
name = "agg-unit"
nodes = [32]
modes = ["fixed", "sync"]
seeds = [1, 2, 3]
[[workload]]
kind = "feitelson"
jobs = 6
"#,
        )
        .unwrap();
        let res = run_campaign(&spec, 2).unwrap();
        let aggs = aggregate(&res.records);
        assert_eq!(aggs.len(), 2, "one aggregate per scenario");
        for a in &aggs {
            assert_eq!(a.runs, 3);
            assert_eq!(a.jobs, 6);
            assert_eq!(a.makespan_s.count(), 3);
            assert!(a.makespan_s.mean() > 0.0);
            assert!(a.util_pct.mean() > 0.0 && a.util_pct.mean() <= 100.0);
            // 3 seeds -> a non-degenerate CI unless all runs tie exactly
            assert!(a.makespan_s.ci95_half() >= 0.0);
        }
        assert_ne!(aggs[0].scenario, aggs[1].scenario);
        // the flexible scenario actually reconfigures
        let sync = aggs.iter().find(|a| a.scenario.ends_with("-sync")).unwrap();
        assert!(sync.expands.sum() + sync.shrinks.sum() > 0.0);
        // self-profile counters ride along per scenario
        for a in &aggs {
            assert_eq!(a.sched_passes.count(), 3);
            assert!(a.sched_passes.mean() > 0.0);
            assert!(a.events_total > 0);
        }
    }
}
