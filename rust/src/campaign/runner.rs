//! Campaign execution: expand the spec's matrix and shard the DES runs
//! across a `std::thread` worker pool.
//!
//! Every run is an independent, fully-deterministic function of its
//! [`RunPlan`] (workload generation, DES cost jitter and policy state are
//! all seeded from the plan), and results land in an index-addressed slot
//! table — so the campaign output is bit-identical regardless of worker
//! count or scheduling order, which `tests/test_campaign.rs` locks in.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use super::spec::{CampaignSpec, RunPlan, WorkloadSource};
use crate::des::{DesConfig, Engine};
use crate::federation::{FedEngine, FederationConfig};
use crate::metrics::RunSummary;
use crate::obs::{Trace, TraceConfig, TraceStats};
use crate::resilience::{FaultSpec, RecoveryConfig, ResilienceConfig};
use crate::rms::{PolicyConfig, RmsConfig};
use crate::workload::{
    self, swf, Adapted, BurstLullParams, BurstLullStream, FeitelsonParams, FeitelsonStream,
    JobStream, SwfStream, WorkloadSpec,
};

/// One finished run.
pub struct RunRecord {
    pub plan: RunPlan,
    /// Jobs in the materialized workload (after `max_jobs` etc.).
    pub jobs: usize,
    pub summary: RunSummary,
    /// Stats of the trace exported for this run (`None` when tracing is
    /// off or the export failed — failures warn, they never kill a run).
    pub trace: Option<TraceStats>,
}

/// Runtime knobs of one campaign invocation that live outside the spec:
/// worker count, the stderr progress line, and span-trace export.  None
/// of them may influence the deterministic outputs — tracing is post-run
/// and the progress line goes to stderr only.
#[derive(Debug, Clone, Default)]
pub struct CampaignOpts {
    /// Worker threads (0 = resolve from the spec / machine).
    pub workers: usize,
    /// Emit a periodic `completed/total (ETA)` line on stderr.
    pub progress: bool,
    /// Write per-run Chrome-trace + JSONL exports under this directory.
    pub trace_dir: Option<PathBuf>,
    /// Stride/cap knobs for the exported traces (enabled flag included —
    /// both it and `trace_dir` must be set for exports to happen).
    pub trace_cfg: TraceConfig,
}

/// Everything a campaign produced.
pub struct CampaignResult {
    /// One record per matrix point, in matrix order.
    pub records: Vec<RunRecord>,
    /// Worker threads actually used.
    pub workers: usize,
    /// Wall-clock seconds for the whole sweep (not part of the
    /// deterministic outputs).
    pub wall_secs: f64,
}

impl CampaignResult {
    /// Total DES runs per wall-clock second (runner throughput).
    pub fn runs_per_sec(&self) -> f64 {
        self.records.len() as f64 / self.wall_secs.max(1e-9)
    }
}

/// Parse the `--workers` CLI argument.  `None` (flag absent) means
/// "auto" and maps to the 0 sentinel [`resolve_workers`] expands to the
/// spec value or one thread per core; an *explicit* `--workers 0` or a
/// non-numeric value is a hard error instead of silently running with
/// some default the user did not ask for.
pub fn parse_workers(arg: Option<&str>) -> Result<usize, String> {
    match arg {
        None => Ok(0),
        Some(s) => match s.parse::<usize>() {
            Ok(0) => Err("--workers must be at least 1 (omit the flag for auto)".into()),
            Ok(n) => Ok(n),
            Err(_) => Err(format!("--workers expects a positive integer, got {s:?}")),
        },
    }
}

/// Resolve the worker count: CLI override, then spec, then one per core.
pub fn resolve_workers(spec: &CampaignSpec, override_workers: usize) -> usize {
    let n = if override_workers > 0 {
        override_workers
    } else if spec.workers > 0 {
        spec.workers
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    n.max(1)
}

/// Run the full campaign matrix on `workers` threads (0 = resolve from
/// the spec / machine).
pub fn run_campaign(spec: &CampaignSpec, workers: usize) -> Result<CampaignResult> {
    run_campaign_opts(spec, &CampaignOpts { workers, ..Default::default() })
}

/// Run the full campaign matrix with explicit runtime options
/// ([`run_campaign`] is the plain wrapper).  The deterministic outputs
/// are identical for every `opts` value: progress reporting writes to
/// stderr only and trace export happens after each run's event log is
/// sealed.
pub fn run_campaign_opts(spec: &CampaignSpec, opts: &CampaignOpts) -> Result<CampaignResult> {
    let plans = spec.expand();
    let workers = resolve_workers(spec, opts.workers).min(plans.len().max(1));
    let traces = preload_traces(spec)?;
    let t0 = Instant::now();

    let next = AtomicUsize::new(0);
    let completed = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<Result<RunRecord>>>> =
        Mutex::new((0..plans.len()).map(|_| None).collect());

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let Some(plan) = plans.get(i) else { return };
                let record = execute_plan(spec, plan, &traces, opts);
                slots.lock().unwrap()[i] = Some(record);
                if opts.progress {
                    let done = completed.fetch_add(1, Ordering::Relaxed) + 1;
                    report_progress(&spec.name, done, plans.len(), t0);
                }
            });
        }
    });

    let records: Vec<RunRecord> = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("worker filled every slot"))
        .collect::<Result<_>>()?;
    Ok(CampaignResult { records, workers, wall_secs: t0.elapsed().as_secs_f64() })
}

/// Execute a single matrix point outside the worker pool — the
/// `repro trace <scenario>` one-run path.  Preloads any SWF trace the
/// plan's workload references, so it is self-contained.
pub fn run_plan(spec: &CampaignSpec, plan: &RunPlan, opts: &CampaignOpts) -> Result<RunRecord> {
    let traces = preload_traces(spec)?;
    execute_plan(spec, plan, &traces, opts)
}

/// Periodic `completed/total (ETA)` line on stderr, behind `--progress`.
/// Throttled to ~20 updates per campaign so huge matrices don't flood the
/// terminal; always fires on the final run.
fn report_progress(name: &str, done: usize, total: usize, t0: Instant) {
    let step = (total / 20).max(1);
    if done % step != 0 && done != total {
        return;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let eta = elapsed / done as f64 * (total - done) as f64;
    eprintln!("campaign {name}: {done}/{total} runs ({eta:.0}s left)");
}

/// Load every SWF trace referenced by the spec once, up front (they are
/// shared read-only across workers and failures should surface before any
/// DES time is spent).  Streamed campaigns skip the preload entirely —
/// each run opens the file line-by-line ([`SwfStream::open`]), which is
/// the whole point of the bounded-memory path.
fn preload_traces(spec: &CampaignSpec) -> Result<HashMap<String, swf::SwfTrace>> {
    let mut traces = HashMap::new();
    if spec.stream.enabled {
        return Ok(traces);
    }
    for w in &spec.workloads {
        if let WorkloadSource::Swf { path, .. } = &w.source {
            if !traces.contains_key(path) {
                let trace =
                    swf::load(path).with_context(|| format!("loading SWF trace {path}"))?;
                anyhow::ensure!(
                    !trace.records.is_empty(),
                    "SWF trace {path} contains no usable records \
                     ({} malformed, {} skipped)",
                    trace.stats.malformed,
                    trace.stats.skipped
                );
                traces.insert(path.clone(), trace);
            }
        }
    }
    Ok(traces)
}

/// Build the DES configuration for one matrix point (shared between the
/// materialized and streamed execution paths — the config must be
/// identical for the two paths to stay bit-identical).
fn des_config(spec: &CampaignSpec, plan: &RunPlan, mode: crate::dmr::SchedMode) -> DesConfig {
    DesConfig {
        rms: RmsConfig {
            nodes: plan.nodes,
            backfill: plan.backfill,
            strategy: plan.strategy,
            policy: PolicyConfig {
                honor_preference: plan.honor_preference,
                wide_optimization: plan.wide_optimization,
                queue_pressure: spec.policy.queue_pressure,
                fair_share_slack: spec.policy.fair_share_slack,
            },
            shrink_priority_boost: plan.shrink_boost,
            keep_records: plan.keep_records,
            ..Default::default()
        },
        mode,
        seed: plan.seed,
        resilience: ResilienceConfig {
            faults: FaultSpec {
                mtbf: plan.mtbf,
                mttr: spec.faults.mttr,
                scripted: spec.faults.scripted.clone(),
                drains: spec.faults.drains.clone(),
            },
            recovery: RecoveryConfig {
                checkpoint_interval: plan.checkpoint_interval,
                ..Default::default()
            },
            resize_faults: spec.resize_faults.spec(plan.spawn_fail),
        },
        ..Default::default()
    }
}

/// Execute one matrix point (pure function of the plan — see module docs).
fn execute_plan(
    spec: &CampaignSpec,
    plan: &RunPlan,
    traces: &HashMap<String, swf::SwfTrace>,
    opts: &CampaignOpts,
) -> Result<RunRecord> {
    if plan.stream {
        return execute_streamed(spec, plan, opts);
    }
    let axis = &spec.workloads[plan.workload];
    let mut w = materialize(&axis.source, plan, traces);
    fit_to_cluster(&mut w, plan.nodes);
    if let Some(slack) = axis.deadline_slack {
        // Soft deadlines from the *clamped* sizes (fit_to_cluster may
        // have shrunk oversized jobs, changing their runtime estimate).
        w = w.with_deadlines(slack);
    }
    let (mode, flexible) = plan.mode.des_mode();
    if !flexible {
        w = w.as_fixed();
    }
    let cfg = des_config(spec, plan, mode);
    let jobs = w.len();
    // Trace derivation must precede summarization (from_run takes the
    // RunResult by value); it reads the sealed event log only, so the run
    // itself is untouched.
    let tracing = opts.trace_cfg.enabled && opts.trace_dir.is_some();
    let (summary, trace) = match &plan.federation {
        None => {
            let result = Engine::new(cfg).run(&w, &plan.label);
            let trace = tracing
                .then(|| Trace::from_run(&result, &opts.trace_cfg))
                .and_then(|t| export_trace(t, plan, opts));
            (RunSummary::from_run(result), trace)
        }
        Some(fp) => {
            let fed = FederationConfig {
                shards: fp.shards.clone(),
                routing: fp.routing,
                steal: fp.steal,
                shard_faults: shard_fault_specs(spec, fp, &cfg),
                outages: fp.outages.clone(),
            };
            let result = FedEngine::new(cfg, fed).run(&w, &plan.label);
            let trace = tracing
                .then(|| Trace::from_fed(&result, &opts.trace_cfg))
                .and_then(|t| export_trace(t, plan, opts));
            (RunSummary::from_fed(&result, fp.routing, fp.steal), trace)
        }
    };
    Ok(RunRecord { plan: plan.clone(), jobs, summary, trace })
}

/// Execute one matrix point through the streaming pipeline: build a
/// [`JobStream`] for the plan's source, wrap it in the [`Adapted`]
/// transform chain (fit → deadlines → fixed, mirroring the materialized
/// path's order exactly), and let the engine pull arrivals lazily with
/// the plan's look-ahead window.  SWF traces are opened here, per run,
/// and read line-by-line — no preload, no resident record vector.
fn execute_streamed(spec: &CampaignSpec, plan: &RunPlan, opts: &CampaignOpts) -> Result<RunRecord> {
    let axis = &spec.workloads[plan.workload];
    let inner: Box<dyn JobStream> = match &axis.source {
        WorkloadSource::Feitelson { jobs, mean_interarrival, work_spread } => {
            let params = FeitelsonParams {
                jobs: *jobs,
                mean_interarrival: *mean_interarrival,
                work_spread: *work_spread,
                ..Default::default()
            };
            Box::new(FeitelsonStream::new(params, plan.seed))
        }
        WorkloadSource::BurstLull { jobs, burst, burst_gap, lull } => {
            let params = BurstLullParams {
                jobs: *jobs,
                burst: *burst,
                burst_gap: *burst_gap,
                lull: *lull,
                ..Default::default()
            };
            Box::new(BurstLullStream::new(params, plan.seed))
        }
        WorkloadSource::Swf { path, opts: swf_opts } => Box::new(
            SwfStream::open(path, swf_opts.clone(), plan.seed)
                .with_context(|| format!("streaming SWF trace {path}"))?,
        ),
    };
    let (mode, flexible) = plan.mode.des_mode();
    let mut stream = Adapted::new(inner).fit(plan.nodes);
    if let Some(slack) = axis.deadline_slack {
        stream = stream.deadlines(slack);
    }
    if !flexible {
        stream = stream.fixed(true);
    }
    let cfg = des_config(spec, plan, mode);
    let tracing = opts.trace_cfg.enabled && opts.trace_dir.is_some();
    if tracing && !plan.keep_records {
        crate::obs::log::warn(&format!(
            "trace export skipped for {}: streamed run without keep_records retains no events",
            plan.label
        ));
    }
    let (jobs, summary, trace) = match &plan.federation {
        None => {
            let result = Engine::new(cfg)
                .run_stream(&mut stream, plan.lookahead, &plan.label)
                .with_context(|| format!("streamed run {}", plan.label))?;
            let trace = (tracing && plan.keep_records)
                .then(|| Trace::from_run(&result, &opts.trace_cfg))
                .and_then(|t| export_trace(t, plan, opts));
            (result.user_jobs, RunSummary::from_run(result), trace)
        }
        Some(fp) => {
            let fed = FederationConfig {
                shards: fp.shards.clone(),
                routing: fp.routing,
                steal: fp.steal,
                shard_faults: shard_fault_specs(spec, fp, &cfg),
                outages: fp.outages.clone(),
            };
            let result = FedEngine::new(cfg, fed)
                .run_stream(&mut stream, plan.lookahead, &plan.label)
                .with_context(|| format!("streamed run {}", plan.label))?;
            let trace = (tracing && plan.keep_records)
                .then(|| Trace::from_fed(&result, &opts.trace_cfg))
                .and_then(|t| export_trace(t, plan, opts));
            (result.user_jobs, RunSummary::from_fed(&result, fp.routing, fp.steal), trace)
        }
    };
    Ok(RunRecord { plan: plan.clone(), jobs, summary, trace })
}

/// Write the run's trace files.  Export failures warn and yield `None` —
/// a full disk must not abort a long sweep.
fn export_trace(trace: Trace, plan: &RunPlan, opts: &CampaignOpts) -> Option<TraceStats> {
    let dir: &Path = opts.trace_dir.as_deref()?;
    let stats = trace.stats();
    match trace.write_files(dir, &plan.label) {
        Ok(_) => Some(stats),
        Err(e) => {
            crate::obs::log::warn(&format!("trace export for {} failed: {e}", plan.label));
            None
        }
    }
}

/// Build the per-shard fault list from the spec's
/// `[[federation.shard_fault]]` overrides: entry `i` is the override
/// targeting shard `i`, or the run's base fault spec with the shard's
/// `mtbf_scale` applied — replicating the engine's own defaulting so
/// overridden and defaulted shards mix in one run.  `None` (no overrides)
/// keeps the engine-side defaulting path for every shard.
fn shard_fault_specs(
    spec: &CampaignSpec,
    fp: &crate::campaign::spec::FedPlan,
    cfg: &DesConfig,
) -> Option<Vec<FaultSpec>> {
    let overrides = &spec.federation.as_ref()?.shard_faults;
    if overrides.is_empty() {
        return None;
    }
    Some(
        fp.shards
            .iter()
            .enumerate()
            .map(|(i, sh)| match overrides.iter().find(|o| o.shard == i) {
                Some(o) => FaultSpec {
                    mtbf: o.mtbf,
                    mttr: o.mttr.unwrap_or(spec.faults.mttr),
                    scripted: spec.faults.scripted.clone(),
                    drains: spec.faults.drains.clone(),
                },
                None => {
                    let mut f = cfg.resilience.faults.clone();
                    f.mtbf *= sh.mtbf_scale;
                    f
                }
            })
            .collect(),
    )
}

fn materialize(
    source: &WorkloadSource,
    plan: &RunPlan,
    traces: &HashMap<String, swf::SwfTrace>,
) -> WorkloadSpec {
    match source {
        WorkloadSource::Feitelson { jobs, mean_interarrival, work_spread } => {
            let params = FeitelsonParams {
                jobs: *jobs,
                mean_interarrival: *mean_interarrival,
                work_spread: *work_spread,
                ..Default::default()
            };
            workload::generate_with(&params, plan.seed)
        }
        WorkloadSource::BurstLull { jobs, burst, burst_gap, lull } => {
            let params = BurstLullParams {
                jobs: *jobs,
                burst: *burst,
                burst_gap: *burst_gap,
                lull: *lull,
                ..Default::default()
            };
            workload::generate_burst_lull(&params, plan.seed)
        }
        WorkloadSource::Swf { path, opts } => {
            let trace = traces.get(path).expect("trace preloaded");
            swf::to_workload(trace, opts, plan.seed)
        }
    }
}

/// Clamp job sizes to the scenario's cluster: a job asking for more nodes
/// than exist would never start and the workload would not drain.  The
/// per-job rule is [`workload::fit_spec`], shared with the federated
/// meta-scheduler's per-shard refits.
fn fit_to_cluster(w: &mut WorkloadSpec, nodes: usize) {
    for j in &mut w.jobs {
        workload::fit_spec(j, nodes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::CampaignSpec;

    fn tiny_spec() -> CampaignSpec {
        CampaignSpec::from_toml_str(
            r#"
name = "tiny"
nodes = [32]
modes = ["fixed", "sync"]
seeds = [1, 2]
[[workload]]
kind = "feitelson"
jobs = 8
"#,
        )
        .unwrap()
    }

    #[test]
    fn runs_full_matrix_in_order() {
        let spec = tiny_spec();
        let res = run_campaign(&spec, 2).unwrap();
        assert_eq!(res.records.len(), 4);
        assert_eq!(res.workers, 2);
        for (i, r) in res.records.iter().enumerate() {
            assert_eq!(r.plan.index, i);
            assert_eq!(r.jobs, 8);
            assert!(r.summary.makespan > 0.0);
            assert_eq!(r.summary.jobs.len(), 8);
        }
    }

    #[test]
    fn worker_resolution() {
        let mut spec = tiny_spec();
        assert_eq!(resolve_workers(&spec, 3), 3, "CLI override wins");
        spec.workers = 5;
        assert_eq!(resolve_workers(&spec, 0), 5, "spec value next");
        assert_eq!(resolve_workers(&spec, 2), 2);
        spec.workers = 0;
        assert!(resolve_workers(&spec, 0) >= 1, "auto is at least 1");
    }

    #[test]
    fn workers_flag_parses_strictly() {
        assert_eq!(parse_workers(None), Ok(0), "absent flag means auto");
        assert_eq!(parse_workers(Some("4")), Ok(4));
        assert_eq!(parse_workers(Some("1")), Ok(1));
        assert!(parse_workers(Some("0")).is_err(), "explicit 0 rejected");
        assert!(parse_workers(Some("-2")).is_err());
        assert!(parse_workers(Some("four")).is_err());
        assert!(parse_workers(Some("")).is_err());
    }

    #[test]
    fn federated_plans_run_through_the_fed_engine() {
        let spec = CampaignSpec::from_toml_str(
            r#"
name = "fed-runner"
nodes = [32]
modes = ["sync"]
seeds = [1]
[federation]
shards = [2]
routing = ["ll"]
steal = true
[[workload]]
kind = "feitelson"
jobs = 8
"#,
        )
        .unwrap();
        let res = run_campaign(&spec, 2).unwrap();
        assert_eq!(res.records.len(), 1);
        let s = &res.records[0].summary;
        let fed = s.federation.as_ref().expect("federated summary");
        assert_eq!(fed.shards, 2);
        assert_eq!(fed.routing, "ll");
        assert_eq!(fed.steal, "head", "boolean spec form maps to the head policy");
        assert_eq!(fed.per_shard.len(), 2);
        assert_eq!(fed.per_shard.iter().map(|sh| sh.nodes).sum::<usize>(), 32);
        assert_eq!(s.jobs.len(), 8, "all jobs completed across shards");
        assert!(s.makespan > 0.0);
    }

    #[test]
    fn strategy_axis_runs_all_strategies_on_one_stream() {
        let spec = CampaignSpec::from_toml_str(
            r#"
name = "strategies"
nodes = [64]
modes = ["sync"]
seeds = [1]
[policy]
strategy = ["throughput", "queue", "fair", "deadline"]
[[workload]]
kind = "feitelson"
jobs = 12
deadline_slack = 3.0
"#,
        )
        .unwrap();
        let res = run_campaign(&spec, 2).unwrap();
        assert_eq!(res.records.len(), 4);
        for (r, want) in res.records.iter().zip(["throughput", "queue", "fair", "deadline"])
        {
            assert_eq!(r.plan.strategy.label(), want);
            assert!(r.summary.makespan > 0.0, "{want}: workload drained");
            assert_eq!(r.summary.jobs.len(), 12);
            // deadline decoration landed on every job
            assert_eq!(r.summary.deadline_jobs, 12);
            assert!(r.summary.bounded_slowdown.mean() >= 1.0);
            assert!(
                r.summary.fairness_jain > 0.0 && r.summary.fairness_jain <= 1.0 + 1e-12,
                "{want}: jain {}",
                r.summary.fairness_jain
            );
        }
        // same stream, different strategies: the decision sequences are
        // allowed to coincide only by accident — require at least one
        // divergence across the four scenarios.
        let makespans: Vec<f64> =
            res.records.iter().map(|r| r.summary.makespan).collect();
        assert!(
            makespans.iter().any(|m| (m - makespans[0]).abs() > 1e-9),
            "all four strategies produced identical makespans: {makespans:?}"
        );
    }

    #[test]
    fn resize_fault_axis_flows_into_runs() {
        // seeds = [7] + jobs = 30 on 64 nodes mirrors the engine-level
        // resize-fault test's workload, so "resizes happen" is a given.
        let spec = CampaignSpec::from_toml_str(
            r#"
name = "rf-runner"
nodes = [64]
modes = ["sync"]
seeds = [7]
[resize_faults]
spawn_fail = [0.0, 1.0]
max_retries = 1
backoff_base = 5.0
backoff_cap = 10.0
[[workload]]
kind = "feitelson"
jobs = 30
"#,
        )
        .unwrap();
        let res = run_campaign(&spec, 2).unwrap();
        assert_eq!(res.records.len(), 2);
        assert_eq!(res.records[0].plan.spawn_fail, 0.0);
        assert_eq!(res.records[1].plan.spawn_fail, 1.0);
        let calm = &res.records[0].summary.resilience;
        let hostile = &res.records[1].summary.resilience;
        assert_eq!(calm.resize_attempts, 0, "inactive point keeps the legacy path");
        assert_eq!(calm.resize_aborts, 0);
        assert!(hostile.resize_attempts > 0, "active point counts transactions");
        assert_eq!(
            hostile.resize_aborts, hostile.resize_attempts,
            "spawn_fail = 1 aborts every transaction"
        );
        assert!(hostile.degraded_jobs > 0);
        for r in &res.records {
            assert_eq!(r.summary.jobs.len(), 30, "workload drains under resize faults");
        }
    }

    #[test]
    fn shard_fault_overrides_reach_the_fed_engine() {
        let toml = |sf: &str| {
            format!(
                r#"
name = "shard-faults"
nodes = [32]
modes = ["sync"]
seeds = [1]
[faults]
mttr = 300.0
[federation]
shards = [2]
{sf}
[[workload]]
kind = "feitelson"
jobs = 10
"#
            )
        };
        let quiet = CampaignSpec::from_toml_str(&toml("")).unwrap();
        let noisy = CampaignSpec::from_toml_str(&toml(
            "[[federation.shard_fault]]\nshard = 0\nmtbf = 400.0\nmttr = 200.0\n",
        ))
        .unwrap();

        // the override list materializes into a full per-shard spec vec
        let plan = &noisy.expand()[0];
        let fp = plan.federation.as_ref().unwrap();
        let cfg = DesConfig::default();
        let specs = shard_fault_specs(&noisy, fp, &cfg).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].mtbf, 400.0);
        assert_eq!(specs[0].mttr, 200.0);
        assert_eq!(specs[1].mtbf, 0.0, "non-overridden shard keeps the base spec");
        assert_eq!(specs[1].mttr, 300.0);
        assert!(shard_fault_specs(&quiet, fp, &cfg).is_none(), "no overrides -> engine defaulting");

        // and the targeted faults actually fire in the run
        let q = run_campaign(&quiet, 1).unwrap();
        let n = run_campaign(&noisy, 1).unwrap();
        assert_eq!(q.records[0].summary.resilience.lost_node_seconds, 0.0);
        assert!(
            n.records[0].summary.resilience.lost_node_seconds > 0.0,
            "shard-targeted MTBF override produced no downtime"
        );
        assert_eq!(n.records[0].summary.jobs.len(), 10, "workload still drains");
    }

    #[test]
    fn trace_export_rides_along_without_changing_outputs() {
        let spec = tiny_spec();
        let plain = run_campaign(&spec, 1).unwrap();
        let dir = std::env::temp_dir()
            .join(format!("dmr_runner_trace_{}", std::process::id()));
        let opts = CampaignOpts {
            workers: 2,
            trace_dir: Some(dir.clone()),
            trace_cfg: TraceConfig::on(),
            ..Default::default()
        };
        let traced = run_campaign_opts(&spec, &opts).unwrap();
        assert_eq!(plain.records.len(), traced.records.len());
        for (a, b) in plain.records.iter().zip(&traced.records) {
            assert!(a.trace.is_none(), "tracing defaults to off");
            let st = b.trace.expect("trace stats recorded per run");
            assert!(st.job_tracks_kept > 0);
            assert!(st.spans > 0);
            assert_eq!(
                a.summary.makespan.to_bits(),
                b.summary.makespan.to_bits(),
                "{}: tracing must be observationally inert",
                b.plan.label
            );
            let json = dir.join(format!("{}.trace.json", b.plan.label));
            let jsonl = dir.join(format!("{}.spans.jsonl", b.plan.label));
            assert!(json.is_file(), "missing {}", json.display());
            assert!(jsonl.is_file(), "missing {}", jsonl.display());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn streamed_campaign_matches_materialized() {
        // Same matrix ± a [stream] block: every deterministic output must
        // be bit-identical, while the streamed records drop the per-job
        // vector (keep_records defaults to false under [stream]).
        let body = r#"
name = "streamy"
nodes = [32]
modes = ["fixed", "sync"]
seeds = [1, 2]
"#;
        let tail = "[[workload]]\nkind = \"feitelson\"\njobs = 8\n";
        let plain =
            CampaignSpec::from_toml_str(&format!("{body}{tail}")).unwrap();
        let streamed = CampaignSpec::from_toml_str(&format!(
            "{body}[stream]\nlookahead = 4\n{tail}"
        ))
        .unwrap();
        let a = run_campaign(&plain, 2).unwrap();
        let b = run_campaign(&streamed, 2).unwrap();
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.jobs, y.jobs);
            let (s, t) = (&x.summary, &y.summary);
            assert_eq!(s.makespan.to_bits(), t.makespan.to_bits(), "{}", y.plan.label);
            assert_eq!(s.util_mean.to_bits(), t.util_mean.to_bits(), "{}", y.plan.label);
            assert_eq!(s.wait.mean().to_bits(), t.wait.mean().to_bits());
            assert_eq!(s.exec.mean().to_bits(), t.exec.mean().to_bits());
            assert_eq!(s.node_seconds().to_bits(), t.node_seconds().to_bits());
            assert_eq!(s.jobs.len(), x.jobs, "materialized keeps records");
            assert!(t.jobs.is_empty(), "streamed default drops records");
            assert!(t.peak_live > 0, "peak-resident count recorded");
        }

        // keep_records = true restores the per-job vector, still
        // bit-identical.
        let kept = CampaignSpec::from_toml_str(&format!(
            "{body}[stream]\nkeep_records = true\n{tail}"
        ))
        .unwrap();
        let c = run_campaign(&kept, 2).unwrap();
        for (x, y) in a.records.iter().zip(&c.records) {
            assert_eq!(
                x.summary.makespan.to_bits(),
                y.summary.makespan.to_bits(),
                "{}",
                y.plan.label
            );
            assert_eq!(y.summary.jobs.len(), y.jobs);
            for (ja, jb) in x.summary.jobs.iter().zip(&y.summary.jobs) {
                assert_eq!(ja.name, jb.name);
                assert_eq!(ja.end.to_bits(), jb.end.to_bits());
            }
        }
    }

    #[test]
    fn run_plan_executes_a_single_matrix_point() {
        let spec = tiny_spec();
        let plan = spec.expand().into_iter().next().unwrap();
        let rec = run_plan(&spec, &plan, &CampaignOpts::default()).unwrap();
        assert_eq!(rec.plan.label, plan.label);
        assert_eq!(rec.jobs, 8);
        assert!(rec.summary.makespan > 0.0);
        assert!(rec.trace.is_none());
    }

    #[test]
    fn fit_to_cluster_clamps_oversized_jobs() {
        let mut w = workload::generate(6, 3); // CG/Jacobi max 32, N-body 16
        fit_to_cluster(&mut w, 8);
        for j in &w.jobs {
            assert!(j.procs <= 8);
            assert!(j.max_procs <= 8);
            assert!(j.min_procs <= j.procs);
        }
        // and such a workload actually drains on an 8-node cluster
        let cfg = DesConfig {
            rms: RmsConfig { nodes: 8, ..Default::default() },
            ..Default::default()
        };
        let r = Engine::new(cfg).run(&w, "clamped");
        assert_eq!(r.rms.completed_jobs(), 6);
    }
}
