//! `repro` — the leader entrypoint: regenerates every table and figure of
//! the paper's evaluation (§7) and drives the live end-to-end runs.
//!
//! Subcommands (see `repro help`):
//!   throughput  Table 4 + Fig 4 + Fig 5 (workload sweep, fixed vs flexible)
//!   table2      Table 2 (action analysis, sync vs async)
//!   table3      Table 3 (cluster/job measures, fixed vs sync vs async)
//!   trace       Fig 6 (time evolution of one workload)
//!   perjob      Fig 7 + Fig 8 (per-job times by application)
//!   overhead    Fig 3 (live scheduling + resize times)
//!   live        small live workload with real PJRT compute
//!   campaign    parallel scenario sweep from a declarative spec file
//!   all         everything DES-based
fn main() {
    if let Err(e) = dmr_main::run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

mod dmr_main {
    use anyhow::Result;
    use dmr::des::{DesConfig, Engine};
    use dmr::dmr::SchedMode;
    use dmr::metrics::{report, RunSummary};
    use dmr::rms::RmsConfig;
    use dmr::util::cli::Args;
    use dmr::util::csv::write_csv;
    use dmr::workload;

    pub fn run() -> Result<()> {
        let args = Args::from_env();
        match args.subcommand.as_deref() {
            Some("throughput") => throughput(&args),
            Some("table2") => table2(&args),
            Some("table3") => table3(&args),
            Some("trace") => trace(&args),
            Some("perjob") => perjob(&args),
            Some("overhead") => overhead(&args),
            Some("live") => live(&args),
            Some("calibrate") => calibrate(&args),
            Some("campaign") => campaign(&args),
            Some("all") => {
                throughput(&args)?;
                table2(&args)?;
                table3(&args)?;
                trace(&args)?;
                perjob(&args)
            }
            _ => {
                println!("{}", HELP);
                Ok(())
            }
        }
    }

    const HELP: &str = "repro — DMR API reproduction (Iserte et al., ParCo 2018)

USAGE: repro <SUBCOMMAND> [--jobs N] [--seed S] [--nodes N] [--sizes 50,100,200,400]

  throughput   Table 4 + Fig 4 + Fig 5: workload sweep fixed vs flexible
  table2       Table 2: action analysis (sync vs async scheduling)
  table3       Table 3: cluster and job measures (400-job workloads)
  trace        Fig 6: time evolution (default --jobs 50), or with a
               scenario file: repro trace <spec.toml> [--run I] [--trace DIR]
               runs one matrix point and exports a Chrome/Perfetto trace
               (open the .trace.json in ui.perfetto.dev or chrome://tracing)
  perjob       Fig 7/8: per-job times by application (default --jobs 50)
  overhead     Fig 3: live scheduling + resize overheads (--mb payload)
  live         run a small live workload with real PJRT compute
  calibrate    measure real per-iteration PJRT times per (app, procs)
  campaign     run a scenario sweep: repro campaign <spec.toml> [--workers N]
               (spec schema: scenarios/README.md; examples under scenarios/;
               --workers must be >= 1, omit for one thread per core;
               --dry-run prints the expanded scenario matrix and exits;
               a [federation] block shards the cluster under a
               meta-scheduler — see scenarios/federated_sweep.toml;
               --trace DIR exports per-run Chrome traces there, with
               --trace-stride N / --trace-cap N bounding the job tracks;
               --progress prints completed/total (ETA) lines on stderr.
               Boolean flags go AFTER the spec path)
  all          every DES-based artifact

Set DMR_LOG=off|warn|info|debug to filter stderr diagnostics (default warn).
Results are also written as CSV under results/.";

    fn cfg(args: &Args, mode: SchedMode) -> DesConfig {
        DesConfig {
            rms: RmsConfig {
                nodes: args.get_parse("nodes", 64usize),
                ..Default::default()
            },
            mode,
            seed: args.get_parse("seed", 0xD41u64),
            ..Default::default()
        }
    }

    fn summarize(args: &Args, jobs: usize, seed: u64, mode: SchedMode, flexible: bool) -> RunSummary {
        let w = workload::generate(jobs, seed);
        let w = if flexible { w } else { w.as_fixed() };
        let label = if flexible {
            match mode {
                SchedMode::Sync => "Flexible",
                SchedMode::Async => "Asynchronous",
            }
        } else {
            "Fixed"
        };
        RunSummary::from_run(Engine::new(cfg(args, mode)).run(&w, label))
    }

    fn throughput(args: &Args) -> Result<()> {
        let sizes: Vec<usize> = args
            .get_or("sizes", "50,100,200,400")
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect();
        let seed = args.get_parse("seed", 42u64);
        let mut rows = Vec::new();
        for n in sizes {
            eprintln!("[throughput] {n} jobs ...");
            let fixed = summarize(args, n, seed, SchedMode::Sync, false);
            let flex = summarize(args, n, seed, SchedMode::Sync, true);
            rows.push((n, fixed, flex));
        }
        println!("{}", report::table4(&rows).render());
        println!("{}", report::fig4(&rows));
        println!("{}", report::fig5(&rows));
        write_csv(
            "results/table4_fig4_fig5.csv",
            &["jobs", "version", "makespan_s", "util_pct", "wait_s", "exec_s", "completion_s", "node_seconds"],
            &report::throughput_rows(&rows),
        )?;
        eprintln!("[throughput] wrote results/table4_fig4_fig5.csv");
        Ok(())
    }

    fn table2(args: &Args) -> Result<()> {
        let jobs = args.get_parse("jobs", 400usize);
        let seed = args.get_parse("seed", 42u64);
        eprintln!("[table2] {jobs} jobs sync ...");
        let sync = summarize(args, jobs, seed, SchedMode::Sync, true);
        eprintln!("[table2] {jobs} jobs async ...");
        let asy = summarize(args, jobs, seed, SchedMode::Async, true);
        println!("{}", report::table2(&sync.actions, &asy.actions, jobs).render());
        let row = |s: &RunSummary, m: &str| -> Vec<Vec<String>> {
            [
                ("no-action", &s.actions.no_action),
                ("expand", &s.actions.expand),
                ("shrink", &s.actions.shrink),
            ]
            .iter()
            .map(|(k, x)| {
                vec![
                    m.to_string(),
                    k.to_string(),
                    format!("{}", x.count()),
                    format!("{:.4}", x.min()),
                    format!("{:.4}", x.max()),
                    format!("{:.4}", x.mean()),
                    format!("{:.4}", x.std()),
                ]
            })
            .collect()
        };
        let mut rows = row(&sync, "sync");
        rows.extend(row(&asy, "async"));
        write_csv(
            "results/table2_actions.csv",
            &["mode", "action", "count", "min_s", "max_s", "avg_s", "std_s"],
            &rows,
        )?;
        Ok(())
    }

    fn table3(args: &Args) -> Result<()> {
        let jobs = args.get_parse("jobs", 400usize);
        let seed = args.get_parse("seed", 42u64);
        eprintln!("[table3] fixed ...");
        let fixed = summarize(args, jobs, seed, SchedMode::Sync, false);
        eprintln!("[table3] sync ...");
        let sync = summarize(args, jobs, seed, SchedMode::Sync, true);
        eprintln!("[table3] async ...");
        let asy = summarize(args, jobs, seed, SchedMode::Async, true);
        println!("{}", report::table3(&fixed, &sync, &asy).render());
        Ok(())
    }

    fn trace(args: &Args) -> Result<()> {
        // `repro trace <scenario.toml|.json>` (an existing spec file) is
        // the one-run span-trace exporter; without a scenario file the
        // legacy Fig 6 path runs.
        if let Some(path) = args.positional.first() {
            anyhow::ensure!(
                std::path::Path::new(path).is_file(),
                "scenario file {path:?} not found (repro trace with no \
                 positional argument renders Fig 6)"
            );
            return trace_scenario(args, path);
        }
        let jobs = args.get_parse("jobs", 50usize);
        let seed = args.get_parse("seed", 42u64);
        let fixed = summarize(args, jobs, seed, SchedMode::Sync, false);
        let flex = summarize(args, jobs, seed, SchedMode::Sync, true);
        println!("{}", report::fig6(&fixed, &flex));
        let series = |s: &RunSummary, name: &str| -> Vec<Vec<String>> {
            s.alloc_series
                .iter()
                .map(|(t, v)| vec![name.to_string(), format!("{t:.1}"), format!("{v}")])
                .collect()
        };
        let mut rows = series(&fixed, "alloc-fixed");
        rows.extend(series(&flex, "alloc-flex"));
        write_csv("results/fig6_trace.csv", &["series", "t_s", "value"], &rows)?;
        Ok(())
    }

    /// `repro trace <scenario>`: run one matrix point of a campaign spec
    /// and export its Chrome-trace + JSONL span files.
    fn trace_scenario(args: &Args, path: &str) -> Result<()> {
        use anyhow::Context as _;
        use dmr::campaign::{self, CampaignOpts, CampaignSpec};
        use dmr::obs::TraceConfig;

        let spec = CampaignSpec::from_file(path)?;
        let plans = spec.expand();
        let run = args.get_parse("run", 0usize);
        let plan = plans.get(run).with_context(|| {
            format!("--run {run} is out of range (matrix has {} runs)", plans.len())
        })?;
        let dir = args
            .get("trace")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| spec.output_dir.join("traces"));
        let opts = CampaignOpts {
            workers: 1,
            trace_dir: Some(dir.clone()),
            trace_cfg: TraceConfig {
                enabled: true,
                stride: args.get_parse("trace-stride", spec.trace.stride),
                cap: args.get_parse("trace-cap", spec.trace.cap),
            },
            ..Default::default()
        };
        eprintln!("[trace] {} (run {run}/{}) ...", plan.label, plans.len());
        let rec = campaign::run_plan(&spec, plan, &opts)?;
        let st = rec.trace.context("trace export failed (see warnings above)")?;
        println!(
            "trace {}: {} spans ({} job spans, {} instants), {}/{} job tracks kept",
            rec.plan.label,
            st.spans,
            st.job_spans,
            st.instants,
            st.job_tracks_kept,
            st.job_tracks_total
        );
        println!("  profile: {}", rec.summary.profile.summary_line(rec.summary.events));
        println!("  wrote {}", dir.join(format!("{}.trace.json", rec.plan.label)).display());
        println!("  wrote {}", dir.join(format!("{}.spans.jsonl", rec.plan.label)).display());
        println!("  open the .trace.json in ui.perfetto.dev or chrome://tracing");
        Ok(())
    }

    fn perjob(args: &Args) -> Result<()> {
        let jobs = args.get_parse("jobs", 50usize);
        let seed = args.get_parse("seed", 42u64);
        let fixed = summarize(args, jobs, seed, SchedMode::Sync, false);
        let flex = summarize(args, jobs, seed, SchedMode::Sync, true);
        println!("{}", report::fig7_fig8_preview(&fixed, &flex));
        write_csv(
            "results/fig7_fig8_perjob.csv",
            &["app", "job", "wait_fixed", "wait_flex", "exec_fixed", "exec_flex",
              "d_wait", "d_exec", "d_completion"],
            &report::perjob_rows(&fixed, &flex),
        )?;
        eprintln!("[perjob] wrote results/fig7_fig8_perjob.csv");
        Ok(())
    }

    fn overhead(args: &Args) -> Result<()> {
        let mb = args.get_parse("mb", 64usize);
        let reps = args.get_parse("reps", 3usize);
        eprintln!("[overhead] {mb} MB payload, {reps} reps per point ...");
        let samples = dmr::live::overhead::fig3_sweep(reps, mb * 1024 * 1024 / 4);
        let mut t = dmr::util::table::Table::new(vec![
            "Reconfig", "Scheduling time (s)", "Resize time (s)",
        ])
        .with_title(&format!("Fig 3: reconfiguration overheads ({mb} MB payload)"));
        let mut rows = Vec::new();
        for s in &samples {
            t.row(vec![
                format!("{} -> {}", s.from, s.to),
                format!("{:.6}", s.sched_secs),
                format!("{:.4}", s.resize_secs),
            ]);
            rows.push(vec![
                s.from.to_string(),
                s.to.to_string(),
                format!("{:.6}", s.sched_secs),
                format!("{:.6}", s.resize_secs),
            ]);
        }
        println!("{}", t.render());
        write_csv("results/fig3_overhead.csv", &["from", "to", "sched_s", "resize_s"], &rows)?;
        Ok(())
    }

    /// Run a campaign: expand the spec's scenario matrix, shard the DES
    /// runs across worker threads, aggregate across seeds and write
    /// per-run + aggregate CSV/JSON under the spec's output dir.
    fn campaign(args: &Args) -> Result<()> {
        use anyhow::Context as _;
        use dmr::campaign::{self, CampaignSpec};
        use dmr::metrics::report;

        let path = args.positional.first().context(
            "usage: repro campaign <spec.toml|spec.json> [--workers N] [--dry-run] \
             [--trace DIR [--trace-stride N] [--trace-cap N]] [--progress]",
        )?;
        let spec = CampaignSpec::from_file(path)?;
        let workers = campaign::runner::parse_workers(args.get("workers"))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let trace_dir = args.get("trace").map(std::path::PathBuf::from);
        let opts = campaign::CampaignOpts {
            workers,
            progress: args.flag("progress"),
            trace_cfg: dmr::obs::TraceConfig {
                enabled: trace_dir.is_some(),
                stride: args.get_parse("trace-stride", spec.trace.stride),
                cap: args.get_parse("trace-cap", spec.trace.cap),
            },
            trace_dir,
        };
        if args.flag("dry-run") {
            // Sanity-check large sweeps without executing anything: print
            // the expanded scenario matrix and exit.
            let plans = spec.expand();
            let mut scenarios: Vec<(String, usize)> = Vec::new();
            for p in &plans {
                match scenarios.last_mut() {
                    Some((s, n)) if *s == p.scenario => *n += 1,
                    _ => scenarios.push((p.scenario.clone(), 1)),
                }
            }
            println!(
                "campaign {}: {} scenarios x {} seeds = {} runs (dry run, nothing executed)",
                spec.name,
                scenarios.len(),
                spec.seeds.len(),
                plans.len()
            );
            for (s, n) in &scenarios {
                println!("  {s}  [{n} runs]");
            }
            println!("output dir: {}", spec.output_dir.display());
            return Ok(());
        }
        eprintln!(
            "[campaign] {}: {} runs ({} workloads x {} nodes x {} modes x {} seeds{}), {} workers ...",
            spec.name,
            spec.matrix_size(),
            spec.workloads.len(),
            spec.nodes.len(),
            spec.modes.len(),
            spec.seeds.len(),
            if spec.matrix_size()
                == spec.workloads.len() * spec.nodes.len() * spec.modes.len() * spec.seeds.len()
            {
                String::new()
            } else {
                " x policy/fault/federation knobs".to_string()
            },
            campaign::runner::resolve_workers(&spec, workers),
        );
        let result = campaign::run_campaign_opts(&spec, &opts)?;
        let aggs = campaign::aggregate(&result.records);
        println!("{}", report::campaign_table(&spec.name, &aggs).render());
        let out = campaign::write_outputs(&spec, &result)?;
        if let Some(dir) = &opts.trace_dir {
            let traced = result.records.iter().filter(|r| r.trace.is_some()).count();
            eprintln!(
                "[campaign] wrote {traced}/{} trace pairs under {}",
                result.records.len(),
                dir.display()
            );
        }
        eprintln!(
            "[campaign] {} runs in {:.2}s on {} workers ({:.1} runs/s)",
            result.records.len(),
            result.wall_secs,
            result.workers,
            result.runs_per_sec()
        );
        eprintln!("[campaign] wrote {}", out.runs_csv.display());
        eprintln!("[campaign] wrote {}", out.agg_csv.display());
        eprintln!("[campaign] wrote {}", out.agg_json.display());
        Ok(())
    }

    /// Measure the real per-iteration cost of every (app, procs) variant
    /// through the live stack (rank threads + vmpi + PJRT) and emit
    /// results/calib.json.  These are this testbed's ground-truth step
    /// costs; the DES uses the paper-calibrated model by default
    /// (DESIGN.md par.2) but can be compared against these.
    fn calibrate(args: &Args) -> Result<()> {
        use dmr::apps::config::AppKind;
        use dmr::apps::state::AppState;
        use dmr::runtime::ComputeServer;
        use dmr::util::json::Json;
        use dmr::vmpi::World;
        use std::collections::BTreeMap;

        let iters = args.get_parse("iters", 5u32);
        let server = ComputeServer::start_default()?;
        let world = World::new();
        let mut obj = BTreeMap::new();
        for app in AppKind::WORKLOAD_APPS {
            for procs in [1usize, 2, 4, 8] {
                let (tx, rx) = std::sync::mpsc::channel::<f64>();
                let compute = server.handle();
                let gid = world.spawn(procs, move |ep| {
                    let mut st = AppState::init(app, ep.rank(), ep.size(), 1.0);
                    // one warm-up step (compiles the executable)
                    st.step(&ep, &compute).expect("warmup");
                    ep.barrier();
                    let t0 = std::time::Instant::now();
                    for _ in 0..iters {
                        st.step(&ep, &compute).expect("step");
                    }
                    ep.barrier();
                    if ep.rank() == 0 {
                        tx.send(t0.elapsed().as_secs_f64() / iters as f64).unwrap();
                    }
                });
                let per_iter = rx.recv().expect("calibration result");
                world.join_group(gid);
                world.destroy_group(gid);
                println!("{app:>7} p={procs:<2}  {:.3} ms/iter", per_iter * 1e3);
                obj.insert(format!("{}_p{}", app.name(), procs), Json::Num(per_iter));
            }
        }
        std::fs::create_dir_all("results")?;
        std::fs::write("results/calib.json", Json::Obj(obj).render())?;
        println!("wrote results/calib.json");
        Ok(())
    }

    fn live(args: &Args) -> Result<()> {
        use dmr::live::{LiveDriver, LiveOpts};
        use dmr::runtime::ComputeServer;
        let jobs = args.get_parse("jobs", 4usize);
        let iters = args.get_parse("iters", 10u32);
        std::env::set_var("DMR_TIME_SCALE", args.get_or("time-scale", "0.02"));
        let server = ComputeServer::start_default()?;
        let opts = LiveOpts {
            rms: RmsConfig { nodes: args.get_parse("nodes", 16usize), ..Default::default() },
            arrival_scale: 0.05,
            ..Default::default()
        };
        let mut driver = LiveDriver::new(opts, server.handle());
        let mut specs = Vec::new();
        let mut w = workload::generate(jobs, args.get_parse("seed", 1u64));
        for (i, mut s) in w.jobs.drain(..).enumerate() {
            s.iterations = iters;
            // keep live sizes within the artifact set and the small cluster
            s.procs = if i % 3 == 2 { 8 } else { 4 };
            s.max_procs = 8;
            s.min_procs = 2;
            s.pref_procs = Some(2);
            specs.push(s);
        }
        let t0 = std::time::Instant::now();
        let report = driver.run(specs);
        let rms = report.rms.lock().unwrap();
        println!("live: {} jobs completed in {:.2?}", rms.completed_jobs(), t0.elapsed());
        println!("      expansions={} shrinks={}", rms.log.expansions(), rms.log.shrinks());
        for j in dmr::metrics::extract(&rms) {
            println!(
                "  {:>12} {:>7}: wait {:>6.2}s exec {:>6.2}s resizes {}",
                j.name,
                j.app.name(),
                j.wait(),
                j.exec(),
                j.n_expands + j.n_shrinks
            );
        }
        Ok(())
    }
}
