//! Sharded multi-cluster federation with a meta-scheduler.
//!
//! The paper's throughput-aware malleability assumes one resource manager
//! over one flat node pool; real deployments front many partitions behind
//! a single scheduling brain (Chadha et al., arXiv:2009.08289, drive a
//! SLURM extension against heterogeneous partitions).  This subsystem
//! partitions the simulated machine into **shards** — each owning its own
//! [`crate::rms::Rms`] (priorities, backfill, availability profile) and
//! its own fault timeline — coordinated by a meta-scheduler that:
//!
//! * **routes** every arriving job to one shard via a pluggable
//!   [`RoutingPolicy`] (round-robin, least-loaded, or user-locality);
//! * **steals** queued work from a backlogged shard when another shard
//!   drains (one candidate per processed event; the stolen job re-enters
//!   through the thief's normal submit → clamp → priority path, keeping
//!   its original submission time so queue aging is preserved);
//! * supports **heterogeneous shards**: per-shard node counts, node
//!   speeds (scaling every iteration time on that shard) and MTBF scale
//!   factors (scaling the per-shard failure sampling).
//!
//! ## Determinism contract
//!
//! A federated run is a pure function of (workload spec, seed, shard
//! layout): per-shard RNG streams are salted by shard id, shards are
//! always visited in id order, and the event heap stays a single global
//! total order.  The salt of shard 0 is zero and every heterogeneity
//! knob multiplies by exactly `1.0` in the default layout, so **a 1-shard
//! federation is bit-identical to the flat [`crate::des::Engine`]** —
//! event log digests and makespan bits included.  The golden tests in
//! `rust/tests/test_federation.rs` lock both properties.

use crate::cluster::{Cluster, FederatedView, DEFAULT_NODES};
use crate::des::{ActionStats, DesConfig, Engine};
use crate::resilience::{FaultSpec, OutageSpec, ResilienceStats};
use crate::rms::{PolicyStrategy, Rms};
use crate::workload::{JobStream, WorkloadSpec};
use crate::Time;

/// How the meta-scheduler picks a shard for an arriving job.
///
/// Routing happens when the arrival event is *processed* (not when it is
/// enqueued), so load-sensitive policies see the federation's state at
/// the arrival instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through the shards in id order, skipping shards too small to
    /// ever hold the job (`min_procs` above the shard's node count).
    RoundRobin,
    /// Send the job to the shard with the lowest load ratio
    /// `(pending + running jobs) / nodes`; ties break toward the lowest
    /// shard id.  Unplaceable shards are skipped.
    LeastLoaded,
    /// User-affinity: user *u* homes on shard `u mod k` (models data or
    /// license locality).  If the home shard cannot hold the job, the
    /// scan falls forward to the next placeable shard.
    Locality,
}

impl RoutingPolicy {
    /// Parse a policy name; accepts the short labels (`rr`, `ll`, `loc`)
    /// and the long forms (`round-robin`, `least-loaded`, `locality`,
    /// plus `_`-separated variants and `affinity`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rr" | "round-robin" | "round_robin" | "roundrobin" => Some(RoutingPolicy::RoundRobin),
            "ll" | "least-loaded" | "least_loaded" | "leastloaded" => {
                Some(RoutingPolicy::LeastLoaded)
            }
            "loc" | "locality" | "affinity" => Some(RoutingPolicy::Locality),
            _ => None,
        }
    }

    /// Short label used in scenario ids (`-s4xll`) and CSV cells.
    pub fn label(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "rr",
            RoutingPolicy::LeastLoaded => "ll",
            RoutingPolicy::Locality => "loc",
        }
    }
}

/// How the meta-scheduler steals queued work from backlogged shards into
/// drained ones (invoked after every processed event).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StealPolicy {
    /// No stealing.
    Off,
    /// Take one candidate per invocation — the head of the victim's
    /// lowest-priority fitting work (the historical `steal = true`).
    Head,
    /// Steal-half: take up to half the victim's pending queue in one
    /// invocation (bounded by what fits the thief's free nodes).
    Half,
}

impl StealPolicy {
    /// Parse a policy name; booleans map to the historical semantics
    /// (`"true"`/`"on"` = [`StealPolicy::Head`]).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" | "none" | "false" => Some(StealPolicy::Off),
            "head" | "on" | "true" => Some(StealPolicy::Head),
            "half" | "steal-half" | "steal_half" => Some(StealPolicy::Half),
            _ => None,
        }
    }

    /// Short label used in scenario ids (`-s4xllxhalf`) and reports.
    pub fn label(&self) -> &'static str {
        match self {
            StealPolicy::Off => "off",
            StealPolicy::Head => "head",
            StealPolicy::Half => "half",
        }
    }

    /// Whether this policy steals at all.
    pub fn enabled(&self) -> bool {
        *self != StealPolicy::Off
    }
}

/// Static description of one shard: its node count and its three
/// heterogeneity knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardSpec {
    /// Nodes owned by this shard.
    pub nodes: usize,
    /// Relative node speed (1.0 = the calibrated Table 1 machine).  Every
    /// iteration on this shard takes `1/speed` times the modeled time.
    pub speed: f64,
    /// Multiplier on the configured MTBF for this shard's failure
    /// sampling (2.0 = twice as reliable, 0.5 = twice as flaky).
    pub mtbf_scale: f64,
    /// Per-shard reconfiguration policy override; `None` keeps the run's
    /// global [`crate::rms::RmsConfig::strategy`].
    pub strategy: Option<PolicyStrategy>,
}

impl Default for ShardSpec {
    fn default() -> Self {
        ShardSpec { nodes: DEFAULT_NODES, speed: 1.0, mtbf_scale: 1.0, strategy: None }
    }
}

impl ShardSpec {
    /// Parse a topology entry `"nodes[:speed[:mtbf_scale[:strategy]]]"`,
    /// e.g. `"64"`, `"64:0.5"`, `"128:1.0:2.0"`, `"32:1:1:queue"`.  The
    /// strategy field is validated against the policy registry
    /// ([`PolicyStrategy::parse`]).
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut parts = s.split(':');
        let nodes: usize = parts
            .next()
            .unwrap_or("")
            .trim()
            .parse()
            .map_err(|_| format!("bad shard node count in {s:?}"))?;
        if nodes == 0 {
            return Err(format!("shard must have at least one node: {s:?}"));
        }
        let mut spec = ShardSpec { nodes, ..Default::default() };
        if let Some(sp) = parts.next() {
            spec.speed =
                sp.trim().parse().map_err(|_| format!("bad shard speed in {s:?}"))?;
            if !(spec.speed > 0.0) {
                return Err(format!("shard speed must be positive: {s:?}"));
            }
        }
        if let Some(m) = parts.next() {
            m.trim()
                .parse()
                .map(|v| spec.mtbf_scale = v)
                .map_err(|_| format!("bad shard mtbf_scale in {s:?}"))?;
            if !(spec.mtbf_scale > 0.0) {
                return Err(format!("shard mtbf_scale must be positive: {s:?}"));
            }
        }
        if let Some(st) = parts.next() {
            match PolicyStrategy::parse(st.trim()) {
                Ok(p) => spec.strategy = Some(p),
                Err(e) => return Err(format!("bad shard strategy in {s:?}: {e}")),
            }
        }
        if parts.next().is_some() {
            return Err(format!("too many ':' fields in shard spec {s:?}"));
        }
        Ok(spec)
    }

    /// Split `total` nodes uniformly into `k` homogeneous shards (the
    /// remainder goes to the lowest shard ids, one node each).
    pub fn uniform(total: usize, k: usize) -> Vec<ShardSpec> {
        let k = k.max(1);
        let base = total / k;
        let rem = total % k;
        (0..k)
            .map(|i| ShardSpec {
                nodes: base + usize::from(i < rem),
                ..Default::default()
            })
            .collect()
    }
}

/// Everything the federated engine needs beyond the per-shard
/// [`DesConfig`]: the shard layout, the routing policy, the
/// work-stealing policy, and the optional failure-domain layer.
#[derive(Debug, Clone)]
pub struct FederationConfig {
    /// The shard layout (at least one shard).
    pub shards: Vec<ShardSpec>,
    /// Arrival routing policy.
    pub routing: RoutingPolicy,
    /// Cross-shard work-stealing policy (off / head / half).
    pub steal: StealPolicy,
    /// Optional per-shard fault-spec override (index = shard id; shards
    /// past the end of the vector keep the scaled base spec).  Used for
    /// scripted per-shard fault traces and shard-loss drain experiments;
    /// campaigns populate it from `[[federation.shard_fault]]` tables
    /// (see `scenarios/README.md`).
    pub shard_faults: Option<Vec<FaultSpec>>,
    /// Optional per-shard correlated-outage specs (index = shard id;
    /// shards past the end stay outage-free).  `None` — the default —
    /// keeps every event stream byte-identical to pre-outage builds;
    /// campaigns populate it from `[federation.outages]`.
    pub outages: Option<Vec<OutageSpec>>,
}

impl Default for FederationConfig {
    fn default() -> Self {
        FederationConfig {
            shards: vec![ShardSpec::default()],
            routing: RoutingPolicy::RoundRobin,
            steal: StealPolicy::Off,
            shard_faults: None,
            outages: None,
        }
    }
}

/// Final state and counters of one shard after a federated run.
pub struct ShardRun {
    /// Shard id (position in the layout).
    pub shard: usize,
    /// Nodes the shard owned.
    pub nodes: usize,
    /// Relative node speed of the shard.
    pub speed: f64,
    /// The shard's manager state: job records, event log, telemetry.
    pub rms: Rms,
    /// The shard's own resilience measures (its fault timeline only).
    pub stats: ResilienceStats,
    /// Jobs this shard received through cross-shard stealing.
    pub steals_in: u64,
    /// Jobs stolen away from this shard's pending queue.
    pub steals_out: u64,
    /// Arrivals the meta-scheduler routed to this shard.
    pub routed: u64,
    /// Evacuated jobs this shard received (cross-shard requeues in).
    pub evac_in: u64,
    /// Jobs evacuated away from this shard during outages.
    pub evac_out: u64,
}

/// Everything measured from one federated run: the global measures plus
/// one [`ShardRun`] per shard.
pub struct FedRunResult {
    /// Run label (scenario + seed for campaigns).
    pub label: String,
    /// Completion time of the last job (global, across all shards).
    pub makespan: Time,
    /// Arrival time of the first job.
    pub first_submit: Time,
    /// Reconfiguration timing statistics, merged across shards.
    pub actions: ActionStats,
    /// User jobs processed (across all shards).
    pub user_jobs: usize,
    /// Discrete events processed by the shared event loop.
    pub events: u64,
    /// Merged resilience measures (counts summed; availability weighted
    /// by shard capacity).
    pub resilience: ResilienceStats,
    /// High-water mark of live simulation-slab slots, summed across
    /// shards (see [`crate::des::RunResult::peak_slab`]).
    pub peak_slab: usize,
    /// Per-shard final states, in shard-id order.
    pub shards: Vec<ShardRun>,
    /// Host-side wall-clock profile of the shared event loop (global,
    /// not per-shard).  Observational only — see [`crate::obs::profile`].
    pub profile: crate::obs::PhaseProfile,
}

impl FedRunResult {
    /// Total cross-shard steals (each steal counts once).
    pub fn steals(&self) -> u64 {
        self.shards.iter().map(|s| s.steals_out).sum()
    }

    /// Total outage evacuations (each evacuated job counts once).
    pub fn evacuations(&self) -> u64 {
        self.shards.iter().map(|s| s.evac_out).sum()
    }

    /// Total cross-shard requeues received (equals
    /// [`FedRunResult::evacuations`] — every evacuated job lands on
    /// exactly one surviving shard).
    pub fn cross_shard_requeues(&self) -> u64 {
        self.shards.iter().map(|s| s.evac_in).sum()
    }

    /// Snapshot of the federated node pool at the end of the run.
    pub fn view(&self) -> FederatedView {
        let mut v = FederatedView::default();
        for s in &self.shards {
            v.push(&s.rms.cluster);
        }
        v
    }
}

/// The federated engine: a thin façade over [`crate::des::Engine`]
/// generalized to a shard vector.  Build one per run.
///
/// ```
/// use dmr::des::DesConfig;
/// use dmr::federation::{FedEngine, FederationConfig, RoutingPolicy, ShardSpec, StealPolicy};
/// use dmr::workload;
///
/// let w = workload::generate(20, 7);
/// let fed = FederationConfig {
///     shards: ShardSpec::uniform(64, 2),
///     routing: RoutingPolicy::LeastLoaded,
///     steal: StealPolicy::Head,
///     ..Default::default()
/// };
/// let r = FedEngine::new(DesConfig::default(), fed).run(&w, "demo");
/// assert_eq!(r.shards.len(), 2);
/// assert_eq!(r.shards.iter().map(|s| s.rms.completed_jobs()).sum::<usize>(), 20);
/// ```
pub struct FedEngine {
    inner: Engine,
}

impl FedEngine {
    /// Build a federated engine: one `Rms` + fault timeline per shard,
    /// RNG streams salted by shard id (shard 0's salt is zero, which is
    /// what makes the 1-shard layout bit-identical to the flat engine).
    pub fn new(cfg: DesConfig, fed: FederationConfig) -> Self {
        assert!(!fed.shards.is_empty(), "federation needs at least one shard");
        FedEngine { inner: Engine::new_federated(cfg, &fed) }
    }

    /// Direct access to one shard's machine (tests mark nodes down before
    /// arrivals).  Panics if the shard id is out of range.
    pub fn shard_cluster_mut(&mut self, shard: usize) -> &mut Cluster {
        self.inner.shard_cluster_mut(shard)
    }

    /// Run a workload to completion across the federation.
    pub fn run(self, workload: &WorkloadSpec, label: &str) -> FedRunResult {
        self.inner.run_federated(workload, label)
    }

    /// Streamed counterpart of [`FedEngine::run`]: pull arrivals lazily
    /// from a [`JobStream`], holding at most `window` unarrived jobs
    /// resident.  Bit-identical to [`FedEngine::run`] over the
    /// materialized workload, for any `window ≥ 1`.
    pub fn run_stream(
        self,
        stream: &mut dyn JobStream,
        window: usize,
        label: &str,
    ) -> anyhow::Result<FedRunResult> {
        self.inner.run_stream_federated(stream, window, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_policy_parses_short_and_long_forms() {
        assert_eq!(RoutingPolicy::parse("rr"), Some(RoutingPolicy::RoundRobin));
        assert_eq!(RoutingPolicy::parse("round-robin"), Some(RoutingPolicy::RoundRobin));
        assert_eq!(RoutingPolicy::parse("ll"), Some(RoutingPolicy::LeastLoaded));
        assert_eq!(RoutingPolicy::parse("least_loaded"), Some(RoutingPolicy::LeastLoaded));
        assert_eq!(RoutingPolicy::parse("loc"), Some(RoutingPolicy::Locality));
        assert_eq!(RoutingPolicy::parse("affinity"), Some(RoutingPolicy::Locality));
        assert_eq!(RoutingPolicy::parse("bogus"), None);
        for p in [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded, RoutingPolicy::Locality] {
            assert_eq!(RoutingPolicy::parse(p.label()), Some(p), "label round-trips");
        }
    }

    #[test]
    fn shard_spec_parses_topology_strings() {
        let s = ShardSpec::parse("64").unwrap();
        assert_eq!(s, ShardSpec { nodes: 64, speed: 1.0, mtbf_scale: 1.0, strategy: None });
        let s = ShardSpec::parse("32:0.5").unwrap();
        assert_eq!(s.nodes, 32);
        assert_eq!(s.speed, 0.5);
        let s = ShardSpec::parse("128:2.0:0.25").unwrap();
        assert_eq!((s.nodes, s.speed, s.mtbf_scale), (128, 2.0, 0.25));
        assert_eq!(s.strategy, None);
        assert!(ShardSpec::parse("0").is_err(), "zero nodes rejected");
        assert!(ShardSpec::parse("8:-1").is_err(), "negative speed rejected");
        assert!(ShardSpec::parse("8:1:0").is_err(), "zero mtbf_scale rejected");
        assert!(ShardSpec::parse("x").is_err());
    }

    #[test]
    fn shard_spec_parses_per_shard_strategy() {
        let s = ShardSpec::parse("32:1:1:queue").unwrap();
        assert_eq!(s.strategy, Some(PolicyStrategy::QueueAware));
        let s = ShardSpec::parse("64:2.0:0.5:fair").unwrap();
        assert_eq!((s.nodes, s.speed, s.mtbf_scale), (64, 2.0, 0.5));
        assert_eq!(s.strategy, Some(PolicyStrategy::FairShare));
        assert!(ShardSpec::parse("8:1:1:1").is_err(), "unknown strategy rejected");
        assert!(ShardSpec::parse("8:1:1:bogus").is_err(), "unknown strategy rejected");
        assert!(ShardSpec::parse("8:1:1:queue:x").is_err(), "extra fields rejected");
    }

    #[test]
    fn steal_policy_parses_and_labels() {
        assert_eq!(StealPolicy::parse("off"), Some(StealPolicy::Off));
        assert_eq!(StealPolicy::parse("false"), Some(StealPolicy::Off));
        assert_eq!(StealPolicy::parse("head"), Some(StealPolicy::Head));
        assert_eq!(StealPolicy::parse("true"), Some(StealPolicy::Head));
        assert_eq!(StealPolicy::parse("half"), Some(StealPolicy::Half));
        assert_eq!(StealPolicy::parse("bogus"), None);
        for p in [StealPolicy::Off, StealPolicy::Head, StealPolicy::Half] {
            assert_eq!(StealPolicy::parse(p.label()), Some(p), "label round-trips");
        }
        assert!(!StealPolicy::Off.enabled());
        assert!(StealPolicy::Head.enabled() && StealPolicy::Half.enabled());
    }

    #[test]
    fn uniform_split_spreads_remainder() {
        let v = ShardSpec::uniform(64, 4);
        assert_eq!(v.iter().map(|s| s.nodes).collect::<Vec<_>>(), vec![16, 16, 16, 16]);
        let v = ShardSpec::uniform(10, 3);
        assert_eq!(v.iter().map(|s| s.nodes).collect::<Vec<_>>(), vec![4, 3, 3]);
        assert_eq!(v.iter().map(|s| s.nodes).sum::<usize>(), 10);
        assert!(v.iter().all(|s| s.speed == 1.0 && s.mtbf_scale == 1.0));
    }
}
