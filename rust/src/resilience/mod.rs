//! Resilience engine: deterministic fault injection with
//! malleability-aware recovery.
//!
//! Node failures and maintenance drains are the scenario class where
//! RMS–runtime collaboration pays twice: a *malleable* job can shrink
//! onto its surviving nodes and keep running, while a *rigid* job must be
//! killed and requeued, losing all work since its last checkpoint.  This
//! subsystem threads that comparison through the whole stack:
//!
//! * [`model`] — deterministic fault sources: seeded per-node MTBF/MTTR
//!   sampling (exponential, [`crate::util::rng::Rng`]), scripted fault
//!   traces (`fail node=N at t=…, repair at t=…`) and scheduled drain
//!   windows.  Same spec + seed ⇒ bit-identical fault timelines, and the
//!   machine timeline is independent of the scheduling mode, so fixed and
//!   sync runs face the *same* fault trace.
//! * [`recovery`] — the recovery policy: checkpoint/rework accounting
//!   ([`rework_lost`]) and the factor-chain shrink-rescue target
//!   ([`feasible_shrink`], built on [`crate::rms::policy::shrink_target`]
//!   / [`crate::rms::policy::factor_reachable`]).
//! * [`crate::cluster`] — real `Down`/`Draining` node states: `alloc`
//!   skips them, the counters stay O(1), and draining nodes finish their
//!   current job before going offline.
//! * [`crate::des`] — `NodeFail`/`NodeRepair`/`DrainStart`/`DrainEnd`
//!   events interleaved with the workload stream; failure events are
//!   folded into [`crate::rms::EventLog::digest`] so the golden
//!   determinism lock covers them.
//! * [`crate::campaign`] — a `[faults]` sweep axis (mtbf, drain schedule,
//!   checkpoint interval) and the per-run metrics below, emitted through
//!   the standard CSV/JSON aggregation.
//!
//! Every recovery entry point (`Rms::fail_node`, `rescue_shrink_to`,
//! `requeue_after_failure`) publishes its delta to the incremental
//! availability profile ([`crate::rms::profile`]) in O(log active), so
//! fault-heavy runs keep the same per-pass scheduling cost as fault-free
//! ones — the randomized differential test drives exactly these
//! transitions and re-derives the profile from scratch after each.

pub mod model;
pub mod recovery;
pub mod resize;

pub use model::{
    DrainSet, DrainWindow, FailureDomain, FaultKind, FaultSpec, FaultTraceEvent, OutageEvent,
    OutageSpec, PartitionWindow,
};
pub use recovery::{feasible_shrink, rework_lost, RecoveryConfig};
pub use resize::ResizeFaultSpec;

/// Everything the DES needs to inject faults and recover from them.
#[derive(Debug, Clone, Default)]
pub struct ResilienceConfig {
    /// Fault sources (MTBF sampling, scripted trace, drain windows).
    pub faults: FaultSpec,
    /// Recovery policy (checkpoint interval, rescue on/off).
    pub recovery: RecoveryConfig,
    /// Resize-transaction failure injection + retry/backoff policy
    /// ([`resize`]).  Inactive by default: the DES then keeps the legacy
    /// single-event resize path, byte-identical to the pre-transaction
    /// engine.
    pub resize_faults: ResizeFaultSpec,
}

/// Per-run resilience measures (the new robustness axis of the campaign
/// CSV/JSON outputs).
#[derive(Debug, Clone)]
pub struct ResilienceStats {
    /// Hardware failures landed on existing nodes — including ones that
    /// hit a node already offline (the outage then nests instead of
    /// duplicating).  The failure *timeline* is a pure function of the
    /// fault spec + seed; this count covers the slice of it up to each
    /// run's own makespan, so runs with different makespans see a
    /// different-length prefix of the same timeline.
    pub node_failures: u64,
    /// Running jobs hit by a failed node.
    pub interrupted: u64,
    /// Interrupted malleable jobs saved by a DMR shrink onto their
    /// surviving nodes.
    pub rescued: u64,
    /// Interrupted jobs killed and requeued (rigid, or no factor-reachable
    /// shrink fit).
    pub requeued: u64,
    /// Interrupted malleable jobs evacuated off this shard during a
    /// correlated outage: their checkpointed state was requeued through
    /// the router to a surviving shard.  Zero outside federated
    /// outage runs; per shard, `interrupted == rescued + requeued +
    /// evacuated` (the failure ledger).
    pub evacuated: u64,
    /// Total execution time redone because it post-dated the last
    /// checkpoint (seconds).
    pub rework_time: f64,
    /// Integral of down nodes over the makespan (node-seconds the machine
    /// could not sell).
    pub lost_node_seconds: f64,
    /// Machine availability: `1 - lost_node_seconds / (nodes * makespan)`.
    pub availability: f64,
    /// Resize transactions begun (multi-phase path only; the legacy
    /// single-event resize path never counts here).
    pub resize_attempts: u64,
    /// Resize transactions aborted — by a drawn fault (revocation, spawn
    /// failure, redistribution abort) or by a machine fault landing on
    /// the job's allocation during the transfer window.
    pub resize_aborts: u64,
    /// Time lost to aborted transactions: the in-flight phase time thrown
    /// away at each rollback plus the backoff waits before retries
    /// (seconds).
    pub retry_time: f64,
    /// Jobs that exhausted their resize retries and degraded to
    /// non-malleable for the rest of the run.
    pub degraded_jobs: u64,
}

impl Default for ResilienceStats {
    fn default() -> Self {
        ResilienceStats {
            node_failures: 0,
            interrupted: 0,
            rescued: 0,
            requeued: 0,
            evacuated: 0,
            rework_time: 0.0,
            lost_node_seconds: 0.0,
            availability: 1.0,
            resize_attempts: 0,
            resize_aborts: 0,
            retry_time: 0.0,
            degraded_jobs: 0,
        }
    }
}
