//! Resize-transaction fault source: seeded failure injection for the
//! multi-phase reconfiguration protocol (allocation grant → spawn →
//! redistribute → commit, §5.2).
//!
//! PR 3's machine faults can kill nodes mid-run but can never make a
//! *resize itself* fail; this spec closes that gap.  Each transaction
//! draws three Bernoulli outcomes (revocation, spawn failure,
//! redistribution abort — always in that fixed order, always all three,
//! so the draw stream is a pure function of the transaction sequence) from
//! a dedicated RNG stream salted away from both the cost-model stream and
//! the machine-fault stream.  An inactive spec (`fail_prob = 0`
//! everywhere) must leave the event stream byte-identical to today's
//! single-event resize — the DES only takes the multi-phase path when
//! [`ResizeFaultSpec::is_active`] holds.

use crate::util::rng::Rng;

/// Salt folded into the run seed for the resize-fault RNG, distinct from
/// the cost stream (no salt) and the machine-fault stream
/// (`model::FAULT_SEED_SALT`), so the three never alias.
const RESIZE_FAULT_SEED_SALT: u64 = 0x2E51_5EED_FA17_0B57;

/// Which phase of the transaction a drawn fault lands on (also the
/// `phase` code carried by `RmsEvent::ResizeAbort`).
pub const PHASE_GRANT: u8 = 0;
/// Spawn phase (new processes launched on the granted nodes).
pub const PHASE_SPAWN: u8 = 1;
/// Redistribution phase (data moves to the new process set).
pub const PHASE_REDIST: u8 = 2;
/// Not a drawn fault: a machine fault hit the job's allocation during
/// the transfer window and revoked the transaction.
pub const PHASE_NODE_FAULT: u8 = 3;

/// Failure injection for resize transactions, plus the retry policy
/// applied after a rollback.
#[derive(Debug, Clone, PartialEq)]
pub struct ResizeFaultSpec {
    /// Probability the spawn phase fails (new processes never come up).
    pub spawn_fail: f64,
    /// Probability the redistribution phase aborts mid-transfer.
    pub redist_fail: f64,
    /// Probability the allocation grant is revoked before the spawn.
    pub revoke: f64,
    /// Aborted transactions are retried at most this many times before
    /// the job degrades to non-malleable for the rest of the run.
    pub max_retries: u32,
    /// First retry waits this long (seconds); each further retry doubles
    /// the wait (bounded exponential backoff).
    pub backoff_base: f64,
    /// Backoff ceiling (seconds).
    pub backoff_cap: f64,
}

impl Default for ResizeFaultSpec {
    fn default() -> Self {
        ResizeFaultSpec {
            spawn_fail: 0.0,
            redist_fail: 0.0,
            revoke: 0.0,
            max_retries: 3,
            backoff_base: 30.0,
            backoff_cap: 480.0,
        }
    }
}

impl ResizeFaultSpec {
    /// Whether this spec injects anything at all.  An inactive spec keeps
    /// the DES on the legacy single-event resize path, byte-identical to
    /// the pre-transaction engine.
    pub fn is_active(&self) -> bool {
        self.spawn_fail > 0.0 || self.redist_fail > 0.0 || self.revoke > 0.0
    }

    /// The dedicated resize-fault RNG for a run seed.
    pub fn rng(&self, seed: u64) -> Rng {
        Rng::new(seed ^ RESIZE_FAULT_SEED_SALT)
    }

    /// Draw one transaction's fault outcomes: `[revoked, spawn_failed,
    /// redist_failed]`, indexed by phase.  Exactly three draws in a fixed
    /// order per transaction, so the stream position depends only on how
    /// many transactions began before this one.
    pub fn draw(&self, rng: &mut Rng) -> [bool; 3] {
        let revoked = rng.f64() < self.revoke;
        let spawn_failed = rng.f64() < self.spawn_fail;
        let redist_failed = rng.f64() < self.redist_fail;
        [revoked, spawn_failed, redist_failed]
    }

    /// Backoff before retry number `attempt` (1-based): bounded
    /// exponential, `base * 2^(attempt-1)` clamped to `backoff_cap`.
    pub fn backoff(&self, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(60) as i32;
        (self.backoff_base * 2f64.powi(exp)).min(self.backoff_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_by_default() {
        let s = ResizeFaultSpec::default();
        assert!(!s.is_active());
        assert!(ResizeFaultSpec { spawn_fail: 0.1, ..Default::default() }.is_active());
        assert!(ResizeFaultSpec { redist_fail: 0.1, ..Default::default() }.is_active());
        assert!(ResizeFaultSpec { revoke: 0.1, ..Default::default() }.is_active());
    }

    #[test]
    fn draws_are_deterministic_per_seed_and_independent_of_other_streams() {
        let s = ResizeFaultSpec { spawn_fail: 0.5, redist_fail: 0.5, revoke: 0.5, ..Default::default() };
        let seq = |seed: u64| {
            let mut rng = s.rng(seed);
            (0..32).map(|_| s.draw(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(seq(7), seq(7), "same seed, same outcomes");
        assert_ne!(seq(7), seq(8), "different seeds differ");
        // Salted away from the cost stream and the machine-fault stream.
        let a = s.rng(42).next_u64();
        assert_ne!(a, Rng::new(42).next_u64());
        assert_ne!(a, crate::resilience::FaultSpec::default().rng(42).next_u64());
    }

    #[test]
    fn three_draws_per_transaction_regardless_of_outcome() {
        // The stream position after N transactions must not depend on
        // what the outcomes were (reproducibility across fault configs
        // with the same probabilities).
        let s = ResizeFaultSpec { spawn_fail: 1.0, redist_fail: 1.0, revoke: 1.0, ..Default::default() };
        let mut a = s.rng(3);
        let mut b = s.rng(3);
        let _ = s.draw(&mut a);
        for _ in 0..3 {
            b.f64();
        }
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let s = ResizeFaultSpec { backoff_base: 30.0, backoff_cap: 200.0, ..Default::default() };
        assert_eq!(s.backoff(1), 30.0);
        assert_eq!(s.backoff(2), 60.0);
        assert_eq!(s.backoff(3), 120.0);
        assert_eq!(s.backoff(4), 200.0, "capped");
        assert_eq!(s.backoff(40), 200.0, "huge attempts stay capped");
    }
}
