//! Deterministic fault sources: MTBF/MTTR sampling, scripted fault
//! traces and scheduled drain windows.
//!
//! Replay contract: all random failure times are drawn from a *dedicated*
//! RNG stream (seeded from the run seed, salted — see
//! [`FaultSpec::rng`]), pre-seeded per node in node order and then
//! advanced only when fault events are processed.  Because repair and
//! next-failure delays depend only on previous draws, the machine
//! timeline is a pure function of (spec, seed): bit-identical across
//! reruns and identical between the rigid and malleable runs of one
//! scenario — the "same fault trace" the acceptance comparison needs.

use crate::util::rng::Rng;
use crate::{NodeId, Time};

/// One scripted machine event (`fail node=3 at t=500, repair at t=2000`
/// becomes a `Fail` and a `Repair` entry).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultTraceEvent {
    /// When the event fires.
    pub at: Time,
    /// The affected node.
    pub node: NodeId,
    /// Failure or repair.
    pub kind: FaultKind,
}

/// What a scripted machine event does to its node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The node goes down.
    Fail,
    /// The node is repaired.
    Repair,
}

/// Which nodes a drain window takes offline.
#[derive(Debug, Clone, PartialEq)]
pub enum DrainSet {
    /// The first `n` node ids (`0..n`) — the nodes the deterministic
    /// allocator prefers, so a count-drain is maximally disruptive.
    Count(usize),
    /// An explicit node list.
    Nodes(Vec<NodeId>),
}

impl DrainSet {
    /// Resolve to concrete node ids on a `total`-node machine.
    pub fn node_ids(&self, total: usize) -> Vec<NodeId> {
        match self {
            DrainSet::Count(n) => (0..(*n).min(total)).collect(),
            DrainSet::Nodes(v) => v.iter().copied().filter(|&n| n < total).collect(),
        }
    }
}

/// A scheduled maintenance window: the nodes stop accepting work at
/// `start` (idle nodes go offline immediately; allocated nodes finish
/// their current job first) and return at `end`.
#[derive(Debug, Clone, PartialEq)]
pub struct DrainWindow {
    /// Window start.
    pub start: Time,
    /// Window end.
    pub end: Time,
    /// The drained nodes.
    pub nodes: DrainSet,
}

/// The fault sources of one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSpec {
    /// Mean time between failures *per node*, seconds (exponential).
    /// `0` disables random failures.
    pub mtbf: f64,
    /// Mean time to repair a failed node, seconds (exponential).
    pub mttr: f64,
    /// Scripted machine events, replayed verbatim.
    pub scripted: Vec<FaultTraceEvent>,
    /// Scheduled drain windows.
    pub drains: Vec<DrainWindow>,
}

/// Salt folded into the run seed for the fault RNG, so the fault stream
/// never aliases the cost-model stream (both start from the same seed).
const FAULT_SEED_SALT: u64 = 0xFA11_5EED_D0E5_0B57;

impl FaultSpec {
    /// Whether this spec injects anything at all (an inactive spec leaves
    /// the event stream byte-identical to a fault-free run).
    pub fn is_active(&self) -> bool {
        self.mtbf > 0.0 || !self.scripted.is_empty() || !self.drains.is_empty()
    }

    /// The dedicated fault RNG for a run seed.
    pub fn rng(&self, seed: u64) -> Rng {
        Rng::new(seed ^ FAULT_SEED_SALT)
    }

    /// First failure time per node (one exponential draw each, in node-id
    /// order).  Empty when MTBF sampling is off.
    pub fn initial_failures(&self, nodes: usize, rng: &mut Rng) -> Vec<(NodeId, Time)> {
        if self.mtbf <= 0.0 {
            return Vec::new();
        }
        (0..nodes).map(|n| (n, rng.exp(self.mtbf))).collect()
    }

    /// Repair delay and next-failure delay for one failure cycle (drawn in
    /// that order, exactly once per processed auto-failure).
    pub fn next_cycle(&self, rng: &mut Rng) -> (Time, Time) {
        let repair = rng.exp(self.mttr.max(0.0));
        let next_fail = rng.exp(self.mtbf.max(0.0));
        (repair, next_fail)
    }
}

// ---------------------------------------------------------------------
// Shard-level failure domains: correlated outages and partitions.

/// A named node group inside one shard that fails *together* (a rack, a
/// switch, a sub-cluster).  The whole shard is always an implicit domain;
/// explicit domains model finer-grained correlated blast radii.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureDomain {
    /// Domain name, referenced by scripted [`OutageEvent`]s.
    pub name: String,
    /// The member nodes (resolved against the shard size like drains).
    pub nodes: DrainSet,
}

/// One scripted correlated outage: the named domain (or, with an empty
/// name, the whole shard) goes dark at `at` and returns `duration` later.
#[derive(Debug, Clone, PartialEq)]
pub struct OutageEvent {
    /// Target domain name; `""`, `"shard"` or `"all"` means the implicit
    /// whole-shard domain.
    pub domain: String,
    /// Outage start.
    pub at: Time,
    /// Outage length (`for` in the TOML schema).
    pub duration: Time,
}

/// A network partition window: the shard keeps running its local jobs but
/// is unreachable for routing and stealing between `start` and `end`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartitionWindow {
    /// Partition start.
    pub start: Time,
    /// Partition end (recovery).
    pub end: Time,
}

/// The correlated-outage sources of one shard: scripted outage/partition
/// traces plus an optional seeded per-domain MTBF stream.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OutageSpec {
    /// Explicit failure domains.  Empty means the only domain is the
    /// implicit whole shard.
    pub domains: Vec<FailureDomain>,
    /// Scripted outages, replayed verbatim.
    pub scripted: Vec<OutageEvent>,
    /// Mean time between correlated outages *per domain*, seconds
    /// (exponential).  `0` disables the seeded stream.
    pub mtbf: f64,
    /// Mean outage duration, seconds (exponential).
    pub mttr: f64,
    /// Scripted partition windows.
    pub partitions: Vec<PartitionWindow>,
}

/// Salt for the domain-outage RNG stream: distinct from both the cost
/// stream (no salt) and the per-node fault stream ([`FAULT_SEED_SALT`]),
/// so enabling outages never perturbs either — and an outage-free run is
/// byte-identical whether the stream exists or not.
const DOMAIN_SEED_SALT: u64 = 0xD07A_60E5_DA2C_5EED;

impl OutageSpec {
    /// Whether this spec injects anything (an inactive spec leaves the
    /// event stream byte-identical to an outage-free run).
    pub fn is_active(&self) -> bool {
        self.mtbf > 0.0 || !self.scripted.is_empty() || !self.partitions.is_empty()
    }

    /// The dedicated domain-outage RNG for a (shard-salted) run seed.
    pub fn rng(&self, seed: u64) -> Rng {
        Rng::new(seed ^ DOMAIN_SEED_SALT)
    }

    /// First outage time per sampled domain (one exponential draw each,
    /// in domain order).  `domains` is the number of sampled domains —
    /// the explicit domain count, or 1 (the whole shard) when none are
    /// declared.  Empty when MTBF sampling is off.
    pub fn initial_outages(&self, domains: usize, rng: &mut Rng) -> Vec<(usize, Time)> {
        if self.mtbf <= 0.0 {
            return Vec::new();
        }
        (0..domains).map(|d| (d, rng.exp(self.mtbf))).collect()
    }

    /// Outage duration and next-outage delay for one cycle (drawn in that
    /// order, exactly once per processed auto-outage).
    pub fn next_cycle(&self, rng: &mut Rng) -> (Time, Time) {
        let duration = rng.exp(self.mttr.max(0.0));
        let next = rng.exp(self.mtbf.max(0.0));
        (duration, next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_by_default() {
        let f = FaultSpec::default();
        assert!(!f.is_active());
        assert!(f.initial_failures(8, &mut f.rng(1)).is_empty());
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let f = FaultSpec { mtbf: 1000.0, mttr: 100.0, ..Default::default() };
        assert!(f.is_active());
        let draw = |seed| {
            let mut rng = f.rng(seed);
            let init = f.initial_failures(16, &mut rng);
            let cycle = f.next_cycle(&mut rng);
            (init, cycle)
        };
        assert_eq!(draw(7), draw(7), "same seed, same timeline");
        assert_ne!(draw(7).0, draw(8).0, "different seeds differ");
    }

    #[test]
    fn fault_stream_is_independent_of_cost_stream() {
        // Same base seed must not produce the same first draw in both
        // streams (the salt keeps them apart).
        let f = FaultSpec { mtbf: 1.0, ..Default::default() };
        let a = f.rng(42).next_u64();
        let b = Rng::new(42).next_u64();
        assert_ne!(a, b);
    }

    #[test]
    fn drain_sets_resolve() {
        assert_eq!(DrainSet::Count(3).node_ids(8), vec![0, 1, 2]);
        assert_eq!(DrainSet::Count(9).node_ids(4), vec![0, 1, 2, 3], "clamped to machine");
        assert_eq!(DrainSet::Nodes(vec![5, 2, 9]).node_ids(8), vec![5, 2]);
    }

    #[test]
    fn initial_failures_cover_every_node_in_order() {
        let f = FaultSpec { mtbf: 500.0, mttr: 50.0, ..Default::default() };
        let init = f.initial_failures(5, &mut f.rng(3));
        let ids: Vec<usize> = init.iter().map(|&(n, _)| n).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(init.iter().all(|&(_, t)| t >= 0.0));
    }

    #[test]
    fn outage_spec_inactive_by_default() {
        let o = OutageSpec::default();
        assert!(!o.is_active());
        assert!(o.initial_outages(4, &mut o.rng(1)).is_empty());
    }

    #[test]
    fn outage_spec_activity_flags() {
        let scripted = OutageSpec {
            scripted: vec![OutageEvent { domain: String::new(), at: 100.0, duration: 50.0 }],
            ..Default::default()
        };
        assert!(scripted.is_active());
        let sampled = OutageSpec { mtbf: 1000.0, mttr: 100.0, ..Default::default() };
        assert!(sampled.is_active());
        let partitioned = OutageSpec {
            partitions: vec![PartitionWindow { start: 10.0, end: 20.0 }],
            ..Default::default()
        };
        assert!(partitioned.is_active());
    }

    #[test]
    fn outage_stream_is_independent_of_fault_and_cost_streams() {
        let o = OutageSpec { mtbf: 1.0, ..Default::default() };
        let f = FaultSpec { mtbf: 1.0, ..Default::default() };
        let a = o.rng(42).next_u64();
        assert_ne!(a, f.rng(42).next_u64(), "distinct from the node-fault stream");
        assert_ne!(a, Rng::new(42).next_u64(), "distinct from the cost stream");
    }

    #[test]
    fn outage_sampling_is_deterministic_per_seed() {
        let o = OutageSpec { mtbf: 5000.0, mttr: 500.0, ..Default::default() };
        let draw = |seed| {
            let mut rng = o.rng(seed);
            let init = o.initial_outages(3, &mut rng);
            let cycle = o.next_cycle(&mut rng);
            (init, cycle)
        };
        assert_eq!(draw(7), draw(7), "same seed, same outage timeline");
        assert_ne!(draw(7).0, draw(8).0, "different seeds differ");
    }
}
