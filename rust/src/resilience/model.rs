//! Deterministic fault sources: MTBF/MTTR sampling, scripted fault
//! traces and scheduled drain windows.
//!
//! Replay contract: all random failure times are drawn from a *dedicated*
//! RNG stream (seeded from the run seed, salted — see
//! [`FaultSpec::rng`]), pre-seeded per node in node order and then
//! advanced only when fault events are processed.  Because repair and
//! next-failure delays depend only on previous draws, the machine
//! timeline is a pure function of (spec, seed): bit-identical across
//! reruns and identical between the rigid and malleable runs of one
//! scenario — the "same fault trace" the acceptance comparison needs.

use crate::util::rng::Rng;
use crate::{NodeId, Time};

/// One scripted machine event (`fail node=3 at t=500, repair at t=2000`
/// becomes a `Fail` and a `Repair` entry).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultTraceEvent {
    /// When the event fires.
    pub at: Time,
    /// The affected node.
    pub node: NodeId,
    /// Failure or repair.
    pub kind: FaultKind,
}

/// What a scripted machine event does to its node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The node goes down.
    Fail,
    /// The node is repaired.
    Repair,
}

/// Which nodes a drain window takes offline.
#[derive(Debug, Clone, PartialEq)]
pub enum DrainSet {
    /// The first `n` node ids (`0..n`) — the nodes the deterministic
    /// allocator prefers, so a count-drain is maximally disruptive.
    Count(usize),
    /// An explicit node list.
    Nodes(Vec<NodeId>),
}

impl DrainSet {
    /// Resolve to concrete node ids on a `total`-node machine.
    pub fn node_ids(&self, total: usize) -> Vec<NodeId> {
        match self {
            DrainSet::Count(n) => (0..(*n).min(total)).collect(),
            DrainSet::Nodes(v) => v.iter().copied().filter(|&n| n < total).collect(),
        }
    }
}

/// A scheduled maintenance window: the nodes stop accepting work at
/// `start` (idle nodes go offline immediately; allocated nodes finish
/// their current job first) and return at `end`.
#[derive(Debug, Clone, PartialEq)]
pub struct DrainWindow {
    /// Window start.
    pub start: Time,
    /// Window end.
    pub end: Time,
    /// The drained nodes.
    pub nodes: DrainSet,
}

/// The fault sources of one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSpec {
    /// Mean time between failures *per node*, seconds (exponential).
    /// `0` disables random failures.
    pub mtbf: f64,
    /// Mean time to repair a failed node, seconds (exponential).
    pub mttr: f64,
    /// Scripted machine events, replayed verbatim.
    pub scripted: Vec<FaultTraceEvent>,
    /// Scheduled drain windows.
    pub drains: Vec<DrainWindow>,
}

/// Salt folded into the run seed for the fault RNG, so the fault stream
/// never aliases the cost-model stream (both start from the same seed).
const FAULT_SEED_SALT: u64 = 0xFA11_5EED_D0E5_0B57;

impl FaultSpec {
    /// Whether this spec injects anything at all (an inactive spec leaves
    /// the event stream byte-identical to a fault-free run).
    pub fn is_active(&self) -> bool {
        self.mtbf > 0.0 || !self.scripted.is_empty() || !self.drains.is_empty()
    }

    /// The dedicated fault RNG for a run seed.
    pub fn rng(&self, seed: u64) -> Rng {
        Rng::new(seed ^ FAULT_SEED_SALT)
    }

    /// First failure time per node (one exponential draw each, in node-id
    /// order).  Empty when MTBF sampling is off.
    pub fn initial_failures(&self, nodes: usize, rng: &mut Rng) -> Vec<(NodeId, Time)> {
        if self.mtbf <= 0.0 {
            return Vec::new();
        }
        (0..nodes).map(|n| (n, rng.exp(self.mtbf))).collect()
    }

    /// Repair delay and next-failure delay for one failure cycle (drawn in
    /// that order, exactly once per processed auto-failure).
    pub fn next_cycle(&self, rng: &mut Rng) -> (Time, Time) {
        let repair = rng.exp(self.mttr.max(0.0));
        let next_fail = rng.exp(self.mtbf.max(0.0));
        (repair, next_fail)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_by_default() {
        let f = FaultSpec::default();
        assert!(!f.is_active());
        assert!(f.initial_failures(8, &mut f.rng(1)).is_empty());
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let f = FaultSpec { mtbf: 1000.0, mttr: 100.0, ..Default::default() };
        assert!(f.is_active());
        let draw = |seed| {
            let mut rng = f.rng(seed);
            let init = f.initial_failures(16, &mut rng);
            let cycle = f.next_cycle(&mut rng);
            (init, cycle)
        };
        assert_eq!(draw(7), draw(7), "same seed, same timeline");
        assert_ne!(draw(7).0, draw(8).0, "different seeds differ");
    }

    #[test]
    fn fault_stream_is_independent_of_cost_stream() {
        // Same base seed must not produce the same first draw in both
        // streams (the salt keeps them apart).
        let f = FaultSpec { mtbf: 1.0, ..Default::default() };
        let a = f.rng(42).next_u64();
        let b = Rng::new(42).next_u64();
        assert_ne!(a, b);
    }

    #[test]
    fn drain_sets_resolve() {
        assert_eq!(DrainSet::Count(3).node_ids(8), vec![0, 1, 2]);
        assert_eq!(DrainSet::Count(9).node_ids(4), vec![0, 1, 2, 3], "clamped to machine");
        assert_eq!(DrainSet::Nodes(vec![5, 2, 9]).node_ids(8), vec![5, 2]);
    }

    #[test]
    fn initial_failures_cover_every_node_in_order() {
        let f = FaultSpec { mtbf: 500.0, mttr: 50.0, ..Default::default() };
        let init = f.initial_failures(5, &mut f.rng(3));
        let ids: Vec<usize> = init.iter().map(|&(n, _)| n).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(init.iter().all(|&(_, t)| t >= 0.0));
    }
}
