//! Malleability-aware recovery: what happens to a running job when one of
//! its nodes fails.
//!
//! * Every interrupted job first rolls back to its last checkpoint
//!   ([`rework_lost`]): with a checkpoint interval `C`, the work done
//!   since the most recent multiple of `C` seconds of *execution* time is
//!   redone; `C == 0` models no checkpointing (restart from scratch).
//! * A **malleable** job then attempts a DMR shrink onto its surviving
//!   nodes ([`feasible_shrink`]): the largest factor-chain size that fits
//!   the survivors, honoring the job's resize factor and minimum — the
//!   same chain rules as [`crate::rms::policy::shrink_target`].  Only the
//!   redistribution/scheduling cost is paid; the job keeps its nodes and
//!   its checkpointed progress.
//! * A **rigid** job (or a malleable one with no factor-reachable fit) is
//!   killed and requeued; it restarts from the checkpoint once the
//!   scheduler finds room again.

use crate::rms::policy::{factor_reachable, shrink_target};

/// Checkpoint/rework model knobs.
#[derive(Debug, Clone)]
pub struct RecoveryConfig {
    /// Seconds of execution between checkpoints; `0` = no checkpointing
    /// (an interrupted job loses all progress).
    pub checkpoint_interval: f64,
    /// Attempt the malleable shrink rescue (ablatable; `false` forces
    /// every interrupted job through kill + requeue).
    pub rescue: bool,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig { checkpoint_interval: 600.0, rescue: true }
    }
}

/// Closed-form reference of the rework model: execution time lost to a
/// failure is the progress since the last checkpoint.  `run_time` is the
/// job's accumulated execution time.  The engine tracks checkpoint
/// boundaries incrementally instead (recording the iterations held at
/// each boundary, which stays exact when resizes change the iteration
/// rate mid-interval); this form matches it whenever the rate was
/// constant since the last checkpoint and anchors the model's unit
/// tests.
pub fn rework_lost(run_time: f64, checkpoint_interval: f64) -> f64 {
    if checkpoint_interval > 0.0 {
        run_time % checkpoint_interval
    } else {
        run_time
    }
}

/// Largest factor-chain size reachable by shrinking from `current` that
/// fits on `survivors` nodes and stays at or above `min_procs`.  `None`
/// when no reachable size fits (the job must requeue).  `current <=
/// survivors` (nothing lost below the current size — e.g. a failure that
/// only ate uncommitted expansion nodes) keeps the current size.
pub fn feasible_shrink(
    current: usize,
    survivors: usize,
    factor: usize,
    min_procs: usize,
) -> Option<usize> {
    if survivors == 0 || current == 0 {
        return None;
    }
    if current <= survivors {
        return (current >= min_procs).then_some(current);
    }
    if factor < 2 {
        // Degenerate chain: any size is reachable.
        return (survivors >= min_procs).then_some(survivors);
    }
    // Walk down the chain from `current`; `deepest` is where it ends
    // (indivisible size or the min_procs floor).
    let deepest = shrink_target(current, factor, min_procs);
    let mut to = current;
    while to > survivors {
        if to == deepest {
            return None; // chain exhausted above the survivor count
        }
        to /= factor;
    }
    debug_assert!(factor_reachable(current, to, factor));
    (to >= min_procs).then_some(to)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rework_follows_checkpoint_grid() {
        assert_eq!(rework_lost(1000.0, 600.0), 400.0);
        assert_eq!(rework_lost(599.0, 600.0), 599.0);
        assert_eq!(rework_lost(1200.0, 600.0), 0.0, "failure right at a checkpoint");
        assert_eq!(rework_lost(1000.0, 0.0), 1000.0, "no checkpointing loses everything");
        assert_eq!(rework_lost(0.0, 600.0), 0.0);
    }

    #[test]
    fn shrink_rescue_walks_the_chain() {
        // 32 procs, one node lost: 31 survivors -> 16.
        assert_eq!(feasible_shrink(32, 31, 2, 2), Some(16));
        // exactly-fitting survivor count keeps the chain step
        assert_eq!(feasible_shrink(32, 16, 2, 2), Some(16));
        assert_eq!(feasible_shrink(32, 15, 2, 2), Some(8));
        // min_procs floors the walk
        assert_eq!(feasible_shrink(8, 7, 2, 4), Some(4));
        assert_eq!(feasible_shrink(8, 3, 2, 4), None, "4 does not fit 3 survivors");
        // at the floor already: nothing reachable below
        assert_eq!(feasible_shrink(2, 1, 2, 2), None);
        // off-chain current sizes stop where the chain ends
        assert_eq!(feasible_shrink(6, 5, 2, 1), Some(3));
        assert_eq!(feasible_shrink(7, 6, 2, 1), None, "7 is indivisible by 2");
    }

    #[test]
    fn shrink_rescue_edges() {
        assert_eq!(feasible_shrink(16, 0, 2, 1), None, "no survivors");
        // mid-expand failure: survivors can exceed the committed size
        assert_eq!(feasible_shrink(16, 20, 2, 1), Some(16));
        // factor 1: any size reachable, land on the survivors
        assert_eq!(feasible_shrink(10, 7, 1, 2), Some(7));
        assert_eq!(feasible_shrink(10, 1, 1, 2), None, "below min");
    }
}
