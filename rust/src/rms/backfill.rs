//! EASY backfill over node *counts* (§7.2: "Slurm was configured with the
//! backfill job scheduling policy").
//!
//! The head of the priority queue gets a reservation at the earliest time
//! enough nodes will be free (projected from running jobs' expected ends);
//! later jobs may start out of order only if they do not delay that
//! reservation: either they finish before the shadow time, or they use
//! only nodes the head will not need ("extra" nodes).

use crate::Time;

/// A running job as seen by the backfill projection.
#[derive(Debug, Clone, Copy)]
pub struct RunningInfo {
    /// Nodes the job currently holds.
    pub procs: usize,
    /// Scheduler's estimate of when those nodes free up.
    pub expected_end: Time,
}

/// A pending job as seen by the scheduler pass.
#[derive(Debug, Clone, Copy)]
pub struct PendingInfo {
    /// Job id (returned in the start list).
    pub id: crate::JobId,
    /// Nodes the job needs to start.
    pub procs: usize,
    /// Runtime estimate used for the shadow-time check.
    pub est_duration: f64,
}

/// The availability projection a scheduling pass consults: "given
/// `free_now` free nodes, when are at least `need` projected free, and
/// how many then?"  Two implementations exist:
///
/// * [`SortedEnds`] — the reference: snapshot every running job's end
///   and sort, O(R log R) per query (the pre-profile behavior, kept
///   alive behind `RmsConfig::incremental_profile = false`).
/// * [`crate::rms::profile::ProfileShadow`] — an in-order walk of the
///   incrementally maintained availability profile, no snapshot, no
///   sort.
///
/// Both must return bit-identical answers; the golden determinism tests
/// compare them end-to-end.
pub trait ShadowSource {
    /// Earliest projected time at least `need` nodes are free, and the
    /// projected free count at that instant.
    fn shadow(&mut self, free_now: usize, need: usize, now: Time) -> (Time, usize);
}

/// The reference [`ShadowSource`]: sorts a snapshot of the running
/// jobs' expected ends on every query.
pub struct SortedEnds<'a> {
    /// Running jobs in ascending-id order (the RMS's active-set order).
    pub running: &'a [RunningInfo],
    /// Reusable sort buffer.
    pub scratch: &'a mut Vec<(Time, usize)>,
}

impl ShadowSource for SortedEnds<'_> {
    fn shadow(&mut self, free_now: usize, need: usize, now: Time) -> (Time, usize) {
        shadow_time_with(self.scratch, free_now, self.running, need, now)
    }
}

/// Decide which pending jobs (already priority-ordered) start *now*.
///
/// Returns the ids to start, in order.  Pure function — the RMS applies
/// the allocations afterwards.  Convenience wrapper over
/// [`plan_starts_into`] that allocates fresh buffers; the RMS hot path
/// keeps reusable scratch buffers instead.
pub fn plan_starts(
    free: usize,
    running: &[RunningInfo],
    pending_ordered: &[PendingInfo],
    now: Time,
    backfill: bool,
) -> Vec<crate::JobId> {
    let mut starts = Vec::new();
    let mut ends_scratch = Vec::new();
    plan_starts_into(free, running, pending_ordered, now, backfill, &mut ends_scratch, &mut starts);
    starts
}

/// Allocation-free scheduling pass over the reference projection:
/// `starts` is cleared and filled with the ids to start (in order);
/// `ends_scratch` is the reusable sorted-ends buffer for the
/// shadow-time projection, so a pass costs no heap allocations once the
/// buffers have grown to steady-state size.
pub fn plan_starts_into(
    free: usize,
    running: &[RunningInfo],
    pending_ordered: &[PendingInfo],
    now: Time,
    backfill: bool,
    ends_scratch: &mut Vec<(Time, usize)>,
    starts: &mut Vec<crate::JobId>,
) {
    let mut src = SortedEnds { running, scratch: ends_scratch };
    plan_starts_with(free, &mut src, pending_ordered, now, backfill, starts);
}

/// The scheduling pass, generic over the availability projection: start
/// in priority order until the head-of-line blocker, reserve the
/// blocker's shadow time from `shadow`, then backfill jobs that do not
/// delay the reservation.  The projection is queried at most **once**
/// per pass (only a blocked head needs it).
pub fn plan_starts_with<S: ShadowSource>(
    mut free: usize,
    shadow_src: &mut S,
    pending_ordered: &[PendingInfo],
    now: Time,
    backfill: bool,
    starts: &mut Vec<crate::JobId>,
) {
    starts.clear();
    // Start in priority order until the first job that does not fit; that
    // head-of-line blocker gets a reservation at its shadow time.
    let mut blocked: Option<(Time, usize)> = None; // (shadow, extra)
    let mut blocked_at = pending_ordered.len();
    for (i, p) in pending_ordered.iter().enumerate() {
        if p.procs <= free {
            free -= p.procs;
            starts.push(p.id);
        } else {
            let (shadow, free_at_shadow) = shadow_src.shadow(free, p.procs, now);
            blocked = Some((shadow, free_at_shadow.saturating_sub(p.procs)));
            blocked_at = i;
            break;
        }
    }

    if !backfill {
        return;
    }

    if let Some((shadow, mut extra)) = blocked {
        // Jobs behind the blocker may start out of order only if they do
        // not delay its reservation.
        for p in &pending_ordered[blocked_at + 1..] {
            if p.procs > free {
                continue;
            }
            let finishes_before_shadow = now + p.est_duration <= shadow;
            let fits_in_extra = p.procs <= extra;
            if finishes_before_shadow || fits_in_extra {
                free -= p.procs;
                if !finishes_before_shadow {
                    extra -= p.procs;
                }
                starts.push(p.id);
            }
        }
    }
}

/// Earliest time at least `need` nodes are projected free, and how many
/// will be free then.  `ends` is a reusable scratch buffer.
///
/// The sort is a *stable* `total_cmp` on the end time: ties keep the
/// caller's ascending-id order (matching the profile's `(end, id)` key
/// order), and a NaN estimate sorts last instead of panicking the
/// scheduler as `partial_cmp().unwrap()` used to.
fn shadow_time_with(
    ends: &mut Vec<(Time, usize)>,
    free_now: usize,
    running: &[RunningInfo],
    need: usize,
    now: Time,
) -> (Time, usize) {
    if free_now >= need {
        return (now, free_now);
    }
    ends.clear();
    ends.extend(running.iter().map(|r| (r.expected_end, r.procs)));
    ends.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut free = free_now;
    for &(t, p) in ends.iter() {
        free += p;
        if free >= need {
            return (t.max(now), free);
        }
    }
    (Time::INFINITY, free)
}

#[cfg(test)]
fn shadow_time(free_now: usize, running: &[RunningInfo], need: usize, now: Time) -> (Time, usize) {
    shadow_time_with(&mut Vec::new(), free_now, running, need, now)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(id: u64, procs: usize, est: f64) -> PendingInfo {
        PendingInfo { id, procs, est_duration: est }
    }

    #[test]
    fn starts_in_priority_order_until_blocked() {
        let starts = plan_starts(10, &[], &[p(1, 4, 10.0), p(2, 4, 10.0), p(3, 4, 10.0)], 0.0, true);
        // 1 and 2 fit (8 <= 10); 3 blocks (needs 4, free 2); nothing to
        // backfill behind it.
        assert_eq!(starts, vec![1, 2]);
    }

    #[test]
    fn backfill_short_job_before_shadow() {
        // 8 nodes total: 6 busy until t=100, 2 free. Head needs 8.
        let running = [RunningInfo { procs: 6, expected_end: 100.0 }];
        // Job 2 is small and short: fits the 2 free nodes and ends before
        // the shadow (t=100).
        let starts = plan_starts(
            2,
            &running,
            &[p(1, 8, 50.0), p(2, 2, 50.0)],
            0.0,
            true,
        );
        assert_eq!(starts, vec![2]);
    }

    #[test]
    fn backfill_respects_reservation() {
        // Job 2 is long (would end after shadow) and would consume nodes
        // the head needs => must NOT start.
        let running = [RunningInfo { procs: 6, expected_end: 100.0 }];
        let starts = plan_starts(
            2,
            &running,
            &[p(1, 8, 50.0), p(2, 2, 500.0)],
            0.0,
            true,
        );
        assert!(starts.is_empty());
    }

    #[test]
    fn backfill_long_job_in_extra_nodes() {
        // 10 total: 6 busy until 100, 4 free; head needs 8 => shadow=100,
        // free_at_shadow=10, extra=2. A long 2-node job can run on the
        // extra nodes without delaying the head.
        let running = [RunningInfo { procs: 6, expected_end: 100.0 }];
        let starts = plan_starts(
            4,
            &running,
            &[p(1, 8, 50.0), p(2, 2, 500.0)],
            0.0,
            true,
        );
        assert_eq!(starts, vec![2]);
    }

    #[test]
    fn no_backfill_mode_blocks_strictly() {
        let running = [RunningInfo { procs: 6, expected_end: 100.0 }];
        let starts = plan_starts(
            2,
            &running,
            &[p(1, 8, 50.0), p(2, 2, 10.0)],
            0.0,
            false,
        );
        assert!(starts.is_empty());
    }

    #[test]
    fn shadow_infinite_when_never_enough() {
        let (t, _) = shadow_time(1, &[], 4, 0.0);
        assert!(t.is_infinite());
    }

    #[test]
    fn into_variant_matches_with_dirty_buffers() {
        // Pre-polluted scratch buffers must not leak into the result.
        let running = [
            RunningInfo { procs: 6, expected_end: 100.0 },
            RunningInfo { procs: 2, expected_end: 40.0 },
        ];
        let pending = [p(1, 8, 50.0), p(2, 2, 30.0), p(3, 2, 500.0)];
        let want = plan_starts(4, &running, &pending, 0.0, true);
        let mut ends = vec![(999.0, 77); 5];
        let mut starts = vec![42, 43];
        plan_starts_into(4, &running, &pending, 0.0, true, &mut ends, &mut starts);
        assert_eq!(starts, want);
        // and again, reusing the now-dirty buffers
        plan_starts_into(4, &running, &pending, 0.0, true, &mut ends, &mut starts);
        assert_eq!(starts, want);
    }
}
