//! Incrementally maintained cluster-availability profile.
//!
//! The EASY-backfill shadow-time projection needs the running jobs'
//! expected end times in ascending order.  The original implementation
//! rebuilt that view on every scheduling pass: snapshot all R active
//! jobs into a scratch vector, then `extend` + `sort` the ends list —
//! O(R log R) per pass even when nothing changed since the last one.
//! Production schedulers keep an *availability profile* instead (the
//! slot structures of the EASY/Feitelson parallel-workload line): a
//! sorted end-time structure updated in O(log R) on every job start,
//! finish, resize, failure and requeue, so a pass walks it in order and
//! never sorts.
//!
//! [`AvailProfile`] is that structure.  The RMS owns one and publishes a
//! delta at every mutation site ([`crate::rms::Rms`] start/finish/
//! cancel/expand/shrink/rescue/requeue/failure paths); the scheduling
//! pass consumes it through [`ProfileShadow`], an impl of
//! [`super::backfill::ShadowSource`].
//!
//! ## Ordering contract
//!
//! The reference path iterates active jobs in ascending-id order and
//! stable-sorts by expected end ([`f64::total_cmp`]), so ties on the
//! end time keep ascending job ids.  The profile's B-tree is keyed by
//! `(time_key(end), JobId)` where [`time_key`] is the order-preserving
//! bit encoding of `f64::total_cmp` — an in-order walk therefore visits
//! exactly the sequence the reference sort produces, and the two paths
//! return bit-identical shadow times (locked by the randomized
//! differential test in `rust/tests/test_profile.rs` and the golden
//! digests in `rust/tests/test_golden_determinism.rs`).
//!
//! Jobs whose end is *unknown* (no `expected_end` yet — never the case
//! under the DES drivers, which estimate on arrival) are carried with
//! their duration estimate; a shadow query then falls back to the
//! reference rebuild so behavior cannot diverge, it is only the fast
//! walk that requires every end to be known.

use std::collections::BTreeMap;

use super::backfill::ShadowSource;
use crate::{JobId, Time};

/// Order-preserving integer encoding of an `f64` under
/// [`f64::total_cmp`]: `key(a) < key(b)` iff `a.total_cmp(&b)` is
/// `Less`.  Lets the B-tree key on times without wrapping floats in an
/// `Ord` newtype.
pub fn time_key(t: Time) -> u64 {
    let bits = t.to_bits() as i64;
    // Same transform `f64::total_cmp` applies before its integer
    // compare, shifted into unsigned order by flipping the sign bit.
    let key = bits ^ (((bits >> 63) as u64) >> 1) as i64;
    (key as u64) ^ (1u64 << 63)
}

/// One active job as tracked by the profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProfileEntry {
    /// Scheduler's end estimate, if known (`None` keeps the job on the
    /// reference fallback path — the DES drivers always know).
    pub end: Option<Time>,
    /// Nodes the job currently holds.
    pub procs: usize,
    /// Static duration estimate used when `end` is unknown
    /// (`now + est`, exactly like the reference snapshot).
    pub est: f64,
}

/// The incrementally maintained availability profile: every active job,
/// indexed both by id (for O(log R) updates) and by projected end time
/// (for the in-order shadow walk).
#[derive(Debug, Default, Clone)]
pub struct AvailProfile {
    /// `(end-time key, job id) -> (end, procs)`, ascending by end then
    /// id — the walk order of the shadow projection.  Holds exactly the
    /// jobs whose end is known.
    ends: BTreeMap<(u64, JobId), (Time, usize)>,
    /// Every active job, by id.
    jobs: BTreeMap<JobId, ProfileEntry>,
    /// Bumped on every mutation; the RMS folds it into the state stamp
    /// that drives no-op pass elision.
    version: u64,
}

impl AvailProfile {
    /// Active jobs tracked.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    /// No active jobs tracked.
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Monotonic mutation counter.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// The tracked entry for `id`, if any.
    pub fn entry(&self, id: JobId) -> Option<&ProfileEntry> {
        self.jobs.get(&id)
    }

    /// Track a job that just became active.  O(log R).
    pub fn insert(&mut self, id: JobId, procs: usize, end: Option<Time>, est: f64) {
        self.version += 1;
        if let Some(t) = end {
            self.ends.insert((time_key(t), id), (t, procs));
        }
        let prev = self.jobs.insert(id, ProfileEntry { end, procs, est });
        debug_assert!(prev.is_none(), "profile: job {id} inserted twice");
    }

    /// Stop tracking a job (finished, cancelled, requeued).  O(log R);
    /// a no-op for untracked ids.
    pub fn remove(&mut self, id: JobId) {
        if let Some(e) = self.jobs.remove(&id) {
            self.version += 1;
            if let Some(t) = e.end {
                self.ends.remove(&(time_key(t), id));
            }
        }
    }

    /// Publish a node-count change (resize commit, expansion transfer,
    /// failure eviction, rescue shrink).  O(log R).
    pub fn set_procs(&mut self, id: JobId, procs: usize) {
        let Some(e) = self.jobs.get_mut(&id) else {
            debug_assert!(false, "profile: set_procs on untracked job {id}");
            return;
        };
        self.version += 1;
        e.procs = procs;
        if let Some(t) = e.end {
            self.ends.insert((time_key(t), id), (t, procs));
        }
    }

    /// Publish a new end estimate.  O(log R).
    pub fn set_end(&mut self, id: JobId, end: Time) {
        let Some(e) = self.jobs.get_mut(&id) else {
            debug_assert!(false, "profile: set_end on untracked job {id}");
            return;
        };
        self.version += 1;
        if let Some(old) = e.end {
            self.ends.remove(&(time_key(old), id));
        }
        e.end = Some(end);
        self.ends.insert((time_key(end), id), (end, e.procs));
    }

    /// Earliest projected time at least `need` nodes are free (given
    /// `free_now` free right now) and how many are projected free then —
    /// the shadow-time query of the EASY reservation.
    ///
    /// Fast path (every end known): an in-order walk of the B-tree, no
    /// snapshot, no sort — O(k) for the k ends visited before the
    /// crossing.  Fallback (some end unknown): rebuilds `(end, procs)`
    /// exactly like the reference snapshot and sorts, so results stay
    /// bit-identical to the rebuild path in every case.
    pub fn shadow(
        &self,
        free_now: usize,
        need: usize,
        now: Time,
        scratch: &mut Vec<(Time, usize)>,
    ) -> (Time, usize) {
        if free_now >= need {
            return (now, free_now);
        }
        let mut free = free_now;
        if self.ends.len() == self.jobs.len() {
            for &(t, p) in self.ends.values() {
                free += p;
                if free >= need {
                    return (t.max(now), free);
                }
            }
            return (Time::INFINITY, free);
        }
        // Some job has no known end: reproduce the reference snapshot
        // (ascending-id iteration, stable sort by end).
        scratch.clear();
        scratch.extend(self.jobs.values().map(|e| (e.end.unwrap_or(now + e.est), e.procs)));
        scratch.sort_by(|a, b| a.0.total_cmp(&b.0));
        for &(t, p) in scratch.iter() {
            free += p;
            if free >= need {
                return (t.max(now), free);
            }
        }
        (Time::INFINITY, free)
    }

    /// Internal consistency: the two indices describe the same set.
    /// Deliberately O(R log R) — property-test only.
    pub fn check_invariants(&self) -> bool {
        let known = self.jobs.iter().filter(|(_, e)| e.end.is_some()).count();
        if known != self.ends.len() {
            return false;
        }
        self.ends.iter().all(|(&(k, id), &(t, procs))| {
            k == time_key(t)
                && self.jobs.get(&id).is_some_and(|e| e.end == Some(t) && e.procs == procs)
        })
    }
}

/// Borrow of the profile (plus the fallback scratch buffer) that plugs
/// into [`super::backfill::plan_starts_with`] as the availability
/// projection of a scheduling pass.
pub struct ProfileShadow<'a> {
    /// The RMS-owned profile.
    pub profile: &'a AvailProfile,
    /// Reusable fallback buffer (untouched on the fast path).
    pub scratch: &'a mut Vec<(Time, usize)>,
}

impl ShadowSource for ProfileShadow<'_> {
    fn shadow(&mut self, free_now: usize, need: usize, now: Time) -> (Time, usize) {
        self.profile.shadow(free_now, need, now, self.scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_key_matches_total_cmp() {
        let samples = [
            f64::NEG_INFINITY,
            -1e300,
            -2.5,
            -0.0,
            0.0,
            1e-9,
            1.0,
            2.5,
            1e300,
            f64::INFINITY,
            f64::NAN,
            -f64::NAN,
        ];
        for &a in &samples {
            for &b in &samples {
                assert_eq!(
                    time_key(a).cmp(&time_key(b)),
                    a.total_cmp(&b),
                    "key order diverges from total_cmp for ({a}, {b})"
                );
            }
        }
    }

    #[test]
    fn insert_walk_remove() {
        let mut p = AvailProfile::default();
        p.insert(3, 4, Some(100.0), 50.0);
        p.insert(1, 2, Some(50.0), 50.0);
        p.insert(2, 8, Some(100.0), 50.0);
        // Walk order: t=50 first, then the t=100 tie in id order (2, 3).
        let mut scratch = Vec::new();
        // need 3: free 1 + job1's 2 = 3 at t=50
        assert_eq!(p.shadow(1, 3, 0.0, &mut scratch), (50.0, 3));
        // need 11: 1 + 2 + 8 = 11 at the first t=100 entry (job 2)
        assert_eq!(p.shadow(1, 11, 0.0, &mut scratch), (100.0, 11));
        // need 16: exhausted -> infinity
        let (t, f) = p.shadow(1, 16, 0.0, &mut scratch);
        assert!(t.is_infinite());
        assert_eq!(f, 15);
        // free already sufficient short-circuits at `now`
        assert_eq!(p.shadow(5, 3, 7.0, &mut scratch), (7.0, 5));
        assert!(p.check_invariants());

        p.remove(2);
        assert_eq!(p.len(), 2);
        let (t, f) = p.shadow(1, 7, 0.0, &mut scratch);
        assert_eq!((t, f), (100.0, 7));
        p.remove(42); // unknown id: no-op
        assert_eq!(p.len(), 2);
        assert!(p.check_invariants());
    }

    #[test]
    fn set_procs_and_end_move_entries() {
        let mut p = AvailProfile::default();
        p.insert(1, 4, Some(10.0), 5.0);
        p.set_procs(1, 2);
        assert_eq!(p.entry(1).unwrap().procs, 2);
        let mut scratch = Vec::new();
        assert_eq!(p.shadow(0, 2, 0.0, &mut scratch), (10.0, 2));
        p.set_end(1, 99.0);
        assert_eq!(p.shadow(0, 2, 0.0, &mut scratch), (99.0, 2));
        assert!(p.check_invariants());
    }

    #[test]
    fn unknown_end_falls_back_to_reference_rebuild() {
        let mut p = AvailProfile::default();
        p.insert(1, 4, None, 30.0); // end = now + 30
        p.insert(2, 4, Some(20.0), 99.0);
        let mut scratch = Vec::new();
        // At now=0: job 2 ends at 20, job 1 at 30 -> need 6 crosses at 30.
        assert_eq!(p.shadow(0, 6, 0.0, &mut scratch), (30.0, 8));
        // At now=25: job 1 now projects to 55, after job 2's 20 (clamped
        // to now=25).
        assert_eq!(p.shadow(0, 6, 25.0, &mut scratch), (55.0, 8));
        assert!(p.check_invariants());
    }

    #[test]
    fn version_bumps_on_every_mutation() {
        let mut p = AvailProfile::default();
        let v0 = p.version();
        p.insert(1, 4, Some(10.0), 5.0);
        let v1 = p.version();
        assert!(v1 > v0);
        p.set_procs(1, 2);
        let v2 = p.version();
        assert!(v2 > v1);
        p.set_end(1, 20.0);
        let v3 = p.version();
        assert!(v3 > v2);
        p.remove(1);
        assert!(p.version() > v3);
        // No-op remove does not bump.
        let v4 = p.version();
        p.remove(1);
        assert_eq!(p.version(), v4);
    }
}
