//! The resource-selection plug-in's reconfiguration policy — §4 of the
//! paper, three modes with increasing scheduling freedom:
//!
//! 1. **Request an action** (§4.1): the application "strongly suggests" a
//!    specific action by raising its minimum (forced expand) or lowering
//!    its maximum (forced shrink).  Slurm still grants it only if the
//!    system status allows.
//! 2. **Preferred number of nodes** (§4.2): with no queued jobs the job
//!    may grow up to its maximum; otherwise the RMS steers the job toward
//!    its preferred size.
//! 3. **Wide optimization** (§4.3): expand when spare resources cannot
//!    start any queued job anyway; shrink when releasing nodes lets a
//!    queued job start (that job then gets the maximum priority).

/// What the application conveys on each DMR call (§5.1).
#[derive(Debug, Clone, Copy)]
pub struct DmrRequest {
    pub min: usize,
    pub max: usize,
    pub pref: Option<usize>,
    /// Resizing factor: targets are multiples/divisors of the current
    /// size by powers of this factor.
    pub factor: usize,
}

/// The resizing action returned to the runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    NoAction,
    Expand { to: usize },
    Shrink { to: usize },
}

impl Action {
    pub fn name(&self) -> &'static str {
        match self {
            Action::NoAction => "no-action",
            Action::Expand { .. } => "expand",
            Action::Shrink { .. } => "shrink",
        }
    }
}

/// The queue/cluster snapshot the policy inspects ("the RMS inspects the
/// global status of the system" — §3).
#[derive(Debug, Clone, Copy)]
pub struct SystemView {
    /// Free (allocatable) nodes right now.
    pub available: usize,
    /// Number of queued (pending, non-resizer) jobs.
    pub pending_jobs: usize,
    /// Node requirement of the highest-priority pending job, if any.
    pub head_need: Option<usize>,
}

/// Policy configuration (ablations: DESIGN.md §5).
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// §4.2 preferred-number-of-nodes handling.
    pub honor_preference: bool,
    /// §4.3 wide optimization.
    pub wide_optimization: bool,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self { honor_preference: true, wide_optimization: true }
    }
}

/// Largest factor-reachable size from `current` that is <= `cap`
/// (expansion targets: current * factor^k).
pub fn expand_target(current: usize, factor: usize, cap: usize) -> usize {
    let mut t = current;
    while t * factor <= cap {
        t *= factor;
    }
    t
}

/// Smallest factor-reachable size from `current` that is >= `floor`
/// (shrink targets: current / factor^k).
pub fn shrink_target(current: usize, factor: usize, floor: usize) -> usize {
    let mut t = current;
    while t % factor == 0 && t / factor >= floor {
        t /= factor;
    }
    t
}

/// Whether `target` is reachable from `current` by multiplying/dividing by
/// `factor` repeatedly.
pub fn factor_reachable(current: usize, target: usize, factor: usize) -> bool {
    if factor < 2 {
        return true;
    }
    let (mut lo, hi) = if target < current { (target, current) } else { (current, target) };
    while lo < hi {
        lo *= factor;
    }
    lo == hi
}

/// Decide the action for a job currently at `current` processes.
///
/// Pure function of the request and the system view; the RMS applies the
/// protocols (resizer job, ACK shrink) afterwards.
pub fn decide(
    cfg: &PolicyConfig,
    current: usize,
    req: &DmrRequest,
    view: &SystemView,
) -> Action {
    // --- §4.1 Request an action -----------------------------------------
    if req.min > current {
        // Forced expansion; grant only up to what is available.
        let want = expand_target(current, req.factor, req.max.min(current + view.available));
        let want = want.max(req.min.min(current + view.available));
        if want > current && factor_reachable(current, want, req.factor) {
            return Action::Expand { to: want };
        }
        return Action::NoAction;
    }
    if req.max < current {
        // Forced shrink: release only as much as needed to get under the
        // new maximum (factor-reachable).
        let mut to = current;
        while to > req.max && to % req.factor == 0 && to / req.factor >= req.min {
            to /= req.factor;
        }
        if to > req.max {
            to = req.max; // not factor-reachable; honor the hard cap
        }
        return Action::Shrink { to };
    }

    // --- §4.2 Preferred number of nodes ----------------------------------
    if cfg.honor_preference {
        if let Some(pref) = req.pref {
            let pref = pref.clamp(req.min, req.max);
            if pref == current {
                // "If the desired size corresponds to the current size,
                // the RMS will return no action" — at the §4.2 level.
                // §4.3 wide optimization below may still expand the job
                // into *queue-starved* idle nodes (nodes no pending job
                // can use anyway); the checking inhibitor bounds the
                // resulting churn.
            } else if view.pending_jobs == 0 {
                // Queue empty: expansion can be granted up to the maximum.
                let to = expand_target(current, req.factor, req.max.min(current + view.available));
                if to > current {
                    return Action::Expand { to };
                }
            } else if pref < current {
                // Steer toward the preferred size, releasing nodes for the
                // queue.
                if factor_reachable(current, pref, req.factor) {
                    return Action::Shrink { to: pref };
                }
                return Action::Shrink { to: shrink_target(current, req.factor, pref) };
            } else {
                // pref > current: expand toward pref if resources allow.
                let cap = pref.min(current + view.available);
                let to = expand_target(current, req.factor, cap);
                if to > current {
                    return Action::Expand { to };
                }
                return Action::NoAction;
            }
        }
    }

    // --- §4.3 Wide optimization ------------------------------------------
    if cfg.wide_optimization {
        // Expand if resources are spare and either the queue is empty or
        // no pending job can use them anyway.
        let queue_starved = match view.head_need {
            None => true,
            Some(need) => need > view.available,
        };
        if view.available > 0 && queue_starved && current < req.max {
            let to = expand_target(current, req.factor, req.max.min(current + view.available));
            if to > current {
                return Action::Expand { to };
            }
        }
        // Shrink if that lets a queued job start.
        if let Some(need) = view.head_need {
            let floor = req.pref.unwrap_or(req.min).clamp(req.min, req.max);
            let to = shrink_target(current, req.factor, floor);
            let released = current.saturating_sub(to);
            if released > 0 && view.available + released >= need {
                return Action::Shrink { to };
            }
        }
    }

    Action::NoAction
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(min: usize, max: usize, pref: Option<usize>) -> DmrRequest {
        DmrRequest { min, max, pref, factor: 2 }
    }

    fn view(available: usize, pending: usize, head: Option<usize>) -> SystemView {
        SystemView { available, pending_jobs: pending, head_need: head }
    }

    #[test]
    fn targets() {
        assert_eq!(expand_target(8, 2, 32), 32);
        assert_eq!(expand_target(8, 2, 31), 16);
        assert_eq!(expand_target(8, 2, 8), 8);
        assert_eq!(shrink_target(32, 2, 8), 8);
        assert_eq!(shrink_target(32, 2, 9), 16);
        assert_eq!(shrink_target(7, 2, 1), 7); // 7 not divisible
        assert!(factor_reachable(8, 32, 2));
        assert!(!factor_reachable(8, 24, 2));
    }

    #[test]
    fn target_boundaries() {
        // expand_target when the cap sits below the next factor step:
        // stay put (31 < 8*2*2, 15 < 8*2).
        assert_eq!(expand_target(8, 2, 15), 8);
        assert_eq!(expand_target(8, 2, 16), 16);
        assert_eq!(expand_target(1, 2, 1), 1);
        assert_eq!(expand_target(8, 2, 7), 8, "cap below current never shrinks");
        // shrink_target at the floor: no movement
        assert_eq!(shrink_target(8, 2, 8), 8);
        // floor above current: shrink_target never moves upward
        assert_eq!(shrink_target(8, 2, 9), 8);
        // the chain stops where divisibility ends, not at the floor
        assert_eq!(shrink_target(12, 2, 1), 3);
        assert_eq!(shrink_target(1, 2, 1), 1);
        // factor_reachable for non-chain targets
        assert!(!factor_reachable(8, 12, 2), "12 is not on 8's factor-2 chain");
        assert!(!factor_reachable(3, 10, 2));
        assert!(factor_reachable(3, 48, 2), "48 = 3 * 2^4");
        assert!(factor_reachable(5, 5, 3), "zero steps is always reachable");
        // factor < 2 treats every target as reachable (degenerate chain)
        assert!(factor_reachable(7, 9, 1));
        assert!(factor_reachable(2, 9, 0));
    }

    #[test]
    fn forced_expand_41() {
        // App raises min above current => expand (resources permitting).
        let a = decide(&PolicyConfig::default(), 8, &req(16, 32, None), &view(24, 3, Some(64)));
        assert_eq!(a, Action::Expand { to: 32 });
        // Without resources: no action.
        let a = decide(&PolicyConfig::default(), 8, &req(16, 32, None), &view(0, 3, Some(64)));
        assert_eq!(a, Action::NoAction);
    }

    #[test]
    fn forced_shrink_41() {
        let a = decide(&PolicyConfig::default(), 32, &req(2, 8, None), &view(0, 0, None));
        assert_eq!(a, Action::Shrink { to: 8 });
    }

    #[test]
    fn preference_no_action_at_pref_with_queue() {
        // At preferred size, queue nonempty, no shrink would help the
        // (huge) head job => no action.
        let a = decide(&PolicyConfig::default(), 8, &req(2, 32, Some(8)), &view(0, 2, Some(64)));
        assert_eq!(a, Action::NoAction);
    }

    #[test]
    fn preference_empty_queue_expands_to_max() {
        let a = decide(&PolicyConfig::default(), 8, &req(2, 32, Some(8)), &view(56, 0, None));
        assert_eq!(a, Action::Expand { to: 32 });
    }

    #[test]
    fn preference_shrinks_toward_pref_when_queued() {
        // Launched at max (32), pref 8, jobs waiting => scale down
        // (the paper's "scaled-down as soon as possible", §7.5).
        let a = decide(&PolicyConfig::default(), 32, &req(2, 32, Some(8)), &view(0, 4, Some(32)));
        assert_eq!(a, Action::Shrink { to: 8 });
    }

    #[test]
    fn preference_expands_toward_pref() {
        let a = decide(&PolicyConfig::default(), 2, &req(2, 32, Some(8)), &view(10, 3, Some(64)));
        assert_eq!(a, Action::Expand { to: 8 });
    }

    #[test]
    fn wide_expand_when_queue_starved() {
        // No preference; 4 free nodes; head needs 32 (> 4) => the spare
        // nodes go to the running job.
        let a = decide(&PolicyConfig::default(), 4, &req(1, 16, None), &view(4, 1, Some(32)));
        assert_eq!(a, Action::Expand { to: 8 });
    }

    #[test]
    fn wide_shrink_when_release_starts_head() {
        // No preference: shrink 16 -> 1 (floor = min) releases 15; head
        // needs 8 <= 0 + 15 => shrink.
        let a = decide(&PolicyConfig::default(), 16, &req(1, 16, None), &view(0, 1, Some(8)));
        assert_eq!(a, Action::Shrink { to: 1 });
    }

    #[test]
    fn wide_no_shrink_when_release_insufficient() {
        let a = decide(&PolicyConfig::default(), 4, &req(2, 16, None), &view(0, 1, Some(32)));
        assert_eq!(a, Action::NoAction);
    }

    #[test]
    fn ablation_disable_wide() {
        let cfg = PolicyConfig { wide_optimization: false, ..Default::default() };
        let a = decide(&cfg, 4, &req(1, 16, None), &view(4, 1, Some(32)));
        assert_eq!(a, Action::NoAction);
    }

    #[test]
    fn ablation_disable_preference_falls_through_to_wide() {
        let cfg = PolicyConfig { honor_preference: false, ..Default::default() };
        // pref says shrink to 8, but preference handling is off; wide
        // optimization still shrinks (to pref floor) because head fits.
        let a = decide(&cfg, 32, &req(2, 32, Some(8)), &view(0, 1, Some(16)));
        assert_eq!(a, Action::Shrink { to: 8 });
    }
}
