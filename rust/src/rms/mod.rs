//! The Slurm-like workload manager with the paper's reconfiguration
//! plug-in: multifactor priorities, EASY backfill over the incremental
//! cluster-availability profile ([`profile`]), the pluggable
//! reconfiguration-policy engine ([`policy`] — the paper's §4 rule plus
//! queue-pressure / fair-share / deadline strategies) and the resize
//! protocols (§3, §5.2).

pub mod backfill;
pub mod events;
pub mod job;
pub mod policy;
pub mod profile;
pub mod queue;
#[allow(clippy::module_inception)]
mod rms;

pub use events::{EventLog, RmsEvent};
pub use job::{Job, JobState, ResizeEvent};
pub use policy::{
    Action, DmrRequest, PolicyConfig, PolicyContext, PolicyStrategy, ReconfigPolicy, SystemView,
    UsageView,
};
pub use profile::AvailProfile;
pub use queue::PriorityWeights;
pub use rms::{DmrOutcome, NodeFailure, PassStats, Rms, RmsConfig, Started, Telemetry};
