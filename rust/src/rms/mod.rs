//! The Slurm-like workload manager with the paper's reconfiguration
//! plug-in: multifactor priorities, EASY backfill, the three-mode
//! reconfiguration policy (§4) and the resize protocols (§3, §5.2).

pub mod backfill;
pub mod events;
pub mod job;
pub mod policy;
pub mod queue;
#[allow(clippy::module_inception)]
mod rms;

pub use events::{EventLog, RmsEvent};
pub use job::{Job, JobState, ResizeEvent};
pub use policy::{Action, DmrRequest, PolicyConfig, SystemView};
pub use queue::PriorityWeights;
pub use rms::{DmrOutcome, NodeFailure, Rms, RmsConfig, Started, Telemetry};
