//! RMS event log: an append-only record of every scheduling decision,
//! used by tests and by the evaluation reports.

use super::policy::Action;
use crate::{JobId, Time};

#[derive(Debug, Clone, PartialEq)]
pub enum RmsEvent {
    Submitted { job: JobId, time: Time },
    Started { job: JobId, time: Time, procs: usize },
    Finished { job: JobId, time: Time },
    Cancelled { job: JobId, time: Time },
    /// A DMR call was evaluated (§5.1); `action` is what the policy chose.
    DmrDecision { job: JobId, time: Time, action: Action },
    /// Expansion committed: the resizer-job protocol succeeded (§5.2.1).
    Expanded { job: JobId, time: Time, from: usize, to: usize },
    /// Shrink committed after the ACK-synchronized release (§5.2.2).
    Shrunk { job: JobId, time: Time, from: usize, to: usize },
    /// Expansion aborted: the resizer job timed out (§5.2.1).
    ExpandAborted { job: JobId, time: Time },
}

/// Append-only log with query helpers.
#[derive(Debug, Default, Clone)]
pub struct EventLog {
    events: Vec<RmsEvent>,
}

impl EventLog {
    pub fn push(&mut self, e: RmsEvent) {
        self.events.push(e);
    }

    pub fn all(&self) -> &[RmsEvent] {
        &self.events
    }

    pub fn count<F: Fn(&RmsEvent) -> bool>(&self, f: F) -> usize {
        self.events.iter().filter(|e| f(e)).count()
    }

    pub fn expansions(&self) -> usize {
        self.count(|e| matches!(e, RmsEvent::Expanded { .. }))
    }

    pub fn shrinks(&self) -> usize {
        self.count(|e| matches!(e, RmsEvent::Shrunk { .. }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        let mut log = EventLog::default();
        log.push(RmsEvent::Expanded { job: 1, time: 0.0, from: 8, to: 16 });
        log.push(RmsEvent::Shrunk { job: 2, time: 1.0, from: 16, to: 8 });
        log.push(RmsEvent::Shrunk { job: 2, time: 2.0, from: 8, to: 4 });
        assert_eq!(log.expansions(), 1);
        assert_eq!(log.shrinks(), 2);
        assert_eq!(log.all().len(), 3);
    }
}
