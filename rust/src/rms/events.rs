//! RMS event log: an append-only record of every scheduling decision,
//! used by tests and by the evaluation reports.
//!
//! The log keeps a **rolling digest** and per-variant counters that are
//! updated at [`EventLog::push`] time, so the determinism contract
//! ([`EventLog::digest`]) and the summary counters survive even when the
//! backing event `Vec` is disabled (`retain = false`, the bounded-memory
//! streaming mode — see `docs/ARCHITECTURE.md`, "Streaming replay").

use super::policy::Action;
use crate::{JobId, NodeId, Time};

#[derive(Debug, Clone, PartialEq)]
pub enum RmsEvent {
    Submitted { job: JobId, time: Time },
    Started { job: JobId, time: Time, procs: usize },
    Finished { job: JobId, time: Time },
    Cancelled { job: JobId, time: Time },
    /// A DMR call was evaluated (§5.1); `action` is what the policy chose.
    DmrDecision { job: JobId, time: Time, action: Action },
    /// Expansion committed: the resizer-job protocol succeeded (§5.2.1).
    Expanded { job: JobId, time: Time, from: usize, to: usize },
    /// Shrink committed after the ACK-synchronized release (§5.2.2).
    Shrunk { job: JobId, time: Time, from: usize, to: usize },
    /// Expansion aborted: the resizer job timed out (§5.2.1).
    ExpandAborted { job: JobId, time: Time },
    // --- resilience events (crate::resilience) -----------------------
    /// A node went down (failure injection).
    NodeFailed { node: NodeId, time: Time },
    /// A failed node was repaired and returned to the free pool.
    NodeRepaired { node: NodeId, time: Time },
    /// A maintenance drain took hold of a node.
    DrainStarted { node: NodeId, time: Time },
    /// A drain window ended for a node.
    DrainEnded { node: NodeId, time: Time },
    /// A running job lost `node` to a failure.
    Interrupted { job: JobId, time: Time, node: NodeId },
    /// An interrupted job was killed and requeued (rigid recovery).
    Requeued { job: JobId, time: Time },
    /// An interrupted malleable job shrank onto its surviving nodes.
    Rescued { job: JobId, time: Time, from: usize, to: usize },
    // --- federation events (crate::federation) -----------------------
    /// A pending job was withdrawn from this shard's queue by the
    /// meta-scheduler's work stealing (it re-submits on another shard).
    /// Only federated multi-shard runs emit this, so flat and 1-shard
    /// event logs are untouched.
    Stolen { job: JobId, time: Time },
    // --- resize-transaction events (crate::resilience::resize) -------
    /// A multi-phase resize transaction began (emitted only when resize
    /// faults are active; fault-free runs keep the legacy single-event
    /// resize, so their logs are untouched).
    ResizeBegin { job: JobId, time: Time, from: usize, to: usize },
    /// A resize transaction aborted in `phase` (codes in
    /// [`crate::resilience::resize`]: 0 grant-revoked, 1 spawn failed,
    /// 2 redistribution aborted, 3 machine fault on the allocation) and
    /// the job rolled back to its pre-transaction process set.
    ResizeAbort { job: JobId, time: Time, phase: u8 },
    /// A resize transaction committed: the job now runs on `procs`.
    ResizeCommit { job: JobId, time: Time, procs: usize },
    /// A job exhausted its resize retries and degraded to non-malleable
    /// for the rest of the run (policies stop proposing resizes for it).
    Degraded { job: JobId, time: Time },
    // --- failure-domain events (crate::resilience::model) ------------
    /// A correlated outage took failure domain `domain` of this shard
    /// dark (domain 0 is the implicit whole shard).  Only outage-enabled
    /// federated runs emit this — outage-free logs are untouched.
    ShardDown { domain: usize, time: Time },
    /// The outage on `domain` ended; its nodes return to the pool.
    ShardUp { domain: usize, time: Time },
    /// An interrupted malleable job was evacuated to shard `to`: removed
    /// here, its checkpointed state re-submitted through the router.
    /// Every evacuation pairs with a completion (or requeue) on the
    /// target shard — the cross-shard half of the failure ledger.
    Evacuated { job: JobId, time: Time, to: usize },
    /// A network partition isolated this shard (it keeps running local
    /// jobs; routing and stealing toward it are suppressed).
    PartitionStarted { time: Time },
    /// The partition healed.
    PartitionEnded { time: Time },
}

/// Fold one event into the rolling FNV-1a digest (order-sensitive; times
/// hashed bit-exactly).  Kept as a free function so the per-push rolling
/// digest is — by construction — the same fold the historical whole-log
/// digest computed.
fn fold_event(h: &mut u64, e: &RmsEvent) {
    fn mix(h: &mut u64, x: u64) {
        *h ^= x;
        *h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    fn mix_action(h: &mut u64, a: &Action) {
        match a {
            Action::NoAction => mix(h, 0),
            Action::Expand { to } => {
                mix(h, 1);
                mix(h, *to as u64);
            }
            Action::Shrink { to } => {
                mix(h, 2);
                mix(h, *to as u64);
            }
        }
    }
    match e {
        RmsEvent::Submitted { job, time } => {
            mix(h, 1);
            mix(h, *job);
            mix(h, time.to_bits());
        }
        RmsEvent::Started { job, time, procs } => {
            mix(h, 2);
            mix(h, *job);
            mix(h, time.to_bits());
            mix(h, *procs as u64);
        }
        RmsEvent::Finished { job, time } => {
            mix(h, 3);
            mix(h, *job);
            mix(h, time.to_bits());
        }
        RmsEvent::Cancelled { job, time } => {
            mix(h, 4);
            mix(h, *job);
            mix(h, time.to_bits());
        }
        RmsEvent::DmrDecision { job, time, action } => {
            mix(h, 5);
            mix(h, *job);
            mix(h, time.to_bits());
            mix_action(h, action);
        }
        RmsEvent::Expanded { job, time, from, to } => {
            mix(h, 6);
            mix(h, *job);
            mix(h, time.to_bits());
            mix(h, *from as u64);
            mix(h, *to as u64);
        }
        RmsEvent::Shrunk { job, time, from, to } => {
            mix(h, 7);
            mix(h, *job);
            mix(h, time.to_bits());
            mix(h, *from as u64);
            mix(h, *to as u64);
        }
        RmsEvent::ExpandAborted { job, time } => {
            mix(h, 8);
            mix(h, *job);
            mix(h, time.to_bits());
        }
        RmsEvent::NodeFailed { node, time } => {
            mix(h, 9);
            mix(h, *node as u64);
            mix(h, time.to_bits());
        }
        RmsEvent::NodeRepaired { node, time } => {
            mix(h, 10);
            mix(h, *node as u64);
            mix(h, time.to_bits());
        }
        RmsEvent::DrainStarted { node, time } => {
            mix(h, 11);
            mix(h, *node as u64);
            mix(h, time.to_bits());
        }
        RmsEvent::DrainEnded { node, time } => {
            mix(h, 12);
            mix(h, *node as u64);
            mix(h, time.to_bits());
        }
        RmsEvent::Interrupted { job, time, node } => {
            mix(h, 13);
            mix(h, *job);
            mix(h, time.to_bits());
            mix(h, *node as u64);
        }
        RmsEvent::Requeued { job, time } => {
            mix(h, 14);
            mix(h, *job);
            mix(h, time.to_bits());
        }
        RmsEvent::Rescued { job, time, from, to } => {
            mix(h, 15);
            mix(h, *job);
            mix(h, time.to_bits());
            mix(h, *from as u64);
            mix(h, *to as u64);
        }
        RmsEvent::Stolen { job, time } => {
            mix(h, 16);
            mix(h, *job);
            mix(h, time.to_bits());
        }
        RmsEvent::ResizeBegin { job, time, from, to } => {
            mix(h, 17);
            mix(h, *job);
            mix(h, time.to_bits());
            mix(h, *from as u64);
            mix(h, *to as u64);
        }
        RmsEvent::ResizeAbort { job, time, phase } => {
            mix(h, 18);
            mix(h, *job);
            mix(h, time.to_bits());
            mix(h, *phase as u64);
        }
        RmsEvent::ResizeCommit { job, time, procs } => {
            mix(h, 19);
            mix(h, *job);
            mix(h, time.to_bits());
            mix(h, *procs as u64);
        }
        RmsEvent::Degraded { job, time } => {
            mix(h, 20);
            mix(h, *job);
            mix(h, time.to_bits());
        }
        RmsEvent::ShardDown { domain, time } => {
            mix(h, 21);
            mix(h, *domain as u64);
            mix(h, time.to_bits());
        }
        RmsEvent::ShardUp { domain, time } => {
            mix(h, 22);
            mix(h, *domain as u64);
            mix(h, time.to_bits());
        }
        RmsEvent::Evacuated { job, time, to } => {
            mix(h, 23);
            mix(h, *job);
            mix(h, time.to_bits());
            mix(h, *to as u64);
        }
        RmsEvent::PartitionStarted { time } => {
            mix(h, 24);
            mix(h, time.to_bits());
        }
        RmsEvent::PartitionEnded { time } => {
            mix(h, 25);
            mix(h, time.to_bits());
        }
    }
}

/// Append-only log with query helpers.
///
/// The digest and the named counters are maintained incrementally at
/// push time; the event `Vec` itself is only an *optional* retention
/// buffer (needed by trace export and a handful of timeline tests).
/// `EventLog::default()` retains; `set_retain(false)` switches the log
/// to O(1) memory while keeping `digest()`/counters/`total_pushed()`
/// bit-for-bit identical.
#[derive(Debug, Clone)]
pub struct EventLog {
    events: Vec<RmsEvent>,
    retain: bool,
    digest: u64,
    pushed: u64,
    n_expanded: usize,
    n_shrunk: usize,
    n_node_failed: usize,
    n_rescued: usize,
    n_requeued: usize,
    n_stolen: usize,
    n_resize_begin: usize,
    n_resize_abort: usize,
    n_resize_commit: usize,
    n_degraded: usize,
    n_shard_down: usize,
    n_shard_up: usize,
    n_evacuated: usize,
    n_partitions: usize,
}

impl Default for EventLog {
    fn default() -> Self {
        EventLog {
            events: Vec::new(),
            retain: true,
            digest: 0xCBF2_9CE4_8422_2325, // FNV-1a offset basis
            pushed: 0,
            n_expanded: 0,
            n_shrunk: 0,
            n_node_failed: 0,
            n_rescued: 0,
            n_requeued: 0,
            n_stolen: 0,
            n_resize_begin: 0,
            n_resize_abort: 0,
            n_resize_commit: 0,
            n_degraded: 0,
            n_shard_down: 0,
            n_shard_up: 0,
            n_evacuated: 0,
            n_partitions: 0,
        }
    }
}

impl EventLog {
    /// Append an event: fold it into the rolling digest, bump its
    /// counter, and (when retaining) keep the event itself.
    pub fn push(&mut self, e: RmsEvent) {
        fold_event(&mut self.digest, &e);
        self.pushed += 1;
        match &e {
            RmsEvent::Expanded { .. } => self.n_expanded += 1,
            RmsEvent::Shrunk { .. } => self.n_shrunk += 1,
            RmsEvent::NodeFailed { .. } => self.n_node_failed += 1,
            RmsEvent::Rescued { .. } => self.n_rescued += 1,
            RmsEvent::Requeued { .. } => self.n_requeued += 1,
            RmsEvent::Stolen { .. } => self.n_stolen += 1,
            RmsEvent::ResizeBegin { .. } => self.n_resize_begin += 1,
            RmsEvent::ResizeAbort { .. } => self.n_resize_abort += 1,
            RmsEvent::ResizeCommit { .. } => self.n_resize_commit += 1,
            RmsEvent::Degraded { .. } => self.n_degraded += 1,
            RmsEvent::ShardDown { .. } => self.n_shard_down += 1,
            RmsEvent::ShardUp { .. } => self.n_shard_up += 1,
            RmsEvent::Evacuated { .. } => self.n_evacuated += 1,
            RmsEvent::PartitionStarted { .. } => self.n_partitions += 1,
            _ => {}
        }
        if self.retain {
            self.events.push(e);
        }
    }

    /// Toggle event retention.  With `retain = false` subsequent pushes
    /// update only the digest/counters; [`EventLog::all`] stays empty.
    /// Must be flipped before the first push — flipping mid-run would
    /// leave a partial retention buffer.
    pub fn set_retain(&mut self, retain: bool) {
        debug_assert!(self.pushed == 0, "set_retain must precede the first push");
        self.retain = retain;
    }

    /// Whether pushed events are retained in memory (trace export and
    /// timeline queries need this; the digest/counters never do).
    pub fn retains(&self) -> bool {
        self.retain
    }

    /// Total number of events ever pushed (independent of retention).
    pub fn total_pushed(&self) -> u64 {
        self.pushed
    }

    /// Every recorded event, in order.  Empty when retention is off,
    /// even though events were pushed — check [`EventLog::retains`].
    pub fn all(&self) -> &[RmsEvent] {
        &self.events
    }

    /// Count retained events matching a predicate (requires retention).
    pub fn count<F: Fn(&RmsEvent) -> bool>(&self, f: F) -> usize {
        self.events.iter().filter(|e| f(e)).count()
    }

    /// Committed expansions recorded.
    pub fn expansions(&self) -> usize {
        self.n_expanded
    }

    /// Committed shrinks recorded.
    pub fn shrinks(&self) -> usize {
        self.n_shrunk
    }

    /// Node failures recorded.
    pub fn node_failures(&self) -> usize {
        self.n_node_failed
    }

    /// Shrink rescues recorded.
    pub fn rescues(&self) -> usize {
        self.n_rescued
    }

    /// Failure requeues recorded.
    pub fn requeues(&self) -> usize {
        self.n_requeued
    }

    /// Cross-shard steals recorded (jobs withdrawn from this shard).
    pub fn steals(&self) -> usize {
        self.n_stolen
    }

    /// Resize transactions begun (multi-phase path only).
    pub fn resize_begins(&self) -> usize {
        self.n_resize_begin
    }

    /// Resize transactions aborted.
    pub fn resize_aborts(&self) -> usize {
        self.n_resize_abort
    }

    /// Resize transactions committed.
    pub fn resize_commits(&self) -> usize {
        self.n_resize_commit
    }

    /// Jobs degraded to non-malleable after exhausting resize retries.
    pub fn degradations(&self) -> usize {
        self.n_degraded
    }

    /// Correlated domain outages begun on this shard.
    pub fn shard_downs(&self) -> usize {
        self.n_shard_down
    }

    /// Correlated domain outages ended on this shard.
    pub fn shard_ups(&self) -> usize {
        self.n_shard_up
    }

    /// Jobs evacuated off this shard during outages.
    pub fn evacuations(&self) -> usize {
        self.n_evacuated
    }

    /// Partition windows that isolated this shard.
    pub fn partitions(&self) -> usize {
        self.n_partitions
    }

    /// Order-sensitive FNV-1a digest over every event ever pushed and
    /// all its fields (times hashed bit-exactly).  Two logs digest equal
    /// iff their push sequences are bit-identical — the
    /// behavior-preservation contract the golden determinism test and
    /// the `hotpath_scale` checksum rely on.  Maintained incrementally,
    /// so it is retention-independent and O(1) to read.
    pub fn digest(&self) -> u64 {
        self.digest
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        let mut log = EventLog::default();
        log.push(RmsEvent::Expanded { job: 1, time: 0.0, from: 8, to: 16 });
        log.push(RmsEvent::Shrunk { job: 2, time: 1.0, from: 16, to: 8 });
        log.push(RmsEvent::Shrunk { job: 2, time: 2.0, from: 8, to: 4 });
        assert_eq!(log.expansions(), 1);
        assert_eq!(log.shrinks(), 2);
        assert_eq!(log.all().len(), 3);
        assert_eq!(log.total_pushed(), 3);
    }

    #[test]
    fn unretained_log_keeps_digest_and_counters() {
        let events = [
            RmsEvent::Submitted { job: 1, time: 0.0 },
            RmsEvent::Started { job: 1, time: 1.0, procs: 8 },
            RmsEvent::Expanded { job: 1, time: 2.0, from: 8, to: 16 },
            RmsEvent::NodeFailed { node: 3, time: 2.5 },
            RmsEvent::Requeued { job: 1, time: 2.5 },
            RmsEvent::Finished { job: 1, time: 3.0 },
        ];
        let mut kept = EventLog::default();
        let mut dropped = EventLog::default();
        dropped.set_retain(false);
        for e in &events {
            kept.push(e.clone());
            dropped.push(e.clone());
        }
        assert_eq!(kept.digest(), dropped.digest(), "digest is retention-independent");
        assert_eq!(kept.total_pushed(), dropped.total_pushed());
        assert_eq!(kept.all().len(), events.len());
        assert!(dropped.all().is_empty(), "unretained log holds no events");
        assert!(!dropped.retains());
        assert_eq!(dropped.expansions(), 1);
        assert_eq!(dropped.node_failures(), 1);
        assert_eq!(dropped.requeues(), 1);
    }

    #[test]
    fn digest_is_order_and_field_sensitive() {
        let mut a = EventLog::default();
        a.push(RmsEvent::Submitted { job: 1, time: 0.0 });
        a.push(RmsEvent::Started { job: 1, time: 1.0, procs: 8 });
        let mut b = EventLog::default();
        b.push(RmsEvent::Started { job: 1, time: 1.0, procs: 8 });
        b.push(RmsEvent::Submitted { job: 1, time: 0.0 });
        assert_ne!(a.digest(), b.digest(), "order matters");

        let mut c = EventLog::default();
        c.push(RmsEvent::Submitted { job: 1, time: 0.0 });
        c.push(RmsEvent::Started { job: 1, time: 1.0, procs: 16 });
        assert_ne!(a.digest(), c.digest(), "fields matter");

        let mut d = EventLog::default();
        d.push(RmsEvent::Submitted { job: 1, time: 0.0 });
        d.push(RmsEvent::Started { job: 1, time: 1.0, procs: 8 });
        assert_eq!(a.digest(), d.digest(), "identical logs digest equal");

        // Decision actions are distinguishable.
        let mut e = EventLog::default();
        e.push(RmsEvent::DmrDecision { job: 2, time: 3.0, action: Action::Expand { to: 8 } });
        let mut f = EventLog::default();
        f.push(RmsEvent::DmrDecision { job: 2, time: 3.0, action: Action::Shrink { to: 8 } });
        assert_ne!(e.digest(), f.digest());
    }

    #[test]
    fn resilience_events_distinct_in_digest() {
        let digest_of = |e: RmsEvent| {
            let mut l = EventLog::default();
            l.push(e);
            l.digest()
        };
        let all = [
            digest_of(RmsEvent::NodeFailed { node: 1, time: 2.0 }),
            digest_of(RmsEvent::NodeRepaired { node: 1, time: 2.0 }),
            digest_of(RmsEvent::DrainStarted { node: 1, time: 2.0 }),
            digest_of(RmsEvent::DrainEnded { node: 1, time: 2.0 }),
            digest_of(RmsEvent::Interrupted { job: 1, time: 2.0, node: 1 }),
            digest_of(RmsEvent::Requeued { job: 1, time: 2.0 }),
            digest_of(RmsEvent::Rescued { job: 1, time: 2.0, from: 8, to: 4 }),
            digest_of(RmsEvent::Stolen { job: 1, time: 2.0 }),
            digest_of(RmsEvent::ResizeBegin { job: 1, time: 2.0, from: 8, to: 4 }),
            digest_of(RmsEvent::ResizeAbort { job: 1, time: 2.0, phase: 1 }),
            digest_of(RmsEvent::ResizeCommit { job: 1, time: 2.0, procs: 8 }),
            digest_of(RmsEvent::Degraded { job: 1, time: 2.0 }),
            digest_of(RmsEvent::ShardDown { domain: 1, time: 2.0 }),
            digest_of(RmsEvent::ShardUp { domain: 1, time: 2.0 }),
            digest_of(RmsEvent::Evacuated { job: 1, time: 2.0, to: 1 }),
            digest_of(RmsEvent::PartitionStarted { time: 2.0 }),
            digest_of(RmsEvent::PartitionEnded { time: 2.0 }),
        ];
        for (i, a) in all.iter().enumerate() {
            for (j, b) in all.iter().enumerate() {
                if i != j {
                    assert_ne!(a, b, "variants {i} and {j} collide");
                }
            }
        }
        // field-sensitivity of the new variants
        assert_ne!(
            digest_of(RmsEvent::Rescued { job: 1, time: 2.0, from: 8, to: 4 }),
            digest_of(RmsEvent::Rescued { job: 1, time: 2.0, from: 8, to: 2 }),
        );
        let mut log = EventLog::default();
        log.push(RmsEvent::NodeFailed { node: 3, time: 1.0 });
        log.push(RmsEvent::Rescued { job: 2, time: 1.0, from: 32, to: 16 });
        log.push(RmsEvent::Requeued { job: 4, time: 2.0 });
        log.push(RmsEvent::Stolen { job: 5, time: 3.0 });
        assert_eq!(log.node_failures(), 1);
        assert_eq!(log.rescues(), 1);
        assert_eq!(log.requeues(), 1);
        assert_eq!(log.steals(), 1);
    }

    #[test]
    fn resize_transaction_events_distinct_and_counted() {
        let digest_of = |e: RmsEvent| {
            let mut l = EventLog::default();
            l.push(e);
            l.digest()
        };
        // The abort phase code is digest-covered.
        assert_ne!(
            digest_of(RmsEvent::ResizeAbort { job: 1, time: 2.0, phase: 0 }),
            digest_of(RmsEvent::ResizeAbort { job: 1, time: 2.0, phase: 2 }),
        );
        // Begin and commit are field-sensitive.
        assert_ne!(
            digest_of(RmsEvent::ResizeBegin { job: 1, time: 2.0, from: 8, to: 16 }),
            digest_of(RmsEvent::ResizeBegin { job: 1, time: 2.0, from: 8, to: 32 }),
        );
        assert_ne!(
            digest_of(RmsEvent::ResizeCommit { job: 1, time: 2.0, procs: 8 }),
            digest_of(RmsEvent::ResizeCommit { job: 1, time: 2.0, procs: 16 }),
        );
        let mut log = EventLog::default();
        log.push(RmsEvent::ResizeBegin { job: 1, time: 1.0, from: 8, to: 16 });
        log.push(RmsEvent::ResizeAbort { job: 1, time: 2.0, phase: 1 });
        log.push(RmsEvent::ResizeBegin { job: 1, time: 3.0, from: 8, to: 16 });
        log.push(RmsEvent::ResizeCommit { job: 1, time: 4.0, procs: 16 });
        log.push(RmsEvent::Degraded { job: 2, time: 5.0 });
        assert_eq!(log.resize_begins(), 2);
        assert_eq!(log.resize_aborts(), 1);
        assert_eq!(log.resize_commits(), 1);
        assert_eq!(log.degradations(), 1);
    }

    #[test]
    fn failure_domain_events_distinct_and_counted() {
        let digest_of = |e: RmsEvent| {
            let mut l = EventLog::default();
            l.push(e);
            l.digest()
        };
        // Domain and target fields are digest-covered.
        assert_ne!(
            digest_of(RmsEvent::ShardDown { domain: 0, time: 2.0 }),
            digest_of(RmsEvent::ShardDown { domain: 1, time: 2.0 }),
        );
        assert_ne!(
            digest_of(RmsEvent::Evacuated { job: 1, time: 2.0, to: 1 }),
            digest_of(RmsEvent::Evacuated { job: 1, time: 2.0, to: 2 }),
        );
        assert_ne!(
            digest_of(RmsEvent::PartitionStarted { time: 2.0 }),
            digest_of(RmsEvent::PartitionStarted { time: 3.0 }),
        );
        let mut log = EventLog::default();
        log.push(RmsEvent::ShardDown { domain: 0, time: 1.0 });
        log.push(RmsEvent::Evacuated { job: 7, time: 1.0, to: 1 });
        log.push(RmsEvent::ShardUp { domain: 0, time: 5.0 });
        log.push(RmsEvent::PartitionStarted { time: 6.0 });
        log.push(RmsEvent::PartitionEnded { time: 7.0 });
        assert_eq!(log.shard_downs(), 1);
        assert_eq!(log.shard_ups(), 1);
        assert_eq!(log.evacuations(), 1);
        assert_eq!(log.partitions(), 1);
    }
}
