//! RMS event log: an append-only record of every scheduling decision,
//! used by tests and by the evaluation reports.

use super::policy::Action;
use crate::{JobId, NodeId, Time};

#[derive(Debug, Clone, PartialEq)]
pub enum RmsEvent {
    Submitted { job: JobId, time: Time },
    Started { job: JobId, time: Time, procs: usize },
    Finished { job: JobId, time: Time },
    Cancelled { job: JobId, time: Time },
    /// A DMR call was evaluated (§5.1); `action` is what the policy chose.
    DmrDecision { job: JobId, time: Time, action: Action },
    /// Expansion committed: the resizer-job protocol succeeded (§5.2.1).
    Expanded { job: JobId, time: Time, from: usize, to: usize },
    /// Shrink committed after the ACK-synchronized release (§5.2.2).
    Shrunk { job: JobId, time: Time, from: usize, to: usize },
    /// Expansion aborted: the resizer job timed out (§5.2.1).
    ExpandAborted { job: JobId, time: Time },
    // --- resilience events (crate::resilience) -----------------------
    /// A node went down (failure injection).
    NodeFailed { node: NodeId, time: Time },
    /// A failed node was repaired and returned to the free pool.
    NodeRepaired { node: NodeId, time: Time },
    /// A maintenance drain took hold of a node.
    DrainStarted { node: NodeId, time: Time },
    /// A drain window ended for a node.
    DrainEnded { node: NodeId, time: Time },
    /// A running job lost `node` to a failure.
    Interrupted { job: JobId, time: Time, node: NodeId },
    /// An interrupted job was killed and requeued (rigid recovery).
    Requeued { job: JobId, time: Time },
    /// An interrupted malleable job shrank onto its surviving nodes.
    Rescued { job: JobId, time: Time, from: usize, to: usize },
    // --- federation events (crate::federation) -----------------------
    /// A pending job was withdrawn from this shard's queue by the
    /// meta-scheduler's work stealing (it re-submits on another shard).
    /// Only federated multi-shard runs emit this, so flat and 1-shard
    /// event logs are untouched.
    Stolen { job: JobId, time: Time },
    // --- resize-transaction events (crate::resilience::resize) -------
    /// A multi-phase resize transaction began (emitted only when resize
    /// faults are active; fault-free runs keep the legacy single-event
    /// resize, so their logs are untouched).
    ResizeBegin { job: JobId, time: Time, from: usize, to: usize },
    /// A resize transaction aborted in `phase` (codes in
    /// [`crate::resilience::resize`]: 0 grant-revoked, 1 spawn failed,
    /// 2 redistribution aborted, 3 machine fault on the allocation) and
    /// the job rolled back to its pre-transaction process set.
    ResizeAbort { job: JobId, time: Time, phase: u8 },
    /// A resize transaction committed: the job now runs on `procs`.
    ResizeCommit { job: JobId, time: Time, procs: usize },
    /// A job exhausted its resize retries and degraded to non-malleable
    /// for the rest of the run (policies stop proposing resizes for it).
    Degraded { job: JobId, time: Time },
}

/// Append-only log with query helpers.
#[derive(Debug, Default, Clone)]
pub struct EventLog {
    events: Vec<RmsEvent>,
}

impl EventLog {
    /// Append an event.
    pub fn push(&mut self, e: RmsEvent) {
        self.events.push(e);
    }

    /// Every recorded event, in order.
    pub fn all(&self) -> &[RmsEvent] {
        &self.events
    }

    /// Count events matching a predicate.
    pub fn count<F: Fn(&RmsEvent) -> bool>(&self, f: F) -> usize {
        self.events.iter().filter(|e| f(e)).count()
    }

    /// Committed expansions recorded.
    pub fn expansions(&self) -> usize {
        self.count(|e| matches!(e, RmsEvent::Expanded { .. }))
    }

    /// Committed shrinks recorded.
    pub fn shrinks(&self) -> usize {
        self.count(|e| matches!(e, RmsEvent::Shrunk { .. }))
    }

    /// Node failures recorded.
    pub fn node_failures(&self) -> usize {
        self.count(|e| matches!(e, RmsEvent::NodeFailed { .. }))
    }

    /// Shrink rescues recorded.
    pub fn rescues(&self) -> usize {
        self.count(|e| matches!(e, RmsEvent::Rescued { .. }))
    }

    /// Failure requeues recorded.
    pub fn requeues(&self) -> usize {
        self.count(|e| matches!(e, RmsEvent::Requeued { .. }))
    }

    /// Cross-shard steals recorded (jobs withdrawn from this shard).
    pub fn steals(&self) -> usize {
        self.count(|e| matches!(e, RmsEvent::Stolen { .. }))
    }

    /// Resize transactions begun (multi-phase path only).
    pub fn resize_begins(&self) -> usize {
        self.count(|e| matches!(e, RmsEvent::ResizeBegin { .. }))
    }

    /// Resize transactions aborted.
    pub fn resize_aborts(&self) -> usize {
        self.count(|e| matches!(e, RmsEvent::ResizeAbort { .. }))
    }

    /// Resize transactions committed.
    pub fn resize_commits(&self) -> usize {
        self.count(|e| matches!(e, RmsEvent::ResizeCommit { .. }))
    }

    /// Jobs degraded to non-malleable after exhausting resize retries.
    pub fn degradations(&self) -> usize {
        self.count(|e| matches!(e, RmsEvent::Degraded { .. }))
    }

    /// Order-sensitive FNV-1a digest over every event and all its fields
    /// (times hashed bit-exactly).  Two logs digest equal iff they are
    /// bit-identical — the behavior-preservation contract the golden
    /// determinism test and the `hotpath_scale` checksum rely on.
    pub fn digest(&self) -> u64 {
        fn mix(h: &mut u64, x: u64) {
            *h ^= x;
            *h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        fn mix_action(h: &mut u64, a: &Action) {
            match a {
                Action::NoAction => mix(h, 0),
                Action::Expand { to } => {
                    mix(h, 1);
                    mix(h, *to as u64);
                }
                Action::Shrink { to } => {
                    mix(h, 2);
                    mix(h, *to as u64);
                }
            }
        }
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for e in &self.events {
            match e {
                RmsEvent::Submitted { job, time } => {
                    mix(&mut h, 1);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                }
                RmsEvent::Started { job, time, procs } => {
                    mix(&mut h, 2);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                    mix(&mut h, *procs as u64);
                }
                RmsEvent::Finished { job, time } => {
                    mix(&mut h, 3);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                }
                RmsEvent::Cancelled { job, time } => {
                    mix(&mut h, 4);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                }
                RmsEvent::DmrDecision { job, time, action } => {
                    mix(&mut h, 5);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                    mix_action(&mut h, action);
                }
                RmsEvent::Expanded { job, time, from, to } => {
                    mix(&mut h, 6);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                    mix(&mut h, *from as u64);
                    mix(&mut h, *to as u64);
                }
                RmsEvent::Shrunk { job, time, from, to } => {
                    mix(&mut h, 7);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                    mix(&mut h, *from as u64);
                    mix(&mut h, *to as u64);
                }
                RmsEvent::ExpandAborted { job, time } => {
                    mix(&mut h, 8);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                }
                RmsEvent::NodeFailed { node, time } => {
                    mix(&mut h, 9);
                    mix(&mut h, *node as u64);
                    mix(&mut h, time.to_bits());
                }
                RmsEvent::NodeRepaired { node, time } => {
                    mix(&mut h, 10);
                    mix(&mut h, *node as u64);
                    mix(&mut h, time.to_bits());
                }
                RmsEvent::DrainStarted { node, time } => {
                    mix(&mut h, 11);
                    mix(&mut h, *node as u64);
                    mix(&mut h, time.to_bits());
                }
                RmsEvent::DrainEnded { node, time } => {
                    mix(&mut h, 12);
                    mix(&mut h, *node as u64);
                    mix(&mut h, time.to_bits());
                }
                RmsEvent::Interrupted { job, time, node } => {
                    mix(&mut h, 13);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                    mix(&mut h, *node as u64);
                }
                RmsEvent::Requeued { job, time } => {
                    mix(&mut h, 14);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                }
                RmsEvent::Rescued { job, time, from, to } => {
                    mix(&mut h, 15);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                    mix(&mut h, *from as u64);
                    mix(&mut h, *to as u64);
                }
                RmsEvent::Stolen { job, time } => {
                    mix(&mut h, 16);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                }
                RmsEvent::ResizeBegin { job, time, from, to } => {
                    mix(&mut h, 17);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                    mix(&mut h, *from as u64);
                    mix(&mut h, *to as u64);
                }
                RmsEvent::ResizeAbort { job, time, phase } => {
                    mix(&mut h, 18);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                    mix(&mut h, *phase as u64);
                }
                RmsEvent::ResizeCommit { job, time, procs } => {
                    mix(&mut h, 19);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                    mix(&mut h, *procs as u64);
                }
                RmsEvent::Degraded { job, time } => {
                    mix(&mut h, 20);
                    mix(&mut h, *job);
                    mix(&mut h, time.to_bits());
                }
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        let mut log = EventLog::default();
        log.push(RmsEvent::Expanded { job: 1, time: 0.0, from: 8, to: 16 });
        log.push(RmsEvent::Shrunk { job: 2, time: 1.0, from: 16, to: 8 });
        log.push(RmsEvent::Shrunk { job: 2, time: 2.0, from: 8, to: 4 });
        assert_eq!(log.expansions(), 1);
        assert_eq!(log.shrinks(), 2);
        assert_eq!(log.all().len(), 3);
    }

    #[test]
    fn digest_is_order_and_field_sensitive() {
        let mut a = EventLog::default();
        a.push(RmsEvent::Submitted { job: 1, time: 0.0 });
        a.push(RmsEvent::Started { job: 1, time: 1.0, procs: 8 });
        let mut b = EventLog::default();
        b.push(RmsEvent::Started { job: 1, time: 1.0, procs: 8 });
        b.push(RmsEvent::Submitted { job: 1, time: 0.0 });
        assert_ne!(a.digest(), b.digest(), "order matters");

        let mut c = EventLog::default();
        c.push(RmsEvent::Submitted { job: 1, time: 0.0 });
        c.push(RmsEvent::Started { job: 1, time: 1.0, procs: 16 });
        assert_ne!(a.digest(), c.digest(), "fields matter");

        let mut d = EventLog::default();
        d.push(RmsEvent::Submitted { job: 1, time: 0.0 });
        d.push(RmsEvent::Started { job: 1, time: 1.0, procs: 8 });
        assert_eq!(a.digest(), d.digest(), "identical logs digest equal");

        // Decision actions are distinguishable.
        let mut e = EventLog::default();
        e.push(RmsEvent::DmrDecision { job: 2, time: 3.0, action: Action::Expand { to: 8 } });
        let mut f = EventLog::default();
        f.push(RmsEvent::DmrDecision { job: 2, time: 3.0, action: Action::Shrink { to: 8 } });
        assert_ne!(e.digest(), f.digest());
    }

    #[test]
    fn resilience_events_distinct_in_digest() {
        let digest_of = |e: RmsEvent| {
            let mut l = EventLog::default();
            l.push(e);
            l.digest()
        };
        let all = [
            digest_of(RmsEvent::NodeFailed { node: 1, time: 2.0 }),
            digest_of(RmsEvent::NodeRepaired { node: 1, time: 2.0 }),
            digest_of(RmsEvent::DrainStarted { node: 1, time: 2.0 }),
            digest_of(RmsEvent::DrainEnded { node: 1, time: 2.0 }),
            digest_of(RmsEvent::Interrupted { job: 1, time: 2.0, node: 1 }),
            digest_of(RmsEvent::Requeued { job: 1, time: 2.0 }),
            digest_of(RmsEvent::Rescued { job: 1, time: 2.0, from: 8, to: 4 }),
            digest_of(RmsEvent::Stolen { job: 1, time: 2.0 }),
            digest_of(RmsEvent::ResizeBegin { job: 1, time: 2.0, from: 8, to: 4 }),
            digest_of(RmsEvent::ResizeAbort { job: 1, time: 2.0, phase: 1 }),
            digest_of(RmsEvent::ResizeCommit { job: 1, time: 2.0, procs: 8 }),
            digest_of(RmsEvent::Degraded { job: 1, time: 2.0 }),
        ];
        for (i, a) in all.iter().enumerate() {
            for (j, b) in all.iter().enumerate() {
                if i != j {
                    assert_ne!(a, b, "variants {i} and {j} collide");
                }
            }
        }
        // field-sensitivity of the new variants
        assert_ne!(
            digest_of(RmsEvent::Rescued { job: 1, time: 2.0, from: 8, to: 4 }),
            digest_of(RmsEvent::Rescued { job: 1, time: 2.0, from: 8, to: 2 }),
        );
        let mut log = EventLog::default();
        log.push(RmsEvent::NodeFailed { node: 3, time: 1.0 });
        log.push(RmsEvent::Rescued { job: 2, time: 1.0, from: 32, to: 16 });
        log.push(RmsEvent::Requeued { job: 4, time: 2.0 });
        log.push(RmsEvent::Stolen { job: 5, time: 3.0 });
        assert_eq!(log.node_failures(), 1);
        assert_eq!(log.rescues(), 1);
        assert_eq!(log.requeues(), 1);
        assert_eq!(log.steals(), 1);
    }

    #[test]
    fn resize_transaction_events_distinct_and_counted() {
        let digest_of = |e: RmsEvent| {
            let mut l = EventLog::default();
            l.push(e);
            l.digest()
        };
        // The abort phase code is digest-covered.
        assert_ne!(
            digest_of(RmsEvent::ResizeAbort { job: 1, time: 2.0, phase: 0 }),
            digest_of(RmsEvent::ResizeAbort { job: 1, time: 2.0, phase: 2 }),
        );
        // Begin and commit are field-sensitive.
        assert_ne!(
            digest_of(RmsEvent::ResizeBegin { job: 1, time: 2.0, from: 8, to: 16 }),
            digest_of(RmsEvent::ResizeBegin { job: 1, time: 2.0, from: 8, to: 32 }),
        );
        assert_ne!(
            digest_of(RmsEvent::ResizeCommit { job: 1, time: 2.0, procs: 8 }),
            digest_of(RmsEvent::ResizeCommit { job: 1, time: 2.0, procs: 16 }),
        );
        let mut log = EventLog::default();
        log.push(RmsEvent::ResizeBegin { job: 1, time: 1.0, from: 8, to: 16 });
        log.push(RmsEvent::ResizeAbort { job: 1, time: 2.0, phase: 1 });
        log.push(RmsEvent::ResizeBegin { job: 1, time: 3.0, from: 8, to: 16 });
        log.push(RmsEvent::ResizeCommit { job: 1, time: 4.0, procs: 16 });
        log.push(RmsEvent::Degraded { job: 2, time: 5.0 });
        assert_eq!(log.resize_begins(), 2);
        assert_eq!(log.resize_aborts(), 1);
        assert_eq!(log.resize_commits(), 1);
        assert_eq!(log.degradations(), 1);
    }
}
