//! Job records as tracked by the RMS.

use crate::workload::JobSpec;
use crate::{JobId, NodeId, Time};

/// Lifecycle of a job inside the RMS.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Queued, waiting for resources.
    Pending,
    /// Executing on its allocated nodes.
    Running,
    /// Mid-reconfiguration: the decision was returned to the runtime but
    /// the resize has not been committed yet (shrink: waiting for the
    /// ACK-synchronized release; expand: waiting for the spawn).
    Resizing,
    Completed,
    Cancelled,
}

/// Memoized `NoAction` DMR check: the no-op elision of the incremental
/// availability profile ([`crate::rms::profile`]).  Valid while the
/// RMS's state stamp is unchanged; never stored for expand/shrink
/// decisions (those mutate state, so their stamps die immediately).
#[derive(Debug, Clone, Copy)]
pub(crate) struct DmrMemo {
    /// The request the memoized decision answered.
    pub req: super::policy::DmrRequest,
    /// Clock of the memoized decision (same-instant hits are always
    /// sound; cross-clock hits additionally require the strategy's
    /// [`crate::rms::ReconfigPolicy::time_invariant`]).
    pub now: Time,
    /// `(cluster, pending-queue, profile)` version stamp at decision
    /// time.
    pub stamp: (u64, u64, u64),
}

/// One committed reconfiguration (for the per-job analysis of §7.3–7.5).
#[derive(Debug, Clone, Copy)]
pub struct ResizeEvent {
    /// Commit time.
    pub time: Time,
    /// Process count before the resize.
    pub from_procs: usize,
    /// Process count after the resize.
    pub to_procs: usize,
}

/// A job inside the RMS.
#[derive(Debug, Clone)]
pub struct Job {
    /// Id assigned at submission.
    pub id: JobId,
    /// The submission-time specification.
    pub spec: JobSpec,
    /// Lifecycle state.
    pub state: JobState,
    /// Nodes currently allocated to the job (empty while pending).
    pub nodes: Vec<NodeId>,
    /// Submission time.
    pub submit_time: Time,
    /// Execution start time (the last start, after requeues).
    pub start_time: Option<Time>,
    /// Finalization time.
    pub end_time: Option<Time>,
    /// Scheduler's estimate of when the job will finish (feeds backfill
    /// reservations; refreshed by the execution engine after resizes).
    pub expected_end: Option<Time>,
    /// Maximum-priority boost: set on resizer jobs (§5.2.1) and on the
    /// queued job that triggered a shrink (§4.3).
    pub qos_boost: bool,
    /// True for the internal "resizer job" of the expansion protocol.
    pub is_resizer: bool,
    /// Resizer jobs depend on their original job.
    pub depends_on: Option<JobId>,
    pub resize_log: Vec<ResizeEvent>,
    /// Times the job was killed by a node failure and requeued
    /// ([`crate::resilience`]); `start_time` then reflects the *last*
    /// start and `resize_log` the last incarnation.
    pub requeues: usize,
    /// The job exhausted its resize-transaction retries
    /// ([`crate::resilience::resize`]) and is non-malleable for the rest
    /// of the run: every policy sees `NoAction` for it from now on.
    pub degraded: bool,
    /// Last `NoAction` DMR decision, for the no-op check elision
    /// (invalidated implicitly: the stamp it carries stops matching).
    pub(crate) dmr_memo: Option<DmrMemo>,
}

impl Job {
    /// A freshly-submitted (pending) job.
    pub fn new(id: JobId, spec: JobSpec, now: Time) -> Self {
        Job {
            id,
            spec,
            state: JobState::Pending,
            nodes: Vec::new(),
            submit_time: now,
            start_time: None,
            end_time: None,
            expected_end: None,
            qos_boost: false,
            is_resizer: false,
            depends_on: None,
            resize_log: Vec::new(),
            requeues: 0,
            degraded: false,
            dmr_memo: None,
        }
    }

    /// Current number of processes (== nodes; one process per node, as in
    /// the paper's evaluation).
    pub fn procs(&self) -> usize {
        self.nodes.len()
    }

    /// Whether the job currently holds resources (running or mid-resize).
    pub fn is_active(&self) -> bool {
        matches!(self.state, JobState::Running | JobState::Resizing)
    }

    /// Waiting time (§7.5): submission until execution start.
    pub fn wait_time(&self) -> Option<f64> {
        self.start_time.map(|s| s - self.submit_time)
    }

    /// Execution time: start until end.
    pub fn exec_time(&self) -> Option<f64> {
        match (self.start_time, self.end_time) {
            (Some(s), Some(e)) => Some(e - s),
            _ => None,
        }
    }

    /// Completion time (§7.5): submission until finalization.
    pub fn completion_time(&self) -> Option<f64> {
        self.end_time.map(|e| e - self.submit_time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::config::AppKind;

    fn job() -> Job {
        let spec = JobSpec::from_app(AppKind::Cg, "CG-0".into(), 3.0, 1.0);
        Job::new(1, spec, 3.0)
    }

    #[test]
    fn times() {
        let mut j = job();
        assert_eq!(j.wait_time(), None);
        j.start_time = Some(10.0);
        j.end_time = Some(25.0);
        assert_eq!(j.wait_time(), Some(7.0));
        assert_eq!(j.exec_time(), Some(15.0));
        assert_eq!(j.completion_time(), Some(22.0));
    }

    #[test]
    fn procs_tracks_nodes() {
        let mut j = job();
        assert_eq!(j.procs(), 0);
        j.nodes = vec![0, 1, 2];
        assert_eq!(j.procs(), 3);
    }
}
