//! The workload manager facade — the Slurm-with-reconfiguration-plug-in of
//! the paper, as a *pure state machine*: no threads, no clock syscalls.
//! Both drivers call into it with explicit `now` timestamps:
//!
//! * the discrete-event engine ([`crate::des`]) with virtual time, and
//! * the live threaded driver ([`crate::live`]) with wall-clock time.
//!
//! The resize protocols follow §3/§5.2 exactly: expansion goes through an
//! internal *resizer job* submitted with maximum priority and a dependency
//! on the original job; its allocation is transferred (never freed, so no
//! other job can steal the nodes) and the resizer is cancelled.  Shrinking
//! returns the nodes to release; the runtime redistributes data, collects
//! ACKs, and only then commits the release.
//!
//! ## Complexity budget
//!
//! Every public operation is O(active jobs), never O(all jobs ever
//! submitted):
//!
//! * Job storage is split into a **live** map (pending + active) and an
//!   **archive** (completed/cancelled); scheduling passes never touch the
//!   archive.
//! * `running_jobs()`, `pending_user_jobs()` and `all_done()` are O(1)
//!   incrementally-maintained counters; the set of active jobs is a
//!   `BTreeSet` so the backfill projection iterates exactly the active
//!   jobs in a deterministic (ascending-id) order.
//! * The priority-ordered pending queue is cached behind a dirty flag:
//!   membership and boost changes invalidate it, while *pure aging*
//!   reuses it whenever that provably preserves the relative order —
//!   either every pending job is still inside the age-saturation
//!   horizon (age factors grow in lockstep) or every pending job was
//!   already *saturated* when the cache was sorted (age factors are all
//!   pinned at 1, so priorities are constants of time).  Set
//!   [`RmsConfig::cache_pending_order`] to `false` to force a re-sort on
//!   every pass (the golden determinism test runs both ways and asserts
//!   bit-identical event logs).
//! * The backfill projection reads the **incremental availability
//!   profile** ([`super::profile`]): a sorted end-time structure updated
//!   in O(log active) at every start/finish/resize/failure/requeue, so a
//!   scheduling pass walks projected ends in order instead of
//!   snapshotting all running jobs and sorting (the pre-profile
//!   behavior, kept as the differential reference behind
//!   [`RmsConfig::incremental_profile`] `= false`).
//! * **No-op pass elision**: version counters on the cluster, the
//!   pending queue and the profile form a state stamp; a scheduling
//!   pass that started nothing memoizes its stamp, and `schedule()`
//!   returns the empty answer in O(1) while the stamp (and the cached
//!   order's reuse window) still hold.  `dmr_check` likewise memoizes a
//!   `NoAction` decision per job and replays it (still logging the
//!   `DmrDecision` event, so event streams are bit-identical) while the
//!   stamp holds — across clock values only for strategies that declare
//!   [`ReconfigPolicy::time_invariant`].
//! * The `PendingInfo`/sorted-ends scratch buffers are owned by the
//!   `Rms` and reused across passes, so a steady-state pass performs no
//!   heap allocation.
//!
//! Mutating `cfg` (weights, policy) mid-run is not supported — the cached
//! queue order assumes stable weights between invalidations.

use std::collections::{BTreeSet, HashMap};

use super::backfill::{plan_starts_with, PendingInfo, RunningInfo, SortedEnds};
use super::events::{EventLog, RmsEvent};
use super::job::{DmrMemo, Job, JobState, ResizeEvent};
use super::policy::{
    Action, DmrRequest, PolicyConfig, PolicyContext, PolicyStrategy, ReconfigPolicy, SystemView,
    UsageView,
};
use super::profile::{AvailProfile, ProfileShadow};
use super::queue::{pending_cmp, priority, PriorityWeights};
use crate::cluster::Cluster;
use crate::workload::JobSpec;
use crate::{JobId, NodeId, Time};

/// RMS configuration.
#[derive(Debug, Clone)]
pub struct RmsConfig {
    /// Cluster size (nodes).
    pub nodes: usize,
    /// EASY backfill (§7.2).
    pub backfill: bool,
    /// Multifactor priority weights for the pending queue.
    pub weights: PriorityWeights,
    /// Knobs read by the selected reconfiguration strategy.
    pub policy: PolicyConfig,
    /// Which reconfiguration strategy decides DMR calls (see
    /// [`crate::rms::policy`]).  The default, `ThroughputAware`, is the
    /// paper's §4 rule and the golden baseline.
    pub strategy: PolicyStrategy,
    /// Give the queued job that triggered a shrink the maximum priority
    /// (§4.3).  Ablatable.
    pub shrink_priority_boost: bool,
    /// Record every `telemetry_stride`-th telemetry snapshot.  `1`
    /// (default) is lossless — identical to the pre-stride behavior at
    /// paper scale; larger strides downsample the Fig. 6 series on
    /// multi-thousand-job traces (utilization statistics then become
    /// approximations); `0` disables telemetry entirely.
    pub telemetry_stride: usize,
    /// Reuse the cached priority order of the pending queue when provably
    /// unchanged (see module docs).  Disabled only by the golden
    /// determinism test, which compares both paths bit-for-bit.
    pub cache_pending_order: bool,
    /// Drive the backfill projection from the incrementally maintained
    /// availability profile and elide provably no-op scheduling passes /
    /// DMR checks (see module docs).  `false` restores the
    /// rebuild-and-sort reference path with no elision — the
    /// differential baseline the golden determinism tests compare
    /// against bit-for-bit.
    pub incremental_profile: bool,
    /// Retain terminal jobs in the archive, the raw event list and the
    /// telemetry series (default).  `false` is the streaming-replay
    /// memory model: terminal jobs fold into [`Rms::fold`] and are
    /// dropped, the event log keeps only its rolling digest + counters,
    /// and telemetry series stay empty — memory stays O(active jobs)
    /// over million-job runs.  Per-job reports, trace export and
    /// `gains_vs` need retention; every CSV-level measure does not (see
    /// `docs/ARCHITECTURE.md`, "Streaming replay & memory model").
    pub keep_records: bool,
}

impl Default for RmsConfig {
    fn default() -> Self {
        Self {
            nodes: crate::cluster::DEFAULT_NODES,
            backfill: true,
            weights: PriorityWeights::default(),
            policy: PolicyConfig::default(),
            strategy: PolicyStrategy::default(),
            shrink_priority_boost: true,
            telemetry_stride: 1,
            cache_pending_order: true,
            incremental_profile: true,
            keep_records: true,
        }
    }
}

/// Hot-path instrumentation: how many scheduling passes / DMR checks
/// ran, and how many were elided by the no-op memoization (see module
/// docs).  Purely observational — not part of the event log or any
/// digest.
#[derive(Debug, Default, Clone, Copy)]
pub struct PassStats {
    /// `schedule()` invocations that got past the empty-queue early
    /// exit.
    pub sched_passes: u64,
    /// Of those, passes answered from the no-op memo in O(1).
    pub sched_elided: u64,
    /// `dmr_check` invocations.
    pub dmr_checks: u64,
    /// Of those, checks answered from the per-job `NoAction` memo.
    pub dmr_elided: u64,
}

/// A job started by a scheduling pass.
#[derive(Debug, Clone)]
pub struct Started {
    /// The started job.
    pub job: JobId,
    /// Its allocation.
    pub nodes: Vec<NodeId>,
}

/// Victim report of a node failure ([`Rms::fail_node`]): the job that
/// held the failed node and how many of its nodes survive.
#[derive(Debug, Clone, Copy)]
pub struct NodeFailure {
    /// The job that held the failed node.
    pub job: JobId,
    /// Nodes the job still holds after losing the failed one.
    pub survivors: usize,
}

/// Outcome of a (synchronous) DMR check.
#[derive(Debug, Clone)]
pub enum DmrOutcome {
    NoAction,
    /// Expansion granted: the job now also owns `new_nodes` (transferred
    /// from the resizer job).  The runtime must spawn processes there and
    /// then call [`Rms::commit_resize`].
    Expand { to: usize, new_nodes: Vec<NodeId> },
    /// Shrink requested: the runtime must drain `release_nodes` (data out,
    /// ACKs in — §5.2.2) and then call [`Rms::commit_shrink_to`].
    Shrink { to: usize, release_nodes: Vec<NodeId> },
}

impl DmrOutcome {
    /// Stable lowercase name (logs, CSV cells).
    pub fn action_name(&self) -> &'static str {
        match self {
            DmrOutcome::NoAction => "no-action",
            DmrOutcome::Expand { .. } => "expand",
            DmrOutcome::Shrink { .. } => "shrink",
        }
    }
}

/// Time-series telemetry for Fig. 6 (allocated nodes / running jobs /
/// completed jobs over time).
#[derive(Debug, Default, Clone)]
pub struct Telemetry {
    /// (time, allocated nodes) samples.
    pub alloc_series: Vec<(Time, f64)>,
    /// (time, running user jobs) samples.
    pub running_series: Vec<(Time, f64)>,
    /// (time, completed user jobs) samples.
    pub completed_series: Vec<(Time, f64)>,
}

/// The workload manager.
pub struct Rms {
    /// Configuration the manager was built with (stable for the run).
    pub cfg: RmsConfig,
    /// The simulated machine.
    pub cluster: Cluster,
    /// The reconfiguration strategy built from `cfg.strategy`.
    policy: Box<dyn ReconfigPolicy>,
    /// Pending + active jobs — everything a scheduling pass may touch.
    live: HashMap<JobId, Job>,
    /// Completed/cancelled jobs, kept for metrics extraction only.
    archived: HashMap<JobId, Job>,
    /// Pending (queued) job ids, unordered; ordering is cached below.
    pending: Vec<JobId>,
    /// Active (Running | Resizing) job ids, resizers included; BTreeSet so
    /// the backfill projection iterates deterministically.
    active: BTreeSet<JobId>,
    next_id: JobId,
    completed_count: usize,
    /// Pending non-resizer jobs (incremental mirror of `pending` minus
    /// resizers).
    pending_user: usize,
    /// Active non-resizer jobs.
    active_user: usize,
    // --- cached priority order of `pending` --------------------------
    pending_order: Vec<JobId>,
    order_scratch: Vec<(f64, Time, JobId)>,
    /// `pending_order` matches `pending` membership and boosts.
    order_valid: bool,
    /// Time the cached order was sorted at.
    order_now: Time,
    /// Earliest submit time among the cached pending jobs (age-saturation
    /// reuse bound: nobody saturated yet ⇒ ages grow in lockstep).
    order_oldest_submit: Time,
    /// Latest submit time among the cached pending jobs (the complementary
    /// bound: everybody already saturated at `order_now` ⇒ ages pinned).
    order_youngest_submit: Time,
    /// Bumped whenever the cached order's *content* may change: every
    /// [`Rms::invalidate_pending_order`] call and every actual re-sort in
    /// [`Rms::refresh_pending_order`].  One component of the elision
    /// state stamp.
    pending_version: u64,
    // --- incremental availability profile + no-op elision ------------
    /// Sorted end-time structure mirroring the active set (kept in sync
    /// even when `cfg.incremental_profile` is off, so the flag only
    /// selects the *read* path and invariants hold in both modes).
    profile: AvailProfile,
    /// `(clock, state stamp)` of the last scheduling pass that started
    /// nothing; lets an identical pass return in O(1).
    sched_noop: Option<(Time, (u64, u64, u64))>,
    /// Pass/check counters (observational only).
    passes: PassStats,
    // --- reusable scheduling-pass scratch buffers --------------------
    running_buf: Vec<RunningInfo>,
    eligible_buf: Vec<PendingInfo>,
    ends_scratch: Vec<(Time, usize)>,
    starts_buf: Vec<JobId>,
    /// Starts not yet observed by the execution driver.  Scheduling passes
    /// can run *inside* `dmr_check` (the resizer-job protocol), so drivers
    /// must drain this buffer rather than rely on `schedule`'s return
    /// value alone.
    recent_starts: Vec<Started>,
    /// Append-only event log (the golden digests hash it).
    pub log: EventLog,
    /// Fig. 6 telemetry series.
    pub telemetry: Telemetry,
    telemetry_tick: u64,
    /// Archive-time streaming metrics accumulator — the canonical source
    /// of every run-level job measure, maintained identically whether or
    /// not records are retained (so streamed and materialized summaries
    /// agree by construction).  Seal via [`Rms::seal_metrics`] before
    /// reading the utilization integral.
    pub fold: crate::metrics::MetricsFold,
    /// High-water mark of the live map (pending + active jobs) — the
    /// peak-resident job count the streaming memory model is bounded by.
    peak_live: usize,
}

impl Rms {
    /// A fresh manager over an empty `cfg.nodes`-node cluster, with the
    /// reconfiguration strategy built from `cfg.strategy`.
    pub fn new(cfg: RmsConfig) -> Self {
        let cluster = Cluster::new(cfg.nodes);
        let policy = cfg.strategy.build(&cfg.policy);
        let mut log = EventLog::default();
        log.set_retain(cfg.keep_records);
        Self {
            cfg,
            cluster,
            policy,
            live: HashMap::new(),
            archived: HashMap::new(),
            pending: Vec::new(),
            active: BTreeSet::new(),
            next_id: 1,
            completed_count: 0,
            pending_user: 0,
            active_user: 0,
            pending_order: Vec::new(),
            order_scratch: Vec::new(),
            order_valid: false,
            order_now: 0.0,
            order_oldest_submit: f64::INFINITY,
            order_youngest_submit: f64::NEG_INFINITY,
            pending_version: 0,
            profile: AvailProfile::default(),
            sched_noop: None,
            passes: PassStats::default(),
            running_buf: Vec::new(),
            eligible_buf: Vec::new(),
            ends_scratch: Vec::new(),
            starts_buf: Vec::new(),
            recent_starts: Vec::new(),
            log,
            telemetry: Telemetry::default(),
            telemetry_tick: 0,
            fold: crate::metrics::MetricsFold::default(),
            peak_live: 0,
        }
    }

    /// Drain the buffer of starts the driver has not yet launched.
    pub fn take_recent_starts(&mut self) -> Vec<Started> {
        std::mem::take(&mut self.recent_starts)
    }

    // ------------------------------------------------------------------
    // Introspection

    /// Look up a job, live or archived.
    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.live.get(&id).or_else(|| self.archived.get(&id))
    }

    /// All jobs ever submitted (live first, then archived; order within
    /// each group is unspecified — metrics sort by submit time).
    pub fn jobs(&self) -> impl Iterator<Item = &Job> {
        self.live.values().chain(self.archived.values())
    }

    /// Pending *user* jobs (resizer jobs excluded).  O(1).
    pub fn pending_user_jobs(&self) -> usize {
        self.pending_user
    }

    /// Active (running or resizing) user jobs.  O(1).
    pub fn running_jobs(&self) -> usize {
        self.active_user
    }

    /// Jobs that ran to completion.  Resizer jobs never appear here —
    /// the expansion protocol always cancels them (commit and abort
    /// paths alike), so on a drained workload this equals the user-job
    /// count.  O(1).
    pub fn completed_jobs(&self) -> usize {
        self.completed_count
    }

    /// All user jobs have completed (drained workload).  O(1).
    pub fn all_done(&self) -> bool {
        self.pending.is_empty() && self.active_user == 0
    }

    /// Hot-path pass/elision counters (observational; see [`PassStats`]).
    pub fn pass_stats(&self) -> PassStats {
        self.passes
    }

    /// High-water mark of the live map: the most jobs (pending + active,
    /// resizers included) ever resident at once.  Under the streaming
    /// memory model this — not the total job count — bounds the
    /// manager's job storage.
    pub fn peak_live(&self) -> usize {
        self.peak_live
    }

    /// Close the metrics fold's utilization integral at the end of the
    /// run (`t1` = the makespan).  The engines call this once after the
    /// event loop drains; idempotent.
    pub fn seal_metrics(&mut self, t1: Time) {
        self.fold.seal_util(t1);
    }

    /// Read-only view of the incremental availability profile (tests,
    /// invariant checks).
    pub fn profile(&self) -> &AvailProfile {
        &self.profile
    }

    /// The state stamp driving no-op elision: equal stamps prove that
    /// the free pool, the pending queue (membership, boosts *and* cached
    /// order content) and every active job's (procs, expected end) are
    /// unchanged — each component is a monotonic version counter, so
    /// equality can never alias across a mutation.
    fn stamp(&self) -> (u64, u64, u64) {
        (self.cluster.version(), self.pending_version, self.profile.version())
    }

    // ------------------------------------------------------------------
    // Cached pending-queue order

    /// Recompute or reuse the priority order of the pending queue at
    /// `now`.  The reuse conditions (and their soundness arguments) live
    /// on [`Rms::order_reusable`], the shared predicate.
    fn refresh_pending_order(&mut self, now: Time) {
        if self.order_reusable(now) {
            return;
        }
        // The cached order's content is about to be replaced: bump the
        // queue version so memoized no-op answers taken against the old
        // order can no longer match (the re-sorted order may differ).
        self.pending_version += 1;
        let total = self.cluster.total();
        self.order_scratch.clear();
        let mut oldest = f64::INFINITY;
        let mut youngest = f64::NEG_INFINITY;
        for &id in &self.pending {
            let j = &self.live[&id];
            oldest = oldest.min(j.submit_time);
            youngest = youngest.max(j.submit_time);
            self.order_scratch.push((
                priority(j, &self.cfg.weights, total, now),
                j.submit_time,
                id,
            ));
        }
        self.order_scratch.sort_by(pending_cmp);
        self.pending_order.clear();
        self.pending_order.extend(self.order_scratch.iter().map(|k| k.2));
        self.order_valid = true;
        self.order_now = now;
        self.order_oldest_submit = oldest;
        self.order_youngest_submit = youngest;
    }

    fn invalidate_pending_order(&mut self) {
        self.order_valid = false;
        self.pending_version += 1;
    }

    /// Whether the cached pending order may be reused at `now` — the one
    /// reuse predicate shared by [`Rms::refresh_pending_order`] (the
    /// `&mut` sorting path) and `view_at` (the `&self` peeking path), so
    /// the two can never drift.  Reuse is sound (order provably equal to
    /// a fresh sort) in either of two regimes, given unchanged
    /// membership/boosts (`order_valid`):
    ///
    /// * **Lockstep aging** — every cached pending job is still below
    ///   the age-saturation horizon at `now`: all age factors have grown
    ///   by the same amount since the cached sort, preserving pairwise
    ///   order.
    /// * **Full saturation** — every cached pending job was *already*
    ///   saturated when the cache was sorted: all age factors are pinned
    ///   at 1 from then on, so priorities are constants of time and a
    ///   fresh sort would compute identical keys.  This is the
    ///   deep-backlog regime (thousands of queued jobs, all older than
    ///   the horizon) where the pre-existing rule re-sorted on every
    ///   single pass.
    fn order_reusable(&self, now: Time) -> bool {
        let horizon = self.cfg.weights.age_horizon;
        self.order_valid
            && self.cfg.cache_pending_order
            && (now == self.order_now
                || (now > self.order_now
                    && (now - self.order_oldest_submit < horizon
                        || self.order_now - self.order_youngest_submit >= horizon)))
    }

    fn view(&mut self, now: Time) -> SystemView {
        self.refresh_pending_order(now);
        let head = self
            .pending_order
            .iter()
            .copied()
            .find(|id| !self.live[id].is_resizer);
        SystemView {
            available: self.cluster.available(),
            pending_jobs: self.pending_user,
            head_need: head.map(|id| self.live[&id].spec.procs),
        }
    }

    /// Side-effect-free equivalent of [`Rms::view`], used by
    /// [`Rms::dmr_peek`] so peeking stays `&self`.  While the cached
    /// pending order is reusable (the shared `order_reusable` predicate)
    /// the head comes from a read-only cache lookup, exactly as the
    /// `&mut` path would see it; otherwise the head is found by a single
    /// `min_by` scan under the same total comparator ([`pending_cmp`]),
    /// which yields exactly the first element the sort would produce.
    /// Cost: one scan is cheaper than the sort `view()` would pay in the
    /// same (stale-cache) situation, but a *stretch* of peeks with no
    /// intervening `&mut` pass re-scans each time where the old mutable
    /// peek sorted once and cached — strict immutability trades that
    /// amortization away.  Per event this stays O(pending), within the
    /// O(active + pending) budget.
    fn view_at(&self, now: Time) -> SystemView {
        let head = if self.order_reusable(now) {
            self.pending_order
                .iter()
                .copied()
                .find(|id| !self.live[id].is_resizer)
        } else {
            let total = self.cluster.total();
            self.pending
                .iter()
                .copied()
                .filter(|id| !self.live[id].is_resizer)
                .map(|id| {
                    let j = &self.live[&id];
                    (priority(j, &self.cfg.weights, total, now), j.submit_time, id)
                })
                .min_by(pending_cmp)
                .map(|k| k.2)
        };
        SystemView {
            available: self.cluster.available(),
            pending_jobs: self.pending_user,
            head_need: head.map(|id| self.live[&id].spec.procs),
        }
    }

    /// Assemble the decision context for `id`'s DMR call: the system
    /// view plus the job's own facts (user, deadline, completion
    /// estimate) and — only when the strategy opts in via
    /// [`ReconfigPolicy::wants_usage`] — the per-user usage indices
    /// (an O(active + pending) scan the default strategy never pays).
    fn policy_ctx<'a>(
        &self,
        id: JobId,
        current: usize,
        req: &'a DmrRequest,
        view: SystemView,
        now: Time,
    ) -> PolicyContext<'a> {
        let job = &self.live[&id];
        let mut ctx = PolicyContext::new(now, current, req, view);
        ctx.user = job.spec.user;
        ctx.deadline = job.spec.deadline;
        ctx.expected_end = job.expected_end;
        if self.policy.wants_usage() {
            // One resizer-excluded pass supplies numerator *and*
            // denominator: `busy_nodes` must not count allocations held
            // by in-flight resizer jobs, or every user would read as
            // under-share while an expansion protocol is in progress.
            let mut users = std::collections::BTreeSet::new();
            let mut user_nodes = 0usize;
            let mut busy_nodes = 0usize;
            for aid in &self.active {
                let a = &self.live[aid];
                if a.is_resizer {
                    continue;
                }
                users.insert(a.spec.user);
                busy_nodes += a.nodes.len();
                if a.spec.user == ctx.user {
                    user_nodes += a.nodes.len();
                }
            }
            let user_pending = self
                .pending
                .iter()
                .filter(|pid| {
                    let p = &self.live[*pid];
                    !p.is_resizer && p.spec.user == ctx.user
                })
                .count();
            ctx.usage = Some(UsageView {
                user_nodes,
                busy_nodes,
                active_users: users.len().max(1),
                user_pending,
            });
        }
        ctx
    }

    // ------------------------------------------------------------------
    // Submission / completion

    /// Submit a job to the pending queue; returns its assigned id.
    pub fn submit(&mut self, spec: JobSpec, now: Time) -> JobId {
        let id = self.next_id;
        self.next_id += 1;
        let job = Job::new(id, spec, now);
        self.live.insert(id, job);
        self.peak_live = self.peak_live.max(self.live.len());
        self.pending.push(id);
        self.pending_user += 1;
        self.invalidate_pending_order();
        self.log.push(RmsEvent::Submitted { job: id, time: now });
        id
    }

    /// Mark a running job finished and release its nodes.
    pub fn finish(&mut self, id: JobId, now: Time) {
        let mut job = self.live.remove(&id).expect("finish: unknown job");
        assert!(job.is_active(), "finish: job {id} not active");
        job.state = JobState::Completed;
        job.end_time = Some(now);
        let nodes = std::mem::take(&mut job.nodes);
        self.cluster.release(id, &nodes).expect("finish: release");
        self.active.remove(&id);
        self.profile.remove(id);
        if !job.is_resizer {
            self.active_user -= 1;
        }
        self.completed_count += 1;
        // Archive-time metrics fold: canonical for both memory models,
        // so the summary never depends on whether records are kept.
        self.fold.fold_job(&job);
        if self.cfg.keep_records {
            self.archived.insert(id, job);
        }
        self.log.push(RmsEvent::Finished { job: id, time: now });
        self.snapshot(now);
    }

    /// Cancel a pending job (also used for resizer jobs).
    pub fn cancel(&mut self, id: JobId, now: Time) {
        if let Some(pos) = self.pending.iter().position(|&p| p == id) {
            // Ordering is recomputed per pass from the cached keys, so the
            // queue position is irrelevant: O(1) swap_remove, not O(n).
            self.pending.swap_remove(pos);
            self.invalidate_pending_order();
        }
        let mut job = self.live.remove(&id).expect("cancel: unknown job");
        if job.state == JobState::Pending && !job.is_resizer {
            self.pending_user -= 1;
        }
        if job.is_active() {
            self.active.remove(&id);
            self.profile.remove(id);
            if !job.is_resizer {
                self.active_user -= 1;
            }
        }
        if !job.nodes.is_empty() {
            let nodes = std::mem::take(&mut job.nodes);
            self.cluster.release(id, &nodes).expect("cancel: release");
        }
        job.state = JobState::Cancelled;
        job.end_time = Some(now);
        // No-op for every job cancel() actually sees (resizers and
        // never-started jobs fail the fold's filter), but kept symmetric
        // with finish() so the invariant is structural, not situational.
        self.fold.fold_job(&job);
        if self.cfg.keep_records {
            self.archived.insert(id, job);
        }
        self.log.push(RmsEvent::Cancelled { job: id, time: now });
    }

    // ------------------------------------------------------------------
    // Cross-shard work stealing (crate::federation)

    /// Pick the pending job a federated meta-scheduler should steal from
    /// this shard: the **lowest-priority** queued user job that fits in
    /// `free` nodes (scanning the priority order from the back keeps the
    /// shard's own head-of-queue — the job its backfill reservation
    /// protects — at home).  Resizer jobs, boosted jobs and jobs with a
    /// dependency are never candidates.  O(pending).
    pub fn steal_candidate(&mut self, free: usize, now: Time) -> Option<JobId> {
        if free == 0 || self.pending_user == 0 {
            return None;
        }
        self.refresh_pending_order(now);
        self.pending_order.iter().rev().copied().find(|id| {
            let j = &self.live[id];
            !j.is_resizer && !j.qos_boost && j.depends_on.is_none() && j.spec.min_procs <= free
        })
    }

    /// Withdraw a pending user job from this shard so it can re-submit on
    /// another shard: the job leaves the queue *and* the live map (no
    /// archiving — exactly one shard owns the job's record at any time),
    /// a [`RmsEvent::Stolen`] is logged, and the spec plus the original
    /// submission time are returned for the thief's `submit` (preserving
    /// queue aging).  Returns `None` if the job is not a stealable
    /// pending user job.
    pub fn withdraw(&mut self, id: JobId, now: Time) -> Option<(JobSpec, Time)> {
        let pos = self.pending.iter().position(|&p| p == id)?;
        let job = self.live.get(&id)?;
        if job.state != JobState::Pending || job.is_resizer {
            return None;
        }
        self.pending.swap_remove(pos);
        self.invalidate_pending_order();
        self.pending_user -= 1;
        let job = self.live.remove(&id).expect("withdraw: unknown job");
        self.log.push(RmsEvent::Stolen { job: id, time: now });
        Some((job.spec, job.submit_time))
    }

    /// Refresh the scheduler's estimate of a running job's end time
    /// (feeds backfill reservations; published to the availability
    /// profile when the job is active).
    pub fn set_expected_end(&mut self, id: JobId, t: Time) {
        if let Some(j) = self.live.get_mut(&id) {
            j.expected_end = Some(t);
            if j.is_active() {
                self.profile.set_end(id, t);
            }
        }
    }

    // ------------------------------------------------------------------
    // Scheduling pass

    /// One scheduling pass: start every pending job the policy allows.
    /// Returns the started jobs with their allocations.
    ///
    /// Cost: O(pending) — completed jobs are never visited, the backfill
    /// projection walks the incremental availability profile instead of
    /// snapshotting + sorting the active set, and the pass reuses the
    /// Rms-owned scratch buffers.  A pass provably identical to the last
    /// no-op pass (same clock-or-reusable-order, same state stamp)
    /// returns in O(1) without planning at all; see the module docs for
    /// the elision soundness argument.
    pub fn schedule(&mut self, now: Time) -> Vec<Started> {
        if self.pending.is_empty() {
            return Vec::new();
        }
        self.passes.sched_passes += 1;
        if self.cfg.incremental_profile {
            if let Some((t, stamp)) = self.sched_noop {
                // A no-op pass stays a no-op while nothing changed: at
                // the same clock trivially; at a later clock because
                // every reason a job failed to start only hardens with
                // time (backfill windows shrink as `now` grows, free
                // nodes and projected ends are pinned by the stamp, and
                // the order-reuse window pins the head).
                if stamp == self.stamp() && (now == t || self.order_reusable(now)) {
                    self.passes.sched_elided += 1;
                    return Vec::new();
                }
            }
        }
        self.refresh_pending_order(now);

        // Resizer jobs whose original is not active cannot start
        // (dependency); they are filtered from this pass.
        self.eligible_buf.clear();
        for &id in &self.pending_order {
            let j = &self.live[&id];
            let eligible = match j.depends_on {
                Some(dep) => self.live.get(&dep).map(|d| d.is_active()).unwrap_or(false),
                None => true,
            };
            if eligible {
                self.eligible_buf.push(PendingInfo {
                    id,
                    procs: j.spec.procs,
                    est_duration: j.spec.est_duration(),
                });
            }
        }

        let free = self.cluster.available();
        let backfill = self.cfg.backfill;
        let mut starts = std::mem::take(&mut self.starts_buf);
        if self.cfg.incremental_profile {
            // Profile path: no running-jobs snapshot at all — a blocked
            // head walks the sorted ends in order.
            let mut src =
                ProfileShadow { profile: &self.profile, scratch: &mut self.ends_scratch };
            plan_starts_with(free, &mut src, &self.eligible_buf, now, backfill, &mut starts);
        } else {
            // Reference path (differential baseline): snapshot active
            // jobs in ascending-id order and let the projection sort.
            self.running_buf.clear();
            for &id in &self.active {
                let j = &self.live[&id];
                self.running_buf.push(RunningInfo {
                    procs: j.procs(),
                    expected_end: j.expected_end.unwrap_or(now + j.spec.est_duration()),
                });
            }
            let mut src =
                SortedEnds { running: &self.running_buf, scratch: &mut self.ends_scratch };
            plan_starts_with(free, &mut src, &self.eligible_buf, now, backfill, &mut starts);
        }

        let mut out = Vec::with_capacity(starts.len());
        let mut started_user = 0usize;
        for &id in &starts {
            let procs = self.live[&id].spec.procs;
            let nodes = self.cluster.alloc(id, procs).expect("schedule: alloc");
            let (expected_end, est) = {
                let job = self.live.get_mut(&id).unwrap();
                job.nodes = nodes.clone();
                job.state = JobState::Running;
                job.start_time = Some(now);
                job.qos_boost = false; // boost consumed
                if !job.is_resizer {
                    started_user += 1;
                }
                (job.expected_end, job.spec.est_duration())
            };
            self.profile.insert(id, procs, expected_end, est);
            self.active.insert(id);
            self.log.push(RmsEvent::Started { job: id, time: now, procs });
            out.push(Started { job: id, nodes });
        }
        if !starts.is_empty() {
            // Single O(pending) sweep instead of one retain per start.
            let mut started_ids = starts.clone();
            started_ids.sort_unstable();
            self.pending.retain(|p| started_ids.binary_search(p).is_err());
            self.pending_user -= started_user;
            self.active_user += started_user;
            self.invalidate_pending_order();
        }
        self.starts_buf = starts;
        if !out.is_empty() {
            self.recent_starts.extend(out.iter().cloned());
            self.snapshot(now);
        }
        // Memoize a no-op pass: its stamp is untouched (nothing mutated),
        // so an identical follow-up pass can skip planning entirely.
        self.sched_noop = if out.is_empty() { Some((now, self.stamp())) } else { None };
        out
    }

    // ------------------------------------------------------------------
    // The DMR path (§5)

    /// Evaluate a DMR call from `id` (synchronous semantics: decision and
    /// resource movement happen now).  The decision is delegated to the
    /// configured [`ReconfigPolicy`] strategy.
    ///
    /// **No-op elision**: a `NoAction` decision is memoized per job with
    /// the state stamp it was taken under.  A repeated check whose stamp
    /// still matches — same free pool, same pending queue (membership,
    /// boosts, cached-order content), same active procs/ends — replays
    /// the memo in O(1) instead of reassembling the context, *still
    /// logging* the `DmrDecision` event so event streams stay
    /// bit-identical to the reference path.  Cross-clock replays are
    /// allowed only for strategies declaring
    /// [`ReconfigPolicy::time_invariant`] and only inside the cached
    /// order's reuse window (which pins the queue head the view would
    /// report).
    pub fn dmr_check(&mut self, id: JobId, req: &DmrRequest, now: Time) -> DmrOutcome {
        self.passes.dmr_checks += 1;
        if self.live[&id].degraded {
            // Resize retries exhausted ([`Rms::degrade`]): the policy is
            // never consulted again, but the decision event is still
            // logged so the digest covers the (non-)decision.
            self.log.push(RmsEvent::DmrDecision { job: id, time: now, action: Action::NoAction });
            return DmrOutcome::NoAction;
        }
        if self.cfg.incremental_profile {
            if let Some(memo) = self.live[&id].dmr_memo {
                if memo.req == *req
                    && memo.stamp == self.stamp()
                    && (now == memo.now
                        || (self.policy.time_invariant() && self.order_reusable(now)))
                {
                    self.passes.dmr_elided += 1;
                    self.log.push(RmsEvent::DmrDecision {
                        job: id,
                        time: now,
                        action: Action::NoAction,
                    });
                    return DmrOutcome::NoAction;
                }
            }
        }
        let current = self.live[&id].procs();
        let view = self.view(now);
        let ctx = self.policy_ctx(id, current, req, view, now);
        let action = self.policy.decide(&ctx);
        if self.cfg.incremental_profile && action == Action::NoAction {
            // Stamp *after* the view refresh (which may have re-sorted
            // the queue and bumped its version).
            let memo = DmrMemo { req: *req, now, stamp: self.stamp() };
            self.live.get_mut(&id).unwrap().dmr_memo = Some(memo);
        }
        self.log.push(RmsEvent::DmrDecision { job: id, time: now, action });
        match action {
            Action::NoAction => DmrOutcome::NoAction,
            Action::Expand { to } => self.begin_expand(id, to, now),
            Action::Shrink { to } => self.begin_shrink(id, to, now),
        }
    }

    /// Policy-only evaluation (the asynchronous path computes the decision
    /// ahead of time and applies it at the *next* reconfiguring point —
    /// §5.1; the queue may change in between, which is exactly the hazard
    /// Table 2 quantifies).  Takes `&self`: the queue head is found by a
    /// scan (`view_at`) instead of refreshing the cached order, so a peek
    /// is guaranteed side-effect-free — and provably identical, since the
    /// scan minimizes under the same total comparator the sort uses.
    pub fn dmr_peek(&self, id: JobId, req: &DmrRequest, now: Time) -> Action {
        if self.live[&id].degraded {
            return Action::NoAction;
        }
        let current = self.live[&id].procs();
        let view = self.view_at(now);
        let ctx = self.policy_ctx(id, current, req, view, now);
        self.policy.decide(&ctx)
    }

    /// Try to apply a previously-computed (async) decision.  Returns the
    /// outcome; an expand that can no longer be satisfied returns
    /// `Err(())` so the caller models the resizer-job timeout.
    pub fn dmr_apply(
        &mut self,
        id: JobId,
        action: Action,
        now: Time,
    ) -> Result<DmrOutcome, ()> {
        if self.live[&id].degraded {
            // A stale async decision computed before the degradation is
            // discarded; the applied outcome is logged as `NoAction`.
            self.log.push(RmsEvent::DmrDecision { job: id, time: now, action: Action::NoAction });
            return Ok(DmrOutcome::NoAction);
        }
        self.log.push(RmsEvent::DmrDecision { job: id, time: now, action });
        match action {
            Action::NoAction => Ok(DmrOutcome::NoAction),
            Action::Expand { to } => {
                let current = self.live[&id].procs();
                if to <= current {
                    return Ok(DmrOutcome::NoAction);
                }
                let delta = to - current;
                if self.cluster.available() < delta {
                    // Resizer job would sit pending: the caller models the
                    // wait/timeout (§5.2.1).
                    return Err(());
                }
                Ok(self.begin_expand(id, to, now))
            }
            Action::Shrink { to } => {
                let current = self.live[&id].procs();
                if to >= current {
                    return Ok(DmrOutcome::NoAction);
                }
                Ok(self.begin_shrink(id, to, now))
            }
        }
    }

    /// §5.2.1 expansion protocol: submit the resizer job (max priority,
    /// dependency on the original), let a scheduling pass allocate it,
    /// transfer its nodes to the original job, cancel it.
    fn begin_expand(&mut self, id: JobId, to: usize, now: Time) -> DmrOutcome {
        let current = self.live[&id].procs();
        assert!(to > current, "begin_expand: {to} <= {current}");
        let delta = to - current;

        // Resizer job: requests exactly the *difference*, "enabling the
        // original nodes to be reused".
        let mut rspec = self.live[&id].spec.clone();
        rspec.name = format!("{}-resizer", rspec.name);
        rspec.procs = delta;
        rspec.malleable = false;
        let rj = self.submit(rspec, now);
        {
            let r = self.live.get_mut(&rj).unwrap();
            r.is_resizer = true;
            r.qos_boost = true; // "RJ is set to the maximum priority"
            r.depends_on = Some(id);
        }
        // The freshly-submitted job is a resizer after all, and its boost
        // changed: fix the user count and drop the cached order.
        self.pending_user -= 1;
        self.invalidate_pending_order();

        let started = self.schedule(now);
        let got = started.iter().find(|s| s.job == rj).map(|s| s.nodes.clone());
        match got {
            Some(new_nodes) => {
                // Transfer RJ's allocation to the original job (update job
                // B to 0 nodes / update job A to NA+NB), then cancel RJ.
                self.cluster.transfer(rj, id, &new_nodes).expect("expand: transfer");
                {
                    let r = self.live.get_mut(&rj).unwrap();
                    r.nodes.clear();
                }
                self.cancel(rj, now);
                let procs = {
                    let job = self.live.get_mut(&id).unwrap();
                    job.nodes.extend_from_slice(&new_nodes);
                    job.state = JobState::Resizing;
                    job.resize_log.push(ResizeEvent {
                        time: now,
                        from_procs: current,
                        to_procs: to,
                    });
                    job.nodes.len()
                };
                self.profile.set_procs(id, procs);
                self.log.push(RmsEvent::Expanded { job: id, time: now, from: current, to });
                self.snapshot(now);
                DmrOutcome::Expand { to, new_nodes }
            }
            None => {
                // Could not start immediately (sync mode: abort right away
                // rather than wait — the scheduling decision was made on a
                // stale queue).
                self.cancel(rj, now);
                self.log.push(RmsEvent::ExpandAborted { job: id, time: now });
                DmrOutcome::NoAction
            }
        }
    }

    /// §5.2.2 shrink: pick the nodes to release (the tail of the job's
    /// allocation), boost the queued job that triggered the shrink, and
    /// hand the node list to the runtime for the ACK-synchronized drain.
    fn begin_shrink(&mut self, id: JobId, to: usize, now: Time) -> DmrOutcome {
        let current = self.live[&id].procs();
        assert!(to < current, "begin_shrink: {to} >= {current}");
        let release: Vec<NodeId> = self.live[&id].nodes[to..].to_vec();

        if self.cfg.shrink_priority_boost {
            // "the queued job that has triggered the shrinking event will
            // be assigned the maximum priority".
            self.refresh_pending_order(now);
            if let Some(head) = self
                .pending_order
                .iter()
                .copied()
                .find(|hid| !self.live[hid].is_resizer)
            {
                self.live.get_mut(&head).unwrap().qos_boost = true;
                self.invalidate_pending_order();
            }
        }

        let job = self.live.get_mut(&id).unwrap();
        job.state = JobState::Resizing;
        DmrOutcome::Shrink { to, release_nodes: release }
    }

    /// Commit a shrink to `to` processes (release the tail nodes) after
    /// the runtime collected all ACKs (§5.2.2).
    pub fn commit_shrink_to(&mut self, id: JobId, to: usize, now: Time) {
        let (released, from) = {
            let job = self.live.get_mut(&id).expect("commit_shrink_to");
            assert_eq!(job.state, JobState::Resizing, "job {id} not resizing");
            let from = job.nodes.len();
            assert!(to < from);
            let released: Vec<NodeId> = job.nodes.split_off(to);
            (released, from)
        };
        self.cluster.release(id, &released).expect("shrink: release");
        let job = self.live.get_mut(&id).unwrap();
        job.state = JobState::Running;
        job.resize_log.push(ResizeEvent { time: now, from_procs: from, to_procs: to });
        self.profile.set_procs(id, to);
        self.log.push(RmsEvent::Shrunk { job: id, time: now, from, to });
        self.snapshot(now);
    }

    /// Commit an expansion after the runtime spawned the new processes.
    pub fn commit_resize(&mut self, id: JobId, now: Time) {
        let job = self.live.get_mut(&id).expect("commit_resize");
        assert_eq!(job.state, JobState::Resizing, "job {id} not resizing");
        job.state = JobState::Running;
        let _ = now;
    }

    // ------------------------------------------------------------------
    // Resize-transaction rollback ([`crate::resilience::resize`])

    /// Roll back an aborted expansion transaction: the job returns to its
    /// pre-transaction `old_procs` process set (the granted tail of its
    /// allocation is released), the provisional resize-log entry pushed
    /// at grant time is dropped — so `resize_log` keeps recording only
    /// reconfigurations that *stuck*, and node-second integrals /
    /// expand counts derived from it stay clean — and a digest-covered
    /// [`RmsEvent::ResizeAbort`] records the abort `phase`.
    pub fn abort_expand_to(&mut self, id: JobId, old_procs: usize, now: Time, phase: u8) {
        let released = {
            let job = self.live.get_mut(&id).expect("abort_expand: unknown job");
            assert_eq!(job.state, JobState::Resizing, "abort_expand: job {id} not resizing");
            assert!(
                old_procs <= job.nodes.len(),
                "abort_expand: old {old_procs} > held {}",
                job.nodes.len()
            );
            job.nodes.split_off(old_procs)
        };
        if !released.is_empty() {
            self.cluster.release(id, &released).expect("abort_expand: release");
        }
        let job = self.live.get_mut(&id).unwrap();
        job.state = JobState::Running;
        job.resize_log.pop();
        self.profile.set_procs(id, old_procs);
        self.log.push(RmsEvent::ResizeAbort { job: id, time: now, phase });
        self.snapshot(now);
    }

    /// Roll back an aborted shrink transaction.  Shrinks hold every node
    /// until [`Rms::commit_shrink_to`], so nothing moves: the job's state
    /// flips back to running and the abort is logged.
    pub fn abort_shrink(&mut self, id: JobId, now: Time, phase: u8) {
        let job = self.live.get_mut(&id).expect("abort_shrink: unknown job");
        assert_eq!(job.state, JobState::Resizing, "abort_shrink: job {id} not resizing");
        job.state = JobState::Running;
        self.log.push(RmsEvent::ResizeAbort { job: id, time: now, phase });
        self.snapshot(now);
    }

    /// Degrade a job to non-malleable after its resize retries ran out:
    /// [`Job::degraded`] pins every future DMR decision to `NoAction`
    /// (check, peek and apply alike), so policy engines stop proposing
    /// resizes for it.  Logged as a digest-covered event.
    pub fn degrade(&mut self, id: JobId, now: Time) {
        let job = self.live.get_mut(&id).expect("degrade: unknown job");
        assert!(!job.degraded, "degrade: job {id} already degraded");
        job.degraded = true;
        self.log.push(RmsEvent::Degraded { job: id, time: now });
    }

    // ------------------------------------------------------------------
    // Resilience (crate::resilience): node failures, drains, recovery

    /// A node failure at `node` hit the machine.  If a job held the node,
    /// it becomes the failure's victim: the node is removed from its
    /// allocation (it is gone) and the caller decides between the shrink
    /// rescue ([`Rms::rescue_shrink_to`]) and kill + requeue
    /// ([`Rms::requeue_after_failure`]).
    pub fn fail_node(&mut self, node: NodeId, now: Time) -> Option<NodeFailure> {
        let victim = self.cluster.force_down(node);
        self.log.push(RmsEvent::NodeFailed { node, time: now });
        let id = victim?;
        let job = self.live.get_mut(&id).expect("failed node held by unknown job");
        debug_assert!(job.is_active(), "victim job {id} not active");
        debug_assert!(!job.is_resizer, "resizer jobs never hold nodes across events");
        job.nodes.retain(|&n| n != node);
        let survivors = job.nodes.len();
        self.profile.set_procs(id, survivors);
        self.log.push(RmsEvent::Interrupted { job: id, time: now, node });
        self.snapshot(now);
        Some(NodeFailure { job: id, survivors })
    }

    /// Repair a failed node (no-op unless it is `Down`).  Returns whether
    /// capacity was restored.
    pub fn repair_node(&mut self, node: NodeId, now: Time) -> bool {
        if *self.cluster.state(node) == crate::cluster::NodeState::Down {
            self.cluster.set_up(node);
            self.log.push(RmsEvent::NodeRepaired { node, time: now });
            true
        } else {
            false
        }
    }

    /// Put a node into maintenance drain: idle nodes go offline now,
    /// allocated nodes finish their current job first.
    pub fn begin_drain(&mut self, node: NodeId, now: Time) {
        self.cluster.begin_drain(node);
        self.log.push(RmsEvent::DrainStarted { node, time: now });
    }

    /// End a node's maintenance drain.  Returns whether capacity was
    /// restored (an offline node came back to the free pool).
    pub fn end_drain(&mut self, node: NodeId, now: Time) -> bool {
        let freed = self.cluster.end_drain(node);
        self.log.push(RmsEvent::DrainEnded { node, time: now });
        freed
    }

    /// Kill an interrupted job and put it back in the queue: its surviving
    /// nodes are released and it competes for resources again (restarting
    /// from its last checkpoint — the execution engine models the rework).
    pub fn requeue_after_failure(&mut self, id: JobId, now: Time) {
        let job = self.live.get_mut(&id).expect("requeue: unknown job");
        assert!(job.is_active(), "requeue: job {id} not active");
        assert!(!job.is_resizer, "requeue: resizer jobs cannot requeue");
        let nodes = std::mem::take(&mut job.nodes);
        job.state = JobState::Pending;
        job.start_time = None;
        job.expected_end = None;
        job.requeues += 1;
        job.resize_log.clear();
        if !nodes.is_empty() {
            self.cluster.release(id, &nodes).expect("requeue: release");
        }
        self.active.remove(&id);
        self.profile.remove(id);
        self.active_user -= 1;
        self.pending.push(id);
        self.pending_user += 1;
        self.invalidate_pending_order();
        self.log.push(RmsEvent::Requeued { job: id, time: now });
        self.snapshot(now);
    }

    /// Shrink an interrupted malleable job onto `to` of its surviving
    /// nodes (the failure already removed the dead node): the tail beyond
    /// `to` is released and the job keeps running.  The caller picked a
    /// factor-reachable `to` via [`crate::resilience::feasible_shrink`].
    pub fn rescue_shrink_to(&mut self, id: JobId, to: usize, now: Time) {
        let (released, survivors) = {
            let job = self.live.get_mut(&id).expect("rescue: unknown job");
            assert!(job.is_active(), "rescue: job {id} not active");
            let s = job.nodes.len();
            assert!(to <= s, "rescue: target {to} > survivors {s}");
            (job.nodes.split_off(to), s)
        };
        if !released.is_empty() {
            self.cluster.release(id, &released).expect("rescue: release");
        }
        self.profile.set_procs(id, to);
        let job = self.live.get_mut(&id).unwrap();
        job.state = JobState::Running;
        // `from` is the pre-failure size: survivors + the node that died.
        let from = survivors + 1;
        job.resize_log.push(ResizeEvent { time: now, from_procs: from, to_procs: to });
        self.log.push(RmsEvent::Rescued { job: id, time: now, from, to });
        self.snapshot(now);
    }

    /// Evacuate an interrupted *active* job off this shard during a
    /// correlated outage: its surviving nodes (possibly none — a
    /// whole-shard outage takes them all) are released, the record leaves
    /// the live map (no archiving — like [`Rms::withdraw`], exactly one
    /// shard owns a job's record at any time), and the spec plus the
    /// original submission time are returned for the target shard's
    /// `submit` (preserving queue aging; the engine carries the
    /// checkpointed progress).  Any pending resizer job still waiting on
    /// the evacuee is cancelled — its dependency is leaving the shard for
    /// good.  Logged as a digest-covered [`RmsEvent::Evacuated`] naming
    /// the target shard.
    pub fn evacuate(&mut self, id: JobId, to_shard: usize, now: Time) -> Option<(JobSpec, Time)> {
        let job = self.live.get_mut(&id)?;
        if !job.is_active() || job.is_resizer {
            return None;
        }
        let nodes = std::mem::take(&mut job.nodes);
        if !nodes.is_empty() {
            self.cluster.release(id, &nodes).expect("evacuate: release");
        }
        self.active.remove(&id);
        self.profile.remove(id);
        self.active_user -= 1;
        let job = self.live.remove(&id).expect("evacuate: unknown job");
        let orphaned: Vec<JobId> = self
            .pending
            .iter()
            .copied()
            .filter(|rid| {
                let j = &self.live[rid];
                j.is_resizer && j.depends_on == Some(id)
            })
            .collect();
        for rid in orphaned {
            self.cancel(rid, now);
        }
        self.log.push(RmsEvent::Evacuated { job: id, time: now, to: to_shard });
        self.snapshot(now);
        Some((job.spec, job.submit_time))
    }

    // ------------------------------------------------------------------
    // Telemetry

    fn snapshot(&mut self, now: Time) {
        // The utilization integral advances on *every* snapshot call —
        // before stride gating or the keep_records check — so util_mean
        // is exact and identical across memory models and strides.
        self.fold.observe_alloc(now, self.cluster.allocated() as f64);
        let stride = self.cfg.telemetry_stride;
        if stride == 0 || !self.cfg.keep_records {
            return;
        }
        self.telemetry_tick += 1;
        if stride > 1 && self.telemetry_tick % stride as u64 != 0 {
            return;
        }
        self.telemetry
            .alloc_series
            .push((now, self.cluster.allocated() as f64));
        self.telemetry
            .running_series
            .push((now, self.running_jobs() as f64));
        self.telemetry
            .completed_series
            .push((now, self.completed_count as f64));
    }

    /// Consistency checks used by property tests.  Deliberately O(all
    /// jobs): re-derives every incremental counter from scratch and
    /// compares.
    pub fn check_invariants(&self) -> bool {
        if !self.cluster.check_invariants() {
            return false;
        }
        // Every active job's nodes are allocated to it (possibly mid-
        // drain); archived jobs hold nothing.
        for j in self.live.values().chain(self.archived.values()) {
            if j.is_active() {
                for &n in &j.nodes {
                    let owned = matches!(
                        self.cluster.state(n),
                        crate::cluster::NodeState::Allocated(id)
                            | crate::cluster::NodeState::Draining(id) if *id == j.id
                    );
                    if !owned {
                        return false;
                    }
                }
            } else if matches!(j.state, JobState::Completed | JobState::Cancelled)
                && !j.nodes.is_empty()
            {
                return false;
            }
        }
        // The archive holds exactly the terminal jobs.
        if self.live.values().any(|j| matches!(j.state, JobState::Completed | JobState::Cancelled))
        {
            return false;
        }
        if self.archived.values().any(|j| !matches!(j.state, JobState::Completed | JobState::Cancelled))
        {
            return false;
        }
        // Pending jobs hold no nodes.
        for id in &self.pending {
            if !self.live[id].nodes.is_empty() {
                return false;
            }
        }
        // The availability profile mirrors the active set exactly: one
        // entry per active job carrying its live procs / end estimate —
        // the rebuilt-from-scratch reference the incremental updates
        // must match after every operation.
        if !self.profile.check_invariants() {
            return false;
        }
        if self.profile.len() != self.active.len() {
            return false;
        }
        for id in &self.active {
            let j = &self.live[id];
            let ok = self.profile.entry(*id).is_some_and(|e| {
                e.procs == j.nodes.len()
                    && e.end == j.expected_end
                    && e.est == j.spec.est_duration()
            });
            if !ok {
                return false;
            }
        }
        // Incremental counters/indices re-derived from scratch.
        let pending_user = self
            .pending
            .iter()
            .filter(|id| !self.live[id].is_resizer)
            .count();
        let active_user = self
            .live
            .values()
            .filter(|j| j.is_active() && !j.is_resizer)
            .count();
        let active_all: BTreeSet<JobId> =
            self.live.values().filter(|j| j.is_active()).map(|j| j.id).collect();
        // Without record retention the archive is deliberately empty, so
        // the re-derived completion count is only meaningful when records
        // are kept.
        let completed = self
            .archived
            .values()
            .filter(|j| j.state == JobState::Completed)
            .count();
        let archive_consistent = !self.cfg.keep_records || completed == self.completed_count;
        pending_user == self.pending_user
            && active_user == self.active_user
            && active_all == self.active
            && archive_consistent
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::config::AppKind;

    fn spec(app: AppKind, t: Time) -> JobSpec {
        JobSpec::from_app(app, format!("{app}-{t}"), t, 1.0)
    }

    fn small_rms(nodes: usize) -> Rms {
        Rms::new(RmsConfig { nodes, ..Default::default() })
    }

    #[test]
    fn submit_schedule_finish_cycle() {
        let mut rms = small_rms(64);
        let id = rms.submit(spec(AppKind::Cg, 0.0), 0.0);
        let started = rms.schedule(0.0);
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].nodes.len(), 32);
        assert_eq!(rms.running_jobs(), 1);
        rms.finish(id, 100.0);
        assert_eq!(rms.completed_jobs(), 1);
        assert_eq!(rms.cluster.available(), 64);
        assert!(rms.check_invariants());
        assert!(rms.all_done());
    }

    #[test]
    fn queue_blocks_when_full() {
        let mut rms = small_rms(64);
        let a = rms.submit(spec(AppKind::Cg, 0.0), 0.0); // 32 nodes
        let b = rms.submit(spec(AppKind::Cg, 1.0), 1.0); // 32 nodes
        let c = rms.submit(spec(AppKind::Cg, 2.0), 2.0); // 32 nodes -> queued
        let started = rms.schedule(2.0);
        assert_eq!(started.len(), 2);
        assert_eq!(rms.pending_user_jobs(), 1);
        rms.finish(a, 50.0);
        let started = rms.schedule(50.0);
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].job, c);
        let _ = b;
        assert!(rms.check_invariants());
    }

    #[test]
    fn dmr_shrink_protocol() {
        let mut rms = small_rms(64);
        let a = rms.submit(spec(AppKind::Cg, 0.0), 0.0);
        rms.schedule(0.0);
        let _b = rms.submit(spec(AppKind::Cg, 1.0), 1.0); // queued: 32+32 > 64? no: 32 free
        let _c = rms.submit(spec(AppKind::Cg, 1.5), 1.5);
        rms.schedule(1.5); // b starts (32 free), c queued
        assert_eq!(rms.pending_user_jobs(), 1);

        // a at 32, pref 8 with a queued job => shrink to 8.
        let req = DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 };
        let out = rms.dmr_check(a, &req, 10.0);
        let (to, release) = match out {
            DmrOutcome::Shrink { to, release_nodes } => (to, release_nodes),
            o => panic!("expected shrink, got {o:?}"),
        };
        assert_eq!(to, 8);
        assert_eq!(release.len(), 24);
        // Commit after "ACKs".
        rms.commit_shrink_to(a, to, 11.0);
        assert_eq!(rms.job(a).unwrap().procs(), 8);
        assert_eq!(rms.cluster.available(), 24);
        // Queued job c (32 nodes) can now start... only 24 free; but b
        // could also shrink later. Scheduling pass starts nothing yet.
        let started = rms.schedule(11.0);
        assert!(started.is_empty());
        assert!(rms.check_invariants());
        assert_eq!(rms.log.shrinks(), 1);
    }

    #[test]
    fn dmr_expand_protocol() {
        let mut rms = small_rms(64);
        let a = rms.submit(spec(AppKind::NBody, 0.0), 0.0); // 16 nodes
        rms.schedule(0.0);
        // Queue empty, 48 free => preference mode expands toward max.
        let req = DmrRequest { min: 1, max: 16, pref: Some(1), factor: 2 };
        // Shrink would trigger only with queued jobs; queue is empty and
        // job already at max => no action.
        match rms.dmr_check(a, &req, 5.0) {
            DmrOutcome::NoAction => {}
            o => panic!("expected no action, got {o:?}"),
        }

        // Shrink it manually to 4 first (simulate earlier shrink).
        let _ = rms.begin_shrink(a, 4, 6.0);
        rms.commit_shrink_to(a, 4, 6.0);
        assert_eq!(rms.job(a).unwrap().procs(), 4);

        // Now queue is empty: expansion up to max.
        let out = rms.dmr_check(a, &req, 20.0);
        let (to, new_nodes) = match out {
            DmrOutcome::Expand { to, new_nodes } => (to, new_nodes),
            o => panic!("expected expand, got {o:?}"),
        };
        assert_eq!(to, 16);
        assert_eq!(new_nodes.len(), 12);
        assert_eq!(rms.job(a).unwrap().state, JobState::Resizing);
        rms.commit_resize(a, 21.0);
        assert_eq!(rms.job(a).unwrap().procs(), 16);
        assert_eq!(rms.log.expansions(), 1);
        assert!(rms.check_invariants());
        // Resizer job left no residue.
        assert_eq!(rms.pending_user_jobs(), 0);
        assert_eq!(rms.running_jobs(), 1);
    }

    #[test]
    fn expand_aborts_when_no_resources() {
        let mut rms = small_rms(32);
        let a = rms.submit(spec(AppKind::Cg, 0.0), 0.0); // takes all 32
        rms.schedule(0.0);
        // Force expand via dmr_apply (async path) — no free nodes.
        let r = rms.dmr_apply(a, Action::Expand { to: 64 }, 5.0);
        assert!(r.is_err());
        assert!(rms.check_invariants());
    }

    #[test]
    fn shrink_boost_prioritizes_trigger() {
        let mut rms = small_rms(64);
        let a = rms.submit(spec(AppKind::Cg, 0.0), 0.0);
        let b = rms.submit(spec(AppKind::Cg, 0.0), 0.0);
        rms.schedule(0.0); // both start (64 nodes)
        let _ = b;
        // Two queued jobs; the head (older) gets the boost on shrink.
        let c = rms.submit(spec(AppKind::Jacobi, 10.0), 10.0);
        let d = rms.submit(spec(AppKind::Jacobi, 11.0), 11.0);
        let req = DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 };
        let out = rms.dmr_check(a, &req, 20.0);
        assert!(matches!(out, DmrOutcome::Shrink { .. }));
        assert!(rms.job(c).unwrap().qos_boost);
        assert!(!rms.job(d).unwrap().qos_boost);
    }

    #[test]
    fn cancel_then_schedule() {
        // Cancel a queued job (exercising the swap_remove path with a job
        // in the *middle* of the pending vec), then verify the next pass
        // starts the remaining jobs in the correct priority order.
        let mut rms = small_rms(32);
        let a = rms.submit(spec(AppKind::Cg, 0.0), 0.0); // 32 nodes
        rms.schedule(0.0); // a takes the whole machine
        let b = rms.submit(spec(AppKind::Cg, 1.0), 1.0);
        let c = rms.submit(spec(AppKind::Cg, 2.0), 2.0);
        let d = rms.submit(spec(AppKind::Cg, 3.0), 3.0);
        assert_eq!(rms.pending_user_jobs(), 3);

        rms.cancel(c, 4.0); // middle of `pending`
        assert_eq!(rms.pending_user_jobs(), 2);
        assert_eq!(rms.job(c).unwrap().state, JobState::Cancelled);
        assert!(rms.check_invariants());

        // Free the machine: the oldest surviving job (b) starts first,
        // regardless of swap_remove having shuffled the raw vec.
        rms.finish(a, 10.0);
        let started = rms.schedule(10.0);
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].job, b);
        rms.finish(b, 20.0);
        let started = rms.schedule(20.0);
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].job, d);
        rms.finish(d, 30.0);
        assert!(rms.all_done());
        assert!(rms.check_invariants());
    }

    #[test]
    fn cached_order_matches_fresh_sort() {
        // Same submission stream, cache on vs off: identical event logs.
        let run = |cache: bool| {
            let mut rms = Rms::new(RmsConfig {
                nodes: 64,
                cache_pending_order: cache,
                ..Default::default()
            });
            let mut ids = Vec::new();
            for i in 0..12 {
                ids.push(rms.submit(spec(AppKind::Cg, i as f64), i as f64));
            }
            rms.schedule(12.0);
            // age the queue past events at several timestamps
            for t in [13.0, 100.0, 2000.0, 5000.0] {
                rms.schedule(t);
            }
            let running: Vec<JobId> = ids
                .iter()
                .copied()
                .filter(|&id| rms.job(id).unwrap().is_active())
                .collect();
            for id in running {
                rms.finish(id, 6000.0);
                rms.schedule(6000.0);
            }
            assert!(rms.check_invariants());
            rms.log.digest()
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn scan_view_matches_sorted_view() {
        // `dmr_peek` builds its SystemView by a min_by scan (`view_at`)
        // instead of sorting; both must agree on every field — including
        // the head under age differences, size differences, boosts, and
        // cached-order reuse at later timestamps.
        let mut rms = small_rms(64);
        let a = rms.submit(spec(AppKind::Cg, 0.0), 0.0);
        rms.schedule(0.0); // a takes 32, queue builds behind it
        let _b = rms.submit(spec(AppKind::Cg, 1.0), 1.0);
        let _c = rms.submit(spec(AppKind::NBody, 2.0), 2.0);
        let d = rms.submit(spec(AppKind::Jacobi, 3.0), 3.0);
        let check = |rms: &mut Rms, t: Time| {
            // Before view() refreshes: exercises view_at's scan branch
            // whenever the cache is invalid or outside the reuse window.
            let scanned = rms.view_at(t);
            let sorted = rms.view(t);
            // After the refresh: exercises view_at's cache-reuse branch.
            let cached = rms.view_at(t);
            assert_eq!(sorted.available, scanned.available, "t={t}");
            assert_eq!(sorted.pending_jobs, scanned.pending_jobs, "t={t}");
            assert_eq!(sorted.head_need, scanned.head_need, "t={t}");
            assert_eq!(sorted.head_need, cached.head_need, "t={t} (cached)");
        };
        for t in [5.0, 100.0, 2000.0, 5000.0] {
            check(&mut rms, t);
        }
        // a qos boost reorders the head: both views must track it
        rms.live.get_mut(&d).unwrap().qos_boost = true;
        rms.invalidate_pending_order();
        check(&mut rms, 5001.0);
        let _ = a;
    }

    #[test]
    fn dmr_peek_is_side_effect_free_and_matches_check_decision() {
        // Peeking must neither change state nor disagree with the action
        // a synchronous check would log at the same instant.
        let mut rms = small_rms(64);
        let a = rms.submit(spec(AppKind::Cg, 0.0), 0.0);
        rms.schedule(0.0);
        rms.submit(spec(AppKind::Cg, 1.0), 1.0);
        rms.schedule(1.0); // second job starts too (64 nodes)
        rms.submit(spec(AppKind::Cg, 2.0), 2.0); // queued
        let req = DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 };
        let events_before = rms.log.all().len();
        let peeked = rms.dmr_peek(a, &req, 10.0);
        assert_eq!(rms.log.all().len(), events_before, "peek must not log");
        let out = rms.dmr_check(a, &req, 10.0);
        match (peeked, out) {
            (Action::Shrink { to }, DmrOutcome::Shrink { to: to2, .. }) => {
                assert_eq!(to, to2)
            }
            (p, o) => panic!("peek {p:?} disagrees with check {o:?}"),
        }
    }

    #[test]
    fn noop_schedule_pass_is_elided() {
        let mut rms = small_rms(32);
        let a = rms.submit(spec(AppKind::Cg, 0.0), 0.0); // 32 nodes
        rms.schedule(0.0); // a takes the whole machine
        let b = rms.submit(spec(AppKind::Cg, 1.0), 1.0); // blocked
        assert!(rms.schedule(5.0).is_empty());
        assert_eq!(rms.pass_stats().sched_elided, 0);
        let events = rms.log.all().len();

        // Same clock, unchanged state: elided.
        assert!(rms.schedule(5.0).is_empty());
        assert_eq!(rms.pass_stats().sched_elided, 1);
        // Later clock inside the order-reuse window, unchanged state:
        // still elided (a no-op pass only hardens with time).
        assert!(rms.schedule(6.0).is_empty());
        assert_eq!(rms.pass_stats().sched_elided, 2);
        assert_eq!(rms.log.all().len(), events, "elided passes log nothing");

        // A submission bumps the queue version: the memo dies and the
        // real pass runs (and still starts nothing — no room).
        let c = rms.submit(spec(AppKind::Cg, 7.0), 7.0);
        assert!(rms.schedule(7.0).is_empty());
        assert_eq!(rms.pass_stats().sched_elided, 2);

        // Freeing the machine kills the memo via the cluster/profile
        // versions: the next pass must really run and start the head.
        rms.finish(a, 10.0);
        let started = rms.schedule(10.0);
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].job, b);
        let _ = c;
        assert!(rms.check_invariants());
    }

    #[test]
    fn elision_disabled_on_reference_path() {
        let mut rms = Rms::new(RmsConfig {
            nodes: 32,
            incremental_profile: false,
            ..Default::default()
        });
        rms.submit(spec(AppKind::Cg, 0.0), 0.0);
        rms.schedule(0.0);
        rms.submit(spec(AppKind::Cg, 1.0), 1.0);
        rms.schedule(5.0);
        rms.schedule(5.0);
        rms.schedule(6.0);
        assert_eq!(rms.pass_stats().sched_elided, 0);
        assert_eq!(rms.pass_stats().dmr_elided, 0);
        assert!(rms.check_invariants());
    }

    #[test]
    fn noop_dmr_check_is_memoized_and_logs_identically() {
        let run = |incremental: bool| {
            let mut rms = Rms::new(RmsConfig {
                nodes: 64,
                incremental_profile: incremental,
                ..Default::default()
            });
            let a = rms.submit(spec(AppKind::Cg, 0.0), 0.0);
            rms.schedule(0.0);
            rms.submit(spec(AppKind::Cg, 1.0), 1.0);
            rms.schedule(1.0); // both running: machine full, queue empty
            let req = DmrRequest { min: 2, max: 32, pref: None, factor: 2 };
            // Nothing free, nothing queued: NoAction, repeatedly.
            for t in [10.0, 11.0, 12.0] {
                assert!(matches!(rms.dmr_check(a, &req, t), DmrOutcome::NoAction));
            }
            // State change (a queued job) invalidates the memo; decision
            // is recomputed (still NoAction: releasing 30 < head's 32).
            rms.submit(spec(AppKind::Cg, 13.0), 13.0);
            assert!(matches!(rms.dmr_check(a, &req, 14.0), DmrOutcome::NoAction));
            assert!(rms.check_invariants());
            (rms.pass_stats(), rms.log.digest())
        };
        let (fast, fast_digest) = run(true);
        let (slow, slow_digest) = run(false);
        assert_eq!(fast.dmr_checks, 4);
        assert_eq!(fast.dmr_elided, 2, "checks at t=11, t=12 replay the memo");
        assert_eq!(slow.dmr_elided, 0);
        assert_eq!(
            fast_digest, slow_digest,
            "memoized decisions must log bit-identically to the reference"
        );
    }

    #[test]
    fn saturated_queue_reuses_order_and_matches_resort() {
        // Jobs all older than the age horizon: their age factors are
        // pinned at 1, so the cached order is reusable indefinitely —
        // and must stay bit-identical to re-sorting every pass.
        let run = |cache: bool| {
            let mut rms = Rms::new(RmsConfig {
                nodes: 32,
                cache_pending_order: cache,
                ..Default::default()
            });
            let a = rms.submit(spec(AppKind::Cg, 0.0), 0.0);
            rms.schedule(0.0); // takes the machine
            for t in [1.0, 2.0, 3.0] {
                rms.submit(spec(AppKind::Cg, t), t);
            }
            // First pass far past the horizon (3600): sorts a queue whose
            // youngest member is already saturated.
            rms.schedule(4000.0);
            // These passes may reuse (cache on) or re-sort (cache off).
            rms.schedule(5000.0);
            rms.schedule(9000.0);
            rms.finish(a, 9500.0);
            let started = rms.schedule(9500.0);
            assert_eq!(started.len(), 1, "head starts once the machine frees");
            assert!(rms.check_invariants());
            rms.log.digest()
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn expand_rollback_restores_pre_transaction_state() {
        let mut rms = small_rms(64);
        let a = rms.submit(spec(AppKind::NBody, 0.0), 0.0); // 16 nodes
        rms.schedule(0.0);
        let _ = rms.begin_shrink(a, 4, 1.0);
        rms.commit_shrink_to(a, 4, 1.0);
        let before_nodes = rms.job(a).unwrap().nodes.clone();
        let before_log = rms.job(a).unwrap().resize_log.len();
        let free_before = rms.cluster.available();
        let req = DmrRequest { min: 1, max: 16, pref: Some(1), factor: 2 };
        let out = rms.dmr_check(a, &req, 5.0);
        assert!(matches!(out, DmrOutcome::Expand { .. }));
        assert_eq!(rms.job(a).unwrap().state, JobState::Resizing);
        rms.abort_expand_to(a, before_nodes.len(), 6.0, 1);
        let j = rms.job(a).unwrap();
        assert_eq!(j.state, JobState::Running);
        assert_eq!(j.nodes, before_nodes, "granted tail released, original nodes kept");
        assert_eq!(j.resize_log.len(), before_log, "provisional entry dropped");
        assert_eq!(rms.cluster.available(), free_before);
        assert_eq!(rms.log.resize_aborts(), 1);
        assert!(rms.check_invariants());
    }

    #[test]
    fn shrink_rollback_keeps_all_nodes() {
        let mut rms = small_rms(64);
        let a = rms.submit(spec(AppKind::Cg, 0.0), 0.0); // 32 nodes
        rms.schedule(0.0);
        rms.submit(spec(AppKind::Cg, 1.0), 1.0);
        rms.schedule(1.0);
        rms.submit(spec(AppKind::Cg, 2.0), 2.0); // queued: shrink trigger
        let before_nodes = rms.job(a).unwrap().nodes.clone();
        let req = DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 };
        let out = rms.dmr_check(a, &req, 10.0);
        assert!(matches!(out, DmrOutcome::Shrink { .. }));
        rms.abort_shrink(a, 11.0, 2);
        let j = rms.job(a).unwrap();
        assert_eq!(j.state, JobState::Running);
        assert_eq!(j.nodes, before_nodes, "shrink holds nodes until commit");
        assert_eq!(rms.log.resize_aborts(), 1);
        assert!(rms.check_invariants());
    }

    #[test]
    fn degraded_job_gets_no_action_everywhere() {
        let mut rms = small_rms(64);
        let a = rms.submit(spec(AppKind::Cg, 0.0), 0.0); // 32 nodes
        rms.schedule(0.0);
        rms.submit(spec(AppKind::Cg, 1.0), 1.0);
        rms.schedule(1.0);
        rms.submit(spec(AppKind::Cg, 2.0), 2.0); // queued: shrink pressure
        let req = DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 };
        // Sanity: the policy would shrink this job...
        assert!(matches!(rms.dmr_peek(a, &req, 10.0), Action::Shrink { .. }));
        // ...until it degrades.
        rms.degrade(a, 10.0);
        assert!(rms.job(a).unwrap().degraded);
        assert_eq!(rms.log.degradations(), 1);
        assert!(matches!(rms.dmr_peek(a, &req, 11.0), Action::NoAction));
        assert!(matches!(rms.dmr_check(a, &req, 12.0), DmrOutcome::NoAction));
        let applied = rms.dmr_apply(a, Action::Shrink { to: 8 }, 13.0);
        assert!(matches!(applied, Ok(DmrOutcome::NoAction)));
        assert_eq!(rms.job(a).unwrap().procs(), 32, "nothing moved");
        assert!(rms.check_invariants());
    }

    #[test]
    fn telemetry_stride_downsamples() {
        let run = |stride: usize| {
            let mut rms = Rms::new(RmsConfig {
                nodes: 64,
                telemetry_stride: stride,
                ..Default::default()
            });
            for i in 0..8 {
                let id = rms.submit(spec(AppKind::NBody, i as f64), i as f64);
                rms.schedule(i as f64);
                rms.finish(id, i as f64 + 0.5);
            }
            rms.telemetry.alloc_series.len()
        };
        let lossless = run(1);
        assert_eq!(lossless, 16, "one snapshot per start + finish");
        assert_eq!(run(4), lossless / 4);
        assert_eq!(run(0), 0, "stride 0 disables telemetry");
    }

    #[test]
    fn unretained_archive_folds_and_reclaims() {
        // keep_records = false: terminal jobs vanish, yet the digest, the
        // counters and every folded measure match the retaining run.
        let run = |keep: bool| {
            let mut rms =
                Rms::new(RmsConfig { nodes: 64, keep_records: keep, ..Default::default() });
            for i in 0..6 {
                let id = rms.submit(spec(AppKind::NBody, i as f64), i as f64);
                rms.schedule(i as f64);
                rms.finish(id, i as f64 + 30.0);
            }
            rms.seal_metrics(35.0);
            assert!(rms.check_invariants());
            rms
        };
        let kept = run(true);
        let dropped = run(false);
        assert_eq!(kept.log.digest(), dropped.log.digest());
        assert_eq!(kept.log.total_pushed(), dropped.log.total_pushed());
        assert_eq!(dropped.log.all().len(), 0, "raw events reclaimed");
        assert_eq!(dropped.jobs().count(), 0, "archive reclaimed");
        assert_eq!(kept.jobs().count(), 6);
        assert!(dropped.telemetry.alloc_series.is_empty(), "telemetry reclaimed");
        assert_eq!(dropped.completed_jobs(), 6);
        assert_eq!(dropped.fold.count(), kept.fold.count());
        assert_eq!(dropped.fold.wait.mean().to_bits(), kept.fold.wait.mean().to_bits());
        assert_eq!(dropped.fold.util_area.to_bits(), kept.fold.util_area.to_bits());
        assert_eq!(dropped.peak_live(), kept.peak_live());
        assert!(dropped.peak_live() <= 2, "live map bounded by concurrent jobs");
    }
}
