//! Queue-pressure-driven strategy: shrink under a loaded queue, expand
//! only into a drained one.

use super::{
    expand_fill, forced_action, pref_floor, shrink_target, Action, PolicyContext,
    ReconfigPolicy,
};

/// The SLURM-extension flavor of adaptive scheduling (Chadha et al.,
/// arXiv:2009.08289): the *queue*, not the individual job, drives every
/// decision.
///
/// * **Pressure at or above the threshold** — shrink aggressively, all
///   the way down the factor chain to the job's preferred size (its
///   minimum when no preference is stated), freeing as many nodes for
///   the backlog as the chain allows.
/// * **Queue drained** — expand up to the maximum the free nodes permit;
///   an empty queue means idle nodes benefit nobody else.
/// * **In between** — hold steady: mild backlogs are left to backfill
///   rather than paying reconfiguration costs.
///
/// §4.1 forced requests ([`forced_action`]) always win.
#[derive(Debug, Clone, Copy)]
pub struct QueueAware {
    /// Pending-job count at (or above) which running jobs shrink; values
    /// below 1 are treated as 1.
    pub pressure: usize,
}

impl ReconfigPolicy for QueueAware {
    fn name(&self) -> &'static str {
        "queue"
    }

    fn decide(&self, ctx: &PolicyContext) -> Action {
        if let Some(forced) = forced_action(ctx.current, ctx.req, &ctx.view) {
            return forced;
        }
        let pressure = self.pressure.max(1);
        if ctx.view.pending_jobs >= pressure {
            let to = shrink_target(ctx.current, ctx.req.factor, pref_floor(ctx.req));
            if to < ctx.current {
                return Action::Shrink { to };
            }
        } else if ctx.view.pending_jobs == 0 {
            if let Some(to) = expand_fill(ctx.current, ctx.req, ctx.view.available) {
                return Action::Expand { to };
            }
        }
        Action::NoAction
    }

    /// Queue pressure is read from the view, never from the clock, so
    /// repeated checks under an unchanged context may be elided.
    fn time_invariant(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rms::policy::{DmrRequest, SystemView};

    fn ctx<'a>(current: usize, req: &'a DmrRequest, view: SystemView) -> PolicyContext<'a> {
        PolicyContext::new(50.0, current, req, view)
    }

    const REQ: DmrRequest = DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 };

    #[test]
    fn shrinks_at_exactly_the_threshold() {
        let p = QueueAware { pressure: 3 };
        let view = SystemView { available: 0, pending_jobs: 3, head_need: Some(16) };
        assert_eq!(p.decide(&ctx(32, &REQ, view)), Action::Shrink { to: 8 });
    }

    #[test]
    fn holds_one_below_the_threshold() {
        let p = QueueAware { pressure: 3 };
        let view = SystemView { available: 0, pending_jobs: 2, head_need: Some(16) };
        assert_eq!(p.decide(&ctx(32, &REQ, view)), Action::NoAction);
    }

    #[test]
    fn expands_only_when_queue_drained() {
        let p = QueueAware { pressure: 3 };
        let drained = SystemView { available: 24, pending_jobs: 0, head_need: None };
        assert_eq!(p.decide(&ctx(8, &REQ, drained)), Action::Expand { to: 32 });
        // One pending job is enough to suppress expansion entirely —
        // unlike the baseline's wide optimization, which expands into
        // queue-starved idle nodes.
        let mild = SystemView { available: 24, pending_jobs: 1, head_need: Some(64) };
        assert_eq!(p.decide(&ctx(8, &REQ, mild)), Action::NoAction);
    }

    #[test]
    fn shrink_stops_at_the_pref_floor_and_the_chain_end() {
        let p = QueueAware { pressure: 1 };
        let view = SystemView { available: 0, pending_jobs: 5, head_need: Some(64) };
        // Already at the preferred floor: nothing to release.
        assert_eq!(p.decide(&ctx(8, &REQ, view)), Action::NoAction);
        // No preference: the floor is the minimum.
        let req = DmrRequest { min: 4, max: 32, pref: None, factor: 2 };
        assert_eq!(p.decide(&ctx(32, &req, view)), Action::Shrink { to: 4 });
        // Off-chain size: stop where divisibility ends.
        let req = DmrRequest { min: 1, max: 32, pref: None, factor: 2 };
        assert_eq!(p.decide(&ctx(12, &req, view)), Action::Shrink { to: 3 });
    }

    #[test]
    fn forced_requests_override_pressure() {
        let p = QueueAware { pressure: 1 };
        // Queue is loaded, but the app raised its minimum: forced expand.
        let req = DmrRequest { min: 16, max: 32, pref: None, factor: 2 };
        let view = SystemView { available: 24, pending_jobs: 5, head_need: Some(64) };
        assert_eq!(p.decide(&ctx(8, &req, view)), Action::Expand { to: 32 });
    }

    #[test]
    fn zero_pressure_behaves_as_one() {
        let p = QueueAware { pressure: 0 };
        let view = SystemView { available: 0, pending_jobs: 1, head_need: Some(8) };
        assert_eq!(p.decide(&ctx(32, &REQ, view)), Action::Shrink { to: 8 });
    }
}
