//! Per-user fair-share strategy: balance the busy nodes across users.

use super::{forced_action, pref_floor, Action, PolicyContext, ReconfigPolicy, UsageView};

/// Weighted per-user balancing over the RMS's pending/running indices:
/// each user is entitled to an equal share of the currently-busy nodes,
/// and jobs of over-served users yield one factor step to the queue while
/// jobs of under-served users may claim one.
///
/// The decision compares the requesting user's held nodes
/// ([`UsageView::user_nodes`]) against the fair share
/// `busy_nodes / active_users`, with a tolerance factor (`slack`) so the
/// cluster does not churn around small imbalances:
///
/// * **Over share** (`held > fair × slack`) *and* someone else's jobs
///   are queued ([`UsageView::user_pending`] <
///   [`SystemView::pending_jobs`]) — shrink one factor step toward the
///   preferred size, handing nodes to the under-served.  A backlog
///   consisting solely of the over-served user's own jobs triggers
///   nothing: yielding to yourself redistributes no share.
/// * **Under share** (`held × slack < fair`) *and* nodes are free —
///   expand one factor step.
/// * Otherwise hold steady.
///
/// [`SystemView::pending_jobs`]: super::SystemView::pending_jobs
///
/// Moves are deliberately one step at a time: fairness is re-evaluated at
/// every reconfiguring point and single steps keep the shares from
/// oscillating.  §4.1 forced requests ([`forced_action`]) always win.
///
/// This strategy opts into the per-user usage scan
/// ([`ReconfigPolicy::wants_usage`]).
#[derive(Debug, Clone, Copy)]
pub struct FairShare {
    /// Tolerated over/under-share factor before acting (values below 1
    /// are treated as 1; 1.0 reacts to any imbalance).
    pub slack: f64,
}

impl ReconfigPolicy for FairShare {
    fn name(&self) -> &'static str {
        "fair"
    }

    fn wants_usage(&self) -> bool {
        true
    }

    fn decide(&self, ctx: &PolicyContext) -> Action {
        if let Some(forced) = forced_action(ctx.current, ctx.req, &ctx.view) {
            return forced;
        }
        let f = ctx.req.factor;
        if f < 2 {
            // Degenerate chain: no single-step moves exist.
            return Action::NoAction;
        }
        let u: &UsageView = ctx
            .usage
            .as_ref()
            .expect("FairShare wants_usage(), so the RMS must supply a UsageView");
        let slack = self.slack.max(1.0);
        let fair = u.busy_nodes as f64 / u.active_users.max(1) as f64;
        let held = u.user_nodes as f64;
        let others_waiting = ctx.view.pending_jobs > u.user_pending;
        if held > fair * slack && others_waiting {
            let floor = pref_floor(ctx.req);
            if ctx.current % f == 0 && ctx.current / f >= floor {
                return Action::Shrink { to: ctx.current / f };
            }
        } else if held * slack < fair && ctx.view.available > 0 {
            let to = ctx.current * f;
            if to > ctx.current && to <= ctx.req.max && to - ctx.current <= ctx.view.available {
                return Action::Expand { to };
            }
        }
        Action::NoAction
    }

    /// Shares are computed from the usage view, never from the clock, so
    /// repeated checks under an unchanged context may be elided.
    fn time_invariant(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rms::policy::{DmrRequest, SystemView};

    const REQ: DmrRequest = DmrRequest { min: 2, max: 32, pref: Some(4), factor: 2 };

    fn usage_ctx<'a>(
        current: usize,
        req: &'a DmrRequest,
        view: SystemView,
        user_nodes: usize,
        busy: usize,
        users: usize,
    ) -> PolicyContext<'a> {
        let mut ctx = PolicyContext::new(10.0, current, req, view);
        ctx.usage = Some(UsageView {
            user_nodes,
            busy_nodes: busy,
            active_users: users,
            user_pending: 0,
        });
        ctx
    }

    #[test]
    fn over_share_with_queue_shrinks_one_step() {
        // 2 users, 48 busy nodes, this user holds 40 (fair = 24, slack
        // 1.25 → threshold 30): over share, someone waiting → one step.
        let view = SystemView { available: 0, pending_jobs: 2, head_need: Some(8) };
        let p = FairShare { slack: 1.25 };
        let ctx = usage_ctx(16, &REQ, view, 40, 48, 2);
        assert_eq!(p.decide(&ctx), Action::Shrink { to: 8 });
    }

    #[test]
    fn over_share_with_only_own_backlog_holds() {
        // Every queued job belongs to the over-served user: shrinking
        // would hand the nodes straight back to them — no action.
        let view = SystemView { available: 0, pending_jobs: 2, head_need: Some(8) };
        let p = FairShare { slack: 1.25 };
        let mut ctx = usage_ctx(16, &REQ, view, 40, 48, 2);
        ctx.usage.as_mut().unwrap().user_pending = 2;
        assert_eq!(p.decide(&ctx), Action::NoAction);
        // One of the two queued jobs is someone else's: shrink again.
        ctx.usage.as_mut().unwrap().user_pending = 1;
        assert_eq!(p.decide(&ctx), Action::Shrink { to: 8 });
    }

    #[test]
    fn over_share_without_queue_holds() {
        let view = SystemView { available: 16, pending_jobs: 0, head_need: None };
        let p = FairShare { slack: 1.25 };
        let ctx = usage_ctx(16, &REQ, view, 40, 48, 2);
        assert_eq!(p.decide(&ctx), Action::NoAction);
    }

    #[test]
    fn under_share_with_room_expands_one_step() {
        // This user holds 4 of 48 busy nodes across 2 users (fair 24):
        // deeply under share, 16 free → one factor step up.
        let view = SystemView { available: 16, pending_jobs: 1, head_need: Some(64) };
        let p = FairShare { slack: 1.25 };
        let ctx = usage_ctx(4, &REQ, view, 4, 48, 2);
        assert_eq!(p.decide(&ctx), Action::Expand { to: 8 });
    }

    #[test]
    fn under_share_without_free_nodes_holds() {
        let view = SystemView { available: 0, pending_jobs: 1, head_need: Some(64) };
        let p = FairShare { slack: 1.25 };
        let ctx = usage_ctx(4, &REQ, view, 4, 48, 2);
        assert_eq!(p.decide(&ctx), Action::NoAction);
    }

    #[test]
    fn exactly_at_fair_share_holds() {
        // held == fair: neither `held > fair*slack` nor `held*slack <
        // fair` can fire for slack >= 1 — the boundary is stable even at
        // slack exactly 1.
        let view = SystemView { available: 16, pending_jobs: 3, head_need: Some(8) };
        for slack in [1.0, 1.25, 2.0] {
            let p = FairShare { slack };
            let ctx = usage_ctx(16, &REQ, view, 24, 48, 2);
            assert_eq!(p.decide(&ctx), Action::NoAction, "slack {slack}");
        }
    }

    #[test]
    fn shrink_respects_pref_floor_and_chain() {
        let view = SystemView { available: 0, pending_jobs: 2, head_need: Some(8) };
        let p = FairShare { slack: 1.0 };
        // At the preferred floor already: no step down exists.
        let ctx = usage_ctx(4, &REQ, view, 40, 48, 2);
        assert_eq!(p.decide(&ctx), Action::NoAction);
        // Off-chain current (odd): no divisible step.
        let req = DmrRequest { min: 1, max: 32, pref: None, factor: 2 };
        let ctx = usage_ctx(7, &req, view, 40, 48, 2);
        assert_eq!(p.decide(&ctx), Action::NoAction);
    }

    #[test]
    fn expand_respects_max_and_available() {
        let p = FairShare { slack: 1.0 };
        // Step would exceed max: hold.
        let view = SystemView { available: 64, pending_jobs: 0, head_need: None };
        let ctx = usage_ctx(32, &REQ, view, 1, 48, 4);
        assert_eq!(p.decide(&ctx), Action::NoAction);
        // Step would exceed the free pool: hold.
        let view = SystemView { available: 3, pending_jobs: 0, head_need: None };
        let ctx = usage_ctx(4, &REQ, view, 1, 48, 4);
        assert_eq!(p.decide(&ctx), Action::NoAction);
    }

    #[test]
    fn forced_requests_override_fairness() {
        let p = FairShare { slack: 1.0 };
        // Over share, but the app lowered its maximum: forced shrink to 8
        // even though fairness alone would only step to 16.
        let req = DmrRequest { min: 2, max: 8, pref: None, factor: 2 };
        let view = SystemView { available: 0, pending_jobs: 0, head_need: None };
        let ctx = usage_ctx(32, &req, view, 40, 48, 2);
        assert_eq!(p.decide(&ctx), Action::Shrink { to: 8 });
    }
}
