//! The paper baseline: §4's three-mode rule as a [`ReconfigPolicy`].

use super::{decide, Action, PolicyConfig, PolicyContext, ReconfigPolicy};

/// The paper's §4 decision rule — request-an-action, then
/// preferred-number-of-nodes, then wide optimization — wrapped as a
/// strategy.  This is the default of [`crate::rms::RmsConfig`] and the
/// *golden baseline*: it delegates to the pure [`decide`] function
/// unchanged, so its event streams are bit-identical to the pre-trait
/// implementation (locked by `rust/tests/test_golden_determinism.rs`).
#[derive(Debug, Clone)]
pub struct ThroughputAware {
    cfg: PolicyConfig,
}

impl ThroughputAware {
    /// Wrap the §4 rule with its ablation switches.
    pub fn new(cfg: PolicyConfig) -> Self {
        ThroughputAware { cfg }
    }
}

impl ReconfigPolicy for ThroughputAware {
    fn name(&self) -> &'static str {
        "throughput"
    }

    fn decide(&self, ctx: &PolicyContext) -> Action {
        decide(&self.cfg, ctx.current, ctx.req, &ctx.view)
    }

    /// The §4 rule never reads the clock — only the request and the
    /// system view — so repeated checks under an unchanged context may
    /// be elided by the RMS.
    fn time_invariant(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rms::policy::{DmrRequest, SystemView};

    /// The strategy must be a transparent wrapper over `decide`.
    #[test]
    fn matches_pure_decide() {
        let cfg = PolicyConfig::default();
        let strat = ThroughputAware::new(cfg.clone());
        let cases = [
            (8, DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 },
             SystemView { available: 56, pending_jobs: 0, head_need: None }),
            (32, DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 },
             SystemView { available: 0, pending_jobs: 4, head_need: Some(32) }),
            (4, DmrRequest { min: 1, max: 16, pref: None, factor: 2 },
             SystemView { available: 4, pending_jobs: 1, head_need: Some(32) }),
            (8, DmrRequest { min: 16, max: 32, pref: None, factor: 2 },
             SystemView { available: 24, pending_jobs: 3, head_need: Some(64) }),
        ];
        for (current, req, view) in cases {
            let ctx = PolicyContext::new(100.0, current, &req, view);
            assert_eq!(strat.decide(&ctx), decide(&cfg, current, &req, &view));
        }
    }

    #[test]
    fn does_not_request_usage_scan() {
        assert!(!ThroughputAware::new(PolicyConfig::default()).wants_usage());
    }
}
