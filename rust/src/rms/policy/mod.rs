//! The reconfiguration-policy subsystem: *what should a malleable job do
//! when it reaches a reconfiguring point?*
//!
//! The paper answers with one rule (§4, three modes with increasing
//! scheduling freedom — request-an-action, preferred-number-of-nodes,
//! wide optimization), preserved bit-identically here as
//! [`ThroughputAware`] and still the default.  Related work shows the
//! decision space is much richer — Chadha et al. schedule adaptively
//! against queue pressure (arXiv:2009.08289), Zojer/Posner/Özden compare
//! whole strategy families on real-world workloads — so the decision is a
//! first-class, swappable component:
//!
//! * [`ReconfigPolicy`] — the strategy trait: a pure function from a
//!   [`PolicyContext`] (request + system snapshot + per-job/per-user
//!   facts) to an [`Action`].
//! * [`PolicyStrategy`] — the registry of built-in strategies, selected
//!   via [`crate::rms::RmsConfig::strategy`] and sweepable as the
//!   campaign `[policy] strategy = [...]` axis.
//! * [`ThroughputAware`] — the paper baseline (§4.1–§4.3).
//! * [`QueueAware`] — shrink aggressively once pending pressure crosses a
//!   threshold, expand only when the queue is drained.
//! * [`FairShare`] — steer each user toward an equal share of the busy
//!   nodes, one factor step at a time.
//! * [`DeadlineAware`] — expand jobs projected to miss their soft
//!   deadline and never shrink them; deadline-less jobs fall back to the
//!   baseline.
//!
//! Every strategy moves along the job's resize-factor chain (targets are
//! `current × factor^k` / `current ÷ factor^k`) and must honor the §4.1
//! *forced* actions — the application raising its minimum or lowering its
//! maximum is a hard constraint, shared via [`forced_action`].

mod deadline;
mod fair_share;
mod queue_aware;
mod throughput;

pub use deadline::DeadlineAware;
pub use fair_share::FairShare;
pub use queue_aware::QueueAware;
pub use throughput::ThroughputAware;

use crate::Time;

/// What the application conveys on each DMR call (§5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DmrRequest {
    /// Minimum acceptable process count.
    pub min: usize,
    /// Maximum acceptable process count.
    pub max: usize,
    /// Preferred process count, if the application states one (§4.2).
    pub pref: Option<usize>,
    /// Resizing factor: targets are multiples/divisors of the current
    /// size by powers of this factor.
    pub factor: usize,
}

/// The resizing action returned to the runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Keep the current allocation.
    NoAction,
    /// Grow the job to `to` processes.
    Expand { to: usize },
    /// Release nodes down to `to` processes.
    Shrink { to: usize },
}

impl Action {
    /// Stable lowercase name (logs, CSV cells).
    pub fn name(&self) -> &'static str {
        match self {
            Action::NoAction => "no-action",
            Action::Expand { .. } => "expand",
            Action::Shrink { .. } => "shrink",
        }
    }
}

/// The queue/cluster snapshot the policy inspects ("the RMS inspects the
/// global status of the system" — §3).
#[derive(Debug, Clone, Copy)]
pub struct SystemView {
    /// Free (allocatable) nodes right now.
    pub available: usize,
    /// Number of queued (pending, non-resizer) jobs.
    pub pending_jobs: usize,
    /// Node requirement of the highest-priority pending job, if any.
    pub head_need: Option<usize>,
}

/// Everything a [`ReconfigPolicy`] may consult for one decision.
///
/// The first four fields are always populated.  The per-job facts
/// (`user`, `deadline`, `expected_end`) come from the requesting job's
/// spec and scheduler state; the per-user [`UsageView`] is `Some` only
/// when the strategy opts in via [`ReconfigPolicy::wants_usage`] — the
/// scan that fills it is O(active + pending jobs) and the default
/// strategy does not need it.
#[derive(Debug, Clone)]
pub struct PolicyContext<'a> {
    /// Decision time.
    pub now: Time,
    /// Current process count of the requesting job.
    pub current: usize,
    /// What the application conveyed on this DMR call.
    pub req: &'a DmrRequest,
    /// Queue/cluster snapshot at `now`.
    pub view: SystemView,
    /// Owning user of the requesting job (0 = the default single user).
    pub user: u32,
    /// Soft deadline of the requesting job, if it has one.
    pub deadline: Option<Time>,
    /// Scheduler's estimate of the job's completion time at its current
    /// size (refreshed by the execution driver on every start/resize).
    pub expected_end: Option<Time>,
    /// Per-user usage facts — `Some` iff the strategy returned `true`
    /// from [`ReconfigPolicy::wants_usage`].  Kept behind an `Option` so
    /// a strategy that consults usage without opting in fails loudly at
    /// the read site instead of silently computing with zeros.
    pub usage: Option<UsageView>,
}

/// The per-user usage indices a [`ReconfigPolicy::wants_usage`] strategy
/// receives (one resizer-excluded scan over the RMS's active/pending
/// sets).
#[derive(Debug, Clone, Copy)]
pub struct UsageView {
    /// Nodes held by the requesting user's active jobs, this one
    /// included.
    pub user_nodes: usize,
    /// Nodes held by active user jobs cluster-wide (resizer jobs
    /// excluded, matching `user_nodes`, so shares stay consistent while
    /// an expansion protocol is mid-flight).
    pub busy_nodes: usize,
    /// Distinct users with active jobs (always ≥ 1 while deciding — the
    /// requester is active).
    pub active_users: usize,
    /// Pending jobs of the requesting user.
    pub user_pending: usize,
}

impl<'a> PolicyContext<'a> {
    /// A context with the always-available fields set and every optional
    /// fact at its neutral value (single anonymous user, no deadline, no
    /// usage scan).
    pub fn new(now: Time, current: usize, req: &'a DmrRequest, view: SystemView) -> Self {
        PolicyContext {
            now,
            current,
            req,
            view,
            user: 0,
            deadline: None,
            expected_end: None,
            usage: None,
        }
    }
}

/// A reconfiguration strategy: decide what a malleable job should do at a
/// reconfiguring point, given the request and the system state.
///
/// Implementations must be pure (no state observable across calls): the
/// RMS logs the returned [`Action`] and applies the resize protocols
/// afterwards, and the discrete-event engine relies on decisions being a
/// deterministic function of the context.
///
/// # Example
///
/// A custom strategy that grabs every idle node whenever the queue is
/// empty and otherwise holds steady:
///
/// ```
/// use dmr::rms::policy::{
///     expand_target, Action, DmrRequest, PolicyContext, ReconfigPolicy, SystemView,
/// };
///
/// struct Greedy;
///
/// impl ReconfigPolicy for Greedy {
///     fn name(&self) -> &'static str {
///         "greedy"
///     }
///
///     fn decide(&self, ctx: &PolicyContext) -> Action {
///         let cap = ctx.req.max.min(ctx.current + ctx.view.available);
///         let to = expand_target(ctx.current, ctx.req.factor, cap);
///         if ctx.view.pending_jobs == 0 && to > ctx.current {
///             Action::Expand { to }
///         } else {
///             Action::NoAction
///         }
///     }
/// }
///
/// let req = DmrRequest { min: 2, max: 32, pref: None, factor: 2 };
/// let view = SystemView { available: 24, pending_jobs: 0, head_need: None };
/// let ctx = PolicyContext::new(0.0, 8, &req, view);
/// assert_eq!(Greedy.decide(&ctx), Action::Expand { to: 32 });
/// ```
pub trait ReconfigPolicy: Send + Sync {
    /// Stable strategy name (scenario labels, logs).
    fn name(&self) -> &'static str;

    /// Decide the action for the job described by `ctx`.
    fn decide(&self, ctx: &PolicyContext) -> Action;

    /// Whether the RMS should pay the O(active + pending) scan that
    /// populates the per-user usage fields of the context.  Defaults to
    /// `false` so the baseline stays scan-free.
    fn wants_usage(&self) -> bool {
        false
    }

    /// Whether [`ReconfigPolicy::decide`] ignores [`PolicyContext::now`]
    /// — i.e. two contexts differing *only* in `now` always yield the
    /// same action.  When `true`, the RMS may return a memoized
    /// `NoAction` for a repeated check whose entire remaining context is
    /// provably unchanged (the no-op elision of the incremental
    /// availability profile) even though the clock advanced.  Defaults
    /// to `false`: a time-reading strategy that wrongly advertises
    /// invariance would make the memoized path diverge from the
    /// reference path, so only opt in when `decide` genuinely never
    /// reads `now`.
    fn time_invariant(&self) -> bool {
        false
    }
}

/// The built-in strategy registry: a copyable selector carried by
/// [`crate::rms::RmsConfig`] and swept by campaigns.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyStrategy {
    /// The paper's §4 rule (the golden baseline) — [`ThroughputAware`].
    #[default]
    ThroughputAware,
    /// Queue-pressure-driven — [`QueueAware`].
    QueueAware,
    /// Per-user node-share balancing — [`FairShare`].
    FairShare,
    /// Soft-deadline protection — [`DeadlineAware`].
    DeadlineAware,
}

impl PolicyStrategy {
    /// Every built-in strategy, in registry order.
    pub const ALL: [PolicyStrategy; 4] = [
        PolicyStrategy::ThroughputAware,
        PolicyStrategy::QueueAware,
        PolicyStrategy::FairShare,
        PolicyStrategy::DeadlineAware,
    ];

    /// Parse a spec-file name (`"throughput" | "queue" | "fair" |
    /// "deadline"`, long aliases accepted).
    pub fn parse(s: &str) -> Result<PolicyStrategy, String> {
        match s {
            "throughput" | "throughput_aware" => Ok(PolicyStrategy::ThroughputAware),
            "queue" | "queue_aware" => Ok(PolicyStrategy::QueueAware),
            "fair" | "fair_share" => Ok(PolicyStrategy::FairShare),
            "deadline" | "deadline_aware" => Ok(PolicyStrategy::DeadlineAware),
            other => Err(format!(
                "unknown policy strategy {other:?} (expected throughput | queue | fair | deadline)"
            )),
        }
    }

    /// Short label used in scenario ids and CSV cells.
    pub fn label(&self) -> &'static str {
        match self {
            PolicyStrategy::ThroughputAware => "throughput",
            PolicyStrategy::QueueAware => "queue",
            PolicyStrategy::FairShare => "fair",
            PolicyStrategy::DeadlineAware => "deadline",
        }
    }

    /// Instantiate the strategy with its knobs drawn from `cfg`.
    pub fn build(&self, cfg: &PolicyConfig) -> Box<dyn ReconfigPolicy> {
        match self {
            PolicyStrategy::ThroughputAware => Box::new(ThroughputAware::new(cfg.clone())),
            PolicyStrategy::QueueAware => {
                Box::new(QueueAware { pressure: cfg.queue_pressure })
            }
            PolicyStrategy::FairShare => Box::new(FairShare { slack: cfg.fair_share_slack }),
            PolicyStrategy::DeadlineAware => Box::new(DeadlineAware::new(cfg.clone())),
        }
    }
}

/// Policy configuration: the [`ThroughputAware`] ablation switches
/// (DESIGN.md §5) plus the knobs of the non-default strategies.  Knobs a
/// strategy does not read are ignored by it.
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// §4.2 preferred-number-of-nodes handling ([`ThroughputAware`]).
    pub honor_preference: bool,
    /// §4.3 wide optimization ([`ThroughputAware`]).
    pub wide_optimization: bool,
    /// [`QueueAware`]: pending-job count at (or above) which running jobs
    /// are shrunk toward their preferred size.
    pub queue_pressure: usize,
    /// [`FairShare`]: tolerated over/under-share factor (≥ 1) before the
    /// strategy acts; 1.0 reacts to any imbalance.
    pub fair_share_slack: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            honor_preference: true,
            wide_optimization: true,
            queue_pressure: 2,
            fair_share_slack: 1.25,
        }
    }
}

/// Largest factor-reachable size from `current` that is <= `cap`
/// (expansion targets: current * factor^k).
pub fn expand_target(current: usize, factor: usize, cap: usize) -> usize {
    let mut t = current;
    while t * factor <= cap {
        t *= factor;
    }
    t
}

/// Smallest factor-reachable size from `current` that is >= `floor`
/// (shrink targets: current / factor^k).
pub fn shrink_target(current: usize, factor: usize, floor: usize) -> usize {
    let mut t = current;
    while t % factor == 0 && t / factor >= floor {
        t /= factor;
    }
    t
}

/// Whether `target` is reachable from `current` by multiplying/dividing by
/// `factor` repeatedly.
pub fn factor_reachable(current: usize, target: usize, factor: usize) -> bool {
    if factor < 2 {
        return true;
    }
    let (mut lo, hi) = if target < current { (target, current) } else { (current, target) };
    while lo < hi {
        lo *= factor;
    }
    lo == hi
}

/// The shrink floor every strategy steers toward: the job's preferred
/// size clamped into `[min, max]`, or its minimum when no preference is
/// stated.  One definition so the strategies cannot drift on the same
/// request.
pub fn pref_floor(req: &DmrRequest) -> usize {
    req.pref.unwrap_or(req.min).clamp(req.min, req.max)
}

/// The largest factor-chain expansion that fits both the request maximum
/// and the free pool: [`expand_target`] capped at
/// `max.min(current + available)`.  `None` when no step up fits.  Like
/// [`pref_floor`], one definition shared by every strategy so the
/// expansion cap rule cannot drift between them.
pub fn expand_fill(current: usize, req: &DmrRequest, available: usize) -> Option<usize> {
    let to = expand_target(current, req.factor, req.max.min(current + available));
    (to > current).then_some(to)
}

/// The §4.1 *request an action* handling every strategy must honor: the
/// application raising its minimum above the current size forces an
/// expansion (granted only up to what is available), lowering its maximum
/// below it forces a shrink.  Returns `None` when nothing is forced and
/// the strategy is free to decide.
pub fn forced_action(current: usize, req: &DmrRequest, view: &SystemView) -> Option<Action> {
    if req.min > current {
        // Forced expansion; grant only up to what is available.
        let want = expand_target(current, req.factor, req.max.min(current + view.available));
        let want = want.max(req.min.min(current + view.available));
        if want > current && factor_reachable(current, want, req.factor) {
            return Some(Action::Expand { to: want });
        }
        return Some(Action::NoAction);
    }
    if req.max < current {
        // Forced shrink: release only as much as needed to get under the
        // new maximum (factor-reachable).
        let mut to = current;
        while to > req.max && to % req.factor == 0 && to / req.factor >= req.min {
            to /= req.factor;
        }
        if to > req.max {
            to = req.max; // not factor-reachable; honor the hard cap
        }
        return Some(Action::Shrink { to });
    }
    None
}

/// Decide the action for a job currently at `current` processes under the
/// paper's §4 rule (the [`ThroughputAware`] baseline).
///
/// Pure function of the request and the system view; the RMS applies the
/// protocols (resizer job, ACK shrink) afterwards.
pub fn decide(
    cfg: &PolicyConfig,
    current: usize,
    req: &DmrRequest,
    view: &SystemView,
) -> Action {
    // --- §4.1 Request an action -----------------------------------------
    if let Some(forced) = forced_action(current, req, view) {
        return forced;
    }

    // --- §4.2 Preferred number of nodes ----------------------------------
    if cfg.honor_preference {
        if let Some(pref) = req.pref {
            let pref = pref.clamp(req.min, req.max);
            if pref == current {
                // "If the desired size corresponds to the current size,
                // the RMS will return no action" — at the §4.2 level.
                // §4.3 wide optimization below may still expand the job
                // into *queue-starved* idle nodes (nodes no pending job
                // can use anyway); the checking inhibitor bounds the
                // resulting churn.
            } else if view.pending_jobs == 0 {
                // Queue empty: expansion can be granted up to the maximum.
                if let Some(to) = expand_fill(current, req, view.available) {
                    return Action::Expand { to };
                }
            } else if pref < current {
                // Steer toward the preferred size, releasing nodes for the
                // queue.
                if factor_reachable(current, pref, req.factor) {
                    return Action::Shrink { to: pref };
                }
                return Action::Shrink { to: shrink_target(current, req.factor, pref) };
            } else {
                // pref > current: expand toward pref if resources allow.
                let cap = pref.min(current + view.available);
                let to = expand_target(current, req.factor, cap);
                if to > current {
                    return Action::Expand { to };
                }
                return Action::NoAction;
            }
        }
    }

    // --- §4.3 Wide optimization ------------------------------------------
    if cfg.wide_optimization {
        // Expand if resources are spare and either the queue is empty or
        // no pending job can use them anyway.
        let queue_starved = match view.head_need {
            None => true,
            Some(need) => need > view.available,
        };
        if view.available > 0 && queue_starved && current < req.max {
            if let Some(to) = expand_fill(current, req, view.available) {
                return Action::Expand { to };
            }
        }
        // Shrink if that lets a queued job start.
        if let Some(need) = view.head_need {
            let floor = pref_floor(req);
            let to = shrink_target(current, req.factor, floor);
            let released = current.saturating_sub(to);
            if released > 0 && view.available + released >= need {
                return Action::Shrink { to };
            }
        }
    }

    Action::NoAction
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(min: usize, max: usize, pref: Option<usize>) -> DmrRequest {
        DmrRequest { min, max, pref, factor: 2 }
    }

    fn view(available: usize, pending: usize, head: Option<usize>) -> SystemView {
        SystemView { available, pending_jobs: pending, head_need: head }
    }

    #[test]
    fn targets() {
        assert_eq!(expand_target(8, 2, 32), 32);
        assert_eq!(expand_target(8, 2, 31), 16);
        assert_eq!(expand_target(8, 2, 8), 8);
        assert_eq!(shrink_target(32, 2, 8), 8);
        assert_eq!(shrink_target(32, 2, 9), 16);
        assert_eq!(shrink_target(7, 2, 1), 7); // 7 not divisible
        assert!(factor_reachable(8, 32, 2));
        assert!(!factor_reachable(8, 24, 2));
    }

    #[test]
    fn target_boundaries() {
        // expand_target when the cap sits below the next factor step:
        // stay put (31 < 8*2*2, 15 < 8*2).
        assert_eq!(expand_target(8, 2, 15), 8);
        assert_eq!(expand_target(8, 2, 16), 16);
        assert_eq!(expand_target(1, 2, 1), 1);
        assert_eq!(expand_target(8, 2, 7), 8, "cap below current never shrinks");
        // shrink_target at the floor: no movement
        assert_eq!(shrink_target(8, 2, 8), 8);
        // floor above current: shrink_target never moves upward
        assert_eq!(shrink_target(8, 2, 9), 8);
        // the chain stops where divisibility ends, not at the floor
        assert_eq!(shrink_target(12, 2, 1), 3);
        assert_eq!(shrink_target(1, 2, 1), 1);
        // factor_reachable for non-chain targets
        assert!(!factor_reachable(8, 12, 2), "12 is not on 8's factor-2 chain");
        assert!(!factor_reachable(3, 10, 2));
        assert!(factor_reachable(3, 48, 2), "48 = 3 * 2^4");
        assert!(factor_reachable(5, 5, 3), "zero steps is always reachable");
        // factor < 2 treats every target as reachable (degenerate chain)
        assert!(factor_reachable(7, 9, 1));
        assert!(factor_reachable(2, 9, 0));
    }

    #[test]
    fn forced_expand_41() {
        // App raises min above current => expand (resources permitting).
        let a = decide(&PolicyConfig::default(), 8, &req(16, 32, None), &view(24, 3, Some(64)));
        assert_eq!(a, Action::Expand { to: 32 });
        // Without resources: no action.
        let a = decide(&PolicyConfig::default(), 8, &req(16, 32, None), &view(0, 3, Some(64)));
        assert_eq!(a, Action::NoAction);
    }

    #[test]
    fn forced_shrink_41() {
        let a = decide(&PolicyConfig::default(), 32, &req(2, 8, None), &view(0, 0, None));
        assert_eq!(a, Action::Shrink { to: 8 });
    }

    #[test]
    fn forced_action_helper_matches_decide_on_forced_cases() {
        // The helper is the §4.1 blocks verbatim: on forced inputs its
        // answer must equal decide()'s for any ablation config.
        let cfgs = [
            PolicyConfig::default(),
            PolicyConfig { honor_preference: false, ..Default::default() },
            PolicyConfig { wide_optimization: false, ..Default::default() },
        ];
        let cases = [
            (8, req(16, 32, None), view(24, 3, Some(64))),
            (8, req(16, 32, None), view(0, 3, Some(64))),
            (32, req(2, 8, None), view(0, 0, None)),
            (32, req(2, 7, None), view(4, 1, Some(8))),
        ];
        for cfg in &cfgs {
            for (current, r, v) in &cases {
                let forced = forced_action(*current, r, v).expect("case is forced");
                assert_eq!(forced, decide(cfg, *current, r, v));
            }
        }
        // Non-forced inputs leave the strategy free.
        assert!(forced_action(8, &req(2, 32, Some(8)), &view(0, 2, Some(64))).is_none());
    }

    #[test]
    fn preference_no_action_at_pref_with_queue() {
        // At preferred size, queue nonempty, no shrink would help the
        // (huge) head job => no action.
        let a = decide(&PolicyConfig::default(), 8, &req(2, 32, Some(8)), &view(0, 2, Some(64)));
        assert_eq!(a, Action::NoAction);
    }

    #[test]
    fn preference_empty_queue_expands_to_max() {
        let a = decide(&PolicyConfig::default(), 8, &req(2, 32, Some(8)), &view(56, 0, None));
        assert_eq!(a, Action::Expand { to: 32 });
    }

    #[test]
    fn preference_shrinks_toward_pref_when_queued() {
        // Launched at max (32), pref 8, jobs waiting => scale down
        // (the paper's "scaled-down as soon as possible", §7.5).
        let a = decide(&PolicyConfig::default(), 32, &req(2, 32, Some(8)), &view(0, 4, Some(32)));
        assert_eq!(a, Action::Shrink { to: 8 });
    }

    #[test]
    fn preference_expands_toward_pref() {
        let a = decide(&PolicyConfig::default(), 2, &req(2, 32, Some(8)), &view(10, 3, Some(64)));
        assert_eq!(a, Action::Expand { to: 8 });
    }

    #[test]
    fn wide_expand_when_queue_starved() {
        // No preference; 4 free nodes; head needs 32 (> 4) => the spare
        // nodes go to the running job.
        let a = decide(&PolicyConfig::default(), 4, &req(1, 16, None), &view(4, 1, Some(32)));
        assert_eq!(a, Action::Expand { to: 8 });
    }

    #[test]
    fn wide_shrink_when_release_starts_head() {
        // No preference: shrink 16 -> 1 (floor = min) releases 15; head
        // needs 8 <= 0 + 15 => shrink.
        let a = decide(&PolicyConfig::default(), 16, &req(1, 16, None), &view(0, 1, Some(8)));
        assert_eq!(a, Action::Shrink { to: 1 });
    }

    #[test]
    fn wide_no_shrink_when_release_insufficient() {
        let a = decide(&PolicyConfig::default(), 4, &req(2, 16, None), &view(0, 1, Some(32)));
        assert_eq!(a, Action::NoAction);
    }

    #[test]
    fn ablation_disable_wide() {
        let cfg = PolicyConfig { wide_optimization: false, ..Default::default() };
        let a = decide(&cfg, 4, &req(1, 16, None), &view(4, 1, Some(32)));
        assert_eq!(a, Action::NoAction);
    }

    #[test]
    fn ablation_disable_preference_falls_through_to_wide() {
        let cfg = PolicyConfig { honor_preference: false, ..Default::default() };
        // pref says shrink to 8, but preference handling is off; wide
        // optimization still shrinks (to pref floor) because head fits.
        let a = decide(&cfg, 32, &req(2, 32, Some(8)), &view(0, 1, Some(16)));
        assert_eq!(a, Action::Shrink { to: 8 });
    }

    #[test]
    fn strategy_registry_round_trips() {
        for s in PolicyStrategy::ALL {
            assert_eq!(PolicyStrategy::parse(s.label()), Ok(s));
            let built = s.build(&PolicyConfig::default());
            assert_eq!(built.name(), s.label());
        }
        assert!(PolicyStrategy::parse("warp").is_err());
        assert_eq!(PolicyStrategy::parse("fair_share"), Ok(PolicyStrategy::FairShare));
        assert_eq!(PolicyStrategy::default(), PolicyStrategy::ThroughputAware);
    }
}
