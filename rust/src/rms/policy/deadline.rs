//! Deadline-protection strategy: expand jobs projected to miss their
//! soft deadline, never shrink them.

use super::{
    decide, expand_fill, forced_action, Action, PolicyConfig, PolicyContext, ReconfigPolicy,
};

/// Soft-deadline protection.  Jobs may carry an optional deadline
/// ([`crate::workload::JobSpec::deadline`]); at every reconfiguring point
/// the strategy compares the scheduler's completion estimate
/// ([`PolicyContext::expected_end`]) against it:
///
/// * **Projected to miss** (estimate strictly past the deadline) —
///   expand as far as the free nodes and the job's maximum allow.
/// * **On track** (estimate at or before the deadline — exactly-on-time
///   counts as on track) — hold steady.  A deadline job is *never*
///   voluntarily shrunk: giving its nodes away is exactly how deadlines
///   get missed.
///
/// Jobs without a deadline fall back to the [`ThroughputAware`] baseline
/// unmodified, so their nodes remain available to the queue — and, via
/// the resizer-job protocol, to deadline jobs that need to grow.
///
/// §4.1 forced requests ([`forced_action`]) always win, including forced
/// shrinks: the application lowering its own maximum is a hard
/// constraint, not a scheduler choice.
///
/// [`ThroughputAware`]: super::ThroughputAware
#[derive(Debug, Clone)]
pub struct DeadlineAware {
    cfg: PolicyConfig,
}

impl DeadlineAware {
    /// Build with the baseline's config for the deadline-less fallback.
    pub fn new(cfg: PolicyConfig) -> Self {
        DeadlineAware { cfg }
    }
}

impl ReconfigPolicy for DeadlineAware {
    fn name(&self) -> &'static str {
        "deadline"
    }

    fn decide(&self, ctx: &PolicyContext) -> Action {
        let Some(deadline) = ctx.deadline else {
            // No deadline to protect: behave exactly like the baseline.
            return decide(&self.cfg, ctx.current, ctx.req, &ctx.view);
        };
        if let Some(forced) = forced_action(ctx.current, ctx.req, &ctx.view) {
            return forced;
        }
        let projected = ctx.expected_end.unwrap_or(ctx.now);
        if projected > deadline {
            if let Some(to) = expand_fill(ctx.current, ctx.req, ctx.view.available) {
                return Action::Expand { to };
            }
        }
        Action::NoAction
    }

    /// **Not** time-invariant: with no completion estimate the deadline
    /// projection falls back to `ctx.now` (above), so the same context at
    /// a later clock can cross the deadline and flip the decision.  The
    /// RMS therefore never elides this strategy's checks across clock
    /// values (same-instant elision remains sound and allowed).
    fn time_invariant(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rms::policy::{DmrRequest, SystemView};

    const REQ: DmrRequest = DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 };

    fn ctx_with<'a>(
        current: usize,
        req: &'a DmrRequest,
        view: SystemView,
        deadline: Option<f64>,
        expected_end: Option<f64>,
    ) -> PolicyContext<'a> {
        let mut ctx = PolicyContext::new(100.0, current, req, view);
        ctx.deadline = deadline;
        ctx.expected_end = expected_end;
        ctx
    }

    #[test]
    fn projected_miss_expands_to_what_fits() {
        let p = DeadlineAware::new(PolicyConfig::default());
        let view = SystemView { available: 24, pending_jobs: 3, head_need: Some(64) };
        let ctx = ctx_with(8, &REQ, view, Some(500.0), Some(600.0));
        assert_eq!(p.decide(&ctx), Action::Expand { to: 32 });
    }

    #[test]
    fn exactly_on_time_is_on_track() {
        // The edge case: estimate == deadline must NOT trigger an
        // expansion (the job makes it, strictly-late is the miss).
        let p = DeadlineAware::new(PolicyConfig::default());
        let view = SystemView { available: 24, pending_jobs: 0, head_need: None };
        let ctx = ctx_with(8, &REQ, view, Some(500.0), Some(500.0));
        assert_eq!(p.decide(&ctx), Action::NoAction);
    }

    #[test]
    fn deadline_jobs_are_never_voluntarily_shrunk() {
        // The baseline would shrink 32 → 8 here (pref 8, queue waiting,
        // release starts the head); the deadline job holds instead.
        let p = DeadlineAware::new(PolicyConfig::default());
        let view = SystemView { available: 0, pending_jobs: 4, head_need: Some(16) };
        let baseline = decide(&PolicyConfig::default(), 32, &REQ, &view);
        assert!(matches!(baseline, Action::Shrink { .. }));
        let ctx = ctx_with(32, &REQ, view, Some(5_000.0), Some(400.0));
        assert_eq!(p.decide(&ctx), Action::NoAction);
    }

    #[test]
    fn miss_without_resources_holds() {
        let p = DeadlineAware::new(PolicyConfig::default());
        let view = SystemView { available: 0, pending_jobs: 1, head_need: Some(8) };
        let ctx = ctx_with(8, &REQ, view, Some(500.0), Some(600.0));
        assert_eq!(p.decide(&ctx), Action::NoAction);
    }

    #[test]
    fn no_deadline_falls_back_to_baseline() {
        let p = DeadlineAware::new(PolicyConfig::default());
        for (current, view) in [
            (32, SystemView { available: 0, pending_jobs: 4, head_need: Some(16) }),
            (8, SystemView { available: 56, pending_jobs: 0, head_need: None }),
            (4, SystemView { available: 4, pending_jobs: 1, head_need: Some(32) }),
        ] {
            let ctx = ctx_with(current, &REQ, view, None, Some(999.0));
            assert_eq!(
                p.decide(&ctx),
                decide(&PolicyConfig::default(), current, &REQ, &view)
            );
        }
    }

    #[test]
    fn forced_shrink_still_wins_over_protection() {
        // The app lowered its own maximum below the current size: hard
        // constraint, even for a deadline job projected to miss.
        let p = DeadlineAware::new(PolicyConfig::default());
        let req = DmrRequest { min: 2, max: 8, pref: None, factor: 2 };
        let view = SystemView { available: 24, pending_jobs: 0, head_need: None };
        let ctx = ctx_with(32, &req, view, Some(500.0), Some(600.0));
        assert_eq!(p.decide(&ctx), Action::Shrink { to: 8 });
    }
}
