//! Pending-job ordering: Slurm's *multifactor* priority policy (§7.2 —
//! "we also enabled job priorities with the policy multifactor", default
//! weights), plus the max-priority boost used by the reconfiguration
//! protocols.

use super::job::Job;
use crate::Time;

/// Weights of the multifactor plug-in components we model (age + job
/// size), normalized like Slurm's: each factor in \[0,1\] scaled by its
/// weight.
#[derive(Debug, Clone)]
pub struct PriorityWeights {
    /// Weight of the (saturating) age factor.
    pub age_weight: f64,
    /// Favor bigger jobs (Slurm's default size factor favours larger
    /// allocations so they do not starve).
    pub size_weight: f64,
    /// Saturation horizon for the age factor (Slurm default 7 days; our
    /// workloads span hours, so we saturate at 1 h).
    pub age_horizon: f64,
    /// Boost added by `qos_boost` (resizer jobs / shrink triggers get the
    /// maximum priority — §4.3, §5.2.1).
    pub boost: f64,
}

impl Default for PriorityWeights {
    fn default() -> Self {
        Self { age_weight: 1000.0, size_weight: 100.0, age_horizon: 3600.0, boost: 1e9 }
    }
}

/// Compute the multifactor priority of a pending job at time `now`.
pub fn priority(job: &Job, w: &PriorityWeights, total_nodes: usize, now: Time) -> f64 {
    let age = ((now - job.submit_time) / w.age_horizon).clamp(0.0, 1.0);
    let size = job.spec.procs as f64 / total_nodes.max(1) as f64;
    let mut p = w.age_weight * age + w.size_weight * size;
    if job.qos_boost {
        p += w.boost;
    }
    p
}

/// The queue's sort key: (priority, submit time, id).
pub type PendingKey = (f64, Time, crate::JobId);

/// THE canonical pending-queue order: descending priority; FIFO (submit
/// time, then id) as the tie-break so ordering is deterministic and
/// total.  Every consumer — [`order_pending`] and the RMS's cached
/// order (`rms::Rms`) — must sort with this comparator, never a copy.
/// Built on [`f64::total_cmp`]: a NaN priority (a poisoned estimate
/// upstream) sorts deterministically instead of panicking the scheduler
/// mid-pass.
pub fn pending_cmp(a: &PendingKey, b: &PendingKey) -> std::cmp::Ordering {
    b.0.total_cmp(&a.0).then(a.1.total_cmp(&b.1)).then(a.2.cmp(&b.2))
}

/// Sort job ids by [`pending_cmp`].
pub fn order_pending(
    ids: &[crate::JobId],
    get: impl Fn(crate::JobId) -> PendingKey,
) -> Vec<crate::JobId> {
    let mut keyed: Vec<PendingKey> = ids.iter().map(|&id| get(id)).collect();
    keyed.sort_by(pending_cmp);
    keyed.into_iter().map(|k| k.2).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::config::AppKind;
    use crate::workload::JobSpec;

    fn job(id: u64, submit: f64) -> Job {
        let spec = JobSpec::from_app(AppKind::Cg, format!("j{id}"), submit, 1.0);
        Job::new(id, spec, submit)
    }

    #[test]
    fn age_increases_priority() {
        let w = PriorityWeights::default();
        let old = job(1, 0.0);
        let new = job(2, 100.0);
        assert!(priority(&old, &w, 64, 200.0) > priority(&new, &w, 64, 200.0));
    }

    #[test]
    fn boost_dominates() {
        let w = PriorityWeights::default();
        let mut boosted = job(1, 1000.0);
        boosted.qos_boost = true;
        let aged = job(2, 0.0);
        assert!(priority(&boosted, &w, 64, 5000.0) > priority(&aged, &w, 64, 5000.0));
    }

    #[test]
    fn age_saturates() {
        let w = PriorityWeights::default();
        let j = job(1, 0.0);
        let p1 = priority(&j, &w, 64, 3600.0);
        let p2 = priority(&j, &w, 64, 7200.0);
        assert_eq!(p1, p2);
    }

    #[test]
    fn order_deterministic_fifo_tiebreak() {
        let ids = vec![3, 1, 2];
        let ordered = order_pending(&ids, |id| (1.0, id as f64, id));
        assert_eq!(ordered, vec![1, 2, 3]);
    }
}
