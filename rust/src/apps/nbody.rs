//! Distributed all-pairs N-body: bodies sharded across ranks; positions
//! all-gathered every step; forces/integration in `nbody_step_p{P}`.

use anyhow::{Context, Result};

use super::state::N_NB;
use crate::runtime::{ComputeHandle, TensorF32};
use crate::vmpi::Endpoint;

const DT: f32 = 1e-3;

pub struct NBodyShard {
    pub rank: usize,
    pub size: usize,
    pub n_loc: usize,
    /// Local positions (n_loc x 3 row-major).
    pub pos: Vec<f32>,
    /// Local velocities.
    pub vel: Vec<f32>,
    /// Full mass vector (deterministic; recomputed locally, never moved).
    pub mass: Vec<f32>,
}

/// Deterministic initial position component (SplitMix64-hashed lattice).
pub fn pos_at(body: usize, dim: usize) -> f32 {
    let mut z = (body as u64).wrapping_mul(3).wrapping_add(dim as u64).wrapping_add(1);
    z = z.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    // map to [-1, 1)
    ((z >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
}

impl NBodyShard {
    /// pos(3) + vel(3) per body.
    pub const ROW_F32S: usize = 6;

    pub fn init(rank: usize, size: usize) -> NBodyShard {
        let n_loc = N_NB / size;
        let off = rank * n_loc;
        let mut pos = Vec::with_capacity(n_loc * 3);
        for b in 0..n_loc {
            for d in 0..3 {
                pos.push(pos_at(off + b, d));
            }
        }
        let mass = vec![1.0 / N_NB as f32; N_NB];
        NBodyShard { rank, size, n_loc, pos, vel: vec![0.0; n_loc * 3], mass }
    }

    /// One integration step; returns the global kinetic energy.
    pub fn step(&mut self, ep: &Endpoint, compute: &ComputeHandle) -> Result<f64> {
        let p = self.size;
        let pos_all = ep.allgather_f32(&self.pos);
        debug_assert_eq!(pos_all.len(), N_NB * 3);
        let out = compute
            .execute(
                &format!("nbody_step_p{p}"),
                vec![
                    TensorF32::new(vec![N_NB, 3], pos_all),
                    TensorF32::new(vec![self.n_loc, 3], self.pos.clone()),
                    TensorF32::new(vec![self.n_loc, 3], self.vel.clone()),
                    TensorF32::vec(self.mass.clone()),
                    TensorF32::scalar(DT),
                ],
            )
            .context("nbody_step")?;
        self.pos = out[0].data.clone();
        self.vel = out[1].data.clone();
        Ok(ep.allreduce_sum(out[2].item() as f64))
    }

    pub fn to_rows(&self) -> Vec<f32> {
        let mut rows = Vec::with_capacity(self.n_loc * 6);
        for b in 0..self.n_loc {
            rows.extend_from_slice(&self.pos[b * 3..b * 3 + 3]);
            rows.extend_from_slice(&self.vel[b * 3..b * 3 + 3]);
        }
        rows
    }

    pub fn from_rows(rank: usize, size: usize, rows: Vec<f32>) -> NBodyShard {
        let n_loc = rows.len() / 6;
        assert_eq!(n_loc, N_NB / size, "N-body shard size mismatch");
        let mut pos = Vec::with_capacity(n_loc * 3);
        let mut vel = Vec::with_capacity(n_loc * 3);
        for c in rows.chunks_exact(6) {
            pos.extend_from_slice(&c[..3]);
            vel.extend_from_slice(&c[3..]);
        }
        let mass = vec![1.0 / N_NB as f32; N_NB];
        NBodyShard { rank, size, n_loc, pos, vel, mass }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_deterministic() {
        let a = NBodyShard::init(0, 2);
        let b = NBodyShard::init(1, 2);
        assert_eq!(a.n_loc, 512);
        assert_eq!(b.pos[0], pos_at(512, 0));
        assert!(a.pos.iter().all(|&x| (-1.0..1.0).contains(&x)));
    }

    #[test]
    fn rows_roundtrip() {
        let mut s = NBodyShard::init(3, 4);
        s.vel.iter_mut().enumerate().for_each(|(i, v)| *v = i as f32 * 0.5);
        let s2 = NBodyShard::from_rows(3, 4, s.to_rows());
        assert_eq!(s2.pos, s.pos);
        assert_eq!(s2.vel, s.vel);
    }
}
