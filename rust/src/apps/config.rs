//! Application kinds and their reconfiguration parameters — Table 1 of the
//! paper, plus the execution-model constants used to calibrate the
//! discrete-event mode (see `des::execmodel`).

/// The applications the paper evaluates (§7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AppKind {
    /// Synthetic "Flexible Sleep" used for the overhead study (§7.3).
    FlexibleSleep,
    /// Conjugate Gradient on the 1-D Laplacian.
    Cg,
    /// Jacobi 5-point relaxation.
    Jacobi,
    /// All-pairs N-body.
    NBody,
}

impl AppKind {
    pub fn name(&self) -> &'static str {
        match self {
            AppKind::FlexibleSleep => "FS",
            AppKind::Cg => "CG",
            AppKind::Jacobi => "Jacobi",
            AppKind::NBody => "N-body",
        }
    }

    /// The three non-synthetic applications of the throughput evaluation
    /// (§7.5): CG, Jacobi and N-body.
    pub const WORKLOAD_APPS: [AppKind; 3] = [AppKind::Cg, AppKind::Jacobi, AppKind::NBody];
}

impl std::fmt::Display for AppKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-application reconfiguration parameters — Table 1.
#[derive(Debug, Clone, Copy)]
pub struct AppConfig {
    pub app: AppKind,
    /// Reconfiguring points: iterations of the outer loop.
    pub iterations: u32,
    /// Minimum number of processes the job can shrink to.
    pub min_procs: usize,
    /// Maximum number of processes the job can expand to
    /// ("prevents the application from growing beyond its scalability").
    pub max_procs: usize,
    /// Preferred number of processes ("sweet spot"), if any.
    pub pref_procs: Option<usize>,
    /// Checking-inhibitor period in seconds (0 = every iteration).
    pub sched_period: f64,
    /// Resizing factor: expand/shrink moves to multiples/divisors of this.
    pub factor: usize,
    /// Execution-model calibration: node-seconds of work per iteration at
    /// scale 1.0.
    pub work_per_iter: f64,
    /// Parallel-scaling exponent: exec time at p processes =
    /// iterations * work / p^alpha.  The paper's own Table 4 numbers
    /// (flexible exec only ~1.45x fixed despite 32->8 shrinks, and a ~3x
    /// node-seconds reduction at equal work) require sublinear scaling:
    /// CG/Jacobi are memory/communication-bound (alpha ~ 0.5, sweet spot
    /// 8) and N-body is dominated by the all-gather (alpha ~ 0, sweet
    /// spot 1 — exactly why Table 1 prefers 1).  See DESIGN.md §2.
    pub alpha: f64,
}

/// Table 1 of the paper (plus calibration constants chosen so the *fixed*
/// per-job execution times land in the paper's 500–650 s band — §7.5,
/// Table 4).
pub const fn config_for(app: AppKind) -> AppConfig {
    match app {
        AppKind::FlexibleSleep => AppConfig {
            app,
            iterations: 25,
            min_procs: 1,
            max_procs: 20,
            pref_procs: None,
            sched_period: 0.0,
            factor: 2,
            work_per_iter: 4.0,
            alpha: 1.0,
        },
        AppKind::Cg => AppConfig {
            app,
            iterations: 10_000,
            min_procs: 2,
            max_procs: 32,
            pref_procs: Some(8),
            sched_period: 15.0,
            factor: 2,
            work_per_iter: 0.19, // 600 s at 32 procs over 10k iterations
            alpha: 0.33,
        },
        AppKind::Jacobi => AppConfig {
            app,
            iterations: 10_000,
            min_procs: 2,
            max_procs: 32,
            pref_procs: Some(8),
            sched_period: 15.0,
            factor: 2,
            work_per_iter: 0.17, // slightly cheaper sweep than CG
            alpha: 0.33,
        },
        AppKind::NBody => AppConfig {
            app,
            iterations: 25,
            min_procs: 1,
            max_procs: 16,
            pref_procs: Some(1),
            sched_period: 0.0,
            factor: 2,
            work_per_iter: 22.0, // ~550 s regardless of size (alpha ~ 0)
            alpha: 0.08,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        let cg = config_for(AppKind::Cg);
        assert_eq!(cg.iterations, 10_000);
        assert_eq!((cg.min_procs, cg.max_procs), (2, 32));
        assert_eq!(cg.pref_procs, Some(8));
        assert_eq!(cg.sched_period, 15.0);

        let fs = config_for(AppKind::FlexibleSleep);
        assert_eq!(fs.iterations, 25);
        assert_eq!((fs.min_procs, fs.max_procs), (1, 20));
        assert_eq!(fs.pref_procs, None);

        let nb = config_for(AppKind::NBody);
        assert_eq!((nb.min_procs, nb.max_procs), (1, 16));
        assert_eq!(nb.pref_procs, Some(1));
    }

    #[test]
    fn fixed_exec_times_in_paper_band() {
        // Fixed jobs run at max procs for all iterations: the paper's
        // Table 4 reports 520–620 s averages.
        for app in AppKind::WORKLOAD_APPS {
            let c = config_for(app);
            let exec =
                c.iterations as f64 * c.work_per_iter / (c.max_procs as f64).powf(c.alpha);
            assert!(
                (400.0..700.0).contains(&exec),
                "{app}: fixed exec {exec}s out of band"
            );
        }
    }

    #[test]
    fn display_names() {
        assert_eq!(AppKind::Cg.to_string(), "CG");
        assert_eq!(AppKind::FlexibleSleep.to_string(), "FS");
    }
}
