//! Flexible Sleep (FS): the synthetic application of the overhead study
//! (§7.3).  Each iteration "computes" by sleeping for the configured work
//! divided by the current process count; the per-rank data payload is what
//! the reconfiguration redistributes (1 GB total in the paper's
//! experiments).
//!
//! Sleeps are scaled by `DMR_TIME_SCALE` (default 1.0) so live examples
//! can run at, e.g., 100× speed without changing the workload definition.

use anyhow::Result;

use super::config::{config_for, AppKind};
use crate::vmpi::Endpoint;

pub struct FsShard {
    pub rank: usize,
    pub size: usize,
    /// Payload ballast (f32s so redistribution reuses the row machinery).
    pub data: Vec<f32>,
    /// Seconds to sleep per iteration at the current size (pre-scaled).
    pub sleep_per_iter: f64,
}

/// Total FS payload redistributed on resize (f32 elements).  The paper's
/// overhead study transfers 1 GB; the default here is 64 MB so the test
/// suite stays fast — the overhead-study bench overrides it via
/// `DMR_FS_MB`.
pub fn fs_payload_f32s() -> usize {
    let mb: usize = std::env::var("DMR_FS_MB")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);
    mb * 1024 * 1024 / 4
}

pub fn time_scale() -> f64 {
    std::env::var("DMR_TIME_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

impl FsShard {
    pub const ROW_F32S: usize = 1;

    pub fn init(rank: usize, size: usize, work_scale: f64) -> FsShard {
        let total = fs_payload_f32s();
        let n_loc = total / size;
        let off = rank * n_loc;
        let data: Vec<f32> = (0..n_loc).map(|i| (off + i) as f32).collect();
        let work = config_for(AppKind::FlexibleSleep).work_per_iter * work_scale;
        FsShard {
            rank,
            size,
            data,
            sleep_per_iter: work / size as f64 * time_scale(),
        }
    }

    pub fn step(&mut self, _ep: &Endpoint) -> Result<f64> {
        std::thread::sleep(std::time::Duration::from_secs_f64(self.sleep_per_iter));
        Ok(self.sleep_per_iter)
    }

    pub fn to_rows(&self) -> Vec<f32> {
        self.data.clone()
    }

    pub fn from_rows(rank: usize, size: usize, rows: Vec<f32>, work_scale: f64) -> FsShard {
        let work = config_for(AppKind::FlexibleSleep).work_per_iter * work_scale;
        FsShard {
            rank,
            size,
            data: rows,
            sleep_per_iter: work / size as f64 * time_scale(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_splits_by_size() {
        let a = FsShard::init(0, 4, 1.0);
        assert_eq!(a.data.len(), fs_payload_f32s() / 4);
        assert_eq!(a.data[0], 0.0);
        let b = FsShard::init(1, 4, 1.0);
        assert_eq!(b.data[0], (fs_payload_f32s() / 4) as f32);
    }

    #[test]
    fn sleep_scales_inverse_with_size() {
        let a = FsShard::init(0, 1, 1.0);
        let b = FsShard::init(0, 4, 1.0);
        assert!((a.sleep_per_iter / b.sleep_per_iter - 4.0).abs() < 1e-9);
    }
}
