//! Per-rank application state: the numeric shard a rank owns, how to step
//! it (PJRT artifacts + vmpi halo exchange / reductions), and how to
//! serialize it into redistribution rows (§6).
//!
//! Global problem sizes mirror `python/compile/model.py` — the artifacts
//! are lowered for exactly these shapes.

use anyhow::Result;

use super::config::AppKind;
use super::{cg::CgShard, fsleep::FsShard, jacobi::JacobiShard, nbody::NBodyShard};
use crate::runtime::ComputeHandle;
use crate::vmpi::Endpoint;

/// Global CG vector length (== model.N_CG).
pub const N_CG: usize = 16384;
/// Global Jacobi grid (== model.JACOBI_ROWS/COLS).
pub const JACOBI_ROWS: usize = 512;
pub const JACOBI_COLS: usize = 256;
/// Global N-body count (== model.N_NB).
pub const N_NB: usize = 1024;
/// Process counts with AOT artifacts (powers of two; factor-2 resizes stay
/// inside this set).
pub const PROC_COUNTS: [usize; 6] = [1, 2, 4, 8, 16, 32];

/// The per-rank state of one running application.
pub enum AppState {
    Cg(CgShard),
    Jacobi(JacobiShard),
    NBody(NBodyShard),
    Fs(FsShard),
}

impl AppState {
    /// Fresh state for `rank` of `size` (deterministic — every rank
    /// constructs its shard without communication).
    pub fn init(app: AppKind, rank: usize, size: usize, work_scale: f64) -> AppState {
        match app {
            AppKind::Cg => AppState::Cg(CgShard::init(rank, size)),
            AppKind::Jacobi => AppState::Jacobi(JacobiShard::init(rank, size)),
            AppKind::NBody => AppState::NBody(NBodyShard::init(rank, size)),
            AppKind::FlexibleSleep => AppState::Fs(FsShard::init(rank, size, work_scale)),
        }
    }

    /// One outer-loop iteration (a "reconfiguring point" boundary).
    /// Returns a monitor value (residual norm / kinetic energy) that
    /// integration tests check for sanity.
    pub fn step(&mut self, ep: &Endpoint, compute: &ComputeHandle) -> Result<f64> {
        match self {
            AppState::Cg(s) => s.step(ep, compute),
            AppState::Jacobi(s) => s.step(ep, compute),
            AppState::NBody(s) => s.step(ep, compute),
            AppState::Fs(s) => s.step(ep),
        }
    }

    /// Width (in f32s) of one redistribution row.
    pub fn row_f32s(&self) -> usize {
        match self {
            AppState::Cg(_) => CgShard::ROW_F32S,
            AppState::Jacobi(_) => JacobiShard::ROW_F32S,
            AppState::NBody(_) => NBodyShard::ROW_F32S,
            AppState::Fs(_) => FsShard::ROW_F32S,
        }
    }

    /// Serialize the shard into rows (redistribution payload).
    pub fn to_rows(&self) -> Vec<f32> {
        match self {
            AppState::Cg(s) => s.to_rows(),
            AppState::Jacobi(s) => s.to_rows(),
            AppState::NBody(s) => s.to_rows(),
            AppState::Fs(s) => s.to_rows(),
        }
    }

    /// Replicated scalars carried across a resize (e.g. CG's r·r).
    pub fn scalars(&self) -> Vec<f64> {
        match self {
            AppState::Cg(s) => vec![s.rr],
            _ => Vec::new(),
        }
    }

    /// Rebuild the state of `rank`/`size` from redistribution rows.
    pub fn from_rows(
        app: AppKind,
        rank: usize,
        size: usize,
        rows: Vec<f32>,
        scalars: &[f64],
        work_scale: f64,
    ) -> AppState {
        match app {
            AppKind::Cg => AppState::Cg(CgShard::from_rows(rank, size, rows, scalars)),
            AppKind::Jacobi => AppState::Jacobi(JacobiShard::from_rows(rank, size, rows)),
            AppKind::NBody => AppState::NBody(NBodyShard::from_rows(rank, size, rows)),
            AppKind::FlexibleSleep => {
                AppState::Fs(FsShard::from_rows(rank, size, rows, work_scale))
            }
        }
    }

    /// Gather the full solution to rank 0 (integration-test hook).
    pub fn gather_solution(&self, ep: &Endpoint) -> Vec<f32> {
        let local = match self {
            AppState::Cg(s) => s.x.clone(),
            AppState::Jacobi(s) => s.u.clone(),
            AppState::NBody(s) => s.pos.clone(),
            AppState::Fs(_) => Vec::new(),
        };
        ep.allgather_f32(&local)
    }
}

/// Whether `size` has artifacts (FS needs none).
pub fn size_supported(app: AppKind, size: usize) -> bool {
    app == AppKind::FlexibleSleep || PROC_COUNTS.contains(&size)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_divide() {
        for p in PROC_COUNTS {
            assert_eq!(N_CG % p, 0);
            assert_eq!(JACOBI_ROWS % p, 0);
            assert_eq!(N_NB % p, 0);
        }
    }

    #[test]
    fn supported_sizes() {
        assert!(size_supported(AppKind::Cg, 8));
        assert!(!size_supported(AppKind::Cg, 20));
        assert!(size_supported(AppKind::FlexibleSleep, 20));
    }

    #[test]
    fn rows_roundtrip_without_comm() {
        // CG state serializes and deserializes losslessly at same layout.
        let s = AppState::init(AppKind::Cg, 1, 4, 1.0);
        let rows = s.to_rows();
        assert_eq!(rows.len() % s.row_f32s(), 0);
        let scal = s.scalars();
        let s2 = AppState::from_rows(AppKind::Cg, 1, 4, rows.clone(), &scal, 1.0);
        assert_eq!(s2.to_rows(), rows);
        assert_eq!(s2.scalars(), scal);
    }
}
