//! The malleable applications of the evaluation (§7): CG, Jacobi, N-body
//! and the synthetic Flexible Sleep, plus their Table 1 configurations.

pub mod cg;
pub mod config;
pub mod fsleep;
pub mod jacobi;
pub mod nbody;
pub mod state;

pub use config::{config_for, AppConfig, AppKind};
pub use state::{size_supported, AppState};
