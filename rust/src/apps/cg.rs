//! Distributed Conjugate Gradient on `tridiag(-1,2,-1) x = b`.
//!
//! Each rank owns a contiguous shard of the vectors; the matvec needs one
//! halo element per side (exchanged over vmpi); dot products are partial
//! sums reduced with `allreduce_sum`.  All arithmetic runs in the AOT
//! artifacts `cg_phase{1,2,3}_p{P}` (L1/L2); Rust only moves data.

use anyhow::{Context, Result};

use super::state::N_CG;
use crate::runtime::{ComputeHandle, TensorF32};
use crate::vmpi::{bytes_to_f32s, f32s_to_bytes, Endpoint};

/// App-level message tags (below `TAG_RESERVED_BASE`).
const TAG_HALO_TO_LEFT: u64 = 10;
const TAG_HALO_TO_RIGHT: u64 = 11;

pub struct CgShard {
    pub rank: usize,
    pub size: usize,
    pub n_loc: usize,
    pub x: Vec<f32>,
    pub r: Vec<f32>,
    pub p: Vec<f32>,
    /// Global r·r (replicated across ranks after the allreduce).
    pub rr: f64,
}

/// Deterministic right-hand side: every rank can build its shard locally.
pub fn b_at(i: usize) -> f32 {
    ((i as f32) * 0.01).sin()
}

impl CgShard {
    /// x, r, p interleaved per element.
    pub const ROW_F32S: usize = 3;

    pub fn init(rank: usize, size: usize) -> CgShard {
        let n_loc = N_CG / size;
        let off = rank * n_loc;
        let b: Vec<f32> = (0..n_loc).map(|i| b_at(off + i)).collect();
        // x0 = 0 => r0 = b, p0 = r0.
        // rr is the *global* dot; every rank computes the same full sum
        // locally (deterministic, no comm needed at init).
        let rr: f64 = (0..N_CG).map(|i| (b_at(i) as f64) * (b_at(i) as f64)).sum();
        CgShard { rank, size, n_loc, x: vec![0.0; n_loc], r: b.clone(), p: b, rr }
    }

    fn halo_exchange(&self, ep: &Endpoint) -> (f32, f32) {
        // Send my boundary values; receive the neighbours'.
        if self.rank > 0 {
            ep.send(self.rank - 1, TAG_HALO_TO_LEFT, f32s_to_bytes(&[self.p[0]]));
        }
        if self.rank + 1 < self.size {
            ep.send(
                self.rank + 1,
                TAG_HALO_TO_RIGHT,
                f32s_to_bytes(&[self.p[self.n_loc - 1]]),
            );
        }
        let hl = if self.rank > 0 {
            bytes_to_f32s(&ep.recv_from(self.rank - 1, TAG_HALO_TO_RIGHT).payload)[0]
        } else {
            0.0
        };
        let hr = if self.rank + 1 < self.size {
            bytes_to_f32s(&ep.recv_from(self.rank + 1, TAG_HALO_TO_LEFT).payload)[0]
        } else {
            0.0
        };
        (hl, hr)
    }

    /// One CG iteration; returns the residual norm ||r||² (global).
    pub fn step(&mut self, ep: &Endpoint, compute: &ComputeHandle) -> Result<f64> {
        let p = self.size;
        let (hl, hr) = self.halo_exchange(ep);

        // q = A p ; partial p·q
        let out = compute
            .execute(
                &format!("cg_phase1_p{p}"),
                vec![
                    TensorF32::vec(self.p.clone()),
                    TensorF32::scalar(hl),
                    TensorF32::scalar(hr),
                ],
            )
            .context("cg_phase1")?;
        let q = out[0].data.clone();
        let pq = ep.allreduce_sum(out[1].item() as f64);

        let alpha = (self.rr / pq) as f32;
        let out = compute
            .execute(
                &format!("cg_phase2_p{p}"),
                vec![
                    TensorF32::vec(self.x.clone()),
                    TensorF32::vec(self.r.clone()),
                    TensorF32::vec(self.p.clone()),
                    TensorF32::vec(q),
                    TensorF32::scalar(alpha),
                ],
            )
            .context("cg_phase2")?;
        self.x = out[0].data.clone();
        self.r = out[1].data.clone();
        let rr_new = ep.allreduce_sum(out[2].item() as f64);

        let beta = (rr_new / self.rr) as f32;
        self.rr = rr_new;
        let out = compute
            .execute(
                &format!("cg_phase3_p{p}"),
                vec![
                    TensorF32::vec(self.r.clone()),
                    TensorF32::vec(self.p.clone()),
                    TensorF32::scalar(beta),
                ],
            )
            .context("cg_phase3")?;
        self.p = out[0].data.clone();
        Ok(rr_new)
    }

    pub fn to_rows(&self) -> Vec<f32> {
        let mut rows = Vec::with_capacity(self.n_loc * 3);
        for i in 0..self.n_loc {
            rows.push(self.x[i]);
            rows.push(self.r[i]);
            rows.push(self.p[i]);
        }
        rows
    }

    pub fn from_rows(rank: usize, size: usize, rows: Vec<f32>, scalars: &[f64]) -> CgShard {
        let n_loc = rows.len() / 3;
        assert_eq!(n_loc, N_CG / size, "CG shard size mismatch");
        let mut x = Vec::with_capacity(n_loc);
        let mut r = Vec::with_capacity(n_loc);
        let mut p = Vec::with_capacity(n_loc);
        for c in rows.chunks_exact(3) {
            x.push(c[0]);
            r.push(c[1]);
            p.push(c[2]);
        }
        CgShard { rank, size, n_loc, x, r, p, rr: scalars[0] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_deterministic_and_sharded() {
        let a = CgShard::init(0, 4);
        let b = CgShard::init(1, 4);
        assert_eq!(a.n_loc, N_CG / 4);
        assert_eq!(a.rr, b.rr);
        assert_eq!(b.r[0], b_at(N_CG / 4));
    }

    #[test]
    fn rows_roundtrip() {
        let s = CgShard::init(2, 8);
        let rows = s.to_rows();
        let s2 = CgShard::from_rows(2, 8, rows, &[s.rr]);
        assert_eq!(s2.x, s.x);
        assert_eq!(s2.r, s.r);
        assert_eq!(s2.p, s.p);
        assert_eq!(s2.rr, s.rr);
    }
}
