//! Distributed Jacobi relaxation on the 2-D Poisson problem, row-block
//! sharded; one halo row per side.  Compute runs in `jacobi_step_p{P}`.

use anyhow::{Context, Result};

use super::state::{JACOBI_COLS, JACOBI_ROWS};
use crate::runtime::{ComputeHandle, TensorF32};
use crate::vmpi::{bytes_to_f32s, f32s_to_bytes, Endpoint};

const TAG_ROW_TO_UP: u64 = 20;
const TAG_ROW_TO_DOWN: u64 = 21;

pub struct JacobiShard {
    pub rank: usize,
    pub size: usize,
    pub rows_loc: usize,
    /// Local block of u, row-major (rows_loc x COLS).
    pub u: Vec<f32>,
    /// Local block of the right-hand side.
    pub b: Vec<f32>,
}

/// Deterministic RHS.
pub fn b_at(row: usize, col: usize) -> f32 {
    ((row as f32) * 0.05).sin() * ((col as f32) * 0.05).cos()
}

impl JacobiShard {
    /// One u row + one b row per redistribution row.
    pub const ROW_F32S: usize = 2 * JACOBI_COLS;

    pub fn init(rank: usize, size: usize) -> JacobiShard {
        let rows_loc = JACOBI_ROWS / size;
        let r0 = rank * rows_loc;
        let mut b = Vec::with_capacity(rows_loc * JACOBI_COLS);
        for r in 0..rows_loc {
            for c in 0..JACOBI_COLS {
                b.push(b_at(r0 + r, c));
            }
        }
        JacobiShard { rank, size, rows_loc, u: vec![0.0; rows_loc * JACOBI_COLS], b }
    }

    fn halo_exchange(&self, ep: &Endpoint) -> (Vec<f32>, Vec<f32>) {
        let cols = JACOBI_COLS;
        if self.rank > 0 {
            ep.send(self.rank - 1, TAG_ROW_TO_UP, f32s_to_bytes(&self.u[..cols]));
        }
        if self.rank + 1 < self.size {
            let last = &self.u[(self.rows_loc - 1) * cols..];
            ep.send(self.rank + 1, TAG_ROW_TO_DOWN, f32s_to_bytes(last));
        }
        let top = if self.rank > 0 {
            bytes_to_f32s(&ep.recv_from(self.rank - 1, TAG_ROW_TO_DOWN).payload)
        } else {
            vec![0.0; cols]
        };
        let bot = if self.rank + 1 < self.size {
            bytes_to_f32s(&ep.recv_from(self.rank + 1, TAG_ROW_TO_UP).payload)
        } else {
            vec![0.0; cols]
        };
        (top, bot)
    }

    /// One sweep; returns the global squared update norm.
    pub fn step(&mut self, ep: &Endpoint, compute: &ComputeHandle) -> Result<f64> {
        let p = self.size;
        let (top, bot) = self.halo_exchange(ep);
        let out = compute
            .execute(
                &format!("jacobi_step_p{p}"),
                vec![
                    TensorF32::new(vec![self.rows_loc, JACOBI_COLS], self.u.clone()),
                    TensorF32::new(vec![1, JACOBI_COLS], top),
                    TensorF32::new(vec![1, JACOBI_COLS], bot),
                    TensorF32::new(vec![self.rows_loc, JACOBI_COLS], self.b.clone()),
                ],
            )
            .context("jacobi_step")?;
        self.u = out[0].data.clone();
        Ok(ep.allreduce_sum(out[1].item() as f64))
    }

    pub fn to_rows(&self) -> Vec<f32> {
        let cols = JACOBI_COLS;
        let mut rows = Vec::with_capacity(self.rows_loc * 2 * cols);
        for r in 0..self.rows_loc {
            rows.extend_from_slice(&self.u[r * cols..(r + 1) * cols]);
            rows.extend_from_slice(&self.b[r * cols..(r + 1) * cols]);
        }
        rows
    }

    pub fn from_rows(rank: usize, size: usize, rows: Vec<f32>) -> JacobiShard {
        let cols = JACOBI_COLS;
        let rows_loc = rows.len() / (2 * cols);
        assert_eq!(rows_loc, JACOBI_ROWS / size, "Jacobi shard size mismatch");
        let mut u = Vec::with_capacity(rows_loc * cols);
        let mut b = Vec::with_capacity(rows_loc * cols);
        for ch in rows.chunks_exact(2 * cols) {
            u.extend_from_slice(&ch[..cols]);
            b.extend_from_slice(&ch[cols..]);
        }
        JacobiShard { rank, size, rows_loc, u, b }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shards_cover_grid() {
        let s0 = JacobiShard::init(0, 4);
        let s3 = JacobiShard::init(3, 4);
        assert_eq!(s0.rows_loc, 128);
        // compare with tolerance: LLVM may const-fold sin/cos at higher
        // precision than the runtime libm call
        assert!((s0.b[0] - b_at(0, 0)).abs() < 1e-6);
        assert!((s3.b[0] - b_at(384, 0)).abs() < 1e-6);
    }

    #[test]
    fn rows_roundtrip() {
        let mut s = JacobiShard::init(1, 8);
        s.u.iter_mut().enumerate().for_each(|(i, v)| *v = i as f32);
        let s2 = JacobiShard::from_rows(1, 8, s.to_rows());
        assert_eq!(s2.u, s.u);
        assert_eq!(s2.b, s.b);
    }
}
