//! Cost models for the discrete-event mode: scheduling-decision times and
//! resize (data-redistribution) times.
//!
//! Calibrated against the paper's measurements (Fig. 3, Table 2), since
//! those costs come from Slurm RPC round-trips and InfiniBand transfers we
//! do not have.  The live mode (overhead study) measures our own stack's
//! real costs; the DES uses *paper-scale* costs so workload dynamics match
//! the evaluation's regime.  Both are reported in EXPERIMENTS.md.

use crate::util::rng::Rng;

/// Scheduling/action cost model.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// "No action" decision time: the paper's Table 2 reports
    /// avg ≈ 9.4 ms, σ ≈ 10 ms, min 0.3 ms, max ~0.2 s.
    pub no_action_mean: f64,
    pub no_action_std: f64,
    /// Base expand/shrink protocol time (scheduling + spawn/drain):
    /// Table 2 sync ≈ 0.42 s with small spread.
    pub action_base: f64,
    pub action_std: f64,
    /// Per-node increment of the scheduling step (Fig. 3(a) shows a slight
    /// growth with the number of nodes involved).
    pub per_node: f64,
    /// Modeled redistribution bandwidth per receiving process (bytes/s) —
    /// FDR10 InfiniBand ballpark.
    pub bw_per_rank: f64,
    /// Per-synchronization-stage cost of the shrink drain (§5.2.2: "shrinks
    /// involve much more synchronization among processes").
    pub shrink_sync: f64,
    /// Resizer-job wait deadline in the asynchronous mode (§5.2.1; the
    /// Table 2 async expand max is ≈ 40 s).
    pub expand_timeout: f64,
    /// Fraction of the scheduling step modeled as the allocation-grant
    /// phase of a resize transaction; the remainder is the spawn phase.
    /// Only the multi-phase (fault-injected) resize path reads it — the
    /// phase durations sum exactly to `action_sched` + `resize_transfer`,
    /// so a fault-free transaction commits at the same instant the legacy
    /// single-event resize would have.
    pub grant_frac: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            no_action_mean: 0.0094,
            no_action_std: 0.0100,
            action_base: 0.40,
            action_std: 0.04,
            per_node: 0.0012,
            bw_per_rank: 1.5e9,
            shrink_sync: 0.08,
            expand_timeout: 40.0,
            grant_frac: 0.3,
        }
    }
}

impl CostModel {
    /// Decision time for a "no action" outcome.
    pub fn no_action(&self, rng: &mut Rng) -> f64 {
        // Right-skewed like the measured distribution: lognormal fitted to
        // mean/std, clipped to the observed band.
        let m = self.no_action_mean;
        let s = self.no_action_std;
        let sigma2 = (1.0 + (s * s) / (m * m)).ln();
        let mu = m.ln() - sigma2 / 2.0;
        rng.lognormal(mu, sigma2.sqrt()).clamp(0.0003, 0.21)
    }

    /// Scheduling time of an expand/shrink decision involving
    /// `nodes_delta` nodes (Fig. 3(a)).
    pub fn action_sched(&self, nodes_delta: usize, rng: &mut Rng) -> f64 {
        (rng.normal(self.action_base, self.action_std) + self.per_node * nodes_delta as f64)
            .max(0.2)
    }

    /// Data-redistribution time (Fig. 3(b)): chunks move concurrently, so
    /// the wall time is the per-receiving-rank share; shrinks add a
    /// synchronization term growing with the merge factor.
    pub fn resize_transfer(&self, bytes_total: f64, from: usize, to: usize) -> f64 {
        let recv_ranks = to.max(1);
        let transfer = bytes_total / recv_ranks as f64 / self.bw_per_rank;
        if to < from {
            let factor = (from / to.max(1)).max(1);
            let stages = (factor as f64).log2().ceil().max(1.0);
            transfer + self.shrink_sync * stages
        } else {
            transfer
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn no_action_distribution_matches_table2() {
        let m = CostModel::default();
        let mut rng = Rng::new(5);
        let s = Summary::from_iter((0..20_000).map(|_| m.no_action(&mut rng)));
        assert!((s.mean() - 0.0094).abs() < 0.004, "mean {}", s.mean());
        assert!(s.max() <= 0.21 && s.min() >= 0.0003);
    }

    #[test]
    fn action_sched_grows_with_nodes() {
        let m = CostModel::default();
        let mut rng = Rng::new(6);
        let small = Summary::from_iter((0..2000).map(|_| m.action_sched(2, &mut rng)));
        let big = Summary::from_iter((0..2000).map(|_| m.action_sched(64, &mut rng)));
        assert!(big.mean() > small.mean());
        assert!((small.mean() - 0.40).abs() < 0.05);
    }

    #[test]
    fn transfer_shapes_match_fig3b() {
        let m = CostModel::default();
        let gb = 1e9;
        // More receiving processes => shorter resize (1->2 vs 32->64).
        let t_1_2 = m.resize_transfer(gb, 1, 2);
        let t_32_64 = m.resize_transfer(gb, 32, 64);
        assert!(t_1_2 > t_32_64 * 4.0);
        // Shrinks cost more than the mirror expands (sync overhead).
        let t_16_2 = m.resize_transfer(gb, 16, 2);
        let t_2_16 = m.resize_transfer(gb, 2, 16);
        assert!(t_16_2 > t_2_16);
        // Bigger shrink gap => more sync stages.
        let t_64_2 = m.resize_transfer(gb, 64, 2);
        let t_4_2 = m.resize_transfer(gb, 4, 2);
        assert!(t_64_2 > t_4_2);
    }
}
