//! The discrete-event execution mode: the paper's 50–400-job workloads
//! (fixed vs flexible, sync vs async) processed through the real RMS in
//! virtual time with calibrated cost models.

mod engine;
mod execmodel;
mod sched_cost;

pub use engine::{ActionStats, DesConfig, Engine, RunResult};
pub use execmodel::ExecModel;
pub use sched_cost::CostModel;
