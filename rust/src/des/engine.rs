//! The discrete-event workload engine: processes a workload through the
//! *real* RMS state machine in virtual time, with modeled iteration and
//! reconfiguration costs (see [`super::sched_cost`], [`super::execmodel`]).
//!
//! The same `Rms` code drives both this engine and the live threaded mode
//! — the DES only replaces wall-clock execution with the calibrated model,
//! which is what lets the paper's 9-hour, 400-job workloads run in
//! milliseconds (DESIGN.md §2).
//!
//! ## Complexity budget
//!
//! One simulated event costs O(active jobs), independent of how many jobs
//! have already completed:
//!
//! * Per-job simulation state lives in a **dense slab** (`Vec<SimJob>`
//!   plus an id→slot table) instead of a hash map; a `SimJob` carries a
//!   copyable [`SimSpec`] extracted from the `JobSpec` — starting a job
//!   allocates no strings and never clones the spec.
//! * `iter_time` is memoized per (job, procs): the `powf` in the
//!   execution model is recomputed only when a resize changes the
//!   process count.
//! * Arrival handling borrows specs straight from the caller's
//!   `WorkloadSpec`; exactly one clone per job is made — the one the RMS
//!   must own.
//!
//! `RunResult::events` counts every processed event so throughput
//! benchmarks (`benches/hotpath_scale.rs`) can report events/s.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::execmodel::ExecModel;
use super::sched_cost::CostModel;
use crate::dmr::{Inhibitor, SchedMode};
use crate::rms::{Action, DmrOutcome, DmrRequest, Rms, RmsConfig};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::workload::{JobSpec, WorkloadSpec};
use crate::{JobId, Time};

/// DES configuration.
#[derive(Debug, Clone)]
pub struct DesConfig {
    pub rms: RmsConfig,
    pub mode: SchedMode,
    pub costs: CostModel,
    pub exec: ExecModel,
    pub seed: u64,
}

impl Default for DesConfig {
    fn default() -> Self {
        DesConfig {
            rms: RmsConfig::default(),
            mode: SchedMode::Sync,
            costs: CostModel::default(),
            exec: ExecModel::default(),
            seed: 0xD41,
        }
    }
}

/// Per-action timing statistics (Table 2).
#[derive(Debug, Clone, Default)]
pub struct ActionStats {
    pub no_action: Summary,
    pub expand: Summary,
    pub shrink: Summary,
    pub expand_aborts: u64,
}

/// Everything measured from one workload run.
pub struct RunResult {
    pub label: String,
    pub rms: Rms,
    pub makespan: Time,
    pub first_submit: Time,
    pub actions: ActionStats,
    pub user_jobs: usize,
    /// Discrete events processed (arrivals, checks, completions, resize
    /// commits, retries — including stale ones).  Deterministic for a
    /// given workload + config; the denominator of events/s.
    pub events: u64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum EvKind {
    Arrival(usize),
    Check,
    Complete,
    ResizeDone { to: usize, expand: bool, began: Time },
    ExpandRetry { to: usize, began: Time, deadline: Time },
}

#[derive(Debug, Clone, Copy)]
struct Ev {
    t: Time,
    seq: u64,
    job: JobId,
    epoch: u64,
    kind: EvKind,
}

// Order by time (then sequence) for the min-heap.
impl PartialEq for Ev {
    fn eq(&self, o: &Self) -> bool {
        self.t == o.t && self.seq == o.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Ev {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&o.t).then(self.seq.cmp(&o.seq))
    }
}

/// The copyable subset of a [`JobSpec`] the simulation needs per event —
/// extracting it once at start time keeps the slab string-free and makes
/// iteration-time math allocation-free.
#[derive(Debug, Clone, Copy)]
struct SimSpec {
    iterations: u32,
    /// Pre-resolved `spec.work_per_iter()` (same float ops, same value).
    work_per_iter: f64,
    alpha: f64,
    sched_period: f64,
    min_procs: usize,
    max_procs: usize,
    pref_procs: Option<usize>,
    factor: usize,
}

impl SimSpec {
    fn of(spec: &JobSpec) -> Self {
        SimSpec {
            iterations: spec.iterations,
            work_per_iter: spec.work_per_iter(),
            alpha: spec.alpha,
            sched_period: spec.sched_period,
            min_procs: spec.min_procs,
            max_procs: spec.max_procs,
            pref_procs: spec.pref_procs,
            factor: spec.factor,
        }
    }
}

struct SimJob {
    spec: SimSpec,
    procs: usize,
    iters_done: f64,
    last_t: Time,
    running: bool,
    epoch: u64,
    inhibitor: Inhibitor,
    pending_async: Option<Action>,
    /// Memoized `iter_time` at `memo_procs` processes.
    memo_procs: usize,
    memo_iter: f64,
}

impl SimJob {
    fn remaining(&self) -> f64 {
        (self.spec.iterations as f64 - self.iters_done).max(0.0)
    }

    /// Seconds per iteration at the current size; recomputed only when a
    /// resize changed `procs` since the last call.
    fn iter_time(&mut self, exec: &ExecModel) -> f64 {
        if self.memo_procs != self.procs {
            self.memo_iter =
                exec.iter_time_raw(self.spec.work_per_iter, self.spec.alpha, self.procs);
            self.memo_procs = self.procs;
        }
        self.memo_iter
    }
}

const NO_SLOT: u32 = u32::MAX;

/// The engine.
pub struct Engine {
    cfg: DesConfig,
    rms: Rms,
    rng: Rng,
    heap: BinaryHeap<Reverse<Ev>>,
    /// Dense per-job simulation slab, one slot per started user job.
    sims: Vec<SimJob>,
    /// JobId → slab slot (`NO_SLOT` = not simulated: resizers, unstarted).
    slot_of: Vec<u32>,
    now: Time,
    seq: u64,
    events: u64,
    actions: ActionStats,
    done: usize,
    user_jobs: usize,
    first_submit: Time,
}

impl Engine {
    pub fn new(cfg: DesConfig) -> Self {
        let rms = Rms::new(cfg.rms.clone());
        let rng = Rng::new(cfg.seed);
        Engine {
            cfg,
            rms,
            rng,
            heap: BinaryHeap::new(),
            sims: Vec::new(),
            slot_of: Vec::new(),
            now: 0.0,
            seq: 0,
            events: 0,
            actions: ActionStats::default(),
            done: 0,
            user_jobs: 0,
            first_submit: f64::INFINITY,
        }
    }

    /// Direct access to the machine (failure-injection tests mark nodes
    /// down before arrivals).
    pub fn cluster_mut(&mut self) -> &mut crate::cluster::Cluster {
        &mut self.rms.cluster
    }

    fn push(&mut self, t: Time, job: JobId, epoch: u64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Reverse(Ev { t, seq: self.seq, job, epoch, kind }));
    }

    fn slot(&self, id: JobId) -> Option<usize> {
        match self.slot_of.get(id as usize) {
            Some(&s) if s != NO_SLOT => Some(s as usize),
            _ => None,
        }
    }

    fn insert_sim(&mut self, id: JobId, sim: SimJob) {
        let idx = id as usize;
        if self.slot_of.len() <= idx {
            self.slot_of.resize(idx + 1, NO_SLOT);
        }
        debug_assert_eq!(self.slot_of[idx], NO_SLOT, "job {id} simulated twice");
        self.slot_of[idx] = self.sims.len() as u32;
        self.sims.push(sim);
    }

    /// Run a workload to completion; returns the measurements.
    pub fn run(mut self, workload: &WorkloadSpec, label: &str) -> RunResult {
        self.user_jobs = workload.jobs.len();
        self.sims.reserve(self.user_jobs);
        for (i, spec) in workload.jobs.iter().enumerate() {
            self.push(spec.submit_time, 0, 0, EvKind::Arrival(i));
        }

        while let Some(Reverse(ev)) = self.heap.pop() {
            debug_assert!(ev.t >= self.now - 1e-9, "time went backwards");
            self.now = ev.t.max(self.now);
            self.events += 1;
            match ev.kind {
                EvKind::Arrival(i) => self.on_arrival(&workload.jobs[i]),
                EvKind::Check => self.on_check(ev),
                EvKind::Complete => self.on_complete(ev),
                EvKind::ResizeDone { to, expand, began } => {
                    self.on_resize_done(ev, to, expand, began)
                }
                EvKind::ExpandRetry { to, began, deadline } => {
                    self.on_expand_retry(ev, to, began, deadline)
                }
            }
            if self.done == self.user_jobs {
                break;
            }
        }
        assert_eq!(self.done, self.user_jobs, "workload did not drain");

        RunResult {
            label: label.to_string(),
            makespan: self.now,
            first_submit: self.first_submit,
            actions: self.actions,
            user_jobs: self.user_jobs,
            events: self.events,
            rms: self.rms,
        }
    }

    // ------------------------------------------------------------------

    fn on_arrival(&mut self, spec: &JobSpec) {
        self.first_submit = self.first_submit.min(self.now);
        // Estimate for backfill: duration at the requested size.
        let est = self.cfg.exec.exec_time(spec, spec.procs);
        let id = self.rms.submit(spec.clone(), self.now);
        self.rms.set_expected_end(id, self.now + est);
        self.try_schedule();
    }

    fn try_schedule(&mut self) {
        self.rms.schedule(self.now);
        let started = self.rms.take_recent_starts();
        for s in started {
            let (spec, malleable) = match self.rms.job(s.job) {
                Some(j) if !j.is_resizer => (SimSpec::of(&j.spec), j.spec.malleable),
                _ => continue,
            };
            let procs = s.nodes.len();
            let iter_t = self.cfg.exec.iter_time_raw(spec.work_per_iter, spec.alpha, procs);
            let period = spec.sched_period;
            let sim = SimJob {
                spec,
                procs,
                iters_done: 0.0,
                last_t: self.now,
                running: true,
                epoch: 0,
                inhibitor: Inhibitor::new(period),
                pending_async: None,
                memo_procs: procs,
                memo_iter: iter_t,
            };
            let complete_at = self.now + sim.remaining() * iter_t;
            self.rms.set_expected_end(s.job, complete_at);
            let check_at = self.now + iter_t.max(period).max(1e-3);
            self.insert_sim(s.job, sim);
            self.push(complete_at, s.job, 0, EvKind::Complete);
            if malleable {
                self.push(check_at, s.job, 0, EvKind::Check);
            }
        }
    }

    fn progress(&mut self, slot: usize) {
        let exec = &self.cfg.exec;
        let now = self.now;
        let j = &mut self.sims[slot];
        if j.running {
            let it = j.iter_time(exec);
            j.iters_done = (j.iters_done + (now - j.last_t) / it).min(j.spec.iterations as f64);
        }
        j.last_t = now;
    }

    fn on_complete(&mut self, ev: Ev) {
        let Some(slot) = self.slot(ev.job) else { return };
        if self.sims[slot].epoch != ev.epoch || !self.sims[slot].running {
            return; // stale
        }
        self.progress(slot);
        let j = &mut self.sims[slot];
        debug_assert!(j.remaining() < 1e-6, "completion with work left");
        j.running = false;
        j.epoch += 1;
        self.rms.finish(ev.job, self.now);
        self.done += 1;
        self.try_schedule();
    }

    fn on_check(&mut self, ev: Ev) {
        let Some(slot) = self.slot(ev.job) else { return };
        if self.sims[slot].epoch != ev.epoch || !self.sims[slot].running {
            return;
        }
        self.progress(slot);
        if self.sims[slot].remaining() <= 1e-9 {
            return; // completion event will fire at this same instant
        }
        let spec = self.sims[slot].spec;
        let req = DmrRequest {
            min: spec.min_procs,
            max: spec.max_procs,
            pref: spec.pref_procs,
            factor: spec.factor,
        };

        if !self.sims[slot].inhibitor.allow(self.now) {
            let epoch = self.sims[slot].epoch;
            let next = self.next_check_time(slot);
            self.push(next, ev.job, epoch, EvKind::Check);
            return;
        }

        let mode = self.cfg.mode;
        let outcome: Result<DmrOutcome, usize> = match mode {
            SchedMode::Sync => Ok(self.rms.dmr_check(ev.job, &req, self.now)),
            SchedMode::Async => {
                let prev = self.sims[slot].pending_async.take();
                let next_decision = self.rms.dmr_peek(ev.job, &req, self.now);
                self.sims[slot].pending_async = Some(next_decision);
                match prev {
                    None | Some(Action::NoAction) => Ok(DmrOutcome::NoAction),
                    Some(a) => match self.rms.dmr_apply(ev.job, a, self.now) {
                        Ok(o) => Ok(o),
                        Err(()) => {
                            // Stale expansion: resizer job waits (§5.2.1).
                            let to = match a {
                                Action::Expand { to } => to,
                                _ => unreachable!(),
                            };
                            Err(to)
                        }
                    },
                }
            }
        };

        match outcome {
            Ok(DmrOutcome::NoAction) => {
                let cost = self.cfg.costs.no_action(&mut self.rng);
                self.actions.no_action.push(cost);
                // The ~10 ms decision overhead is recorded (Table 2) but
                // not charged against progress: charging it would require
                // rescheduling the completion event for a <0.1 % effect
                // (the inhibitor spaces the calls 15 s apart).
                let epoch = self.sims[slot].epoch;
                let next = self.next_check_time(slot).max(self.now + cost);
                self.push(next, ev.job, epoch, EvKind::Check);
            }
            Ok(DmrOutcome::Expand { to, .. }) => self.begin_resize(slot, ev.job, to, true),
            Ok(DmrOutcome::Shrink { to, .. }) => self.begin_resize(slot, ev.job, to, false),
            Err(to) => {
                // Pause and retry until the deadline (async wait hazard).
                let j = &mut self.sims[slot];
                j.running = false;
                j.epoch += 1;
                let epoch = j.epoch;
                let deadline = self.now + self.cfg.costs.expand_timeout;
                self.push(
                    self.now + 1.0,
                    ev.job,
                    epoch,
                    EvKind::ExpandRetry { to, began: self.now, deadline },
                );
            }
        }
    }

    /// Pause the job and schedule the commit of a granted resize.
    fn begin_resize(&mut self, slot: usize, id: JobId, to: usize, expand: bool) {
        let began = self.now;
        let (from, epoch) = {
            let j = &mut self.sims[slot];
            let from = j.procs;
            j.running = false;
            j.epoch += 1;
            (from, j.epoch)
        };
        let delta = to.abs_diff(from);
        let sched = self.cfg.costs.action_sched(delta, &mut self.rng);
        let transfer = self
            .cfg
            .costs
            .resize_transfer(self.cfg.exec.resize_bytes, from, to);
        self.push(
            self.now + sched + transfer,
            id,
            epoch,
            EvKind::ResizeDone { to, expand, began },
        );
    }

    fn on_resize_done(&mut self, ev: Ev, to: usize, expand: bool, began: Time) {
        let Some(slot) = self.slot(ev.job) else { return };
        if self.sims[slot].epoch != ev.epoch {
            return;
        }
        if expand {
            self.rms.commit_resize(ev.job, self.now);
            self.actions.expand.push(self.now - began);
        } else {
            self.rms.commit_shrink_to(ev.job, to, self.now);
            self.actions.shrink.push(self.now - began);
        }
        let exec = &self.cfg.exec;
        let now = self.now;
        let j = &mut self.sims[slot];
        j.procs = to;
        j.running = true;
        j.last_t = now;
        j.epoch += 1;
        let epoch = j.epoch;
        let iter_t = j.iter_time(exec);
        let complete_at = now + j.remaining() * iter_t;
        self.rms.set_expected_end(ev.job, complete_at);
        self.push(complete_at, ev.job, epoch, EvKind::Complete);
        let next = self.next_check_time(slot);
        self.push(next, ev.job, epoch, EvKind::Check);
        // A shrink may let queued jobs start.
        self.try_schedule();
    }

    fn on_expand_retry(&mut self, ev: Ev, to: usize, began: Time, deadline: Time) {
        let Some(slot) = self.slot(ev.job) else { return };
        if self.sims[slot].epoch != ev.epoch {
            return;
        }
        match self.rms.dmr_apply(ev.job, Action::Expand { to }, self.now) {
            Ok(DmrOutcome::Expand { .. }) => {
                // Resources appeared: pay the protocol costs now; the
                // elapsed wait is part of the measured expand time.
                let (from, epoch) = {
                    let j = &mut self.sims[slot];
                    j.epoch += 1;
                    (j.procs, j.epoch)
                };
                let delta = to.abs_diff(from);
                let sched = self.cfg.costs.action_sched(delta, &mut self.rng);
                let transfer = self
                    .cfg
                    .costs
                    .resize_transfer(self.cfg.exec.resize_bytes, from, to);
                self.push(
                    self.now + sched + transfer,
                    ev.job,
                    epoch,
                    EvKind::ResizeDone { to, expand: true, began },
                );
            }
            _ => {
                if self.now + 1.0 <= deadline {
                    let epoch = ev.epoch;
                    self.push(
                        self.now + 1.0,
                        ev.job,
                        epoch,
                        EvKind::ExpandRetry { to, began, deadline },
                    );
                } else {
                    // Timed out: abort the action and resume (§5.2.1).
                    self.actions.expand.push(self.now - began);
                    self.actions.expand_aborts += 1;
                    let exec = &self.cfg.exec;
                    let now = self.now;
                    let j = &mut self.sims[slot];
                    j.running = true;
                    j.last_t = now;
                    j.epoch += 1;
                    let epoch = j.epoch;
                    let iter_t = j.iter_time(exec);
                    let complete_at = now + j.remaining() * iter_t;
                    self.rms.set_expected_end(ev.job, complete_at);
                    self.push(complete_at, ev.job, epoch, EvKind::Complete);
                    let next = self.next_check_time(slot);
                    self.push(next, ev.job, epoch, EvKind::Check);
                }
            }
        }
    }

    fn next_check_time(&mut self, slot: usize) -> Time {
        let exec = &self.cfg.exec;
        let j = &mut self.sims[slot];
        let iter_t = j.iter_time(exec);
        // Reconfiguring points are iteration boundaries, rate-limited by
        // the checking inhibitor.
        self.now + iter_t.max(j.spec.sched_period).max(1e-3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    #[test]
    fn single_fixed_job_runs_exact_time() {
        let w = workload::generate(1, 1).as_fixed();
        let spec = &w.jobs[0];
        let want = ExecModel::default().exec_time(spec, spec.procs);
        let r = Engine::new(DesConfig::default()).run(&w, "one");
        let job = r.rms.jobs().next().unwrap();
        let exec = job.exec_time().unwrap();
        assert!((exec - want).abs() < 1e-6, "exec {exec} vs {want}");
        assert_eq!(r.user_jobs, 1);
        assert!(r.events >= 2, "at least arrival + completion");
    }

    #[test]
    fn fixed_workload_drains_and_is_deterministic() {
        let w = workload::generate(30, 7).as_fixed();
        let a = Engine::new(DesConfig::default()).run(&w, "a");
        let b = Engine::new(DesConfig::default()).run(&w, "b");
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.events, b.events, "event count is deterministic");
        assert_eq!(a.rms.log.digest(), b.rms.log.digest(), "event logs bit-identical");
        assert_eq!(a.rms.completed_jobs(), 30);
        assert!(a.rms.check_invariants());
    }

    #[test]
    fn flexible_beats_fixed_makespan() {
        let w = workload::generate(30, 7);
        let fixed = Engine::new(DesConfig::default()).run(&w.as_fixed(), "fixed");
        let flex = Engine::new(DesConfig::default()).run(&w, "flexible");
        assert_eq!(flex.rms.completed_jobs(), 30);
        assert!(
            flex.makespan < fixed.makespan,
            "flexible {} !< fixed {}",
            flex.makespan,
            fixed.makespan
        );
        // Reconfigurations actually happened.
        assert!(flex.actions.shrink.count() + flex.actions.expand.count() > 0);
        assert!(flex.rms.check_invariants());
    }

    #[test]
    fn async_mode_drains() {
        let w = workload::generate(20, 9);
        let cfg = DesConfig { mode: SchedMode::Async, ..Default::default() };
        let r = Engine::new(cfg).run(&w, "async");
        assert_eq!(r.rms.completed_jobs(), 20);
        assert!(r.rms.check_invariants());
    }
}
